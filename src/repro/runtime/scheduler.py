"""Serving scheduler: request batching, straggler hedging, elastic replicas.

Simulation-grade but real control logic (unit-tested), designed for the
1000+-node story:

* ``MicroBatcher`` — admission queue -> fixed-size decode batches with a
  deadline; late requests ride the next batch (continuous batching lite).
* ``StragglerMitigator`` — per-replica latency EWMA + p95; hedges a request
  to the second-best replica when the primary exceeds its hedge deadline
  (tail-at-scale).  The paper's edge/cloud tiers are just two replicas here.
* ``ElasticPool`` — replicas join/leave; on loss of the edge tier the
  RoboECC controller's ``replan()`` degrades to cloud-only (split=0), on
  re-join it re-runs Alg. 1.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class Request:
    rid: int
    arrival_s: float
    prompt_len: int
    max_new: int = 16


@dataclasses.dataclass
class Batch:
    requests: List[Request]
    formed_s: float


class MicroBatcher:
    def __init__(self, batch_size: int, max_wait_s: float):
        self.batch_size = batch_size
        self.max_wait_s = max_wait_s
        self.queue: deque[Request] = deque()

    def add(self, req: Request) -> None:
        self.queue.append(req)

    def maybe_form(self, now_s: float) -> Optional[Batch]:
        if not self.queue:
            return None
        oldest = self.queue[0].arrival_s
        if (len(self.queue) >= self.batch_size
                or now_s - oldest >= self.max_wait_s):
            return self.flush(now_s)
        return None

    def flush(self, now_s: float) -> Optional[Batch]:
        """Drain up to one batch regardless of size/deadline (used at tick
        boundaries and on replica teardown; call repeatedly to empty)."""
        if not self.queue:
            return None
        take = [self.queue.popleft()
                for _ in range(min(self.batch_size, len(self.queue)))]
        return Batch(take, now_s)


class LatencyStats:
    """EWMA mean + streaming p95 over a sliding window."""

    def __init__(self, alpha: float = 0.2, window: int = 64):
        self.alpha = alpha
        self.mean: Optional[float] = None
        self.samples: deque = deque(maxlen=window)

    def observe(self, s: float) -> None:
        self.mean = s if self.mean is None else \
            (1 - self.alpha) * self.mean + self.alpha * s
        self.samples.append(s)

    def p95(self) -> float:
        if not self.samples:
            return float("inf")
        xs = sorted(self.samples)
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]


@dataclasses.dataclass
class HedgeOutcome:
    replica: str
    latency_s: float
    hedged: bool
    winner: str


class StragglerMitigator:
    def __init__(self, hedge_quantile: float = 0.95):
        self.stats: Dict[str, LatencyStats] = defaultdict(LatencyStats)
        self.hedge_quantile = hedge_quantile

    def pick_primary(self, replicas: List[str]) -> str:
        def key(r):
            m = self.stats[r].mean
            return m if m is not None else 0.0
        return min(replicas, key=key)

    def run(self, replicas: List[str],
            exec_fn: Callable[[str], float]) -> HedgeOutcome:
        """exec_fn(replica) -> latency seconds (simulated or measured).
        Hedge: if primary exceeds its p95, launch on backup; winner = min."""
        primary = self.pick_primary(replicas)
        t_primary = exec_fn(primary)
        deadline = self.stats[primary].p95()
        hedged, winner, lat = False, primary, t_primary
        if t_primary > deadline and len(replicas) > 1:
            backup = self.pick_primary([r for r in replicas if r != primary])
            t_backup = deadline + exec_fn(backup)  # hedge fires at deadline
            hedged = True
            if t_backup < t_primary:
                winner, lat = backup, t_backup
        self.stats[primary].observe(t_primary)
        return HedgeOutcome(primary, lat, hedged, winner)


class ElasticPool:
    """Tracks live replicas via heartbeats; triggers replan callbacks."""

    def __init__(self, on_change: Optional[Callable[[List[str]], None]] = None,
                 timeout_s: float = 1.0):
        self.last_beat: Dict[str, float] = {}
        self.timeout_s = timeout_s
        self.on_change = on_change
        self._live: List[str] = []

    def heartbeat(self, replica: str, now_s: float) -> None:
        self.last_beat[replica] = now_s
        self._refresh(now_s)

    def _refresh(self, now_s: float) -> None:
        live = sorted(r for r, t in self.last_beat.items()
                      if now_s - t <= self.timeout_s)
        if live != self._live:
            self._live = live
            if self.on_change:
                self.on_change(live)

    def live(self, now_s: float) -> List[str]:
        self._refresh(now_s)
        return list(self._live)
