"""Serving scheduler: request batching, straggler hedging, elastic replicas.

Simulation-grade but real control logic (unit-tested), designed for the
1000+-node story:

* ``MicroBatcher`` — admission queue -> fixed-size decode batches with a
  deadline; late requests ride the next batch (continuous batching lite).
* ``StragglerMitigator`` — per-replica latency EWMA + p95; hedges a request
  to the second-best replica when the primary exceeds its hedge deadline
  (tail-at-scale).  The paper's edge/cloud tiers are just two replicas here.
* ``ElasticPool`` — replicas join/leave; on loss of the edge tier the
  RoboECC controller's ``replan()`` degrades to cloud-only (split=0), on
  re-join it re-runs Alg. 1.
* ``ContinuousBatcher`` — vLLM-style continuous batching: arriving
  prefills are admitted into the in-flight batch as slots free up, each
  slot's KV occupancy ramps from a reserved fraction to its full
  footprint as the request executes, and the youngest slot is preempted
  (requeued with a full recompute) when aggregate occupancy would cross
  the replica KV budget.  ``MicroBatcher`` stays as the fixed-batch
  degenerate/control case (``FleetConfig(continuous=False)``).
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class Request:
    rid: int
    arrival_s: float
    prompt_len: int
    max_new: int = 16


@dataclasses.dataclass
class Batch:
    requests: List[Request]
    formed_s: float


class MicroBatcher:
    def __init__(self, batch_size: int, max_wait_s: float):
        self.batch_size = batch_size
        self.max_wait_s = max_wait_s
        self.queue: deque[Request] = deque()

    def add(self, req: Request) -> None:
        self.queue.append(req)

    def maybe_form(self, now_s: float) -> Optional[Batch]:
        if not self.queue:
            return None
        oldest = self.queue[0].arrival_s
        if (len(self.queue) >= self.batch_size
                or now_s - oldest >= self.max_wait_s):
            return self.flush(now_s)
        return None

    def flush(self, now_s: float) -> Optional[Batch]:
        """Drain up to one batch regardless of size/deadline (used at tick
        boundaries and on replica teardown; call repeatedly to empty)."""
        if not self.queue:
            return None
        take = [self.queue.popleft()
                for _ in range(min(self.batch_size, len(self.queue)))]
        return Batch(take, now_s)


class LatencyStats:
    """EWMA mean + streaming p95 over a sliding window."""

    def __init__(self, alpha: float = 0.2, window: int = 64):
        self.alpha = alpha
        self.mean: Optional[float] = None
        self.samples: deque = deque(maxlen=window)

    def observe(self, s: float) -> None:
        self.mean = s if self.mean is None else \
            (1 - self.alpha) * self.mean + self.alpha * s
        self.samples.append(s)

    def p95(self) -> float:
        if not self.samples:
            return float("inf")
        xs = sorted(self.samples)
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]


@dataclasses.dataclass
class HedgeOutcome:
    replica: str
    latency_s: float
    hedged: bool
    winner: str


class StragglerMitigator:
    def __init__(self, hedge_quantile: float = 0.95):
        self.stats: Dict[str, LatencyStats] = defaultdict(LatencyStats)
        self.hedge_quantile = hedge_quantile

    def pick_primary(self, replicas: List[str]) -> str:
        def key(r):
            m = self.stats[r].mean
            return m if m is not None else 0.0
        return min(replicas, key=key)

    def run(self, replicas: List[str],
            exec_fn: Callable[[str], float]) -> HedgeOutcome:
        """exec_fn(replica) -> latency seconds (simulated or measured).
        Hedge: if primary exceeds its p95, launch on backup; winner = min."""
        primary = self.pick_primary(replicas)
        t_primary = exec_fn(primary)
        deadline = self.stats[primary].p95()
        hedged, winner, lat = False, primary, t_primary
        if t_primary > deadline and len(replicas) > 1:
            backup = self.pick_primary([r for r in replicas if r != primary])
            t_backup_exec = exec_fn(backup)
            t_backup = deadline + t_backup_exec  # hedge fires at deadline
            hedged = True
            # the backup's own service time is a real observation too —
            # without it the backup keeps mean=None (scored 0.0 by
            # pick_primary) and hedge targets are chosen on no data
            self.stats[backup].observe(t_backup_exec)
            if t_backup < t_primary:
                winner, lat = backup, t_backup
        self.stats[primary].observe(t_primary)
        return HedgeOutcome(primary, lat, hedged, winner)


@dataclasses.dataclass
class _ContItem:
    """A queued request: full (re)compute cost + final KV footprint."""
    req: Request
    service_s: float
    kv_bytes: float
    wait_from: float            # queue-delay clock start (arrival/preempt)


@dataclasses.dataclass
class _ContSlot:
    """An in-flight request occupying one batch slot."""
    item: _ContItem
    remaining_s: float          # service-seconds of work left
    admit_s: float
    kv_reserved: float          # bytes pinned at admission


class ContinuousBatcher:
    """Continuous batching with KV-budget preemption (event-driven).

    Requests carry a *service time* (full solo execution cost, seconds)
    and a *KV footprint* (bytes held once the request's cache is fully
    materialized).  The batcher runs an exact event loop:

    * k in-flight slots share the replica; batching efficiency follows
      the fleet's micro-batch cost model — a k-batch costs
      ``eff(k) = 1 + (k - 1) * (1 - batch_overlap)`` times one request,
      so each slot drains ``dt / eff(k)`` service-seconds per wall
      second.
    * A slot's KV occupancy ramps linearly from a reserved fraction
      (``kv_admit_frac * kv_bytes``, pinned at admission) to its full
      footprint as the request progresses — the prefill writes cache as
      it runs.
    * When aggregate occupancy would cross ``kv_budget_bytes``, the
      YOUNGEST preemptable slot (never slot 0 — guaranteed progress) is
      evicted back to the front of the queue with its full service time
      restored (preempt-with-recompute, as in vLLM's recompute policy).
    * Admission is FIFO and happens only at arrival / completion /
      horizon events, never at budget-crossing events, which bounds the
      event count and rules out admit/preempt livelock.

    Counters (``n_admitted`` / ``n_completed`` / ``n_preempted`` /
    ``kv_high_watermark_bytes`` / ``queue_delay_sum_s``) feed the fleet
    report's queue metrics.
    """

    _EPS = 1e-12

    def __init__(self, max_slots: int, kv_budget_bytes: float, *,
                 batch_overlap: float = 0.8, kv_admit_frac: float = 0.25):
        self.max_slots = max(1, int(max_slots))
        self.kv_budget_bytes = float(kv_budget_bytes)
        self.batch_overlap = batch_overlap
        self.kv_admit_frac = min(1.0, max(0.0, kv_admit_frac))
        self.queue: deque[_ContItem] = deque()
        self.slots: List[_ContSlot] = []    # admission order: oldest first
        self.now_s = 0.0
        self.n_admitted = 0
        self.n_completed = 0
        self.n_preempted = 0
        self.kv_high_watermark_bytes = 0.0
        self.queue_delay_sum_s = 0.0
        # optional telemetry observer (core/telemetry.ContObserver):
        # on_admit(rid, wait_s, now_s, kv_reserved) / on_preempt(rid,
        # now_s) fire on admission and KV-budget eviction.  None (the
        # default) costs one attribute check per event and changes no
        # scheduling behavior.
        self.observer = None

    # ------------------------------------------------------------- model
    def _eff(self, k: int) -> float:
        if k <= 1:
            return 1.0
        return 1.0 + (k - 1) * (1.0 - self.batch_overlap)

    def _slot_occupancy(self, s: _ContSlot) -> float:
        frac_done = 1.0 - s.remaining_s / s.item.service_s
        return s.kv_reserved + (s.item.kv_bytes - s.kv_reserved) * frac_done

    def occupancy_bytes(self) -> float:
        return sum(self._slot_occupancy(s) for s in self.slots)

    @property
    def backlog_s(self) -> float:
        """Outstanding service-seconds (in-flight + queued) — the fleet's
        least-loaded routing metric."""
        return (sum(s.remaining_s for s in self.slots)
                + sum(it.service_s for it in self.queue))

    def __len__(self) -> int:
        return len(self.slots) + len(self.queue)

    # ------------------------------------------------------------- input
    def add(self, req: Request, service_s: float, kv_bytes: float) -> None:
        item = _ContItem(req, max(service_s, self._EPS), float(kv_bytes),
                         wait_from=max(req.arrival_s, self.now_s))
        self.queue.append(item)

    def _admit(self) -> None:
        """FIFO admission while a slot and budget headroom exist.  When
        the machine is idle the head is admitted unconditionally — a
        request whose reservation alone exceeds the budget must still
        run (solo) or the queue deadlocks."""
        while self.queue and len(self.slots) < self.max_slots:
            head = self.queue[0]
            if head.req.arrival_s > self.now_s + self._EPS:
                break                        # not here yet (future arrival)
            res = self.kv_admit_frac * head.kv_bytes
            if self.slots and \
                    self.occupancy_bytes() + res > self.kv_budget_bytes + 1e-9:
                break                        # no headroom: FIFO blocks
            self.queue.popleft()
            self.slots.append(_ContSlot(head, head.service_s, self.now_s,
                                        res))
            self.n_admitted += 1
            self.queue_delay_sum_s += self.now_s - head.wait_from
            if self.observer is not None:
                self.observer.on_admit(head.req.rid,
                                       self.now_s - head.wait_from,
                                       self.now_s, res)

    # -------------------------------------------------------------- loop
    def step(self, until_s: Optional[float] = None
             ) -> List[Tuple[Request, float]]:
        """Advance the event loop to ``until_s`` (or to quiescence when
        ``None``).  Returns ``[(request, finish_s)]`` completions."""
        horizon = float("inf") if until_s is None else float(until_s)
        done: List[Tuple[Request, float]] = []
        self._admit()
        while True:
            k = len(self.slots)
            eff = self._eff(k)
            occ = self.occupancy_bytes()
            self.kv_high_watermark_bytes = max(
                self.kv_high_watermark_bytes, occ)

            t_done = min((s.remaining_s for s in self.slots),
                         default=float("inf")) * eff + self.now_s
            t_arr = float("inf")
            if self.queue and self.queue[0].req.arrival_s > self.now_s:
                t_arr = self.queue[0].req.arrival_s
            # budget crossing: occupancy grows at sum((kv-res)/service)/eff
            t_cross = float("inf")
            preemptable = [i for i in range(1, k)
                           if self.slots[i].item.kv_bytes > 0]
            if preemptable:
                rate = sum((s.item.kv_bytes - s.kv_reserved)
                           / s.item.service_s for s in self.slots) / eff
                if occ >= self.kv_budget_bytes - 1e-9:
                    t_cross = self.now_s
                elif rate > 0:
                    t_cross = self.now_s \
                        + (self.kv_budget_bytes - occ) / rate

            t_next = min(t_done, t_arr, t_cross, horizon)
            if t_next == float("inf"):
                break
            dt = t_next - self.now_s
            if dt > 0:
                for s in self.slots:
                    s.remaining_s = max(0.0, s.remaining_s - dt / eff)
                self.now_s = t_next
                self.kv_high_watermark_bytes = max(
                    self.kv_high_watermark_bytes, self.occupancy_bytes())

            finished = [s for s in self.slots if s.remaining_s <= self._EPS]
            if finished:
                for s in finished:
                    self.slots.remove(s)
                    self.n_completed += 1
                    done.append((s.item.req, self.now_s))
                self._admit()                # freed slot + KV headroom
                continue
            if self.now_s >= horizon:
                self._admit()                # same-instant arrivals
                break
            if t_next == t_cross:
                # evict the youngest preemptable slot; its cache is
                # dropped, so the full service time is restored.  NO
                # admission here — re-admission waits for the next
                # arrival/completion event, which bounds the event count
                # (<= k-1 preemptions between admission events).
                victim = self.slots.pop(preemptable[-1])
                victim.item.wait_from = self.now_s
                self.queue.appendleft(victim.item)
                self.n_preempted += 1
                if self.observer is not None:
                    self.observer.on_preempt(victim.item.req.rid,
                                             self.now_s)
                continue
            self._admit()                    # arrival event
        return done

    # ---------------------------------------------------------- teardown
    def drain(self) -> List[Tuple[Request, float, float]]:
        """Evict everything (replica death).  Returns
        ``[(request, service_s, kv_bytes)]`` — in-flight slots first
        (their work is lost; full recompute), then the queue in order."""
        out = [(s.item.req, s.item.service_s, s.item.kv_bytes)
               for s in self.slots]
        out += [(it.req, it.service_s, it.kv_bytes) for it in self.queue]
        self.slots.clear()
        self.queue.clear()
        return out


@dataclasses.dataclass
class AutoScaler:
    """Reactive replica autoscaling on backlog pressure.

    A deliberately simple hysteresis policy (the point of the event
    engine is to make policies like this *measurable* at 10k-robot
    scale, not to bake in a clever one): scale up one replica when the
    mean backlog per routable replica exceeds ``high_s`` seconds, scale
    down one when it falls below ``low_s``, never leaving the
    ``[min_replicas, max_replicas]`` band.  ``decide`` is pure — the
    caller (``runtime/events.EventEngine``) owns the replica set and
    applies the returned delta as synthetic join/leave transitions, so
    the policy composes with scheduled ``ReplicaEvent`` chaos and the
    ``ElasticPool`` heartbeat-timeout view without special cases."""
    min_replicas: int = 1
    max_replicas: int = 8
    high_s: float = 0.25
    low_s: float = 0.02

    def decide(self, n_live: int, mean_backlog_s: float) -> int:
        """Return the replica delta in {-1, 0, +1} for this control step."""
        if n_live < self.min_replicas:
            return 1
        if mean_backlog_s > self.high_s and n_live < self.max_replicas:
            return 1
        if mean_backlog_s < self.low_s and n_live > self.min_replicas:
            return -1
        return 0


class ElasticPool:
    """Tracks live replicas via heartbeats; triggers replan callbacks."""

    def __init__(self, on_change: Optional[Callable[[List[str]], None]] = None,
                 timeout_s: float = 1.0):
        self.last_beat: Dict[str, float] = {}
        self.timeout_s = timeout_s
        self.on_change = on_change
        self._live: List[str] = []

    def heartbeat(self, replica: str, now_s: float) -> None:
        self.last_beat[replica] = now_s
        self._refresh(now_s)

    def _refresh(self, now_s: float) -> None:
        live = sorted(r for r, t in self.last_beat.items()
                      if now_s - t <= self.timeout_s)
        if live != self._live:
            self._live = live
            if self.on_change:
                self.on_change(live)

    def live(self, now_s: float) -> List[str]:
        self._refresh(now_s)
        return list(self._live)
