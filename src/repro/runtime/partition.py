"""Edge/cloud partitioned execution — RoboECC's runtime artifact.

The model's layer stack is cut at a *dynamic* split index that lives inside a
static **parameter-sharing pool** ``[pool_start, pool_end)`` (paper §IV-B-2):
both tiers hold the pool layers' weights, so moving the split inside the pool
needs **no weight shipping and no recompilation** — the split index is a
traced argument, and each pool layer runs under a ``lax.cond`` keyed on
``layer_idx < split``.

Semantics: the split is fixed for the duration of one request (one VLA action
inference).  VLA workloads re-prefill every action step (the camera image
changes), so caches never need to migrate across the cut — this matches the
paper's setting, where adjustment happens between inferences.

The cut activation is optionally shipped through the int8 or packed-int4
activation codec (kernels/activation_codec) — 2x / ~3.8x fewer wire bytes.
The planner-side price of each format (wire factor + encode/decode compute)
lives in ``core/codec.py``; this module is the matching data plane.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.activation_codec import ops as codec
from ..models import transformer as T
from ..models import vla as V
from ..models.layers import embed, rmsnorm, unembed
from ..models.transformer import block_forward, block_decode, _layer_slice

Tree = Any


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Static pool placement + codec choice; `split` itself is dynamic.

    ``codec``: "" (raw), "int8" or "int4" — the wire format for the cut
    activation.  ``use_codec=True`` is the backwards-compatible alias for
    ``codec="int8"``."""
    pool_start: int
    pool_end: int
    use_codec: bool = False
    codec: str = ""

    @property
    def wire_codec(self) -> str:
        if self.codec:
            return self.codec
        return "int8" if self.use_codec else ""

    def clamp(self, split: int) -> int:
        return max(self.pool_start, min(int(split), self.pool_end))


# ------------------------------------------------------------------ helpers
def _masked_stack(cfg, pool_params: Tree, x: jax.Array, positions, split,
                  offset: int, side: str, *, is_moe: bool):
    """Run pool layers under lax.cond(active-on-this-side)."""
    n = jax.tree_util.tree_leaves(pool_params)[0].shape[0]

    def body(h, xs):
        pl, i = xs
        on = (i < split) if side == "edge" else (i >= split)

        def run(a):
            out, _, _ = block_forward(cfg, pl, a, positions, is_moe=is_moe)
            return out

        h = jax.lax.cond(on, run, lambda a: a, h)
        return h, None

    idx = jnp.arange(offset, offset + n)
    x, _ = jax.lax.scan(body, x, (pool_params, idx))
    return x


def _codec_block(D: int) -> int:
    return 128 if D % 128 == 0 else D


def encode_activation(x: jax.Array, wire_codec):
    """``wire_codec``: "" / False (raw), "int8" / True, or "int4".

    int4 requires ``x.shape[-1] % 256 == 0`` (two 128-blocks pack per
    byte lane-aligned) and raises otherwise — a silent int8 fallback
    would ship ~2x the wire bytes the planner priced."""
    if not wire_codec:
        return {"x": x}
    if wire_codec == "int4":
        if x.shape[-1] % 256 != 0:
            raise ValueError(
                f"int4 codec needs last dim % 256 == 0, got {x.shape}; "
                "use int8 (and plan with the int8 codec) instead")
        p, s = codec.quantize_int4(x)
        return {"q4": p, "s": s}
    if wire_codec not in ("int8", True):
        # refuse rather than silently ship a different format than the
        # planner priced (planner codecs like fp16/topk have no data
        # plane here yet)
        raise ValueError(f"no data-plane codec {wire_codec!r}; "
                         "have '', 'int8', 'int4'")
    q, s = codec.quantize(x, block=_codec_block(x.shape[-1]))
    return {"q": q, "s": s}


def decode_activation(payload: Dict, dtype=jnp.bfloat16) -> jax.Array:
    if "x" in payload:
        return payload["x"]
    if "q4" in payload:
        return codec.dequantize_int4(payload["q4"], payload["s"],
                                     jnp.dtype(dtype))
    q, s = payload["q"], payload["s"]
    return codec.dequantize(q, s, jnp.dtype(dtype),
                            block=q.shape[-1] // s.shape[-1])


def payload_bytes(payload: Dict) -> int:
    return sum(v.size * v.dtype.itemsize for k, v in payload.items()
               if hasattr(v, "size"))


# ================================================================ LM executor
class LMSplitExecutor:
    """Dense/MoE decoder-only LM split at a block boundary.

    Layer indexing: 0..L-1 are transformer blocks; embed always on edge,
    final-norm + unembed always on cloud (the paper segments from the last
    layer towards the front, keeping the output head cloud-side).
    """

    def __init__(self, cfg, plan: SplitPlan):
        assert cfg.family in ("dense", "moe")
        assert 0 <= plan.pool_start <= plan.pool_end <= cfg.n_layers
        self.cfg = cfg
        self.plan = plan
        self._edge = jax.jit(self._edge_fwd)
        self._cloud = jax.jit(self._cloud_fwd)

    # -- groups bookkeeping (dense vs moe layer groups)
    def _block_at(self, params, i: int) -> Tuple[Tree, bool]:
        cfg = self.cfg
        if cfg.family == "moe" and i >= cfg.first_dense_layers:
            return _layer_slice(params["moe_blocks"],
                                i - cfg.first_dense_layers), True
        name = "dense_blocks" if cfg.family == "moe" else "blocks"
        return _layer_slice(params[name], i), False

    def _pool_params(self, params) -> Tuple[Tree, bool]:
        cfg, plan = self.cfg, self.plan
        if cfg.family == "moe":
            nd = cfg.first_dense_layers
            assert plan.pool_start >= nd or plan.pool_end <= nd, \
                "pool must not straddle the dense/moe group boundary"
            if plan.pool_start >= nd:
                grp = jax.tree_util.tree_map(
                    lambda w: w[plan.pool_start - nd:plan.pool_end - nd],
                    params["moe_blocks"])
                return grp, True
            grp = jax.tree_util.tree_map(
                lambda w: w[plan.pool_start:plan.pool_end],
                params["dense_blocks"])
            return grp, False
        grp = jax.tree_util.tree_map(
            lambda w: w[plan.pool_start:plan.pool_end], params["blocks"])
        return grp, False

    # -- edge side: embed + [0, pool_start) + masked pool
    def _edge_fwd(self, params, tokens, split):
        cfg, plan = self.cfg, self.plan
        S = tokens.shape[1]
        positions = jnp.arange(S)
        x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
        for i in range(plan.pool_start):
            pl, is_moe = self._block_at(params, i)
            x, _, _ = block_forward(cfg, pl, x, positions, is_moe=is_moe)
        pool, is_moe = self._pool_params(params)
        if plan.pool_end > plan.pool_start:
            x = _masked_stack(cfg, pool, x, positions, split,
                              plan.pool_start, "edge", is_moe=is_moe)
        return encode_activation(x, plan.wire_codec)

    # -- cloud side: masked pool + [pool_end, L) + head
    def _cloud_fwd(self, params, payload, split):
        cfg, plan = self.cfg, self.plan
        x = decode_activation(payload, cfg.dtype)
        positions = jnp.arange(x.shape[1])
        pool, is_moe = self._pool_params(params)
        if plan.pool_end > plan.pool_start:
            x = _masked_stack(cfg, pool, x, positions, split,
                              plan.pool_start, "cloud", is_moe=is_moe)
        for i in range(plan.pool_end, cfg.n_layers):
            pl, is_moe = self._block_at(params, i)
            x, _, _ = block_forward(cfg, pl, x, positions, is_moe=is_moe)
        return T.lm_logits(cfg, params, x)

    # -- public API
    def run(self, params, tokens, split: int):
        """One co-inference: returns (logits, transfer_payload)."""
        split = jnp.int32(self.plan.clamp(split))
        payload = self._edge(params, tokens, split)
        logits = self._cloud(params, payload, split)
        return logits, payload


# ================================================================ VLA executor
class VLASplitExecutor:
    """ViT + LLM (+ action head) split; pool inside the LLM block range.

    Layer indexing (matches core/structure.py): ViT blocks [0, Lv) —
    always edge-side candidates; LLM blocks [Lv, Lv+L); action head after.
    The dynamic pool must lie inside the LLM range; the ViT boundary and the
    action-head side are static placement choices evaluated by the cost
    model (DESIGN.md §7).
    """

    def __init__(self, cfg, plan: SplitPlan, action_on_cloud: bool = True):
        assert cfg.family == "vla"
        self.cfg = cfg
        self.plan = plan
        Lv = cfg.vit_layers
        assert Lv <= plan.pool_start <= plan.pool_end <= Lv + cfg.n_layers
        self.action_on_cloud = action_on_cloud
        self._edge = jax.jit(self._edge_fwd)
        self._cloud = jax.jit(self._cloud_fwd)

    def _edge_fwd(self, params, patches, tokens, split):
        cfg, plan = self.cfg, self.plan
        Lv = cfg.vit_layers
        img = V.vit_encode(cfg, params["vit"], patches)
        txt = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
        x = jnp.concatenate([img, txt], axis=1)
        positions = jnp.arange(x.shape[1])
        for i in range(plan.pool_start - Lv):
            pl = _layer_slice(params["blocks"], i)
            x, _, _ = block_forward(cfg, pl, x, positions, is_moe=False)
        pool = jax.tree_util.tree_map(
            lambda w: w[plan.pool_start - Lv:plan.pool_end - Lv],
            params["blocks"])
        if plan.pool_end > plan.pool_start:
            x = _masked_stack(cfg, pool, x, positions, split,
                              plan.pool_start, "edge", is_moe=False)
        return encode_activation(x, plan.wire_codec)

    def _cloud_fwd(self, params, payload, split, key):
        cfg, plan = self.cfg, self.plan
        Lv = cfg.vit_layers
        x = decode_activation(payload, cfg.dtype)
        positions = jnp.arange(x.shape[1])
        pool = jax.tree_util.tree_map(
            lambda w: w[plan.pool_start - Lv:plan.pool_end - Lv],
            params["blocks"])
        if plan.pool_end > plan.pool_start:
            x = _masked_stack(cfg, pool, x, positions, split,
                              plan.pool_start, "cloud", is_moe=False)
        for i in range(plan.pool_end - Lv, cfg.n_layers):
            pl = _layer_slice(params["blocks"], i)
            x, _, _ = block_forward(cfg, pl, x, positions, is_moe=False)
        h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        # action decode (same logic as models.vla.vla_forward tail)
        if cfg.vla_action_head in ("detok", ""):
            logits = unembed(params["head"], h[:, -cfg.action_dim:])
            toks = jnp.argmax(logits, -1)
            act = (toks.astype(jnp.float32) % 256) / 127.5 - 1.0
            return act[:, None, :]
        cog = h[:, -1]
        if cfg.vla_action_head == "dit":
            return V.dit_sample(cfg, params["action"], cog, key)
        raise NotImplementedError(cfg.vla_action_head)

    def run(self, params, patches, tokens, split: int,
            key: Optional[jax.Array] = None):
        split = jnp.int32(self.plan.clamp(split))
        payload = self._edge(params, patches, tokens, split)
        key = key if key is not None else jax.random.PRNGKey(0)
        action = self._cloud(params, payload, split, key)
        return action, payload
