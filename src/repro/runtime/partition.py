"""Edge/cloud partitioned execution — RoboECC's runtime artifact.

The model's layer stack is cut at a *dynamic* split index that lives inside a
static **parameter-sharing pool** ``[pool_start, pool_end)`` (paper §IV-B-2):
both tiers hold the pool layers' weights, so moving the split inside the pool
needs **no weight shipping and no recompilation** — the split index is a
traced argument, and each pool layer runs under a ``lax.cond`` keyed on
``layer_idx < split``.

Multi-cut placements (``core/placement.py``) add a **second pool**
``[pool2_start, pool2_end)`` around the cloud→edge tail cut of an
edge→cloud→edge plan: the cloud runs pool-2 layers with ``layer_idx <
split2`` and the edge tail (including the final norm / LM head / action
decode) runs the rest — both cuts are traced arguments, so moving either
one inside its pool recompiles nothing.  A two-pool run ships two
payloads: the uplink cut activation (``codec``) and the downlink tail
activation (``codec2``).

Semantics: the split is fixed for the duration of one request (one VLA action
inference).  VLA workloads re-prefill every action step (the camera image
changes), so caches never need to migrate across the cut — this matches the
paper's setting, where adjustment happens between inferences.

The cut activation is optionally shipped through the int8 or packed-int4
activation codec (kernels/activation_codec) — 2x / ~3.8x fewer wire bytes.
The planner-side price of each format (wire factor + encode/decode compute)
lives in ``core/codec.py``; this module is the matching data plane.

Streamed transport (``core/pipeline.py``): ``chunk_payload`` slices an
encoded payload into ``n_chunks`` token-axis chunks and ``merge_chunks``
reassembles them — the data plane of the 3-stage streaming pipeline the
planner prices as a makespan.  Both codec formats quantize per
(row, 128-block) with no cross-token state, so slicing the encoded
payload along the token axis is bit-identical to encoding each chunk
separately, and ``decode(merge(chunks)) == decode(payload)`` exactly —
the streamed forward produces bit-identical outputs to the monolithic
one (``run_streamed``).  Chunk extraction is pure shape logic outside
every jitted function: the traced edge/cloud forwards never see the
chunk count, so changing ``n_chunks`` between requests recompiles
nothing (one trace per function across all chunk counts — the same
invariant the dynamic cut indices already have).

Temporal-delta transport (``core/codec.DeltaCodec``): ``delta_encode``
ships only the token rows whose activation changed since the previous
step against a cloud-side *reference* copy, plus a packed one-bit
change mask; every R-th frame is a full key frame (byte-identical to
the plain ``encode_activation`` payload) that resyncs the reference.
``DeltaTransport`` keeps the per-robot reference cache, with bytes
accounted against an optional budget via
``runtime.kvcache.ReferenceLedger`` — an evicted robot's next frame is
forced back to a key frame.  These run host-side (the change mask is
data-dependent shape logic), outside every jitted forward.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pipeline import chunk_sizes
from ..core.telemetry import Span
from ..kernels.activation_codec import ops as codec
from ..models import transformer as T
from ..models import vla as V
from ..models.layers import embed, rmsnorm, unembed
from ..models.transformer import block_forward, block_decode, _layer_slice

Tree = Any


def _record_exec_spans(recorder, t0: float, t1: float, t2: float) -> None:
    """Two wall-clock spans — edge forward, then cloud forward (+ edge
    tail for two-pool plans) — on the ``executor:*`` lanes.  Host
    ``perf_counter`` time, so the trace mixes with the simulator's model
    time only by lane, never by clock."""
    recorder.record_span(Span(name="edge_fwd", cat="executor", t0_s=t0,
                              dur_s=t1 - t0, lane="executor:edge"))
    recorder.record_span(Span(name="cloud_fwd", cat="executor", t0_s=t1,
                              dur_s=t2 - t1, lane="executor:cloud"))


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Static pool placement(s) + codec choice; the cut indices themselves
    are dynamic.

    ``codec``: "" (raw), "int8" or "int4" — the wire format for the uplink
    cut activation.  ``pool2_start``/``pool2_end`` (both ``-1`` =
    disabled) place the second pool of an edge→cloud→edge plan; ``codec2``
    is the downlink wire format.

    ``use_codec`` is a DEPRECATED alias for ``codec="int8"`` kept as a
    warning shim for one release — pass ``codec`` explicitly
    (``core/placement.py`` plans carry codec names per cut)."""
    pool_start: int
    pool_end: int
    use_codec: Optional[bool] = None
    codec: str = ""
    pool2_start: int = -1
    pool2_end: int = -1
    codec2: str = ""

    def __post_init__(self):
        if self.use_codec is not None:
            warnings.warn(
                "SplitPlan(use_codec=...) is deprecated; pass "
                "codec='int8' (or '') instead — use_codec will be removed "
                "next release", DeprecationWarning, stacklevel=3)
        if (self.pool2_start >= 0) != (self.pool2_end >= 0):
            raise ValueError("pool2_start and pool2_end must be set "
                             "together (or both left at -1)")
        if self.two_pool and not (self.pool_end <= self.pool2_start
                                  <= self.pool2_end):
            raise ValueError(
                f"second pool [{self.pool2_start}, {self.pool2_end}) must "
                f"follow the first [{self.pool_start}, {self.pool_end})")

    @property
    def two_pool(self) -> bool:
        return self.pool2_start >= 0

    @property
    def wire_codec(self) -> str:
        if self.codec:
            return self.codec
        return "int8" if self.use_codec else ""

    def clamp(self, split: int) -> int:
        return max(self.pool_start, min(int(split), self.pool_end))

    def clamp2(self, split2: int) -> int:
        return max(self.pool2_start, min(int(split2), self.pool2_end))


# ------------------------------------------------------------------ helpers
def _masked_stack(cfg, pool_params: Tree, x: jax.Array, positions, split,
                  offset: int, side: str, *, is_moe: bool):
    """Run pool layers under lax.cond(active-on-this-side).

    ``side`` names the *predicate*, not the physical tier: ``"edge"`` runs
    layers with ``i < split`` (the below-the-cut half), ``"cloud"`` those
    with ``i >= split``.  A two-pool plan reuses the same predicates around
    its second cut with the tiers swapped — the cloud owns the below-half
    of pool 2 and the edge tail the above-half."""
    n = jax.tree_util.tree_leaves(pool_params)[0].shape[0]

    def body(h, xs):
        pl, i = xs
        on = (i < split) if side == "edge" else (i >= split)

        def run(a):
            out, _, _ = block_forward(cfg, pl, a, positions, is_moe=is_moe)
            return out

        h = jax.lax.cond(on, run, lambda a: a, h)
        return h, None

    idx = jnp.arange(offset, offset + n)
    x, _ = jax.lax.scan(body, x, (pool_params, idx))
    return x


def _codec_block(D: int) -> int:
    return 128 if D % 128 == 0 else D


def encode_activation(x: jax.Array, wire_codec):
    """``wire_codec``: "" / False (raw), "int8" / True, or "int4".

    int4 requires ``x.shape[-1] % 256 == 0`` (two 128-blocks pack per
    byte lane-aligned) and raises otherwise — a silent int8 fallback
    would ship ~2x the wire bytes the planner priced."""
    if not wire_codec:
        return {"x": x}
    if wire_codec == "int4":
        if x.shape[-1] % 256 != 0:
            raise ValueError(
                f"int4 codec needs last dim % 256 == 0, got {x.shape}; "
                "use int8 (and plan with the int8 codec) instead")
        p, s = codec.quantize_int4(x)
        return {"q4": p, "s": s}
    if wire_codec not in ("int8", True):
        # refuse rather than silently ship a different format than the
        # planner priced (planner codecs like fp16/topk have no data
        # plane here yet)
        raise ValueError(f"no data-plane codec {wire_codec!r}; "
                         "have '', 'int8', 'int4'")
    q, s = codec.quantize(x, block=_codec_block(x.shape[-1]))
    return {"q": q, "s": s}


def decode_activation(payload: Dict, dtype=jnp.bfloat16) -> jax.Array:
    if "x" in payload:
        return payload["x"]
    if "q4" in payload:
        return codec.dequantize_int4(payload["q4"], payload["s"],
                                     jnp.dtype(dtype))
    q, s = payload["q"], payload["s"]
    return codec.dequantize(q, s, jnp.dtype(dtype),
                            block=q.shape[-1] // s.shape[-1])


def payload_bytes(payload: Dict) -> int:
    return sum(v.size * v.dtype.itemsize for k, v in payload.items()
               if hasattr(v, "size"))


def chunk_payload(payload: Dict, n_chunks: int) -> List[Dict]:
    """Slice an encoded cut-activation payload into ``n_chunks`` token-axis
    chunks (``numpy.array_split`` sizing via ``core.pipeline.chunk_sizes``,
    so the planner's byte accounting and the wire slices agree).  Every
    payload array — raw ``x``, int8 ``q``, packed-int4 ``q4`` and the
    block scales ``s`` — carries tokens on axis 1 with per-row scale
    groups, so slicing commutes with the codec: shipping these chunks is
    byte-identical to encoding each token slice separately.  Chunks for
    ``n_chunks > tokens`` come out empty and merge back harmlessly."""
    S = next(iter(payload.values())).shape[1]
    out: List[Dict] = []
    start = 0
    for sz in chunk_sizes(S, n_chunks):
        out.append({k: v[:, start:start + sz] for k, v in payload.items()})
        start += sz
    return out


def merge_chunks(chunks: List[Dict]) -> Dict:
    """Reassemble ``chunk_payload`` slices.  ``decode_activation`` of the
    merged payload is bit-identical to decoding the original payload —
    concatenation of token slices is exact."""
    if not chunks:
        raise ValueError("merge_chunks needs at least one chunk")
    return {k: jnp.concatenate([c[k] for c in chunks], axis=1)
            for k in chunks[0]}


# ------------------------------------------------- temporal-delta transport
def delta_encode(x: jax.Array, base_codec: str,
                 ref: Optional[jax.Array] = None, *,
                 threshold: float = 0.02, resync_every: int = 8,
                 steps_since_key: int = 0
                 ) -> Tuple[Dict, jax.Array, bool]:
    """Encode ``x`` against the reference ``ref`` from the previous step.

    Returns ``(payload, new_ref, is_keyframe)``.  Key frames (``ref`` is
    ``None``, ``resync_every <= 1``, the resync cadence fires, or the
    delta would be at least as large as a full frame) produce a payload
    **byte-identical** to ``encode_activation(x, base_codec)`` — the
    non-delta path — and reset the reference.  Delta frames ship a
    packed one-bit change mask over the token rows (axis 1) plus the
    base-codec encoding of just the changed rows; a row counts as
    changed when ``max|x - ref|`` over that row exceeds
    ``threshold * max|x|``.  ``new_ref`` is the cloud-side
    reconstruction (``delta_decode`` of the payload) — both tiers
    update their reference from the *shipped* bytes, so they stay
    bit-identical without a second channel.

    Unsent rows satisfy ``|x - ref| <= threshold * max|x|`` at *this*
    step by construction; the planner's per-cycle bound
    ``base_err + (R-1) * threshold`` (``DeltaCodec.err_bound``) is the
    conservative envelope of that over a key-frame cycle.

    Host-side only: the change mask drives data-dependent shapes, so
    this cannot run under ``jit`` (same contract as ``chunk_payload`` —
    pure transport logic outside the traced forwards).  Unknown codec
    names are rejected by ``encode_activation`` exactly as on the
    non-delta path."""
    is_key = (ref is None or int(resync_every) <= 1
              or int(steps_since_key) + 1 >= int(resync_every))
    if not is_key:
        absmax = float(jnp.max(jnp.abs(x)))
        rowdiff = jnp.max(jnp.abs(x - ref.astype(x.dtype)), axis=(0, 2))
        changed = np.asarray(rowdiff > threshold * absmax)
        idx = np.flatnonzero(changed)
        S = x.shape[1]
        body = encode_activation(x[:, idx, :], base_codec)
        mask = np.packbits(changed)
        # encoded bytes are linear in the token count (per-row block
        # scales, no cross-token state), so the full-frame size follows
        # from the changed-rows size without encoding twice
        if idx.size and mask.nbytes + payload_bytes(body) \
                >= payload_bytes(body) * (S / idx.size):
            is_key = True       # delta no smaller than a key frame
        else:
            payload = {"mask": mask, **body}
            new_ref = delta_decode(payload, ref, x.dtype)
            return payload, new_ref, False
    payload = encode_activation(x, base_codec)
    return payload, decode_activation(payload, x.dtype), True


def delta_decode(payload: Dict, ref: Optional[jax.Array] = None,
                 dtype=jnp.bfloat16) -> jax.Array:
    """Reconstruct the full cut activation from a ``delta_encode``
    payload.  Key-frame payloads (no ``"mask"`` key) decode standalone;
    delta payloads scatter the decoded changed rows into a copy of
    ``ref``."""
    if "mask" not in payload:
        return decode_activation(payload, dtype)
    if ref is None:
        raise ValueError("delta payload needs the reference activation "
                         "(reference evicted? force a key frame)")
    S = ref.shape[1]
    changed = np.unpackbits(np.asarray(payload["mask"]),
                            count=S).astype(bool)
    idx = np.flatnonzero(changed)
    out = jnp.asarray(ref, dtype=jnp.dtype(dtype))
    if idx.size:
        body = {k: v for k, v in payload.items() if k != "mask"}
        out = out.at[:, idx, :].set(decode_activation(body, dtype))
    return out


class DeltaTransport:
    """Per-robot temporal-delta transport state.

    One instance simulates both tiers of the delta channel for a fleet:
    the per-robot reference activation (cloud-side copy the edge
    mirrors bit-exactly, since both update from the shipped bytes), the
    steps-since-keyframe counter that drives the resync cadence, and
    the ``ReferenceLedger`` byte accounting that makes references
    compete with the KV budget.  When a ``put`` overflows the budget
    the stalest robots' references are evicted and their next ``step``
    is forced onto a key frame."""

    def __init__(self, base_codec: str = "int8", *,
                 threshold: float = 0.02, resync_every: int = 8,
                 budget_bytes: Optional[float] = None):
        from .kvcache import ReferenceLedger
        self.base_codec = base_codec
        self.threshold = threshold
        self.resync_every = int(resync_every)
        self.ledger = ReferenceLedger(budget_bytes)
        self._ref: Dict[int, jax.Array] = {}
        self._ssk: Dict[int, int] = {}
        self.n_keyframes = 0
        self.n_delta_frames = 0
        self.n_evictions = 0

    def step(self, robot_id: int, x: jax.Array
             ) -> Tuple[Dict, jax.Array, bool]:
        """Encode ``x`` for ``robot_id`` and return
        ``(payload, reconstruction, is_keyframe)`` — the reconstruction
        is what the cloud decodes (and the next step's reference)."""
        payload, new_ref, is_key = delta_encode(
            x, self.base_codec, self._ref.get(robot_id),
            threshold=self.threshold, resync_every=self.resync_every,
            steps_since_key=self._ssk.get(robot_id, 0))
        self._ref[robot_id] = new_ref
        self._ssk[robot_id] = 0 if is_key else self._ssk[robot_id] + 1
        if is_key:
            self.n_keyframes += 1
        else:
            self.n_delta_frames += 1
        for k in self.ledger.put(robot_id,
                                 new_ref.size * new_ref.dtype.itemsize):
            self.evict(k)
            self.n_evictions += 1
        return payload, new_ref, is_key

    def evict(self, robot_id: int) -> None:
        """Drop ``robot_id``'s reference; its next frame is a forced
        key frame."""
        self._ref.pop(robot_id, None)
        self._ssk.pop(robot_id, None)
        self.ledger.drop(robot_id)


# ================================================================ LM executor
class LMSplitExecutor:
    """Dense/MoE decoder-only LM split at a block boundary.

    Layer indexing: 0..L-1 are transformer blocks; embed always on edge.
    Single-pool plans keep final-norm + unembed cloud-side (the paper
    segments from the last layer towards the front, keeping the output
    head cloud-side); a two-pool plan returns the tail — pool-2 layers
    with ``i >= split2``, the blocks after ``pool2_end`` and the LM head —
    to the edge, shipping a second (downlink) payload.
    """

    def __init__(self, cfg, plan: SplitPlan):
        assert cfg.family in ("dense", "moe")
        assert 0 <= plan.pool_start <= plan.pool_end <= cfg.n_layers
        if plan.two_pool:
            assert plan.pool_end <= plan.pool2_start \
                <= plan.pool2_end <= cfg.n_layers
        self.cfg = cfg
        self.plan = plan
        self._edge = jax.jit(self._edge_fwd)
        self._cloud = jax.jit(self._cloud_fwd)
        if plan.two_pool:
            self._cloud_mid = jax.jit(self._cloud_mid_fwd)
            self._tail = jax.jit(self._tail_fwd)

    # -- groups bookkeeping (dense vs moe layer groups)
    def _block_at(self, params, i: int) -> Tuple[Tree, bool]:
        cfg = self.cfg
        if cfg.family == "moe" and i >= cfg.first_dense_layers:
            return _layer_slice(params["moe_blocks"],
                                i - cfg.first_dense_layers), True
        name = "dense_blocks" if cfg.family == "moe" else "blocks"
        return _layer_slice(params[name], i), False

    def _group_params(self, params, start: int, end: int
                      ) -> Tuple[Tree, bool]:
        """Stacked params of blocks [start, end) (one pool's weights)."""
        cfg = self.cfg
        if cfg.family == "moe":
            nd = cfg.first_dense_layers
            assert start >= nd or end <= nd, \
                "pool must not straddle the dense/moe group boundary"
            if start >= nd:
                grp = jax.tree_util.tree_map(
                    lambda w: w[start - nd:end - nd], params["moe_blocks"])
                return grp, True
            grp = jax.tree_util.tree_map(
                lambda w: w[start:end], params["dense_blocks"])
            return grp, False
        grp = jax.tree_util.tree_map(
            lambda w: w[start:end], params["blocks"])
        return grp, False

    def _pool_params(self, params) -> Tuple[Tree, bool]:
        return self._group_params(params, self.plan.pool_start,
                                  self.plan.pool_end)

    # -- edge side: embed + [0, pool_start) + masked pool
    def _edge_fwd(self, params, tokens, split):
        cfg, plan = self.cfg, self.plan
        S = tokens.shape[1]
        positions = jnp.arange(S)
        x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
        for i in range(plan.pool_start):
            pl, is_moe = self._block_at(params, i)
            x, _, _ = block_forward(cfg, pl, x, positions, is_moe=is_moe)
        pool, is_moe = self._pool_params(params)
        if plan.pool_end > plan.pool_start:
            x = _masked_stack(cfg, pool, x, positions, split,
                              plan.pool_start, "edge", is_moe=is_moe)
        return encode_activation(x, plan.wire_codec)

    # -- cloud side (single-pool): masked pool + [pool_end, L) + head
    def _cloud_fwd(self, params, payload, split):
        cfg, plan = self.cfg, self.plan
        x = decode_activation(payload, cfg.dtype)
        positions = jnp.arange(x.shape[1])
        pool, is_moe = self._pool_params(params)
        if plan.pool_end > plan.pool_start:
            x = _masked_stack(cfg, pool, x, positions, split,
                              plan.pool_start, "cloud", is_moe=is_moe)
        for i in range(plan.pool_end, cfg.n_layers):
            pl, is_moe = self._block_at(params, i)
            x, _, _ = block_forward(cfg, pl, x, positions, is_moe=is_moe)
        return T.lm_logits(cfg, params, x)

    # -- cloud side (two-pool): masked pool + mid blocks + masked pool 2
    def _cloud_mid_fwd(self, params, payload, split, split2):
        cfg, plan = self.cfg, self.plan
        x = decode_activation(payload, cfg.dtype)
        positions = jnp.arange(x.shape[1])
        pool, is_moe = self._pool_params(params)
        if plan.pool_end > plan.pool_start:
            x = _masked_stack(cfg, pool, x, positions, split,
                              plan.pool_start, "cloud", is_moe=is_moe)
        for i in range(plan.pool_end, plan.pool2_start):
            pl, is_moe = self._block_at(params, i)
            x, _, _ = block_forward(cfg, pl, x, positions, is_moe=is_moe)
        pool2, is_moe2 = self._group_params(params, plan.pool2_start,
                                            plan.pool2_end)
        if plan.pool2_end > plan.pool2_start:
            # cloud owns the BELOW-split2 half of pool 2 ("edge" predicate)
            x = _masked_stack(cfg, pool2, x, positions, split2,
                              plan.pool2_start, "edge", is_moe=is_moe2)
        return encode_activation(x, plan.codec2)

    # -- edge tail (two-pool): masked pool 2 + [pool2_end, L) + head
    def _tail_fwd(self, params, payload, split2):
        cfg, plan = self.cfg, self.plan
        x = decode_activation(payload, cfg.dtype)
        positions = jnp.arange(x.shape[1])
        pool2, is_moe2 = self._group_params(params, plan.pool2_start,
                                            plan.pool2_end)
        if plan.pool2_end > plan.pool2_start:
            x = _masked_stack(cfg, pool2, x, positions, split2,
                              plan.pool2_start, "cloud", is_moe=is_moe2)
        for i in range(plan.pool2_end, cfg.n_layers):
            pl, is_moe = self._block_at(params, i)
            x, _, _ = block_forward(cfg, pl, x, positions, is_moe=is_moe)
        return T.lm_logits(cfg, params, x)

    # -- public API
    def run(self, params, tokens, split: int,
            split2: Optional[int] = None, recorder=None):
        """One co-inference.  Single-pool plans return
        ``(logits, uplink_payload)``; two-pool plans take the second cut
        ``split2`` and return ``(logits, {"up": ..., "down": ...})`` — the
        logits computed on the edge tail.  With a ``FlightRecorder``
        passed as ``recorder``, emits wall-clock edge/cloud spans (forces
        device sync at the cut, so only pass one when tracing)."""
        split = jnp.int32(self.plan.clamp(split))
        t0 = time.perf_counter() if recorder is not None else 0.0
        payload = self._edge(params, tokens, split)
        t1 = 0.0
        if recorder is not None:
            jax.block_until_ready(payload)
            t1 = time.perf_counter()
        if not self.plan.two_pool:
            logits = self._cloud(params, payload, split)
            if recorder is not None:
                jax.block_until_ready(logits)
                _record_exec_spans(recorder, t0, t1, time.perf_counter())
            return logits, payload
        split2 = jnp.int32(self.plan.clamp2(
            split2 if split2 is not None else self.plan.pool2_end))
        down = self._cloud_mid(params, payload, split, split2)
        logits = self._tail(params, down, split2)
        if recorder is not None:
            jax.block_until_ready(logits)
            _record_exec_spans(recorder, t0, t1, time.perf_counter())
        return logits, {"up": payload, "down": down}

    def run_streamed(self, params, tokens, split: int, n_chunks: int,
                     split2: Optional[int] = None):
        """One co-inference with the uplink payload shipped in
        ``n_chunks`` token-axis chunk slices (``chunk_payload``).  Returns
        ``(logits, chunks)`` (two-pool: ``(logits, {"up": chunks,
        "down": payload})`` — the small downlink tail never streams).
        Bit-identical to ``run``: the jitted forwards are chunk-agnostic
        (no retrace across chunk counts) and the codec slices exactly."""
        split_t = jnp.int32(self.plan.clamp(split))
        payload = self._edge(params, tokens, split_t)
        chunks = chunk_payload(payload, n_chunks)
        merged = merge_chunks(chunks)
        if not self.plan.two_pool:
            return self._cloud(params, merged, split_t), chunks
        split2_t = jnp.int32(self.plan.clamp2(
            split2 if split2 is not None else self.plan.pool2_end))
        down = self._cloud_mid(params, merged, split_t, split2_t)
        logits = self._tail(params, down, split2_t)
        return logits, {"up": chunks, "down": down}


# ================================================================ VLA executor
class VLASplitExecutor:
    """ViT + LLM (+ action head) split; pool(s) inside the LLM block range.

    Layer indexing (matches core/structure.py): ViT blocks [0, Lv) —
    always edge-side candidates; LLM blocks [Lv, Lv+L); action head after.
    The dynamic pools must lie inside the LLM range; the ViT boundary is a
    static placement choice evaluated by the cost model (DESIGN.md §7).

    A two-pool plan realizes the edge→cloud→edge placement: the cloud runs
    the trunk up to the (dynamic) second cut and ships the tail activation
    back; the final norm + action decode run on the **edge** — ActionFlow's
    action-stage-on-edge pattern, priced by
    ``core/segmentation.search_multicut``.
    """

    def __init__(self, cfg, plan: SplitPlan, action_on_cloud: bool = True):
        assert cfg.family == "vla"
        self.cfg = cfg
        self.plan = plan
        Lv = cfg.vit_layers
        assert Lv <= plan.pool_start <= plan.pool_end <= Lv + cfg.n_layers
        if plan.two_pool:
            assert plan.pool_end <= plan.pool2_start \
                <= plan.pool2_end <= Lv + cfg.n_layers
        self.action_on_cloud = action_on_cloud and not plan.two_pool
        self._edge = jax.jit(self._edge_fwd)
        self._cloud = jax.jit(self._cloud_fwd)
        if plan.two_pool:
            self._cloud_mid = jax.jit(self._cloud_mid_fwd)
            self._tail = jax.jit(self._tail_fwd)

    def _blocks(self, params, start: int, end: int) -> Tree:
        """Stacked LLM-block params [start, end) in graph indexing."""
        Lv = self.cfg.vit_layers
        return jax.tree_util.tree_map(
            lambda w: w[start - Lv:end - Lv], params["blocks"])

    def _tail_slice(self) -> int:
        """Static downlink sequence length.  When pool 2 is degenerate at
        the graph end the tail is exactly the action stage, which reads
        only its semantic conditioning slice (detok: the last
        ``action_dim`` positions; DiT/MLP/LSTM: the cognition token) — the
        bytes the planner prices via ``LayerCost.in_transfer_bytes``.  A
        pool 2 with movable blocks needs the full sequence (and the
        planner prices those mid-trunk cuts at full activation too).
        0 means "ship everything"."""
        cfg, plan = self.cfg, self.plan
        if plan.pool2_start == plan.pool2_end == cfg.vit_layers \
                + cfg.n_layers:
            return cfg.action_dim if cfg.vla_action_head in ("detok", "") \
                else 1
        return 0

    def _action_decode(self, params, x, key):
        """Final norm + action decode (models.vla.vla_forward tail) — runs
        on whichever tier owns the last segment."""
        cfg = self.cfg
        h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if cfg.vla_action_head in ("detok", ""):
            logits = unembed(params["head"], h[:, -cfg.action_dim:])
            toks = jnp.argmax(logits, -1)
            act = (toks.astype(jnp.float32) % 256) / 127.5 - 1.0
            return act[:, None, :]
        cog = h[:, -1]
        if cfg.vla_action_head == "dit":
            return V.dit_sample(cfg, params["action"], cog, key)
        raise NotImplementedError(cfg.vla_action_head)

    def _edge_fwd(self, params, patches, tokens, split):
        cfg, plan = self.cfg, self.plan
        Lv = cfg.vit_layers
        img = V.vit_encode(cfg, params["vit"], patches)
        txt = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
        x = jnp.concatenate([img, txt], axis=1)
        positions = jnp.arange(x.shape[1])
        for i in range(plan.pool_start - Lv):
            pl = _layer_slice(params["blocks"], i)
            x, _, _ = block_forward(cfg, pl, x, positions, is_moe=False)
        pool = self._blocks(params, plan.pool_start, plan.pool_end)
        if plan.pool_end > plan.pool_start:
            x = _masked_stack(cfg, pool, x, positions, split,
                              plan.pool_start, "edge", is_moe=False)
        return encode_activation(x, plan.wire_codec)

    def _cloud_fwd(self, params, payload, split, key):
        cfg, plan = self.cfg, self.plan
        Lv = cfg.vit_layers
        x = decode_activation(payload, cfg.dtype)
        positions = jnp.arange(x.shape[1])
        pool = self._blocks(params, plan.pool_start, plan.pool_end)
        if plan.pool_end > plan.pool_start:
            x = _masked_stack(cfg, pool, x, positions, split,
                              plan.pool_start, "cloud", is_moe=False)
        for i in range(plan.pool_end - Lv, cfg.n_layers):
            pl = _layer_slice(params["blocks"], i)
            x, _, _ = block_forward(cfg, pl, x, positions, is_moe=False)
        return self._action_decode(params, x, key)

    # -- two-pool cloud trunk: masked pool + mid blocks + masked pool 2
    def _cloud_mid_fwd(self, params, payload, split, split2):
        cfg, plan = self.cfg, self.plan
        Lv = cfg.vit_layers
        x = decode_activation(payload, cfg.dtype)
        positions = jnp.arange(x.shape[1])
        pool = self._blocks(params, plan.pool_start, plan.pool_end)
        if plan.pool_end > plan.pool_start:
            x = _masked_stack(cfg, pool, x, positions, split,
                              plan.pool_start, "cloud", is_moe=False)
        for i in range(plan.pool_end - Lv, plan.pool2_start - Lv):
            pl = _layer_slice(params["blocks"], i)
            x, _, _ = block_forward(cfg, pl, x, positions, is_moe=False)
        pool2 = self._blocks(params, plan.pool2_start, plan.pool2_end)
        if plan.pool2_end > plan.pool2_start:
            # cloud owns the BELOW-split2 half of pool 2 ("edge" predicate)
            x = _masked_stack(cfg, pool2, x, positions, split2,
                              plan.pool2_start, "edge", is_moe=False)
        k = self._tail_slice()
        if k:
            x = x[:, -k:]       # semantic downlink: only what the tail reads
        return encode_activation(x, plan.codec2)

    # -- two-pool edge tail: masked pool 2 + remaining blocks + action
    def _tail_fwd(self, params, payload, split2, key):
        cfg, plan = self.cfg, self.plan
        Lv = cfg.vit_layers
        x = decode_activation(payload, cfg.dtype)
        positions = jnp.arange(x.shape[1])
        pool2 = self._blocks(params, plan.pool2_start, plan.pool2_end)
        if plan.pool2_end > plan.pool2_start:
            x = _masked_stack(cfg, pool2, x, positions, split2,
                              plan.pool2_start, "cloud", is_moe=False)
        for i in range(plan.pool2_end - Lv, cfg.n_layers):
            pl = _layer_slice(params["blocks"], i)
            x, _, _ = block_forward(cfg, pl, x, positions, is_moe=False)
        return self._action_decode(params, x, key)

    def run(self, params, patches, tokens, split: int,
            key: Optional[jax.Array] = None,
            split2: Optional[int] = None, recorder=None):
        """One co-inference.  Single-pool plans return
        ``(action, uplink_payload)``; two-pool plans take the second cut
        ``split2`` and return ``(action, {"up": ..., "down": ...})`` with
        the action decoded on the edge tail.  ``recorder`` as in
        ``LMSplitExecutor.run``."""
        split = jnp.int32(self.plan.clamp(split))
        t0 = time.perf_counter() if recorder is not None else 0.0
        payload = self._edge(params, patches, tokens, split)
        t1 = 0.0
        if recorder is not None:
            jax.block_until_ready(payload)
            t1 = time.perf_counter()
        key = key if key is not None else jax.random.PRNGKey(0)
        if not self.plan.two_pool:
            action = self._cloud(params, payload, split, key)
            if recorder is not None:
                jax.block_until_ready(action)
                _record_exec_spans(recorder, t0, t1, time.perf_counter())
            return action, payload
        split2 = jnp.int32(self.plan.clamp2(
            split2 if split2 is not None else self.plan.pool2_end))
        down = self._cloud_mid(params, payload, split, split2)
        action = self._tail(params, down, split2, key)
        if recorder is not None:
            jax.block_until_ready(action)
            _record_exec_spans(recorder, t0, t1, time.perf_counter())
        return action, {"up": payload, "down": down}

    def run_streamed(self, params, patches, tokens, split: int,
                     n_chunks: int, key: Optional[jax.Array] = None,
                     split2: Optional[int] = None):
        """One co-inference with the uplink payload shipped in
        ``n_chunks`` token-axis chunk slices — the VLA sibling of
        ``LMSplitExecutor.run_streamed`` (actions bit-identical to
        ``run``; one trace per function across chunk counts)."""
        split_t = jnp.int32(self.plan.clamp(split))
        payload = self._edge(params, patches, tokens, split_t)
        chunks = chunk_payload(payload, n_chunks)
        merged = merge_chunks(chunks)
        key = key if key is not None else jax.random.PRNGKey(0)
        if not self.plan.two_pool:
            return self._cloud(params, merged, split_t, key), chunks
        split2_t = jnp.int32(self.plan.clamp2(
            split2 if split2 is not None else self.plan.pool2_end))
        down = self._cloud_mid(params, merged, split_t, split2_t)
        action = self._tail(params, down, split2_t, key)
        return action, {"up": chunks, "down": down}
