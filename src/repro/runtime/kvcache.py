"""Cache utilities: allocation, prefill->decode padding, accounting.

Also hosts the *analytic* KV sizing used by the continuous-batching
cloud tier (``runtime/scheduler.ContinuousBatcher``): numpy-only
closed-form byte counts per attention layer so the fleet simulator can
price a request's KV footprint for any placement window without
allocating real jax buffers.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..models.sharding import init_params, is_spec, shape_tree

Tree = Any

# graph layer kinds that materialize a decode-time KV cache (attention
# blocks); ViT/encoder/mamba/DiT/head stages run once per request and
# hold no KV across decode steps
KV_KINDS = ("llm", "moe")


def kv_bytes_per_token(cfg, act_bytes: int = 2) -> float:
    """Per-token per-attention-layer KV cache bytes for ``cfg``.

    Standard attention stores K and V per kv-head; MLA (DeepSeek) stores
    the compressed latent (``kv_lora_rank``) plus the decoupled RoPE key
    (``qk_rope_dim``) instead.
    """
    if getattr(cfg, "use_mla", False):
        return (cfg.kv_lora_rank + cfg.qk_rope_dim) * act_bytes
    return 2 * cfg.n_kv_heads * cfg.resolved_head_dim * act_bytes


def request_kv_tokens(workload) -> int:
    """Tokens resident in the cache at the end of a request: the full
    context + the new chunk + one slot per decode step."""
    return workload.s_ctx + workload.s_new + workload.decode_steps


def graph_kv_cumsum(graph: List, cfg, workload) -> np.ndarray:
    """Suffix cumulative KV bytes over a layer graph: ``out[s]`` is the
    full per-request KV footprint of layers ``[s, n)``, so a placement
    window's cloud-side KV is ``out[s1] - out[s2]`` — the same window
    convention as ``GraphArrays``' cost cumsums."""
    per_layer = kv_bytes_per_token(cfg, workload.act_bytes) \
        * request_kv_tokens(workload) * workload.batch
    has_kv = np.array([1.0 if c.kind in KV_KINDS else 0.0 for c in graph])
    out = np.zeros(len(graph) + 1)
    out[:-1] = per_layer * has_kv[::-1].cumsum()[::-1]
    return out


class ReferenceLedger:
    """Byte accounting for the cloud-side temporal-delta reference cache.

    The delta codec keeps one reference activation per robot on the
    cloud so later frames can ship only changed token rows.  Those
    references live in the same accelerator memory as the KV cache, so
    they compete with it: this ledger tracks bytes per key (robot id)
    against an optional budget and evicts deterministically when a
    ``put`` overflows it.

    Eviction is FIFO-by-refresh: keys are held in dict insertion order,
    a ``put`` of an existing key moves it to the back (its reference
    was just refreshed), and overflow evicts from the front — the
    robots whose references are stalest.  The evicted keys are returned
    so the caller can force those robots onto a key frame next step.
    Determinism (no clocks, no hashing randomness) is what keeps the
    tick and event engines bit-identical when a budget is set.
    """

    def __init__(self, budget_bytes: Optional[float] = None):
        self.budget_bytes = budget_bytes
        self._bytes: Dict[int, float] = {}
        self.total_bytes = 0.0

    def put(self, key: int, n_bytes: float) -> List[int]:
        """Record ``key``'s reference at ``n_bytes``, refreshing its
        eviction position; returns the (possibly empty) list of keys
        evicted to fit the budget.  The new key itself is never evicted
        even when ``n_bytes`` alone exceeds the budget — a reference
        that can never be held would force key frames forever without
        ever reporting an eviction."""
        old = self._bytes.pop(key, 0.0)
        self.total_bytes -= old
        self._bytes[key] = float(n_bytes)
        self.total_bytes += float(n_bytes)
        evicted: List[int] = []
        if self.budget_bytes is not None:
            for k in list(self._bytes):
                if self.total_bytes <= self.budget_bytes or k == key:
                    break
                self.total_bytes -= self._bytes.pop(k)
                evicted.append(k)
        return evicted

    def drop(self, key: int) -> None:
        """Forget ``key``'s reference (robot left, or its cache was
        invalidated out-of-band).  Missing keys are a no-op."""
        old = self._bytes.pop(key, None)
        if old is not None:
            self.total_bytes -= old


def alloc_cache(model, batch: int, max_len: int, **kw) -> Tree:
    """Zero-allocate the full decode cache."""
    specs = model.cache_specs(batch, max_len, **kw)
    return init_params(specs, jax.random.PRNGKey(0))


def pad_cache(cache: Tree, specs: Tree) -> Tree:
    """Zero-pad every cache leaf up to its full-size spec shape.

    Prefill produces caches sized to the prompt; decode wants max_len-sized
    buffers.  Dims only ever differ along the sequence axis, so a generic
    per-dim pad is safe.
    """
    shapes = shape_tree(specs)

    def one(x, s):
        pads = []
        for have, want in zip(x.shape, s.shape):
            assert have <= want, (x.shape, s.shape)
            pads.append((0, want - have))
        if any(p[1] for p in pads):
            x = jnp.pad(x, pads)
        return x.astype(s.dtype)

    return jax.tree_util.tree_map(one, cache, shapes)


def cache_bytes(cache: Tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(cache))
