"""Cache utilities: allocation, prefill->decode padding, accounting."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..models.sharding import init_params, is_spec, shape_tree

Tree = Any


def alloc_cache(model, batch: int, max_len: int, **kw) -> Tree:
    """Zero-allocate the full decode cache."""
    specs = model.cache_specs(batch, max_len, **kw)
    return init_params(specs, jax.random.PRNGKey(0))


def pad_cache(cache: Tree, specs: Tree) -> Tree:
    """Zero-pad every cache leaf up to its full-size spec shape.

    Prefill produces caches sized to the prompt; decode wants max_len-sized
    buffers.  Dims only ever differ along the sequence axis, so a generic
    per-dim pad is safe.
    """
    shapes = shape_tree(specs)

    def one(x, s):
        pads = []
        for have, want in zip(x.shape, s.shape):
            assert have <= want, (x.shape, s.shape)
            pads.append((0, want - have))
        if any(p[1] for p in pads):
            x = jnp.pad(x, pads)
        return x.astype(s.dtype)

    return jax.tree_util.tree_map(one, cache, shapes)


def cache_bytes(cache: Tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(cache))
