"""Cache utilities: allocation, prefill->decode padding, accounting.

Also hosts the *analytic* KV sizing used by the continuous-batching
cloud tier (``runtime/scheduler.ContinuousBatcher``): numpy-only
closed-form byte counts per attention layer so the fleet simulator can
price a request's KV footprint for any placement window without
allocating real jax buffers.
"""
from __future__ import annotations

from typing import Any, List

import numpy as np

import jax
import jax.numpy as jnp

from ..models.sharding import init_params, is_spec, shape_tree

Tree = Any

# graph layer kinds that materialize a decode-time KV cache (attention
# blocks); ViT/encoder/mamba/DiT/head stages run once per request and
# hold no KV across decode steps
KV_KINDS = ("llm", "moe")


def kv_bytes_per_token(cfg, act_bytes: int = 2) -> float:
    """Per-token per-attention-layer KV cache bytes for ``cfg``.

    Standard attention stores K and V per kv-head; MLA (DeepSeek) stores
    the compressed latent (``kv_lora_rank``) plus the decoupled RoPE key
    (``qk_rope_dim``) instead.
    """
    if getattr(cfg, "use_mla", False):
        return (cfg.kv_lora_rank + cfg.qk_rope_dim) * act_bytes
    return 2 * cfg.n_kv_heads * cfg.resolved_head_dim * act_bytes


def request_kv_tokens(workload) -> int:
    """Tokens resident in the cache at the end of a request: the full
    context + the new chunk + one slot per decode step."""
    return workload.s_ctx + workload.s_new + workload.decode_steps


def graph_kv_cumsum(graph: List, cfg, workload) -> np.ndarray:
    """Suffix cumulative KV bytes over a layer graph: ``out[s]`` is the
    full per-request KV footprint of layers ``[s, n)``, so a placement
    window's cloud-side KV is ``out[s1] - out[s2]`` — the same window
    convention as ``GraphArrays``' cost cumsums."""
    per_layer = kv_bytes_per_token(cfg, workload.act_bytes) \
        * request_kv_tokens(workload) * workload.batch
    has_kv = np.array([1.0 if c.kind in KV_KINDS else 0.0 for c in graph])
    out = np.zeros(len(graph) + 1)
    out[:-1] = per_layer * has_kv[::-1].cumsum()[::-1]
    return out


def alloc_cache(model, batch: int, max_len: int, **kw) -> Tree:
    """Zero-allocate the full decode cache."""
    specs = model.cache_specs(batch, max_len, **kw)
    return init_params(specs, jax.random.PRNGKey(0))


def pad_cache(cache: Tree, specs: Tree) -> Tree:
    """Zero-pad every cache leaf up to its full-size spec shape.

    Prefill produces caches sized to the prompt; decode wants max_len-sized
    buffers.  Dims only ever differ along the sequence axis, so a generic
    per-dim pad is safe.
    """
    shapes = shape_tree(specs)

    def one(x, s):
        pads = []
        for have, want in zip(x.shape, s.shape):
            assert have <= want, (x.shape, s.shape)
            pads.append((0, want - have))
        if any(p[1] for p in pads):
            x = jnp.pad(x, pads)
        return x.astype(s.dtype)

    return jax.tree_util.tree_map(one, cache, shapes)


def cache_bytes(cache: Tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(cache))
