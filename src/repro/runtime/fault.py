"""Fault tolerance: checkpoint/restart training supervisor + failure injection.

``Supervisor.run`` drives a train loop that survives injected (or real)
step failures: on exception it restores the latest checkpoint — including
the data-stream position — and replays from there.  This is the same
control flow a multi-host launcher would run per-coordinator; the
single-host container just makes the failures synthetic.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, Iterator, Optional

from ..checkpoint.ckpt import AsyncCheckpointer, latest_step, load_checkpoint, \
    restore_into

log = logging.getLogger("repro.fault")

Tree = Any


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FaultPlan:
    """Deterministic failure schedule for tests: fail at these step indices
    (each fires once)."""
    fail_at: tuple = ()

    def check(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at = tuple(s for s in self.fail_at if s != step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class RunReport:
    steps_done: int
    restarts: int
    final_loss: float
    losses: list


class Supervisor:
    def __init__(self, ckpt_dir: str, ckpt_every: int = 10,
                 max_restarts: int = 5):
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts

    def run(self, state, stream, train_step: Callable, n_steps: int,
            key_fn: Callable[[int], Any],
            fault_plan: Optional[FaultPlan] = None) -> RunReport:
        import jax
        restarts = 0
        losses = []
        step = int(state.step)
        while step < n_steps:
            try:
                batch = stream.next()
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                if fault_plan is not None:
                    fault_plan.check(step)
                state, metrics = train_step(state, batch, key_fn(step))
                losses.append(float(metrics["loss"]))
                step = int(state.step)
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, {"params": state.params,
                                          "m": state.m, "v": state.v},
                                   extra={"data": stream.state(),
                                          "step": step})
            except InjectedFailure as e:
                restarts += 1
                log.warning("step %d failed (%s); restart %d", step, e,
                            restarts)
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                last = latest_step(self.ckpt_dir)
                if last is None:            # no checkpoint yet: restart fresh
                    continue
                _, loaded, extra = load_checkpoint(self.ckpt_dir, last)
                state.params = restore_into(state.params, loaded["params"])
                state.m = restore_into(state.m, loaded["m"])
                state.v = restore_into(state.v, loaded["v"])
                state.step = jax.numpy.int32(extra["step"])
                stream.restore(extra["data"])
                step = int(extra["step"])
        self.ckpt.wait()
        return RunReport(steps_done=step, restarts=restarts,
                         final_loss=losses[-1] if losses else float("nan"),
                         losses=losses)
