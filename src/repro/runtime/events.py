"""Event-driven fleet engine: the sparse, scale-out replay of the tick loop.

The historical ``FleetSimulator._run_ticks`` loop visits every robot and
replica every tick — at 10k+ robots that is hundreds of millions of
Python iterations, almost all of which do nothing (a busy robot's only
per-tick work is advancing its link cursor).  ``EventEngine`` replaces
the dense scan with a single binary heap of **(tick, phase, idx)** keys
and visits a robot exactly when it has a control step to take:

* robot wake-ups are computed from each completion (``_complete`` fires
  the ``_wake`` hook instead of being polled), with the wake tick found
  by the same float comparison the tick loop would have made;
* per-robot ``NetworkSim`` cursors are positioned absolutely
  (``NetworkSim.seek``) instead of stepped once per tick;
* the ``ElasticPool`` heartbeat-timeout view is tracked analytically:
  live-set changes can only happen at tick 0, at replica join/leave
  ticks and at heartbeat-expiry ticks, so those are the only ticks a
  POOL event recomputes the live list (and fires the fleet's
  ``_on_replicas`` replan callback on change, exactly as the dense
  heartbeat loop would);
* micro-batch formation is driven by enqueue events plus the exact
  batch-age deadline tick; the continuous tier's replicas chain one
  SERVICE event per routable replica per tick (replica count, not robot
  count — the cheap dimension), which keeps every ``ContinuousBatcher``
  clock at the same boundary the tick loop would have stepped it to.

**Parity contract** (tests/test_engine_parity.py): with no open-loop
traffic the engine produces a ``FleetReport`` that is dataclass-EQUAL to
the tick loop's across the {micro, continuous} x {streamed, plain} x
{single-cut, multi-cut} matrix, outage schedules included.  The proof
strategy is structural: every phase body lives once in
``runtime/fleet.py`` (``_robot_step`` / ``_drain_dead`` /
``_service_replica`` / ``_final_drain``) and the heap's total order
replays the tick loop's phase order — REPLICA < POOL < ROBOT < ARRIVAL
< DRAIN < SERVICE < SCALE within a tick, robot index and replica rank
within a phase — so the same RNG draws happen in the same sequence.

Beyond parity, the engine adds what the tick loop cannot express:

* **open-loop arrival processes** (``fleet.ArrivalProcess``): Poisson
  and diurnally-modulated request streams with their own seeded traces
  and RNGs, pre-generated vectorized and replayed as ARRIVAL events;
* **SLO admission control** (``FleetConfig.slo_s``): arrivals whose
  estimated cloud wait exceeds the SLO are rejected to edge-only
  execution and counted;
* **replica autoscaling** (``scheduler.AutoScaler``): SCALE events
  compare backlog pressure against watermarks and apply synthetic
  join/leave transitions through the same pool machinery as scheduled
  chaos events.
"""
from __future__ import annotations

import bisect
import heapq
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .fleet import FleetSimulator, _CloudWork
from .scheduler import AutoScaler, Request

# phase order within a tick — mirrors the tick loop's A..E sections
PH_REPLICA = 0       # scheduled/synthetic replica leave/join application
PH_POOL = 1          # heartbeat/live-set recomputation (replans fire here)
PH_ROBOT = 2         # closed-loop robot control steps, by robot index
PH_ARRIVAL = 3       # open-loop arrivals, by global arrival sequence
PH_DRAIN = 4         # dead-replica queue drain
PH_SERVICE = 5       # batch formation / continuous event loop, by replica
PH_SCALE = 6         # autoscaler decision


class EventHeap:
    """Binary heap over ``(tick, phase, idx)`` with a push-sequence
    tiebreak (equal keys pop in insertion order; the engine's handlers
    are idempotent under duplicates, so the tiebreak is about
    determinism, not correctness).  ``validate=True`` checks the
    nondecreasing-pop invariant on every pop."""

    def __init__(self, validate: bool = False):
        self._h: List[Tuple[int, int, int, int]] = []
        self._seq = 0
        self.validate = validate
        self.n_pushed = 0
        self.n_popped = 0
        self._last_key: Optional[Tuple[int, int, int]] = None

    def __len__(self) -> int:
        return len(self._h)

    def push(self, tick: int, phase: int, idx: int) -> None:
        heapq.heappush(self._h, (tick, phase, idx, self._seq))
        self._seq += 1
        self.n_pushed += 1

    def peek(self) -> Optional[Tuple[int, int, int]]:
        return self._h[0][:3] if self._h else None

    def pop(self) -> Tuple[int, int, int]:
        tick, phase, idx, _ = heapq.heappop(self._h)
        self.n_popped += 1
        if self.validate:
            key = (tick, phase, idx)
            if self._last_key is not None and key < self._last_key:
                raise AssertionError(
                    f"heap popped {key} after {self._last_key}")
            self._last_key = key
        return tick, phase, idx


def _poisson_times(rng: np.random.Generator, rate_hz: float,
                   horizon_s: float) -> np.ndarray:
    """Vectorized homogeneous-Poisson arrival times on [0, horizon)."""
    block = max(16, int(rate_hz * horizon_s * 1.2) + 16)
    t = np.cumsum(rng.exponential(1.0 / rate_hz, block))
    while t[-1] < horizon_s:
        t = np.concatenate(
            [t, t[-1] + np.cumsum(rng.exponential(1.0 / rate_hz, block))])
    return t[t < horizon_s]


def generate_arrivals(cfg) -> List[Tuple[float, int]]:
    """Pre-generate every open-loop arrival as ``(time_s, process_idx)``,
    globally time-sorted.  Poisson streams are exponential-gap cumsums;
    diurnal streams thin a peak-rate stream against the sinusoidal
    intensity ``rate * (1 + amp * sin(2*pi*t/period))``.  Each process
    draws from its own seeded generator, so traffic mixes are
    reproducible and adding a process never disturbs another."""
    horizon = cfg.n_ticks * cfg.tick_s
    out: List[Tuple[float, int]] = []
    for p, proc in enumerate(cfg.arrival_processes):
        rate = float(proc.rate_hz)
        if rate <= 0.0 or horizon <= 0.0:
            continue
        rng = np.random.default_rng(cfg.seed * 1_000_003 + 7919 * (p + 1))
        if proc.kind == "poisson":
            ts = _poisson_times(rng, rate, horizon)
        elif proc.kind == "diurnal":
            lam_max = rate * (1.0 + abs(proc.diurnal_amp))
            ts = _poisson_times(rng, lam_max, horizon)
            lam_t = rate * (1.0 + proc.diurnal_amp * np.sin(
                2.0 * np.pi * ts / proc.diurnal_period_s))
            ts = ts[rng.random(len(ts)) * lam_max < lam_t]
        else:
            raise ValueError(f"unknown arrival kind {proc.kind!r}")
        out.extend((float(t), p) for t in ts)
    out.sort()
    return out


class EventEngine:
    """Runs a ``FleetSimulator`` off an event heap.  Construct with the
    simulator (fresh — one engine per run) and call ``run()``;
    ``validate=True`` turns on the heap/state invariant assertions used
    by the property tests (nondecreasing pops, a robot never acts while
    a request is in flight, replica slot/KV capacity respected)."""

    def __init__(self, sim: FleetSimulator, validate: bool = False):
        self.sim = sim
        self.cfg = sim.cfg
        self.validate = validate
        self.heap = EventHeap(validate=validate)
        # vectorized ROBOT phase: same-tick robot wake-ups collect into
        # per-tick index buckets (one PH_ROBOT marker event per tick)
        # and run as one ``_robot_step_batch``; ``vectorized=False``
        # keeps the per-robot heap entries and the scalar ``_robot_step``
        # as the parity oracle
        self.vectorized = bool(self.cfg.vectorized)
        self._wake_buckets: Dict[int, List[np.ndarray]] = {}
        # replica rank = position in the SORTED name list: the tick loop
        # services `for r in routable` where routable inherits the
        # ElasticPool's sorted order, so heap idx must rank the same way
        self._names_sorted = sorted(sim.replica_names)
        self._rank = {r: k for k, r in enumerate(self._names_sorted)}
        # analytic ElasticPool view
        self.prev_live: List[str] = []       # == ElasticPool._live init
        self.routable: List[str] = []
        self.last_beat_tick: Dict[str, int] = {}
        self.up_since: Dict[str, int] = {r: 0 for r in sim.replica_names}
        # dedupe sets so duplicate (tick, idx) work items stay O(1)
        self._svc_sched: set = set()
        self._pool_sched: set = set()
        self._drain_sched: set = set()
        self._cur_tick = 0
        self._rev = sorted(self.cfg.replica_events)
        # open-loop traffic
        self._arrivals = generate_arrivals(self.cfg)
        self._proc_nets = []
        self._proc_rng = []
        for p, proc in enumerate(self.cfg.arrival_processes):
            from ..core.network import NetworkSim, generate_trace
            # a process may carry its own regional bandwidth regime
            # (ArrivalProcess.trace); None inherits the fleet-wide one
            tr = proc.trace if proc.trace is not None else self.cfg.trace
            self._proc_nets.append(NetworkSim(
                generate_trace(self.cfg.n_ticks + 1, tr,
                               seed=(self.cfg.seed * 100_003
                                     + self.cfg.n_robots + p)),
                tick_s=self.cfg.tick_s, rtt_s=self.cfg.rtt_s))
            self._proc_rng.append(np.random.default_rng(
                self.cfg.seed * 1_000_003 + 7919 * (p + 1) + 1))
        self.scaler: Optional[AutoScaler] = None
        if self.cfg.autoscale:
            mx = (self.cfg.autoscale_max
                  if self.cfg.autoscale_max is not None
                  else self.cfg.n_replicas)
            self.scaler = AutoScaler(
                min_replicas=self.cfg.autoscale_min, max_replicas=mx,
                high_s=self.cfg.autoscale_high_s,
                low_s=self.cfg.autoscale_low_s)

    # ------------------------------------------------------- tick algebra
    # All tick computations replicate the tick loop's float comparisons
    # exactly: compute a fast first guess, then adjust with the SAME
    # expressions (`t * tick_s`, `tick * tick_s + tick_s`) the dense loop
    # evaluates, so rounding never shifts an event across a tick edge.

    def _tick_at_or_after(self, t_s: float) -> int:
        """Smallest tick t with ``t * tick_s >= t_s`` — the first tick at
        which the tick loop would see ``now >= t_s``."""
        ts = self.cfg.tick_s
        t = int(math.ceil(t_s / ts))
        while t * ts < t_s:
            t += 1
        while t > 0 and (t - 1) * ts >= t_s:
            t -= 1
        return t

    def _expiry_tick(self, beat_tick: int) -> int:
        """First tick at which a beat at ``beat_tick`` has timed out of
        the ElasticPool view (``now - beat > timeout`` with the pool's
        ``<=`` liveness comparison)."""
        ts = self.cfg.tick_s
        timeout = self.cfg.heartbeat_timeout_s
        beat_s = beat_tick * ts
        t = beat_tick + max(1, int(timeout / ts))
        while t - 1 > beat_tick and (t - 1) * ts - beat_s > timeout:
            t -= 1
        while t * ts - beat_s <= timeout:
            t += 1
        return t

    def _deadline_tick(self, oldest_s: float, cur_tick: int) -> int:
        """First tick whose service boundary trips the micro-batch age
        trigger: smallest m with ``(m*tick_s + tick_s) - oldest >= wait``
        (the exact ``maybe_form`` comparison at ``end = now + tick_s``)."""
        ts = self.cfg.tick_s
        wait = self.cfg.batch_wait_s
        m = max(cur_tick,
                int(math.floor((oldest_s + wait) / ts)) - 2)
        while m * ts + ts - oldest_s < wait:
            m += 1
        return m

    # --------------------------------------------------------- scheduling
    def _push_pool(self, tick: int) -> None:
        if tick < self.cfg.n_ticks and tick not in self._pool_sched:
            self._pool_sched.add(tick)
            self.heap.push(tick, PH_POOL, 0)

    def _push_drain(self, tick: int) -> None:
        if tick < self.cfg.n_ticks and tick not in self._drain_sched:
            self._drain_sched.add(tick)
            self.heap.push(tick, PH_DRAIN, 0)

    def _push_service(self, tick: int, replica: str) -> None:
        key = (tick, self._rank[replica])
        if tick < self.cfg.n_ticks and key not in self._svc_sched:
            self._svc_sched.add(key)
            self.heap.push(tick, PH_SERVICE, key[1])

    def _note_enqueue(self, replica: str) -> None:
        """``FleetSimulator._enq`` hook: cloud work landed on a replica
        during the current tick — make sure it gets a service pass."""
        self._push_service(self._cur_tick, replica)

    def _bucket_add(self, tick: int, idx: np.ndarray) -> None:
        """Collect woken robot indices into the tick's bucket; the FIRST
        insert for a tick pushes one PH_ROBOT marker event (idx 0) that
        triggers the whole batch.  Wake-ups always target strictly future
        ticks, so a popped bucket's tick can never be re-entered."""
        parts = self._wake_buckets.get(tick)
        if parts is None:
            self._wake_buckets[tick] = [idx]
            self.heap.push(tick, PH_ROBOT, 0)
        else:
            parts.append(idx)

    def _wake_robot(self, i: int) -> None:
        """``FleetSimulator._complete`` hook: the robot's closed loop is
        released at ``next_free``; schedule its next control step at the
        first tick the dense loop would have found it free (never before
        the next tick — this tick's robot phase has already run)."""
        t = max(self._cur_tick + 1,
                self._tick_at_or_after(float(self.sim.next_free[i])))
        if t < self.cfg.n_ticks:
            if self.vectorized:
                self._bucket_add(t, np.asarray([i], dtype=np.int64))
            else:
                self.heap.push(t, PH_ROBOT, i)

    def _wake_robots(self, idx: np.ndarray) -> None:
        """``FleetSimulator._complete_batch`` hook: vectorized
        ``_wake_robot`` over a completion batch.  The wake tick replays
        ``_tick_at_or_after``'s ceil-then-adjust float comparisons
        elementwise, so no robot shifts across a tick edge relative to
        the scalar path."""
        ts = self.cfg.tick_s
        nf = self.sim.next_free[idx]
        t = np.ceil(nf / ts).astype(np.int64)
        while True:
            m = t.astype(np.float64) * ts < nf
            if not m.any():
                break
            t[m] += 1
        while True:
            m = (t > 0) & ((t - 1).astype(np.float64) * ts >= nf)
            if not m.any():
                break
            t[m] -= 1
        t = np.maximum(t, self._cur_tick + 1)
        keep = t < self.cfg.n_ticks
        if not keep.all():
            t, idx = t[keep], idx[keep]
        if not len(t):
            return
        order = np.argsort(t, kind="stable")
        t, idx = t[order], idx[order]
        uniq, starts = np.unique(t, return_index=True)
        bounds = list(starts[1:]) + [len(t)]
        for k, tk in enumerate(uniq):
            self._bucket_add(int(tk), idx[int(starts[k]):int(bounds[k])])

    def _schedule_initial(self) -> None:
        cfg, heap = self.cfg, self.heap
        self._push_pool(0)
        if self.vectorized:
            self._bucket_add(0, np.arange(cfg.n_robots, dtype=np.int64))
        else:
            for i in range(cfg.n_robots):
                heap.push(0, PH_ROBOT, i)
        for pos, ev in enumerate(self._rev):
            t = max(0, ev.tick)      # the tick loop applies tick<=0 at 0
            if t < cfg.n_ticks:
                heap.push(t, PH_REPLICA, pos)
        for k, (t_arr, _p) in enumerate(self._arrivals):
            tk = min(cfg.n_ticks - 1, int(t_arr / cfg.tick_s))
            heap.push(tk, PH_ARRIVAL, k)
        if cfg.continuous:
            # continuous batcher clocks advance every tick they are
            # routable (exactly like the dense loop), so seed the
            # per-replica service chain at tick 0
            for r in self.sim.replica_names:
                self._push_service(0, r)
        if self.scaler is not None:
            for t in range(cfg.autoscale_every, cfg.n_ticks,
                           cfg.autoscale_every):
                heap.push(t, PH_SCALE, 0)

    # ----------------------------------------------------------- liveness
    def _is_live(self, r: str, now: float) -> bool:
        if r not in self.sim._down:
            return True              # beats this tick
        lb = self.last_beat_tick.get(r)
        if lb is None:
            return False             # never heartbeated: not in the pool
        return now - lb * self.cfg.tick_s <= self.cfg.heartbeat_timeout_s

    def _refresh_pool_view(self, tick: int) -> None:
        """POOL event: recompute the sorted live list the ElasticPool
        would report this tick and fire the fleet's replan callback on
        change — then refresh the fail-fast routable view."""
        sim = self.sim
        now = tick * self.cfg.tick_s
        live = [r for r in self._names_sorted if self._is_live(r, now)]
        if live != self.prev_live:
            sim._on_replicas(live)
            self.prev_live = live
        self.routable = [r for r in live if r not in sim._down]

    def _apply_leave(self, r: str, tick: int) -> None:
        sim = self.sim
        if r in sim._down:
            return                   # already down: idempotent
        if tick - 1 >= self.up_since.get(r, 0) and tick >= 1:
            self.last_beat_tick[r] = tick - 1
        sim._down.add(r)
        lb = self.last_beat_tick.get(r)
        if lb is not None:
            self._push_pool(self._expiry_tick(lb))
        self._push_pool(tick)
        self._push_drain(tick)

    def _apply_join(self, r: str, tick: int) -> None:
        sim = self.sim
        if r not in sim._down:
            return
        sim._down.discard(r)
        self.up_since[r] = tick
        self._push_pool(tick)
        if self.cfg.continuous:
            self._push_service(tick, r)   # resume the clock chain

    # ------------------------------------------------------ open arrivals
    def _est_wait_s(self, now_s: float) -> float:
        """Cheapest-replica wait estimate for SLO admission: continuous
        replicas expose outstanding service-seconds directly, the micro
        tier's proxy is the busy-until horizon."""
        sim = self.sim
        if self.cfg.continuous:
            return min(sim.cbatchers[r].backlog_s for r in self.routable)
        return min(max(0.0, sim.busy_until[r] - now_s)
                   for r in self.routable)

    def _handle_arrival(self, tick: int, k: int) -> None:
        sim, cfg = self.sim, self.cfg
        t_arr, p = self._arrivals[k]
        proc = cfg.arrival_processes[p]
        sim.proc_arrivals[p] += 1
        arrays = sim.arrays[proc.arch]
        n = arrays.n
        edge_only = float(arrays.edge_s[n])
        # telemetry sampling key: arrival sequence index offset past the
        # robot id space — engine-order-independent like the robot keys
        rec = sim.recorder
        lane = f"proc:{proc.name}"
        want = (rec is not None
                and rec.want((cfg.n_robots + 1 + p) * 1_000_003 + k))
        if not sim._cloud_up or not self.routable:
            if want:
                rec.record_request(
                    req=-1, lane=lane, t0_s=t_arr, edge_s=edge_only,
                    uplink_s=0.0, queue_s=0.0, service_s=0.0, down_s=0.0,
                    total_s=edge_only,
                    pred=sim._tele_pred_edge(lane, edge_only),
                    outcome="outage", wire_bytes=0.0)
            sim.proc_latencies[p].append(edge_only)
            return
        net = self._proc_nets[p]
        net.seek(tick)
        bw = net.now_bps if proc.bw_bps is None else float(proc.bw_bps)
        kidx = bisect.bisect_left(sim._bw_mid_list, bw)
        s1 = int(sim.plan[proc.arch][kidx])
        s2 = int(sim.plan_s2[proc.arch][kidx])
        ci = int(sim.plan_codec[proc.arch][kidx])
        cdc = sim.codecs[ci]
        down_s, two_cut = 0.0, False
        if s2 < n:
            eh, c, t, dn = arrays.placement_latency(
                s1, s2, bw, cfg.rtt_s, codec=cdc,
                down_bw_factor=cfg.down_bw_factor)
            tail = float(arrays.edge_s[n] - arrays.edge_s[s2])
            e = eh - tail
            down_s = dn + tail
            two_cut = True
        else:
            e, c, t = arrays.latency(s1, bw, cfg.rtt_s, codec=cdc)
        tele = None
        if want:
            tele = sim._tele_pred(lane, proc.arch, bw, s1, s2, 1, ci,
                                  e, c, t, down_s)
        if c <= 0.0:
            lat = e + t + down_s
            if want:
                rec.record_request(
                    req=-1, lane=lane, t0_s=t_arr, edge_s=e, uplink_s=t,
                    queue_s=0.0, service_s=0.0, down_s=down_s,
                    total_s=lat, enc_s=tele["_enc_s"],
                    dec_s=tele["_dec_s"], pred=tele, outcome="local",
                    wire_bytes=tele["_wire_bytes"])
            sim.proc_latencies[p].append(lat)
            return
        if cfg.slo_s is not None and self._est_wait_s(t_arr) > cfg.slo_s:
            # SLO admission: the cloud cannot meet the deadline — serve
            # the whole model on the edge instead of joining the queue
            sim.proc_rejections[p] += 1
            if want:
                # measured = the edge-only fallback; predicted = the
                # split the planner wanted — the drift IS the rejection
                rec.record_request(
                    req=-1, lane=lane, t0_s=t_arr, edge_s=edge_only,
                    uplink_s=0.0, queue_s=0.0, service_s=0.0, down_s=0.0,
                    total_s=edge_only, pred=tele, outcome="slo_reject",
                    wire_bytes=0.0)
            sim.proc_latencies[p].append(edge_only)
            return
        wid = sim._next_wid
        sim._next_wid += 1
        sim._pending[wid] = _CloudWork(-1, t_arr, t_arr + e + t, e, t, c,
                                       down_s, two_cut, proc=p, pred=tele)
        if tele is not None and cfg.continuous:
            rec.cont_open(wid)
        if cfg.continuous:
            rng = self._proc_rng[p]
            slow = float(np.exp(rng.normal(0.0, cfg.straggler_sigma)))
            if rng.random() < cfg.tail_prob:
                slow *= cfg.tail_scale
            kvc = sim.kv_cumsum[proc.arch]
            replica = min(self.routable,
                          key=lambda r: sim.cbatchers[r].backlog_s)
            sim.cbatchers[replica].add(Request(wid, t_arr + e + t, 0),
                                       c * slow, float(kvc[s1] - kvc[s2]))
        else:
            replica = sim.mitigator.pick_primary(self.routable)
            sim.batchers[replica].add(Request(wid, t_arr + e + t, 0))
        self._push_service(tick, replica)

    # --------------------------------------------------------- autoscaling
    def _handle_scale(self, tick: int) -> None:
        sim, cfg = self.sim, self.cfg
        now = tick * cfg.tick_s
        if self.routable:
            if cfg.continuous:
                bl = [sim.cbatchers[r].backlog_s for r in self.routable]
            else:
                bl = [max(0.0, sim.busy_until[r] - now)
                      for r in self.routable]
            n_live, mean_bl = len(self.routable), sum(bl) / len(bl)
        else:
            n_live, mean_bl = 0, 0.0
        delta = self.scaler.decide(n_live, mean_bl)
        if delta > 0:
            spares = [r for r in sim.replica_names if r in sim._down]
            if spares:
                r = spares[0]
                sim._down.discard(r)
                self.up_since[r] = tick + 1   # starts beating next tick
                sim.n_autoscale += 1
                self._push_pool(tick + 1)
                if cfg.continuous:
                    self._push_service(tick + 1, r)
        elif delta < 0 and self.routable:
            r = self.routable[-1]
            # it heartbeated through this tick; down from the next
            self.last_beat_tick[r] = tick
            sim._down.add(r)
            sim.n_autoscale += 1
            self._push_pool(self._expiry_tick(tick))
            self._push_pool(tick + 1)
            self._push_drain(tick + 1)

    # ---------------------------------------------------------------- run
    def run(self):
        sim, cfg = self.sim, self.cfg
        heap = self.heap
        n_ticks = cfg.n_ticks
        tick_s = cfg.tick_s
        sim._wake = self._wake_robot
        sim._wake_batch = self._wake_robots if self.vectorized else None
        sim._enq = self._note_enqueue
        try:
            self._schedule_initial()
            while len(heap) and heap.peek()[0] < n_ticks:
                tick, phase, idx = heap.pop()
                self._cur_tick = tick
                if phase == PH_ROBOT:
                    now = tick * tick_s
                    if self.vectorized:
                        parts = self._wake_buckets.pop(tick, None)
                        if parts is None:
                            continue    # marker raced an emptied bucket
                        idxs = (parts[0] if len(parts) == 1
                                else np.concatenate(parts))
                        idxs = np.sort(idxs)
                        free = now >= sim.next_free[idxs]
                        if not free.all():
                            if self.validate:
                                raise AssertionError(
                                    f"{int((~free).sum())} robots woken "
                                    f"busy at tick {tick}")
                            idxs = idxs[free]   # stale wake: skip
                        if self.validate:
                            assert len(np.unique(idxs)) == len(idxs)
                        if len(idxs):
                            sim._robot_step_batch(idxs, tick, now,
                                                  self.routable)
                        continue
                    if now < sim.next_free[idx]:
                        if self.validate:
                            raise AssertionError(
                                f"robot {idx} woken at tick {tick} while "
                                f"busy until {sim.next_free[idx]}")
                        continue     # stale wake: defensive skip
                    sim.nets[idx].seek(tick)
                    sim._robot_step(idx, now, self.routable)
                elif phase == PH_SERVICE:
                    self._svc_sched.discard((tick, idx))
                    r = self._names_sorted[idx]
                    if r not in self.routable:
                        continue
                    end = tick * tick_s + tick_s   # == the loop's now+tick_s
                    sim._service_replica(r, end, self.routable)
                    if self.validate and cfg.continuous:
                        cb = sim.cbatchers[r]
                        assert len(cb.slots) <= cb.max_slots
                        assert (cb.occupancy_bytes()
                                <= cb.kv_budget_bytes + 1e-6)
                    if cfg.continuous:
                        self._push_service(tick + 1, r)
                    else:
                        q = sim.batchers[r].queue
                        if q:
                            m = self._deadline_tick(q[0].arrival_s, tick)
                            self._push_service(m, r)
                elif phase == PH_ARRIVAL:
                    self._handle_arrival(tick, idx)
                elif phase == PH_POOL:
                    self._pool_sched.discard(tick)
                    self._refresh_pool_view(tick)
                elif phase == PH_REPLICA:
                    ev = self._rev[idx]
                    if ev.kind == "leave":
                        self._apply_leave(ev.replica, tick)
                    else:
                        self._apply_join(ev.replica, tick)
                elif phase == PH_DRAIN:
                    self._drain_sched.discard(tick)
                    sim._drain_dead(tick * tick_s, self.routable)
                    # re-routed work needs a same-tick service pass
                    for r in self.routable:
                        pending = (len(sim.cbatchers[r]) if cfg.continuous
                                   else len(sim.batchers[r].queue))
                        if pending:
                            self._push_service(tick, r)
                else:                # PH_SCALE
                    self._handle_scale(tick)
        finally:
            sim._wake = None
            sim._wake_batch = None
            sim._enq = None
        sim._final_drain()
        if self.validate:
            assert not sim._pending, (
                f"{len(sim._pending)} requests leaked past the horizon")
        return sim._report()
