"""Serving steps: prefill + autoregressive decode with preallocated caches."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .kvcache import pad_cache

Tree = Any


def prefill_and_pad(model, params: Tree, batch: Dict, max_len: int,
                    **cache_kw) -> Tuple[jax.Array, Tree]:
    """Run prefill, then zero-pad caches to `max_len` decode buffers."""
    logits, cache = model.prefill(params, batch)
    specs = model.cache_specs(batch["tokens"].shape[0], max_len, **cache_kw)
    return logits, pad_cache(cache, specs)


def make_serve_step(model, donate: bool = True):
    """jit'd one-token decode step: (params, cache, tokens, pos) ->
    (logits, cache).  The cache buffer is donated (updated in place)."""
    fn = functools.partial(_serve_step, model)
    return jax.jit(fn, donate_argnums=(1,) if donate else ())


def _serve_step(model, params, cache, tokens, pos):
    return model.decode(params, cache, tokens, pos)


def greedy_generate(model, params: Tree, batch: Dict, n_steps: int,
                    max_len: Optional[int] = None, **cache_kw):
    """Prefill + greedy decode n_steps tokens. Returns (B, n_steps) ids."""
    prompt_len = batch["tokens"].shape[1]
    max_len = max_len or (prompt_len + n_steps)
    logits, cache = prefill_and_pad(model, params, batch, max_len, **cache_kw)
    step = make_serve_step(model, donate=False)
    toks = []
    cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(n_steps):
        toks.append(cur)
        logits, cache = step(params, cache, cur, jnp.int32(prompt_len + i))
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(toks, axis=1)
