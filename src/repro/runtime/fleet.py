"""Fleet-scale edge-cloud collaborative serving simulator.

Composes the pieces that exist elsewhere in the repo but never meet:

* per-robot ``RoboECC`` controllers (``core/controller.py``) planned by the
  **vectorized** Alg. 1 sweep (``core/segmentation.search_vec`` /
  ``sweep_search``) — one array pass plans every
  (model × bandwidth × codec) cell;
* per-robot **codec state** (``core/codec.py``): the plan table carries the
  jointly-optimal split-boundary codec per bandwidth bin, robots switch
  codecs as their link moves between bins (counted in
  ``n_codec_switches``), and wire-byte pricing, hedged cloud work and
  post-outage ``replan()`` all see the compressed traffic;
* per-robot **placements** (``core/placement.py``, ``multicut=True``): the
  plan table becomes the joint (S1, S2, codec) multi-cut optimum per bin
  (``sweep_multicut``), each cut clamps into its own parameter-sharing
  pool, placement changes across requests are counted in ``n_cut_moves``,
  and 2-cut requests pay their downlink leg + edge-tail compute after the
  cloud batch returns (the downlink rides ``down_bw_factor`` × the uplink
  bandwidth);
* per-robot **streamed chunk transport** (``core/pipeline.py``,
  ``streamed=True``): the plan table gains the chunk-count axis
  (``sweep_multicut(chunk_grid=...)``), each robot carries in-flight
  chunk state (``n_chunk_reconfigs`` counts reconfigurations), chunked
  uplinks draw the **per-tick** trace bandwidth chunk-by-chunk
  (``NetworkSim.wire_trace_s``) while the cloud window's prefill runs
  concurrently, and ``FleetReport`` reports the residual pipeline
  ``mean_bubble_frac``;
* per-robot ``NetworkSim`` bandwidth traces (``core/network.py``), each
  robot on its own seeded link;
* ``MicroBatcher`` / ``StragglerMitigator`` / ``ElasticPool`` primitives
  (``runtime/scheduler.py``) — cloud-side work is batched per replica,
  hedged across replicas on tail events, and replica loss/join is detected
  via heartbeats;
* a **continuous-batching** cloud tier (``runtime/scheduler.
  ContinuousBatcher``, ``continuous=True``): replicas admit arriving
  prefills straight into the in-flight batch, track per-slot KV
  occupancy (``runtime/kvcache.graph_kv_cumsum`` prices each placement
  window's cache analytically) and preempt/requeue the youngest slot
  when occupancy would cross ``kv_budget_bytes``; ``FleetReport`` gains
  ``n_preemptions`` / ``mean_queue_delay_s`` / ``kv_high_watermark_bytes``
  and ``continuous=False`` keeps the fixed-batch path bit-for-bit;
* **queue-aware planning** (``queue_aware=True``): the plan tables and
  per-robot controllers fold an M/G/1 expected-wait term
  (``core/segmentation.queue_delay_s`` — per-replica arrival rate ×
  roofline service time) into Alg. 1's objective, so congested fleets
  retreat toward the edge *before* the queues build; the arrival rate is
  auto-estimated from the queue-blind plan at the nominal bandwidth
  (override with ``queue_hz``), and a zero rate reproduces the
  queue-blind tables bit-for-bit;
* shared cloud replicas with **finite capacity**: each replica serializes
  its batches (a ``busy_until`` clock), so queueing delay emerges when the
  fleet outruns cloud capacity;
* elasticity: a full cloud outage triggers every controller's ``replan()``
  (degrading to edge-only, split = n); the first replica re-join replans
  again and restores collaborative splits.

Everything is deterministic under ``FleetConfig.seed`` — the simulator
keeps its own ``numpy`` RNG and never reads wall-clock time.  Units follow
the repo conventions: bandwidth in BYTES/s, latency in seconds.

Simulation loop (one control tick = ``tick_s`` seconds):

1. live replicas heartbeat into the ``ElasticPool``; scheduled loss/join
   events silence/revive replicas, and pool transitions fire ``replan()``;
2. every idle robot takes one control step (closed loop: a robot has at
   most one outstanding request and issues the next observation once the
   previous action returns): look up the planned split for its current
   bandwidth in the precomputed plan table, clamp it into the
   parameter-sharing pool (moves outside the pool would ship weights), and
   price the edge/net components with O(1) ``GraphArrays`` indexing;
3. robots with cloud-side work enqueue it on the least-loaded replica's
   ``MicroBatcher``; formed batches execute with partial overlap (the
   batching win), a lognormal straggler multiplier, and p95 hedging;
4. completions are folded into per-robot latency series, reported as
   per-robot p50/p95 plus fleet-aggregate latency and throughput.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..configs import get_config
from ..core.codec import (Codec, DeltaCodec, make_codecs, make_delta_codec,
                          resolve_codecs)
from ..core.controller import RoboECC
from ..core.hardware import A100, ORIN, DeviceSpec
from ..core.network import NetworkSim, TraceConfig, generate_trace_matrix
from ..core.scene import SceneConfig, generate_scene_matrix, scene_config
from ..core.pipeline import (DEFAULT_CHUNK_GRID, stream_applies,
                             stream_makespan_scalar)
from ..core.segmentation import (GraphArrays, graph_arrays, queue_delay_s,
                                 sweep_multicut, sweep_search)
from ..core.structure import LayerCost, Workload, build_graph
from ..core.telemetry import _HASH_KNUTH, ContObserver, FlightRecorder
from .scheduler import (ContinuousBatcher, ElasticPool, MicroBatcher,
                        Request, StragglerMitigator)


# ------------------------------------------------------------------ config
@dataclasses.dataclass(frozen=True)
class ReplicaEvent:
    """Scheduled availability change: replica leaves or joins at ``tick``.

    Carries a TOTAL order ``(tick, kind, replica)`` so that schedules
    containing a leave and a join on the same tick sort deterministically
    regardless of the input list's construction order (``sorted`` is
    stable, so a key on ``tick`` alone preserves whatever order the
    caller happened to build — two logically identical schedules could
    replay differently).  At equal ticks ``"join" < "leave"``, i.e. the
    leave is applied last and wins the tick."""
    tick: int
    replica: str
    kind: str                    # "leave" | "join"

    def _key(self):
        return (self.tick, self.kind, self.replica)

    def __lt__(self, other: "ReplicaEvent") -> bool:
        if not isinstance(other, ReplicaEvent):
            return NotImplemented
        return self._key() < other._key()


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Open-loop request traffic alongside the closed-loop robots
    (``engine="events"`` only): stateless one-shot requests of ``arch``
    arriving as a Poisson stream (``kind="poisson"``) or a sinusoidally
    modulated Poisson stream (``kind="diurnal"``, thinned against the
    peak rate ``rate_hz * (1 + diurnal_amp)``).  Each process rides its
    own seeded bandwidth trace (or a fixed ``bw_bps``) and its own RNG
    stream, so adding processes never perturbs the closed-loop robots'
    draw order.  Arrivals look up the shared plan table at their
    process link bandwidth, pay the same edge/uplink/cloud/downlink legs
    as robots, and are batched on the same replicas — but hold no
    controller state (no pool clamps, no sticky codec) and never
    re-issue: they model external users, not robots."""
    name: str
    arch: str = "openvla-7b"
    kind: str = "poisson"          # "poisson" | "diurnal"
    rate_hz: float = 5.0           # mean arrival rate over the run
    diurnal_amp: float = 0.5       # relative amplitude, kind="diurnal"
    diurnal_period_s: float = 30.0
    bw_bps: Optional[float] = None  # fixed link; None -> own seeded trace
    # per-process bandwidth regime: a cohort of users behind a different
    # network (e.g. metro fiber vs rural LTE) rides its own TraceConfig;
    # None inherits the fleet-wide one.  Ignored when bw_bps is fixed.
    trace: Optional[TraceConfig] = None


@dataclasses.dataclass
class FleetConfig:
    """Fleet run description.  ``archs`` are cycled across ``n_robots``
    (robot i runs ``archs[i % len(archs)]``), so ≥3 entries gives a
    heterogeneous fleet.  Bandwidths in bytes/s, times in seconds."""
    n_robots: int = 16
    archs: Sequence[str] = ("openvla-7b", "cogact-7b", "llama3.2-3b")
    n_ticks: int = 200
    tick_s: float = 0.05
    rtt_s: float = 0.005
    n_replicas: int = 2
    batch_size: int = 8
    batch_wait_s: float = 0.02
    nominal_bw_bps: float = 10e6
    bw_grid_points: int = 32          # plan-table resolution (log-spaced)
    bw_grid_lo_bps: float = 0.05e6
    bw_grid_hi_bps: float = 40e6
    # per-robot cloud-side weight budget (bytes).  Finite by default — a
    # shared cloud serving many robots cannot host every full model, which
    # is what makes the splits collaborative (paper Tab. II uses 12.1 GB)
    cloud_budget_bytes: Optional[float] = 12.1e9
    # split-boundary transport codec axis (core/codec.py names).  The plan
    # table searches (model × split × bandwidth × codec) jointly and each
    # robot carries its planned codec as per-request state; the default
    # single-identity axis reproduces codec-free behaviour exactly.
    codecs: Sequence[str] = ("identity",)
    max_codec_err: Optional[float] = None   # drop codecs above this bound
    # multi-cut placements (core/placement.py): plan (S1, S2) edge→cloud→
    # edge windows instead of single splits.  The downlink leg rides
    # ``down_bw_factor`` × the uplink bandwidth (robot WANs are asymmetric
    # — the uplink is the constrained direction); 1.0 keeps it symmetric.
    multicut: bool = False
    down_bw_factor: float = 1.0
    # streamed chunk transport (core/pipeline.py): the plan table gains a
    # chunk-count axis, robots carry per-request chunk state
    # (``n_chunk_reconfigs``), and streamed uplinks draw the PER-TICK
    # trace bandwidth chunk-by-chunk (``NetworkSim.wire_trace_s``) while
    # the cloud window's prefill overlaps the transfer — the fleet-level
    # realization of the 3-stage pipeline makespan.  ``chunk_grid`` is
    # the chunk counts the planner searches; bins where chunking does not
    # pay plan K = 1, which prices exactly like ``streamed=False``.
    streamed: bool = False
    chunk_grid: Sequence[int] = DEFAULT_CHUNK_GRID
    # continuous-batching cloud tier (runtime/scheduler.ContinuousBatcher):
    # replicas admit arriving prefills into the in-flight batch as slots
    # free up (``batch_size`` caps the slot count), each slot's KV
    # occupancy ramps to the placement window's analytic footprint
    # (runtime/kvcache.py), and the youngest slot is preempted/requeued
    # with a full recompute when aggregate occupancy would cross
    # ``kv_budget_bytes``.  False keeps the fixed-batch MicroBatcher path
    # bit-for-bit as the degenerate/control case.
    continuous: bool = False
    kv_budget_bytes: float = 1.0e9     # per-replica KV memory budget
    kv_admit_frac: float = 0.25        # KV fraction pinned at admission
    # queue-aware planning: fold the M/G/1 expected-wait term
    # (core/segmentation.queue_delay_s, Pollaczek–Khinchine) into the
    # plan-table sweeps and every controller's Alg. 1 / ΔNB decisions.
    # ``queue_hz=None`` auto-estimates the per-replica arrival rate from
    # the queue-blind plan at the nominal bandwidth (robots with planned
    # cloud work re-issue at their closed-loop rate, spread over the
    # replicas); queue_aware=False — or an estimated rate of 0 —
    # reproduces the queue-blind tables bit-for-bit.
    queue_aware: bool = False
    queue_hz: Optional[float] = None
    queue_cv2: float = 1.0             # service-time coefficient-of-var²
    queue_service_scale: float = 1.0   # planned→served service inflation
    pool_overhead_target: float = 0.026
    batch_overlap: float = 0.8        # fraction of non-max work overlapped
    straggler_sigma: float = 0.2      # lognormal sigma on replica exec time
    tail_prob: float = 0.01           # chance of a tail event per execution
    tail_scale: float = 5.0           # tail slowdown multiplier
    heartbeat_timeout_s: float = 0.12
    trace: TraceConfig = dataclasses.field(default_factory=TraceConfig)
    workload: Workload = dataclasses.field(default_factory=Workload)
    edge: DeviceSpec = ORIN
    cloud: DeviceSpec = A100
    replica_events: Sequence[ReplicaEvent] = ()
    seed: int = 0
    # simulation engine: "ticks" replays the historical per-tick loop;
    # "events" runs the sparse event-driven core (runtime/events.py) —
    # proven FleetReport-dataclass-equal to the tick loop on every
    # parity-matrix config (tests/test_engine_parity.py) and the only
    # engine that scales to 10k+ robots (busy robots cost nothing).
    engine: str = "ticks"
    # vectorized ROBOT phase (events engine only): same-tick control
    # steps run as ONE numpy pass over the struct-of-arrays robot state
    # (``FleetSimulator._robot_step_batch``) instead of n per-robot
    # Python calls.  The batch replays the scalar arithmetic in the
    # scalar evaluation order, so reports are dataclass-equal either way
    # (tests/test_engine_parity.py pins it); ``vectorized=False`` keeps
    # the per-robot ``_robot_step`` as the parity oracle.
    vectorized: bool = True
    # open-loop arrival traffic (events engine only; the tick loop
    # refuses it — it has no sub-tick arrival machinery)
    arrival_processes: Sequence[ArrivalProcess] = ()
    # SLO-based admission control for open-loop arrivals: reject (serve
    # edge-only, counted in n_slo_rejections) when the estimated cloud
    # wait exceeds slo_s.  None disables.  Closed-loop robots are never
    # rejected — their backpressure is the closed loop itself.
    slo_s: Optional[float] = None
    # ElasticPool-driven replica autoscaling (events engine only): every
    # autoscale_every ticks an AutoScaler (runtime/scheduler.py) compares
    # mean backlog per routable replica against the high/low watermarks
    # and joins/leaves one replica inside [autoscale_min, autoscale_max].
    # Replicas beyond the initial live set are provisioned as cold spares
    # via tick-0 leave events in replica_events.
    autoscale: bool = False
    autoscale_every: int = 20
    autoscale_min: int = 1
    autoscale_max: Optional[int] = None    # None -> n_replicas
    autoscale_high_s: float = 0.25
    autoscale_low_s: float = 0.02
    # flight-recorder telemetry (core/telemetry.py): "off" keeps the
    # recorder out of every hot path (a single ``is None`` check per
    # request — runs are bit-identical to a build without telemetry,
    # pinned by tests/test_engine_parity.py); "sampled" records a
    # deterministic ~1/telemetry_sample_every subset of requests chosen
    # by hashing (robot, issue tick) — never the simulation RNG — and
    # "full" records every request.  Span groups are reservoir-bounded
    # at telemetry_cap (runtime/trace_export.py renders them as Chrome
    # trace-event JSON); metrics/drift sketches are O(1) memory always.
    # Sampled cost is ~full/sample_every (the keep/drop hash itself is
    # negligible): 1/64 keeps the 10k-robot fleet inside the <3 %
    # overhead budget benchmarks/fleet_bench.py bench_overhead gates.
    telemetry: str = "off"
    telemetry_cap: int = 65536
    telemetry_sample_every: int = 64
    # scene-dynamics axis for the temporal-delta codec (core/scene.py):
    # a scene name ("static"/"slow"/"dynamic") or a SceneConfig gives
    # every robot a seeded per-tick token change-fraction trace (its own
    # stream, disjoint from the bandwidth traces).  With a "delta" codec
    # in ``codecs``, each uplink is then priced at its MEASURED frame
    # cost — key frames at the base codec's bytes, delta frames at
    # ``frac x base + mask`` — instead of the plan table's cycle
    # average, per-robot wire bytes are accounted
    # (``FleetReport.total_wire_bytes``), and the resync cadence /
    # reference-cache state is tracked per robot.  Delta state applies
    # to closed-loop uplinks only: open-loop arrivals are stateless
    # one-shots with no reference to delta against, and the downlink
    # leg keeps cycle-average pricing (the reference cache is
    # cloud-side).  ``None`` (default) skips every delta branch — runs
    # are bit-identical to builds without the axis.
    scene: Optional[object] = None          # str | core.scene.SceneConfig
    # cloud-side reference-cache byte budget shared across the fleet's
    # delta references (accounted by runtime/kvcache.ReferenceLedger,
    # the same memory pool the KV budget draws from).  Overflow evicts
    # the stalest robots' references (FIFO-by-refresh), forcing their
    # next frame back to a key frame (``n_ref_evictions``).  None =
    # unbounded (and keeps the batched robot phase fully vectorized —
    # a budget makes eviction order-sensitive, so budgeted runs walk
    # delta state per-robot in ascending index).
    delta_ref_budget_bytes: Optional[float] = None
    # measured-vs-planned change-fraction drift replans: every
    # ``delta_drift_every`` ticks the fleet-mean measured change
    # fraction over the window is compared against the delta codec's
    # planned ``change_frac``; relative drift beyond
    # ``delta_drift_tol`` rebuilds the delta codec around the measured
    # fraction and re-runs the plan tables (``n_delta_replans``).  The
    # schedule is precomputed from the scene matrix at construction —
    # a pure function of the tick, never of robot processing order —
    # which is what keeps the tick/event/vectorized engines
    # bit-identical.  0 disables.
    delta_drift_tol: float = 0.25
    delta_drift_every: int = 0


def outage_schedule(cfg: FleetConfig) -> List[ReplicaEvent]:
    """Default chaos schedule: one replica leaves and re-joins mid-run
    (capacity crunch), then ALL replicas drop for a window (full outage →
    every controller replans to edge-only) and come back."""
    T = cfg.n_ticks
    ev = []
    if cfg.n_replicas > 1:
        ev += [ReplicaEvent(T // 5, "cloud1", "leave"),
               ReplicaEvent(2 * T // 5, "cloud1", "join")]
    for i in range(cfg.n_replicas):
        ev.append(ReplicaEvent(3 * T // 5, f"cloud{i}", "leave"))
        ev.append(ReplicaEvent(7 * T // 10, f"cloud{i}", "join"))
    return sorted(ev)          # ReplicaEvent total order: (tick, kind, name)


# ------------------------------------------------------------------ report
@dataclasses.dataclass(frozen=True)
class RobotStats:
    name: str
    arch: str
    n_requests: int
    mean_s: float
    p50_s: float
    p95_s: float
    codec: str = "identity"      # codec the robot ended the run on
    n_chunks: int = 1            # chunk count the robot ended the run on


@dataclasses.dataclass(frozen=True)
class ProcessStats:
    """Per-arrival-process latency breakdown (open-loop traffic only)."""
    name: str
    kind: str
    n_arrivals: int
    n_completed: int
    n_rejected: int              # SLO admission rejections (served edge-only)
    mean_s: float
    p50_s: float
    p95_s: float
    p99_s: float
    p999_s: float


@dataclasses.dataclass(frozen=True)
class FleetReport:
    robots: List[RobotStats]
    n_requests: int
    fleet_p50_s: float
    fleet_p95_s: float
    throughput_rps: float        # completed requests / simulated second
    n_hedged: int
    n_replans: int
    n_outage_completions: int    # requests served edge-only during outages
    n_codec_switches: int = 0    # per-robot codec changes across requests
    n_cut_moves: int = 0         # per-robot (S1, S2) changes across requests
    n_multicut_requests: int = 0  # requests served on a real 2-cut placement
    n_chunk_reconfigs: int = 0   # per-robot chunk-count changes
    n_streamed_requests: int = 0  # requests served on a chunked (K>1) uplink
    # mean fill/drain bubble fraction over streamed requests (0 when none):
    # how much pipeline dead time the chosen chunking left unrecovered
    mean_bubble_frac: float = 0.0
    # continuous-batching tier (continuous=True; all zero under the
    # MicroBatcher control path)
    n_preemptions: int = 0            # KV-budget evictions (recomputed)
    mean_queue_delay_s: float = 0.0   # cloud admission wait per completion
    kv_high_watermark_bytes: float = 0.0   # peak per-replica KV occupancy
    # tail percentiles over the fleet latency series — the scale story:
    # p99/p99.9 only mean anything with thousands of robots' worth of
    # samples, which is what the event engine exists to provide
    fleet_p99_s: float = 0.0
    fleet_p999_s: float = 0.0
    # open-loop arrival traffic (events engine; empty/zero otherwise)
    processes: tuple = ()             # tuple[ProcessStats, ...]
    n_open_arrivals: int = 0          # arrivals generated across processes
    n_slo_rejections: int = 0         # arrivals rejected by SLO admission
    n_autoscale_events: int = 0       # replicas joined/left by the scaler
    # flight-recorder snapshot (core/telemetry.py) when the run had
    # telemetry on: counters/gauges/quantile sketches + drift summary.
    # None when telemetry="off", so historical reports compare equal.
    metrics: Optional[dict] = None
    # temporal-delta transport (FleetConfig.scene; all zero when the
    # scene axis is off, so historical reports compare equal).
    # ``total_wire_bytes`` sums every applicable closed-loop uplink's
    # MEASURED wire bytes (any codec, not just delta — the comparison
    # baseline needs the same accounting); the frame counters and
    # eviction/replan counts are delta-codec specific.
    total_wire_bytes: float = 0.0
    n_keyframes: int = 0
    n_delta_frames: int = 0
    n_ref_evictions: int = 0
    n_delta_replans: int = 0

    def summary(self) -> str:
        lines = [
            f"{len(self.robots)} robots, {self.n_requests} requests: "
            f"fleet p50 {self.fleet_p50_s * 1e3:.1f} ms, "
            f"p95 {self.fleet_p95_s * 1e3:.1f} ms, "
            f"p99 {self.fleet_p99_s * 1e3:.1f} ms, "
            f"p99.9 {self.fleet_p999_s * 1e3:.1f} ms, "
            f"{self.throughput_rps:.1f} req/s",
            f"  {self.n_hedged} hedges, {self.n_replans} replans, "
            f"{self.n_codec_switches} codec switches, "
            f"{self.n_cut_moves} cut moves, "
            f"{self.n_chunk_reconfigs} chunk reconfigs",
            f"  queue: mean delay {self.mean_queue_delay_s * 1e3:.1f} ms, "
            f"{self.n_preemptions} preemptions, "
            f"KV high-water {self.kv_high_watermark_bytes / 1e6:.1f} MB",
        ]
        if self.n_open_arrivals or self.processes:
            lines.append(
                f"  open loop: {self.n_open_arrivals} arrivals, "
                f"{self.n_slo_rejections} SLO rejections, "
                f"{self.n_autoscale_events} autoscale events")
        if self.total_wire_bytes or self.n_keyframes or self.n_delta_frames:
            lines.append(
                f"  delta: {self.total_wire_bytes / 1e6:.1f} MB wire, "
                f"{self.n_keyframes} key / {self.n_delta_frames} delta "
                f"frames, {self.n_ref_evictions} evictions, "
                f"{self.n_delta_replans} drift replans")
        return "\n".join(lines)


@dataclasses.dataclass
class _CloudWork:
    robot: int
    issued_s: float              # control step that produced this request
    ready_s: float               # edge compute + uplink done at this time
    edge_s: float
    net_s: float                 # uplink leg (edge → cloud)
    cloud_s: float
    down_s: float = 0.0          # downlink leg + edge tail (multi-cut only)
    two_cut: bool = False        # issued on a real (S2 < n) placement
    proc: int = -1               # arrival-process index; -1 = robot traffic
    # issue-time telemetry payload (recorder-on sampled requests only):
    # the planner's predicted stage decomposition plus span context,
    # joined against the measured stages at completion.  None when the
    # recorder is off or the request was not sampled.
    pred: Optional[dict] = None


# --------------------------------------------------------------- simulator
class FleetSimulator:
    """Event-driven fleet run; see module docstring for the loop."""

    def __init__(self, cfg: FleetConfig):
        if cfg.n_robots < 1 or cfg.n_replicas < 1 or not cfg.archs:
            raise ValueError("fleet needs >=1 robot, >=1 replica and >=1 arch")
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._dead_cloud = cfg.cloud.with_eta(1e-12, 1e-12)
        _t_init = time.perf_counter()

        # one graph + cost-array set per arch, shared by all its robots
        self.arch_of: List[str] = [cfg.archs[i % len(cfg.archs)]
                                   for i in range(cfg.n_robots)]
        archs = list(dict.fromkeys(self.arch_of))
        self.graphs: Dict[str, List[LayerCost]] = {
            a: build_graph(get_config(a), cfg.workload) for a in archs}
        self.arrays: Dict[str, GraphArrays] = {
            a: graph_arrays(g, cfg.edge, cfg.cloud,
                            input_bytes=cfg.workload.input_bytes)
            for a, g in self.graphs.items()}

        # vectorized Alg. 1 plan table: (model × bandwidth-bin) ->
        # (split, codec) — one (M, C, S, B) pass covers the whole fleet
        self.codecs: List[Codec] = list(
            resolve_codecs(cfg.codecs, cfg.max_codec_err))
        self.bw_grid = np.geomspace(cfg.bw_grid_lo_bps, cfg.bw_grid_hi_bps,
                                    cfg.bw_grid_points)
        # geometric midpoints: searchsorted on these snaps a bandwidth to
        # the NEAREST grid bin in log space (plain searchsorted on the grid
        # would always round up to the plan of a faster link)
        self._bw_mid = np.sqrt(self.bw_grid[:-1] * self.bw_grid[1:])
        # plain-float copy for the per-request lookup: bisect_left on a
        # list is ~10x a scalar np.searchsorted and bit-identical to
        # side="left" (same total order on finite floats)
        self._bw_mid_list = [float(x) for x in self._bw_mid]
        (self.plan, self.plan_s2, self.plan_codec,
         self.plan_chunks) = self._build_plans(0.0)
        # queue-aware planning: estimate the per-replica arrival rate the
        # queue-blind plan induces at the nominal bandwidth, then rebuild
        # the tables with the M/G/1 wait term in the objective.  λ = 0
        # skips the rebuild, so the degenerate case keeps the queue-blind
        # tables bit-for-bit.
        self.plan_queue_hz = 0.0
        if cfg.queue_aware:
            lam = (float(cfg.queue_hz) if cfg.queue_hz is not None
                   else self._estimate_arrival_hz())
            if lam > 0.0:
                self.plan_queue_hz = lam
                (self.plan, self.plan_s2, self.plan_codec,
                 self.plan_chunks) = self._build_plans(lam)

        # robots start on the codec planned at the nominal bandwidth; the
        # same codec prices the controller's Alg. 1 (so replan() after an
        # outage restores a codec-consistent split)
        k0 = int(np.searchsorted(self._bw_mid, cfg.nominal_bw_bps))
        # robot state is struct-of-arrays: at 10k+ robots, per-robot
        # Python objects dominate memory and attribute access dominates
        # time; int64/float64 arrays keep the hot path flat
        self.codec_of = np.asarray(
            [int(self.plan_codec[a][k0]) for a in self.arch_of],
            dtype=np.int64)
        _t_plan = time.perf_counter()
        # ONE controller per distinct arch, shared by every robot of that
        # arch: construction and replan() are deterministic functions of
        # (arch, devices, budget, codec, queue prior) — identical for all
        # robots of an arch — so n_robots controller objects were
        # n_robots recomputations of the same Alg. 1 sweep (the dominant
        # setup cost at 10k+).  ``self.controllers`` stays a length-n
        # list (robot i -> its arch's shared controller); controller
        # state only changes inside ``_on_replicas`` replan waves, which
        # replan each DISTINCT controller once.
        uniq: Dict[str, RoboECC] = {
            a: RoboECC(get_config(a), cfg.edge, cfg.cloud,
                       workload=cfg.workload,
                       cloud_budget_bytes=cfg.cloud_budget_bytes,
                       pool_overhead_target=cfg.pool_overhead_target,
                       nominal_bw_bps=cfg.nominal_bw_bps,
                       codec=self.codecs[int(self.plan_codec[a][k0])],
                       graph=self.graphs[a],
                       multicut=cfg.multicut,
                       down_bw_factor=cfg.down_bw_factor,
                       streamed=cfg.streamed,
                       chunk_grid=cfg.chunk_grid,
                       plan_rtt_s=cfg.rtt_s,
                       queue_hz=self.plan_queue_hz,
                       queue_cv2=cfg.queue_cv2,
                       queue_service_scale=cfg.queue_service_scale)
            for a in archs}
        self.controllers: List[RoboECC] = [uniq[a] for a in self.arch_of]
        # replan memo: a chaos schedule replays the same two cloud
        # conditions ("dead"/"alive") every wave, and replan() under a
        # fixed condition is deterministic — so snapshot the post-replan
        # controller state per (controller, condition) and restore it on
        # repeat waves.  The "alive" snapshot is the construction state
        # (replan with the original cloud + budget reproduces it
        # bit-for-bit), so a full outage/rejoin cycle costs at most one
        # dead-condition search per arch for the whole run.
        self._replan_memo: Dict[tuple, dict] = {
            (id(c), "alive"): self._ctl_snapshot(c) for c in uniq.values()}
        self.replan_wall_s = 0.0
        _t_ctl = time.perf_counter()
        # per-robot effective placement state (for n_cut_moves)
        self.place_s1 = np.asarray([int(self.plan[a][k0])
                                    for a in self.arch_of], dtype=np.int64)
        self.place_s2 = np.asarray([int(self.plan_s2[a][k0])
                                    for a in self.arch_of], dtype=np.int64)
        # per-robot streaming chunk state (for n_chunk_reconfigs)
        self.chunks_of = np.asarray([int(self.plan_chunks[a][k0])
                                     for a in self.arch_of], dtype=np.int64)
        # per-robot pool bounds, cached as Pool objects: pools only move
        # on replan(), so _on_replicas refreshes the cache and the
        # per-request path clamps against plain ints (Pool.clamp) instead
        # of chasing controller attributes + np.clip
        self._pools1: List = [None] * cfg.n_robots
        self._pools2: List = [None] * cfg.n_robots
        # struct-of-arrays mirror of the pool bounds, refreshed alongside
        # the Pool cache: the vectorized ROBOT phase clamps with numpy
        # min/max (bit-identical to Pool.clamp) instead of method calls
        self._pool_lo1 = np.zeros(cfg.n_robots, dtype=np.int64)
        self._pool_hi1 = np.zeros(cfg.n_robots, dtype=np.int64)
        self._pool_lo2 = np.zeros(cfg.n_robots, dtype=np.int64)
        self._pool_hi2 = np.zeros(cfg.n_robots, dtype=np.int64)
        self._has_pool2 = np.zeros(cfg.n_robots, dtype=bool)
        self._refresh_pool_cache()
        # one bulk (n_robots, n_ticks+1) bandwidth matrix — row i is
        # bit-identical to the historical per-robot
        # ``generate_trace(..., seed=seed*100_003 + i)`` — and the
        # NetworkSim objects wrap the rows as views (no copies): the
        # vectorized ROBOT phase reads ``trace_mat[idx, tick]`` directly,
        # the scalar/streamed paths keep their per-robot cursor API
        self.trace_mat = generate_trace_matrix(
            cfg.n_ticks + 1, cfg.trace,
            [cfg.seed * 100_003 + i for i in range(cfg.n_robots)])
        self.nets: List[NetworkSim] = [
            NetworkSim(row, tick_s=cfg.tick_s, rtt_s=cfg.rtt_s)
            for row in self.trace_mat]
        _t_trace = time.perf_counter()
        # setup wall breakdown (``benchmarks/fleet_bench.py --profile``):
        # plan tables (+ graphs), controller construction, trace matrix
        self.profile = {"plan_s": _t_plan - _t_init,
                        "controller_s": _t_ctl - _t_plan,
                        "trace_s": _t_trace - _t_ctl}
        # lazily-built stacked plan/cost tables for _robot_step_batch
        self._bst: Optional[dict] = None

        # ---- temporal-delta scene axis (None = every branch below is
        # skipped; the run is bit-identical to a scene-free build)
        self.scene_cfg: Optional[SceneConfig] = None
        self.scene_mat: Optional[np.ndarray] = None
        self._delta_ledger = None
        self.wire_bytes_of = np.zeros(cfg.n_robots, dtype=np.float64)
        self.n_keyframes = 0
        self.n_delta_frames = 0
        self.n_ref_evictions = 0
        self.n_delta_replans = 0
        self._delta_replan_at: Dict[int, float] = {}
        self._delta_replan_ticks: List[int] = []
        self._delta_replan_ptr = 0
        if cfg.scene is not None:
            self.scene_cfg = scene_config(cfg.scene)
            # per-robot change-fraction traces on a seed stream disjoint
            # from the bandwidth traces (rows i use seed*100_003 + i; the
            # +59_999_999 offset keeps the streams apart for any fleet
            # under ~59M robots)
            self.scene_mat = generate_scene_matrix(
                cfg.n_ticks + 1, self.scene_cfg,
                [cfg.seed * 100_003 + i + 59_999_999
                 for i in range(cfg.n_robots)])
            self.delta_ssk = np.zeros(cfg.n_robots, dtype=np.int64)
            self.delta_has_ref = np.zeros(cfg.n_robots, dtype=bool)
            if cfg.delta_ref_budget_bytes is not None:
                # lazy: kvcache pulls in jax for its buffer helpers; the
                # ledger itself is pure Python
                from .kvcache import ReferenceLedger
                self._delta_ledger = ReferenceLedger(
                    cfg.delta_ref_budget_bytes)
            self._refresh_delta_tables()
            if cfg.delta_drift_every > 0 and bool(self._delta_is.any()):
                self._schedule_delta_replans()

        self.replica_names = [f"cloud{i}" for i in range(cfg.n_replicas)]
        self.pool = ElasticPool(on_change=self._on_replicas,
                                timeout_s=cfg.heartbeat_timeout_s)
        self.batchers: Dict[str, MicroBatcher] = {
            r: MicroBatcher(cfg.batch_size, cfg.batch_wait_s)
            for r in self.replica_names}
        self.cbatchers: Dict[str, ContinuousBatcher] = {}
        self.kv_cumsum: Dict[str, np.ndarray] = {}
        if cfg.continuous:
            # lazy: kvcache pulls in jax for its buffer helpers; the
            # analytic cumsums used here are numpy-only
            from .kvcache import graph_kv_cumsum
            self.kv_cumsum = {
                a: graph_kv_cumsum(self.graphs[a], get_config(a),
                                   cfg.workload) for a in archs}
            self.cbatchers = {
                r: ContinuousBatcher(cfg.batch_size, cfg.kv_budget_bytes,
                                     batch_overlap=cfg.batch_overlap,
                                     kv_admit_frac=cfg.kv_admit_frac)
                for r in self.replica_names}
        self.mitigator = StragglerMitigator()
        self.busy_until: Dict[str, float] = {r: 0.0
                                             for r in self.replica_names}

        self._down: set = set()
        self._cloud_up = True
        self._pending: Dict[int, _CloudWork] = {}
        self._next_wid = 0
        self.next_free = np.zeros(cfg.n_robots, dtype=np.float64)
        self.latencies: List[List[float]] = [[] for _ in range(cfg.n_robots)]
        # engine hooks (events engine only; None = tick loop, no-ops):
        # _wake(robot) fires after _complete releases a robot's closed
        # loop, _wake_batch(idx_array) is its vectorized counterpart
        # (one call per completion batch), _enq(replica) after cloud
        # work lands on a replica
        self._wake = None
        self._wake_batch = None
        self._enq = None
        # open-loop arrival traffic state (events engine fills these)
        self.proc_latencies: List[List[float]] = [
            [] for _ in cfg.arrival_processes]
        self.proc_arrivals = [0] * len(cfg.arrival_processes)
        self.proc_rejections = [0] * len(cfg.arrival_processes)
        self.n_autoscale = 0
        self.n_hedged = 0
        self.n_replans = 0
        self.n_outage_completions = 0
        self.n_codec_switches = 0
        self.n_cut_moves = 0
        self.n_multicut_requests = 0
        self.n_chunk_reconfigs = 0
        self.n_streamed_requests = 0
        self._bubble_sum = 0.0
        # flight recorder (core/telemetry.py): None = off.  Every hot-path
        # hook below guards on ``self.recorder is not None``, so the off
        # path costs one attribute check per request.
        self.recorder: Optional[FlightRecorder] = None
        if cfg.telemetry != "off":
            self.recorder = FlightRecorder(
                mode=cfg.telemetry, cap=cfg.telemetry_cap,
                sample_every=cfg.telemetry_sample_every, seed=cfg.seed)
            for r, cb in self.cbatchers.items():
                cb.observer = ContObserver(self.recorder, r)

    # ---------------------------------------------------------- plan tables
    def _build_plans(self, queue_hz: float):
        """One vectorized plan-table pass at the given per-replica arrival
        rate.  Returns ``(plan, plan_s2, plan_codec, plan_chunks)`` dicts
        keyed by arch; ``queue_hz = 0`` is the queue-blind table."""
        cfg = self.cfg
        archs = list(self.graphs)
        qkw = dict(queue_hz=queue_hz, queue_cv2=cfg.queue_cv2,
                   queue_service_scale=cfg.queue_service_scale)
        if cfg.streamed:
            # streamed plan table: per-model (C, S1, S2, K, B) passes —
            # each bin stores the joint (S1, S2, codec, n_chunks) optimum
            # (single-cut masked when not multicut); K = 1 bins price
            # exactly like the non-streamed tables
            st = sweep_multicut(self.graphs, cfg.edge, cfg.cloud,
                                self.bw_grid, cfg.cloud_budget_bytes,
                                rtt_s=cfg.rtt_s,
                                input_bytes=cfg.workload.input_bytes,
                                codecs=self.codecs,
                                down_bw_factor=cfg.down_bw_factor,
                                single_cut_only=not cfg.multicut,
                                chunk_grid=cfg.chunk_grid, **qkw)
            return ({a: st[a].s1 for a in archs},
                    {a: st[a].s2 for a in archs},
                    {a: st[a].codec_idx for a in archs},
                    {a: st[a].n_chunks for a in archs})
        if cfg.multicut:
            # multi-cut plan table: one (M, C, S1, S2, B) pass — each bin
            # stores the joint (S1, S2, codec) optimum; S2 == n collapses
            # the bin to the single-cut plan
            mc = sweep_multicut(self.graphs, cfg.edge, cfg.cloud,
                                self.bw_grid, cfg.cloud_budget_bytes,
                                rtt_s=cfg.rtt_s,
                                input_bytes=cfg.workload.input_bytes,
                                codecs=self.codecs,
                                down_bw_factor=cfg.down_bw_factor, **qkw)
            return ({a: mc[a].s1 for a in archs},
                    {a: mc[a].s2 for a in archs},
                    {a: mc[a].codec_idx for a in archs},
                    {a: np.ones(len(self.bw_grid), dtype=int)
                     for a in archs})
        plans = sweep_search(self.graphs, cfg.edge, cfg.cloud,
                             self.bw_grid, cfg.cloud_budget_bytes,
                             rtt_s=cfg.rtt_s,
                             input_bytes=cfg.workload.input_bytes,
                             codecs=self.codecs, **qkw)
        return ({a: plans[a].splits for a in archs},
                {a: np.full(len(self.bw_grid), self.arrays[a].n, dtype=int)
                 for a in archs},
                {a: plans[a].codec_idx for a in archs},
                {a: np.ones(len(self.bw_grid), dtype=int) for a in archs})

    def _estimate_arrival_hz(self) -> float:
        """Per-replica cloud arrival rate for the queue-aware plan tables:
        the open-loop estimate (``_open_arrival_hz``) capped by the
        closed-network population bound (``_closed_loop_cap_hz``).  The
        open estimate alone treats every robot as re-issuing at its
        zero-wait cycle rate — on a fast cloud that over-counts badly
        (the closed loop slows itself down as queues build), drives the
        M/G/1 term to ρ ≥ 1 and makes the planner retreat to plan-harmful
        edge-heavy splits (docs/EXPERIMENTS.md §Queue-aware)."""
        lam = self._open_arrival_hz()
        cap = self._closed_loop_cap_hz()
        return min(lam, cap) if cap > 0.0 else lam

    def _open_arrival_hz(self) -> float:
        """Per-replica cloud arrival rate implied by the queue-blind plan
        at the nominal bandwidth: every robot whose nominal-bin plan has a
        non-empty cloud window re-issues as fast as its planned closed
        loop allows (rate ``1 / T_i``, with ``T_i`` the plan's end-to-end
        latency), spread uniformly over the replicas."""
        cfg = self.cfg
        k0 = int(np.searchsorted(self._bw_mid, cfg.nominal_bw_bps))
        lam = 0.0
        for a in self.arch_of:
            arrays = self.arrays[a]
            s1 = int(self.plan[a][k0])
            s2 = int(self.plan_s2[a][k0])
            if s1 >= s2:
                continue                       # no cloud work planned
            cdc = self.codecs[int(self.plan_codec[a][k0])]
            if s2 < arrays.n:
                eh, c, t, dn = arrays.placement_latency(
                    s1, s2, cfg.nominal_bw_bps, cfg.rtt_s, codec=cdc,
                    down_bw_factor=cfg.down_bw_factor)
                total = eh + c + t + dn
            else:
                e, c, t = arrays.latency(s1, cfg.nominal_bw_bps,
                                         cfg.rtt_s, codec=cdc)
                total = e + c + t
            if total > 0:
                lam += 1.0 / total
        return lam / max(1, cfg.n_replicas)

    def _closed_loop_cap_hz(self) -> float:
        """Closed-network population bound on the per-replica arrival
        rate.  The fleet is a CLOSED queueing network — each robot has at
        most one request in flight — and a single server cycled by ``N_r``
        customers can never be driven past utilization
        ``ρ = N_r / (N_r + 1)`` (the asymptotic mean-value-analysis bound;
        at ρ above it the customers would all have to be queued *and* in
        service at once).  With ``S̄`` the mean planned cloud service time
        of the robots that use the cloud, that bounds the sustainable
        per-replica rate at ``λ ≤ ρ_max / S̄`` — equivalently
        ``λ ≤ N_r / E[cycle time]`` with the cycle floored at its service
        content.  The full M/M/1/K / exact-MVA prior (wait-aware cycle
        times, per-class populations) stays on the roadmap; this cap is
        the honest slice that stops the open estimator's ρ ≥ 1 retreat.
        Returns 0.0 when no robot plans cloud work (no cap needed)."""
        cfg = self.cfg
        k0 = int(np.searchsorted(self._bw_mid, cfg.nominal_bw_bps))
        services = []
        for a in self.arch_of:
            arrays = self.arrays[a]
            s1 = int(self.plan[a][k0])
            s2 = int(self.plan_s2[a][k0])
            if s1 >= s2:
                continue                       # no cloud work planned
            services.append(float(arrays.cloud_s[s1] - arrays.cloud_s[s2]))
        if not services:
            return 0.0
        n_r = len(services) / max(1, cfg.n_replicas)
        s_bar = (sum(services) / len(services)) * cfg.queue_service_scale
        if s_bar <= 0.0:
            return 0.0
        rho_max = n_r / (n_r + 1.0)
        return rho_max / s_bar

    @property
    def place_of(self) -> List[tuple]:
        """Compatibility view of the per-robot placement state (the
        struct-of-arrays refactor split it into ``place_s1``/``place_s2``)."""
        return list(zip(self.place_s1.tolist(), self.place_s2.tolist()))

    def _refresh_pool_cache(self) -> None:
        """Re-snapshot every robot's parameter-sharing pools — the Pool
        objects for the scalar clamp and the lo/hi bound arrays for the
        vectorized one.  Pools move only inside ``RoboECC.replan()``, so
        this runs at construction and after each ``_on_replicas`` replan
        wave — the per-request clamp then never touches the controller."""
        for i, ctl in enumerate(self.controllers):
            p1 = ctl.pool
            p2 = getattr(ctl, "pool2", None)
            self._pools1[i] = p1
            self._pools2[i] = p2
            self._pool_lo1[i] = p1.start
            self._pool_hi1[i] = p1.end
            if p2 is not None:
                self._pool_lo2[i] = p2.start
                self._pool_hi2[i] = p2.end
                self._has_pool2[i] = True
            else:
                self._pool_lo2[i] = 0
                self._pool_hi2[i] = 0
                self._has_pool2[i] = False

    # ----------------------------------------------------------- elasticity
    # attributes ``RoboECC.replan`` reassigns — the replan memo snapshots
    # exactly these (all are replaced wholesale, never mutated in place,
    # so a shallow snapshot/restore is exact)
    _REPLAN_ATTRS = ("edge_dev", "cloud_dev", "seg", "placement", "split",
                     "pool", "pool2")

    def _ctl_snapshot(self, ctl: RoboECC) -> dict:
        return {a: getattr(ctl, a) for a in self._REPLAN_ATTRS}

    def _replan_wave(self, condition: str) -> None:
        """Replan every DISTINCT controller for a cloud condition
        (``"dead"`` = full outage, ``"alive"`` = restored), restoring a
        memoized snapshot when this controller has already been replanned
        for the condition — ``replan()`` under a fixed condition is
        deterministic, so the snapshot IS the replan result."""
        cfg = self.cfg
        t0 = time.perf_counter()
        done: set = set()
        for ctl in self.controllers:
            if id(ctl) in done:
                continue
            done.add(id(ctl))
            key = (id(ctl), condition)
            snap = self._replan_memo.get(key)
            if snap is not None:
                for attr, val in snap.items():
                    setattr(ctl, attr, val)
                continue
            if condition == "dead":
                ctl.replan(cloud=self._dead_cloud,
                           nominal_bw_bps=cfg.nominal_bw_bps)
            else:
                ctl.replan(cloud=cfg.cloud,
                           cloud_budget_bytes=cfg.cloud_budget_bytes,
                           nominal_bw_bps=cfg.nominal_bw_bps)
            self._replan_memo[key] = self._ctl_snapshot(ctl)
        # accounting matches the historical one-replan-per-robot waves:
        # sharing controllers dedups the WORK, not the event count
        self.n_replans += cfg.n_robots
        self._refresh_pool_cache()
        self.replan_wall_s += time.perf_counter() - t0

    def _on_replicas(self, live: List[str]) -> None:
        """ElasticPool transition: full outage → every robot replans to
        edge-only (split = n); first re-join → replan restores Alg. 1."""
        if not live and self._cloud_up:
            self._cloud_up = False
            self._replan_wave("dead")
        elif live and not self._cloud_up:
            self._cloud_up = True
            self._replan_wave("alive")

    # ------------------------------------------------------------- planning
    def _planned_placement(self, robot: int, bw_bps: float) -> tuple:
        """Plan-table lookup for this bandwidth bin: the (S1, S2) placement
        window, each cut clamped into its parameter-sharing pool — cuts
        may only move where weights are already resident on both tiers
        (a robot whose controller planned single-cut has no tail pool, so
        its S2 pins to n).  Also advances the robot's codec state to the
        jointly-planned codec (a pure software switch — no weights move)
        and counts effective placement changes in ``n_cut_moves``; in
        streamed mode likewise the robot's chunk count (another pure
        software reconfiguration, ``n_chunk_reconfigs``) — bins or
        clamped placements where streaming does not apply reset it to 1.
        Returns ``(s1, s2, n_chunks)``."""
        arch = self.arch_of[robot]
        k = bisect.bisect_left(self._bw_mid_list, bw_bps)
        n = self.arrays[arch].n
        s1_plan = int(self.plan[arch][k])
        s2_plan = int(self.plan_s2[arch][k])
        # adopt the bin's codec only when its plan has a codec-applicable
        # transport leg — a no-transfer (edge-only) or raw-observation-only
        # bin breaks codec ties arbitrarily, and the pool clamp below may
        # still force a collaborative cut, which must not ship raw just
        # because the bin's codec was meaningless
        if s1_plan < s2_plan and (0 < s1_plan < n or s2_plan < n):
            ci = int(self.plan_codec[arch][k])
            if ci != self.codec_of[robot]:
                self.codec_of[robot] = ci
                self.n_codec_switches += 1
        s1 = self._pools1[robot].clamp(s1_plan)
        pool2 = self._pools2[robot]
        if pool2 is not None:
            s2 = max(s1, pool2.clamp(s2_plan))
        else:
            s2 = n
        if s1 != self.place_s1[robot] or s2 != self.place_s2[robot]:
            self.place_s1[robot] = s1
            self.place_s2[robot] = s2
            self.n_cut_moves += 1
        kc = int(self.plan_chunks[arch][k]) if self.cfg.streamed else 1
        if not (s1 < s2 and stream_applies(
                s1, n, float(self.arrays[arch].wire_bytes[s1]))):
            kc = 1          # clamped/degenerate placement: nothing streams
        if kc != self.chunks_of[robot]:
            self.chunks_of[robot] = kc
            self.n_chunk_reconfigs += 1
        return s1, s2, kc

    def _planned_split(self, robot: int, bw_bps: float) -> int:
        """Single-cut view of ``_planned_placement`` (legacy helper)."""
        return self._planned_placement(robot, bw_bps)[0]

    # -------------------------------------------------------- temporal delta
    def _refresh_delta_tables(self) -> None:
        """Per-codec-index delta parameter arrays for the measured
        pricing: which codecs are delta, the base codec's wire factor
        (key-frame cost), the change-mask wire factor (one bit per
        ``row_elems`` raw elements) and the resync cadence.  Rebuilt
        whenever ``self.codecs`` is swapped by a drift replan."""
        cd = self.codecs
        self._delta_is = np.asarray(
            [isinstance(c, DeltaCodec) for c in cd], dtype=bool)
        base_wf = np.zeros(len(cd))
        mask_wf = np.zeros(len(cd))
        R = np.ones(len(cd), dtype=np.int64)
        for j, c in enumerate(cd):
            if isinstance(c, DeltaCodec):
                b = make_codecs(c.raw_bytes_per_elem)[c.base]
                base_wf[j] = b.wire_factor
                mask_wf[j] = (1.0 / (8.0 * c.row_elems)) \
                    / c.raw_bytes_per_elem
                R[j] = c.resync_every
        self._delta_base_wf = base_wf
        self._delta_mask_wf = mask_wf
        self._delta_R = R

    def _schedule_delta_replans(self) -> None:
        """Precompute the drift-replan schedule from the scene matrix:
        every ``delta_drift_every`` ticks, compare the fleet-mean
        measured change fraction over the window against the current
        planned ``change_frac``; relative drift beyond
        ``delta_drift_tol`` schedules a replan at that tick.  Purely a
        function of (seed, scene, tick) — robot processing order never
        enters — so the tick, scalar-event and vectorized-event engines
        apply identical replans at identical points."""
        cfg = self.cfg
        fm = self.scene_mat.mean(axis=0)
        planned = float(next(c.change_frac for c in self.codecs
                             if isinstance(c, DeltaCodec)))
        w = int(cfg.delta_drift_every)
        for t0 in range(w, cfg.n_ticks + 1, w):
            m = float(fm[t0 - w:t0].mean())
            if planned > 0.0 and abs(m - planned) / planned \
                    > cfg.delta_drift_tol:
                self._delta_replan_at[t0] = m
                planned = m
        self._delta_replan_ticks = sorted(self._delta_replan_at)

    def _maybe_delta_replan(self, tick: int) -> None:
        """Apply every scheduled drift replan with trigger tick ≤ this
        tick.  Called at the top of both robot-phase bodies, before any
        robot of the tick is priced; the fast path (no pending replan)
        is one comparison."""
        ptr = self._delta_replan_ptr
        ts = self._delta_replan_ticks
        while ptr < len(ts) and ts[ptr] <= tick:
            self._apply_delta_replan(self._delta_replan_at[ts[ptr]])
            ptr += 1
        self._delta_replan_ptr = ptr

    def _apply_delta_replan(self, measured_frac: float) -> None:
        """Rebuild every delta codec around the measured change fraction
        (same base / cadence / threshold, same NAME — codec indices in
        ``codec_of`` and the plan tables stay valid) and re-run the plan
        tables with it.  Controllers keep their construction-time codec:
        fleet drift replans move the shared plan tables, not the
        per-arch ``RoboECC`` state — controller-grade adaptation is the
        separately-tested ``RoboECC.observe_change_frac``."""
        self.codecs = [
            make_delta_codec(base=c.base, change_frac=measured_frac,
                             resync_every=c.resync_every,
                             threshold=c.threshold, row_elems=c.row_elems,
                             raw_bytes_per_elem=c.raw_bytes_per_elem,
                             name=c.name)
            if isinstance(c, DeltaCodec) else c for c in self.codecs]
        (self.plan, self.plan_s2, self.plan_codec,
         self.plan_chunks) = self._build_plans(self.plan_queue_hz)
        self._bst = None
        self._refresh_delta_tables()
        self.n_delta_replans += 1

    def _delta_frame(self, i: int, ci: int, frac: float, wire_raw: float
                     ) -> float:
        """One robot's delta-frame decision: key frame when the robot
        has no live reference, the resync cadence fires, or the delta
        at this frame's change fraction would not beat a key frame
        (fully dynamic scenes degrade to every-frame key frames — the
        honest negative).  Updates the per-robot cadence state, the
        frame counters and — with a budget — the reference ledger
        (reference bytes = the raw activation at the cut; evicted
        robots lose their reference and key-frame next time).  Returns
        the measured wire factor for this frame."""
        base_wf = float(self._delta_base_wf[ci])
        dwf = frac * base_wf + float(self._delta_mask_wf[ci])
        key = ((not self.delta_has_ref[i])
               or self.delta_ssk[i] >= self._delta_R[ci] - 1
               or dwf >= base_wf)
        self.delta_ssk[i] = 0 if key else self.delta_ssk[i] + 1
        self.delta_has_ref[i] = True
        if key:
            self.n_keyframes += 1
        else:
            self.n_delta_frames += 1
        if self._delta_ledger is not None:
            for k in self._delta_ledger.put(int(i), wire_raw):
                self.delta_has_ref[k] = False
                self.n_ref_evictions += 1
        return base_wf if key else dwf

    def _delta_uplink(self, i: int, tick: int, s1: int, s2: int, n: int,
                      wire_raw: float, cdc: Codec) -> Optional[float]:
        """Scalar measured-wire hook for one robot step: ``None`` when
        the placement has no codec-applicable uplink leg; otherwise the
        measured wire factor (the codec's own factor for non-delta
        codecs — the byte accounting must cover the comparison
        baselines too), with the robot's wire bytes accumulated."""
        if not (s1 < s2 and 0 < s1 < n and wire_raw > 0.0):
            return None
        if self._delta_is[cdc_i := int(self.codec_of[i])]:
            wf = self._delta_frame(i, cdc_i, float(self.scene_mat[i, tick]),
                                   wire_raw)
        else:
            wf = cdc.wire_factor
        self.wire_bytes_of[i] += wf * wire_raw
        return wf

    def _delta_uplink_batch(self, idxs: np.ndarray, tick: int,
                            s1: np.ndarray, s2: np.ndarray,
                            n_v: np.ndarray, wire_s1: np.ndarray,
                            ci: np.ndarray) -> tuple:
        """Vector mirror of ``_delta_uplink`` over one tick's batch:
        identical expressions elementwise, per-robot state updates
        vectorized (independent across robots), byte accumulation
        per-robot (order-independent — ``idxs`` are unique).  With a
        reference budget the delta walk drops to a scalar loop in
        ascending index: ledger eviction is order-sensitive, and the
        scalar engine processes robots in exactly that order.  Returns
        ``(wire factors, applicable mask)``."""
        bst = self._bst
        app = (s1 < s2) & (0 < s1) & (s1 < n_v) & (wire_s1 > 0.0)
        wf = np.array(bst["wf"][ci])
        d = app & self._delta_is[ci]
        if self._delta_ledger is None:
            if d.any():
                frac = self.scene_mat[idxs, tick]
                base_wf = self._delta_base_wf[ci]
                dwf = frac * base_wf + self._delta_mask_wf[ci]
                ssk = self.delta_ssk[idxs]
                key = d & (~self.delta_has_ref[idxs]
                           | (ssk >= self._delta_R[ci] - 1)
                           | (dwf >= base_wf))
                wf = np.where(d, np.where(key, base_wf, dwf), wf)
                self.delta_ssk[idxs] = np.where(
                    d, np.where(key, 0, ssk + 1), ssk)
                self.delta_has_ref[idxs] |= d
                self.n_keyframes += int(np.count_nonzero(key))
                self.n_delta_frames += int(np.count_nonzero(d & ~key))
        else:
            for j in np.flatnonzero(d):
                wf[j] = self._delta_frame(
                    int(idxs[j]), int(ci[j]),
                    float(self.scene_mat[int(idxs[j]), tick]),
                    float(wire_s1[j]))
        aw = np.flatnonzero(app)
        if len(aw):
            self.wire_bytes_of[idxs[aw]] += wf[aw] * wire_s1[aw]
        return wf, app

    # ------------------------------------------------------------- streaming
    def _stream_uplink(self, robot: int, arrays: GraphArrays, s1: int,
                       cdc: Codec, edge_head_s: float, cloud_s: float,
                       wire_factor: Optional[float] = None) -> tuple:
        """Price the robot's chunked uplink against its ACTUAL trace: the
        transfer starts once the edge head finishes and chunk 1 is
        encoded, chunks ship back-to-back consuming each tick's bandwidth
        (``NetworkSim.wire_trace_s`` — a transfer spanning many ticks
        sees every tick it spans, not one frozen rate), and the cloud
        window's prefill overlaps arrived chunks.  Returns the
        transport-exposed uplink seconds (``makespan − cloud_s`` — the
        replica still executes the full window inside its batch, so the
        batched-execution machinery composes unchanged) and the pipeline's
        fill/drain bubble fraction.

        ``wire_factor`` overrides the codec's cycle-average wire factor
        with this frame's MEASURED one (temporal delta: key frames ship
        the full base payload, delta frames only the changed rows).
        Encode/decode stay at the codec's cycle-average rates — the
        per-frame codec work variation is second-order next to the wire
        term it scales, and keeping it fixed keeps the planner's
        compute-side pricing exact."""
        net = self.nets[robot]
        K = self.chunks_of[robot]
        wire_raw = float(arrays.wire_bytes[s1])
        enc = cdc.encode_s(wire_raw, self.cfg.edge)
        dec = cdc.decode_s(wire_raw, self.cfg.cloud)
        wire_c = (wire_raw * wire_factor if wire_factor is not None
                  else cdc.wire_bytes(wire_raw))
        per_chunk = wire_c / K
        off = edge_head_s + enc / K
        wire_times = []
        for _ in range(K):
            w = net.wire_trace_s(per_chunk, off)
            wire_times.append(w)
            off += w
        m = stream_makespan_scalar(enc, np.asarray(wire_times),
                                   dec + cloud_s, K, net.rtt_s)
        peak = max(enc, sum(wire_times) + K * net.rtt_s, dec + cloud_s)
        bubble = (m - peak) / m if m > 0 else 0.0
        return m - cloud_s, bubble

    # ------------------------------------------------------------ telemetry
    # Issue-time prediction capture for the drift audit.  Only sampled
    # requests pay for these (the recorder's ``want()`` gate comes
    # first), and nothing here touches ``self.rng`` or any other
    # simulation state — the recorder-off run is bit-identical.

    def _tele_key(self, i: int, now: float) -> int:
        """Engine-order-independent request identity for sampling: robot
        (or arrival) index × issue tick.  Both engines and both robot
        phases (scalar/vectorized) derive the same key for the same
        request, so the sampled subset never depends on replay order."""
        return i * 1_000_003 + int(round(now / self.cfg.tick_s))

    def _tele_want_js(self, idxs: np.ndarray, now: float) -> np.ndarray:
        """Vectorized ``FlightRecorder.want`` over one batch of robot
        indices: the same ``_tele_key`` → Knuth-hash keep/drop decision
        as the scalar gate, in one numpy pass — sampled mode must not
        pay a Python loop over every unsampled robot.  uint64 wraps mod
        2**64, a multiple of the gate's 2**32 mask, so the masked hash
        is bitwise the scalar one.  Returns positions into ``idxs``
        whose request is recorded (all of them in full mode)."""
        rec = self.recorder
        if rec.mode == "full":
            return np.arange(len(idxs))
        tickk = int(round(now / self.cfg.tick_s))
        keys = idxs.astype(np.uint64) * np.uint64(1_000_003) \
            + np.uint64(tickk)
        h = (keys * np.uint64(_HASH_KNUTH)) & np.uint64(0xFFFFFFFF)
        return np.flatnonzero(h % np.uint64(rec.sample_every) == 0)

    def _tele_pred_edge(self, lane: str, e: float) -> dict:
        """Edge-only prediction (outage / no-cloud-work placements)."""
        return {"edge_s": e, "uplink_s": 0.0, "queue_s": 0.0,
                "service_s": 0.0, "down_s": 0.0, "total_s": e,
                "wire_bytes": 0.0, "_lane": lane, "_enc_s": 0.0,
                "_dec_s": 0.0, "_wire_bytes": 0.0, "_bubble": None}

    def _tele_pred(self, lane: str, arch: str, bw: float, s1: int, s2: int,
                   kc: int, ci: int, e: float, c: float, t: float,
                   down: float, wire_meas_over: Optional[float] = None
                   ) -> dict:
        """The planner's predicted stage decomposition at issue time —
        the ``evaluate_placement`` legs as priced (edge head, uplink,
        cloud window, downlink + tail), the M/G/1 wait prior the
        queue-aware tables optimized (``queue_delay_s`` at the plan's
        arrival rate; 0 when queue-blind, clamped to 0 with a counter
        when the prior saturates), and for streamed placements the
        FROZEN-bandwidth 3-stage makespan (uniform chunk wire times at
        the issue-time rate) in place of the trace-integrated uplink the
        runtime will actually pay.  Private ``_``-keys carry span
        context (lane, codec costs, measured wire bytes) to completion.

        ``wire_meas_over`` overrides the measured wire bytes with this
        frame's actual shipped bytes (temporal delta); the predicted
        bytes stay at the plan bin's cycle average, so the existing
        ``wire_bytes`` drift stage directly audits how far the planned
        change fraction sat from the scene's reality."""
        cfg = self.cfg
        rec = self.recorder
        arrays = self.arrays[arch]
        cdc = self.codecs[ci]
        n = arrays.n
        wire_raw = float(arrays.wire_bytes[s1])
        applicable = (0 < s1 < n) and wire_raw > 0.0
        wire_meas = cdc.wire_bytes(wire_raw) if applicable else wire_raw
        if wire_meas_over is not None:
            wire_meas = wire_meas_over
        # predicted wire bytes come from the PLAN BIN (unclamped split,
        # bin codec); the measured bytes from the clamped split + sticky
        # codec state — their gap is the pool-clamp / codec-gate drift
        k = bisect.bisect_left(self._bw_mid_list, bw)
        s1p = int(self.plan[arch][k])
        cp = self.codecs[int(self.plan_codec[arch][k])]
        wire_rawp = float(arrays.wire_bytes[s1p]) if s1p <= n else 0.0
        wire_pred = (cp.wire_bytes(wire_rawp)
                     if (0 < s1p < n) and wire_rawp > 0.0 else wire_rawp)
        up_pred, bub_pred = t, 0.0
        enc_s = dec_s = 0.0
        if applicable:
            enc_s = cdc.encode_s(wire_raw, cfg.edge)
            dec_s = cdc.decode_s(wire_raw, cfg.cloud)
        if kc > 1 and c > 0.0:
            # frozen-bandwidth streamed makespan: what the plan table's
            # pipeline model promised before the trace moved under it
            wires = np.full(kc, wire_meas / kc / bw)
            m = stream_makespan_scalar(enc_s, wires, dec_s + c, kc,
                                       cfg.rtt_s)
            peak = max(enc_s, float(wires.sum()) + kc * cfg.rtt_s,
                       dec_s + c)
            bub_pred = (m - peak) / m if m > 0 else 0.0
            up_pred = m - c
            enc_s = dec_s = 0.0      # chunked: no single encode/wire split
        q_pred = 0.0
        if c > 0.0 and self.plan_queue_hz > 0.0:
            q_pred = queue_delay_s(c, self.plan_queue_hz,
                                   cv2=cfg.queue_cv2,
                                   service_scale=cfg.queue_service_scale)
            if not math.isfinite(q_pred):
                rec.drift.n_pred_saturated += 1
                q_pred = 0.0
        return {"edge_s": e, "uplink_s": up_pred, "queue_s": q_pred,
                "service_s": c, "down_s": down,
                "total_s": e + up_pred + q_pred + c + down,
                "wire_bytes": wire_pred, "bubble_frac": bub_pred,
                "_lane": lane, "_enc_s": enc_s, "_dec_s": dec_s,
                "_wire_bytes": wire_meas, "_bubble": None}

    # ------------------------------------------------------------ execution
    def _complete(self, robot: int, issued_s: float, latency_s: float) -> None:
        """Fold a finished request into the robot's series and release the
        robot's control loop (closed loop: one outstanding request each).
        The events engine hooks ``_wake`` to schedule the robot's next
        control step; the tick loop polls ``next_free`` instead."""
        self.latencies[robot].append(latency_s)
        self.next_free[robot] = issued_s + latency_s
        if self._wake is not None:
            self._wake(robot)

    def _deliver(self, it: _CloudWork, latency_s: float) -> None:
        """Route a finished piece of work to its owner: closed-loop robots
        fold into ``_complete`` (releasing the control loop), open-loop
        arrivals into their process latency series (nothing to release —
        a one-shot request has no issuer waiting)."""
        if it.proc >= 0:
            self.proc_latencies[it.proc].append(latency_s)
        else:
            self._complete(it.robot, it.issued_s, latency_s)

    def _execute(self, requests: Sequence[Request], live: List[str]) -> None:
        """Run one formed batch on the best replica, hedging stragglers."""
        cfg = self.cfg
        items = [self._pending.pop(rq.rid) for rq in requests]
        ready = max(it.ready_s for it in items)
        costs = [it.cloud_s for it in items]
        peak = max(costs)
        # batched execution: the heaviest member bounds the pass; the rest
        # overlaps all but (1 - batch_overlap) of its work
        base = peak + (sum(costs) - peak) * (1.0 - cfg.batch_overlap)

        def exec_fn(replica: str) -> float:
            wait = max(0.0, self.busy_until[replica] - ready)
            slow = float(np.exp(self.rng.normal(0.0, cfg.straggler_sigma)))
            if self.rng.random() < cfg.tail_prob:
                slow *= cfg.tail_scale
            return wait + base * slow

        out = self.mitigator.run(list(live), exec_fn)
        if out.hedged:
            self.n_hedged += 1
        rec = self.recorder
        # winner's pre-update busy wait: the queue share of out.latency_s
        wait_w = (max(0.0, self.busy_until[out.winner] - ready)
                  if rec is not None else 0.0)
        self.busy_until[out.winner] = ready + out.latency_s
        for rq, it in zip(requests, items):
            # down_s = downlink transport + edge-tail compute of a 2-cut
            # placement (0 for single-cut), paid after the cloud batch.
            # Only requests that actually complete the 2-cut path count —
            # outage fallbacks re-execute edge-only and don't.
            if it.two_cut:
                self.n_multicut_requests += 1
            lat = (it.edge_s + it.net_s
                   + (ready - it.ready_s) + out.latency_s
                   + it.down_s)
            if rec is not None and it.pred is not None:
                p = it.pred
                rec.record_request(
                    req=rq.rid, lane=p["_lane"], t0_s=it.issued_s,
                    edge_s=it.edge_s, uplink_s=it.net_s,
                    queue_s=(ready - it.ready_s) + wait_w,
                    service_s=out.latency_s - wait_w, down_s=it.down_s,
                    total_s=lat, replica=out.winner,
                    enc_s=p["_enc_s"], dec_s=p["_dec_s"], pred=p,
                    outcome="hedged" if out.hedged else "ok",
                    wire_bytes=p["_wire_bytes"],
                    bubble_frac=p["_bubble"])
            self._deliver(it, lat)

    def _finish_cont(self, req: Request, fin_s: float) -> None:
        """Fold one continuous-tier completion: the robot pays its edge +
        uplink legs, the replica-side sojourn (admission wait + batched
        service, ``fin_s - ready_s``) and any 2-cut downlink tail."""
        it = self._pending.pop(req.rid)
        if it.two_cut:
            self.n_multicut_requests += 1
        lat = (it.edge_s + it.net_s + (fin_s - it.ready_s)
               + it.down_s)
        rec = self.recorder
        if rec is not None and it.pred is not None:
            # the ContObserver accumulated this request's admission
            # waits; service = sojourn minus queue (batched execution
            # including any preempt/recompute cycles)
            st = rec.pop_cont(req.rid) or {}
            p = it.pred
            q = st.get("queue_s", 0.0)
            rec.record_request(
                req=req.rid, lane=p["_lane"], t0_s=it.issued_s,
                edge_s=it.edge_s, uplink_s=it.net_s, queue_s=q,
                service_s=(fin_s - it.ready_s) - q, down_s=it.down_s,
                total_s=lat, replica=st.get("replica"),
                enc_s=p["_enc_s"], dec_s=p["_dec_s"], pred=p,
                extra_spans=st.get("spans", ()),
                outcome="preempted" if st.get("preempts") else "ok",
                wire_bytes=p["_wire_bytes"], bubble_frac=p["_bubble"])
        self._deliver(it, lat)

    def _drain_dead_cont(self, routable: List[str]) -> None:
        """Continuous tier: a dead replica's slots and queue are evicted
        (in-flight KV is lost — full recompute) and re-admitted on the
        least-backlogged routable replica, or fall back to edge-only
        re-execution when no replica accepts work."""
        for r in self.replica_names:
            if r in self._down and len(self.cbatchers[r]):
                for req, svc, kv in self.cbatchers[r].drain():
                    if routable:
                        tgt = min(routable, key=lambda x:
                                  self.cbatchers[x].backlog_s)
                        self.cbatchers[tgt].add(req, svc, kv)
                    else:
                        if self.recorder is not None:
                            self.recorder.pop_cont(req.rid)
                        self._fallback_one(self._pending.pop(req.rid))

    def _fallback_one(self, it: _CloudWork) -> None:
        """Cloud unavailable with work in flight: re-execute the request
        entirely on its robot's edge device (uplink time already spent is
        kept as sunk cost)."""
        arch = (self.arch_of[it.robot] if it.proc < 0
                else self.cfg.arrival_processes[it.proc].arch)
        arrays = self.arrays[arch]
        edge_only = float(arrays.edge_s[arrays.n])
        lat = it.edge_s + it.net_s + edge_only
        rec = self.recorder
        if rec is not None and it.pred is not None:
            # sunk edge+uplink cost plus the edge re-execution; the
            # planned cloud window/downlink never ran — their drift is
            # the full prediction, which is exactly the outage story
            p = it.pred
            rec.record_request(
                req=-1, lane=p["_lane"], t0_s=it.issued_s,
                edge_s=it.edge_s, uplink_s=it.net_s, queue_s=0.0,
                service_s=edge_only, down_s=0.0, total_s=lat,
                enc_s=p["_enc_s"], dec_s=p["_dec_s"], pred=p,
                outcome="fallback", wire_bytes=p["_wire_bytes"])
        self._deliver(it, lat)
        self.n_outage_completions += 1

    def _fallback(self, requests: Sequence[Request]) -> None:
        for rq in requests:
            self._fallback_one(self._pending.pop(rq.rid))

    # --------------------------------------------------- shared phase bodies
    # Both engines call these EXACT bodies.  The parity proof
    # (tests/test_engine_parity.py: FleetReport dataclass-equal across the
    # whole config matrix) rests on the event engine replaying the same
    # arithmetic in the same order, just sparsely — so the phase bodies
    # live here once, and the engines only differ in *when* they call them.

    def _robot_step(self, i: int, now: float, routable: List[str]) -> None:
        """One closed-loop control step for a free robot: plan, price,
        enqueue cloud work (or complete locally).  The caller guarantees
        ``now >= next_free[i]`` and that ``nets[i]`` sits at this tick."""
        cfg = self.cfg
        if self.scene_mat is not None:
            # drift replans fire on a precomputed tick schedule, before
            # any robot of the tick is priced (both engines identical)
            self._maybe_delta_replan(int(round(now / cfg.tick_s)))
        net = self.nets[i]
        bw = net.now_bps
        arrays = self.arrays[self.arch_of[i]]
        down, two_cut = 0.0, False
        s1 = s2 = arrays.n
        kc, bub = 1, None
        wf_eff = None                  # measured wire factor (scene axis)
        if self._cloud_up:
            s1, s2, kc = self._planned_placement(i, bw)
            cdc = self.codecs[self.codec_of[i]]
            if s2 < arrays.n:
                # real 2-cut placement: the edge head runs before the
                # uplink, the edge tail after the downlink — only the
                # head gates when the cloud can start
                eh, c, t, dn = arrays.placement_latency(
                    s1, s2, bw, cfg.rtt_s, codec=cdc,
                    down_bw_factor=cfg.down_bw_factor)
                tail = float(arrays.edge_s[arrays.n] - arrays.edge_s[s2])
                e = eh - tail
                down = dn + tail
                two_cut = True
            else:
                e, c, t = arrays.latency(s1, bw, cfg.rtt_s, codec=cdc)
            if self.scene_mat is not None:
                wf_eff = self._delta_uplink(
                    i, int(round(now / cfg.tick_s)), s1, s2, arrays.n,
                    float(arrays.wire_bytes[s1]), cdc)
            if kc > 1 and c > 0.0:
                # streamed uplink: chunk transfers drawn from the
                # PER-TICK trace (not one frozen bandwidth) while the
                # cloud window prefills arrived chunks; the exposed
                # transport time replaces the sequential uplink leg
                t, bub = self._stream_uplink(i, arrays, s1, cdc, e, c,
                                             wire_factor=wf_eff)
                self.n_streamed_requests += 1
                self._bubble_sum += bub
            elif wf_eff is not None:
                # exact wire-term correction: this frame's measured
                # bytes replace the plan's cycle average in the uplink
                # (identically ``+0.0`` for non-delta codecs, whose
                # measured factor IS the cycle average)
                t = t + (wf_eff - cdc.wire_factor) \
                    * float(arrays.wire_bytes[s1]) / bw
        else:
            e, c, t = float(arrays.edge_s[arrays.n]), 0.0, 0.0
        net.step()                      # link evolves every tick
        rec = self.recorder
        tele = None
        if rec is not None and rec.want(self._tele_key(i, now)):
            lane = f"robot:{self.arch_of[i]}"
            if self._cloud_up:
                tele = self._tele_pred(
                    lane, self.arch_of[i], bw, s1, s2, int(kc),
                    int(self.codec_of[i]), e, c, t, down,
                    wire_meas_over=(
                        wf_eff * float(arrays.wire_bytes[s1])
                        if wf_eff is not None else None))
                tele["_bubble"] = bub
            else:
                tele = self._tele_pred_edge(lane, e)
        if c > 0.0 and routable:
            wid = self._next_wid
            self._next_wid += 1
            work = _CloudWork(i, now, now + e + t, e, t, c, down, two_cut,
                              pred=tele)
            self._pending[wid] = work
            self.next_free[i] = float("inf")   # until completion
            if tele is not None and cfg.continuous:
                rec.cont_open(wid)
            if cfg.continuous:
                # continuous tier: the straggler multiplier is drawn per
                # request at enqueue (batching efficiency lives in the
                # batcher's eff(k) model), the window's analytic KV
                # footprint is priced from the suffix cumsums, and
                # routing is least-backlog rather than EWMA-primary
                slow = float(np.exp(self.rng.normal(
                    0.0, cfg.straggler_sigma)))
                if self.rng.random() < cfg.tail_prob:
                    slow *= cfg.tail_scale
                kvc = self.kv_cumsum[self.arch_of[i]]
                replica = min(routable, key=lambda r:
                              self.cbatchers[r].backlog_s)
                self.cbatchers[replica].add(
                    Request(wid, now + e + t, 0), c * slow,
                    float(kvc[s1] - kvc[s2]))
            else:
                replica = self.mitigator.pick_primary(routable)
                self.batchers[replica].add(Request(wid, now + e + t, 0))
            if self._enq is not None:
                self._enq(replica)
        elif c > 0.0:
            # planned a collaborative split but no replica accepts work
            # (undetected outage window): edge re-execution
            self._fallback_one(_CloudWork(i, now, now + e + t,
                                          e, t, c, down, two_cut,
                                          pred=tele))
        else:
            # no cloud work: complete locally.  ``down`` is normally 0
            # here, but a clamped placement degenerating to an empty
            # cloud window still owes its edge-tail compute
            lat = e + t + down
            if tele is not None:
                rec.record_request(
                    req=-1, lane=tele["_lane"], t0_s=now, edge_s=e,
                    uplink_s=t, queue_s=0.0, service_s=0.0, down_s=down,
                    total_s=lat, enc_s=tele["_enc_s"],
                    dec_s=tele["_dec_s"], pred=tele,
                    outcome="local" if self._cloud_up else "outage",
                    wire_bytes=tele["_wire_bytes"])
            self._complete(i, now, lat)
            if not self._cloud_up:
                self.n_outage_completions += 1

    # ------------------------------------------------- vectorized robot phase
    # ``_robot_step_batch`` prices every robot that wakes on the same tick
    # in one numpy pass over struct-of-arrays state.  Parity discipline:
    # each array expression mirrors the scalar ``_robot_step`` arithmetic
    # OPERATION FOR OPERATION (same association order, same branch
    # structure via masks) — elementwise numpy ufuncs are bitwise
    # identical to their scalar counterparts, so the batch is
    # full-`FleetReport` dataclass-equal to the scalar loop
    # (tests/test_engine_parity.py pins this on the vectorized axis).
    # Order-sensitive side effects (RNG draws, work ids, batcher adds,
    # streamed pricing, float accumulators) drop to scalar loops in
    # ascending robot index — exactly the order the event heap pops
    # same-tick ROBOT events.

    def _ensure_batch_state(self) -> dict:
        """Stacked per-arch plan/cost tables for the batched robot phase,
        built lazily on first use (plan tables are frozen after
        ``__init__``; pools/codecs live in their own refreshed arrays).
        Arch tables are padded to the widest graph — padding lanes are
        never indexed because every split is bounded by its own arch's
        ``n``."""
        if self._bst is not None:
            return self._bst
        cfg = self.cfg
        archs = list(self.graphs)
        aidx = {a: j for j, a in enumerate(archs)}
        A, B = len(archs), len(self.bw_grid)
        nmax = max(self.arrays[a].n for a in archs)
        s1_t = np.zeros((A, B), dtype=np.int64)
        s2_t = np.zeros((A, B), dtype=np.int64)
        cd_t = np.zeros((A, B), dtype=np.int64)
        kc_t = np.ones((A, B), dtype=np.int64)
        E = np.zeros((A, nmax + 1))
        C = np.zeros((A, nmax + 1))
        W = np.zeros((A, nmax + 1))
        DW = np.zeros((A, nmax + 1))
        n_arr = np.zeros(A, dtype=np.int64)
        has_down = np.zeros(A, dtype=bool)
        edge_only = np.zeros(A)
        for j, a in enumerate(archs):
            s1_t[j] = np.asarray(self.plan[a], dtype=np.int64)
            s2_t[j] = np.asarray(self.plan_s2[a], dtype=np.int64)
            cd_t[j] = np.asarray(self.plan_codec[a], dtype=np.int64)
            kc_t[j] = np.asarray(self.plan_chunks[a], dtype=np.int64)
            ar = self.arrays[a]
            n = ar.n
            E[j, :n + 1] = ar.edge_s
            C[j, :n + 1] = ar.cloud_s
            W[j, :n + 1] = ar.wire_bytes
            if ar.down_wire_bytes is not None:
                DW[j, :n + 1] = ar.down_wire_bytes
                has_down[j] = True
            n_arr[j] = n
            edge_only[j] = float(ar.edge_s[n])
        cd = self.codecs
        self._arch_idx = np.asarray([aidx[a] for a in self.arch_of],
                                    dtype=np.int64)
        self._bst = {
            "s1": s1_t, "s2": s2_t, "codec": cd_t, "chunks": kc_t,
            "E": E, "C": C, "W": W, "DW": DW, "n": n_arr,
            "has_down": has_down, "edge_only": edge_only,
            # codec cost tables (linear per raw byte — codec.py contract)
            "wf": np.asarray([c.wire_factor for c in cd]),
            "enc_up": np.asarray([c.encode_s_per_byte(cfg.edge)
                                  for c in cd]),
            "dec_up": np.asarray([c.decode_s_per_byte(cfg.cloud)
                                  for c in cd]),
            "enc_dn": np.asarray([c.encode_s_per_byte(cfg.cloud)
                                  for c in cd]),
            "dec_dn": np.asarray([c.decode_s_per_byte(cfg.edge)
                                  for c in cd]),
        }
        return self._bst

    def _net_time_vec(self, wire: np.ndarray, bw: np.ndarray,
                      ci: np.ndarray, applicable: np.ndarray,
                      enc_rates: np.ndarray, dec_rates: np.ndarray
                      ) -> np.ndarray:
        """Vector mirror of ``segmentation.net_time`` with a codec and
        both devices bound: codec path = compressed wire + rtt + encode +
        decode (the ``transport_s`` term order), non-applicable path =
        raw wire + rtt, zero raw bytes free."""
        bst = self._bst
        rtt = self.cfg.rtt_s
        tc = (wire * bst["wf"][ci]) / bw + rtt
        tc = tc + wire * enc_rates[ci]
        tc = tc + wire * dec_rates[ci]
        tp = wire / bw + rtt
        t = np.where(applicable, tc, tp)
        return np.where(wire == 0.0, 0.0, t)

    def _complete_batch(self, idx: np.ndarray, issued_s: float,
                        lat: np.ndarray) -> None:
        """Vector mirror of ``_complete`` over a batch of robots."""
        self.next_free[idx] = issued_s + lat
        lats = self.latencies
        for j, i in enumerate(idx):
            lats[i].append(float(lat[j]))
        if self._wake_batch is not None:
            self._wake_batch(idx)
        elif self._wake is not None:
            for i in idx:
                self._wake(int(i))

    def _robot_step_batch(self, idxs: np.ndarray, tick: int, now: float,
                          routable: List[str]) -> None:
        """All of one tick's free robots in a single vectorized pass:
        plan-table lookup, codec/cut/chunk state advance, placement
        pricing, then dispatch.  ``idxs`` must be ascending and unique;
        every robot's ``NetworkSim`` conceptually sits at ``tick``
        (bandwidth reads come straight from ``trace_mat``; only streamed
        rows touch their cursor, via ``seek``)."""
        cfg = self.cfg
        if self.scene_mat is not None:
            # before _ensure_batch_state: a due drift replan swaps the
            # plan tables this very batch prices against (the scalar
            # engine replans before pricing the tick's first robot)
            self._maybe_delta_replan(tick)
        bst = self._ensure_batch_state()
        ai = self._arch_idx[idxs]
        if not self._cloud_up:
            # outage fast path: every robot executes edge-only (the
            # scalar branch's ``e + 0.0 + 0.0`` is bitwise ``e``)
            eo = bst["edge_only"][ai]
            rec = self.recorder
            if rec is not None:
                for j in self._tele_want_js(idxs, now):
                    i = int(idxs[j])
                    ev = float(eo[j])
                    tele = self._tele_pred_edge(
                        f"robot:{self.arch_of[i]}", ev)
                    rec.record_request(
                        req=-1, lane=tele["_lane"], t0_s=now,
                        edge_s=ev, uplink_s=0.0, queue_s=0.0,
                        service_s=0.0, down_s=0.0, total_s=ev,
                        pred=tele, outcome="outage", wire_bytes=0.0)
            self._complete_batch(idxs, now, eo)
            self.n_outage_completions += len(idxs)
            return

        bw = self.trace_mat[idxs, tick]
        k = np.searchsorted(self._bw_mid, bw)
        n_v = bst["n"][ai]
        s1p = bst["s1"][ai, k]
        s2p = bst["s2"][ai, k]
        # codec adoption — same gate as _planned_placement: only bins
        # whose plan has a codec-applicable transport leg
        cur = self.codec_of[idxs]
        adopt = (s1p < s2p) & (((0 < s1p) & (s1p < n_v)) | (s2p < n_v))
        ci = np.where(adopt, bst["codec"][ai, k], cur)
        self.n_codec_switches += int(np.count_nonzero(ci != cur))
        self.codec_of[idxs] = ci
        # pool clamps (numpy min/max == Pool.clamp)
        s1 = np.minimum(np.maximum(s1p, self._pool_lo1[idxs]),
                        self._pool_hi1[idxs])
        s2c = np.minimum(np.maximum(s2p, self._pool_lo2[idxs]),
                         self._pool_hi2[idxs])
        s2 = np.where(self._has_pool2[idxs], np.maximum(s1, s2c), n_v)
        moved = ((s1 != self.place_s1[idxs])
                 | (s2 != self.place_s2[idxs]))
        self.n_cut_moves += int(np.count_nonzero(moved))
        self.place_s1[idxs] = s1
        self.place_s2[idxs] = s2
        # chunk state — stream_applies gate, degenerate placements reset
        wire_s1 = bst["W"][ai, s1]
        if cfg.streamed:
            kc = bst["chunks"][ai, k]
            ok = (s1 < s2) & (0 < s1) & (s1 < n_v) & (wire_s1 > 0)
            kc = np.where(ok, kc, 1)
        else:
            kc = np.ones(len(idxs), dtype=np.int64)
        self.n_chunk_reconfigs += int(
            np.count_nonzero(kc != self.chunks_of[idxs]))
        self.chunks_of[idxs] = kc

        # pricing — mirrors latency()/placement_latency() + the 2-cut
        # head/tail shuffle in _robot_step, association order preserved
        Es1 = bst["E"][ai, s1]
        En = bst["E"][ai, n_v]
        Es2 = bst["E"][ai, s2]
        two = s2 < n_v
        collab = s1 < s2
        eh = (Es1 + En) - Es2
        tail = En - Es2
        c2 = bst["C"][ai, s1] - bst["C"][ai, s2]
        tv = self._net_time_vec(wire_s1, bw, ci, (0 < s1) & (s1 < n_v),
                                bst["enc_up"], bst["dec_up"])
        # 2-cut with s1 >= s2 short-circuits before the transport terms
        t = np.where(two & ~collab, 0.0, tv)
        c = np.where(two, np.where(collab, c2, 0.0), bst["C"][ai, s1])
        dn = np.zeros(len(idxs))
        dmask = two & collab & bst["has_down"][ai]
        if dmask.any():
            dnv = self._net_time_vec(
                bst["DW"][ai, s2], bw * cfg.down_bw_factor, ci,
                (0 < s2) & (s2 < n_v), bst["enc_dn"], bst["dec_dn"])
            dn = np.where(dmask, dnv, 0.0)
        e = np.where(two, eh - tail, Es1)
        down = np.where(two, dn + tail, 0.0)

        # measured delta wire factors: the scalar path's exact wire-term
        # correction, vectorized (``+0.0`` on non-delta rows).  Streamed
        # rows are corrected here then overwritten below — value-equal
        # to the scalar if/elif.
        wf_meas = app = None
        if self.scene_mat is not None:
            wf_meas, app = self._delta_uplink_batch(
                idxs, tick, s1, s2, n_v, wire_s1, ci)
            t = t + np.where(app, (wf_meas - bst["wf"][ci]) * wire_s1
                             / bw, 0.0)

        # streamed uplinks price against the per-tick trace — inherently
        # sequential per robot, so scalar in index order
        rec = self.recorder
        bub_of: dict = {}
        if cfg.streamed:
            for j in np.flatnonzero((kc > 1) & (c > 0.0)):
                i = int(idxs[j])
                self.nets[i].seek(tick)
                t[j], bub = self._stream_uplink(
                    i, self.arrays[self.arch_of[i]], int(s1[j]),
                    self.codecs[int(ci[j])], float(e[j]), float(c[j]),
                    wire_factor=(float(wf_meas[j])
                                 if wf_meas is not None and app[j]
                                 else None))
                self.n_streamed_requests += 1
                self._bubble_sum += bub
                if rec is not None:
                    bub_of[int(j)] = bub

        # issue-time telemetry capture (recorder on): the same pred the
        # scalar path builds, from the batch lanes' scalarized values
        tele_of: dict = {}
        if rec is not None:
            for j in self._tele_want_js(idxs, now):
                j = int(j)
                i = int(idxs[j])
                tele = self._tele_pred(
                    f"robot:{self.arch_of[i]}", self.arch_of[i],
                    float(bw[j]), int(s1[j]), int(s2[j]), int(kc[j]),
                    int(ci[j]), float(e[j]), float(c[j]), float(t[j]),
                    float(down[j]),
                    wire_meas_over=(
                        float(wf_meas[j] * wire_s1[j])
                        if wf_meas is not None and app[j] else None))
                tele["_bubble"] = bub_of.get(j)
                tele_of[j] = tele

        # dispatch: cloud work in ascending robot order (work ids, RNG
        # draws and batcher adds replay the scalar sequence), local
        # completions batched
        cloudy = c > 0.0
        if routable:
            for j in np.flatnonzero(cloudy):
                i = int(idxs[j])
                ej, tj, cj = float(e[j]), float(t[j]), float(c[j])
                wid = self._next_wid
                self._next_wid += 1
                tele = tele_of.get(int(j))
                work = _CloudWork(i, now, now + ej + tj, ej, tj, cj,
                                  float(down[j]), bool(two[j]),
                                  pred=tele)
                self._pending[wid] = work
                self.next_free[i] = float("inf")
                if tele is not None and cfg.continuous:
                    rec.cont_open(wid)
                if cfg.continuous:
                    slow = float(np.exp(self.rng.normal(
                        0.0, cfg.straggler_sigma)))
                    if self.rng.random() < cfg.tail_prob:
                        slow *= cfg.tail_scale
                    kvc = self.kv_cumsum[self.arch_of[i]]
                    replica = min(routable, key=lambda r:
                                  self.cbatchers[r].backlog_s)
                    self.cbatchers[replica].add(
                        Request(wid, now + ej + tj, 0), cj * slow,
                        float(kvc[int(s1[j])] - kvc[int(s2[j])]))
                else:
                    replica = self.mitigator.pick_primary(routable)
                    self.batchers[replica].add(
                        Request(wid, now + ej + tj, 0))
                if self._enq is not None:
                    self._enq(replica)
        else:
            for j in np.flatnonzero(cloudy):
                i = int(idxs[j])
                ej, tj = float(e[j]), float(t[j])
                self._fallback_one(_CloudWork(
                    i, now, now + ej + tj, ej, tj, float(c[j]),
                    float(down[j]), bool(two[j]),
                    pred=tele_of.get(int(j))))
        loc = np.flatnonzero(~cloudy)
        if len(loc):
            lat = (e[loc] + t[loc]) + down[loc]
            if rec is not None and tele_of:
                for jj, j in enumerate(loc.tolist()):
                    tele = tele_of.get(j)
                    if tele is not None:
                        rec.record_request(
                            req=-1, lane=tele["_lane"], t0_s=now,
                            edge_s=float(e[j]), uplink_s=float(t[j]),
                            queue_s=0.0, service_s=0.0,
                            down_s=float(down[j]),
                            total_s=float(lat[jj]),
                            enc_s=tele["_enc_s"], dec_s=tele["_dec_s"],
                            pred=tele, outcome="local",
                            wire_bytes=tele["_wire_bytes"])
            self._complete_batch(idxs[loc], now, lat)

    def _drain_dead(self, now: float, routable: List[str]) -> None:
        """Replicas that died with queued work: re-route or fall back."""
        if self.cfg.continuous:
            self._drain_dead_cont(routable)
            return
        for r in self.replica_names:
            if r in self._down and self.batchers[r].queue:
                if routable:
                    for rq in list(self.batchers[r].queue):
                        self.batchers[self.mitigator.pick_primary(
                            routable)].add(rq)
                    self.batchers[r].queue.clear()
                else:
                    batch = self.batchers[r].flush(now)
                    while batch is not None:
                        self._fallback(batch.requests)
                        batch = self.batchers[r].flush(now)

    def _service_replica(self, r: str, end: float,
                         routable: List[str]) -> None:
        """Advance one accepting replica's service to the tick boundary:
        micro-batches form and execute, the continuous tier's event loop
        runs to ``end`` and completions release robots."""
        if self.cfg.continuous:
            for req, fin in self.cbatchers[r].step(end):
                self._finish_cont(req, fin)
        else:
            batch = self.batchers[r].maybe_form(end)
            while batch is not None:
                self._execute(batch.requests, routable)
                batch = self.batchers[r].maybe_form(end)

    def _final_drain(self) -> None:
        """Drain whatever is still queued at the end of the run."""
        cfg = self.cfg
        end = cfg.n_ticks * cfg.tick_s
        routable = [r for r in self.replica_names if r not in self._down]
        if cfg.continuous:
            self._drain_dead_cont(routable)
            for r in routable:
                for req, fin in self.cbatchers[r].step(None):
                    self._finish_cont(req, fin)
        else:
            for r in self.replica_names:
                batch = self.batchers[r].flush(end)
                while batch is not None:
                    if routable:
                        self._execute(batch.requests, routable)
                    else:
                        self._fallback(batch.requests)
                    batch = self.batchers[r].flush(end)

    # ------------------------------------------------------------------ run
    def run(self) -> FleetReport:
        cfg = self.cfg
        if cfg.engine == "events":
            from .events import EventEngine   # lazy: avoids import cycle
            return EventEngine(self).run()
        if cfg.engine != "ticks":
            raise ValueError(f"unknown engine {cfg.engine!r} "
                             "(expected 'ticks' or 'events')")
        if cfg.arrival_processes:
            raise ValueError("arrival_processes require engine='events' "
                             "(the tick loop has no sub-tick arrivals)")
        if cfg.autoscale:
            raise ValueError("autoscale requires engine='events'")
        return self._run_ticks()

    def _run_ticks(self) -> FleetReport:
        """The historical dense per-tick loop: every robot and replica is
        visited every tick.  Kept as the parity oracle for the event
        engine — and still the simplest thing to read when tracing a
        small run by hand."""
        cfg = self.cfg
        events = sorted(cfg.replica_events)
        ei = 0
        for tick in range(cfg.n_ticks):
            now = tick * cfg.tick_s
            while ei < len(events) and events[ei].tick <= tick:
                ev = events[ei]
                (self._down.add if ev.kind == "leave"
                 else self._down.discard)(ev.replica)
                ei += 1
            for r in self.replica_names:
                if r not in self._down:
                    self.pool.heartbeat(r, now)
            # control plane: heartbeat-timeout view (drives replan())
            live = self.pool.live(now)
            # data plane: fail-fast — connections to a dead replica error
            # immediately, before the heartbeat timeout notices
            routable = [r for r in live if r not in self._down]

            # ---- robots take one control step each (closed loop: a robot
            # issues its next observation once the previous action returned)
            for i in range(cfg.n_robots):
                if now < self.next_free[i]:
                    self.nets[i].step()         # link evolves every tick
                    continue                    # previous request in flight
                self._robot_step(i, now, routable)

            self._drain_dead(now, routable)

            # ---- form + execute batches per accepting replica
            end = now + cfg.tick_s
            for r in routable:
                self._service_replica(r, end, routable)

        self._final_drain()
        return self._report()

    # --------------------------------------------------------------- report
    def _report(self) -> FleetReport:
        cfg = self.cfg
        robots = []
        for i, lats in enumerate(self.latencies):
            xs = np.asarray(lats if lats else [0.0])
            robots.append(RobotStats(
                name=f"robot{i:03d}", arch=self.arch_of[i],
                n_requests=len(lats), mean_s=float(xs.mean()),
                p50_s=float(np.percentile(xs, 50)),
                p95_s=float(np.percentile(xs, 95)),
                codec=self.codecs[self.codec_of[i]].name,
                n_chunks=int(self.chunks_of[i])))
        allx = np.asarray([x for lats in self.latencies for x in lats]
                          or [0.0])
        sim_s = cfg.n_ticks * cfg.tick_s
        cbs = list(self.cbatchers.values())
        n_cont_done = sum(cb.n_completed for cb in cbs)
        procs = []
        for p, proc in enumerate(cfg.arrival_processes):
            lats = self.proc_latencies[p]
            ys = np.asarray(lats if lats else [0.0])
            procs.append(ProcessStats(
                name=proc.name, kind=proc.kind,
                n_arrivals=self.proc_arrivals[p],
                n_completed=len(lats),
                n_rejected=self.proc_rejections[p],
                mean_s=float(ys.mean()),
                p50_s=float(np.percentile(ys, 50)),
                p95_s=float(np.percentile(ys, 95)),
                p99_s=float(np.percentile(ys, 99)),
                p999_s=float(np.percentile(ys, 99.9))))
        metrics = None
        if self.recorder is not None:
            # mirror the report-level counters into gauges so a metrics
            # consumer never needs the dataclass, then snapshot
            m = self.recorder.metrics
            m.set_gauge("fleet/p95_s", float(np.percentile(allx, 95)))
            m.set_gauge("fleet/n_hedged", self.n_hedged)
            m.set_gauge("fleet/n_replans", self.n_replans)
            m.set_gauge("fleet/n_preemptions",
                        sum(cb.n_preempted for cb in cbs))
            m.set_gauge("fleet/kv_high_watermark_bytes", max(
                (cb.kv_high_watermark_bytes for cb in cbs), default=0.0))
            metrics = self.recorder.snapshot()
        return FleetReport(
            robots=robots, n_requests=int(sum(r.n_requests for r in robots)),
            fleet_p50_s=float(np.percentile(allx, 50)),
            fleet_p95_s=float(np.percentile(allx, 95)),
            throughput_rps=float(len(allx) / sim_s) if sim_s else 0.0,
            n_hedged=self.n_hedged, n_replans=self.n_replans,
            n_outage_completions=self.n_outage_completions,
            n_codec_switches=self.n_codec_switches,
            n_cut_moves=self.n_cut_moves,
            n_multicut_requests=self.n_multicut_requests,
            n_chunk_reconfigs=self.n_chunk_reconfigs,
            n_streamed_requests=self.n_streamed_requests,
            mean_bubble_frac=(self._bubble_sum / self.n_streamed_requests
                              if self.n_streamed_requests else 0.0),
            n_preemptions=int(sum(cb.n_preempted for cb in cbs)),
            mean_queue_delay_s=(sum(cb.queue_delay_sum_s for cb in cbs)
                                / max(1, n_cont_done)),
            kv_high_watermark_bytes=max(
                (cb.kv_high_watermark_bytes for cb in cbs), default=0.0),
            fleet_p99_s=float(np.percentile(allx, 99)),
            fleet_p999_s=float(np.percentile(allx, 99.9)),
            processes=tuple(procs),
            n_open_arrivals=int(sum(self.proc_arrivals)),
            n_slo_rejections=int(sum(self.proc_rejections)),
            n_autoscale_events=self.n_autoscale,
            metrics=metrics,
            total_wire_bytes=float(self.wire_bytes_of.sum()),
            n_keyframes=self.n_keyframes,
            n_delta_frames=self.n_delta_frames,
            n_ref_evictions=self.n_ref_evictions,
            n_delta_replans=self.n_delta_replans)


def run_fleet(cfg: FleetConfig) -> FleetReport:
    """Convenience one-shot: build a ``FleetSimulator`` and run it."""
    return FleetSimulator(cfg).run()
