"""Chrome trace-event JSON export for the flight recorder.

Renders the span groups a ``core/telemetry.FlightRecorder`` kept into
the Trace Event Format that ``chrome://tracing`` and Perfetto load
directly: one *process* row per lane family (robot cohorts, cloud
replicas, open-loop arrival processes, executor wall-clock), one
*thread* row per lane, ``"X"`` complete events for spans (microsecond
``ts``/``dur``) and ``"M"`` metadata events naming the rows.  The
export walks only the reservoir-kept groups, so writing a trace of a
100k-robot run costs the same as a 1k one.
"""
from __future__ import annotations

import json
from typing import Dict, List, Tuple

from ..core.telemetry import FlightRecorder, Span

__all__ = ["chrome_trace", "export_chrome_trace"]

# lane family (the prefix before ":") -> Chrome pid; unknown families
# group under "other".  Perfetto sorts rows by pid, so this fixes the
# top-to-bottom reading order of the trace.
_FAMILY_PIDS = {"robot": 1, "proc": 2, "replica": 3, "executor": 4}
_OTHER_PID = 9
_FAMILY_NAMES = {1: "robot cohorts", 2: "arrival processes",
                 3: "cloud replicas", 4: "executor wall-clock",
                 _OTHER_PID: "other"}


def _lane_pid(lane: str) -> int:
    family = lane.split(":", 1)[0]
    return _FAMILY_PIDS.get(family, _OTHER_PID)


def chrome_trace(recorder: FlightRecorder) -> dict:
    """Build the Chrome trace-event payload dict for the recorder's kept
    span groups.  Deterministic: lanes get thread ids in sorted order, and
    events are emitted sorted by (timestamp, lane)."""
    spans: List[Span] = [s for group in recorder.spans.items for s in group]
    lanes = sorted({s.lane for s in spans})
    tid_of: Dict[str, Tuple[int, int]] = {}
    next_tid: Dict[int, int] = {}
    for lane in lanes:
        pid = _lane_pid(lane)
        tid = next_tid.get(pid, 0)
        next_tid[pid] = tid + 1
        tid_of[lane] = (pid, tid)

    events: List[dict] = []
    for pid in sorted({p for p, _ in tid_of.values()}):
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0,
                       "args": {"name": _FAMILY_NAMES.get(pid, "other")}})
    for lane in lanes:
        pid, tid = tid_of[lane]
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": lane}})

    for s in sorted(spans, key=lambda s: (s.t0_s, s.lane, s.name)):
        pid, tid = tid_of[s.lane]
        events.append({"name": s.name, "cat": s.cat, "ph": "X",
                       "ts": s.t0_s * 1e6, "dur": s.dur_s * 1e6,
                       "pid": pid, "tid": tid, "args": {"req": s.req}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"spans_kept": len(recorder.spans),
                          "spans_seen": recorder.spans.n_seen,
                          "mode": recorder.mode}}


def export_chrome_trace(recorder: FlightRecorder, path: str) -> str:
    """Write the trace to ``path`` (conventionally ``*.trace.json``) and
    return the path.  Open the file in Perfetto (ui.perfetto.dev) or
    ``chrome://tracing``."""
    with open(path, "w") as f:
        json.dump(chrome_trace(recorder), f)
    return path
