"""Training loop: scan-microbatched, remat'd, fault-tolerant train_step.

``make_train_step`` builds the jit'able ``(state, batch) -> (state, metrics)``
used by both the dry-run (lower/compile only) and the runnable examples.

Distribution defaults (DESIGN.md §5):
  * batch sharded over ``(pod, data)``; params/moments per model rules
    (+ZeRO-1 for moments);
  * gradient accumulation over ``n_microbatches`` via ``lax.scan``
    (XLA overlaps each microbatch's gradient all-reduce with the next
    microbatch's compute);
  * optional int8 ring-compressed gradient all-reduce
    (``grad_compression="int8_ring"``) over the data axes via shard_map.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..models.sharding import current_mesh, resolve
from .compression import compressed_psum_tree
from .optimizer import OptConfig, adamw_update

Tree = Any


@dataclasses.dataclass
class TrainState:
    step: jax.Array
    params: Tree
    m: Tree
    v: Tree

    def tree_flatten(self):
        return (self.step, self.params, self.m, self.v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, lambda s: s.tree_flatten(),
    lambda aux, children: TrainState(*children))


def init_state(params: Tree) -> TrainState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(jnp.int32(0), params,
                      zeros,
                      jax.tree_util.tree_map(jnp.copy, zeros))


def _split_micro(batch: Dict, n: int) -> Dict:
    return {k: v.reshape((n, v.shape[0] // n) + v.shape[1:])
            for k, v in batch.items()}


def make_train_step(model, opt: OptConfig, *, n_microbatches: int = 1,
                    grad_compression: Optional[str] = None,
                    aux_key: bool = False) -> Callable:
    """Returns train_step(state, batch, key) -> (state, metrics)."""

    def loss_fn(params, mb, key):
        return model.loss_fn(params, mb, key)

    def train_step(state: TrainState, batch: Dict, key: jax.Array
                   ) -> Tuple[TrainState, Dict]:
        n = n_microbatches
        if n > 1:
            micro = _split_micro(batch, n)

            def body(carry, mb):
                g_acc, l_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(state.params, mb, key)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), _ = jax.lax.scan(body, (g0, jnp.float32(0)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / n, grads)
            loss = loss / n
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch, key)

        if grad_compression == "int8_ring":
            grads = _compressed_sync(grads)

        new_p, new_m, new_v, gnorm = adamw_update(
            opt, state.params, grads, state.m, state.v, state.step)
        new_state = TrainState(state.step + 1, new_p, new_m, new_v)
        return new_state, {"loss": loss, "grad_norm": gnorm,
                           "step": state.step}

    return train_step


def _compressed_sync(grads: Tree) -> Tree:
    """int8 ring all-reduce over the data axes.

    NOTE on semantics: under pjit the per-device gradients are *already*
    globally averaged by XLA's inserted all-reduce (batch is sharded).  To
    make the compressed ring the real wire path, we instead divide the
    microbatch loss by the *local* batch inside shard_map and do the
    cross-data reduction ourselves.  For simplicity and numerical identity,
    this implementation applies the ring to the (already partial) local
    gradients inside a shard_map whose in_specs keep every gradient dim
    unsharded across data axes — i.e. it is wired for the unsharded-batch
    configuration used by the §Perf collective experiments and the tests.
    """
    mesh = current_mesh()
    if mesh is None:
        return grads
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not data_axes:
        return grads

    def sync(g):
        for ax in data_axes:
            g = compressed_psum_tree(g, ax)
        n = 1
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for ax in data_axes:
            n *= sizes[ax]
        return jax.tree_util.tree_map(lambda x: x / n, g)

    specs = jax.tree_util.tree_map(lambda _: P(), grads)
    return shard_map(sync, mesh=mesh, in_specs=(specs,),
                     out_specs=specs)(grads)
