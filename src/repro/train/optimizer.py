"""AdamW in pure JAX with ZeRO-1 style optimizer-state sharding.

Params stay bf16 (sharded by the model rules); Adam moments are fp32 and
additionally sharded across the ``data`` axis on their largest divisible
replicated dim (``zero_rules``) — the classic optimizer-state-sharding
memory win, visible in the dry-run's ``memory_analysis``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.sharding import ParamSpec, is_spec, resolve, spec

Tree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def opt_state_specs(param_specs: Tree, mesh=None, rules: Optional[Dict] = None,
                    zero1: bool = True) -> Tree:
    """fp32 moment ParamSpecs; with zero1, shard the largest currently-
    replicated dim over the data axes."""
    data_axes = tuple(a for a in ("pod", "data")
                      if mesh is not None and a in mesh.axis_names)
    data_size = 1
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for a in data_axes:
            data_size *= sizes[a]

    def one(s: ParamSpec) -> ParamSpec:
        axes = list(s.axes)
        if zero1 and mesh is not None and data_size > 1:
            pspec = resolve(s.axes, rules)
            # don't double-map mesh axes the param sharding already uses
            # (FSDP params already consume `data`)
            used = set()
            for e in pspec:
                for a in ((e,) if isinstance(e, str) else (e or ())):
                    used.add(a)
            if not used.intersection(data_axes):
                cands = [(dim, i) for i, dim in enumerate(s.shape)
                         if pspec[i] is None and dim % data_size == 0]
                if cands:
                    _, i = max(cands)
                    axes[i] = "__zero__"
        return spec(s.shape, tuple(axes), dtype=jnp.float32, init="zeros")

    return jax.tree_util.tree_map(one, param_specs, is_leaf=is_spec)


def zero_rules(rules: Dict, mesh) -> Dict:
    """Extend model rules with the ZeRO axis mapping."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    out = dict(rules)
    out["__zero__"] = data_axes if data_axes else None
    return out


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum((step + 1.0) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def clip_by_global_norm(grads: Tree, max_norm: float) -> Tuple[Tree, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(cfg: OptConfig, params: Tree, grads: Tree, m: Tree, v: Tree,
                 step: jax.Array) -> Tuple[Tree, Tree, Tree, jax.Array]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32) + 1.0

    def upd(p, g, m_, v_):
        g32 = g.astype(jnp.float32)
        m_ = cfg.b1 * m_ + (1 - cfg.b1) * g32
        v_ = cfg.b2 * v_ + (1 - cfg.b2) * g32 * g32
        mh = m_ / (1 - cfg.b1 ** t)
        vh = v_ / (1 - cfg.b2 ** t)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m_, v_

    out = jax.tree_util.tree_map(upd, params, grads, m, v)
    new_p = jax.tree_util.tree_map(lambda o: o[0], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_p, new_m, new_v, gnorm
