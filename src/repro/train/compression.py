"""Gradient compression: int8 ring all-reduce with per-chunk scales.

A classic bandwidth optimisation for data-parallel training: the ring
all-reduce moves int8 + fp32-scale chunks instead of bf16/f32 gradients —
~2-4x fewer wire bytes on the gradient collective (the dominant collective
term of the train_4k cells; see EXPERIMENTS.md §Perf).

Implemented with ``shard_map`` + ``lax.ppermute``: reduce-scatter phase with
per-hop requantisation, then an int8 all-gather phase.  Error feedback for
the *initial* quantisation is kept by the caller (train loop state);
per-hop requantisation noise is the standard trade-off.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import axis_size

Tree = Any


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8), scale


def _dequant(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s


def ring_allreduce_int8(x: jax.Array, axis: str, rank=None) -> jax.Array:
    """Sum `x` (identical shape on each shard) over `axis`, int8 on the wire.

    Call inside shard_map.  x: any shape; internally chunked N-ways.
    `rank`: this shard's index along `axis`; pass it explicitly from
    partial-manual shard_map regions (axis_index lowers to PartitionId,
    which GSPMD rejects there).
    """
    N = axis_size(axis)
    if N == 1:
        return x
    r = jax.lax.axis_index(axis) if rank is None else rank
    perm = [(i, (i + 1) % N) for i in range(N)]
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % N
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros(pad, jnp.float32)])
    chunks = flat.reshape(N, -1)

    # ---- reduce-scatter: after N-1 hops, rank r owns chunk (r+1) % N
    def rs_step(k, chunks):
        send_idx = (r - k) % N
        send = jax.lax.dynamic_index_in_dim(chunks, send_idx, 0,
                                            keepdims=False)
        q, s = _quant(send)
        q = jax.lax.ppermute(q, axis, perm)
        s = jax.lax.ppermute(s, axis, perm)
        recv_idx = (r - k - 1) % N
        upd = jax.lax.dynamic_index_in_dim(chunks, recv_idx, 0,
                                           keepdims=False) + _dequant(q, s)
        return jax.lax.dynamic_update_index_in_dim(chunks, upd, recv_idx, 0)

    chunks = jax.lax.fori_loop(0, N - 1, rs_step, chunks)

    # ---- all-gather: circulate completed chunks (int8 on the wire)
    def ag_step(k, chunks):
        send_idx = (r + 1 - k) % N
        send = jax.lax.dynamic_index_in_dim(chunks, send_idx, 0,
                                            keepdims=False)
        q, s = _quant(send)
        q = jax.lax.ppermute(q, axis, perm)
        s = jax.lax.ppermute(s, axis, perm)
        recv_idx = (r - k) % N
        return jax.lax.dynamic_update_index_in_dim(
            chunks, _dequant(q, s), recv_idx, 0)

    chunks = jax.lax.fori_loop(0, N - 1, ag_step, chunks)
    out = chunks.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(orig_shape).astype(orig_dtype)


def compressed_psum_tree(tree: Tree, axis: str) -> Tree:
    return jax.tree_util.tree_map(
        lambda g: ring_allreduce_int8(g, axis), tree)


# ------------------------------------------------------- error feedback (EF)
def ef_compress(grads: Tree, ef: Tree) -> Tuple[Tree, Tree]:
    """One-shot int8 quantisation with error feedback: returns
    (dequantised grads to feed the ring, new residual)."""
    def one(g, e):
        tgt = g.astype(jnp.float32) + e
        q, s = _quant(tgt)
        deq = _dequant(q, s)
        return deq.astype(g.dtype), tgt - deq

    out = jax.tree_util.tree_map(one, grads, ef)
    g2 = jax.tree_util.tree_map(lambda o: o[0], out,
                                is_leaf=lambda x: isinstance(x, tuple))
    ef2 = jax.tree_util.tree_map(lambda o: o[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    return g2, ef2
