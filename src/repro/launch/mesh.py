"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets its fake-device XLA flag before
first jax init, everything else sees the real single CPU device.
"""
from __future__ import annotations

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests/examples (same axis names)."""
    return make_mesh((1, 1), ("data", "model"))
