"""Post-SPMD HLO analysis: collective bytes + schedule for §Roofline.

Parses ``compiled.as_text()`` (the per-device program).  For every
``all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute``
op we take the result shapes (tuple-aware), the replica-group size N, and a
ring wire factor:

    all-reduce:          2 (N-1)/N x bytes   (reduce-scatter + all-gather)
    all-gather:            (N-1)/N x bytes   (bytes = full output)
    reduce-scatter:        (N-1)/N x bytes   (bytes = full input ~ N x out)
    all-to-all:            (N-1)/N x bytes
    collective-permute:              1 x bytes

Collectives inside ``while`` bodies (e.g. a microbatch scan) are multiplied
by the loop trip count when it is statically parseable; the dry-run unrolls
layers so in practice whiles only appear when explicitly requested.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_RE = re.compile(r"^(?:%?([\w.\-]+))\s*(?:\([^)]*\))?\s*->.*\{\s*$", re.M)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes: int              # result bytes (per device)
    group_size: int
    wire_bytes: float       # ring-model bytes on the wire per device
    computation: str
    count: int = 1          # trip-count multiplier
    wire_bytes_bf16: float = 0.0   # bf16-equivalent (TPU target) wire bytes


def _wire_factor(kind: str, n: int, op_bytes: int) -> float:
    if kind == "collective-permute":
        return float(op_bytes)   # pairwise; no replica_groups attribute
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * op_bytes
    if kind in ("all-gather", "all-to-all"):
        return (n - 1) / n * op_bytes
    if kind == "reduce-scatter":
        return (n - 1) * op_bytes        # result is the scattered shard
    if kind == "collective-permute":
        return float(op_bytes)
    return float(op_bytes)


def _shape_bytes_bf16_equiv(type_str: str) -> int:
    """Bytes if every f32 tensor were bf16.

    The CPU backend has no native bf16 dot, so XLA float-normalises model
    matmuls (and the all-reduces fed by them) to f32; on the TPU target
    these run in bf16.  Large f32 collectives in a bf16 model are therefore
    counted at half size for the TPU roofline (DESIGN.md §6).
    """
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = _DTYPE_BYTES[dt]
        if dt == "f32" and n * b >= 1 << 20:
            b = 2
        total += n * b
    return total


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    # map line offset -> computation name
    comp_spans: List[Tuple[int, str]] = []
    for m in re.finditer(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->[^{]*\{",
                         hlo_text, re.M):
        comp_spans.append((m.start(), m.group(1)))
    comp_spans.sort()

    def comp_at(pos: int) -> str:
        name = "?"
        for start, n in comp_spans:
            if start <= pos:
                name = n
            else:
                break
        return name

    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind, started = m.group(1), m.group(2), m.group(3)
        if started and kind != "collective-permute":
            pass  # -start ops carry the real shape; -done is aliasing
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start():line_end]
        nbytes = _shape_bytes(type_str)
        gm = _GROUPS_RE.search(line)
        if gm:
            group = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            group = int(gi.group(2)) if gi else 1
        nbytes16 = _shape_bytes_bf16_equiv(type_str)
        ops.append(CollectiveOp(
            kind=kind, bytes=nbytes, group_size=group,
            wire_bytes=_wire_factor(kind, group, nbytes),
            computation=comp_at(m.start()),
            wire_bytes_bf16=_wire_factor(kind, group, nbytes16)))
    # drop the "-done" halves of async pairs (zero-arg matches won't occur;
    # -done ops don't match _COLL_RE since they are "<kind>-done")
    return _apply_while_counts(hlo_text, ops)


def _apply_while_counts(hlo_text: str, ops: List[CollectiveOp]
                        ) -> List[CollectiveOp]:
    """Multiply collectives inside while bodies by parsed trip counts."""
    bodies: Dict[str, int] = {}
    for m in re.finditer(
            r"while\([^)]*\), condition=%?([\w.\-]+), body=%?([\w.\-]+)",
            hlo_text):
        cond, body = m.group(1), m.group(2)
        trip = _parse_trip_count(hlo_text, cond)
        if trip:
            bodies[body] = trip
    if not bodies:
        return ops
    out = []
    for op in ops:
        count = bodies.get(op.computation, 1)
        if count != 1:
            op = dataclasses.replace(op, count=count,
                                     wire_bytes=op.wire_bytes * count,
                                     wire_bytes_bf16=op.wire_bytes_bf16 * count)
        out.append(op)
    return out


def _parse_trip_count(hlo_text: str, cond_name: str) -> Optional[int]:
    m = re.search(re.escape(cond_name) + r"[\s\S]{0,2000}?"
                  r"compare\([^)]*\), direction=LT", hlo_text)
    if not m:
        return None
    window = hlo_text[m.start():m.end() + 200]
    cm = re.findall(r"constant\((\d+)\)", window)
    if cm:
        return int(cm[-1])
    return None


def summarize(ops: List[CollectiveOp]) -> Dict:
    by_kind: Dict[str, Dict] = {}
    for op in ops:
        d = by_kind.setdefault(op.kind, {"count": 0, "bytes": 0.0,
                                         "wire_bytes": 0.0,
                                         "wire_bytes_bf16": 0.0})
        d["count"] += op.count
        d["bytes"] += op.bytes * op.count
        d["wire_bytes"] += op.wire_bytes
        d["wire_bytes_bf16"] += op.wire_bytes_bf16
    total_wire = sum(d["wire_bytes"] for d in by_kind.values())
    total_16 = sum(d["wire_bytes_bf16"] for d in by_kind.values())
    return {"by_kind": by_kind, "total_wire_bytes_per_device": total_wire,
            "total_wire_bytes_bf16_per_device": total_16, "n_ops": len(ops)}
