"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
first two lines below force 512 host placeholder devices BEFORE jax
initialises.  Nothing else in the repo sets this flag.

Per cell this driver:
  1. builds the unrolled-layers model (exact HLO costs — DESIGN.md §6),
  2. lowers the right step (train_step / prefill / serve_step) with full
     in/out shardings on the production mesh,
  3. ``.compile()``s it (the SPMD partitioner must succeed — this is the
     multi-pod runnability proof),
  4. records memory_analysis / cost_analysis / parsed collective schedule /
     roofline terms into a JSON artifact under benchmarks/artifacts/dryrun/.
"""
import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ASSIGNED, get_config, get_shape, shape_applicable, SHAPES
from ..core.hardware import TPU_V5E, roofline
from ..models import build
from ..models.sharding import make_rules, shape_tree, sharding_tree, use_mesh
from ..train.optimizer import OptConfig, opt_state_specs, zero_rules
from ..train.train_loop import TrainState, make_train_step
from .hlo_analysis import parse_collectives, summarize
from .mesh import make_production_mesh

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts/dryrun")


def _sharded_bytes(specs, mesh, rules) -> float:
    """Per-device bytes of a ParamSpec tree under the given rules."""
    from ..models.sharding import is_spec, resolve
    import numpy as _np
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 0.0
    for s in jax.tree_util.tree_leaves(specs, is_leaf=is_spec):
        n = float(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
        pspec = resolve(s.axes, rules)
        denom = 1
        for entry in pspec:
            if entry is None:
                continue
            for ax in ((entry,) if isinstance(entry, str) else entry):
                denom *= sizes.get(ax, 1)
        total += n / denom
    return total


def analytic_residency(model, cfg, shape, mesh, rules) -> Dict:
    """TPU-expected per-device residency (bf16 semantics).

    The CPU backend float-normalises bf16 dots to f32 and its thunk
    scheduler is not memory-minimising, so `memory_analysis()` temp sizes
    over-report vs the TPU target (EXPERIMENTS.md §Dry-run discusses the
    delta); this analytic model is the fits-in-HBM estimate.
    """
    from ..train.optimizer import opt_state_specs, zero_rules
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_shards = 1
    br = rules.get("batch")
    for ax in ((br,) if isinstance(br, str) else (br or ())):
        batch_shards *= sizes.get(ax, 1)
    model_shards = sizes.get("model", 1)
    B_loc = max(shape.global_batch // batch_shards, 1)
    d = cfg.d_model or cfg.vit_dim
    S = shape.seq_len
    out = {"params": _sharded_bytes(model.param_specs, mesh, rules)}
    if shape.kind == "train":
        # deployable config: 8-way gradient-accumulation microbatching
        # (per-step flops/collectives identical; the dry-run lowers the
        # single-macrobatch form for exact HLO cost accounting, DESIGN §6)
        n_micro = 8
        B_mb = max(B_loc // n_micro, 1)
        ospecs = opt_state_specs(model.param_specs, mesh, rules, zero1=True)
        zr = zero_rules(rules, mesh)
        out["adam_moments"] = 2 * _sharded_bytes(ospecs, mesh, zr)
        out["grads"] = out["params"] * 2          # f32 accumulation buffer
        act_mult = (1 + cfg.ssm_expand) if cfg.family in ("ssm", "hybrid") \
            else 1
        out["remat_activations"] = cfg.n_layers * B_mb * S * d * 2 * act_mult
        out["logits_shard"] = B_mb * S * max(cfg.vocab_size, 1) * 2 \
            / model_shards
        out["working_set"] = 4 * B_mb * S * d * 2
    elif shape.kind == "prefill":
        cspecs = model.cache_specs(shape.global_batch, S, src_len=S)
        out["kv_cache"] = _sharded_bytes(cspecs, mesh, rules)
        out["working_set"] = 6 * B_loc * S * d * 2
    else:
        cspecs = model.cache_specs(shape.global_batch, S, src_len=S)
        out["kv_cache"] = _sharded_bytes(cspecs, mesh, rules)
        out["working_set"] = 8 * B_loc * 1 * d * 2 + B_loc * S * 4
    out["total"] = sum(v for v in out.values())
    return out


def model_flops(cfg, shape) -> float:
    """6·N·D (train) / 2·N·D (inference); N_active for MoE."""
    n = cfg.n_params()
    if cfg.n_experts:
        expert = 3 * cfg.d_model * cfg.moe_d_ff
        n_moe_layers = cfg.n_layers - cfg.first_dense_layers
        n -= n_moe_layers * (cfg.n_experts - cfg.moe_top_k) * expert
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # one token per request


def _cell(arch: str, shape_name: str, multi_pod: bool,
          opt_overrides: Optional[Dict] = None, *, strategy: str = "tp",
          decode_attn: str = "tp", tp_collective: str = "ar",
          scan_layers: bool = False) -> Dict:
    shape = get_shape(shape_name)
    cfg = get_config(arch).replace(scan_layers=scan_layers,
                                   decode_attn=decode_attn,
                                   tp_collective=tp_collective)
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rules = make_rules(cfg, mesh, shape.kind, strategy=strategy)
    if tp_collective == "int8_ring":
        rules["__tp_int8__"] = True
    model = build(cfg)
    opt_overrides = opt_overrides or {}

    t0 = time.time()
    with use_mesh(mesh, rules):
        if shape.kind == "train":
            lowered = _lower_train(model, cfg, shape, mesh, rules,
                                   **opt_overrides)
        elif shape.kind == "prefill":
            lowered = _lower_prefill(model, cfg, shape, mesh, rules)
        else:
            lowered = _lower_decode(model, cfg, shape, mesh, rules)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):    # older jax: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    coll_sum = summarize(colls)

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    wire_dev = float(coll_sum["total_wire_bytes_per_device"])
    wire16_dev = float(coll_sum["total_wire_bytes_bf16_per_device"])
    terms = roofline(flops_dev * n_dev, bytes_dev * n_dev, wire16_dev * n_dev,
                     n_dev, TPU_V5E)
    mf = model_flops(cfg, shape)
    residency = analytic_residency(model, cfg, shape, mesh, rules)

    out = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "analytic_residency_per_device": residency,
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_wire_bytes": wire_dev,
            "collective_wire_bytes_bf16": wire16_dev,
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_hbm_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "global": {
            "hlo_flops": flops_dev * n_dev,
            "hlo_bytes": bytes_dev * n_dev,
            "collective_wire_bytes": wire_dev * n_dev,
            "collective_wire_bytes_bf16": wire16_dev * n_dev,
        },
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "bound_s": terms.bound_s,
        },
        "model_flops": mf,
        "useful_flops_ratio": mf / (flops_dev * n_dev)
        if flops_dev else 0.0,
        "collectives": coll_sum,
    }
    return out


# ------------------------------------------------------------------ lowering
def _key_struct():
    k = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return jax.ShapeDtypeStruct(k.shape, k.dtype)


def _lower_train(model, cfg, shape, mesh, rules, n_microbatches: int = 1,
                 grad_compression=None):
    pspecs = model.param_specs
    p_shapes = shape_tree(pspecs)
    p_shard = sharding_tree(pspecs, mesh, rules)
    ospecs = opt_state_specs(pspecs, mesh, rules, zero1=True)
    zrules = zero_rules(rules, mesh)
    o_shapes = shape_tree(ospecs)
    o_shard = sharding_tree(ospecs, mesh, zrules)
    in_specs = model.input_specs(shape)
    b_shapes = shape_tree(in_specs)
    b_shard = sharding_tree(in_specs, mesh, rules)

    state_shapes = TrainState(jax.ShapeDtypeStruct((), jnp.int32),
                              p_shapes, o_shapes,
                              jax.tree_util.tree_map(lambda x: x, o_shapes))
    repl = NamedSharding(mesh, P())
    state_shard = TrainState(repl, p_shard, o_shard,
                             jax.tree_util.tree_map(lambda x: x, o_shard))

    step = make_train_step(model, OptConfig(),
                           n_microbatches=n_microbatches,
                           grad_compression=grad_compression)
    metrics_shard = {"loss": repl, "grad_norm": repl, "step": repl}
    fn = jax.jit(step,
                 in_shardings=(state_shard, b_shard, repl),
                 out_shardings=(state_shard, metrics_shard),
                 donate_argnums=(0,))
    return fn.lower(state_shapes, b_shapes, _key_struct())


def _lower_prefill(model, cfg, shape, mesh, rules):
    pspecs = model.param_specs
    p_shapes = shape_tree(pspecs)
    p_shard = sharding_tree(pspecs, mesh, rules)
    in_specs = model.input_specs(shape)
    b_shapes = shape_tree(in_specs)
    b_shard = sharding_tree(in_specs, mesh, rules)
    fn = jax.jit(lambda p, b: model.prefill(p, b),
                 in_shardings=(p_shard, b_shard))
    return fn.lower(p_shapes, b_shapes)


def _lower_decode(model, cfg, shape, mesh, rules):
    pspecs = model.param_specs
    p_shapes = shape_tree(pspecs)
    p_shard = sharding_tree(pspecs, mesh, rules)
    cspecs = model.cache_specs(shape.global_batch, shape.seq_len,
                               src_len=shape.seq_len)
    c_shapes = shape_tree(cspecs)
    c_shard = sharding_tree(cspecs, mesh, rules)
    in_specs = model.input_specs(shape)
    b_shapes = shape_tree(in_specs)
    b_shard = sharding_tree(in_specs, mesh, rules)
    repl = NamedSharding(mesh, P())
    fn = jax.jit(lambda p, c, t, pos: model.decode(p, c, t, pos),
                 in_shardings=(p_shard, c_shard, b_shard["tokens"], repl),
                 donate_argnums=(1,))
    return fn.lower(p_shapes, c_shapes, b_shapes["tokens"],
                    jax.ShapeDtypeStruct((), jnp.int32))


# ------------------------------------------------------------------ driver
def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (assigned pool)")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="",
                    help="artifact suffix for experiment variants")
    ap.add_argument("--grad-compression", default=None)
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--decode-attn", default="tp", choices=["tp", "sp"])
    ap.add_argument("--tp-collective", default="ar",
                    choices=["ar", "int8_ring"])
    ap.add_argument("--scan-layers", action="store_true",
                    help="scan layer stacks (fast compiles; collective "
                         "costs via while-body trip multiplier)")
    args = ap.parse_args()

    archs = list(ASSIGNED) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    results = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                tag = f"__{args.tag}" if args.tag else ""
                fname = f"{arch}__{shape}__{mesh_name}{tag}.json".replace(
                    "/", "_")
                path = os.path.join(args.out, fname)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") != "error":
                        print(f"[skip-cached] {fname}")
                        continue
                    print(f"[retry-error] {fname}")
                print(f"[run] {arch} x {shape} x {mesh_name}", flush=True)
                try:
                    opt = {"n_microbatches": args.microbatches,
                           "grad_compression": args.grad_compression}
                    res = _cell(arch, shape, multi, opt,
                                strategy=args.strategy,
                                decode_attn=args.decode_attn,
                                tp_collective=args.tp_collective,
                                scan_layers=args.scan_layers)
                except Exception as e:  # noqa: BLE001 - record failures
                    res = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": str(e),
                           "traceback": traceback.format_exc()[-4000:]}
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                results.append(res)
                status = res["status"]
                extra = ""
                if status == "ok":
                    r = res["roofline"]
                    extra = (f" dom={r['dominant']}"
                             f" comp={r['compute_s']*1e3:.2f}ms"
                             f" mem={r['memory_s']*1e3:.2f}ms"
                             f" coll={r['collective_s']*1e3:.2f}ms"
                             f" peak={res['per_device']['peak_hbm_bytes']/2**30:.2f}GiB"
                             f" est={res['analytic_residency_per_device']['total']/2**30:.2f}GiB"
                             f" compile={res['compile_s']:.0f}s")
                elif status == "error":
                    extra = " " + res["error"][:200]
                print(f"[{status}] {arch} x {shape} x {mesh_name}{extra}",
                      flush=True)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_err = sum(1 for r in results if r["status"] == "error")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
