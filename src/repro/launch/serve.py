"""End-to-end RoboECC serving driver.

Drives the full paper pipeline on a small model executing REAL compute on
this host: structure+hardware models -> Alg.1 split -> parameter-sharing
pool -> LSTM predictor -> per-request fine-grained adjustment, with the
LMSplitExecutor actually running both halves and the NetworkSim clocking the
transfer.  Latency accounting combines measured tier compute (scaled onto
the modeled devices) and simulated network time.

    PYTHONPATH=src python -m repro.launch.serve --requests 100
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core import (NetworkSim, PredictorConfig, RoboECC, Thresholds,
                    Workload, generate_trace)
from ..core.hardware import A100, ORIN
from ..models import build
from ..runtime.partition import LMSplitExecutor, SplitPlan, payload_bytes
from ..runtime.scheduler import MicroBatcher, Request, StragglerMitigator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--seq", type=int, default=17)
    ap.add_argument("--codec", action="store_true",
                    help="int8 activation codec on the cut tensor")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # --- control plane: full-size cost models drive the split decision
    cfg_full = get_config(args.arch)
    ctl = RoboECC(cfg_full, ORIN, A100,
                  workload=Workload(s_new=args.seq),
                  cloud_budget_bytes=0.9 * cfg_full.n_params() * 2,
                  use_codec=args.codec)
    trace = generate_trace(4000, seed=args.seed)
    ctl.fit_predictor(trace[:3000], PredictorConfig(epochs=120))
    net = NetworkSim(trace[3000:])
    net.step(ctl.predictor.cfg.window)
    print(f"Alg.1 split: {ctl.seg.split}/{len(ctl.graph)} "
          f"pool=[{ctl.pool.start},{ctl.pool.end}) "
          f"overhead={ctl.pool.overhead_frac*100:.2f}%")

    # --- data plane: reduced model actually executes both halves here
    cfg = cfg_full.reduced().replace(n_layers=8)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = cfg.n_layers
    pool_lo = max(n // 2 - 1, 0)
    ex = LMSplitExecutor(cfg, SplitPlan(pool_lo, min(pool_lo + 3, n),
                                        codec="int8" if args.codec else ""))
    # map the control-plane split into the reduced model's pool range
    def map_split(s):
        frac = s / max(len(ctl.graph), 1)
        return ex.plan.clamp(int(round(frac * n)))

    batcher = MicroBatcher(batch_size=4, max_wait_s=0.02)
    straggler = StragglerMitigator()
    lat, wire, adj = [], [], []
    key = jax.random.PRNGKey(args.seed)
    for rid in range(args.requests):
        batcher.add(Request(rid, time.time(), args.seq))
        b = batcher.maybe_form(time.time())
        if b is None:
            continue
        tick = ctl.tick(net)
        split = map_split(tick.split)
        tokens = jax.random.randint(key, (len(b.requests), args.seq), 0,
                                    cfg.vocab_size)
        t0 = time.time()
        logits, payload = ex.run(params, tokens, split)
        jax.block_until_ready(logits)
        host_s = time.time() - t0
        lat.append(tick.total_s)
        wire.append(payload_bytes(payload))
        if tick.decision is not None:
            adj.append(tick.adjust_overhead_s)
    print(f"served {args.requests} requests in {len(lat)} batches")
    print(f"modeled total latency: mean {np.mean(lat)*1e3:.1f}ms "
          f"p95 {np.percentile(lat, 95)*1e3:.1f}ms")
    print(f"cut payload: {np.mean(wire)/1e3:.1f} KB/request "
          f"(codec={'on' if args.codec else 'off'})")
    if adj:
        print(f"adjustment overhead: mean {np.mean(adj[1:])*1e3:.2f}ms")


if __name__ == "__main__":
    main()
