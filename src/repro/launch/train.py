"""End-to-end training driver.

Runs on whatever devices exist: 1 CPU device (examples/tests) up to the
production mesh (set DRYRUN-style XLA_FLAGS externally for fake-device
experiments).  Fault tolerance comes from runtime.fault.Supervisor
(checkpoint/restart, injected failures for drills).

Example (the ~100M-param run from examples/train_lm.py):
    PYTHONPATH=src python -m repro.launch.train \
        --arch llama3.2-3b --reduce 100m --steps 300 --batch 16 --seq 512
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data.pipeline import DataConfig, SyntheticStream
from ..models import build
from ..models.sharding import make_rules, use_mesh
from ..runtime.fault import FaultPlan, Supervisor
from ..train.optimizer import OptConfig
from ..train.train_loop import init_state, make_train_step


def reduce_to_100m(cfg):
    """A ~100M-param member of the same family (for the e2e example)."""
    kw = dict(n_layers=8, d_model=512, n_heads=8, n_kv_heads=min(
        cfg.n_kv_heads, 8) or 0, head_dim=64, d_ff=2048,
        vocab_size=32768, scan_layers=True, remat=False)
    if cfg.n_experts:
        kw.update(n_experts=8, moe_top_k=2, moe_d_ff=512,
                  first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.use_mla:
        kw.update(kv_lora_rank=128, qk_nope_dim=32, qk_rope_dim=16,
                  v_head_dim=32)
    if cfg.ssm_state:
        kw.update(ssm_state=64, ssm_headdim=64, ssm_chunk=128)
    return cfg.replace(**kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduce", default="smoke", choices=["smoke", "100m",
                                                          "none"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", default="",
                    help="comma list of steps to inject failures (drill)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce == "smoke":
        cfg = cfg.reduced()
    elif args.reduce == "100m":
        cfg = reduce_to_100m(cfg)
    model = build(cfg)
    print(f"arch={cfg.name} family={cfg.family} params={cfg.n_params()/1e6:.1f}M")

    params = model.init(jax.random.PRNGKey(0))
    state = init_state(params)
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1))
    step_fn = jax.jit(make_train_step(model, opt,
                                      n_microbatches=args.microbatches))

    stream = SyntheticStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, family=cfg.family, d_model=cfg.d_model,
        n_vision_tokens=cfg.n_vision_tokens, n_patches=cfg.n_patches,
        vit_dim=cfg.vit_dim, action_dim=cfg.action_dim,
        action_horizon=cfg.action_horizon))

    fail_at = tuple(int(s) for s in args.fail_at.split(",") if s)
    sup = Supervisor(args.ckpt_dir, ckpt_every=args.ckpt_every)

    t0 = time.time()
    losses = []

    class LoggingStream:
        def __init__(self, inner):
            self.inner = inner

        def next(self):
            return self.inner.next()

        def state(self):
            return self.inner.state()

        def restore(self, s):
            self.inner.restore(s)

    rep = sup.run(state, LoggingStream(stream), _wrap_logging(
        step_fn, args.log_every, t0), args.steps,
        key_fn=lambda s: jax.random.PRNGKey(s),
        fault_plan=FaultPlan(fail_at=fail_at) if fail_at else None)
    dt = time.time() - t0
    print(f"done: {rep.steps_done} steps, {rep.restarts} restarts, "
          f"final loss {rep.final_loss:.4f}, {dt:.1f}s "
          f"({rep.steps_done / dt:.2f} steps/s)")
    print(f"loss curve: first={rep.losses[0]:.3f} "
          f"min={min(rep.losses):.3f} last={rep.losses[-1]:.3f}")


def _wrap_logging(step_fn, every, t0):
    def run(state, batch, key):
        state, metrics = step_fn(state, batch, key)
        s = int(metrics["step"])
        if s % every == 0:
            print(f"  step {s:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"t+{time.time() - t0:.0f}s", flush=True)
        return state, metrics
    return run


if __name__ == "__main__":
    main()
