"""Decoder-only LM assembly for the dense and MoE families.

Layer parameters are **stacked** along a leading ``layers`` dim regardless of
execution mode, so checkpoints are mode-independent:

* ``cfg.scan_layers=True``  -> ``lax.scan`` over the stack (fast compiles;
  used by tests/examples/training).
* ``cfg.scan_layers=False`` -> static unroll (exact per-op HLO costs; used by
  the multi-pod dry-run, because XLA's cost analysis counts a scan body only
  once — DESIGN.md §6).

Caches follow the same convention: stacked ``(L, ...)`` arrays, scanned or
statically indexed.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from .layers import (embed, embed_spec, mlp, mlp_specs, rmsnorm, rmsnorm_spec,
                     softmax_xent, unembed)
from .moe import moe_ffn, moe_specs
from .sharding import shard, spec

Tree = Any


# ================================================================= specs
def dense_block_specs(cfg, layers: Optional[int] = None, d_ff: Optional[int] = None):
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    out = {
        "ln1": rmsnorm_spec(d, layers),
        "attn": A.mla_specs(cfg, layers) if cfg.use_mla else A.attn_specs(cfg, layers),
        "mlp": mlp_specs(d, ff, layers),
    }
    if not cfg.parallel_block:
        out["ln2"] = rmsnorm_spec(d, layers)
    return out


def moe_block_specs(cfg, layers: Optional[int] = None):
    d = cfg.d_model
    return {
        "ln1": rmsnorm_spec(d, layers),
        "attn": A.mla_specs(cfg, layers) if cfg.use_mla else A.attn_specs(cfg, layers),
        "ln2": rmsnorm_spec(d, layers),
        "moe": moe_specs(cfg, layers),
    }


def lm_specs(cfg) -> Dict:
    V, d = cfg.vocab_size, cfg.d_model
    specs: Dict = {"embed": embed_spec(V, d), "final_norm": rmsnorm_spec(d)}
    if cfg.family == "moe":
        nd = cfg.first_dense_layers
        if nd:
            specs["dense_blocks"] = dense_block_specs(cfg, nd)
        specs["moe_blocks"] = moe_block_specs(cfg, cfg.n_layers - nd)
    else:
        specs["blocks"] = dense_block_specs(cfg, cfg.n_layers)
    if not cfg.tie_embeddings:
        specs["head"] = embed_spec(V, d)
    return specs


# ================================================================ block fwd
def _self_attn(cfg, p, x, positions, *, return_kv=False):
    if cfg.use_mla:
        return A.mla_forward(cfg, p, x, positions, causal=cfg.causal,
                             return_kv=return_kv)
    return A.attn_forward(cfg, p, x, positions, causal=cfg.causal,
                          return_kv=return_kv)


def block_forward(cfg, p: Dict, x: jax.Array, positions: jax.Array,
                  *, is_moe: bool, return_kv: bool = False):
    """Returns (x, kv_cache_or_None, aux_loss)."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.parallel_block and not is_moe:
        # command-r: shared-norm parallel residual
        a = _self_attn(cfg, p["attn"], h, positions, return_kv=return_kv)
        a, kv = a if return_kv else (a, None)
        m = mlp(p["mlp"], h)
        return shard(x + a + m, "batch", "seq", None), kv, jnp.float32(0)
    a = _self_attn(cfg, p["attn"], h, positions, return_kv=return_kv)
    a, kv = a if return_kv else (a, None)
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if is_moe:
        m, aux = moe_ffn(cfg, p["moe"], h)
    else:
        m, aux = mlp(p["mlp"], h), jnp.float32(0)
    return shard(x + m, "batch", "seq", None), kv, aux


def block_decode(cfg, p: Dict, x: jax.Array, pos, cache: Dict, *, is_moe: bool):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        a, cache = A.mla_decode(cfg, p["attn"], h, pos, cache)
    else:
        a, cache = A.attn_decode(cfg, p["attn"], h, pos, cache)
    if cfg.parallel_block and not is_moe:
        return x + a + mlp(p["mlp"], h), cache
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    m = moe_ffn(cfg, p["moe"], h)[0] if is_moe else mlp(p["mlp"], h)
    return x + m, cache


# ================================================================ stack run
def _layer_slice(tree: Tree, i: int) -> Tree:
    return jax.tree_util.tree_map(lambda w: w[i], tree)


def run_stack(cfg, blocks_p: Tree, x: jax.Array, fwd_one, n_layers: int,
              *, remat: bool, collect=False):
    """fwd_one(layer_params, x) -> (x, ys, aux). Scan or unroll the stack."""
    if cfg.scan_layers:
        def body(h, pl):
            h, ys, aux = fwd_one(pl, h)
            return h, (ys, aux)
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, (ys, auxs) = jax.lax.scan(body, x, blocks_p)
        return x, ys, jnp.sum(auxs)
    ys_list, aux = [], jnp.float32(0)
    fn = jax.checkpoint(fwd_one, prevent_cse=False) if remat else fwd_one
    for i in range(n_layers):
        x, ys, a = fn(_layer_slice(blocks_p, i), x)
        aux = aux + a
        if collect:
            ys_list.append(ys)
    if collect and ys_list and ys_list[0] is not None:
        ys = jax.tree_util.tree_map(lambda *l: jnp.stack(l), *ys_list)
    else:
        ys = None
    return x, ys, aux


def run_stack_decode(cfg, blocks_p: Tree, caches: Tree, x: jax.Array,
                     dec_one, n_layers: int):
    """dec_one(layer_params, x, cache) -> (x, cache)."""
    if cfg.scan_layers:
        def body(h, xs):
            pl, c = xs
            h, c = dec_one(pl, h, c)
            return h, c
        x, caches = jax.lax.scan(body, x, (blocks_p, caches))
        return x, caches
    new = []
    for i in range(n_layers):
        x, c = dec_one(_layer_slice(blocks_p, i), x, _layer_slice(caches, i))
        new.append(c)
    caches = jax.tree_util.tree_map(lambda *l: jnp.stack(l), *new)
    return x, caches


# ================================================================ LM api
def _groups(cfg):
    """[(name, n_layers, is_moe)] in execution order."""
    if cfg.family == "moe":
        g = []
        if cfg.first_dense_layers:
            g.append(("dense_blocks", cfg.first_dense_layers, False))
        g.append(("moe_blocks", cfg.n_layers - cfg.first_dense_layers, True))
        return g
    return [("blocks", cfg.n_layers, False)]


def lm_hidden(cfg, params: Dict, tokens: jax.Array, *, remat: Optional[bool] = None):
    """Token ids -> final hidden states (pre final-norm). Returns (h, aux)."""
    S = tokens.shape[1]
    positions = jnp.arange(S)
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    aux = jnp.float32(0)
    for name, n, is_moe in _groups(cfg):
        def one(pl, h, _moe=is_moe):
            h, _, a = block_forward(cfg, pl, h, positions, is_moe=_moe)
            return h, None, a
        x, _, a = run_stack(cfg, params[name], x, one, n,
                            remat=cfg.remat if remat is None else remat)
        aux = aux + a
    return x, aux


def lm_logits(cfg, params: Dict, h: jax.Array) -> jax.Array:
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(w, h, cfg.vocab_size)


def lm_loss(cfg, params: Dict, tokens: jax.Array, labels: jax.Array,
            *, aux_coef: float = 0.01) -> jax.Array:
    h, aux = lm_hidden(cfg, params, tokens)
    logits = lm_logits(cfg, params, h)
    return softmax_xent(logits, labels) + aux_coef * aux


def lm_prefill(cfg, params: Dict, tokens: jax.Array):
    """Prefill: returns (last-position logits, stacked KV caches per group)."""
    S = tokens.shape[1]
    positions = jnp.arange(S)
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    caches: Dict = {}
    for name, n, is_moe in _groups(cfg):
        def one(pl, h, _moe=is_moe):
            h, kv, a = block_forward(cfg, pl, h, positions, is_moe=_moe,
                                     return_kv=True)
            return h, kv, a
        x, kv, _ = run_stack(cfg, params[name], x, one, n, remat=False,
                             collect=True)
        caches[name] = kv
    logits = lm_logits(cfg, params, x[:, -1:])
    return logits, caches


def lm_decode(cfg, params: Dict, caches: Dict, tokens: jax.Array, pos):
    """One decode step. tokens: (B,1); pos: scalar current position."""
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    new: Dict = {}
    for name, n, is_moe in _groups(cfg):
        def dec(pl, h, c, _moe=is_moe):
            return block_decode(cfg, pl, h, pos, c, is_moe=_moe)
        x, nc = run_stack_decode(cfg, params[name], caches[name], x, dec, n)
        new[name] = nc
    logits = lm_logits(cfg, params, x)
    return logits, new


def lm_cache_specs(cfg, batch: int, max_len: int) -> Dict:
    out = {}
    for name, n, _ in _groups(cfg):
        if cfg.use_mla:
            per = A.mla_cache_specs(cfg, batch, max_len)
        else:
            per = A.kv_cache_specs(cfg, batch, max_len)
        out[name] = jax.tree_util.tree_map(
            lambda s: spec((n,) + s.shape, ("layers",) + s.axes,
                           dtype=s.dtype, init="zeros"),
            per, is_leaf=lambda v: hasattr(v, "axes"))
    return out
