"""VLA models — the paper's own evaluation targets (OpenVLA, CogACT).

Structure: ViT encoder (patch embeddings -> vit blocks -> project to LLM
width)  +  LLM backbone  +  action decoder S_dec ∈ {detok, MLP, LSTM,
diffusion, DiT} (paper §IV-A structure model).  The image frontend proper
(conv patchify) is stubbed: inputs are patch embeddings (B, n_patches,
vit_dim), matching the assignment's STUB rule and the dry-run input specs.

The flattened layer graph of these models is what RoboECC segments; see
``core/structure.py`` which mirrors this file's block ordering.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from .layers import dense, embed, embed_spec, linear_spec, mlp, mlp_specs, \
    rmsnorm, rmsnorm_spec, softmax_xent, unembed
from .sharding import spec
from .transformer import block_forward, dense_block_specs, run_stack, \
    run_stack_decode, lm_cache_specs, _layer_slice


# ------------------------------------------------------------------ ViT
def _vit_cfg(cfg):
    dv = cfg.vit_dim
    hd = min(64, dv)
    return cfg.replace(d_model=dv, n_heads=dv // hd, n_kv_heads=dv // hd,
                       head_dim=hd, d_ff=4 * dv, causal=False,
                       use_mla=False, parallel_block=False, qkv_bias=False)


def vit_specs(cfg) -> Dict:
    dv = cfg.vit_dim
    vit_cfg = _vit_cfg(cfg)
    return {
        "pos_embed": spec((cfg.n_patches, dv), (None, None), scale=0.02),
        "blocks": {
            "ln1": rmsnorm_spec(dv, cfg.vit_layers),
            "attn": A.attn_specs(vit_cfg, cfg.vit_layers),
            "ln2": rmsnorm_spec(dv, cfg.vit_layers),
            "mlp": mlp_specs(dv, 4 * dv, cfg.vit_layers),
        },
        "norm": rmsnorm_spec(dv),
        "proj": linear_spec(dv, cfg.d_model, ("d_model", None)),
    }


def vit_encode(cfg, p, patches: jax.Array) -> jax.Array:
    """patches: (B, n_patches, vit_dim) -> (B, n_patches, d_model)."""
    vit_cfg = _vit_cfg(cfg)
    x = patches.astype(jnp.dtype(cfg.dtype)) + p["pos_embed"].astype(
        jnp.dtype(cfg.dtype))
    positions = jnp.arange(x.shape[1])

    def one(pl, h):
        a = A.attn_forward(vit_cfg, pl["attn"],
                           rmsnorm(h, pl["ln1"], cfg.norm_eps), positions,
                           causal=False)
        h = h + a
        h = h + mlp(pl["mlp"], rmsnorm(h, pl["ln2"], cfg.norm_eps))
        return h, None, jnp.float32(0)

    x, _, _ = run_stack(vit_cfg, p["blocks"], x, one, cfg.vit_layers,
                        remat=False)
    x = rmsnorm(x, p["norm"], cfg.norm_eps)
    return dense(x, p["proj"])


# ------------------------------------------------------------- action heads
def action_head_specs(cfg) -> Dict:
    d, a, h = cfg.d_model, cfg.action_dim, cfg.action_horizon
    kind = cfg.vla_action_head
    if kind in ("detok", ""):
        return {}
    if kind == "mlp":
        return {
            "w1": linear_spec(d, 4 * d, ("d_model", "ff")),
            "w2": linear_spec(4 * d, d, ("ff", "d_model")),
            "out": linear_spec(d, a * h, ("d_model", None)),
        }
    if kind == "lstm":
        return {
            "wx": linear_spec(d, 4 * d, ("d_model", "ff")),
            "wh": linear_spec(d, 4 * d, ("d_model", "ff")),
            "b": spec((4 * d,), ("ff",), init="zeros"),
            "out": linear_spec(d, a, ("d_model", None)),
        }
    if kind == "diffusion":  # small conditional denoising MLP
        return {
            "in": linear_spec(a * h + d + 64, d, (None, "d_model")),
            "mid": linear_spec(d, d, ("d_model", None)),
            "out": linear_spec(d, a * h, ("d_model", None)),
        }
    if kind == "dit":
        dd = cfg.dit_dim
        return {
            "x_in": linear_spec(a, dd, (None, None)),
            "cond": linear_spec(d, dd, ("d_model", None)),
            "t_emb": linear_spec(64, dd, (None, None)),
            "blocks": {
                "mod": linear_spec(dd, 6 * dd, (None, None), cfg.dit_layers,
                                   init="zeros"),
                "wq": linear_spec(dd, dd, (None, "q_heads"), cfg.dit_layers),
                "wk": linear_spec(dd, dd, (None, "q_heads"), cfg.dit_layers),
                "wv": linear_spec(dd, dd, (None, "q_heads"), cfg.dit_layers),
                "wo": linear_spec(dd, dd, ("q_heads", None), cfg.dit_layers),
                "w1": linear_spec(dd, 4 * dd, (None, "ff"), cfg.dit_layers),
                "w2": linear_spec(4 * dd, dd, ("ff", None), cfg.dit_layers),
            },
            "final_mod": linear_spec(dd, 2 * dd, (None, None), init="zeros"),
            "out": linear_spec(dd, a, (None, None), init="zeros"),
        }
    raise ValueError(f"unknown action head {kind!r}")


def _timestep_embed(t: jax.Array, dim: int = 64) -> jax.Array:
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half) / half)
    ang = t[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], -1)


def _dit_block(cfg, pl, x, cond):
    """x: (B, H, dd); cond: (B, dd). adaLN-zero DiT block."""
    dd = cfg.dit_dim
    nh = cfg.dit_heads
    hd = dd // nh
    m = dense(jax.nn.silu(cond.astype(jnp.float32)).astype(x.dtype),
              pl["mod"])
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(m[:, None, :], 6, axis=-1)
    h = _ln(x) * (1 + sc1) + sh1
    B, H, _ = x.shape
    q = dense(h, pl["wq"]).reshape(B, H, nh, hd)
    k = dense(h, pl["wk"]).reshape(B, H, nh, hd).transpose(0, 2, 1, 3)
    v = dense(h, pl["wv"]).reshape(B, H, nh, hd).transpose(0, 2, 1, 3)
    o = A._sdpa(q, k, v, causal=False)
    x = x + g1 * dense(o.reshape(B, H, dd), pl["wo"])
    h = _ln(x) * (1 + sc2) + sh2
    x = x + g2 * dense(jax.nn.gelu(dense(h, pl["w1"])), pl["w2"])
    return x


def _ln(x):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)


def dit_denoise(cfg, p, noisy: jax.Array, t: jax.Array, cognition: jax.Array):
    """noisy: (B, horizon, action_dim); t: (B,); cognition: (B, d_model)."""
    x = dense(noisy.astype(jnp.dtype(cfg.dtype)), p["x_in"])
    cond = dense(cognition, p["cond"]) + dense(
        _timestep_embed(t).astype(jnp.dtype(cfg.dtype)), p["t_emb"])

    def one(pl, h):
        return _dit_block(cfg, pl, h, cond), None, jnp.float32(0)

    x, _, _ = run_stack(cfg, p["blocks"], x, one, cfg.dit_layers, remat=False)
    m = dense(jax.nn.silu(cond.astype(jnp.float32)).astype(x.dtype),
              p["final_mod"])
    sh, sc = jnp.split(m[:, None, :], 2, axis=-1)
    return dense(_ln(x) * (1 + sc) + sh, p["out"])     # predicted noise


def dit_sample(cfg, p, cognition: jax.Array, key: jax.Array) -> jax.Array:
    """DDIM sampling over cfg.diffusion_steps."""
    B = cognition.shape[0]
    a, h = cfg.action_dim, cfg.action_horizon
    x = jax.random.normal(key, (B, h, a), jnp.float32)
    n = cfg.diffusion_steps
    betas = jnp.linspace(1e-4, 0.02, n)
    alphas = jnp.cumprod(1.0 - betas)

    def step(x, i):
        t = n - 1 - i
        ab = alphas[t]
        ab_prev = jnp.where(t > 0, alphas[jnp.maximum(t - 1, 0)], 1.0)
        eps = dit_denoise(cfg, p, x, jnp.full((B,), t), cognition)
        x0 = (x - jnp.sqrt(1 - ab) * eps.astype(jnp.float32)) / jnp.sqrt(ab)
        x = jnp.sqrt(ab_prev) * x0 + jnp.sqrt(1 - ab_prev) * eps.astype(
            jnp.float32)
        return x, None

    x, _ = jax.lax.scan(step, x, jnp.arange(n))
    return x


# ------------------------------------------------------------------ VLA model
def vla_specs(cfg) -> Dict:
    s = {
        "vit": vit_specs(cfg),
        "embed": embed_spec(cfg.vocab_size, cfg.d_model),
        "blocks": dense_block_specs(cfg, cfg.n_layers),
        "final_norm": rmsnorm_spec(cfg.d_model),
        "head": embed_spec(cfg.vocab_size, cfg.d_model),
        "action": action_head_specs(cfg),
    }
    return s


def vla_backbone(cfg, params, patches, tokens, *, remat=False):
    """ViT + LLM over [img ; text] -> hidden states (B, P+S, d)."""
    img = vit_encode(cfg, params["vit"], patches)
    txt = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x = jnp.concatenate([img, txt], axis=1)
    positions = jnp.arange(x.shape[1])

    def one(pl, h):
        h, _, a = block_forward(cfg, pl, h, positions, is_moe=False)
        return h, None, a

    x, _, _ = run_stack(cfg, params["blocks"], x, one, cfg.n_layers,
                        remat=remat)
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def vla_forward(cfg, params, patches, tokens, key=None):
    """Inference: returns action (B, horizon, action_dim)."""
    h = vla_backbone(cfg, params, patches, tokens)
    kind = cfg.vla_action_head
    if kind in ("detok", ""):
        logits = unembed(params["head"], h[:, -cfg.action_dim:], cfg.vocab_size)
        toks = jnp.argmax(logits, -1)                     # (B, action_dim)
        # de-tokenize: 256 uniform bins over [-1, 1] at the vocab tail
        act = (toks.astype(jnp.float32) % 256) / 127.5 - 1.0
        return act[:, None, :]
    cog = h[:, -1]                                        # cognition feature
    if kind == "mlp":
        p = params["action"]
        z = jax.nn.gelu(dense(cog, p["w1"]))
        z = jax.nn.gelu(dense(z, p["w2"]))
        return dense(z, p["out"]).reshape(
            -1, cfg.action_horizon, cfg.action_dim)
    if kind == "lstm":
        p = params["action"]
        B, d = cog.shape
        hs = jnp.zeros((B, d), cog.dtype)
        cs = jnp.zeros((B, d), jnp.float32)

        def step(carry, _):
            hs, cs = carry
            g = dense(cog, p["wx"]) + dense(hs, p["wh"]) + p["b"]
            i, f, o, c = jnp.split(g.astype(jnp.float32), 4, -1)
            cs = jax.nn.sigmoid(f) * cs + jax.nn.sigmoid(i) * jnp.tanh(c)
            hs = (jax.nn.sigmoid(o) * jnp.tanh(cs)).astype(cog.dtype)
            return (hs, cs), dense(hs, p["out"])

        _, acts = jax.lax.scan(step, (hs, cs), None, length=cfg.action_horizon)
        return acts.swapaxes(0, 1)
    if kind == "diffusion":
        p = params["action"]
        B = cog.shape[0]
        key = key if key is not None else jax.random.PRNGKey(0)
        x = jax.random.normal(key, (B, cfg.action_horizon * cfg.action_dim))
        n = cfg.diffusion_steps
        for t in range(n - 1, -1, -1):
            te = _timestep_embed(jnp.full((B,), t))
            inp = jnp.concatenate(
                [x.astype(cog.dtype), cog, te.astype(cog.dtype)], -1)
            eps = dense(jax.nn.gelu(dense(jax.nn.gelu(dense(inp, p["in"])),
                                          p["mid"])), p["out"])
            x = x - eps.astype(jnp.float32) / n
        return x.reshape(B, cfg.action_horizon, cfg.action_dim)
    if kind == "dit":
        key = key if key is not None else jax.random.PRNGKey(0)
        return dit_sample(cfg, params["action"], cog, key)
    raise ValueError(kind)


def vla_loss(cfg, params, patches, tokens, action_labels, key) -> jax.Array:
    """Training loss: detok -> xent on binned action tokens; else regression/
    diffusion loss on the action chunk."""
    h = vla_backbone(cfg, params, patches, tokens, remat=cfg.remat)
    kind = cfg.vla_action_head
    if kind in ("detok", ""):
        logits = unembed(params["head"], h[:, -cfg.action_dim:], cfg.vocab_size)
        bins = jnp.clip(((action_labels[:, 0] + 1) * 127.5), 0, 255).astype(
            jnp.int32)
        return softmax_xent(logits, bins)
    cog = h[:, -1]
    if kind == "dit":
        p = params["action"]
        B = cog.shape[0]
        k1, k2 = jax.random.split(key)
        t = jax.random.randint(k1, (B,), 0, cfg.diffusion_steps)
        noise = jax.random.normal(k2, action_labels.shape)
        betas = jnp.linspace(1e-4, 0.02, cfg.diffusion_steps)
        ab = jnp.cumprod(1.0 - betas)[t][:, None, None]
        noisy = jnp.sqrt(ab) * action_labels + jnp.sqrt(1 - ab) * noise
        eps = dit_denoise(cfg, p, noisy, t, cog)
        return jnp.mean((eps.astype(jnp.float32) - noise) ** 2)
    pred = vla_forward(cfg, params, patches, tokens, key)
    return jnp.mean((pred.astype(jnp.float32)
                     - action_labels.astype(jnp.float32)) ** 2)
