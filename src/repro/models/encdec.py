"""Encoder-decoder backbone (seamless-m4t-large-v2).

Audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings ``(B, S_src, d_model)``; a single learned
projection marks the frontend boundary.  Decoder = causal self-attn +
cross-attn + MLP.  Decode caches: per-layer self KV + precomputed cross KV.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from .layers import embed, embed_spec, linear_spec, mlp, mlp_specs, rmsnorm, \
    rmsnorm_spec, softmax_xent, unembed
from .sharding import spec
from .transformer import run_stack, run_stack_decode, _layer_slice


def enc_block_specs(cfg, layers):
    return {
        "ln1": rmsnorm_spec(cfg.d_model, layers),
        "attn": A.attn_specs(cfg, layers),
        "ln2": rmsnorm_spec(cfg.d_model, layers),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff, layers),
    }


def dec_block_specs(cfg, layers):
    return {
        "ln1": rmsnorm_spec(cfg.d_model, layers),
        "self_attn": A.attn_specs(cfg, layers),
        "lnx": rmsnorm_spec(cfg.d_model, layers),
        "cross_attn": A.attn_specs(cfg, layers, cross=True),
        "ln2": rmsnorm_spec(cfg.d_model, layers),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff, layers),
    }


def encdec_specs(cfg) -> Dict:
    d = cfg.d_model
    s = {
        "frontend_proj": linear_spec(d, d, ("d_model", None)),
        "enc_blocks": enc_block_specs(cfg, cfg.n_enc_layers),
        "enc_norm": rmsnorm_spec(d),
        "embed": embed_spec(cfg.vocab_size, d),
        "dec_blocks": dec_block_specs(cfg, cfg.n_dec_layers),
        "final_norm": rmsnorm_spec(d),
    }
    if not cfg.tie_embeddings:
        s["head"] = embed_spec(cfg.vocab_size, d)
    return s


def encode(cfg, params, frames: jax.Array, *, remat: bool):
    """frames: (B, S_src, d_model) stub embeddings -> encoder output."""
    x = jnp.einsum("...d,df->...f", frames.astype(jnp.dtype(cfg.dtype)),
                   params["frontend_proj"])
    S = x.shape[1]
    positions = jnp.arange(S)

    def one(pl, h):
        a = A.attn_forward(cfg, pl["attn"], rmsnorm(h, pl["ln1"], cfg.norm_eps),
                           positions, causal=False)
        h = h + a
        h = h + mlp(pl["mlp"], rmsnorm(h, pl["ln2"], cfg.norm_eps))
        return h, None, jnp.float32(0)

    x, _, _ = run_stack(cfg, params["enc_blocks"], x, one, cfg.n_enc_layers,
                        remat=remat)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(cfg, pl, h, positions, enc_out=None, cross_kv=None,
               return_kv=False):
    a = A.attn_forward(cfg, pl["self_attn"], rmsnorm(h, pl["ln1"], cfg.norm_eps),
                       positions, causal=True, return_kv=return_kv)
    a, kv = a if return_kv else (a, None)
    h = h + a
    c, ckv = A.cross_attn_forward(cfg, pl["cross_attn"],
                                  rmsnorm(h, pl["lnx"], cfg.norm_eps),
                                  kv_x=enc_out, kv_cache=cross_kv)
    h = h + c
    h = h + mlp(pl["mlp"], rmsnorm(h, pl["ln2"], cfg.norm_eps))
    return h, kv, ckv


def encdec_loss(cfg, params, frames, tokens, labels) -> jax.Array:
    enc_out = encode(cfg, params, frames, remat=cfg.remat)
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(tokens.shape[1])

    def one(pl, h):
        h, _, _ = _dec_block(cfg, pl, h, positions, enc_out=enc_out)
        return h, None, jnp.float32(0)

    x, _, _ = run_stack(cfg, params["dec_blocks"], x, one, cfg.n_dec_layers,
                        remat=cfg.remat)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return softmax_xent(unembed(w, x, cfg.vocab_size), labels)


def encdec_prefill(cfg, params, frames, tokens):
    """Encode src + teacher-force `tokens` prefix; return (logits, caches)."""
    enc_out = encode(cfg, params, frames, remat=False)
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(tokens.shape[1])

    def one(pl, h):
        h, kv, ckv = _dec_block(cfg, pl, h, positions, enc_out=enc_out,
                                return_kv=True)
        return h, {"self": kv, "cross": ckv}, jnp.float32(0)

    x, caches, _ = run_stack(cfg, params["dec_blocks"], x, one,
                             cfg.n_dec_layers, remat=False, collect=True)
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(w, x, cfg.vocab_size), caches


def encdec_decode(cfg, params, caches, tokens, pos):
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))

    def dec(pl, h, c):
        a, kv = A.attn_decode(cfg, pl["self_attn"],
                              rmsnorm(h, pl["ln1"], cfg.norm_eps), pos,
                              c["self"])
        h = h + a
        cr, _ = A.cross_attn_forward(cfg, pl["cross_attn"],
                                     rmsnorm(h, pl["lnx"], cfg.norm_eps),
                                     kv_cache=c["cross"])
        h = h + cr
        h = h + mlp(pl["mlp"], rmsnorm(h, pl["ln2"], cfg.norm_eps))
        return h, {"self": kv, "cross": c["cross"]}

    x, caches = run_stack_decode(cfg, params["dec_blocks"], caches, x, dec,
                                 cfg.n_dec_layers)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(w, x, cfg.vocab_size), caches


def encdec_cache_specs(cfg, batch: int, max_len: int, src_len: int) -> Dict:
    L = cfg.n_dec_layers
    self_kv = A.kv_cache_specs(cfg, batch, max_len)
    cross_kv = A.kv_cache_specs(cfg, batch, src_len)
    stack = lambda tree: jax.tree_util.tree_map(
        lambda s: spec((L,) + s.shape, ("layers",) + s.axes, dtype=s.dtype,
                       init="zeros"),
        tree, is_leaf=lambda v: hasattr(v, "axes"))
    return {"self": stack(self_kv), "cross": stack(cross_kv)}
