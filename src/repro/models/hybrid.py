"""Hybrid SSM + shared-attention backbone (zamba2-1.2b).

38 Mamba2 blocks; ONE shared transformer block (attn + MLP, weights shared)
is invoked before every ``cfg.shared_attn_every``-th Mamba block.  Each
invocation *site* keeps its own KV cache (same weights, different
activations) — an extreme in-model analogue of the paper's parameter-sharing
pool (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from . import attention as A
from .layers import embed, embed_spec, mlp, mlp_specs, rmsnorm, rmsnorm_spec, \
    softmax_xent, unembed
from .sharding import spec
from .ssm import (mamba_decode, mamba_forward, mamba_prefill, mamba_specs,
                  ssm_state_specs)
from .transformer import run_stack, run_stack_decode, _layer_slice


def n_sites(cfg) -> int:
    return math.ceil(cfg.n_layers / cfg.shared_attn_every)


def hybrid_specs(cfg) -> Dict:
    d = cfg.d_model
    s = {
        "embed": embed_spec(cfg.vocab_size, d),
        "mamba": mamba_specs(cfg, cfg.n_layers),
        "shared": {  # ONE block, reused at every site
            "ln1": rmsnorm_spec(d),
            "attn": A.attn_specs(cfg),
            "ln2": rmsnorm_spec(d),
            "mlp": mlp_specs(d, cfg.d_ff),
        },
        "final_norm": rmsnorm_spec(d),
    }
    if not cfg.tie_embeddings:
        s["head"] = embed_spec(cfg.vocab_size, d)
    return s


def _shared_fwd(cfg, p, x, positions, return_kv=False):
    a = A.attn_forward(cfg, p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps),
                       positions, causal=True, return_kv=return_kv)
    a, kv = a if return_kv else (a, None)
    x = x + a
    x = x + mlp(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    return (x, kv) if return_kv else x


def _groups(cfg):
    """[(site_idx, layer_lo, layer_hi)] — shared block fires before layer_lo."""
    k = cfg.shared_attn_every
    return [(g, g * k, min((g + 1) * k, cfg.n_layers))
            for g in range(n_sites(cfg))]


def hybrid_hidden(cfg, params, tokens, *, remat):
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(tokens.shape[1])
    for g, lo, hi in _groups(cfg):
        x = _shared_fwd(cfg, params["shared"], x, positions)
        grp = jax.tree_util.tree_map(lambda w: w[lo:hi], params["mamba"])

        def one(pl, h):
            return h + mamba_forward(cfg, pl, h), None, jnp.float32(0)

        x, _, _ = run_stack(cfg, grp, x, one, hi - lo, remat=remat)
    return x


def hybrid_loss(cfg, params, tokens, labels) -> jax.Array:
    x = hybrid_hidden(cfg, params, tokens, remat=cfg.remat)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return softmax_xent(unembed(w, x, cfg.vocab_size), labels)


def hybrid_prefill(cfg, params, tokens):
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(tokens.shape[1])
    attn_caches, ssm_states = [], []
    for g, lo, hi in _groups(cfg):
        x, kv = _shared_fwd(cfg, params["shared"], x, positions,
                            return_kv=True)
        attn_caches.append(kv)
        grp = jax.tree_util.tree_map(lambda w: w[lo:hi], params["mamba"])

        def one(pl, h):
            out, st = mamba_prefill(cfg, pl, h)
            return h + out, st, jnp.float32(0)

        x, states, _ = run_stack(cfg, grp, x, one, hi - lo, remat=False,
                                 collect=True)
        ssm_states.append(states)
    caches = {
        "attn": jax.tree_util.tree_map(lambda *l: jnp.stack(l), *attn_caches),
        "ssm": jax.tree_util.tree_map(lambda *l: jnp.concatenate(l),
                                      *ssm_states),
    }
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(w, x, cfg.vocab_size), caches


def hybrid_decode(cfg, params, caches, tokens, pos):
    caches = dict(caches)
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    new_attn = []
    for g, lo, hi in _groups(cfg):
        site_kv = _layer_slice(caches["attn"], g)
        h = rmsnorm(x, params["shared"]["ln1"], cfg.norm_eps)
        a, site_kv = A.attn_decode(cfg, params["shared"]["attn"], h, pos,
                                   site_kv)
        x = x + a
        x = x + mlp(params["shared"]["mlp"],
                    rmsnorm(x, params["shared"]["ln2"], cfg.norm_eps))
        new_attn.append(site_kv)
        grp = jax.tree_util.tree_map(lambda w: w[lo:hi], params["mamba"])
        sgrp = jax.tree_util.tree_map(lambda w: w[lo:hi], caches["ssm"])

        def dec(pl, h_, st):
            out, st = mamba_decode(cfg, pl, h_, st)
            return h_ + out, st

        x, nst = run_stack_decode(cfg, grp, sgrp, x, dec, hi - lo)
        caches["ssm"] = jax.tree_util.tree_map(
            lambda full, new, _lo=lo: jax.lax.dynamic_update_slice(
                full, new, (_lo,) + (0,) * (full.ndim - 1)),
            caches["ssm"], nst)
    caches["attn"] = jax.tree_util.tree_map(lambda *l: jnp.stack(l), *new_attn)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(w, x, cfg.vocab_size), caches


def hybrid_cache_specs(cfg, batch: int, max_len: int) -> Dict:
    ns = n_sites(cfg)
    per_attn = A.kv_cache_specs(cfg, batch, max_len)
    stack = lambda tree, n: jax.tree_util.tree_map(
        lambda s: spec((n,) + s.shape, ("layers",) + s.axes, dtype=s.dtype,
                       init="zeros"),
        tree, is_leaf=lambda v: hasattr(v, "axes"))
    return {
        "attn": stack(per_attn, ns),
        "ssm": stack(ssm_state_specs(cfg, batch), cfg.n_layers),
    }
