"""Mamba2 (SSD — state-space duality) blocks, chunked prefill + O(1) decode.

Layout/sharding: the inner dim ``d_inner = expand * d_model`` (and therefore
the SSD head dim) shards on ``model``; the B/C projections (state dim N,
shared across heads, n_groups=1) are small and stay replicated.  The chunked
SSD materialises per-chunk (Q, Q, H) decay-masked scores — with H sharded on
``model`` and batch on ``data`` this stays a few hundred MB/device at the
assigned shapes (see DESIGN.md §5).

``ssd_chunked`` is the pure-jnp implementation that doubles as the oracle for
the ``kernels/ssd_scan`` Pallas kernel.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import linear_spec, rmsnorm, rmsnorm_spec
from .sharding import shard, spec


# ------------------------------------------------------------------ specs
def mamba_specs(cfg, layers: Optional[int] = None) -> Dict:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, W = cfg.ssm_nheads, cfg.ssm_conv
    L = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)
    return {
        "norm": rmsnorm_spec(d, layers),
        "wz": linear_spec(d, di, ("d_model", "inner"), layers),
        "wx": linear_spec(d, di, ("d_model", "inner"), layers),
        "wB": linear_spec(d, N, ("d_model", None), layers),
        "wC": linear_spec(d, N, ("d_model", None), layers),
        "wdt": linear_spec(d, H, ("d_model", "inner"), layers),
        "dt_bias": spec(L + (H,), lax_ + ("inner",), init="zeros"),
        "A_log": spec(L + (H,), lax_ + ("inner",), init="zeros"),
        "D": spec(L + (H,), lax_ + ("inner",), init="ones"),
        "conv_x": spec(L + (W, di), lax_ + (None, "inner"), scale=0.5),
        "conv_B": spec(L + (W, N), lax_ + (None, None), scale=0.5),
        "conv_C": spec(L + (W, N), lax_ + (None, None), scale=0.5),
        "gate_norm": spec(L + (di,), lax_ + ("inner",), init="ones"),
        "wo": linear_spec(di, d, ("inner", "d_model"), layers),
    }


def ssm_state_specs(cfg, batch: int) -> Dict:
    """Decode-time recurrent state (per layer)."""
    di, N = cfg.d_inner, cfg.ssm_state
    H, P, W = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_conv
    dt = jnp.dtype(cfg.dtype)
    return {
        "ssd": spec((batch, H, N, P), ("batch", "act_inner", None, None),
                    dtype=jnp.float32, init="zeros"),
        "conv_x": spec((batch, W - 1, di), ("batch", None, "act_inner"),
                       dtype=dt, init="zeros"),
        "conv_B": spec((batch, W - 1, N), ("batch", None, None), dtype=dt,
                       init="zeros"),
        "conv_C": spec((batch, W - 1, N), ("batch", None, None), dtype=dt,
                       init="zeros"),
    }


# ------------------------------------------------------------------ helpers
def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B,T,C); w: (W,C). Depthwise causal conv, silu activation."""
    W = w.shape[0]
    T = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + T] * w[i] for i in range(W))
    return jax.nn.silu(y)


def _conv_step(x: jax.Array, w: jax.Array, cache: jax.Array):
    """x: (B,C); cache: (B,W-1,C). Returns (y (B,C), new cache)."""
    W = w.shape[0]
    y = x * w[-1] + sum(cache[:, i] * w[i] for i in range(W - 1))
    new = jnp.concatenate([cache[:, 1:], x[:, None]], axis=1)
    return jax.nn.silu(y), new


# ------------------------------------------------------------------ SSD core
def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                Bm: jax.Array, Cm: jax.Array, chunk: int,
                initial_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD forward.

    x:  (B, T, H, P)   inputs (already includes dt weighting? no — raw)
    dt: (B, T, H)      positive step sizes
    A:  (H,)           negative decay rates
    Bm: (B, T, N), Cm: (B, T, N)  (n_groups=1, shared across heads)
    Returns (y (B,T,H,P), final_state (B,H,N,P)).
    """
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    pad = (-T) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc = Tp // Q
    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    dA = dtc * A.astype(jnp.float32)                       # (B,nc,Q,H) <= 0
    dA_cs = jnp.cumsum(dA, axis=2)                          # inclusive cumsum
    xdt = xc * dtc[..., None].astype(xc.dtype)

    # ---- intra-chunk (quadratic within chunk, decay-masked)
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]  # (B,nc,Q,K,H)
    ii = jnp.arange(Q)
    causal = ii[:, None] >= ii[None, :]
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc,
                    preferred_element_type=jnp.float32)
    scores = (CB[..., None] * L).astype(xc.dtype)           # (B,nc,Q,K,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xdt)

    # ---- chunk states
    decay_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)        # (B,nc,Q,H)
    wgt = xdt * decay_end[..., None].astype(xc.dtype)
    S_c = jnp.einsum("bckn,bckhp->bchnp", Bc, wgt,
                     preferred_element_type=jnp.float32)    # (B,nc,H,N,P)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])               # (B,nc,H)

    # ---- inter-chunk recurrence
    S0 = (initial_state if initial_state is not None
          else jnp.zeros((Bsz, H, N, P), jnp.float32))

    def step(S, inp):
        S_chunk, dec = inp                                   # (B,H,N,P),(B,H)
        S_in = S
        S = S * dec[:, :, None, None] + S_chunk
        return S, S_in

    S_final, S_ins = jax.lax.scan(
        step, S0, (S_c.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    S_ins = S_ins.swapaxes(0, 1)                             # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqn,bchnp->bcqhp", Cc,
                         S_ins.astype(xc.dtype))
    y_inter = y_inter * jnp.exp(dA_cs)[..., None].astype(xc.dtype)
    y = (y_intra + y_inter).reshape(Bsz, Tp, H, P)
    return y[:, :T], S_final


def ssd_step(S: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
             Bm: jax.Array, Cm: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single recurrent step. S:(B,H,N,P) x:(B,H,P) dt:(B,H) Bm/Cm:(B,N)."""
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32))                # (B,H)
    upd = jnp.einsum("bn,bhp->bhnp", Bm.astype(jnp.float32),
                     (x * dt[..., None]).astype(jnp.float32))
    S = S * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), S)
    return y.astype(x.dtype), S


# ------------------------------------------------------------------ block
def _proj(cfg, p, u):
    """Shared input projections + activations for prefill and decode."""
    z = jnp.einsum("...d,df->...f", u, p["wz"])
    xi = jnp.einsum("...d,df->...f", u, p["wx"])
    Bm = jnp.einsum("...d,dn->...n", u, p["wB"])
    Cm = jnp.einsum("...d,dn->...n", u, p["wC"])
    dt = jax.nn.softplus(
        jnp.einsum("...d,dh->...h", u, p["wdt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    return z, xi, Bm, Cm, dt


def mamba_forward(cfg, p: Dict, x: jax.Array, *, impl: Optional[str] = None):
    """Full-sequence Mamba2 block (pre-norm, residual outside)."""
    B, T, d = x.shape
    H, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    u = rmsnorm(x, p["norm"], cfg.norm_eps)
    z, xi, Bm, Cm, dt = _proj(cfg, p, u)
    xi = shard(xi, "batch", "seq", "act_inner")
    xi = _causal_conv(xi, p["conv_x"])
    Bm = _causal_conv(Bm, p["conv_B"])
    Cm = _causal_conv(Cm, p["conv_C"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xi.reshape(B, T, H, P)
    impl = impl or cfg.attn_impl
    if impl == "pallas":
        from ..kernels.ssd_scan import ops as ssd_ops
        y, S = ssd_ops.ssd_scan(xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    else:
        y, S = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xh * p["D"].astype(xh.dtype)[:, None]
    y = y.reshape(B, T, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["gate_norm"], cfg.norm_eps)
    y = shard(y, "batch", "seq", "act_inner")
    return jnp.einsum("...f,fd->...d", y, p["wo"])


def _tail(pre_conv_in: jax.Array, W: int) -> jax.Array:
    """Last W-1 raw (pre-activation) conv inputs, for decode handoff."""
    B, T, C = pre_conv_in.shape
    pad = max(W - 1 - T, 0)
    x = jnp.pad(pre_conv_in, ((0, 0), (pad, 0), (0, 0)))
    return x[:, -(W - 1):]


def mamba_prefill(cfg, p: Dict, x: jax.Array):
    """Forward + recurrent state for decode handoff."""
    B, T, d = x.shape
    H, P, N, W = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv
    u = rmsnorm(x, p["norm"], cfg.norm_eps)
    z, xi_raw, Bm_raw, Cm_raw, dt = _proj(cfg, p, u)
    xi_raw = shard(xi_raw, "batch", "seq", "act_inner")
    xi = _causal_conv(xi_raw, p["conv_x"])
    Bm = _causal_conv(Bm_raw, p["conv_B"])
    Cm = _causal_conv(Cm_raw, p["conv_C"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xi.reshape(B, T, H, P)
    y, S = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xh * p["D"].astype(xh.dtype)[:, None]
    y = y.reshape(B, T, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("...f,fd->...d", y, p["wo"])
    state = {"ssd": S,
             "conv_x": _tail(xi_raw, W),
             "conv_B": _tail(Bm_raw, W),
             "conv_C": _tail(Cm_raw, W)}
    return out, state


# ================================================================ SSM LM
def ssm_lm_specs(cfg) -> Dict:
    from .layers import embed_spec
    s = {
        "embed": embed_spec(cfg.vocab_size, cfg.d_model),
        "mamba": mamba_specs(cfg, cfg.n_layers),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        s["head"] = embed_spec(cfg.vocab_size, cfg.d_model)
    return s


def ssm_lm_loss(cfg, params, tokens, labels):
    from .layers import embed, softmax_xent, unembed
    from .transformer import run_stack
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))

    def one(pl, h):
        return h + mamba_forward(cfg, pl, h), None, jnp.float32(0)

    x, _, _ = run_stack(cfg, params["mamba"], x, one, cfg.n_layers,
                        remat=cfg.remat)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return softmax_xent(unembed(w, x, cfg.vocab_size), labels)


def ssm_lm_prefill(cfg, params, tokens):
    from .layers import embed, unembed
    from .transformer import run_stack
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))

    def one(pl, h):
        out, st = mamba_prefill(cfg, pl, h)
        return h + out, st, jnp.float32(0)

    x, states, _ = run_stack(cfg, params["mamba"], x, one, cfg.n_layers,
                             remat=False, collect=True)
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(w, x, cfg.vocab_size), states


def ssm_lm_decode(cfg, params, states, tokens, pos):
    from .layers import embed, unembed
    from .transformer import run_stack_decode
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))

    def dec(pl, h, st):
        out, st = mamba_decode(cfg, pl, h, st)
        return h + out, st

    x, states = run_stack_decode(cfg, params["mamba"], states, x, dec,
                                 cfg.n_layers)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(w, x, cfg.vocab_size), states


def ssm_lm_cache_specs(cfg, batch: int) -> Dict:
    per = ssm_state_specs(cfg, batch)
    return jax.tree_util.tree_map(
        lambda s: spec((cfg.n_layers,) + s.shape, ("layers",) + s.axes,
                       dtype=s.dtype, init="zeros"),
        per, is_leaf=lambda v: hasattr(v, "axes"))


def mamba_decode(cfg, p: Dict, x: jax.Array, state: Dict):
    """One-token step. x: (B,1,d)."""
    B = x.shape[0]
    H, P = cfg.ssm_nheads, cfg.ssm_headdim
    u = rmsnorm(x[:, 0], p["norm"], cfg.norm_eps)
    z, xi, Bm, Cm, dt = _proj(cfg, p, u)
    xi, cx = _conv_step(xi, p["conv_x"], state["conv_x"])
    Bm, cB = _conv_step(Bm, p["conv_B"], state["conv_B"])
    Cm, cC = _conv_step(Cm, p["conv_C"], state["conv_C"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, S = ssd_step(state["ssd"], xi.reshape(B, H, P), dt, A, Bm, Cm)
    y = y + xi.reshape(B, H, P) * p["D"].astype(y.dtype)[:, None]
    y = y.reshape(B, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bf,fd->bd", y, p["wo"])[:, None]
    return out, {"ssd": S, "conv_x": cx, "conv_B": cB, "conv_C": cC}
