"""Attention: GQA / MHA / MLA / cross-attention, with decode caches.

Sharding strategy (DESIGN.md §5):
  * Q/O projections shard the flattened head dim on ``model`` (H*hd and
    KV*hd are always divisible by 16 even when the head *count* is not).
  * Decode caches are stored FLAT as ``(B, S, KV*hd)`` sharded on the last
    dim — the exact sharding of the KV projection output, so cache writes
    need no resharding and jit in_shardings stay evenly divisible for every
    arch (KV head counts of 2/8 would otherwise shard unevenly).  The
    per-head view needed by the attention einsum is an intermediate
    reshape, which GSPMD re-tiles freely.
  * MLA stores the compressed ``(c_kv, k_pe)`` cache (paper-faithful to
    DeepSeek-V2) and decodes in the absorbed form: attention runs in the
    512-dim latent space, never materialising per-head K/V at decode time.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import shard_map
from .layers import apply_rope, dense, linear_spec
from .sharding import ParamSpec, current_mesh, shard, spec


# ============================================================== specs
def attn_specs(cfg, layers: Optional[int] = None, cross: bool = False) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    out = {
        "wq": linear_spec(d, H * hd, ("d_model", "q_heads"), layers),
        "wk": linear_spec(d, KV * hd, ("d_model", "kv_heads"), layers),
        "wv": linear_spec(d, KV * hd, ("d_model", "kv_heads"), layers),
        "wo": linear_spec(H * hd, d, ("q_heads", "d_model"), layers),
    }
    if cfg.qkv_bias and not cross:
        out["bq"] = _bias(H * hd, "q_heads", layers)
        out["bk"] = _bias(KV * hd, "kv_heads", layers)
        out["bv"] = _bias(KV * hd, "kv_heads", layers)
    return out


def _bias(n, axis, layers):
    if layers is None:
        return spec((n,), (axis,), init="zeros")
    return spec((layers, n), ("layers", axis), init="zeros")


def mla_specs(cfg, layers: Optional[int] = None) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    r, qk_n, qk_r, vd = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq": linear_spec(d, H * (qk_n + qk_r), ("d_model", "q_heads"), layers),
        "wkv_a": linear_spec(d, r + qk_r, ("d_model", "lora"), layers),
        "kv_norm": spec((r,) if layers is None else (layers, r),
                        ("lora",) if layers is None else ("layers", "lora"), init="ones"),
        "wk_b": linear_spec(r, H * qk_n, ("lora", "q_heads"), layers),
        "wv_b": linear_spec(r, H * vd, ("lora", "q_heads"), layers),
        "wo": linear_spec(H * vd, d, ("q_heads", "d_model"), layers),
    }


# ============================================================== core attention
# Above this many score elements (S*T) the XLA path switches to the blocked
# online-softmax formulation, which never materialises the full (S, T)
# score matrix — the jnp analogue of the Pallas flash kernel (and the form
# the dry-run compiles, since Pallas does not lower on the CPU backend).
_BLOCK_THRESHOLD = 2048 * 2048
_BQ, _BK = 2048, 8192
_NEG = -1e30


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
          causal: bool, q_pos: Optional[jax.Array] = None,
          kv_len: Optional[jax.Array] = None, impl: str = "xla") -> jax.Array:
    """q: (B,S,H,D); k,v: (B,H,T,D) (already GQA-expanded). fp32 softmax."""
    B, S, H, D = q.shape
    T = k.shape[2]
    if impl == "pallas" and causal and S > 1:
        from ..kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                                      causal=True)
    if S * T > _BLOCK_THRESHOLD and S > 1:
        return _blocked_sdpa(q, k, v, causal=causal, kv_len=kv_len)
    scale = D ** -0.5
    logits = jnp.einsum("bshd,bhtd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = None
    if causal and S > 1:
        qp = q_pos if q_pos is not None else jnp.arange(S)
        mask = qp[:, None] >= jnp.arange(T)[None, :]
    if kv_len is not None:
        lm = jnp.arange(T)[None, :] < kv_len
        mask = lm if mask is None else (mask & lm)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bhtd->bshd", w, v)
    return out


def _blocked_sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool, kv_len=None,
                  bq: int = _BQ, bk: int = _BK) -> jax.Array:
    """Unrolled flash-style attention: per (q-chunk, kv-block) online softmax.

    Unrolled (python loops, not lax.scan) so the dry-run's HLO cost analysis
    counts every block exactly once (DESIGN.md §6); causally-dead blocks are
    skipped at trace time.  Peak memory per step is O(bq*bk) scores instead
    of O(S*T).
    """
    B, S, H, D = q.shape
    T = k.shape[2]
    Dv = v.shape[-1]          # MLA: value dim != query/key dim
    scale = D ** -0.5
    bq = min(bq, S)
    bk = min(bk, T)
    outs = []
    for qi in range(0, S, bq):
        nq = min(bq, S - qi)
        qc = q[:, qi:qi + nq]                            # (B,nq,H,D)
        m = jnp.full((B, H, nq, 1), _NEG, jnp.float32)
        l = jnp.zeros((B, H, nq, 1), jnp.float32)
        acc = jnp.zeros((B, nq, H, Dv), jnp.float32)
        for ki in range(0, T, bk):
            if causal and ki > qi + nq - 1:
                continue                                  # dead block
            nk = min(bk, T - ki)
            kc = k[:, :, ki:ki + nk]
            vc = v[:, :, ki:ki + nk]
            s = jnp.einsum("bshd,bhtd->bhst", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = qi + jnp.arange(nq)
                kpos = ki + jnp.arange(nk)
                s = jnp.where(qpos[:, None] >= kpos[None, :], s, _NEG)
            if kv_len is not None:
                s = jnp.where((ki + jnp.arange(nk))[None, :] < kv_len, s,
                              _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.where(m_new <= _NEG / 2, 0.0, jnp.exp(s - m_new))
            alpha = jnp.exp(m - m_new)
            l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha.transpose(0, 2, 1, 3) + jnp.einsum(
                "bhst,bhtd->bshd", p.astype(v.dtype), vc,
                preferred_element_type=jnp.float32)
            m = m_new
        l = jnp.where(l == 0.0, 1.0, l)
        outs.append((acc / l.transpose(0, 2, 1, 3)).astype(q.dtype))
    return jnp.concatenate(outs, axis=1)


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B,KV,T,D) -> (B,H,T,D); XLA fuses the broadcast into the einsum."""
    B, KV, T, D = k.shape
    if KV == n_heads:
        return k
    g = n_heads // KV
    return jnp.repeat(k, g, axis=1)


def _out_proj(out2d: jax.Array, wo: jax.Array) -> jax.Array:
    """Attention output projection; int8-ring TP combine when enabled."""
    from .layers import _use_int8_ring, int8_ring_proj
    if _use_int8_ring():
        return int8_ring_proj(out2d, wo)
    return dense(out2d, wo)


# ============================================================== GQA forward
def _qkv(cfg, p, x):
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = dense(x, p["wq"])
    k = dense(x, p["wk"])
    v = dense(x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    B, S = x.shape[:2]
    q = shard(q.reshape(B, S, H, hd), "batch", "seq", "act_heads", None)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    return q, k, v


def attn_forward(cfg, p, x, positions, *, causal=True, rope=True,
                 return_kv=False, impl=None):
    """Full-sequence self attention (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _qkv(cfg, p, x)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kt = k.transpose(0, 2, 1, 3)   # (B,KV,T,D)
    vt = v.transpose(0, 2, 1, 3)
    out = _sdpa(q, _expand_kv(kt, cfg.n_heads), _expand_kv(vt, cfg.n_heads),
                causal=causal, q_pos=positions[0] if positions.ndim == 2 else positions,
                impl=impl or cfg.attn_impl)
    out = shard(out, "batch", "seq", "act_heads", None)
    y = _out_proj(out.reshape(B, S, -1), p["wo"])
    if return_kv:
        cax = "cache_seq_sp" if cfg.decode_attn == "sp" else None
        kax = None if cax else "kv_heads"
        kc = shard(k.reshape(B, S, -1), "batch", cax, kax)
        vc = shard(v.reshape(B, S, -1), "batch", cax, kax)
        return y, {"k": kc, "v": vc}
    return y


def attn_decode(cfg, p, x, pos, cache: Dict) -> Tuple[jax.Array, Dict]:
    """One-token decode. cache: {"k","v"}: (B, S_max, KV*hd); pos: scalar."""
    B, S, _ = x.shape
    assert S == 1
    hd, KV = cfg.resolved_head_dim, cfg.n_kv_heads
    q, k, v = _qkv(cfg, p, x)
    positions = jnp.full((1,), pos, jnp.int32)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.decode_attn == "sp" and current_mesh() is not None \
            and "model" in current_mesh().axis_names:
        # cache write happens inside the shard_map (a dynamic_update_slice
        # into the seq-sharded dim at the pjit level trips an XLA SPMD
        # internal check — §Perf A iteration log)
        out, kc, vc = _sp_flash_decode(cfg, q, cache["k"], cache["v"],
                                       k.reshape(B, 1, KV * hd),
                                       v.reshape(B, 1, KV * hd), pos)
        out = shard(out, "batch", "seq", "act_heads", None)
        y = _out_proj(out.reshape(B, 1, -1), p["wo"])
        return y, {"k": kc, "v": vc}
    kc = jax.lax.dynamic_update_slice(cache["k"], k.reshape(B, 1, KV * hd),
                                      (0, pos, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.reshape(B, 1, KV * hd),
                                      (0, pos, 0))
    kc = shard(kc, "batch", None, "kv_heads")
    vc = shard(vc, "batch", None, "kv_heads")
    if cfg.attn_impl == "pallas":
        T = kc.shape[1]
        k4 = kc.reshape(B, T, KV, hd).transpose(0, 2, 1, 3)
        v4 = vc.reshape(B, T, KV, hd).transpose(0, 2, 1, 3)
        from ..kernels.decode_attention import ops as da_ops
        out = da_ops.decode_attention(q[:, 0], k4, v4, kv_len=pos + 1)[:, None]
    else:
        T = kc.shape[1]
        k4 = kc.reshape(B, T, KV, hd).transpose(0, 2, 1, 3)
        v4 = vc.reshape(B, T, KV, hd).transpose(0, 2, 1, 3)
        out = _sdpa(q, _expand_kv(k4, cfg.n_heads), _expand_kv(v4, cfg.n_heads),
                    causal=False, kv_len=pos + 1)
    out = shard(out, "batch", "seq", "act_heads", None)
    y = _out_proj(out.reshape(B, 1, -1), p["wo"])
    return y, {"k": kc, "v": vc}


def _sp_flash_decode(cfg, q, kc, vc, k_new, v_new, pos):
    """Sequence-parallel flash-decode (cfg.decode_attn == "sp").

    Cache is sharded along the SEQUENCE dim over ``model``; each shard
    writes the new token into its own slice (if `pos` falls there) and
    computes complete attention scores for its slice (all heads local);
    shards combine with an online-softmax reduction: one pmax + two psums
    of (B, H)-sized stats/outputs per layer — replacing the baseline's
    per-layer all-gather of the whole KV cache (§Perf hillclimb A).
    shard_map is partial: only ``model`` is manual, batch stays auto.
    Global position ids enter pre-sharded (axis_index lowers to
    PartitionId, which GSPMD rejects in partial-manual regions).
    """
    from jax.sharding import PartitionSpec as P
    mesh = current_mesh()
    B, _, H, hd = q.shape
    KV = cfg.n_kv_heads
    T = kc.shape[1]
    tglob_full = jnp.arange(T, dtype=jnp.int32)

    def local(q_, k_, v_, kn, vn, tglob):
        Bl, Tl = k_.shape[0], k_.shape[1]   # LOCAL shapes (full-manual)
        t0 = tglob[0]
        # local cache write: only the owning shard lands the update
        idx = jnp.clip(pos - t0, 0, Tl - 1)
        k_upd = jax.lax.dynamic_update_slice(k_, kn, (0, idx, 0))
        v_upd = jax.lax.dynamic_update_slice(v_, vn, (0, idx, 0))
        mine = (pos >= t0) & (pos < t0 + Tl)
        k_ = jnp.where(mine, k_upd, k_)
        v_ = jnp.where(mine, v_upd, v_)
        k4 = k_.reshape(Bl, Tl, KV, hd).transpose(0, 2, 1, 3)
        v4 = v_.reshape(Bl, Tl, KV, hd).transpose(0, 2, 1, 3)
        k4 = _expand_kv(k4, H)
        v4 = _expand_kv(v4, H)
        s = jnp.einsum("bshd,bhtd->bhst", q_, k4,
                       preferred_element_type=jnp.float32) * (hd ** -0.5)
        s = jnp.where(tglob[None, None, None, :] < pos + 1, s, -1e30)
        m_loc = jnp.max(s, axis=-1, keepdims=True)            # (B,H,1,1)
        m = jax.lax.pmax(m_loc, "model")
        p_ = jnp.where(m <= -1e29, 0.0, jnp.exp(s - m))
        l = jax.lax.psum(jnp.sum(p_, -1, keepdims=True), "model")
        o = jnp.einsum("bhst,bhtd->bshd", p_.astype(v4.dtype), v4)
        o = jax.lax.psum(o, "model")
        l = jnp.where(l == 0.0, 1.0, l)
        out = (o / l.transpose(0, 2, 1, 3).astype(o.dtype)).astype(q_.dtype)
        return out, k_, v_

    # FULL-manual shard_map (all mesh axes): the partial-manual form trips
    # XLA SPMD internal checks at large host-device counts (§Perf A log).
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if batch_axes else None
    if q.shape[0] % max(
            int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                         for a in batch_axes])) if batch_axes else 1, 1):
        bspec = None  # batch=1 long-decode: keep batch replicated
    cspec = P(bspec, "model", None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec), cspec, cspec, P(bspec), P(bspec), P("model")),
        out_specs=(P(bspec), cspec, cspec),
    )(q, kc, vc, k_new, v_new, tglob_full)


def kv_cache_specs(cfg, batch: int, max_len: int) -> Dict:
    import jax.numpy as _jnp
    hd, KV = cfg.resolved_head_dim, cfg.n_kv_heads
    if cfg.decode_attn == "sp":
        ax = ("batch", "cache_seq_sp", None)
    else:
        ax = ("batch", None, "kv_heads")
    dt = _jnp.dtype(cfg.dtype)
    return {
        "k": spec((batch, max_len, KV * hd), ax, dtype=dt, init="zeros"),
        "v": spec((batch, max_len, KV * hd), ax, dtype=dt, init="zeros"),
    }


# ============================================================== cross attention
def cross_attn_forward(cfg, p, x, kv_x=None, kv_cache: Optional[Dict] = None):
    """Cross attention; pass kv_x once (prefill) or a precomputed kv_cache
    stored flat as (B, T, KV*hd)."""
    B, S, _ = x.shape
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = dense(x, p["wq"]).reshape(B, S, H, hd)
    q = shard(q, "batch", "seq", "act_heads", None)
    if kv_cache is None:
        kv_cache = {
            "k": shard(dense(kv_x, p["wk"]), "batch", None, "kv_heads"),
            "v": shard(dense(kv_x, p["wv"]), "batch", None, "kv_heads"),
        }
    T = kv_cache["k"].shape[1]
    k4 = kv_cache["k"].reshape(B, T, KV, hd).transpose(0, 2, 1, 3)
    v4 = kv_cache["v"].reshape(B, T, KV, hd).transpose(0, 2, 1, 3)
    out = _sdpa(q, _expand_kv(k4, H), _expand_kv(v4, H), causal=False)
    out = shard(out, "batch", "seq", "act_heads", None)
    return dense(out.reshape(B, S, -1), p["wo"]), kv_cache


# ============================================================== MLA (deepseek)
def _mla_q(cfg, p, x, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    qn, qr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = dense(x, p["wq"]).reshape(B, S, H, qn + qr)
    q_nope, q_pe = q[..., :qn], q[..., qn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def _mla_latent(cfg, p, x, positions):
    from .layers import rmsnorm
    r, qr = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv_a = dense(x, p["wkv_a"])                    # (B,S,r+qr)
    c_kv = rmsnorm(kv_a[..., :r], p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope(kv_a[..., r:], positions, cfg.rope_theta)  # (B,S,qr)
    return c_kv, k_pe


def mla_forward(cfg, p, x, positions, *, causal=True, return_kv=False):
    """Training/prefill MLA: decompress K/V per head (naive form)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    qn, vd, r = cfg.qk_nope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    q_nope, q_pe = _mla_q(cfg, p, x, positions)
    c_kv, k_pe = _mla_latent(cfg, p, x, positions)
    k_nope = dense(c_kv, p["wk_b"]).reshape(B, S, H, qn)
    v = dense(c_kv, p["wv_b"]).reshape(B, S, H, vd)
    q = jnp.concatenate([q_nope, q_pe], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe[:, :, None, :],
                                                  (B, S, H, cfg.qk_rope_dim))], -1)
    q = shard(q, "batch", "seq", "act_heads", None)
    k = shard(k, "batch", "seq", "act_heads", None)
    out = _sdpa(q, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                causal=causal,
                q_pos=positions[0] if positions.ndim == 2 else positions,
                impl=cfg.attn_impl)
    out = shard(out, "batch", "seq", "act_heads", None)
    y = dense(out.reshape(B, S, -1), p["wo"])
    if return_kv:
        return y, {"c_kv": shard(c_kv, "batch", None, None),
                   "k_pe": shard(k_pe, "batch", None, None)}
    return y


def mla_decode(cfg, p, x, pos, cache: Dict) -> Tuple[jax.Array, Dict]:
    """Absorbed-form MLA decode: attention in the compressed latent space."""
    B, S, _ = x.shape
    assert S == 1
    H = cfg.n_heads
    qn, qr, vd, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    positions = jnp.full((1,), pos, jnp.int32)
    q_nope, q_pe = _mla_q(cfg, p, x, positions)          # (B,1,H,qn),(B,1,H,qr)
    c_new, kpe_new = _mla_latent(cfg, p, x, positions)   # (B,1,r),(B,1,qr)
    ckv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, pos, 0))
    kpe = jax.lax.dynamic_update_slice(cache["k_pe"], kpe_new, (0, pos, 0))
    # absorb W_kb into q: q_lat (B,1,H,r)
    wk_b = p["wk_b"].reshape(r, H, qn)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wk_b)
    logits = (jnp.einsum("bshr,btr->bhst", q_lat, ckv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshr,btr->bhst", q_pe, kpe,
                           preferred_element_type=jnp.float32))
    logits = logits * ((qn + qr) ** -0.5)
    kv_len = pos + 1
    mask = jnp.arange(ckv.shape[1])[None, :] < kv_len
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhst,btr->bshr", w, ckv)            # (B,1,H,r)
    wv_b = p["wv_b"].reshape(r, H, vd)
    out = jnp.einsum("bshr,rhv->bshv", ctx, wv_b)
    y = dense(out.reshape(B, 1, -1), p["wo"])
    return y, {"c_kv": ckv, "k_pe": kpe}


def mla_cache_specs(cfg, batch: int, max_len: int) -> Dict:
    import jax.numpy as _jnp
    dt = _jnp.dtype(cfg.dtype)
    return {
        "c_kv": spec((batch, max_len, cfg.kv_lora_rank), ("batch", None, None),
                     dtype=dt, init="zeros"),
        "k_pe": spec((batch, max_len, cfg.qk_rope_dim), ("batch", None, None),
                     dtype=dt, init="zeros"),
    }
