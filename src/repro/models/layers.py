"""Shared building blocks: RMSNorm, RoPE, SwiGLU MLP, embeddings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .sharding import ParamSpec, shard, spec


# ------------------------------------------------------------------- norms
def rmsnorm_spec(d: int, layers: Optional[int] = None) -> ParamSpec:
    if layers is None:
        return spec((d,), ("d_model",), init="ones")
    return spec((layers, d), ("layers", "d_model"), init="ones")


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """fp32 variance accumulation, but the full-size tensor math stays in
    the input dtype — an fp32 upcast of the (B, S, d) stream would double
    the dominant activation buffers and drag the TP all-reduces to fp32."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return (x * inv) * w.astype(x.dtype)


# -------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) or (B, S, D); positions: (S,)."""
    dt = x.dtype
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                            # (D/2,)
    ang = positions[:, None].astype(jnp.float32) * freqs    # (S, D/2)
    if x.ndim == 4:
        ang = ang[None, :, None, :]
    else:
        ang = ang[None, :, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# ------------------------------------------------------------------- linear
def linear_spec(d_in: int, d_out: int, axes=("d_model", "ff"),
                layers: Optional[int] = None, **kw) -> ParamSpec:
    if layers is None:
        return spec((d_in, d_out), axes, **kw)
    return spec((layers, d_in, d_out), ("layers",) + tuple(axes), **kw)


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("...d,df->...f", x, w)


# -------------------------------------------------------------------- mlp
def mlp_specs(d: int, ff: int, layers: Optional[int] = None) -> dict:
    return {
        "wg": linear_spec(d, ff, ("d_model", "ff"), layers),
        "wu": linear_spec(d, ff, ("d_model", "ff"), layers),
        "wd": linear_spec(ff, d, ("ff", "d_model"), layers),
    }


def int8_ring_proj(h: jax.Array, w: jax.Array) -> jax.Array:
    """Row-parallel projection whose TP combine runs as an int8 ring
    all-reduce (inference-only §Perf variant, cfg.tp_collective="int8_ring"):
    each model-shard computes its partial (B, S, d) product and the partials
    are summed with int8+scale chunks on the wire — ~2x less collective
    traffic than the bf16 all-reduce that dominates prefill cells.

    h: (..., F) sharded on F over `model`; w: (F, d) sharded on F.
    """
    from jax.sharding import PartitionSpec as P
    from ..train.compression import ring_allreduce_int8
    from .sharding import axis_size, current_mesh
    mesh = current_mesh()
    ranks = jnp.arange(axis_size("model"), dtype=jnp.int32)

    def local(h_, w_, r_):
        part = jnp.einsum("...f,fd->...d", h_, w_)
        return ring_allreduce_int8(part, "model", rank=r_[0])

    hspec = P(*((None,) * (h.ndim - 1) + ("model",)))
    from ..compat import shard_map
    return shard_map(local, mesh=mesh,
                     in_specs=(hspec, P("model", None), P("model")),
                     out_specs=P(*((None,) * h.ndim)),
                     axis_names={"model"})(h, w, ranks)


def _use_int8_ring() -> bool:
    from .sharding import current_mesh, rule_flag
    m = current_mesh()
    return bool(rule_flag("__tp_int8__")) and m is not None \
        and "model" in m.axis_names


def mlp(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(dense(x, p["wg"])) * dense(x, p["wu"])
    h = shard(h, "batch", "seq", "act_ff")
    if _use_int8_ring():
        return int8_ring_proj(h, p["wd"])
    return dense(h, p["wd"])


# -------------------------------------------------------------- embeddings
VOCAB_PAD = 16   # embedding tables pad to a multiple of the model axis


def padded_vocab(vocab: int) -> int:
    return ((vocab + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


def embed_spec(vocab: int, d: int) -> ParamSpec:
    """Table padded so the vocab dim always shards evenly on `model`
    (granite 49155 / mamba 50280 / seamless 256206 are not 16-divisible);
    pad rows are masked out of the logits in :func:`unembed`."""
    return spec((padded_vocab(vocab), d), ("vocab", "d_model"), scale=1.0)


def embed(w: jax.Array, tokens: jax.Array) -> jax.Array:
    out = jnp.take(w, tokens, axis=0)
    return shard(out, "batch", "seq", None)


def unembed(w: jax.Array, x: jax.Array, vocab: Optional[int] = None
            ) -> jax.Array:
    """x @ w.T -> logits (sharded on vocab); pad slots masked to -inf."""
    logits = jnp.einsum("...d,vd->...v", x, w)
    V_pad = w.shape[0]
    if vocab is not None and vocab != V_pad:
        ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(ids < vocab, logits,
                           jnp.asarray(-1e30, logits.dtype))
    return shard(logits, "batch", "seq", "act_vocab")


# ---------------------------------------------------------------- softmax xent
def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Token-mean cross entropy, fp32 accumulation, vocab-sharded safe.

    The label pick uses an iota-mask + masked reduce instead of
    ``take_along_axis``: a gather over the vocab-sharded axis would force
    GSPMD to all-gather the full logits; the mask+reduce stays elementwise
    (fused) and reduces with a cheap psum.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                         logits.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_ids == labels[..., None], logits, 0.0),
                 axis=-1)
    return jnp.mean(lse - ll)
