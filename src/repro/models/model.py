"""Uniform model API over all families.

``build(cfg)`` returns a :class:`Model` exposing

* ``param_specs``               — ParamSpec tree (shapes + logical axes)
* ``init(key)``                 — random params
* ``loss_fn(params, batch, key)``        — training loss (scalar)
* ``prefill(params, batch)``    — (last-logits, cache)
* ``decode(params, cache, tokens, pos)`` — one serve step
* ``cache_specs(batch, max_len)``        — decode-cache ParamSpec tree
* ``input_specs(shape)``        — dry-run input ParamSpec dict per ShapeConfig

Inputs/caches are ParamSpec trees too, so the dry-run derives
ShapeDtypeStructs + NamedShardings from one source of truth
(``sharding.shape_tree`` / ``sharding.sharding_tree``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import encdec as E
from . import hybrid as H
from . import ssm as S
from . import transformer as T
from . import vla as V
from . import vlm as VL
from .sharding import init_params, spec

Tree = Any


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    param_specs: Tree
    loss_fn: Callable
    prefill: Callable
    decode: Callable
    cache_specs: Callable
    input_specs: Callable
    forward: Optional[Callable] = None   # VLA: action inference

    def init(self, key: jax.Array) -> Tree:
        return init_params(self.param_specs, key)


def _tok_specs(shape: ShapeConfig, with_labels: bool) -> Dict:
    B, Ssz = shape.global_batch, shape.seq_len
    out = {"tokens": spec((B, Ssz), ("batch", "seq"), dtype=jnp.int32,
                          init="zeros")}
    if with_labels:
        out["labels"] = spec((B, Ssz), ("batch", "seq"), dtype=jnp.int32,
                             init="zeros")
    return out


def build(cfg: ModelConfig) -> Model:
    fam = cfg.family

    # ---------------------------------------------------------------- dense/moe
    if fam in ("dense", "moe"):
        def loss_fn(params, batch, key=None):
            return T.lm_loss(cfg, params, batch["tokens"], batch["labels"])

        def prefill(params, batch):
            return T.lm_prefill(cfg, params, batch["tokens"])

        def decode(params, cache, tokens, pos):
            return T.lm_decode(cfg, params, cache, tokens, pos)

        def cache_specs(batch, max_len, **_):
            return T.lm_cache_specs(cfg, batch, max_len)

        def input_specs(shape: ShapeConfig):
            if shape.kind == "train":
                return _tok_specs(shape, True)
            if shape.kind == "prefill":
                return _tok_specs(shape, False)
            return {"tokens": spec((shape.global_batch, 1), ("batch", "seq"),
                                   dtype=jnp.int32, init="zeros")}

        return Model(cfg, T.lm_specs(cfg), loss_fn, prefill, decode,
                     cache_specs, input_specs)

    # ---------------------------------------------------------------- ssm
    if fam == "ssm":
        def loss_fn(params, batch, key=None):
            return S.ssm_lm_loss(cfg, params, batch["tokens"], batch["labels"])

        def prefill(params, batch):
            return S.ssm_lm_prefill(cfg, params, batch["tokens"])

        def decode(params, cache, tokens, pos):
            return S.ssm_lm_decode(cfg, params, cache, tokens, pos)

        def cache_specs(batch, max_len=0, **_):
            return S.ssm_lm_cache_specs(cfg, batch)

        def input_specs(shape: ShapeConfig):
            if shape.kind == "train":
                return _tok_specs(shape, True)
            if shape.kind == "prefill":
                return _tok_specs(shape, False)
            return {"tokens": spec((shape.global_batch, 1), ("batch", "seq"),
                                   dtype=jnp.int32, init="zeros")}

        return Model(cfg, S.ssm_lm_specs(cfg), loss_fn, prefill, decode,
                     cache_specs, input_specs)

    # ---------------------------------------------------------------- hybrid
    if fam == "hybrid":
        def loss_fn(params, batch, key=None):
            return H.hybrid_loss(cfg, params, batch["tokens"], batch["labels"])

        def prefill(params, batch):
            return H.hybrid_prefill(cfg, params, batch["tokens"])

        def decode(params, cache, tokens, pos):
            return H.hybrid_decode(cfg, params, cache, tokens, pos)

        def cache_specs(batch, max_len, **_):
            return H.hybrid_cache_specs(cfg, batch, max_len)

        def input_specs(shape: ShapeConfig):
            if shape.kind == "train":
                return _tok_specs(shape, True)
            if shape.kind == "prefill":
                return _tok_specs(shape, False)
            return {"tokens": spec((shape.global_batch, 1), ("batch", "seq"),
                                   dtype=jnp.int32, init="zeros")}

        return Model(cfg, H.hybrid_specs(cfg), loss_fn, prefill, decode,
                     cache_specs, input_specs)

    # ---------------------------------------------------------------- audio
    if fam == "audio":
        def loss_fn(params, batch, key=None):
            return E.encdec_loss(cfg, params, batch["frames"],
                                 batch["tokens"], batch["labels"])

        def prefill(params, batch):
            return E.encdec_prefill(cfg, params, batch["frames"],
                                    batch["tokens"])

        def decode(params, cache, tokens, pos):
            return E.encdec_decode(cfg, params, cache, tokens, pos)

        def cache_specs(batch, max_len, src_len=None, **_):
            return E.encdec_cache_specs(cfg, batch, max_len,
                                        src_len or max_len)

        def input_specs(shape: ShapeConfig):
            B, Ssz = shape.global_batch, shape.seq_len
            frames = spec((B, Ssz, cfg.d_model), ("batch", "seq", None),
                          init="zeros")
            if shape.kind == "train":
                return {"frames": frames, **_tok_specs(shape, True)}
            if shape.kind == "prefill":
                # encode S_src frames + BOS teacher-forcing token
                return {"frames": frames,
                        "tokens": spec((B, 1), ("batch", "seq"),
                                       dtype=jnp.int32, init="zeros")}
            return {"tokens": spec((B, 1), ("batch", "seq"),
                                   dtype=jnp.int32, init="zeros")}

        return Model(cfg, E.encdec_specs(cfg), loss_fn, prefill, decode,
                     cache_specs, input_specs)

    # ---------------------------------------------------------------- vlm
    if fam == "vlm":
        def loss_fn(params, batch, key=None):
            return VL.vlm_loss(cfg, params, batch["tokens"], batch["vision"],
                               batch["labels"])

        def prefill(params, batch):
            return VL.vlm_prefill(cfg, params, batch["tokens"],
                                  batch["vision"])

        def decode(params, cache, tokens, pos):
            return VL.vlm_decode(cfg, params, cache, tokens, pos)

        def cache_specs(batch, max_len, **_):
            return VL.vlm_cache_specs(cfg, batch, max_len)

        def input_specs(shape: ShapeConfig):
            B = shape.global_batch
            vis = spec((B, cfg.n_vision_tokens, cfg.d_model),
                       ("batch", None, None), init="zeros")
            if shape.kind == "train":
                return {"vision": vis, **_tok_specs(shape, True)}
            if shape.kind == "prefill":
                return {"vision": vis, **_tok_specs(shape, False)}
            return {"tokens": spec((B, 1), ("batch", "seq"),
                                   dtype=jnp.int32, init="zeros")}

        return Model(cfg, VL.vlm_specs(cfg), loss_fn, prefill, decode,
                     cache_specs, input_specs)

    # ---------------------------------------------------------------- vla
    if fam == "vla":
        def loss_fn(params, batch, key):
            return V.vla_loss(cfg, params, batch["patches"], batch["tokens"],
                              batch["actions"], key)

        def forward(params, batch, key=None):
            return V.vla_forward(cfg, params, batch["patches"],
                                 batch["tokens"], key)

        def prefill(params, batch):
            raise NotImplementedError("VLA serves whole requests; use forward")

        def decode(params, cache, tokens, pos):
            raise NotImplementedError("VLA serves whole requests; use forward")

        def cache_specs(batch, max_len, **_):
            return {}

        def input_specs(shape: ShapeConfig):
            B = shape.global_batch
            out = {
                "patches": spec((B, cfg.n_patches, cfg.vit_dim),
                                ("batch", None, None), init="zeros"),
                "tokens": spec((B, 64), ("batch", "seq"), dtype=jnp.int32,
                               init="zeros"),
            }
            if shape.kind == "train":
                out["actions"] = spec(
                    (B, cfg.action_horizon, cfg.action_dim),
                    ("batch", None, None), dtype=jnp.float32, init="zeros")
            return out

        return Model(cfg, V.vla_specs(cfg), loss_fn, prefill, decode,
                     cache_specs, input_specs, forward=forward)

    raise ValueError(f"unknown family {fam!r}")
