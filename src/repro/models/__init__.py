"""Model zoo substrate: layers, attention, MoE, SSM, assemblies per family."""
from .model import Model, build
from .sharding import (ParamSpec, init_params, make_rules, shape_tree,
                       sharding_tree, shard, spec, use_mesh)

__all__ = ["Model", "build", "ParamSpec", "init_params", "make_rules",
           "shape_tree", "sharding_tree", "shard", "spec", "use_mesh"]
