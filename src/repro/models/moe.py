"""Mixture-of-Experts FFN with expert parallelism.

Design (DESIGN.md §5): experts shard on the ``model`` mesh axis.  Inside a
``shard_map`` region each model-shard owns ``E/model`` experts; tokens are
replicated across ``model`` (they arrive that way from the attention block),
so dispatch is **local gather -> batched expert matmul -> local scatter-add**,
and the only collective is one ``psum`` over ``model`` to combine expert
contributions — the same wire cost as a dense TP FFN's all-reduce.  This is
the TPU-native analogue of DeepSeek-style EP all-to-all dispatch: because
activations are model-replicated under our 2D (data, model) layout, the
all-to-all degenerates into the combine-psum, avoiding the classic GShard
one-hot dispatch einsums (which would cost more FLOPs than the experts
themselves at these expert counts).

Capacity-and-drop semantics follow GShard: per-expert capacity
``C = ceil(T * top_k / E * capacity_factor)``; overflow tokens are dropped
(contribute zero for that expert slot).  A load-balancing auxiliary loss is
returned alongside the output.

When no mesh is installed (CPU tests) the same local routine runs with all
experts on one shard, so numerics are identical modulo capacity.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

from .layers import linear_spec
from .sharding import current_mesh, shard, spec


def _pad_experts(n_experts: int, shards: int) -> int:
    return int(math.ceil(n_experts / shards) * shards)


def padded_expert_count(E: int, max_shards: int = 16) -> int:
    """Expert-table leading dim: jit in_shardings require even divisibility
    on the `model` axis (16), so 40 experts (granite) pad to 48.  Counts
    that already divide 16 — or that 16 divides — stay unchanged (keeps
    reduced test configs small)."""
    if E % max_shards == 0 or max_shards % E == 0:
        return E
    return _pad_experts(E, max_shards)


def moe_specs(cfg, layers: Optional[int] = None) -> Dict:
    d, fe = cfg.d_model, cfg.moe_d_ff
    E = padded_expert_count(cfg.n_experts)
    L = () if layers is None else (layers,)
    lax = () if layers is None else ("layers",)
    out = {
        "router": spec(L + (d, cfg.n_experts), lax + ("d_model", None),
                       scale=0.02),
        "wg": spec(L + (E, d, fe), lax + ("experts", "d_model", "moe_ff")),
        "wu": spec(L + (E, d, fe), lax + ("experts", "d_model", "moe_ff")),
        "wd": spec(L + (E, fe, d), lax + ("experts", "moe_ff", "d_model")),
    }
    if cfg.n_shared_experts:
        fs = fe * cfg.n_shared_experts
        out["shared"] = {
            "wg": linear_spec(d, fs, ("d_model", "ff"), layers),
            "wu": linear_spec(d, fs, ("d_model", "ff"), layers),
            "wd": linear_spec(fs, d, ("ff", "d_model"), layers),
        }
    return out


def capacity(tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(math.ceil(tokens * top_k / n_experts * factor))
    return max(4, ((c + 3) // 4) * 4)


def _route(x2d: jax.Array, router: jax.Array, top_k: int):
    """x2d: (T, d). Returns (gates (T,k) f32, eids (T,k) i32, aux_loss)."""
    logits = jnp.einsum("td,de->te", x2d, router,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, top_k)
    gates = gates / (jnp.sum(gates, -1, keepdims=True) + 1e-9)
    # GShard aux loss: E * sum_e(frac_tokens_e * mean_prob_e)
    E = probs.shape[-1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(eids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return gates, eids, aux


def _local_expert_ffn(x2d, gates, eids, wg, wu, wd, *, E, e0, C):
    """Gather->FFN->scatter for the E_loc experts [e0, e0+E_loc) on this shard.

    x2d: (T, d); gates/eids: (T, k); wg/wu: (E_loc, d, f); wd: (E_loc, f, d).
    Returns partial output (T, d) covering only local experts.
    """
    T, d = x2d.shape
    k = eids.shape[1]
    E_loc = wg.shape[0]
    # position of each (token, choice) in its expert's queue
    onehot = jax.nn.one_hot(eids, E, dtype=jnp.int32).sum(1)      # (T, E)
    pos_all = jnp.cumsum(onehot, axis=0) - onehot                  # (T, E)
    pos = jnp.take_along_axis(pos_all, eids, axis=1)               # (T, k)
    local = (eids >= e0) & (eids < e0 + E_loc) & (pos < C)
    slot = jnp.where(local, (eids - e0) * C + pos, E_loc * C)      # sentinel
    tok = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None], (T, k))
    idx = jnp.full((E_loc * C + 1,), T, jnp.int32)
    idx = idx.at[slot.reshape(-1)].set(tok.reshape(-1), mode="drop")
    idx = idx[: E_loc * C]                                          # (E_loc*C,)
    xpad = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], 0)
    buf = xpad[idx].reshape(E_loc, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) \
        * jnp.einsum("ecd,edf->ecf", buf, wu)
    out = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E_loc * C, d)
    gbuf = jnp.zeros((E_loc * C + 1,), jnp.float32)
    gbuf = gbuf.at[slot.reshape(-1)].set(gates.reshape(-1).astype(jnp.float32),
                                         mode="drop")[: E_loc * C]
    contrib = out * gbuf[:, None].astype(out.dtype)
    y = jnp.zeros((T + 1, d), x2d.dtype).at[idx].add(contrib, mode="drop")
    return y[:T]


def moe_ffn(cfg, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    mesh = current_mesh()
    shards, data_shards = 1, 1
    batch_axes = ()
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        shards = sizes.get("model", 1)
        from .sharding import resolve
        batch_rule = resolve(("batch",))[0]
        if batch_rule is not None:
            batch_axes = (batch_rule,) if isinstance(batch_rule, str) else tuple(batch_rule)
            for a in batch_axes:
                data_shards *= sizes.get(a, 1)
    wg, wu, wd = p["wg"], p["wu"], p["wd"]
    E_tbl = wg.shape[0]           # spec-level padded expert count
    if E_tbl % shards:            # runtime fallback for odd test meshes
        padn = _pad_experts(E_tbl, shards) - E_tbl
        wg = jnp.concatenate([wg, jnp.zeros((padn,) + wg.shape[1:], wg.dtype)], 0)
        wu = jnp.concatenate([wu, jnp.zeros((padn,) + wu.shape[1:], wu.dtype)], 0)
        wd = jnp.concatenate([wd, jnp.zeros((padn,) + wd.shape[1:], wd.dtype)], 0)
    E_pad = wg.shape[0]
    E_loc = E_pad // shards
    x2d = x.reshape(B * S, d)
    gates, eids, aux = _route(x2d, p["router"], k)
    # capacity is per *local* token block: tokens stay data-sharded in the
    # shard_map region, replicated only across `model`.
    C = capacity(B * S // data_shards, E, k, cfg.moe_capacity_factor)

    if mesh is None or shards == 1:
        y = _local_expert_ffn(x2d, gates, eids, wg, wu, wd, E=E, e0=0, C=C)
    else:
        def shard_fn(x2d_, gates_, eids_, wg_, wu_, wd_):
            midx = jax.lax.axis_index("model")
            y_ = _local_expert_ffn(x2d_, gates_, eids_, wg_, wu_, wd_,
                                   E=E, e0=midx * E_loc, C=C)
            return jax.lax.psum(y_, "model")

        # tokens: sharded over the batch axes, replicated over `model`
        tok_spec = P(batch_axes if batch_axes else None, None)
        y = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(tok_spec, tok_spec, tok_spec,
                      P("model"), P("model"), P("model")),
            out_specs=tok_spec,
        )(x2d, gates, eids, wg, wu, wd)

    y = y.reshape(B, S, d)
    if cfg.n_shared_experts:
        from .layers import mlp
        y = y + mlp(p["shared"], x)
    return shard(y, "batch", "seq", None), aux
