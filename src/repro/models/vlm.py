"""VLM backbone (llama-3.2-vision-11b): decoder LM + gated cross-attn layers.

Every ``cfg.cross_attn_every``-th layer is followed by a gated cross-attention
sublayer (tanh-gated attn + tanh-gated MLP) over precomputed vision-patch
embeddings ``(B, n_vision_tokens, d_model)`` (modality frontend is a STUB per
the assignment).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import attention as A
from .layers import embed, embed_spec, mlp, mlp_specs, rmsnorm, rmsnorm_spec, \
    softmax_xent, unembed
from .sharding import spec
from .transformer import (block_decode, block_forward, dense_block_specs,
                          run_stack, run_stack_decode, _layer_slice,
                          lm_cache_specs)


def _n_cross(cfg) -> int:
    return cfg.n_layers // cfg.cross_attn_every


def cross_block_specs(cfg, layers):
    d = cfg.d_model
    return {
        "ln1": rmsnorm_spec(d, layers),
        "attn": A.attn_specs(cfg, layers, cross=True),
        "gate_attn": spec((layers, 1), ("layers", None), init="zeros"),
        "ln2": rmsnorm_spec(d, layers),
        "mlp": mlp_specs(d, cfg.d_ff, layers),
        "gate_mlp": spec((layers, 1), ("layers", None), init="zeros"),
    }


def vlm_specs(cfg) -> Dict:
    s = {
        "embed": embed_spec(cfg.vocab_size, cfg.d_model),
        "blocks": dense_block_specs(cfg, cfg.n_layers),
        "cross_blocks": cross_block_specs(cfg, _n_cross(cfg)),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        s["head"] = embed_spec(cfg.vocab_size, cfg.d_model)
    return s


def _cross_layer(cfg, pl, x, vision=None, kv_cache=None, return_kv=False):
    h = rmsnorm(x, pl["ln1"], cfg.norm_eps)
    a, ckv = A.cross_attn_forward(cfg, pl["attn"], h, kv_x=vision,
                                  kv_cache=kv_cache)
    x = x + jnp.tanh(pl["gate_attn"].astype(jnp.float32)).astype(x.dtype) * a
    m = mlp(pl["mlp"], rmsnorm(x, pl["ln2"], cfg.norm_eps))
    x = x + jnp.tanh(pl["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * m
    return (x, ckv) if return_kv else x


def _hidden(cfg, params, tokens, vision, *, remat, collect_caches=False):
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    vision = vision.astype(x.dtype)
    positions = jnp.arange(tokens.shape[1])
    k = cfg.cross_attn_every
    self_caches, cross_caches = [], []
    for g in range(_n_cross(cfg)):
        grp = jax.tree_util.tree_map(lambda w: w[g * k:(g + 1) * k],
                                     params["blocks"])

        def one(pl, h):
            h, kv, a = block_forward(cfg, pl, h, positions, is_moe=False,
                                     return_kv=collect_caches)
            return h, kv, a

        x, kv, _ = run_stack(cfg, grp, x, one, k, remat=remat,
                             collect=collect_caches)
        pl_cross = _layer_slice(params["cross_blocks"], g)
        if collect_caches:
            self_caches.append(kv)
            x, ckv = _cross_layer(cfg, pl_cross, x, vision=vision,
                                  return_kv=True)
            cross_caches.append(ckv)
        else:
            x = _cross_layer(cfg, pl_cross, x, vision=vision)
    if collect_caches:
        self_kv = jax.tree_util.tree_map(lambda *l: jnp.concatenate(l),
                                         *self_caches)
        cross_kv = jax.tree_util.tree_map(lambda *l: jnp.stack(l),
                                          *cross_caches)
        return x, {"self": self_kv, "cross": cross_kv}
    return x


def vlm_loss(cfg, params, tokens, vision, labels) -> jax.Array:
    x = _hidden(cfg, params, tokens, vision, remat=cfg.remat)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return softmax_xent(unembed(w, x, cfg.vocab_size), labels)


def vlm_prefill(cfg, params, tokens, vision):
    x, caches = _hidden(cfg, params, tokens, vision, remat=False,
                        collect_caches=True)
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(w, x, cfg.vocab_size), caches


def vlm_decode(cfg, params, caches, tokens, pos):
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    k = cfg.cross_attn_every
    caches = dict(caches)

    def dec(pl, h, c):
        return block_decode(cfg, pl, h, pos, c, is_moe=False)

    for g in range(_n_cross(cfg)):
        grp = jax.tree_util.tree_map(lambda w: w[g * k:(g + 1) * k],
                                     params["blocks"])
        cgrp = jax.tree_util.tree_map(lambda w: w[g * k:(g + 1) * k],
                                      caches["self"])
        x, nc = run_stack_decode(cfg, grp, cgrp, x, dec, k)
        caches["self"] = jax.tree_util.tree_map(
            lambda full, new, _g=g: jax.lax.dynamic_update_slice(
                full, new, (_g * k,) + (0,) * (full.ndim - 1)),
            caches["self"], nc)
        pl_cross = _layer_slice(params["cross_blocks"], g)
        ckv = _layer_slice(caches["cross"], g)
        x = _cross_layer(cfg, pl_cross, x, kv_cache=ckv)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(w, x, cfg.vocab_size), caches


def vlm_cache_specs(cfg, batch: int, max_len: int) -> Dict:
    self_kv = lm_cache_specs(cfg, batch, max_len)["blocks"]
    n_cross = _n_cross(cfg)
    per = A.kv_cache_specs(cfg, batch, cfg.n_vision_tokens)
    cross = jax.tree_util.tree_map(
        lambda s: spec((n_cross,) + s.shape, ("layers",) + s.axes,
                       dtype=s.dtype, init="zeros"),
        per, is_leaf=lambda v: hasattr(v, "axes"))
    return {"self": self_kv, "cross": cross}
