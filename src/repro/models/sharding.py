"""Logical-axis sharding: ParamSpec trees, rule resolution, activation constraints.

Every parameter is declared once as a :class:`ParamSpec` carrying *logical*
axis names.  At launch time the rules map logical axes -> mesh axes
(``make_rules``), which gives us — without allocating anything —

* ``jax.ShapeDtypeStruct`` trees for ``.lower()`` (dry-run),
* ``NamedSharding`` trees for ``in_shardings``,
* random-init trees for tests/examples.

Activation shardings inside model code go through :func:`shard`, which is a
no-op unless a mesh context has been installed via :func:`use_mesh` — so the
same model code runs on 1 CPU device and on the 512-device production mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Tree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis names
    dtype: Any = jnp.bfloat16
    init: str = "normal"                     # normal | zeros | ones
    scale: Optional[float] = None            # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape, axes, dtype=jnp.bfloat16, init="normal", scale=None) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), dtype, init, scale)


# --------------------------------------------------------------------------- rules
def make_rules(cfg, mesh: Optional[Mesh], shape_kind: str = "train",
               strategy: str = "tp") -> Dict[str, Any]:
    """Resolve logical-axis -> mesh-axis rules for a (config, mesh, shape) cell.

    Strategies:
      * ``tp`` (baseline, paper-faithful to a Megatron-style deployment):
        weights shard their big output dim over ``model``; activations are
        model-replicated between blocks (2 all-reduces per layer).
      * ``fsdp`` (§Perf hillclimb for small-model training): weights shard
        over ``(data, model)`` jointly (ZeRO-3); activations shard over
        batch only — GSPMD turns the per-layer collectives into parameter
        all-gathers + gradient reduce-scatters, removing the O(activations)
        all-reduce wire.
      * ``batch`` shards on ``(pod, data)`` except for ``long_decode``
        (global_batch=1) where it stays replicated.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else {}
    batch_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    batch_rule = None if shape_kind == "long_decode" else (batch_axes or None)

    if strategy == "fsdp":
        w = ("data", "model") if "data" in axis_sizes else ("model",)
        # true FSDP: data-parallel over EVERY chip; params sharded over all
        fsdp_batch = tuple(a for a in ("pod", "data", "model")
                           if a in axis_sizes) or None
        batch_rule = None if shape_kind == "long_decode" else fsdp_batch
        return {
            "d_model": None, "vocab": w, "q_heads": w, "kv_heads": w,
            "head_dim": None, "ff": w, "experts": w, "moe_ff": None,
            "inner": w, "state": None, "lora": None, "layers": None,
            "dit": None, "vit_ff": w, "vit_heads": w,
            "batch": batch_rule, "seq": None,
            "act_heads": None, "act_kv_heads": None, "act_ff": None,
            "act_inner": None, "act_vocab": None, "act_experts": None,
            "cache_kv_heads": None, "cache_seq": None, "cache_seq_sp": None,
            None: None,
        }

    rules: Dict[str, Any] = {
        # weights
        "d_model": None,
        "vocab": "model",
        "q_heads": "model",          # flattened H*hd dim — always divisible
        "kv_heads": "model",         # flattened KV*hd dim — always divisible
        "head_dim": None,
        "ff": "model",
        "experts": "model",
        "moe_ff": None,
        "inner": "model",            # mamba2 d_inner / ssm heads
        "state": None,
        "lora": None,
        "layers": None,              # stacked-layer leading dim
        "dit": None,
        "vit_ff": "model",
        "vit_heads": "model",
        # activations (KV head tensors left to propagation: small KV-head
        # counts shard unevenly; XLA pads/partially-replicates better than a
        # forced constraint — see EXPERIMENTS.md §Perf iteration log)
        "batch": batch_rule,
        "seq": None,
        "act_heads": "model",
        "act_kv_heads": None,
        "act_ff": "model",
        "act_inner": "model",
        "act_vocab": "model",
        "act_experts": "model",
        # decode caches: shard KV-head dim (uneven counts get padded)
        "cache_kv_heads": "model",
        "cache_seq": None,
        # sequence-parallel flash-decode cache (cfg.decode_attn == "sp")
        "cache_seq_sp": "model",
        None: None,
    }
    return rules


# ---------------------------------------------------------------- mesh context
class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, Any]] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, Any]]):
    """Install (mesh, rules) so that in-model ``shard()`` constraints apply."""
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def axis_size(name: str) -> int:
    m = _CTX.mesh
    if m is None or name not in m.axis_names:
        return 1
    return dict(zip(m.axis_names, m.devices.shape))[name]


def resolve(axes: Tuple[Optional[str], ...], rules=None) -> P:
    rules = rules if rules is not None else (_CTX.rules or {})
    out = []
    for a in axes:
        r = rules.get(a)
        if isinstance(r, tuple) and len(r) == 0:
            r = None
        out.append(r)
    return P(*out)


def rule_flag(name: str) -> Any:
    """Read an out-of-band flag stashed in the active rules dict."""
    return (_CTX.rules or {}).get(name)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a with_sharding_constraint if a mesh context is installed."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    s = NamedSharding(_CTX.mesh, resolve(axes))
    return jax.lax.with_sharding_constraint(x, s)


# --------------------------------------------------------------- tree utilities
def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree: Tree) -> Tree:
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def shape_tree(specs: Tree) -> Tree:
    """ParamSpec tree -> ShapeDtypeStruct tree (no allocation; for .lower())."""
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def sharding_tree(specs: Tree, mesh: Mesh, rules: Dict[str, Any]) -> Tree:
    return tree_map_specs(
        lambda s: NamedSharding(mesh, resolve(s.axes, rules)), specs)


def spec_bytes(specs: Tree) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    total = 0
    for s in leaves:
        n = 1
        for d in s.shape:
            n *= d
        total += n * jnp.dtype(s.dtype).itemsize
    return total


def init_params(specs: Tree, key: jax.Array) -> Tree:
    """Materialise a random parameter tree from a ParamSpec tree."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))

    def one(s: ParamSpec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else max(s.shape[-1], 1)
        scale = s.scale if s.scale is not None else fan_in ** -0.5
        return (jax.random.normal(k, s.shape, jnp.float32) * scale).astype(s.dtype)

    return jax.tree_util.tree_unflatten(treedef, [one(s, k) for s, k in zip(leaves, keys)])
