"""JAX version compatibility shims.

The repo targets the modern API surface (``jax.shard_map`` with
``check_vma``/``axis_names``, ``jax.make_mesh(..., axis_types=...)``)
but must also run on older toolchains (e.g. jax 0.4.x) where those live
in ``jax.experimental.shard_map`` with ``check_rep``/``auto`` and
``jax.sharding.AxisType`` does not exist.  Every mesh/shard_map call in
``src/`` goes through these two helpers.
"""
from __future__ import annotations

from typing import Optional, Sequence, Set

import jax

_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _AXIS_TYPE is not None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=(_AXIS_TYPE.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def axis_size(axis: str) -> int:
    """Static size of a named mesh axis from inside a shard_map region
    (``jax.lax.axis_size`` where available, else the psum(1) idiom)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Set[str]] = None):
    """``jax.shard_map`` across versions, replication checks disabled.

    ``axis_names``: the manual axes of a partial-manual region (newer
    jax keyword); on the legacy API it maps to ``auto`` = the mesh axes
    NOT in ``axis_names``.  ``None`` means fully manual (all axes).
    """
    if _NEW_SHARD_MAP:
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False, **kw)
    from jax.experimental.shard_map import shard_map as _legacy
    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False, **kw)
