"""jit'd wrapper: (B,S,H,D)/(B,T,KV,D) layout -> flash attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


@functools.partial(jax.jit, static_argnames=("causal", "impl", "bq", "bk"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, impl: str = "pallas",
                    bq: int = kernel.DEFAULT_BQ,
                    bk: int = kernel.DEFAULT_BK) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, T, KV, D) -> (B, S, H, D)."""
    if impl == "jnp":
        return ref.attention(q, k, v, causal=causal)
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    of = kernel.flash_attention_pallas(qf, kf, vf, causal=causal, bq=bq,
                                       bk=bk,
                                       interpret=(impl == "interpret"))
    return of.reshape(B, H, S, D).transpose(0, 2, 1, 3)
