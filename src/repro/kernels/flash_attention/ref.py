"""Pure-jnp oracle for causal flash attention (GQA-aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True) -> jnp.ndarray:
    """q: (B, S, H, D); k, v: (B, T, KV, D). fp32 softmax, GQA by repeat."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    if KV != H:
        g = H // KV
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    logits = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * (D ** -0.5)
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", w, v)
