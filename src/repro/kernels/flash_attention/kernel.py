"""Pallas TPU flash attention (prefill, causal, GQA by index-mapped KV).

Classic online-softmax blocking: grid ``(B*H, S/BQ, T/BK)``; the innermost
(k-block) axis runs sequentially on TPU, carrying (m, l, acc) in VMEM
scratch.  GQA needs no KV repeat — the K/V BlockSpec index maps head
``h -> h // group`` so each KV head's tile is fetched once per group from
HBM.  Block shapes default to (128, 128): MXU-aligned, and the working set
(q 128xD + k/v 128xD + acc 128xD fp32) stays a few hundred KB in VMEM for
D <= 256.  Causal masking skips fully-masked K blocks via ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = iq * bq
    k_lo = ik * bk

    def _compute():
        q = q_ref[0]                       # (BQ, D)
        k = k_ref[0]                       # (BK, D)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)
        if causal:
            qi = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            ki = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qi >= ki, s, NEG_INF)
        m_prev = m_ref[...]                # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # rows with no valid key yet (m_new == -inf) must contribute 0
        p = jnp.where(m_new <= NEG_INF / 2, 0.0, jnp.exp(s - m_new))
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip K blocks strictly above the diagonal
        pl.when(k_lo <= q_lo + bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK,
                           interpret: bool = False) -> jax.Array:
    """q: (BH, S, D); k, v: (BKV, T, D) with BH % BKV == 0 (GQA groups)."""
    BH, S, D = q.shape
    BKV, T, _ = k.shape
    assert BH % BKV == 0
    group = BH // BKV
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    nq, nk = S // bq, T // bk
    grid = (BH, nq, nk)
    kernel = functools.partial(_kernel, scale=D ** -0.5, causal=causal,
                               bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda bh, iq, ik, _g=group: (bh // _g, ik, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda bh, iq, ik, _g=group: (bh // _g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
