"""jit'd wrapper matching the model layout (B, T, H, P)."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import kernel, ref


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int, impl: str = "pallas"
             ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,T,H,P); dt: (B,T,H); A: (H,); Bm/Cm: (B,T,N).

    Returns (y (B,T,H,P), final state (B,H,N,P)) — same contract as
    models.ssm.ssd_chunked.
    """
    if impl == "jnp":
        return ref.ssd(x, dt, A, Bm, Cm, chunk)
    B, T, H, P = x.shape
    pad = (-T) % chunk if T > chunk else (chunk - T if T < chunk else 0)
    if T < chunk:
        chunk = T
        pad = 0
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, Tp, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, Tp)
    y, s = kernel.ssd_scan_pallas(xf, dtf, A, Bm, Cm, chunk=chunk,
                                  interpret=(impl == "interpret"))
    y = y.reshape(B, H, Tp, P).transpose(0, 2, 1, 3)[:, :T]
    return y, s.reshape(B, H, *s.shape[1:])
