"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunked scan.

One (batch, head) pair per outer grid step; the chunk axis is innermost and
sequential, carrying the (N, P) inter-chunk SSD state in fp32 VMEM scratch —
the same carry-across-grid idiom as flash attention, but the carry is a
matrix recurrence instead of softmax stats.

Per chunk (Q = chunk length):
  intra:  scores = (C B^T) ⊙ exp(seg(dA_cs)) masked-causal  -> (Q, Q) @ xdt
  inter:  y += exp(dA_cs) * (C @ S)
  state:  S <- exp(sum dA) * S + B^T diag(dt*decay_end) x

VMEM working set at (Q=128, N=128, P=64): scores 128² f32 (64 KB) + state
128x64 f32 (32 KB) + x/B/C tiles — comfortably under 1 MB.  dt/A enter as
(Q, 1)/(1, 1) tiles so every tensor stays >=2D for the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_out_ref, state_ref,
            *, nc: int, Q: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)      # (Q, 1)
    A = a_ref[0, 0]                         # scalar
    Bm = b_ref[0].astype(jnp.float32)       # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)       # (Q, N)

    dA = dt * A                             # (Q, 1), <= 0
    dA_cs = jnp.cumsum(dA, axis=0)          # (Q, 1) inclusive
    xdt = x * dt                            # (Q, P)

    # ---- intra-chunk
    seg = dA_cs - dA_cs.reshape(1, Q)       # (Q, Q): cs_i - cs_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y = jax.lax.dot_general(CB * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # ---- inter-chunk (uses incoming state)
    S = state_ref[...]                      # (N, P)
    y += jnp.exp(dA_cs) * jax.lax.dot_general(
        Cm, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # ---- state update
    decay_end = jnp.exp(dA_cs[Q - 1] - dA_cs)          # (Q, 1)
    wgt = xdt * decay_end                               # (Q, P)
    S_chunk = jax.lax.dot_general(Bm, wgt, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(dA_cs[Q - 1]) * S + S_chunk

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit_state():
        s_out_ref[0] = state_ref[...]


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array, *, chunk: int,
                    interpret: bool = False):
    """x: (BH, T, P); dt: (BH, T); A: (H,); Bm/Cm: (B, T, N); BH = B*H.

    Returns (y (BH, T, P), final_state (BH, N, P)).
    """
    BH, T, P = x.shape
    B, _, N = Bm.shape
    H = BH // B
    Q = min(chunk, T)
    assert T % Q == 0, (T, Q)
    nc = T // Q
    kernel = functools.partial(_kernel, nc=nc, Q=Q)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, Q, 1), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, 1), lambda bh, c, _H=H: (bh % _H, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, c, _H=H: (bh // _H, c, 0)),
            pl.BlockSpec((1, Q, N), lambda bh, c, _H=H: (bh // _H, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, N, P), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt[..., None], A.reshape(H, 1), Bm, Cm)
    return y, s_out
