"""Oracle for the SSD scan kernel: the model's own chunked-jnp implementation."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from ...models.ssm import ssd_chunked


def ssd(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, Bm: jnp.ndarray,
        Cm: jnp.ndarray, chunk: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,T,H,P); dt: (B,T,H); A: (H,); Bm/Cm: (B,T,N)."""
    return ssd_chunked(x, dt, A, Bm, Cm, chunk)
