"""jit'd wrapper for flash-decode; runtime layout (B,KV,T,D) caches."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


@functools.partial(jax.jit, static_argnames=("impl", "bk"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len, impl: str = "pallas",
                     bk: int = kernel.DEFAULT_BK) -> jax.Array:
    """q: (B, 1, H, D) or (B, H, D); k, v: (B, KV, T, D) -> (B, 1, H, D)."""
    if q.ndim == 4:
        q = q[:, 0]
    B, H, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    if impl == "jnp":
        return ref.decode_attention(q, k, v, kv_len)
    qf = q.reshape(B * H, 1, D)
    kf = k.reshape(B * KV, T, D)
    vf = v.reshape(B * KV, T, D)
    of = kernel.decode_attention_pallas(qf, kf, vf, kv_len, bk=bk,
                                        interpret=(impl == "interpret"))
    return of.reshape(B, H, D)[:, None]
