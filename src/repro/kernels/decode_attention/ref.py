"""Pure-jnp oracle for single-token GQA decode attention with length mask."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     kv_len: int) -> jnp.ndarray:
    """q: (B, H, D); k, v: (B, KV, T, D); positions >= kv_len are masked.

    Returns (B, 1, H, D) — matching the serve-step layout.
    """
    B, H, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    if KV != H:
        g = H // KV
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhd,bhtd->bht", q, k,
                        preferred_element_type=jnp.float32) * (D ** -0.5)
    mask = jnp.arange(T)[None, None, :] < kv_len
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bht,bhtd->bhd", w, v)
    return out[:, None]
