"""Pallas TPU flash-decode: one query token vs a long KV cache.

Grid ``(B*H, T/BK)``; the K-block axis is innermost/sequential, carrying the
online-softmax state in VMEM scratch.  The live cache length arrives via
scalar prefetch (``PrefetchScalarGridSpec``) so the same compiled kernel
serves every decode position — blocks entirely past ``kv_len`` are skipped
with ``pl.when`` (no HBM reads for dead cache: at 32k context and 128-deep
blocks that's the difference between reading the whole cache and reading
only the live prefix).  GQA via head->kv-head index mapping, same as the
prefill kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 256
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, bk: int, nk: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0]
    k_lo = ik * bk

    @pl.when(k_lo < kv_len)
    def _compute():
        q = q_ref[0]                                    # (1, D)
        k = k_ref[0]                                    # (BK, D)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (1, BK)
        ki = k_lo + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(ki < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(m_new <= NEG_INF / 2, 0.0, jnp.exp(s - m_new))
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            kv_len: jax.Array, *, bk: int = DEFAULT_BK,
                            interpret: bool = False) -> jax.Array:
    """q: (BH, 1, D); k, v: (BKV, T, D); kv_len: int32 scalar (traced OK)."""
    BH, _, D = q.shape
    BKV, T, _ = k.shape
    assert BH % BKV == 0
    group = BH // BKV
    bk = min(bk, T)
    assert T % bk == 0
    nk = T // bk
    kernel = functools.partial(_kernel, scale=D ** -0.5, bk=bk, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda bh, ik, len_ref: (bh, 0, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda bh, ik, len_ref, _g=group: (bh // _g, ik, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda bh, ik, len_ref, _g=group: (bh // _g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda bh, ik, len_ref: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, 1, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32).reshape(1), q, k, v)
