"""Pure-jnp oracle for the activation codec (int8 per-row-block quantisation).

RoboECC ships the cut-layer activation over the edge-cloud network; this
codec shrinks it 2x (bf16->int8) with per-(row, 128-col-block) scales.  The
oracle defines the exact semantics the Pallas kernel must match.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

BLOCK = 128


def quantize_int8(x: jnp.ndarray, block: int = BLOCK
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., D) with D % block == 0 -> (int8 (..., D), f32 scales (..., D/block))."""
    *lead, D = x.shape
    assert D % block == 0, (D, block)
    xb = x.astype(jnp.float32).reshape(*lead, D // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(*lead, D), scale[..., 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.bfloat16,
                    block: int = BLOCK) -> jnp.ndarray:
    *lead, D = q.shape
    xb = q.reshape(*lead, D // block, block).astype(jnp.float32)
    out = xb * scale[..., None]
    return out.reshape(*lead, D).astype(dtype)


def wire_bytes(shape, block: int = BLOCK) -> int:
    """Bytes on the network for a quantised activation of `shape`."""
    n = 1
    for d in shape:
        n *= d
    return n + (n // block) * 4
