"""Pure-jnp oracles for the activation codecs (int8 and packed int4).

RoboECC ships the cut-layer activation over the edge-cloud network; these
codecs shrink it 2x (bf16->int8) / ~3.8x (bf16->packed int4) with
per-(row, 128-col-block) scales.  The oracles define the exact semantics
the Pallas kernels must match.

int4 packing layout: elements are quantised to [-7, 7], biased to [0, 14],
and two elements pack into one byte **lane-aligned**: within each 256-lane
tile, byte ``j`` holds element ``j`` (low nibble) and element ``j + 128``
(high nibble).  This keeps the pack/unpack a pure (128-lane) vector op on
TPU — no strided lane shuffles.  The packed byte is stored as int8 with a
-128 offset so all arithmetic stays in signed types.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

BLOCK = 128


def quantize_int8(x: jnp.ndarray, block: int = BLOCK
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., D) with D % block == 0 -> (int8 (..., D), f32 scales (..., D/block))."""
    *lead, D = x.shape
    assert D % block == 0, (D, block)
    xb = x.astype(jnp.float32).reshape(*lead, D // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(*lead, D), scale[..., 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.bfloat16,
                    block: int = BLOCK) -> jnp.ndarray:
    *lead, D = q.shape
    xb = q.reshape(*lead, D // block, block).astype(jnp.float32)
    out = xb * scale[..., None]
    return out.reshape(*lead, D).astype(dtype)


def wire_bytes(shape, block: int = BLOCK) -> int:
    """Bytes on the network for an int8-quantised activation of `shape`."""
    n = 1
    for d in shape:
        n *= d
    return n + (n // block) * 4


# ------------------------------------------------------------------- int4
PAIR = 2 * BLOCK                     # lanes consumed per packed 128-lane tile


def quantize_int4(x: jnp.ndarray, block: int = BLOCK
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., D) with D % (2*block) == 0 -> (int8 packed (..., D/2),
    f32 scales (..., D/block)).

    Per-(row, block) abs-max scales map values into [-7, 7]; the biased
    nibbles of elements ``j`` and ``j + block`` of each 2*block-lane pair
    pack into byte ``j`` (see module docstring for the layout).
    """
    *lead, D = x.shape
    assert D % (2 * block) == 0, (D, block)
    xb = x.astype(jnp.float32).reshape(*lead, D // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    # constant multiply, NOT amax / 7.0: XLA rewrites division by a
    # constant into a reciprocal multiply under jit, which would make the
    # jitted ops.py path diverge from this eager oracle in the last ulp
    scale = jnp.where(amax > 0, amax * (1.0 / 7.0), 1.0)
    q = jnp.clip(jnp.round(xb / scale), -7, 7).astype(jnp.int32) + 7
    q = q.reshape(*lead, D // (2 * block), 2, block)   # pair of blocks
    packed = q[..., 0, :] + 16 * q[..., 1, :] - 128    # in [-128, 110]
    return (packed.astype(jnp.int8).reshape(*lead, D // 2),
            scale[..., 0])


def dequantize_int4(packed: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.bfloat16, block: int = BLOCK) -> jnp.ndarray:
    *lead, Dh = packed.shape
    D = 2 * Dh
    p = packed.reshape(*lead, D // (2 * block), block).astype(jnp.int32) + 128
    lo = p % 16 - 7
    hi = p // 16 - 7
    q = jnp.stack([lo, hi], axis=-2)                   # (..., pairs, 2, block)
    sb = scale.reshape(*lead, D // (2 * block), 2, 1).astype(jnp.float32)
    out = q.astype(jnp.float32) * sb
    return out.reshape(*lead, D).astype(dtype)


def wire_bytes_int4(shape, block: int = BLOCK) -> int:
    """Bytes on the network for a packed-int4 activation of `shape`."""
    n = 1
    for d in shape:
        n *= d
    return n // 2 + (n // block) * 4
