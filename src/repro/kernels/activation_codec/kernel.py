"""Pallas TPU kernels: fused int8 / packed-int4 activation codecs.

The quantise kernels fuse abs-max reduction, scale computation, rounding
(and, for int4, nibble packing) in one VMEM pass, so the HBM traffic is
exactly read-bf16 + write-quantised + write-scales (vs 3+ passes for the
naive lowering).

int8 grid: (rows / ROW_TILE, D / LANE_TILE); LANE_TILE = 128 matches both
the codec block size and the TPU lane width; ROW_TILE = 256 keeps the
working set (256*128*2B in + 256*128B out) well under VMEM while amortising
control overhead.

int4 grid: (rows / ROW_TILE, D / (2*LANE_TILE)) — each cell reads a
(ROW_TILE, 256) tile and writes a (ROW_TILE, 128) packed byte tile plus a
(ROW_TILE, 2) scale tile.  Packing pairs element ``j`` with element
``j + 128`` of the tile (the ref.py layout), so both nibble sources are
themselves 128-lane aligned slices: the pack is a mul-add on the VPU, never
a strided lane shuffle.  All nibble math is arithmetic in int32 (biased by
+7, byte offset −128) — no bitwise ops, which keeps the same code exact in
interpret mode on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 256
LANE_TILE = 128


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (ROW_TILE, LANE)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)     # (ROW_TILE, 1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref, *, dtype):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s_ref[...]).astype(dtype)


def quantize_int8_pallas(x: jax.Array, *, interpret: bool = False):
    """x: (R, D) bf16/f32, D % 128 == 0 -> (int8 (R, D), f32 (R, D/128))."""
    R, D = x.shape
    rt = min(ROW_TILE, R)
    assert R % rt == 0 and D % LANE_TILE == 0, (R, D)
    grid = (R // rt, D // LANE_TILE)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rt, LANE_TILE), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((rt, LANE_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((rt, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, D), jnp.int8),
            jax.ShapeDtypeStruct((R, D // LANE_TILE), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


def dequantize_int8_pallas(q: jax.Array, s: jax.Array, dtype=jnp.bfloat16,
                           *, interpret: bool = False):
    R, D = q.shape
    rt = min(ROW_TILE, R)
    assert R % rt == 0 and D % LANE_TILE == 0, (R, D)
    grid = (R // rt, D // LANE_TILE)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, dtype=dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, LANE_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((rt, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((rt, LANE_TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, D), dtype),
        interpret=interpret,
    )(q, s)


# ------------------------------------------------------------------- int4
def _quant4_kernel(x_ref, p_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (rt, 2*LANE)
    lo, hi = x[:, :LANE_TILE], x[:, LANE_TILE:]
    amax_lo = jnp.max(jnp.abs(lo), axis=1, keepdims=True)
    amax_hi = jnp.max(jnp.abs(hi), axis=1, keepdims=True)
    # constant multiply to stay bit-identical with ref.py under jit
    s_lo = jnp.where(amax_lo > 0, amax_lo * (1.0 / 7.0), 1.0)
    s_hi = jnp.where(amax_hi > 0, amax_hi * (1.0 / 7.0), 1.0)
    q_lo = jnp.clip(jnp.round(lo / s_lo), -7, 7).astype(jnp.int32) + 7
    q_hi = jnp.clip(jnp.round(hi / s_hi), -7, 7).astype(jnp.int32) + 7
    p_ref[...] = (q_lo + 16 * q_hi - 128).astype(jnp.int8)
    s_ref[...] = jnp.concatenate([s_lo, s_hi], axis=1)    # (rt, 2)


def _dequant4_kernel(p_ref, s_ref, o_ref, *, dtype):
    p = p_ref[...].astype(jnp.int32) + 128                # (rt, LANE)
    s = s_ref[...].astype(jnp.float32)                    # (rt, 2)
    lo = (p % 16 - 7).astype(jnp.float32) * s[:, 0:1]
    hi = (p // 16 - 7).astype(jnp.float32) * s[:, 1:2]
    o_ref[...] = jnp.concatenate([lo, hi], axis=1).astype(dtype)


def quantize_int4_pallas(x: jax.Array, *, interpret: bool = False):
    """x: (R, D) bf16/f32, D % 256 == 0 ->
    (int8 packed (R, D/2), f32 scales (R, D/128))."""
    R, D = x.shape
    rt = min(ROW_TILE, R)
    assert R % rt == 0 and D % (2 * LANE_TILE) == 0, (R, D)
    grid = (R // rt, D // (2 * LANE_TILE))
    p, s = pl.pallas_call(
        _quant4_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rt, 2 * LANE_TILE), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((rt, LANE_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((rt, 2), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, D // 2), jnp.int8),
            jax.ShapeDtypeStruct((R, D // LANE_TILE), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return p, s


def dequantize_int4_pallas(p: jax.Array, s: jax.Array, dtype=jnp.bfloat16,
                           *, interpret: bool = False):
    R, Dh = p.shape
    D = 2 * Dh
    rt = min(ROW_TILE, R)
    assert R % rt == 0 and D % (2 * LANE_TILE) == 0, (R, D)
    grid = (R // rt, D // (2 * LANE_TILE))
    return pl.pallas_call(
        functools.partial(_dequant4_kernel, dtype=dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, LANE_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((rt, 2), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((rt, 2 * LANE_TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, D), dtype),
        interpret=interpret,
    )(p, s)
