"""Pallas TPU kernel: fused int8 activation quantise / dequantise.

The quantise kernel fuses abs-max reduction, scale computation and rounding
in one VMEM pass over (ROWS, 128)-tiles, so the HBM traffic is exactly
read-bf16 + write-int8 + write-scales (vs 3 passes for the naive lowering).
Grid: (rows / ROW_TILE, D / LANE_TILE); LANE_TILE = 128 matches both the
codec block size and the TPU lane width; ROW_TILE = 256 keeps the working
set (256*128*2B in + 256*128B out) well under VMEM while amortising control
overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 256
LANE_TILE = 128


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (ROW_TILE, LANE)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)     # (ROW_TILE, 1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref, *, dtype):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s_ref[...]).astype(dtype)


def quantize_int8_pallas(x: jax.Array, *, interpret: bool = False):
    """x: (R, D) bf16/f32, D % 128 == 0 -> (int8 (R, D), f32 (R, D/128))."""
    R, D = x.shape
    rt = min(ROW_TILE, R)
    assert R % rt == 0 and D % LANE_TILE == 0, (R, D)
    grid = (R // rt, D // LANE_TILE)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rt, LANE_TILE), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((rt, LANE_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((rt, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, D), jnp.int8),
            jax.ShapeDtypeStruct((R, D // LANE_TILE), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


def dequantize_int8_pallas(q: jax.Array, s: jax.Array, dtype=jnp.bfloat16,
                           *, interpret: bool = False):
    R, D = q.shape
    rt = min(ROW_TILE, R)
    assert R % rt == 0 and D % LANE_TILE == 0, (R, D)
    grid = (R // rt, D // LANE_TILE)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, dtype=dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, LANE_TILE), lambda i, j: (i, j)),
            pl.BlockSpec((rt, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((rt, LANE_TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, D), dtype),
        interpret=interpret,
    )(q, s)
