"""jit'd public wrappers for the activation codecs (int8 + packed int4).

``impl``: "jnp" (XLA everywhere), "pallas" (TPU target), "interpret"
(Pallas body executed in Python — CPU validation).  Arbitrary-rank inputs
are flattened to (rows, D).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import kernel, ref


@functools.partial(jax.jit, static_argnames=("impl", "block"))
def quantize(x: jax.Array, impl: str = "jnp", block: int = ref.BLOCK
             ) -> Tuple[jax.Array, jax.Array]:
    shape = x.shape
    D = shape[-1]
    if impl == "jnp" or block != ref.BLOCK:
        return ref.quantize_int8(x, block)
    rows = x.size // D
    x2 = x.reshape(rows, D)
    q, s = kernel.quantize_int8_pallas(x2, interpret=(impl == "interpret"))
    return q.reshape(shape), s.reshape(*shape[:-1], D // block)


@functools.partial(jax.jit, static_argnames=("impl", "block", "dtype"))
def dequantize(q: jax.Array, s: jax.Array, dtype=jnp.bfloat16,
               impl: str = "jnp", block: int = ref.BLOCK) -> jax.Array:
    shape = q.shape
    D = shape[-1]
    if impl == "jnp" or block != ref.BLOCK:
        return ref.dequantize_int8(q, s, dtype, block)
    rows = q.size // D
    out = kernel.dequantize_int8_pallas(
        q.reshape(rows, D), s.reshape(rows, D // block), dtype,
        interpret=(impl == "interpret"))
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("impl", "block"))
def quantize_int4(x: jax.Array, impl: str = "jnp", block: int = ref.BLOCK
                  ) -> Tuple[jax.Array, jax.Array]:
    """(..., D) with D % (2*block) == 0 -> (packed int8 (..., D/2),
    f32 scales (..., D/block))."""
    shape = x.shape
    D = shape[-1]
    if impl == "jnp" or block != ref.BLOCK:
        return ref.quantize_int4(x, block)
    rows = x.size // D
    p, s = kernel.quantize_int4_pallas(x.reshape(rows, D),
                                       interpret=(impl == "interpret"))
    return (p.reshape(*shape[:-1], D // 2),
            s.reshape(*shape[:-1], D // block))


@functools.partial(jax.jit, static_argnames=("impl", "block", "dtype"))
def dequantize_int4(p: jax.Array, s: jax.Array, dtype=jnp.bfloat16,
                    impl: str = "jnp", block: int = ref.BLOCK) -> jax.Array:
    shape = p.shape
    Dh = shape[-1]
    if impl == "jnp" or block != ref.BLOCK:
        return ref.dequantize_int4(p, s, dtype, block)
    rows = p.size // Dh
    out = kernel.dequantize_int4_pallas(
        p.reshape(rows, Dh), s.reshape(rows, 2 * Dh // block), dtype,
        interpret=(impl == "interpret"))
    return out.reshape(*shape[:-1], 2 * Dh)
