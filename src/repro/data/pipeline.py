"""Synthetic data pipeline: deterministic, per-host sharded, restartable.

Produces LM token streams (Zipf-ish unigram + short-range repetition so the
~100M-param training example shows a real falling loss curve), VLA
trajectories, and bandwidth traces for the predictor.  A production swap-in
would replace ``_synth_tokens`` with a tokenized shard reader; the iterator
contract (``state`` -> resumable) is what the checkpointing relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    family: str = "dense"          # which batch keys to emit
    d_model: int = 0               # frames/vision stub width
    n_vision_tokens: int = 0
    n_patches: int = 0
    vit_dim: int = 0
    action_dim: int = 7
    action_horizon: int = 16


class SyntheticStream:
    """Deterministic, seekable batch stream (step index = state)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.step = 0

    # ------------------------------------------------------------ checkpoint
    def state(self) -> Dict:
        return {"step": self.step}

    def restore(self, state: Dict) -> None:
        self.step = int(state["step"])

    # ------------------------------------------------------------- batches
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.cfg.seed, step))

    def _synth_tokens(self, rng, B, S, V) -> np.ndarray:
        # Zipf unigram + copy structure: second half repeats the first.
        base = rng.zipf(1.3, size=(B, S)) % V
        half = S // 2
        base[:, half:half * 2] = base[:, :half]
        return base.astype(np.int32)

    def next(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = self._rng(self.step)
        self.step += 1
        toks = self._synth_tokens(rng, c.global_batch, c.seq_len + 1,
                                  c.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if c.family == "audio":
            batch["frames"] = rng.standard_normal(
                (c.global_batch, c.seq_len, c.d_model)).astype(np.float32)
        if c.family == "vlm":
            batch["vision"] = rng.standard_normal(
                (c.global_batch, c.n_vision_tokens, c.d_model)
            ).astype(np.float32)
        if c.family == "vla":
            batch = {
                "patches": rng.standard_normal(
                    (c.global_batch, c.n_patches, c.vit_dim)
                ).astype(np.float32),
                "tokens": batch["tokens"][:, :64],
                "actions": rng.uniform(
                    -1, 1, (c.global_batch, c.action_horizon, c.action_dim)
                ).astype(np.float32),
            }
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()


def shard_batch(batch: Dict, mesh, rules) -> Dict:
    """Host numpy batch -> globally-sharded jax arrays."""
    import jax
    from jax.sharding import NamedSharding
    from ..models.sharding import resolve
    out = {}
    for k, v in batch.items():
        axes = ("batch",) + (None,) * (v.ndim - 1)
        if k in ("tokens", "labels"):
            axes = ("batch", "seq")
        sh = NamedSharding(mesh, resolve(axes, rules))
        out[k] = jax.device_put(v, sh)
    return out
