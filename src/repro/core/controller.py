"""RoboECC end-to-end controller (paper Fig. 1c / Fig. 4).

Pipeline:
  1. structure model (Eq. 1)  ->  flattened layer graph
  2. hardware model (Eq. 2)   ->  per-layer edge/cloud latencies
  3. Alg. 1                   ->  optimal split under the cloud budget
  4. parameter-sharing pool   ->  movable region around the split
  5. LSTM predictor + ΔNB thresholds -> per-tick fine-grained adjustment

``tick()`` advances one control step against a NetworkSim and returns the
latency decomposition for that inference — this drives the paper-table
benchmarks and the serving examples.  ``adjust_overhead_s`` is the *measured
wall time* of the adjustment decision on this host (paper §V-C-1 reports
10.7 ms on their hosts).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Union

import numpy as np

from ..configs.base import ModelConfig
from .adjustment import (AdjustmentDecision, PlacementDecision, Thresholds,
                         adjust, adjust_placement)
from .codec import (Codec, CodecLike, DeltaCodec, get_codec,
                    make_delta_codec, resolve_codecs)
from .hardware import DeviceSpec, layer_latency
from .network import NetworkSim
from .placement import PlacementPlan
from .pool import Pool, build_pool
from .predictor import Predictor, PredictorConfig, train_predictor
from .pipeline import DEFAULT_CHUNK_GRID
from .segmentation import (SegmentationResult, evaluate_placement,
                           evaluate_split, search, search_multicut,
                           search_streamed)
from .structure import LayerCost, Workload, build_graph


@dataclasses.dataclass
class TickResult:
    split: int
    edge_s: float
    cloud_s: float
    net_s: float
    total_s: float
    decision: Optional[Union[AdjustmentDecision, PlacementDecision]]
    adjust_overhead_s: float
    bw_real_bps: float
    bw_pred_bps: float
    codec: Optional[str] = None  # codec the transfer was priced with
    # the full multi-cut placement this tick ran with (multicut mode);
    # ``split`` stays the primary edge→cloud cut for legacy consumers
    placement: Optional[PlacementPlan] = None
    # streaming chunk count of the uplink cut this tick ran with
    # (1 = sequential transfer; streamed mode only)
    n_chunks: int = 1


class RoboECC:
    """End-to-end controller.  ``codec`` (name or ``Codec``) prices the cut
    transfer through ``core/codec.py`` — inside Alg. 1, so compression
    participates in the planned split, not just the transfer time.
    ``adjust_codecs`` additionally lets the per-tick ΔNB move pick a codec
    jointly with the split (the first list entry is the preferred /
    lowest-error format).  ``use_codec=True`` is the backwards-compatible
    alias for ``codec="int8"``.

    ``multicut=True`` plans over K-segment placements
    (``core/placement.py``): Alg. 1 becomes the (S1, S2, codec) multi-cut
    scan, the per-tick ΔNB move may shift **either** cut (a second
    parameter-sharing pool ``pool2`` wraps the downlink cut), and every
    latency is priced through ``evaluate_placement`` — the downlink leg
    rides ``down_bw_factor × bandwidth``.  ``split`` remains the primary
    edge→cloud cut for legacy consumers; single-cut behaviour is the exact
    K=1 special case (a multicut controller whose planner collapses the
    tail keeps ``placement.is_single``).  Multicut codec state must come
    from the ``core/codec.py`` registry (plans carry codec *names*).

    ``streamed=True`` plans over the streaming chunk axis too
    (``core/pipeline.py``): Alg. 1 becomes ``search_streamed`` (restricted
    to single cuts unless ``multicut``), every tick is priced through the
    chunk-pipeline makespan (``evaluate_placement(streamed=True)``), and
    the per-tick ΔNB move may change ``n_chunks`` jointly with the cuts
    and codec — so the LSTM bandwidth forecast drives chunk replanning: a
    chunk count picked for 10 MB/s is wrong at 0.2 MB/s, the paper's
    performance-drift story replayed on a new axis.  ``plan_rtt_s`` is
    the per-chunk rtt the streamed planner and adjuster price (chunking
    is free at rtt 0, so it must be the deployment's real rtt);
    ``chunk_grid`` the chunk counts searched.

    ``queue_hz > 0`` makes planning queue-aware: Alg. 1 (and the
    multi-cut / streamed scans, and the ΔNB down move) add the M/G/1
    expected wait ``segmentation.queue_delay_s`` for each candidate's
    cloud service time, so the controller retreats toward the edge when
    the shared cloud replica is congested.  The fleet simulator
    estimates the per-replica rate from its own closed loop
    (``FleetConfig(queue_aware=True)``)."""

    def __init__(self, cfg: ModelConfig, edge: DeviceSpec, cloud: DeviceSpec,
                 *, workload: Workload = Workload(),
                 cloud_budget_bytes: Optional[float] = None,
                 pool_overhead_target: float = 0.026,
                 nominal_bw_bps: float = 10e6,
                 thresholds: Optional[Thresholds] = None,
                 use_codec: bool = False,
                 codec: CodecLike = None,
                 adjust_codecs: Optional[List] = None,
                 graph: Optional[List[LayerCost]] = None,
                 multicut: bool = False,
                 down_bw_factor: float = 1.0,
                 streamed: bool = False,
                 chunk_grid=DEFAULT_CHUNK_GRID,
                 plan_rtt_s: float = 0.005,
                 queue_hz: float = 0.0,
                 queue_cv2: float = 1.0,
                 queue_service_scale: float = 1.0):
        self.cfg = cfg
        self.edge_dev, self.cloud_dev = edge, cloud
        self.workload = workload
        if codec is None and use_codec:
            codec = "int8"
        self.codec: Optional[Codec] = get_codec(codec)
        self.adjust_codecs = resolve_codecs(adjust_codecs)
        # `graph` lets a fleet of same-arch robots share one prebuilt graph
        self.graph: List[LayerCost] = list(graph) if graph is not None \
            else build_graph(cfg, workload)
        self.cloud_budget_bytes = cloud_budget_bytes
        self.pool_overhead_target = pool_overhead_target
        self.multicut = multicut
        self.down_bw_factor = down_bw_factor
        self.streamed = streamed
        self.chunk_grid = tuple(chunk_grid)
        self.plan_rtt_s = plan_rtt_s
        # expected per-replica arrival rate (+ M/G/1 shape parameters)
        # the planner and adjuster price cloud congestion with —
        # queue_hz = 0 keeps every decision queue-blind (bit-for-bit)
        self.queue_hz = queue_hz
        self.queue_cv2 = queue_cv2
        self.queue_service_scale = queue_service_scale
        self.seg: SegmentationResult = search(
            self.graph, edge, cloud, nominal_bw_bps,
            cloud_budget_bytes=cloud_budget_bytes,
            input_bytes=workload.input_bytes, codec=self.codec,
            queue_hz=queue_hz, queue_cv2=queue_cv2,
            queue_service_scale=queue_service_scale)
        self.placement: PlacementPlan = self._plan_placement(nominal_bw_bps,
                                                             cloud_budget_bytes)
        self._rebuild_pools()
        self.thresholds = thresholds or Thresholds(high=2e6, low=-2e6)
        self.predictor: Optional[Predictor] = None

    # ------------------------------------------------------------- planning
    def _plan_placement(self, nominal_bw_bps: float,
                        cloud_budget_bytes: Optional[float]
                        ) -> PlacementPlan:
        """Alg. 1 (single-cut), the multi-cut (S1, S2) scan, or — with
        ``streamed`` — the (S1, S2, n_chunks) streamed scan, as a
        ``PlacementPlan``.  All paths share the codec the controller was
        built with."""
        if self.streamed:
            st = search_streamed(
                self.graph, self.edge_dev, self.cloud_dev, [nominal_bw_bps],
                cloud_budget_bytes,
                codecs=[self.codec] if self.codec is not None else None,
                chunk_grid=self.chunk_grid, rtt_s=self.plan_rtt_s,
                input_bytes=self.workload.input_bytes,
                down_bw_factor=self.down_bw_factor,
                single_cut_only=not self.multicut,
                queue_hz=self.queue_hz, queue_cv2=self.queue_cv2,
                queue_service_scale=self.queue_service_scale)
            return st.plan_at(0)
        if not self.multicut:
            return PlacementPlan.single(
                self.seg.split, self.codec.name if self.codec else None)
        mc = search_multicut(
            self.graph, self.edge_dev, self.cloud_dev, [nominal_bw_bps],
            cloud_budget_bytes,
            codecs=[self.codec] if self.codec is not None else None,
            rtt_s=0.0, input_bytes=self.workload.input_bytes,
            down_bw_factor=self.down_bw_factor,
            queue_hz=self.queue_hz, queue_cv2=self.queue_cv2,
            queue_service_scale=self.queue_service_scale)
        return mc.plan_at(0)

    def _rebuild_pools(self) -> None:
        """One parameter-sharing pool per real cut: ``pool`` wraps the
        primary edge→cloud cut, ``pool2`` the cloud→edge tail cut (absent
        for single-cut placements)."""
        n = len(self.graph)
        self.split = self.placement.primary_cut(n)
        self.pool: Pool = build_pool(self.graph, self.split,
                                     self.pool_overhead_target)
        s2 = self.placement.tail_cut(n)
        self.pool2: Optional[Pool] = build_pool(
            self.graph, s2, self.pool_overhead_target) if s2 < n else None

    @property
    def use_codec(self) -> bool:
        return self.codec is not None and self.codec.name != "identity"

    # ------------------------------------------------------------- predictor
    def fit_predictor(self, historical_bps: np.ndarray,
                      pcfg: PredictorConfig = PredictorConfig(),
                      seed: int = 0) -> None:
        self.predictor, _ = train_predictor(historical_bps, pcfg, seed)

    # ------------------------------------------------------------- latencies
    def latency_at(self, split: int, bw_bps: float, rtt_s: float = 0.0):
        """(edge_s, cloud_s, net_s) in seconds at ``split`` for a link of
        ``bw_bps`` BYTES/s — the modeled latency decomposition of one
        inference without advancing any state.  Transport is priced through
        ``self.codec`` (exact wire format bytes + encode/decode compute on
        the two tiers), replacing the former hard-coded bf16→int8 halving
        that ignored scale layout and codec compute entirely."""
        return evaluate_split(self.graph, split, self.edge_dev,
                              self.cloud_dev, bw_bps, rtt_s=rtt_s,
                              input_bytes=self.workload.input_bytes,
                              codec=self.codec)

    def placement_latency_at(self, bw_bps: float, rtt_s: float = 0.0):
        """(edge_s, cloud_s, net_s) of the current (possibly multi-cut /
        streamed) placement — the generalization of ``latency_at``.
        ``net_s`` is uplink + downlink; each leg carries its own rtt.  In
        streamed mode the uplink component is the chunk-pipeline's
        transport-exposed time (makespan − overlapped cloud compute), so
        the three components still sum to the tick latency."""
        ev = evaluate_placement(self.graph, self.placement, self.edge_dev,
                                self.cloud_dev, bw_bps, rtt_s=rtt_s,
                                input_bytes=self.workload.input_bytes,
                                down_bw_factor=self.down_bw_factor,
                                streamed=self.streamed)
        return ev.edge_s, ev.cloud_s, ev.net_s

    # ------------------------------------------------------------------ tick
    def tick(self, net: NetworkSim, adjust_enabled: bool = True) -> TickResult:
        bw_real = net.now_bps
        decision = None
        bw_pred = bw_real
        t0 = time.perf_counter()
        if adjust_enabled and self.predictor is not None:
            window = net.window(self.predictor.cfg.window)
            bw_pred = self.predictor.predict(window)
            if self.multicut or self.streamed:
                # the streamed single-cut controller also routes through
                # the placement adjuster: its move set carries the chunk
                # axis (pool2=None pins S2 = n, so cuts stay single)
                decision = adjust_placement(
                    self.graph, self.pool, self.placement, bw_pred, bw_real,
                    self.thresholds, pool2=self.pool2,
                    codecs=self.adjust_codecs,
                    edge=self.edge_dev, cloud=self.cloud_dev,
                    down_bw_factor=self.down_bw_factor,
                    chunk_grid=self.chunk_grid if self.streamed else None,
                    rtt_s=self.plan_rtt_s if self.streamed else 0.0,
                    queue_hz=self.queue_hz, queue_cv2=self.queue_cv2,
                    queue_service_scale=self.queue_service_scale)
                self.placement = decision.placement
                self.split = self.placement.primary_cut(len(self.graph))
            else:
                decision = adjust(self.graph, self.pool, self.split, bw_pred,
                                  bw_real, self.thresholds,
                                  codecs=self.adjust_codecs,
                                  current_codec=self.codec.name
                                  if self.codec else None,
                                  edge=self.edge_dev, cloud=self.cloud_dev)
                self.split = decision.split
            if decision.codec is not None and (
                    self.codec is None or decision.codec != self.codec.name):
                # resolve within the adjuster's own axis, NOT the global
                # registry — adjust_codecs may hold custom Codec instances
                # (e.g. f32-raw variants) that a name lookup in CODECS
                # would miss or silently swap for the bf16 defaults
                self.codec = next(c for c in self.adjust_codecs
                                  if c.name == decision.codec)
            if not (self.multicut or self.streamed):
                self.placement = PlacementPlan.single(
                    self.split, self.codec.name if self.codec else None)
        overhead = time.perf_counter() - t0
        # the *next* tick's bandwidth is what the transfer actually sees
        net.step()
        bw_serve = net.now_bps
        if self.multicut or self.streamed:
            e, c, t = self.placement_latency_at(bw_serve, net.rtt_s)
        else:
            e, c, t = self.latency_at(self.split, bw_serve, net.rtt_s)
        return TickResult(split=self.split, edge_s=e, cloud_s=c, net_s=t,
                          total_s=e + c + t + (overhead if adjust_enabled else 0.0),
                          decision=decision, adjust_overhead_s=overhead,
                          bw_real_bps=bw_real, bw_pred_bps=bw_pred,
                          codec=self.codec.name if self.codec else None,
                          placement=self.placement,
                          n_chunks=self.placement.primary_chunks(
                              len(self.graph)))

    # --------------------------------------------------------- scene drift
    def observe_change_frac(self, measured_frac: float, *,
                            tol: float = 0.25,
                            nominal_bw_bps: float = 10e6,
                            cloud_budget_bytes: Optional[float] = None
                            ) -> bool:
        """Re-plan when the *measured* token change fraction drifts from
        the one the delta codec was priced with.

        A ``DeltaCodec``'s wire bytes are a bet on scene content: plans
        priced for a static tabletop (``change_frac`` ≈ 0.02) are badly
        wrong once the robot starts driving.  When the relative drift
        ``|measured - planned| / planned`` exceeds ``tol``, rebuild the
        delta codec around the measured fraction (same base, cadence,
        threshold) and re-run the full planner with it.  Returns whether
        a re-plan happened; a no-op (non-delta codec, or drift within
        tolerance) costs one comparison.

        ``nominal_bw_bps`` / ``cloud_budget_bytes`` follow ``replan``'s
        convention: they describe the deployment conditions to re-plan
        under and do not default to construction values."""
        if not isinstance(self.codec, DeltaCodec):
            return False
        planned = self.codec.change_frac
        measured = min(max(float(measured_frac), 0.0), 1.0)
        if planned > 0.0 and abs(measured - planned) / planned <= tol:
            return False
        old_name = self.codec.name
        self.codec = make_delta_codec(
            base=self.codec.base, change_frac=measured,
            resync_every=self.codec.resync_every,
            threshold=self.codec.threshold,
            row_elems=self.codec.row_elems,
            raw_bytes_per_elem=self.codec.raw_bytes_per_elem,
            name=old_name)
        if self.adjust_codecs is not None:
            self.adjust_codecs = [
                self.codec if c.name == old_name else c
                for c in self.adjust_codecs]
        self.replan(cloud_budget_bytes=cloud_budget_bytes,
                    nominal_bw_bps=nominal_bw_bps)
        return True

    # ------------------------------------------------------------ elasticity
    def replan(self, *, edge: Optional[DeviceSpec] = None,
               cloud: Optional[DeviceSpec] = None,
               cloud_budget_bytes: Optional[float] = None,
               nominal_bw_bps: float = 10e6) -> SegmentationResult:
        """Elastic re-planning after a tier change (device loss/join):
        re-run Alg. 1 with the surviving device set.  Losing the edge tier
        degenerates to cloud-only (split=0) — the paper's baseline.

        Note: ``cloud_budget_bytes`` and ``nominal_bw_bps`` describe the NEW
        deployment conditions and intentionally do NOT default to the values
        passed at construction — a tier change usually changes the budget
        too (e.g. cloud-only fallback must host the whole model).  Re-pass
        the original budget explicitly to keep it (as the fleet simulator
        does on replica re-join)."""
        if edge is not None:
            self.edge_dev = edge
        if cloud is not None:
            self.cloud_dev = cloud
        self.seg = search(self.graph, self.edge_dev, self.cloud_dev,
                          nominal_bw_bps, cloud_budget_bytes=cloud_budget_bytes,
                          input_bytes=self.workload.input_bytes,
                          codec=self.codec, queue_hz=self.queue_hz,
                          queue_cv2=self.queue_cv2,
                          queue_service_scale=self.queue_service_scale)
        self.placement = self._plan_placement(nominal_bw_bps,
                                              cloud_budget_bytes)
        self._rebuild_pools()
        return self.seg
