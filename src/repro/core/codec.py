"""Split-boundary transport codecs — compression in the planning loop.

RoboECC's wire cost at the split point decides the split, and the paper's
2.55–2.62 % overhead budget is exactly the codec/adjustment machinery — so
codec cost belongs INSIDE the Alg. 1 search, not bolted on after (RAPID,
arXiv 2603.07949, shows transfer reduction shifts the optimal partition;
ActionFlow, arXiv 2512.20276, shows compression compute must be
co-scheduled with transmission).  Every decision layer in this repo prices
transport through a ``Codec``:

* ``core/segmentation.py`` — ``evaluate_split``/``search`` take a codec;
  ``search_vec``/``sweep_search`` take a codec *axis* and return the joint
  (split × codec) optimum per bandwidth;
* ``core/adjustment.py`` — the ΔNB move is joint over (split, codec);
* ``core/controller.py`` — ``RoboECC`` prices its per-tick latency through
  the shared codec (replacing a hard-coded int8 formula);
* ``runtime/fleet.py`` — robots carry per-robot codec state.

A ``Codec`` models three things about shipping a cut activation:

1. **wire bytes** — ``wire_bytes(raw_bytes)``, exact per-element format
   cost including block-scale / index overheads (layouts match
   ``kernels/activation_codec``: per-(row, 128)-block scales);
2. **codec compute** — encode/decode FLOPs + HBM traffic per element,
   priced into seconds on a concrete ``DeviceSpec`` with the same
   max(compute, memory) roofline as Eq. 2 (``encode_s`` on the edge device,
   ``decode_s`` on the cloud device);
3. **accuracy proxy** — ``err_bound``, the relative per-element
   reconstruction error bound (0 for lossless), so planners can gate codec
   choice with ``max_err``.

Cost model notes: both cost terms are *linear* in the element count, which
is what lets the vectorized planner fold codecs into one numpy pass
(``encode_s(raw) == raw * encode_s_per_byte``).  Identity is exactly free
(factor 1.0, zero compute) so enabling the codec axis with only
``identity`` reproduces codec-free plans bit-for-bit.

**Temporal deltas** (``DeltaCodec`` / ``make_delta_codec``): a VLA control
loop sees near-identical consecutive camera frames, so the cloud caches
the previous step's cut activation and the edge ships only the
changed-token rows plus a 1-bit-per-row change mask, resyncing with a full
key frame every ``resync_every`` steps (RAPID's redundancy-awareness as a
planner axis; ROADMAP item 2).  The planner-facing fields are the
CYCLE-AVERAGED expected costs over one key-frame period parameterized by
the expected change fraction — still linear per raw byte, so every
existing search/sweep/adjust path consumes a ``DeltaCodec`` unchanged.
``err_bound`` grows with the worst-case steps-since-keyframe
(``base + (R-1)*threshold``), so the ``max_err`` gate forces honest
resync cadences.  Degenerate parameters (``resync_every=1``, or a change
fraction at which deltas stop paying) collapse every field to the base
codec's exactly — bit-for-bit the non-delta path.  The matching stateful
data plane (reference cache, mask packing, eviction→resync) lives in
``runtime/partition.py``; the measured-vs-planned change fraction drives
``RoboECC.observe_change_frac`` and the fleet's drift replans
(``runtime/fleet.py``), with scene-dynamics traces from ``core/scene.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

from .hardware import DeviceSpec

BLOCK = 128                      # scale-block size (matches the kernels)


@dataclasses.dataclass(frozen=True)
class Codec:
    """One wire format for the cut activation.

    ``raw_bytes_per_elem`` is the uncompressed on-wire element size the
    factors are quoted against (2 = bf16, the repo-wide ``Workload``
    default).  ``bytes_per_elem`` includes all sideband overhead (scales,
    indices).  FLOPs / move-bytes are per *element*, one-sided (encode and
    decode each have their own pair).
    """
    name: str
    bytes_per_elem: float
    raw_bytes_per_elem: float = 2.0
    enc_flops_per_elem: float = 0.0
    enc_move_bytes_per_elem: float = 0.0
    dec_flops_per_elem: float = 0.0
    dec_move_bytes_per_elem: float = 0.0
    err_bound: float = 0.0

    # ------------------------------------------------------------- wire
    @property
    def wire_factor(self) -> float:
        """wire_bytes / raw_bytes (1.0 for identity)."""
        return self.bytes_per_elem / self.raw_bytes_per_elem

    def wire_bytes(self, raw_bytes: float) -> float:
        """Bytes on the network for ``raw_bytes`` of raw activation."""
        return raw_bytes * self.wire_factor

    # ---------------------------------------------------------- compute
    def _side_s_per_byte(self, flops: float, move: float, dev: DeviceSpec
                         ) -> float:
        """max(compute, memory) seconds per raw byte on ``dev`` (Eq. 2
        roofline form; linear in bytes by construction)."""
        elems_per_byte = 1.0 / self.raw_bytes_per_elem
        t_comp = flops * elems_per_byte / (dev.peak_flops * dev.eta_compute)
        t_mem = move * elems_per_byte / (dev.hbm_bw * dev.eta_mem)
        return max(t_comp, t_mem)

    def encode_s_per_byte(self, dev: DeviceSpec) -> float:
        return self._side_s_per_byte(self.enc_flops_per_elem,
                                     self.enc_move_bytes_per_elem, dev)

    def decode_s_per_byte(self, dev: DeviceSpec) -> float:
        return self._side_s_per_byte(self.dec_flops_per_elem,
                                     self.dec_move_bytes_per_elem, dev)

    def encode_s(self, raw_bytes: float, dev: DeviceSpec) -> float:
        """Seconds to encode ``raw_bytes`` of activation on ``dev``."""
        return raw_bytes * self.encode_s_per_byte(dev)

    def decode_s(self, raw_bytes: float, dev: DeviceSpec) -> float:
        """Seconds to decode on ``dev`` (the receiving tier)."""
        return raw_bytes * self.decode_s_per_byte(dev)


def transport_s(raw_bytes: float, bandwidth_bps: float, codec: "Codec",
                edge: Optional[DeviceSpec] = None,
                cloud: Optional[DeviceSpec] = None,
                rtt_s: float = 0.0) -> float:
    """End-to-end split-boundary transport: encode (edge) + wire + rtt +
    decode (cloud).  Devices are optional — without them codec compute is
    unpriced (wire-only), which is what the ΔNB adjuster uses when called
    without hardware context."""
    t = codec.wire_bytes(raw_bytes) / bandwidth_bps + rtt_s
    if edge is not None:
        t += codec.encode_s(raw_bytes, edge)
    if cloud is not None:
        t += codec.decode_s(raw_bytes, cloud)
    return t


@dataclasses.dataclass(frozen=True)
class DeltaCodec(Codec):
    """Cross-step temporal-delta transport priced as a plain ``Codec``.

    The inherited cost fields are the CYCLE-AVERAGED expected costs of one
    key-frame period (see ``make_delta_codec`` — they stay linear per raw
    byte, so planners need no special casing); the extra fields record the
    parameters the data plane (``runtime/partition.py``) and the fleet's
    measured pricing (``runtime/fleet.py``) execute with:

    * ``base``        — name of the per-frame payload codec (key frames
      ship the full base-encoded activation; delta frames the changed
      rows, base-encoded);
    * ``change_frac`` — expected fraction of token rows changed per step
      (the scene-dependent parameter plans carry; measured drift beyond
      tolerance triggers ``RoboECC.observe_change_frac`` replans);
    * ``resync_every``— key-frame cadence R (a key frame every R steps
      bounds worst-case staleness);
    * ``threshold``   — per-row relative change threshold τ: rows moving
      less than τ x the activation scale are not shipped, so each
      unshipped step adds at most τ relative error — hence
      ``err_bound = base_err + (R-1) * τ``;
    * ``row_elems``   — elements per token row (hidden dim) the 1-bit
      row mask is amortized over.
    """
    base: str = "int8"
    change_frac: float = 0.15
    resync_every: int = 8
    threshold: float = 0.02
    row_elems: int = 4096


def make_delta_codec(base: Union[str, Codec] = "int8",
                     change_frac: float = 0.15,
                     resync_every: int = 8,
                     threshold: float = 0.02,
                     row_elems: int = 4096,
                     raw_bytes_per_elem: float = 2.0,
                     name: str = "delta") -> DeltaCodec:
    """Build a temporal-delta codec whose planner-facing fields are the
    expected per-element costs averaged over one key-frame period.

    With base per-element wire cost ``b``, change fraction ``p``, mask
    cost ``m = 1/(8*row_elems)`` and cadence ``R = resync_every``:

    * a key frame costs exactly the base codec (full re-encode, the cloud
      reference is rewritten — bit-exact reconstruction of the non-delta
      path, checked by the data-plane tests);
    * a delta frame costs ``p*b + m`` wire plus a compare pass on every
      row (the edge must diff against its reference mirror) and the base
      encode/decode of only the changed rows;
    * the cycle average weighs 1 key frame and ``R-1`` delta frames.

    Degenerate settings — ``resync_every <= 1``, or ``p*b + m >= b`` (true
    at ``change_frac = 1.0``: deltas cannot pay, every frame is a key
    frame) — return a ``DeltaCodec`` whose every cost field EQUALS the
    base codec's, so plans and prices reproduce the non-delta codec
    bit-for-bit (the encoder then ships only key frames and skips the
    compare pass).

    ``err_bound = base_err + (R-1)*threshold``: between key frames an
    unshipped row may drift by up to τ per step relative to the reference,
    so the planner's ``max_err`` gate forces small R honestly."""
    if isinstance(base, Codec):
        b = base
    else:
        b = make_codecs(raw_bytes_per_elem)[base]
    r = b.raw_bytes_per_elem
    p = min(max(float(change_frac), 0.0), 1.0)
    R = int(resync_every)
    mask_bpe = 1.0 / (8.0 * row_elems)
    delta_bpe = p * b.bytes_per_elem + mask_bpe
    common = dict(name=name, raw_bytes_per_elem=r, base=b.name,
                  change_frac=p, resync_every=R, threshold=float(threshold),
                  row_elems=int(row_elems))
    if R <= 1 or delta_bpe >= b.bytes_per_elem:
        # degenerate: every frame is a key frame — all fields equal the
        # base codec exactly (bit-for-bit the non-delta path)
        return DeltaCodec(bytes_per_elem=b.bytes_per_elem,
                          enc_flops_per_elem=b.enc_flops_per_elem,
                          enc_move_bytes_per_elem=b.enc_move_bytes_per_elem,
                          dec_flops_per_elem=b.dec_flops_per_elem,
                          dec_move_bytes_per_elem=b.dec_move_bytes_per_elem,
                          err_bound=b.err_bound, **common)
    share = (R - 1.0) / R       # delta-frame weight in the cycle average
    key = 1.0 / R
    return DeltaCodec(
        bytes_per_elem=key * b.bytes_per_elem + share * delta_bpe,
        # delta frames pay a 2-FLOP/elem compare pass (diff + row-max
        # reduce) over the full activation plus the base encode of the
        # changed fraction; key frames the plain base encode
        enc_flops_per_elem=key * b.enc_flops_per_elem
        + share * (2.0 + p * b.enc_flops_per_elem),
        # compare reads current + reference mirror (2r); changed rows
        # then move through the base encoder
        enc_move_bytes_per_elem=key * b.enc_move_bytes_per_elem
        + share * (2.0 * r + p * b.enc_move_bytes_per_elem),
        # cloud: key frames base-decode + rewrite the reference (+r);
        # delta frames read the reference and scatter the decoded rows
        dec_flops_per_elem=key * b.dec_flops_per_elem
        + share * (1.0 + p * b.dec_flops_per_elem),
        dec_move_bytes_per_elem=key * (b.dec_move_bytes_per_elem + r)
        + share * (r + p * b.dec_move_bytes_per_elem),
        err_bound=b.err_bound + (R - 1) * float(threshold),
        **common)


# ------------------------------------------------------------------ zoo
def make_codecs(raw_bytes_per_elem: float = 2.0, block: int = BLOCK,
                topk_frac: float = 0.25) -> Dict[str, Codec]:
    """Build the codec registry for a given raw element size.

    Formats (per-element wire cost, ``block``-element scale groups):

    * ``identity`` — raw bytes through, zero compute, lossless.
    * ``fp16``     — 2-byte float cast (a no-op when raw is already bf16,
      a 2x cut from f32); 1 cast FLOP/elem, ~2^-11 relative error.
    * ``int8``     — block-scaled int8 (`kernels/activation_codec`):
      1 B/elem + 4 B scale per block; fused absmax+scale+round ≈ 4
      FLOPs/elem encode, 2 FLOPs/elem decode; err ≤ 1/127.
    * ``int4``     — block-scaled packed int4 (Pallas pack/unpack kernel):
      0.5 B/elem + 4 B scale per block; ≈ 6 FLOPs/elem encode (absmax,
      scale, round, bias, nibble mul-add), 4 decode; err ≤ 1/7.
    * ``topk``     — per-block top-``topk_frac`` magnitude sparsification:
      kept elements ship fp16 value + 1-byte in-block index
      (3 B × frac per elem); selection ≈ 16 FLOPs/elem encode, scatter
      ≈ 2 decode; ``err_bound`` is the dropped-coefficient L2 proxy.
    * ``delta``    — cross-step temporal deltas over an int8 base
      (``make_delta_codec`` defaults: expected change fraction 0.15,
      key frame every 8 steps, row threshold 0.02): cycle-averaged wire
      ≈ 0.27 B/elem, err grows with steps-since-keyframe.  Scene-specific
      variants come from ``make_delta_codec`` directly.
    """
    r = raw_bytes_per_elem
    scale_b = 4.0 / block
    out = {
        "identity": Codec("identity", bytes_per_elem=r,
                          raw_bytes_per_elem=r),
        "fp16": Codec("fp16", bytes_per_elem=2.0, raw_bytes_per_elem=r,
                      enc_flops_per_elem=1.0,
                      enc_move_bytes_per_elem=r + 2.0,
                      dec_flops_per_elem=1.0,
                      dec_move_bytes_per_elem=2.0 + r,
                      err_bound=2.0 ** -11),
        "int8": Codec("int8", bytes_per_elem=1.0 + scale_b,
                      raw_bytes_per_elem=r,
                      enc_flops_per_elem=4.0,
                      enc_move_bytes_per_elem=r + 1.0 + scale_b,
                      dec_flops_per_elem=2.0,
                      dec_move_bytes_per_elem=1.0 + scale_b + r,
                      err_bound=1.0 / 127.0),
        "int4": Codec("int4", bytes_per_elem=0.5 + scale_b,
                      raw_bytes_per_elem=r,
                      enc_flops_per_elem=6.0,
                      enc_move_bytes_per_elem=r + 0.5 + scale_b,
                      dec_flops_per_elem=4.0,
                      dec_move_bytes_per_elem=0.5 + scale_b + r,
                      err_bound=1.0 / 7.0),
        "topk": Codec("topk", bytes_per_elem=3.0 * topk_frac,
                      raw_bytes_per_elem=r,
                      enc_flops_per_elem=16.0,
                      enc_move_bytes_per_elem=r + 3.0 * topk_frac,
                      dec_flops_per_elem=2.0,
                      dec_move_bytes_per_elem=3.0 * topk_frac + r,
                      err_bound=0.45),
    }
    # registered AFTER the bases so the default delta can reference the
    # int8 instance of THIS registry (same raw element size)
    out["delta"] = make_delta_codec(base=out["int8"],
                                    raw_bytes_per_elem=r)
    return out


CODECS: Dict[str, Codec] = make_codecs()
IDENTITY = CODECS["identity"]

CodecLike = Union[str, Codec, None]


def get_codec(codec: CodecLike) -> Optional[Codec]:
    """Resolve a codec name / instance / None (``None`` passes through:
    callers treat it as "no codec", i.e. raw-byte transport)."""
    if codec is None or isinstance(codec, Codec):
        return codec
    try:
        return CODECS[codec]
    except KeyError:
        raise KeyError(
            f"unknown codec {codec!r}; have {sorted(CODECS)}") from None


def resolve_codecs(codecs: Optional[Sequence[CodecLike]],
                   max_err: Optional[float] = None
                   ) -> Optional[Tuple[Codec, ...]]:
    """Resolve a codec list for a planner's codec axis, optionally dropping
    codecs whose ``err_bound`` exceeds ``max_err``.  Order is preserved —
    planners break latency ties toward the *earlier* codec, so put the
    preferred (usually lossless) codec first."""
    if codecs is None:
        return None
    out = [get_codec(c) for c in codecs]
    if any(c is None for c in out):
        raise ValueError("None is not a valid member of a codec axis; "
                         "use 'identity'")
    if max_err is not None:
        out = [c for c in out if c.err_bound <= max_err]
    if not out:
        raise ValueError(f"no codec satisfies max_err={max_err}")
    return tuple(out)
