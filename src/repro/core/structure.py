"""Structure modeling of VLA/LM models — paper Eq. 1.

The paper divides a VLA into ``[S_enc, S_bac, S_dec]`` with
``S_enc ∈ {ViT}``, ``S_bac ∈ {LLM}``,
``S_dec ∈ {De-tokenizer, MLP, LSTM, Diffusion, DiT}`` and looks up per-layer
``(C_compute, C_datamove)``.  We implement that mapping *analytically* from
the ModelConfig (equivalent information to the paper's measured lookup
table; DESIGN.md §8), producing a **flattened layer graph** shared by

* Alg. 1 segmentation (core/segmentation.py),
* the parameter-sharing pool (core/pool.py),
* the paper-table benchmarks (benchmarks/),
* napkin math in §Perf.

Key heterogeneity captured: action-model layers with ``repeat > 1``
(diffusion/DiT denoise loops) multiply both compute *and* the transfer
volume if the cut lands inside them — this is exactly why CogACT's optimal
split avoids the DiT region (paper Fig. 2).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from ..configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class LayerCost:
    name: str
    kind: str                 # vit | llm | moe | mamba | cross | dit | head | ...
    flops: float              # per request (includes `repeat`)
    weight_bytes: float
    datamove_bytes: float     # HBM traffic per request (weights + activations)
    out_transfer_bytes: float # wire bytes if the model is cut AFTER this layer
    repeat: int = 1
    # wire bytes a segment STARTING at this layer actually needs when the
    # producing segment ships only what the consumer reads (a cloud→edge
    # downlink cut in a multi-cut placement, core/placement.py).  ``None``
    # means "the full upstream activation" (the previous layer's
    # out_transfer_bytes).  Action heads consume a small conditioning
    # slice — OpenVLA's de-tokenizer reads the final ``action_dim`` token
    # positions, CogACT's DiT reads the single cognition token — which is
    # what makes the edge→cloud→edge return leg cheap (ActionFlow's
    # action-stage-on-edge pattern).
    in_transfer_bytes: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Workload:
    """One VLA/LM inference request (paper §III setting: batch 1).

    ``decode_steps`` models the autoregressive tail (OpenVLA emits 7 action
    tokens one by one): every decode step re-reads the layer weights (the
    memory-bound regime that makes edge-only so slow) and ships a 1-token
    activation across the cut.  ``input_bytes`` is the raw observation
    (image + prompt) that must be shipped for cloud-only (split=0).
    """
    batch: int = 1
    s_new: int = 17           # tokens whose activations cross the cut
    s_ctx: int = 290          # attention context (image + prompt tokens)
    decode_steps: int = 7     # autoregressive action tokens (detok VLAs)
    act_bytes: int = 2        # bf16 activations on the wire
    wbits: int = 16           # weight bytes for load/traffic (fp16 residency)
    input_bytes: float = 224 * 224 * 3 + 2048   # raw image + prompt

    @property
    def wbytes(self) -> float:
        return self.wbits / 8.0


def _attn_flops(cfg: ModelConfig, S: int, T: int) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    proj = 2 * S * d * (H + 2 * KV) * hd + 2 * S * H * hd * d
    attn = 2 * S * T * H * hd * 2  # qk + av
    return proj + attn


def _mla_flops(cfg: ModelConfig, S: int, T: int) -> float:
    d, H = cfg.d_model, cfg.n_heads
    r, qn, qr, vd = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    proj = 2 * S * d * H * (qn + qr) + 2 * S * d * (r + qr) \
        + 2 * S * r * H * (qn + vd) + 2 * S * H * vd * d
    attn = 2 * S * T * H * (qn + qr) + 2 * S * T * H * vd
    return proj + attn


def _attn_weight_count(cfg: ModelConfig) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    if cfg.use_mla:
        r, qn, qr, vd = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        return d * cfg.n_heads * (qn + qr) + d * (r + qr) \
            + r * cfg.n_heads * (qn + vd) + cfg.n_heads * vd * d
    return d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd + cfg.n_heads * hd * d


def _mamba_flops(cfg: ModelConfig, S: int) -> float:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, P, W, Q = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_conv, cfg.ssm_chunk
    proj = 2 * S * d * (2 * di + 2 * N + H) + 2 * S * di * d
    conv = 2 * S * (di + 2 * N) * W
    Qe = min(Q, max(S, 1))
    ssd = 2 * S * Qe * (N + H * P) + 4 * S * H * N * P
    return proj + conv + ssd


def _block_cost(cfg: ModelConfig, w: Workload, name: str, kind: str,
                flops_one: float, weight_count: float,
                d_out: Optional[int] = None, repeat: int = 1,
                s_out: Optional[int] = None,
                decode_steps: Optional[int] = None,
                in_transfer_bytes: Optional[float] = None) -> LayerCost:
    """decode_steps: autoregressive invocations of this layer after prefill
    (weights re-read each step; 1-token activation crosses the cut each
    step).  Backbone layers inherit ``w.decode_steps``; ViT/enc/action-model
    layers run once per request (decode_steps=0)."""
    d_out = d_out if d_out is not None else cfg.d_model
    s_out = s_out if s_out is not None else w.s_new
    ds = w.decode_steps if decode_steps is None else decode_steps
    wbytes = weight_count * w.wbytes
    # flops: prefill pass + per-token decode passes (~flops_one / s_new each)
    per_tok = flops_one / max(w.s_new, 1)
    flops = (flops_one + ds * per_tok) * w.batch * repeat
    act_traffic = 2 * w.batch * (s_out + ds) * d_out * w.act_bytes
    reads = 1 + ds
    return LayerCost(
        name=name, kind=kind,
        flops=flops,
        weight_bytes=wbytes,
        datamove_bytes=(wbytes * reads + act_traffic) * repeat,
        out_transfer_bytes=w.batch * (s_out + ds) * d_out * w.act_bytes
        * repeat,
        repeat=repeat,
        in_transfer_bytes=in_transfer_bytes,
    )


def build_graph(cfg: ModelConfig, w: Workload = Workload()) -> List[LayerCost]:
    """Flattened per-request layer graph in execution order."""
    S, T = w.s_new, w.s_ctx
    g: List[LayerCost] = []

    # ---- S_enc: ViT (VLA family) ----------------------------------------
    if cfg.family == "vla" and cfg.vit_layers:
        dv = cfg.vit_dim
        P = cfg.n_patches
        attn = 2 * P * dv * 4 * dv + 2 * P * P * dv * 2
        mlp = 2 * P * 3 * (4 * dv) * dv  # ~GELU MLP ≈ 2*P*2*4dv*dv; use swiglu-equiv
        wcount = 4 * dv * dv + 8 * dv * dv
        for i in range(cfg.vit_layers):
            g.append(_block_cost(cfg, w, f"vit.{i}", "vit", attn + mlp,
                                 wcount, d_out=dv, s_out=P, decode_steps=0))
        g.append(_block_cost(cfg, w, "vit.proj", "vit",
                             2 * P * dv * cfg.d_model, dv * cfg.d_model,
                             s_out=P, decode_steps=0))

    # ---- encoder (audio enc-dec) -----------------------------------------
    if cfg.family == "audio":
        enc_f = _attn_flops(cfg, w.s_ctx, w.s_ctx) \
            + 2 * w.s_ctx * 3 * cfg.d_model * cfg.d_ff
        enc_w = _attn_weight_count(cfg) + 3 * cfg.d_model * cfg.d_ff
        for i in range(cfg.n_enc_layers):
            g.append(_block_cost(cfg, w, f"enc.{i}", "enc", enc_f, enc_w,
                                 s_out=w.s_ctx, decode_steps=0))

    # ---- embedding -------------------------------------------------------
    if cfg.family != "vla":
        g.append(_block_cost(cfg, w, "embed", "embed", 0.0,
                             cfg.vocab_size * cfg.d_model, decode_steps=0))

    # ---- S_bac / backbone blocks ----------------------------------------
    d = cfg.d_model
    if cfg.family in ("dense", "vlm", "vla", "audio"):
        attn_f = _attn_flops(cfg, S, T)
        mlp_f = 2 * S * 3 * d * cfg.d_ff
        wcount = _attn_weight_count(cfg) + 3 * d * cfg.d_ff
        n = cfg.n_dec_layers if cfg.family == "audio" else cfg.n_layers
        for i in range(n):
            extra_f, extra_w = 0.0, 0.0
            if (cfg.family == "vlm" and cfg.cross_attn_every
                    and (i + 1) % cfg.cross_attn_every == 0):
                extra_f = _attn_flops(cfg, S, cfg.n_vision_tokens)
                extra_w = _attn_weight_count(cfg) + 3 * d * cfg.d_ff
            if cfg.family == "audio":
                extra_f = _attn_flops(cfg, S, T)   # cross-attn to encoder
                extra_w = _attn_weight_count(cfg)
            g.append(_block_cost(cfg, w, f"llm.{i}", "llm",
                                 attn_f + mlp_f + extra_f,
                                 wcount + extra_w))
    elif cfg.family == "moe":
        attn_f = _mla_flops(cfg, S, T) if cfg.use_mla else _attn_flops(cfg, S, T)
        for i in range(cfg.n_layers):
            if i < cfg.first_dense_layers:
                ffn_f = 2 * S * 3 * d * cfg.d_ff
                ffn_w = 3 * d * cfg.d_ff
                kind = "llm"
            else:
                k, fe = cfg.moe_top_k, cfg.moe_d_ff
                ffn_f = 2 * S * d * cfg.n_experts \
                    + 2 * S * (k + cfg.n_shared_experts) * 3 * d * fe
                ffn_w = cfg.n_experts * 3 * d * fe + d * cfg.n_experts \
                    + cfg.n_shared_experts * 3 * d * fe
                kind = "moe"
            g.append(_block_cost(cfg, w, f"llm.{i}", kind,
                                 attn_f + ffn_f,
                                 _attn_weight_count(cfg) + ffn_w))
    elif cfg.family == "ssm":
        for i in range(cfg.n_layers):
            g.append(_block_cost(cfg, w, f"ssm.{i}", "mamba",
                                 _mamba_flops(cfg, S),
                                 cfg._mamba_params()))
    elif cfg.family == "hybrid":
        shared_f = _attn_flops(cfg, S, T) + 2 * S * 3 * d * cfg.d_ff
        shared_w = _attn_weight_count(cfg) + 3 * d * cfg.d_ff
        for i in range(cfg.n_layers):
            if cfg.shared_attn_every and i % cfg.shared_attn_every == 0:
                # shared block weights live on BOTH tiers by construction;
                # weight_bytes counted once at the first site
                g.append(_block_cost(cfg, w, f"shared.{i}", "llm", shared_f,
                                     shared_w if i == 0 else 0.0))
            g.append(_block_cost(cfg, w, f"ssm.{i}", "mamba",
                                 _mamba_flops(cfg, S),
                                 cfg._mamba_params()))

    # ---- S_dec: action model / head --------------------------------------
    if cfg.family == "vla":
        kind = cfg.vla_action_head
        # the action stage consumes a small conditioning slice of the final
        # backbone activation (detok: the last action_dim token positions;
        # DiT/MLP/LSTM/diffusion: the single cognition token) — the
        # downlink bytes of an edge→cloud→edge placement's second cut
        detok_in = w.batch * cfg.action_dim * d * w.act_bytes
        cog_in = w.batch * 1 * d * w.act_bytes
        if kind in ("detok", ""):
            g.append(_block_cost(cfg, w, "detok", "head",
                                 2 * cfg.action_dim * d * cfg.vocab_size,
                                 cfg.vocab_size * d,
                                 d_out=cfg.action_dim, s_out=1,
                                 decode_steps=0, in_transfer_bytes=detok_in))
        elif kind == "dit":
            dd, hor = cfg.dit_dim, cfg.action_horizon
            reps = cfg.diffusion_steps
            attn = 2 * hor * dd * 4 * dd + 2 * hor * hor * dd * 2
            mlp = 2 * hor * 2 * (4 * dd) * dd
            ada = 2 * hor * 6 * dd * dd
            wcount = 4 * dd * dd + 8 * dd * dd + 6 * dd * dd
            for i in range(cfg.dit_layers):
                g.append(_block_cost(cfg, w, f"dit.{i}", "dit",
                                     (attn + mlp + ada), wcount,
                                     d_out=dd, s_out=hor, repeat=reps,
                                     decode_steps=0,
                                     in_transfer_bytes=cog_in
                                     if i == 0 else None))
        elif kind == "mlp":
            g.append(_block_cost(cfg, w, "am.mlp", "am",
                                 2 * (4 * d * d + 4 * d * d), 8 * d * d,
                                 d_out=cfg.action_dim,
                                 s_out=cfg.action_horizon, decode_steps=0,
                                 in_transfer_bytes=cog_in))
        elif kind == "lstm":
            g.append(_block_cost(cfg, w, "am.lstm", "am",
                                 cfg.action_horizon * 2 * 8 * d * d,
                                 8 * d * d, d_out=cfg.action_dim,
                                 s_out=cfg.action_horizon,
                                 repeat=cfg.action_horizon, decode_steps=0,
                                 in_transfer_bytes=cog_in))
        elif kind == "diffusion":
            g.append(_block_cost(cfg, w, "am.diff", "am",
                                 2 * 3 * d * d, 3 * d * d,
                                 d_out=cfg.action_dim,
                                 s_out=cfg.action_horizon,
                                 repeat=cfg.diffusion_steps, decode_steps=0,
                                 in_transfer_bytes=cog_in))
    else:
        g.append(_block_cost(cfg, w, "head", "head",
                             2 * S * d * cfg.vocab_size,
                             0.0 if cfg.tie_embeddings
                             else cfg.vocab_size * d,
                             d_out=cfg.vocab_size))
    return g


def total_weight_bytes(graph: List[LayerCost]) -> float:
    return sum(c.weight_bytes for c in graph)


def total_flops(graph: List[LayerCost]) -> float:
    return sum(c.flops for c in graph)
