"""Parameter-sharing pool — paper §IV-B-2.

All layers of the region containing the optimal segmentation point are kept
resident on BOTH tiers, so the split can move inside the pool without
shipping weights.  The paper sizes the pool at "the block containing the
optimal segmentation point" and reports a 2.55–2.62 % weight overhead
(Fig. 6); we size it the same way: grow symmetrically around the optimal
split until the next layer would exceed ``overhead_target`` of total model
weights (at least one layer on each side when possible).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from .structure import LayerCost, total_weight_bytes


@dataclasses.dataclass(frozen=True)
class Pool:
    start: int                   # first layer index in the pool
    end: int                     # one-past-last
    bytes: float                 # pooled weight bytes (replicated once extra)
    overhead_frac: float         # bytes / total model bytes

    def splits(self) -> range:
        """Candidate split positions inside the pool (layer boundaries)."""
        return range(self.start, self.end + 1)

    def contains(self, split: int) -> bool:
        return self.start <= split <= self.end

    def clamp(self, split: int) -> int:
        """Nearest in-pool split position: a planned cut outside
        ``[start, end]`` would ship weights, so it snaps to the pool
        edge.  Equivalent to ``np.clip(split, start, end)`` but stays in
        plain Python ints — the fleet hot path calls this per request."""
        return min(max(int(split), self.start), self.end)


def build_pool(graph: Sequence[LayerCost], optimal_split: int,
               overhead_target: float = 0.026) -> Pool:
    """Grow [start, end) around the split, greedily adding the *cheapest*
    neighbouring layer first.  This maximises the number of candidate split
    positions inside the byte budget — letting the pool span structure
    transitions (e.g. LLM→DiT) where transfer volumes actually differ, which
    is what makes the ΔNB adjustment effective (paper Fig. 3).  If no
    neighbour fits the budget, the smaller one is included anyway (the paper
    always pools at least the block containing the split)."""
    n = len(graph)
    total = total_weight_bytes(graph)
    budget = overhead_target * total
    lo = hi = max(0, min(optimal_split, n))
    pooled = 0.0
    while True:
        cand = []
        if lo > 0:
            cand.append(("lo", graph[lo - 1].weight_bytes))
        if hi < n:
            cand.append(("hi", graph[hi].weight_bytes))
        if not cand:
            break
        side, cost = min(cand, key=lambda t: t[1])
        if pooled + cost > budget:
            if pooled > 0.0:
                break
            # force-include the cheaper neighbour (≥1 pooled layer)
        if side == "lo":
            lo -= 1
        else:
            hi += 1
        pooled += cost
        if pooled > budget:
            break
    return Pool(start=lo, end=hi, bytes=pooled,
                overhead_frac=pooled / total if total else 0.0)


def pool_transfer_profile(graph: Sequence[LayerCost], pool: Pool,
                          codec=None) -> List[float]:
    """Wire bytes for each candidate split inside the pool.  ``codec``
    (name or ``core.codec.Codec``) reports the *compressed* on-wire bytes
    a robot pinned to that codec would ship — a reporting/benchmark view;
    the ΔNB adjuster prices its joint split×codec move itself in
    ``core/adjustment.py`` (per-codec, with encode/decode compute)."""
    from .codec import get_codec
    from .segmentation import codec_applies, cut_bytes
    c = get_codec(codec)
    out = []
    for s in pool.splits():
        raw = cut_bytes(graph, s)
        if c is not None and codec_applies(s, len(graph)):
            raw = c.wire_bytes(raw)
        out.append(raw)
    return out
