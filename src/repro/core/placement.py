"""K-segment edge/cloud placement plans — the multi-cut generalization.

Every decision layer in this repo historically carried one bare ``int``:
split ``S`` meant layers ``[0, S)`` on the edge and ``[S, n)`` on the
cloud.  That representation cannot express the placement real VLA stacks
often want — *edge → cloud → edge*, where the heavy LLM trunk is offloaded
but the byte-heavy, compute-light action head stays on the robot (RAPID,
arXiv 2603.07949, makes the multi-segment compatibility argument;
ActionFlow, arXiv 2512.20276, shows the action-stage-on-edge pattern).

``PlacementPlan`` is the shared first-class plan object:

* ``cuts`` — ordered layer indices where the model is severed (K cuts make
  K+1 segments over ``[0, n)``; segment ``i`` spans ``[cuts[i-1], cuts[i])``
  with the implicit boundaries 0 and n);
* ``tiers`` — one tier name per segment (``"edge"`` / ``"cloud"``);
* ``cut_codecs`` — one transport codec name per cut (``None`` = raw), the
  per-cut companion of ``core/codec.py``;
* ``cut_chunks`` — one streaming chunk count per cut (``1`` = the
  sequential transfer), the per-cut companion of ``core/pipeline.py``:
  a cut with ``n_chunks > 1`` ships its activation in token-axis chunk
  slices through the 3-stage (encode → uplink → decode+prefill)
  pipeline, so the planner prices a makespan instead of a sum.

The single-split world is the K=1 special case (``PlacementPlan.single``),
and an empty-segment plan normalizes back down to it — so every consumer
(``segmentation.evaluate_placement`` / ``search_multicut``,
``adjustment.adjust_placement``, ``controller.RoboECC(multicut=True)``,
``runtime/fleet.py``) degrades to the paper's Alg. 1 behaviour when no
second cut pays for itself.

Transport direction is derived from the tier pair around a cut: an
edge→cloud cut is an **uplink** (priced on the robot's uplink bandwidth,
encode on the edge device) and a cloud→edge cut is a **downlink** (priced
on the usually-faster downlink direction — ``down_bw_factor`` — encode on
the cloud device, and carrying only the bytes the receiving segment
consumes, see ``LayerCost.in_transfer_bytes``).  Each cut pays its own
rtt.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

EDGE = "edge"
CLOUD = "cloud"
_TIERS = (EDGE, CLOUD)


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """Ordered cut list + per-segment tier + per-cut codec + per-cut
    streaming chunk count.

    Invariants (checked at construction): ``cuts`` non-decreasing and
    non-negative, ``len(tiers) == len(cuts) + 1``, every tier in
    {"edge", "cloud"}, ``len(cut_codecs) == len(cuts)``,
    ``len(cut_chunks) == len(cuts)`` with every count ``>= 1``.
    Zero-width segments are allowed in the raw representation
    (``normalize`` removes them); they make degenerate forms like
    ``single(n)`` (edge-only with an empty cloud segment) representable
    in the repo's historical encoding.
    """
    cuts: Tuple[int, ...]
    tiers: Tuple[str, ...]
    cut_codecs: Tuple[Optional[str], ...] = ()
    cut_chunks: Tuple[int, ...] = ()

    def __post_init__(self):
        cuts = tuple(int(c) for c in self.cuts)
        tiers = tuple(self.tiers)
        codecs = tuple(self.cut_codecs) if self.cut_codecs \
            else (None,) * len(cuts)
        chunks = tuple(int(k) for k in self.cut_chunks) if self.cut_chunks \
            else (1,) * len(cuts)
        object.__setattr__(self, "cuts", cuts)
        object.__setattr__(self, "tiers", tiers)
        object.__setattr__(self, "cut_codecs", codecs)
        object.__setattr__(self, "cut_chunks", chunks)
        if len(tiers) != len(cuts) + 1:
            raise ValueError(f"need {len(cuts) + 1} tiers for "
                             f"{len(cuts)} cuts, got {len(tiers)}")
        if len(codecs) != len(cuts):
            raise ValueError(f"need {len(cuts)} cut_codecs, got {len(codecs)}")
        if len(chunks) != len(cuts):
            raise ValueError(f"need {len(cuts)} cut_chunks, got {len(chunks)}")
        if any(k < 1 for k in chunks):
            raise ValueError(f"cut_chunks must be >= 1, got {chunks}")
        if any(t not in _TIERS for t in tiers):
            raise ValueError(f"tiers must be in {_TIERS}, got {tiers}")
        if any(c < 0 for c in cuts):
            raise ValueError(f"cuts must be non-negative, got {cuts}")
        if any(a > b for a, b in zip(cuts, cuts[1:])):
            raise ValueError(f"cuts must be non-decreasing, got {cuts}")

    # ------------------------------------------------------------ factories
    @classmethod
    def single(cls, split: int, codec: Optional[str] = None,
               n_chunks: int = 1) -> "PlacementPlan":
        """The historical K=1 plan: edge ``[0, split)``, cloud
        ``[split, n)``.  ``split == n`` is edge-only, ``split == 0``
        cloud-only — same semantics as ``SegmentationResult.split``.
        ``n_chunks`` streams the uplink cut (``core/pipeline.py``)."""
        return cls(cuts=(split,), tiers=(EDGE, CLOUD), cut_codecs=(codec,),
                   cut_chunks=(n_chunks,))

    @classmethod
    def edge_cloud_edge(cls, s1: int, s2: int,
                        up_codec: Optional[str] = None,
                        down_codec: Optional[str] = None,
                        up_chunks: int = 1) -> "PlacementPlan":
        """The VLA-shaped K=2 plan: edge ``[0, s1)`` (vision front), cloud
        ``[s1, s2)`` (LLM trunk), edge ``[s2, n)`` (action tail).
        ``up_chunks`` streams the uplink cut; the downlink carries the
        small semantic tail slice and never streams (DESIGN.md §9)."""
        return cls(cuts=(s1, s2), tiers=(EDGE, CLOUD, EDGE),
                   cut_codecs=(up_codec, down_codec),
                   cut_chunks=(up_chunks, 1))

    @classmethod
    def from_window(cls, s1: int, s2: int, n: int,
                    codec: Optional[str] = None,
                    n_chunks: int = 1) -> "PlacementPlan":
        """Canonical plan for the cloud window ``[s1, s2)`` of an
        ``n``-layer graph — the one degenerate-case branch every
        materializer shares: ``s2 >= n`` is the single cut at ``s1``,
        ``s1 >= s2`` (empty window) is edge-only (``single(n)``),
        otherwise the real 2-cut edge→cloud→edge plan (both cuts on
        ``codec``).  ``n_chunks`` rides the uplink cut; degenerate
        no-transfer plans pin it back to 1 (streaming nothing is the
        sequential transfer by definition)."""
        if s2 >= n:
            return cls.single(s1, codec, n_chunks if 0 < s1 < n else 1)
        if s1 >= s2:
            return cls.single(n, codec)
        return cls.edge_cloud_edge(s1, s2, codec, codec, n_chunks)

    # ----------------------------------------------------------- structure
    @property
    def n_cuts(self) -> int:
        return len(self.cuts)

    @property
    def is_single(self) -> bool:
        """True when the plan is expressible as one split index (≤1 cut)."""
        return len(self.cuts) <= 1

    def segments(self, n: int) -> Tuple[Tuple[int, int, str], ...]:
        """``(start, end, tier)`` triples covering ``[0, n)`` in order
        (zero-width segments included; see ``normalize``)."""
        bounds = (0,) + self.cuts + (n,)
        return tuple((bounds[i], bounds[i + 1], self.tiers[i])
                     for i in range(len(self.tiers)))

    def normalize(self, n: int) -> "PlacementPlan":
        """Canonical form for a graph of ``n`` layers: drop zero-width
        segments, merge adjacent same-tier segments (removing the cut and
        its codec between them).  ``edge_cloud_edge(s, n)`` normalizes to
        ``single(s)``; an all-edge plan to ``single(n)``; an all-cloud plan
        to ``single(0)`` — the historical encodings."""
        # each non-first segment carries the codec/chunks of its leading cut
        segs = [(a, b, t, self.cut_codecs[i - 1] if i else None,
                 self.cut_chunks[i - 1] if i else 1)
                for i, (a, b, t) in enumerate(self.segments(n)) if b > a]
        merged: list = []
        for a, b, t, cdc, k in segs:
            if merged and merged[-1][2] == t:
                # same-tier neighbours: the cut between them vanishes
                merged[-1] = (merged[-1][0], b, t, merged[-1][3],
                              merged[-1][4])
            else:
                merged.append((a, b, t, cdc, k))
        if not merged:                       # n == 0 degenerate graph
            return PlacementPlan.single(0)
        if len(merged) == 1:
            return PlacementPlan.single(n if merged[0][2] == EDGE else 0)
        return PlacementPlan(
            cuts=tuple(seg[0] for seg in merged[1:]),
            tiers=tuple(seg[2] for seg in merged),
            cut_codecs=tuple(seg[3] for seg in merged[1:]),
            cut_chunks=tuple(seg[4] for seg in merged[1:]))

    def primary_cut(self, n: int) -> int:
        """The first real edge→cloud boundary — what legacy ``split``
        consumers read.  Edge-only plans report ``n``."""
        norm = self.normalize(n)
        return norm.cuts[0] if norm.tiers[0] == EDGE and norm.n_cuts >= 1 \
            else 0

    def primary_chunks(self, n: int) -> int:
        """Streaming chunk count of the primary edge→cloud cut (1 when the
        plan has no real uplink — edge-only / cloud-first plans)."""
        norm = self.normalize(n)
        return norm.cut_chunks[0] if norm.tiers[0] == EDGE \
            and norm.n_cuts >= 1 else 1

    def tail_cut(self, n: int) -> int:
        """The cloud→edge boundary of an edge→cloud→edge plan, or ``n``
        when the plan is single-cut (no on-edge tail)."""
        norm = self.normalize(n)
        if norm.n_cuts >= 2 and norm.tiers[-1] == EDGE:
            return norm.cuts[-1]
        return n

    def describe(self, n: int) -> str:
        parts = []
        for i, (a, b, t) in enumerate(self.segments(n)):
            if b <= a:
                continue
            cdc = self.cut_codecs[i - 1] if 0 < i <= len(self.cut_codecs) \
                else None
            k = self.cut_chunks[i - 1] if 0 < i <= len(self.cut_chunks) else 1
            stream = f" x{k}" if k > 1 else ""
            arrow = f"--{cdc or 'raw'}{stream}--> " if parts else ""
            parts.append(f"{arrow}{t}[{a},{b})")
        return " ".join(parts) if parts else "empty"
