"""Optimal model segmentation — paper Alg. 1.

Split semantics: split index ``S`` means layers ``[0, S)`` run on the edge
and ``[S, n)`` on the cloud; the cut activation is the output of layer
``S-1`` (for ``S=0``, the raw model input is shipped — cloud-only; for
``S=n`` nothing is shipped — edge-only).

The search walks from the last layer towards the front (paper: "start from
the last layer and identify the optimal segmentation point within the
allowable cloud-side load range"), i.e. it grows the cloud set until the
cloud load budget ``B_cloud`` is exhausted, tracking the latency-optimal
feasible split.  All inputs come from the analytic structure+hardware
models, so the search itself costs microseconds (paper §IV-A-3: "extremely
low computational load ... negligible overhead").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from .hardware import DeviceSpec, layer_latency
from .structure import LayerCost


@dataclasses.dataclass(frozen=True)
class SegmentationResult:
    split: int
    total_s: float
    edge_s: float
    cloud_s: float
    net_s: float
    cloud_load_bytes: float
    edge_load_bytes: float
    feasible: List[int]          # splits satisfying the budget
    latencies: List[float]       # total latency per candidate split index


def cut_bytes(graph: Sequence[LayerCost], split: int,
              input_bytes: float = 0.0) -> float:
    """Wire bytes at split S (output activation of layer S-1)."""
    if split == 0:
        return input_bytes
    if split >= len(graph):
        return 0.0
    return graph[split - 1].out_transfer_bytes


def evaluate_split(graph: Sequence[LayerCost], split: int,
                   edge: DeviceSpec, cloud: DeviceSpec,
                   bandwidth_bps: float, *, rtt_s: float = 0.0,
                   input_bytes: float = 0.0):
    edge_s = sum(layer_latency(c, edge) for c in graph[:split])
    cloud_s = sum(layer_latency(c, cloud) for c in graph[split:])
    wire = cut_bytes(graph, split, input_bytes)
    # bandwidth in BYTES/s throughout the repo
    net_s = (wire / bandwidth_bps + rtt_s) if wire else 0.0
    return edge_s, cloud_s, net_s


def search(graph: Sequence[LayerCost], edge: DeviceSpec, cloud: DeviceSpec,
           bandwidth_bps: float, cloud_budget_bytes: Optional[float] = None,
           *, rtt_s: float = 0.0, input_bytes: float = 0.0
           ) -> SegmentationResult:
    """Alg. 1: scan S from n (edge-only) towards 0 while the cloud-side load
    fits the budget; keep the latency-optimal feasible split."""
    n = len(graph)
    budget = cloud_budget_bytes if cloud_budget_bytes is not None else float("inf")
    feasible: List[int] = []
    latencies: List[float] = []
    best = None
    cloud_load = 0.0
    for s in range(n, -1, -1):          # S = n, n-1, ..., 0
        if s < n:
            cloud_load += graph[s].weight_bytes
        if cloud_load > budget:
            break                        # paper line 4: budget exhausted
        e, c, t = evaluate_split(graph, s, edge, cloud, bandwidth_bps,
                                 rtt_s=rtt_s, input_bytes=input_bytes)
        total = e + c + t
        feasible.append(s)
        latencies.append(total)
        if best is None or total < best[1]:
            best = (s, total, e, c, t, cloud_load)
    assert best is not None, "no feasible split (budget < 0?)"
    s, total, e, c, t, load = best
    edge_load = sum(g.weight_bytes for g in graph[:s])
    return SegmentationResult(split=s, total_s=total, edge_s=e, cloud_s=c,
                              net_s=t, cloud_load_bytes=load,
                              edge_load_bytes=edge_load,
                              feasible=feasible, latencies=latencies)


def exhaustive_best(graph: Sequence[LayerCost], edge: DeviceSpec,
                    cloud: DeviceSpec, bandwidth_bps: float,
                    cloud_budget_bytes: Optional[float] = None,
                    **kw) -> int:
    """Brute-force argmin over feasible splits (property-test oracle)."""
    n = len(graph)
    budget = cloud_budget_bytes if cloud_budget_bytes is not None else float("inf")
    best_s, best_t = None, None
    for s in range(n + 1):
        load = sum(c.weight_bytes for c in graph[s:])
        if load > budget:
            continue
        e, c, t = evaluate_split(graph, s, edge, cloud, bandwidth_bps, **kw)
        if best_t is None or e + c + t < best_t:
            best_s, best_t = s, e + c + t
    return best_s


# --------------------------------------------------------------- vectorized
@dataclasses.dataclass(frozen=True)
class GraphArrays:
    """Per-split cost arrays for one layer graph, all of shape ``(n+1,)``
    indexed by split ``S`` (same semantics as the module docstring).

    Units: latencies in seconds, loads and wire volumes in bytes.  Computed
    once per (graph, edge, cloud) triple, these arrays turn every downstream
    latency query into O(1) indexing and every Alg. 1 search into one numpy
    pass — the fleet simulator's per-tick hot path.
    """
    edge_s: np.ndarray          # prefix edge latency of layers [0, S)
    cloud_s: np.ndarray         # suffix cloud latency of layers [S, n)
    wire_bytes: np.ndarray      # cut activation bytes at split S
    cloud_load_bytes: np.ndarray  # weight bytes the cloud must host at S
    n: int

    def latency(self, split: int, bandwidth_bps: float, rtt_s: float = 0.0):
        """(edge_s, cloud_s, net_s) at one split — O(1) equivalent of
        ``evaluate_split`` (bandwidth in bytes/s, result in seconds)."""
        wire = self.wire_bytes[split]
        net = wire / bandwidth_bps + rtt_s if wire else 0.0
        return float(self.edge_s[split]), float(self.cloud_s[split]), net


def graph_arrays(graph: Sequence[LayerCost], edge: DeviceSpec,
                 cloud: DeviceSpec, *, input_bytes: float = 0.0
                 ) -> GraphArrays:
    """Precompute prefix/suffix cost arrays for ``search_vec``.

    ``edge_s`` uses a forward cumsum (identical accumulation order to the
    scalar ``evaluate_split``); ``cloud_s``/``cloud_load_bytes`` are suffix
    sums.  ``wire_bytes[0]`` is ``input_bytes`` (cloud-only ships the raw
    observation) and ``wire_bytes[n]`` is 0 (edge-only ships nothing).
    """
    n = len(graph)
    e_lat = np.array([layer_latency(c, edge) for c in graph], dtype=np.float64)
    c_lat = np.array([layer_latency(c, cloud) for c in graph], dtype=np.float64)
    w = np.array([c.weight_bytes for c in graph], dtype=np.float64)
    edge_s = np.concatenate([[0.0], np.cumsum(e_lat)])
    cloud_s = np.concatenate([np.cumsum(c_lat[::-1])[::-1], [0.0]])
    load = np.concatenate([np.cumsum(w[::-1])[::-1], [0.0]])
    wire = np.array([cut_bytes(graph, s, input_bytes) for s in range(n + 1)],
                    dtype=np.float64)
    return GraphArrays(edge_s=edge_s, cloud_s=cloud_s, wire_bytes=wire,
                       cloud_load_bytes=load, n=n)


@dataclasses.dataclass(frozen=True)
class VecSearchResult:
    """Alg. 1 results for a whole bandwidth sweep (arrays of shape ``(B,)``;
    bandwidths in bytes/s, latencies in seconds)."""
    bandwidths_bps: np.ndarray
    splits: np.ndarray           # optimal split per bandwidth (int)
    total_s: np.ndarray
    edge_s: np.ndarray
    cloud_s: np.ndarray
    net_s: np.ndarray


def search_vec(graph: Sequence[LayerCost], edge: DeviceSpec,
               cloud: DeviceSpec, bandwidths_bps,
               cloud_budget_bytes: Optional[float] = None, *,
               rtt_s: float = 0.0, input_bytes: float = 0.0,
               arrays: Optional[GraphArrays] = None) -> VecSearchResult:
    """Vectorized Alg. 1: optimal split for every bandwidth in one pass.

    Equivalent to calling ``search`` once per bandwidth (the scalar path is
    kept as the property-test oracle) but evaluates the whole
    (split × bandwidth) latency matrix with numpy.  The feasible set under
    ``cloud_budget_bytes`` is identical to the scalar scan's because the
    cloud load is a monotone suffix sum — the scan's early break and a mask
    admit exactly the same splits.  Ties break towards the largest split,
    matching the scalar scan (it walks from S=n down and keeps strict
    improvements only).  Bandwidths in BYTES/s, latencies in seconds.
    """
    ga = arrays if arrays is not None else graph_arrays(
        graph, edge, cloud, input_bytes=input_bytes)
    bw = np.atleast_1d(np.asarray(bandwidths_bps, dtype=np.float64))
    budget = cloud_budget_bytes if cloud_budget_bytes is not None \
        else float("inf")
    net = np.where(ga.wire_bytes[:, None] > 0,
                   ga.wire_bytes[:, None] / bw[None, :] + rtt_s, 0.0)
    totals = ga.edge_s[:, None] + ga.cloud_s[:, None] + net    # (n+1, B)
    totals = np.where((ga.cloud_load_bytes > budget)[:, None], np.inf, totals)
    # argmin over flipped split axis -> largest split wins ties (Alg. 1 order)
    splits = ga.n - np.argmin(totals[::-1], axis=0)
    cols = np.arange(len(bw))
    return VecSearchResult(
        bandwidths_bps=bw, splits=splits, total_s=totals[splits, cols],
        edge_s=ga.edge_s[splits], cloud_s=ga.cloud_s[splits],
        net_s=net[splits, cols])


def sweep_search(graphs: Mapping[str, Sequence[LayerCost]], edge: DeviceSpec,
                 cloud: DeviceSpec, bandwidths_bps,
                 cloud_budget_bytes: Union[None, float,
                                           Mapping[str, Optional[float]]] = None,
                 *, rtt_s: float = 0.0,
                 input_bytes: Union[float, Mapping[str, float]] = 0.0
                 ) -> Dict[str, VecSearchResult]:
    """Fleet-scale plan: Alg. 1 over (model × split × bandwidth) in ONE
    padded numpy pass.

    Graphs of different depths are padded to the deepest model with +inf
    edge latency (those split indices can never win), so a full
    bandwidth-sweep plan for every registered config costs a single
    ``(M, S_max+1, B)`` array evaluation instead of ``M × B`` Python scans.
    ``cloud_budget_bytes`` and ``input_bytes`` may be scalars or per-model
    mappings.  Bandwidths in BYTES/s, latencies in seconds.
    """
    names = list(graphs)
    if not names:
        raise ValueError("sweep_search needs at least one graph")
    bw = np.atleast_1d(np.asarray(bandwidths_bps, dtype=np.float64))

    def per_model(val, name, default):
        if isinstance(val, Mapping):
            v = val.get(name, default)
        else:
            v = val if val is not None else default
        return default if v is None else v

    gas = [graph_arrays(graphs[k], edge, cloud,
                        input_bytes=per_model(input_bytes, k, 0.0))
           for k in names]
    S = max(ga.n for ga in gas) + 1
    M = len(names)

    def pad(vals, fill):
        out = np.full((M, S), fill, dtype=np.float64)
        for i, v in enumerate(vals):
            out[i, :len(v)] = v
        return out

    E = pad([ga.edge_s for ga in gas], np.inf)
    C = pad([ga.cloud_s for ga in gas], 0.0)
    W = pad([ga.wire_bytes for ga in gas], 0.0)
    L = pad([ga.cloud_load_bytes for ga in gas], 0.0)
    budgets = np.array([per_model(cloud_budget_bytes, k, float("inf"))
                        for k in names], dtype=np.float64)

    net = np.where(W[:, :, None] > 0, W[:, :, None] / bw[None, None, :]
                   + rtt_s, 0.0)
    totals = E[:, :, None] + C[:, :, None] + net               # (M, S, B)
    totals = np.where((L > budgets[:, None])[:, :, None], np.inf, totals)
    splits = (S - 1) - np.argmin(totals[:, ::-1, :], axis=1)   # (M, B)

    out: Dict[str, VecSearchResult] = {}
    cols = np.arange(len(bw))
    for i, k in enumerate(names):
        s = splits[i]
        out[k] = VecSearchResult(
            bandwidths_bps=bw, splits=s, total_s=totals[i][s, cols],
            edge_s=E[i][s], cloud_s=C[i][s], net_s=net[i][s, cols])
    return out


def fixed_split(graph: Sequence[LayerCost]) -> int:
    """Baseline: ~50/50 weight split (paper's "Fixed Seg")."""
    total = sum(c.weight_bytes for c in graph)
    acc = 0.0
    for i, c in enumerate(graph):
        acc += c.weight_bytes
        if acc >= total / 2:
            return i + 1
    return len(graph) // 2
