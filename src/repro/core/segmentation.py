"""Optimal model segmentation — paper Alg. 1.

Split semantics: split index ``S`` means layers ``[0, S)`` run on the edge
and ``[S, n)`` on the cloud; the cut activation is the output of layer
``S-1`` (for ``S=0``, the raw model input is shipped — cloud-only; for
``S=n`` nothing is shipped — edge-only).

The search walks from the last layer towards the front (paper: "start from
the last layer and identify the optimal segmentation point within the
allowable cloud-side load range"), i.e. it grows the cloud set until the
cloud load budget ``B_cloud`` is exhausted, tracking the latency-optimal
feasible split.  All inputs come from the analytic structure+hardware
models, so the search itself costs microseconds (paper §IV-A-3: "extremely
low computational load ... negligible overhead").
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from .hardware import DeviceSpec, layer_latency
from .structure import LayerCost


@dataclasses.dataclass(frozen=True)
class SegmentationResult:
    split: int
    total_s: float
    edge_s: float
    cloud_s: float
    net_s: float
    cloud_load_bytes: float
    edge_load_bytes: float
    feasible: List[int]          # splits satisfying the budget
    latencies: List[float]       # total latency per candidate split index


def cut_bytes(graph: Sequence[LayerCost], split: int,
              input_bytes: float = 0.0) -> float:
    """Wire bytes at split S (output activation of layer S-1)."""
    if split == 0:
        return input_bytes
    if split >= len(graph):
        return 0.0
    return graph[split - 1].out_transfer_bytes


def evaluate_split(graph: Sequence[LayerCost], split: int,
                   edge: DeviceSpec, cloud: DeviceSpec,
                   bandwidth_bps: float, *, rtt_s: float = 0.0,
                   input_bytes: float = 0.0):
    edge_s = sum(layer_latency(c, edge) for c in graph[:split])
    cloud_s = sum(layer_latency(c, cloud) for c in graph[split:])
    wire = cut_bytes(graph, split, input_bytes)
    # bandwidth in BYTES/s throughout the repo
    net_s = (wire / bandwidth_bps + rtt_s) if wire else 0.0
    return edge_s, cloud_s, net_s


def search(graph: Sequence[LayerCost], edge: DeviceSpec, cloud: DeviceSpec,
           bandwidth_bps: float, cloud_budget_bytes: Optional[float] = None,
           *, rtt_s: float = 0.0, input_bytes: float = 0.0
           ) -> SegmentationResult:
    """Alg. 1: scan S from n (edge-only) towards 0 while the cloud-side load
    fits the budget; keep the latency-optimal feasible split."""
    n = len(graph)
    budget = cloud_budget_bytes if cloud_budget_bytes is not None else float("inf")
    feasible: List[int] = []
    latencies: List[float] = []
    best = None
    cloud_load = 0.0
    for s in range(n, -1, -1):          # S = n, n-1, ..., 0
        if s < n:
            cloud_load += graph[s].weight_bytes
        if cloud_load > budget:
            break                        # paper line 4: budget exhausted
        e, c, t = evaluate_split(graph, s, edge, cloud, bandwidth_bps,
                                 rtt_s=rtt_s, input_bytes=input_bytes)
        total = e + c + t
        feasible.append(s)
        latencies.append(total)
        if best is None or total < best[1]:
            best = (s, total, e, c, t, cloud_load)
    assert best is not None, "no feasible split (budget < 0?)"
    s, total, e, c, t, load = best
    edge_load = sum(g.weight_bytes for g in graph[:s])
    return SegmentationResult(split=s, total_s=total, edge_s=e, cloud_s=c,
                              net_s=t, cloud_load_bytes=load,
                              edge_load_bytes=edge_load,
                              feasible=feasible, latencies=latencies)


def exhaustive_best(graph: Sequence[LayerCost], edge: DeviceSpec,
                    cloud: DeviceSpec, bandwidth_bps: float,
                    cloud_budget_bytes: Optional[float] = None,
                    **kw) -> int:
    """Brute-force argmin over feasible splits (property-test oracle)."""
    n = len(graph)
    budget = cloud_budget_bytes if cloud_budget_bytes is not None else float("inf")
    best_s, best_t = None, None
    for s in range(n + 1):
        load = sum(c.weight_bytes for c in graph[s:])
        if load > budget:
            continue
        e, c, t = evaluate_split(graph, s, edge, cloud, bandwidth_bps, **kw)
        if best_t is None or e + c + t < best_t:
            best_s, best_t = s, e + c + t
    return best_s


def fixed_split(graph: Sequence[LayerCost]) -> int:
    """Baseline: ~50/50 weight split (paper's "Fixed Seg")."""
    total = sum(c.weight_bytes for c in graph)
    acc = 0.0
    for i, c in enumerate(graph):
        acc += c.weight_bytes
        if acc >= total / 2:
            return i + 1
    return len(graph) // 2
