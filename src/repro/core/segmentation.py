"""Optimal model segmentation — paper Alg. 1.

Split semantics: split index ``S`` means layers ``[0, S)`` run on the edge
and ``[S, n)`` on the cloud; the cut activation is the output of layer
``S-1`` (for ``S=0``, the raw model input is shipped — cloud-only; for
``S=n`` nothing is shipped — edge-only).

The search walks from the last layer towards the front (paper: "start from
the last layer and identify the optimal segmentation point within the
allowable cloud-side load range"), i.e. it grows the cloud set until the
cloud load budget ``B_cloud`` is exhausted, tracking the latency-optimal
feasible split.  All inputs come from the analytic structure+hardware
models, so the search itself costs microseconds (paper §IV-A-3: "extremely
low computational load ... negligible overhead").

Codec-aware transport (``core/codec.py``): a ``codec`` prices the cut
activation as encode(edge) + compressed-wire + rtt + decode(cloud) for any
**mid-graph** split (``0 < S < n``); the ``S = 0`` raw-observation upload
and the ``S = n`` no-transfer extremes are codec-free by construction.
``search_joint`` / the ``codecs=`` axis of ``search_vec``/``sweep_search``
search (split × codec) jointly — latency ties break toward the earliest
codec in the list, then the largest split within that codec (so put the
preferred / lossless codec first).

Multi-cut placements (``core/placement.py``): ``evaluate_placement``
prices an arbitrary K-segment ``PlacementPlan``, and ``search_multicut`` /
``sweep_multicut`` scan every edge→cloud→edge plan ``(S1, S2)`` — edge
``[0, S1)``, cloud ``[S1, S2)``, edge ``[S2, n)`` — in one
(codec × S1 × S2 × bandwidth) numpy pass over the triangular ``S1 ≤ S2``
mask.  The uplink cut at ``S1`` is priced exactly like the single-cut
transport; the downlink cut at ``S2`` is priced separately: it carries
only the bytes the tail segment consumes (``LayerCost.in_transfer_bytes``,
small for action heads), rides the usually-faster downlink direction
(``down_bw_factor`` × uplink bandwidth), pays a second rtt, and encodes on
the cloud / decodes on the edge.  ``S2 = n`` collapses to the single-cut
row, ``S1 = S2`` to edge-only — so the single-split world is the K=1
special case, and latency ties prefer it (largest ``S2`` wins ties).
Cloud budget feasibility is the **window** load ``weights[S1:S2)`` — the
knob that makes multi-cut genuinely better: under a tight per-robot cloud
quota the byte-heavy but compute-light action head can stay on the edge,
freeing quota for one more expensive trunk layer on the cloud.

Streamed execution (``core/pipeline.py``): ``search_streamed`` /
``search_streamed_scalar`` add a chunk-count axis ``K`` — the uplink cut
activation ships in token-axis chunks through a 3-stage pipeline (edge
encode → per-chunk wire+rtt → cloud decode + chunked prefill of the
window), so those cells price a *makespan* instead of a sum.  The
``K = 1`` plane is the sequential (C, S1, S2, B) tensor shared with
``search_multicut`` (``_plan_tensors``), which is what makes
``n_chunks = 1`` reproduce the non-streamed results exactly; ties prefer
the smallest chunk count, so chunking only appears where it strictly
pays.  ``sweep_multicut(chunk_grid=...)`` extends the fleet plan table
with the same axis.

Queue-aware planning (``queue_hz=``): every search accepts an expected
per-replica arrival rate and adds an M/G/1 expected-wait term
``queue_delay_s`` for the cloud-side service time of each candidate —
Alg. 1 stops assuming an idle cloud, so under congestion the optimum
retreats toward the edge exactly where the fleet's replicas queue.  The
term is a *planning prior*, not a realized latency: ``total_s`` includes
it but the ``edge_s``/``cloud_s``/``net_s`` decomposition stays
physical, so components no longer sum to ``total_s`` when
``queue_hz > 0``.  ``queue_hz = 0`` (the default) adds nothing and
reproduces the queue-blind plans bit-for-bit (docs/DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from .codec import Codec, get_codec, resolve_codecs, transport_s
from .hardware import DeviceSpec, layer_latency
from .pipeline import (DEFAULT_CHUNK_GRID, stream_applies,
                       stream_bubble_fraction, stream_makespan,
                       stream_makespan_scalar)
from .placement import CLOUD, EDGE, PlacementPlan
from .structure import LayerCost


@dataclasses.dataclass(frozen=True)
class SegmentationResult:
    split: int
    total_s: float
    edge_s: float
    cloud_s: float
    net_s: float
    cloud_load_bytes: float
    edge_load_bytes: float
    feasible: List[int]          # splits satisfying the budget
    latencies: List[float]       # total latency per candidate split index
    codec: Optional[str] = None  # codec the transport was priced with


def cut_bytes(graph: Sequence[LayerCost], split: int,
              input_bytes: float = 0.0) -> float:
    """Wire bytes at split S (output activation of layer S-1)."""
    if split == 0:
        return input_bytes
    if split >= len(graph):
        return 0.0
    return graph[split - 1].out_transfer_bytes


def codec_applies(split: int, n: int) -> bool:
    """Codecs compress mid-graph activations only: the split-0 raw
    observation ships as-is and the split-n extreme ships nothing."""
    return 0 < split < n


def downlink_bytes(graph: Sequence[LayerCost], cut: int) -> float:
    """Wire bytes of a cloud→edge cut at ``cut``: what the tail segment
    starting at layer ``cut`` actually consumes.  Defaults to the full
    upstream activation (``cut_bytes``); action heads override it with
    their small conditioning slice (``LayerCost.in_transfer_bytes``)."""
    if cut >= len(graph):
        return 0.0
    need = graph[cut].in_transfer_bytes
    if need is not None:
        return need
    return cut_bytes(graph, cut)


def net_time(wire_raw: float, bandwidth_bps: float, *, rtt_s: float = 0.0,
             codec: Optional[Codec] = None, applicable: bool = True,
             edge: Optional[DeviceSpec] = None,
             cloud: Optional[DeviceSpec] = None) -> float:
    """Transport seconds for one cut activation of ``wire_raw`` raw bytes.
    With a codec (and ``applicable``): encode on ``edge`` + compressed wire
    + rtt + decode on ``cloud``; otherwise raw wire + rtt.  Zero raw bytes
    cost zero (bandwidth in BYTES/s throughout the repo)."""
    if not wire_raw:
        return 0.0
    if codec is None or not applicable:
        return wire_raw / bandwidth_bps + rtt_s
    return transport_s(wire_raw, bandwidth_bps, codec, edge, cloud,
                       rtt_s=rtt_s)


def queue_delay_s(service_s, arrival_hz: float, *, cv2: float = 1.0,
                  service_scale: float = 1.0):
    """Expected M/G/1 queueing wait (Pollaczek–Khinchine) for a cloud
    window whose solo service time is ``service_s`` seconds:

        W = λ·S²·(1 + cv²) / (2·(1 − ρ)),   ρ = λ·S

    with ``λ = arrival_hz`` (requests/s reaching ONE replica), ``cv²``
    the squared coefficient of variation of service times (1 ≡ M/M/1;
    the fleet's lognormal straggler noise puts it slightly above), and
    ``S = service_s · service_scale`` (``service_scale`` folds in the
    mean batching efficiency ``eff(k)/k`` of the continuous batcher).
    ``ρ ≥ 1`` → ``inf`` (saturated: the planner retreats toward the
    edge, whose wait is 0 by construction); ``S ≤ 0`` → 0.  Elementwise
    over numpy arrays; scalar in → float out."""
    S = np.asarray(service_s, dtype=np.float64) * service_scale
    if arrival_hz <= 0:
        w = np.zeros_like(S)
    else:
        rho = arrival_hz * S
        with np.errstate(divide="ignore", invalid="ignore"):
            w = arrival_hz * S * S * (1.0 + cv2) / (2.0 * (1.0 - rho))
        w = np.where(S <= 0, 0.0, np.where(rho >= 1.0, np.inf, w))
    return float(w) if np.ndim(service_s) == 0 else w


def evaluate_split(graph: Sequence[LayerCost], split: int,
                   edge: DeviceSpec, cloud: DeviceSpec,
                   bandwidth_bps: float, *, rtt_s: float = 0.0,
                   input_bytes: float = 0.0,
                   codec: Optional[Codec] = None):
    edge_s = sum(layer_latency(c, edge) for c in graph[:split])
    cloud_s = sum(layer_latency(c, cloud) for c in graph[split:])
    wire = cut_bytes(graph, split, input_bytes)
    net_s = net_time(wire, bandwidth_bps, rtt_s=rtt_s, codec=codec,
                     applicable=codec_applies(split, len(graph)),
                     edge=edge, cloud=cloud)
    return edge_s, cloud_s, net_s


def search(graph: Sequence[LayerCost], edge: DeviceSpec, cloud: DeviceSpec,
           bandwidth_bps: float, cloud_budget_bytes: Optional[float] = None,
           *, rtt_s: float = 0.0, input_bytes: float = 0.0,
           codec: Optional[Codec] = None, queue_hz: float = 0.0,
           queue_cv2: float = 1.0,
           queue_service_scale: float = 1.0) -> SegmentationResult:
    """Alg. 1: scan S from n (edge-only) towards 0 while the cloud-side load
    fits the budget; keep the latency-optimal feasible split.  ``codec``
    prices mid-graph transport through ``core/codec.py`` (encode + wire +
    decode), so compression participates in WHERE the cut lands.
    ``queue_hz > 0`` adds the M/G/1 expected wait ``queue_delay_s`` of
    each candidate's cloud service time to its total (module docstring:
    the wait is in ``total_s``/``latencies`` but not in the physical
    component decomposition)."""
    codec = get_codec(codec)
    n = len(graph)
    budget = cloud_budget_bytes if cloud_budget_bytes is not None else float("inf")
    feasible: List[int] = []
    latencies: List[float] = []
    best = None
    cloud_load = 0.0
    for s in range(n, -1, -1):          # S = n, n-1, ..., 0
        if s < n:
            cloud_load += graph[s].weight_bytes
        if cloud_load > budget:
            break                        # paper line 4: budget exhausted
        e, c, t = evaluate_split(graph, s, edge, cloud, bandwidth_bps,
                                 rtt_s=rtt_s, input_bytes=input_bytes,
                                 codec=codec)
        total = e + c + t
        if queue_hz > 0:
            total += queue_delay_s(c, queue_hz, cv2=queue_cv2,
                                   service_scale=queue_service_scale)
        feasible.append(s)
        latencies.append(total)
        if best is None or total < best[1]:
            best = (s, total, e, c, t, cloud_load)
    assert best is not None, "no feasible split (budget < 0?)"
    s, total, e, c, t, load = best
    edge_load = sum(g.weight_bytes for g in graph[:s])
    return SegmentationResult(split=s, total_s=total, edge_s=e, cloud_s=c,
                              net_s=t, cloud_load_bytes=load,
                              edge_load_bytes=edge_load,
                              feasible=feasible, latencies=latencies,
                              codec=codec.name if codec else None)


def search_joint(graph: Sequence[LayerCost], edge: DeviceSpec,
                 cloud: DeviceSpec, bandwidth_bps: float,
                 codecs: Sequence, cloud_budget_bytes: Optional[float] = None,
                 *, rtt_s: float = 0.0, input_bytes: float = 0.0,
                 max_err: Optional[float] = None, queue_hz: float = 0.0,
                 queue_cv2: float = 1.0,
                 queue_service_scale: float = 1.0) -> SegmentationResult:
    """Scalar joint (split × codec) oracle: run Alg. 1 once per codec (in
    list order) and keep the first strict latency winner — the tie-break
    the vectorized codec axis reproduces (earliest codec in the list,
    then the largest split within that codec).  The property-test oracle
    for ``search_vec(codecs=...)``."""
    cs = resolve_codecs(codecs, max_err)
    best: Optional[SegmentationResult] = None
    for c in cs:
        seg = search(graph, edge, cloud, bandwidth_bps, cloud_budget_bytes,
                     rtt_s=rtt_s, input_bytes=input_bytes, codec=c,
                     queue_hz=queue_hz, queue_cv2=queue_cv2,
                     queue_service_scale=queue_service_scale)
        if best is None or seg.total_s < best.total_s:
            best = seg
    return best


def exhaustive_best(graph: Sequence[LayerCost], edge: DeviceSpec,
                    cloud: DeviceSpec, bandwidth_bps: float,
                    cloud_budget_bytes: Optional[float] = None,
                    **kw) -> int:
    """Brute-force argmin over feasible splits (property-test oracle)."""
    n = len(graph)
    budget = cloud_budget_bytes if cloud_budget_bytes is not None else float("inf")
    best_s, best_t = None, None
    for s in range(n + 1):
        load = sum(c.weight_bytes for c in graph[s:])
        if load > budget:
            continue
        e, c, t = evaluate_split(graph, s, edge, cloud, bandwidth_bps, **kw)
        if best_t is None or e + c + t < best_t:
            best_s, best_t = s, e + c + t
    return best_s


# --------------------------------------------------------------- vectorized
@dataclasses.dataclass(frozen=True)
class GraphArrays:
    """Per-split cost arrays for one layer graph, all of shape ``(n+1,)``
    indexed by split ``S`` (same semantics as the module docstring).

    Units: latencies in seconds, loads and wire volumes in bytes.  Computed
    once per (graph, edge, cloud) triple, these arrays turn every downstream
    latency query into O(1) indexing and every Alg. 1 search into one numpy
    pass — the fleet simulator's per-tick hot path.
    """
    edge_s: np.ndarray          # prefix edge latency of layers [0, S)
    cloud_s: np.ndarray         # suffix cloud latency of layers [S, n)
    wire_bytes: np.ndarray      # RAW cut activation bytes at split S
    cloud_load_bytes: np.ndarray  # weight bytes the cloud must host at S
    n: int
    # devices the arrays were priced on — lets ``latency`` price codec
    # encode/decode without re-threading DeviceSpecs through every caller
    edge_dev: Optional[DeviceSpec] = None
    cloud_dev: Optional[DeviceSpec] = None
    # RAW cloud→edge downlink bytes if the tail starts at S (semantic
    # in_transfer of layer S; 0 at S = n) — the multi-cut second cut
    down_wire_bytes: Optional[np.ndarray] = None

    def latency(self, split: int, bandwidth_bps: float, rtt_s: float = 0.0,
                codec: Optional[Codec] = None):
        """(edge_s, cloud_s, net_s) at one split — O(1) equivalent of
        ``evaluate_split`` (bandwidth in bytes/s, result in seconds).
        ``codec`` prices mid-graph transport through the codec (encode on
        ``edge_dev``, decode on ``cloud_dev``)."""
        wire = self.wire_bytes[split]
        net = net_time(wire, bandwidth_bps, rtt_s=rtt_s, codec=codec,
                       applicable=codec_applies(split, self.n),
                       edge=self.edge_dev, cloud=self.cloud_dev)
        return float(self.edge_s[split]), float(self.cloud_s[split]), net

    def placement_latency(self, s1: int, s2: int, bandwidth_bps: float,
                          rtt_s: float = 0.0,
                          codec: Optional[Codec] = None,
                          down_bw_factor: float = 1.0):
        """(edge_s, cloud_s, up_s, down_s) of the edge→cloud→edge placement
        edge ``[0, s1)`` / cloud ``[s1, s2)`` / edge ``[s2, n)`` — the O(1)
        equivalent of ``evaluate_placement``.  ``s2 == n`` is the single
        cut (down_s = 0), ``s1 == s2`` edge-only (no transfer at all).
        The downlink leg rides ``down_bw_factor × bandwidth`` and is
        encoded on the cloud device / decoded on the edge device."""
        n = self.n
        e = float(self.edge_s[s1] + self.edge_s[n] - self.edge_s[s2])
        if s1 >= s2:
            return e, 0.0, 0.0, 0.0
        c = float(self.cloud_s[s1] - self.cloud_s[s2])
        up = net_time(self.wire_bytes[s1], bandwidth_bps, rtt_s=rtt_s,
                      codec=codec, applicable=codec_applies(s1, n),
                      edge=self.edge_dev, cloud=self.cloud_dev)
        down = 0.0
        if s2 < n and self.down_wire_bytes is not None:
            down = net_time(self.down_wire_bytes[s2],
                            bandwidth_bps * down_bw_factor, rtt_s=rtt_s,
                            codec=codec, applicable=codec_applies(s2, n),
                            edge=self.cloud_dev, cloud=self.edge_dev)
        return e, c, up, down

    def window_load_bytes(self, s1: int, s2: int) -> float:
        """Cloud-hosted weight bytes of the window ``[s1, s2)``."""
        if s1 >= s2:
            return 0.0
        return float(self.cloud_load_bytes[s1] - self.cloud_load_bytes[s2])


def graph_arrays(graph: Sequence[LayerCost], edge: DeviceSpec,
                 cloud: DeviceSpec, *, input_bytes: float = 0.0
                 ) -> GraphArrays:
    """Precompute prefix/suffix cost arrays for ``search_vec``.

    ``edge_s`` uses a forward cumsum (identical accumulation order to the
    scalar ``evaluate_split``); ``cloud_s``/``cloud_load_bytes`` are suffix
    sums.  ``wire_bytes[0]`` is ``input_bytes`` (cloud-only ships the raw
    observation) and ``wire_bytes[n]`` is 0 (edge-only ships nothing).
    """
    n = len(graph)
    e_lat = np.array([layer_latency(c, edge) for c in graph], dtype=np.float64)
    c_lat = np.array([layer_latency(c, cloud) for c in graph], dtype=np.float64)
    w = np.array([c.weight_bytes for c in graph], dtype=np.float64)
    edge_s = np.concatenate([[0.0], np.cumsum(e_lat)])
    cloud_s = np.concatenate([np.cumsum(c_lat[::-1])[::-1], [0.0]])
    load = np.concatenate([np.cumsum(w[::-1])[::-1], [0.0]])
    wire = np.array([cut_bytes(graph, s, input_bytes) for s in range(n + 1)],
                    dtype=np.float64)
    down = np.array([downlink_bytes(graph, s) for s in range(n + 1)],
                    dtype=np.float64)
    return GraphArrays(edge_s=edge_s, cloud_s=cloud_s, wire_bytes=wire,
                       cloud_load_bytes=load, n=n,
                       edge_dev=edge, cloud_dev=cloud,
                       down_wire_bytes=down)


@dataclasses.dataclass(frozen=True)
class VecSearchResult:
    """Alg. 1 results for a whole bandwidth sweep (arrays of shape ``(B,)``;
    bandwidths in bytes/s, latencies in seconds).  When the search ran with
    a codec axis, ``codec_idx[b]`` indexes ``codec_names`` — the codec the
    joint (split × codec) optimum chose at bandwidth ``b``."""
    bandwidths_bps: np.ndarray
    splits: np.ndarray           # optimal split per bandwidth (int)
    total_s: np.ndarray
    edge_s: np.ndarray
    cloud_s: np.ndarray
    net_s: np.ndarray
    codec_idx: Optional[np.ndarray] = None
    codec_names: Optional[Tuple[str, ...]] = None


def _codec_wire_split(wire: np.ndarray, n: int, cs: Sequence[Codec],
                      enc_dev: DeviceSpec, dec_dev: DeviceSpec
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-(codec, split) compressed wire bytes and the two codec-compute
    sides SEPARATELY (encode on ``enc_dev``, decode on ``dec_dev``) —
    the streamed pipeline places them in different stages.

    ``wire``: (n+1,) raw cut bytes.  Mid-graph splits (0 < S < n) with
    traffic get the codec's wire factor and encode/decode overhead (both
    linear in raw bytes); the extremes pass through raw.  Shapes (C, n+1).
    """
    app = np.zeros(n + 1, dtype=bool)
    app[1:n] = True
    app &= wire > 0
    factors = np.array([c.wire_factor for c in cs], dtype=np.float64)
    enc_r = np.array([c.encode_s_per_byte(enc_dev) for c in cs],
                     dtype=np.float64)
    dec_r = np.array([c.decode_s_per_byte(dec_dev) for c in cs],
                     dtype=np.float64)
    wire_c = np.where(app[None, :], wire[None, :] * factors[:, None],
                      wire[None, :])
    enc_o = np.where(app[None, :], wire[None, :] * enc_r[:, None], 0.0)
    dec_o = np.where(app[None, :], wire[None, :] * dec_r[:, None], 0.0)
    return wire_c, enc_o, dec_o


def _codec_wire_overhead(wire: np.ndarray, n: int, cs: Sequence[Codec],
                         edge: DeviceSpec, cloud: DeviceSpec
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-(codec, split) compressed wire bytes and COMBINED encode+decode
    seconds — the sequential-transport view (``_codec_wire_split`` summed;
    the sum order matches the historical rate-sum formula)."""
    app = np.zeros(n + 1, dtype=bool)
    app[1:n] = True
    app &= wire > 0
    factors = np.array([c.wire_factor for c in cs], dtype=np.float64)
    rates = np.array([c.encode_s_per_byte(edge) + c.decode_s_per_byte(cloud)
                      for c in cs], dtype=np.float64)
    wire_c = np.where(app[None, :], wire[None, :] * factors[:, None],
                      wire[None, :])
    ovh = np.where(app[None, :], wire[None, :] * rates[:, None], 0.0)
    return wire_c, ovh


def search_vec(graph: Sequence[LayerCost], edge: DeviceSpec,
               cloud: DeviceSpec, bandwidths_bps,
               cloud_budget_bytes: Optional[float] = None, *,
               rtt_s: float = 0.0, input_bytes: float = 0.0,
               arrays: Optional[GraphArrays] = None,
               codecs: Optional[Sequence] = None,
               max_err: Optional[float] = None, queue_hz: float = 0.0,
               queue_cv2: float = 1.0,
               queue_service_scale: float = 1.0) -> VecSearchResult:
    """Vectorized Alg. 1: optimal split for every bandwidth in one pass.

    Equivalent to calling ``search`` once per bandwidth (the scalar path is
    kept as the property-test oracle) but evaluates the whole
    (split × bandwidth) latency matrix with numpy.  The feasible set under
    ``cloud_budget_bytes`` is identical to the scalar scan's because the
    cloud load is a monotone suffix sum — the scan's early break and a mask
    admit exactly the same splits.  Ties break towards the largest split,
    matching the scalar scan (it walks from S=n down and keeps strict
    improvements only).  Bandwidths in BYTES/s, latencies in seconds.

    ``codecs`` adds a codec axis: the (codec × split × bandwidth) tensor is
    evaluated in the same pass and the joint optimum per bandwidth is
    returned (``codec_idx``/``codec_names``).  Equivalent to
    ``search_joint`` per bandwidth: latency ties break toward the earliest
    codec in the list, then the largest split within that codec.
    ``max_err`` drops codecs whose ``err_bound`` exceeds it before the
    search.  ``queue_hz > 0`` adds ``queue_delay_s`` of each split's
    cloud service time to the totals (equivalent to the scalar
    ``search``/``search_joint`` with the same rate).
    """
    ga = arrays if arrays is not None else graph_arrays(
        graph, edge, cloud, input_bytes=input_bytes)
    bw = np.atleast_1d(np.asarray(bandwidths_bps, dtype=np.float64))
    budget = cloud_budget_bytes if cloud_budget_bytes is not None \
        else float("inf")
    cs = resolve_codecs(codecs, max_err)
    cols = np.arange(len(bw))
    qd = queue_delay_s(ga.cloud_s, queue_hz, cv2=queue_cv2,
                       service_scale=queue_service_scale) \
        if queue_hz > 0 else None                    # (n+1,)
    if cs is None:
        net = np.where(ga.wire_bytes[:, None] > 0,
                       ga.wire_bytes[:, None] / bw[None, :] + rtt_s, 0.0)
        totals = ga.edge_s[:, None] + ga.cloud_s[:, None] + net   # (n+1, B)
        if qd is not None:
            totals = totals + qd[:, None]
        totals = np.where((ga.cloud_load_bytes > budget)[:, None],
                          np.inf, totals)
        # argmin over flipped split axis -> largest split wins ties
        splits = ga.n - np.argmin(totals[::-1], axis=0)
        return VecSearchResult(
            bandwidths_bps=bw, splits=splits, total_s=totals[splits, cols],
            edge_s=ga.edge_s[splits], cloud_s=ga.cloud_s[splits],
            net_s=net[splits, cols])

    wire_c, ovh = _codec_wire_overhead(ga.wire_bytes, ga.n, cs, edge, cloud)
    net = np.where(wire_c[:, :, None] > 0,
                   wire_c[:, :, None] / bw[None, None, :] + rtt_s, 0.0) \
        + ovh[:, :, None]                                      # (C, n+1, B)
    totals = ga.edge_s[None, :, None] + ga.cloud_s[None, :, None] + net
    if qd is not None:
        totals = totals + qd[None, :, None]
    totals = np.where((ga.cloud_load_bytes > budget)[None, :, None],
                      np.inf, totals)
    # flatten (codec, flipped-split): first occurrence of the min is the
    # earliest codec at the largest split — the search_joint tie-break
    S = ga.n + 1
    flat = totals[:, ::-1, :].reshape(len(cs) * S, len(bw))
    idx = np.argmin(flat, axis=0)
    codec_idx = idx // S
    splits = ga.n - idx % S
    return VecSearchResult(
        bandwidths_bps=bw, splits=splits,
        total_s=totals[codec_idx, splits, cols],
        edge_s=ga.edge_s[splits], cloud_s=ga.cloud_s[splits],
        net_s=net[codec_idx, splits, cols],
        codec_idx=codec_idx, codec_names=tuple(c.name for c in cs))


def sweep_search(graphs: Mapping[str, Sequence[LayerCost]], edge: DeviceSpec,
                 cloud: DeviceSpec, bandwidths_bps,
                 cloud_budget_bytes: Union[None, float,
                                           Mapping[str, Optional[float]]] = None,
                 *, rtt_s: float = 0.0,
                 input_bytes: Union[float, Mapping[str, float]] = 0.0,
                 codecs: Optional[Sequence] = None,
                 max_err: Optional[float] = None, queue_hz: float = 0.0,
                 queue_cv2: float = 1.0, queue_service_scale: float = 1.0
                 ) -> Dict[str, VecSearchResult]:
    """Fleet-scale plan: Alg. 1 over (model × split × bandwidth × codec) in
    ONE padded numpy pass.

    Graphs of different depths are padded to the deepest model with +inf
    edge latency (those split indices can never win), so a full
    bandwidth-sweep plan for every registered config costs a single
    ``(M, C, S_max+1, B)`` array evaluation instead of ``M × C × B`` Python
    scans (``C = 1`` codec-free when ``codecs`` is None).
    ``cloud_budget_bytes`` and ``input_bytes`` may be scalars or per-model
    mappings.  Bandwidths in BYTES/s, latencies in seconds.  With
    ``codecs``, each model's result carries the joint-optimal
    ``codec_idx``/``codec_names`` per bandwidth (ties: earliest codec,
    then largest split — identical to ``search_joint``).
    """
    names = list(graphs)
    if not names:
        raise ValueError("sweep_search needs at least one graph")
    bw = np.atleast_1d(np.asarray(bandwidths_bps, dtype=np.float64))
    cs = resolve_codecs(codecs, max_err)

    def per_model(val, name, default):
        if isinstance(val, Mapping):
            v = val.get(name, default)
        else:
            v = val if val is not None else default
        return default if v is None else v

    gas = [graph_arrays(graphs[k], edge, cloud,
                        input_bytes=per_model(input_bytes, k, 0.0))
           for k in names]
    S = max(ga.n for ga in gas) + 1
    M = len(names)

    def pad(vals, fill):
        out = np.full((M, S), fill, dtype=np.float64)
        for i, v in enumerate(vals):
            out[i, :len(v)] = v
        return out

    E = pad([ga.edge_s for ga in gas], np.inf)
    C = pad([ga.cloud_s for ga in gas], 0.0)
    W = pad([ga.wire_bytes for ga in gas], 0.0)
    L = pad([ga.cloud_load_bytes for ga in gas], 0.0)
    budgets = np.array([per_model(cloud_budget_bytes, k, float("inf"))
                        for k in names], dtype=np.float64)
    infeasible = (L > budgets[:, None])                        # (M, S)
    cols = np.arange(len(bw))
    # queue prior on the padded cloud-service matrix: padded cells carry
    # cloud_s = 0 so their wait is 0 (and their edge_s = inf anyway)
    qd = queue_delay_s(C, queue_hz, cv2=queue_cv2,
                       service_scale=queue_service_scale) \
        if queue_hz > 0 else None                              # (M, S)

    if cs is None:
        net = np.where(W[:, :, None] > 0, W[:, :, None] / bw[None, None, :]
                       + rtt_s, 0.0)
        totals = E[:, :, None] + C[:, :, None] + net           # (M, S, B)
        if qd is not None:
            totals = totals + qd[:, :, None]
        totals = np.where(infeasible[:, :, None], np.inf, totals)
        splits = (S - 1) - np.argmin(totals[:, ::-1, :], axis=1)  # (M, B)
        out: Dict[str, VecSearchResult] = {}
        for i, k in enumerate(names):
            s = splits[i]
            out[k] = VecSearchResult(
                bandwidths_bps=bw, splits=s, total_s=totals[i][s, cols],
                edge_s=E[i][s], cloud_s=C[i][s], net_s=net[i][s, cols])
        return out

    # codec axis: (M, C, S) wire/overhead via the shared per-model helper
    wire_c = np.empty((M, len(cs), S), dtype=np.float64)
    ovh = np.empty((M, len(cs), S), dtype=np.float64)
    for i, ga in enumerate(gas):
        wc, ov = _codec_wire_overhead(W[i, :ga.n + 1], ga.n, cs, edge, cloud)
        wire_c[i, :, :ga.n + 1], ovh[i, :, :ga.n + 1] = wc, ov
        wire_c[i, :, ga.n + 1:], ovh[i, :, ga.n + 1:] = 0.0, 0.0
    net = np.where(wire_c[..., None] > 0,
                   wire_c[..., None] / bw[None, None, None, :] + rtt_s, 0.0) \
        + ovh[..., None]                                    # (M, C, S, B)
    totals = E[:, None, :, None] + C[:, None, :, None] + net
    if qd is not None:
        totals = totals + qd[:, None, :, None]
    totals = np.where(infeasible[:, None, :, None], np.inf, totals)
    flat = totals[:, :, ::-1, :].reshape(M, len(cs) * S, len(bw))
    idx = np.argmin(flat, axis=1)                           # (M, B)
    codec_idx = idx // S
    splits = (S - 1) - idx % S
    codec_names = tuple(c.name for c in cs)
    out = {}
    for i, k in enumerate(names):
        s, ci = splits[i], codec_idx[i]
        out[k] = VecSearchResult(
            bandwidths_bps=bw, splits=s, total_s=totals[i][ci, s, cols],
            edge_s=E[i][s], cloud_s=C[i][s], net_s=net[i][ci, s, cols],
            codec_idx=ci, codec_names=codec_names)
    return out


# ------------------------------------------------------------ multi-cut
@dataclasses.dataclass(frozen=True)
class PlacementEval:
    """One priced ``PlacementPlan``: latency decomposition in seconds plus
    the cloud-hosted weight load.  ``up_s``/``down_s`` are the edge→cloud /
    cloud→edge transport legs (each includes its own rtt and codec
    encode/decode compute); ``net_s = up_s + down_s``.  For a streamed
    evaluation (``n_chunks > 1``) the uplink leg is the pipeline's
    *transport-exposed* time ``makespan − cloud_s`` — the cloud window
    prefills arrived chunks concurrently, so ``total_s`` still equals
    ``edge_s + cloud_s + up_s + down_s`` — and ``bubble_frac`` reports the
    modeled fill/drain dead time (``core/pipeline.py``)."""
    plan: PlacementPlan
    total_s: float
    edge_s: float
    cloud_s: float
    up_s: float
    down_s: float
    cloud_load_bytes: float
    codec: Optional[str] = None
    n_chunks: int = 1
    bubble_frac: float = 0.0

    @property
    def net_s(self) -> float:
        return self.up_s + self.down_s


def evaluate_placement(graph: Sequence[LayerCost], plan: PlacementPlan,
                       edge: DeviceSpec, cloud: DeviceSpec,
                       bandwidth_bps: float, *, rtt_s: float = 0.0,
                       input_bytes: float = 0.0,
                       down_bw_factor: float = 1.0,
                       streamed: bool = False) -> PlacementEval:
    """Price an arbitrary K-segment placement: per-segment compute on its
    tier plus one transport leg per tier-changing cut.  Edge→cloud cuts
    (uplinks) ship the cut activation (``cut_bytes``; the raw observation
    at cut 0) on the uplink bandwidth with encode-on-edge /
    decode-on-cloud; cloud→edge cuts (downlinks) ship only what the
    receiving segment consumes (``downlink_bytes``) on
    ``down_bw_factor × bandwidth`` with encode-on-cloud / decode-on-edge.
    Every real cut pays ``rtt_s``.  The K=1 plan reproduces
    ``evaluate_split`` exactly.

    ``streamed=True`` honours the plan's per-cut ``cut_chunks``: an
    uplink cut with ``n_chunks > 1`` is priced as the 3-stage chunk
    pipeline (``core/pipeline.py`` — encode → wire+rtt per chunk →
    decode + chunked prefill of the cloud window), replacing that leg's
    sequential ``up_s`` with the transport-exposed ``makespan − cloud_s``.
    Streaming applies only where a codec would (mid-graph cuts with
    traffic, ``pipeline.stream_applies``); plans whose chunks are all 1
    — and any plan under ``streamed=False`` — price exactly as before."""
    n = len(graph)
    norm = plan.normalize(n)
    dev = {EDGE: edge, CLOUD: cloud}
    edge_s = cloud_s = up_s = down_s = 0.0
    cloud_load = 0.0
    segs = [s for s in norm.segments(n) if s[1] > s[0]]
    seg_times = []
    for a, b, tier in segs:
        t = sum(layer_latency(c, dev[tier]) for c in graph[a:b])
        seg_times.append(t)
        if tier == EDGE:
            edge_s += t
        else:
            cloud_s += t
            cloud_load += sum(c.weight_bytes for c in graph[a:b])
    if segs and segs[0][2] == CLOUD:
        # cloud-first placement: the raw observation still has to reach
        # the cloud — the same codec-free split-0 upload evaluate_split
        # prices (the leading empty edge segment normalizes away, but the
        # wire bytes don't)
        up_s += net_time(cut_bytes(graph, 0, input_bytes), bandwidth_bps,
                         rtt_s=rtt_s, applicable=False)
    stream_leg = None            # (wire_raw, codec, n_chunks) of 1st uplink
    for i in range(1, len(segs)):
        cut, _, dst_tier = segs[i]
        codec = get_codec(norm.cut_codecs[i - 1])
        if dst_tier == CLOUD:               # uplink
            wire = cut_bytes(graph, cut, input_bytes)
            leg = net_time(wire, bandwidth_bps, rtt_s=rtt_s, codec=codec,
                           applicable=codec_applies(cut, n),
                           edge=edge, cloud=cloud)
            up_s += leg
            chunks = norm.cut_chunks[i - 1]
            if streamed and stream_leg is None and chunks > 1 \
                    and stream_applies(cut, n, wire):
                # seg_times[i] is the cloud window THIS uplink feeds —
                # only its prefill overlaps the arriving chunks (later
                # cloud segments, if any, stay sequential)
                stream_leg = (wire, codec, chunks, leg, seg_times[i])
        else:                               # downlink
            wire = downlink_bytes(graph, cut)
            down_s += net_time(wire, bandwidth_bps * down_bw_factor,
                               rtt_s=rtt_s, codec=codec,
                               applicable=codec_applies(cut, n),
                               edge=cloud, cloud=edge)
    n_chunks, bubble = 1, 0.0
    if stream_leg is not None:
        # re-price the streamed uplink leg as the chunk pipeline: its
        # stage-3 work is the cloud decode PLUS the fed window's prefill,
        # so the leg's exposed cost becomes makespan − window_s
        wire, codec, n_chunks, seq_leg, window_s = stream_leg
        enc = codec.encode_s(wire, edge) if codec is not None else 0.0
        dec = codec.decode_s(wire, cloud) if codec is not None else 0.0
        wire_c = codec.wire_bytes(wire) if codec is not None else wire
        m = stream_makespan_scalar(enc, wire_c / bandwidth_bps,
                                   dec + window_s, n_chunks, rtt_s)
        up_s = (up_s - seq_leg) + (m - window_s)
        bubble = float(stream_bubble_fraction(enc, wire_c / bandwidth_bps,
                                              dec + window_s, n_chunks,
                                              rtt_s))
    codec_names = [c for c in norm.cut_codecs if c is not None]
    return PlacementEval(plan=norm, total_s=edge_s + cloud_s + up_s + down_s,
                         edge_s=edge_s, cloud_s=cloud_s, up_s=up_s,
                         down_s=down_s, cloud_load_bytes=cloud_load,
                         codec=codec_names[0] if codec_names else None,
                         n_chunks=n_chunks, bubble_frac=bubble)


@dataclasses.dataclass(frozen=True)
class MulticutResult:
    """Joint (S1 × S2 × codec [× chunks]) optimum for a whole bandwidth
    sweep (arrays of shape ``(B,)``).  ``s2[b] == n`` means the optimum
    collapsed to the single-cut plan at ``s1[b]`` (no on-edge tail);
    ``s1 == s2`` is edge-only.  ``codec_idx`` indexes ``codec_names``
    (both cuts of a plan share the chosen codec).  When the search ran
    with a chunk axis (``search_streamed``), ``n_chunks[b]`` is the
    jointly-optimal streaming chunk count for the uplink cut and
    ``bubble_frac[b]`` the modeled fill/drain fraction of its pipeline
    (``core/pipeline.py``); both are ``None`` for non-streamed
    searches."""
    bandwidths_bps: np.ndarray
    s1: np.ndarray
    s2: np.ndarray
    total_s: np.ndarray
    edge_s: np.ndarray
    cloud_s: np.ndarray
    up_s: np.ndarray
    down_s: np.ndarray
    n: int
    codec_idx: Optional[np.ndarray] = None
    codec_names: Optional[Tuple[str, ...]] = None
    n_chunks: Optional[np.ndarray] = None
    bubble_frac: Optional[np.ndarray] = None

    def codec_at(self, b: int) -> Optional[str]:
        if self.codec_idx is None:
            return None
        return self.codec_names[int(self.codec_idx[b])]

    def chunks_at(self, b: int) -> int:
        return int(self.n_chunks[b]) if self.n_chunks is not None else 1

    def plan_at(self, b: int) -> PlacementPlan:
        """Materialize bandwidth bin ``b`` as a ``PlacementPlan``."""
        return PlacementPlan.from_window(int(self.s1[b]), int(self.s2[b]),
                                         self.n, self.codec_at(b),
                                         self.chunks_at(b))


@dataclasses.dataclass(frozen=True)
class _PlanTensors:
    """Shared intermediates of the vectorized placement searches: the
    sequential (non-streamed) totals plus everything the streamed chunk
    axis needs on top (split encode/decode overheads, compressed wire).
    Built once per (GraphArrays, bandwidth grid) by ``_plan_tensors`` and
    consumed by both ``search_multicut`` and ``search_streamed`` — the
    refactor that keeps the two searches priced by ONE set of
    expressions."""
    n_c: int                    # codec-axis length (1 when codec-free)
    edge_t: np.ndarray          # (S1, S2) edge head+tail seconds
    cloud_t: np.ndarray         # (S1, S2) cloud window seconds
    tri: np.ndarray             # (S1, S2) real-window mask (s1 < s2)
    infeasible: np.ndarray      # (S1, S2) budget / ordering mask
    up_w: np.ndarray            # (C, S) compressed uplink wire bytes
    up_enc: np.ndarray          # (C, S) uplink encode seconds (edge side)
    up_dec: np.ndarray          # (C, S) uplink decode seconds (cloud side)
    net_up: np.ndarray          # (C, S, B) sequential uplink leg seconds
    net_dn: np.ndarray          # (C, S, B) sequential downlink leg seconds
    totals: np.ndarray          # (C, S1, S2, B) sequential plan totals
    queue_t: Optional[np.ndarray] = None  # (S1, S2) M/G/1 wait, or None


def _plan_tensors(ga: GraphArrays, bw: np.ndarray,
                  cloud_budget_bytes: Optional[float],
                  cs: Optional[Sequence[Codec]], rtt_s: float,
                  down_bw_factor: float, single_cut_only: bool,
                  edge: DeviceSpec, cloud: DeviceSpec,
                  queue_hz: float = 0.0, queue_cv2: float = 1.0,
                  queue_service_scale: float = 1.0) -> _PlanTensors:
    """Build the (C, S1, S2, B) sequential-pricing tensors — the exact
    expressions ``search_multicut`` has always evaluated, factored out so
    ``search_streamed`` prices its K = 1 plane with bit-identical
    arithmetic (the ``n_chunks = 1 ≡ non-streamed`` acceptance gate)."""
    n = ga.n
    S = n + 1
    budget = cloud_budget_bytes if cloud_budget_bytes is not None \
        else float("inf")
    s1 = np.arange(S)[:, None]
    s2 = np.arange(S)[None, :]
    tri = s1 < s2                                   # real cloud window
    E, C_, L = ga.edge_s, ga.cloud_s, ga.cloud_load_bytes
    edge_t = E[:, None] + (E[n] - E[None, :])       # (S1, S2)
    cloud_t = np.where(tri, C_[:, None] - C_[None, :], 0.0)
    load = np.where(tri, L[:, None] - L[None, :], 0.0)
    infeasible = (s1 > s2) | (load > budget)
    if single_cut_only:
        infeasible = infeasible | (s2 != n)

    # per-(codec, cut) compressed wire + codec compute (C, S); raw when no
    # codec axis.  Uplink encodes on the edge, downlink on the cloud.
    # The sequential totals use the COMBINED overhead (rate-sum, the
    # historical formula); the split enc/dec sides feed the streamed
    # pipeline stages only.
    if cs is None:
        up_w, up_o = ga.wire_bytes[None, :], np.zeros((1, S))
        up_enc = up_dec = np.zeros((1, S))
        dn_w, dn_o = ga.down_wire_bytes[None, :], np.zeros((1, S))
        n_c = 1
    else:
        up_w, up_o = _codec_wire_overhead(ga.wire_bytes, n, cs, edge, cloud)
        _, up_enc, up_dec = _codec_wire_split(ga.wire_bytes, n, cs,
                                              edge, cloud)
        dn_w, dn_o = _codec_wire_overhead(ga.down_wire_bytes, n, cs,
                                          cloud, edge)
        n_c = len(cs)
    net_up = np.where(up_w[:, :, None] > 0,
                      up_w[:, :, None] / bw[None, None, :] + rtt_s, 0.0) \
        + up_o[:, :, None]                          # (C, S, B)
    net_dn = np.where(dn_w[:, :, None] > 0,
                      dn_w[:, :, None] / (bw[None, None, :]
                                          * down_bw_factor) + rtt_s, 0.0) \
        + dn_o[:, :, None]

    totals = edge_t[None, :, :, None] + cloud_t[None, :, :, None] \
        + np.where(tri[None, :, :, None],
                   net_up[:, :, None, :] + net_dn[:, None, :, :], 0.0)
    queue_t = None
    if queue_hz > 0:
        # M/G/1 wait on the window's cloud service time (0 outside the
        # triangular region since cloud_t is 0 there)
        queue_t = queue_delay_s(cloud_t, queue_hz, cv2=queue_cv2,
                                service_scale=queue_service_scale)
        totals = totals + queue_t[None, :, :, None]
    totals = np.where(infeasible[None, :, :, None], np.inf, totals)
    return _PlanTensors(n_c=n_c, edge_t=edge_t, cloud_t=cloud_t, tri=tri,
                        infeasible=infeasible, up_w=up_w, up_enc=up_enc,
                        up_dec=up_dec, net_up=net_up, net_dn=net_dn,
                        totals=totals, queue_t=queue_t)


def search_multicut_scalar(graph: Sequence[LayerCost], edge: DeviceSpec,
                           cloud: DeviceSpec, bandwidth_bps: float,
                           cloud_budget_bytes: Optional[float] = None, *,
                           codecs: Optional[Sequence] = None,
                           rtt_s: float = 0.0, input_bytes: float = 0.0,
                           down_bw_factor: float = 1.0,
                           arrays: Optional[GraphArrays] = None,
                           max_err: Optional[float] = None,
                           queue_hz: float = 0.0, queue_cv2: float = 1.0,
                           queue_service_scale: float = 1.0
                           ) -> PlacementEval:
    """Scalar (S1, S2, codec) oracle: exhaustive triangular scan in the
    exact tie-break order the vectorized pass reproduces — earliest codec
    in the list, then largest ``S1``, then largest ``S2`` (so single-cut
    ``S2 = n`` wins ties over a pointless second cut).  The property-test
    oracle for ``search_multicut``.  ``queue_hz > 0`` adds the window's
    M/G/1 wait to each candidate total (the wait rides ``total_s`` only,
    not the physical decomposition)."""
    ga = arrays if arrays is not None else graph_arrays(
        graph, edge, cloud, input_bytes=input_bytes)
    n = ga.n
    budget = cloud_budget_bytes if cloud_budget_bytes is not None \
        else float("inf")
    cs = resolve_codecs(codecs, max_err)
    axis: Sequence[Optional[Codec]] = cs if cs is not None else (None,)
    best = None
    for ci, c in enumerate(axis):
        for s1 in range(n, -1, -1):
            for s2 in range(n, s1 - 1, -1):
                if ga.window_load_bytes(s1, s2) > budget:
                    continue
                e, cl, up, dn = ga.placement_latency(
                    s1, s2, bandwidth_bps, rtt_s, codec=c,
                    down_bw_factor=down_bw_factor)
                total = e + cl + up + dn
                if queue_hz > 0:
                    total += queue_delay_s(cl, queue_hz, cv2=queue_cv2,
                                           service_scale=queue_service_scale)
                if best is None or total < best[0]:
                    best = (total, ci, s1, s2, e, cl, up, dn)
    assert best is not None, "no feasible placement (budget < 0?)"
    total, ci, s1, s2, e, cl, up, dn = best
    name = axis[ci].name if axis[ci] is not None else None
    plan = PlacementPlan.from_window(s1, s2, n, name)
    return PlacementEval(plan=plan, total_s=total, edge_s=e, cloud_s=cl,
                         up_s=up, down_s=dn,
                         cloud_load_bytes=ga.window_load_bytes(s1, s2),
                         codec=name)


def search_multicut(graph: Sequence[LayerCost], edge: DeviceSpec,
                    cloud: DeviceSpec, bandwidths_bps,
                    cloud_budget_bytes: Optional[float] = None, *,
                    codecs: Optional[Sequence] = None,
                    rtt_s: float = 0.0, input_bytes: float = 0.0,
                    down_bw_factor: float = 1.0,
                    arrays: Optional[GraphArrays] = None,
                    max_err: Optional[float] = None,
                    single_cut_only: bool = False, queue_hz: float = 0.0,
                    queue_cv2: float = 1.0,
                    queue_service_scale: float = 1.0) -> MulticutResult:
    """Vectorized multi-cut Alg. 1: the joint optimum over every
    edge→cloud→edge plan ``(S1 ≤ S2)``, every codec and every bandwidth in
    one (C, S1, S2, B) numpy pass.

    Equivalent to ``search_multicut_scalar`` per bandwidth (ties: earliest
    codec, largest S1, largest S2 — single-cut preferred on ties).  The
    cloud budget gates the **window** load ``weights[S1:S2)``; restricted
    to ``single_cut_only`` (mask ``S2 = n``) the pass reproduces
    ``search``/``search_vec`` exactly — the K=1 property the tests pin.
    Bandwidths in BYTES/s, latencies in seconds; the downlink leg rides
    ``down_bw_factor × bandwidth``.
    """
    ga = arrays if arrays is not None else graph_arrays(
        graph, edge, cloud, input_bytes=input_bytes)
    bw = np.atleast_1d(np.asarray(bandwidths_bps, dtype=np.float64))
    cs = resolve_codecs(codecs, max_err)
    pt = _plan_tensors(ga, bw, cloud_budget_bytes, cs, rtt_s,
                       down_bw_factor, single_cut_only, edge, cloud,
                       queue_hz, queue_cv2, queue_service_scale)
    n, S = ga.n, ga.n + 1

    # flatten (codec, flipped-S1, flipped-S2): first occurrence of the min
    # is the earliest codec at the largest (S1, S2) — the scalar tie-break
    flat = pt.totals[:, ::-1, ::-1, :].reshape(pt.n_c * S * S, len(bw))
    idx = np.argmin(flat, axis=0)
    ci = idx // (S * S)
    rem = idx % (S * S)
    s1v = n - rem // S
    s2v = n - rem % S
    cols = np.arange(len(bw))
    real = s1v < s2v
    return MulticutResult(
        bandwidths_bps=bw, s1=s1v, s2=s2v,
        total_s=pt.totals[ci, s1v, s2v, cols],
        edge_s=pt.edge_t[s1v, s2v], cloud_s=pt.cloud_t[s1v, s2v],
        up_s=np.where(real, pt.net_up[ci, s1v, cols], 0.0),
        down_s=np.where(real, pt.net_dn[ci, s2v, cols], 0.0),
        n=n,
        codec_idx=ci if cs is not None else None,
        codec_names=tuple(c.name for c in cs) if cs is not None else None)


# ------------------------------------------------------------- streamed
def _chunk_axis(chunk_grid) -> Tuple[int, ...]:
    """Normalize a chunk grid: ints, sorted ascending, deduplicated, and
    ALWAYS containing 1 — the sequential option must stay searchable (it
    is the only legal choice wherever streaming does not apply)."""
    ks = sorted({int(k) for k in chunk_grid} | {1})
    if ks[0] < 1:
        raise ValueError(f"chunk counts must be >= 1, got {chunk_grid}")
    return tuple(ks)


def search_streamed_scalar(graph: Sequence[LayerCost], edge: DeviceSpec,
                           cloud: DeviceSpec, bandwidth_bps: float,
                           cloud_budget_bytes: Optional[float] = None, *,
                           codecs: Optional[Sequence] = None,
                           chunk_grid=DEFAULT_CHUNK_GRID,
                           rtt_s: float = 0.0, input_bytes: float = 0.0,
                           down_bw_factor: float = 1.0,
                           arrays: Optional[GraphArrays] = None,
                           max_err: Optional[float] = None,
                           single_cut_only: bool = False,
                           queue_hz: float = 0.0, queue_cv2: float = 1.0,
                           queue_service_scale: float = 1.0
                           ) -> PlacementEval:
    """Scalar (S1, S2, codec, n_chunks) oracle: exhaustive scan in the
    exact tie-break order the vectorized pass reproduces — earliest codec,
    largest ``S1``, largest ``S2``, then SMALLEST chunk count (so the
    sequential transfer wins ties over pointless chunking).  ``K = 1``
    cells are priced by the identical sequential expressions as
    ``search_multicut_scalar``; ``K > 1`` cells by the chunk-pipeline
    makespan recurrence (``pipeline.stream_makespan_scalar``).  The
    property-test oracle for ``search_streamed``."""
    ga = arrays if arrays is not None else graph_arrays(
        graph, edge, cloud, input_bytes=input_bytes)
    n = ga.n
    budget = cloud_budget_bytes if cloud_budget_bytes is not None \
        else float("inf")
    cs = resolve_codecs(codecs, max_err)
    axis: Sequence[Optional[Codec]] = cs if cs is not None else (None,)
    ks = _chunk_axis(chunk_grid)
    best = None
    for ci, c in enumerate(axis):
        for s1 in range(n, -1, -1):
            for s2 in range(n, s1 - 1, -1):
                if single_cut_only and s2 != n:
                    continue
                if ga.window_load_bytes(s1, s2) > budget:
                    continue
                e, cl, up, dn = ga.placement_latency(
                    s1, s2, bandwidth_bps, rtt_s, codec=c,
                    down_bw_factor=down_bw_factor)
                wire = float(ga.wire_bytes[s1])
                # chunking overlaps transport, not the queue: every K
                # cell of a window pays the same M/G/1 wait
                wq = queue_delay_s(cl, queue_hz, cv2=queue_cv2,
                                   service_scale=queue_service_scale) \
                    if queue_hz > 0 else 0.0
                for k in ks:
                    if k == 1:
                        total, up_k, bub = e + cl + up + dn + wq, up, 0.0
                    elif s1 < s2 and stream_applies(s1, n, wire):
                        enc = c.encode_s(wire, edge) if c is not None else 0.0
                        dec = c.decode_s(wire, cloud) if c is not None \
                            else 0.0
                        wire_c = c.wire_bytes(wire) if c is not None else wire
                        m = stream_makespan_scalar(
                            enc, wire_c / bandwidth_bps, dec + cl, k, rtt_s)
                        total = (e + m) + dn + wq
                        up_k = m - cl
                        bub = float(stream_bubble_fraction(
                            enc, wire_c / bandwidth_bps, dec + cl, k, rtt_s))
                    else:
                        continue            # streaming not applicable
                    if best is None or total < best[0]:
                        best = (total, ci, s1, s2, k, e, cl, up_k, dn, bub)
    assert best is not None, "no feasible placement (budget < 0?)"
    total, ci, s1, s2, k, e, cl, up, dn, bub = best
    name = axis[ci].name if axis[ci] is not None else None
    plan = PlacementPlan.from_window(s1, s2, n, name, k)
    return PlacementEval(plan=plan, total_s=total, edge_s=e, cloud_s=cl,
                         up_s=up, down_s=dn,
                         cloud_load_bytes=ga.window_load_bytes(s1, s2),
                         codec=name, n_chunks=k, bubble_frac=bub)


def search_streamed(graph: Sequence[LayerCost], edge: DeviceSpec,
                    cloud: DeviceSpec, bandwidths_bps,
                    cloud_budget_bytes: Optional[float] = None, *,
                    codecs: Optional[Sequence] = None,
                    chunk_grid=DEFAULT_CHUNK_GRID,
                    rtt_s: float = 0.0, input_bytes: float = 0.0,
                    down_bw_factor: float = 1.0,
                    arrays: Optional[GraphArrays] = None,
                    max_err: Optional[float] = None,
                    single_cut_only: bool = False, queue_hz: float = 0.0,
                    queue_cv2: float = 1.0,
                    queue_service_scale: float = 1.0) -> MulticutResult:
    """Vectorized streamed Alg. 1: the joint optimum over every placement
    window, codec, streaming chunk count and bandwidth in one
    (C, S1, S2, K, B) numpy pass.

    The ``K = 1`` plane IS the sequential (C, S1, S2, B) tensor
    ``search_multicut`` evaluates — built by the shared
    ``_plan_tensors`` helper, so restricting ``chunk_grid=(1,)``
    reproduces the non-streamed sweep bit-for-bit.  ``K > 1`` planes
    price the uplink leg as the 3-stage chunk pipeline
    (``pipeline.stream_makespan`` closed form): the cloud window's
    prefill overlaps the transfer, each chunk pays its own rtt, and
    streaming is masked off wherever a codec would not apply
    (``pipeline.stream_applies``).  Equivalent to
    ``search_streamed_scalar`` per bandwidth (ties: earliest codec,
    largest S1, largest S2, smallest chunk count).  Bandwidths in
    BYTES/s, latencies in seconds."""
    ga = arrays if arrays is not None else graph_arrays(
        graph, edge, cloud, input_bytes=input_bytes)
    n = ga.n
    S = n + 1
    bw = np.atleast_1d(np.asarray(bandwidths_bps, dtype=np.float64))
    cs = resolve_codecs(codecs, max_err)
    ks = _chunk_axis(chunk_grid)
    pt = _plan_tensors(ga, bw, cloud_budget_bytes, cs, rtt_s,
                       down_bw_factor, single_cut_only, edge, cloud,
                       queue_hz, queue_cv2, queue_service_scale)

    # streaming gate: mid-graph uplink cuts with traffic, inside a real
    # cloud window (mirrors codec_applies + non-empty payload)
    app = np.zeros(S, dtype=bool)
    app[1:n] = ga.wire_bytes[1:n] > 0
    s1i = np.arange(S)[:, None]
    s2i = np.arange(S)[None, :]
    stream_ok = (s1i < s2i) & app[:, None] & ~pt.infeasible

    planes = []
    bub_planes = []
    for k in ks:
        if k == 1:
            planes.append(pt.totals)
            bub_planes.append(np.zeros_like(pt.totals))
            continue
        # per-chunk stages (C, S1, S2, B): a = encode, b = wire + rtt,
        # c = decode + chunked prefill of the cloud window
        enc = pt.up_enc[:, :, None, None]
        wire_t = pt.up_w[:, :, None, None] / bw[None, None, None, :]
        comp = pt.up_dec[:, :, None, None] + pt.cloud_t[None, :, :, None]
        m = stream_makespan(enc, wire_t, comp, k, rtt_s)
        plane = (pt.edge_t[None, :, :, None] + m) + pt.net_dn[:, None, :, :]
        if pt.queue_t is not None:
            plane = plane + pt.queue_t[None, :, :, None]
        planes.append(np.where(stream_ok[None, :, :, None], plane, np.inf))
        bub_planes.append(stream_bubble_fraction(enc, wire_t, comp, k,
                                                 rtt_s))
    totals = np.stack(planes, axis=3)               # (C, S1, S2, K, B)
    bubbles = np.stack(bub_planes, axis=3)

    # flatten (codec, flipped-S1, flipped-S2, K): first occurrence of the
    # min is the earliest codec at the largest (S1, S2) with the smallest
    # chunk count — the scalar oracle's tie-break
    nK = len(ks)
    n_c = pt.n_c
    flat = totals[:, ::-1, ::-1, :, :].reshape(n_c * S * S * nK, len(bw))
    idx = np.argmin(flat, axis=0)
    ci = idx // (S * S * nK)
    rem = idx % (S * S * nK)
    s1v = n - rem // (S * nK)
    rem2 = rem % (S * nK)
    s2v = n - rem2 // nK
    ki = rem2 % nK
    cols = np.arange(len(bw))
    kv = np.asarray(ks, dtype=int)[ki]
    real = s1v < s2v
    cloud_chosen = pt.cloud_t[s1v, s2v]
    total_chosen = totals[ci, s1v, s2v, ki, cols]
    down_chosen = np.where(real, pt.net_dn[ci, s2v, cols], 0.0)
    # uplink-exposed seconds: sequential leg for K = 1 bins, makespan −
    # cloud window for streamed bins (back out of the chosen total so the
    # edge/cloud/up/down decomposition stays additive — minus the queue
    # wait, which rides total_s only)
    up_seq = np.where(real, pt.net_up[ci, s1v, cols], 0.0)
    queue_chosen = pt.queue_t[s1v, s2v] if pt.queue_t is not None else 0.0
    up_chosen = np.where(kv == 1, up_seq,
                         total_chosen - pt.edge_t[s1v, s2v]
                         - cloud_chosen - down_chosen - queue_chosen)
    return MulticutResult(
        bandwidths_bps=bw, s1=s1v, s2=s2v,
        total_s=total_chosen,
        edge_s=pt.edge_t[s1v, s2v], cloud_s=cloud_chosen,
        up_s=up_chosen, down_s=down_chosen,
        n=n,
        codec_idx=ci if cs is not None else None,
        codec_names=tuple(c.name for c in cs) if cs is not None else None,
        n_chunks=kv,
        bubble_frac=bubbles[ci, s1v, s2v, ki, cols])


def sweep_multicut(graphs: Mapping[str, Sequence[LayerCost]],
                   edge: DeviceSpec, cloud: DeviceSpec, bandwidths_bps,
                   cloud_budget_bytes: Union[None, float,
                                             Mapping[str,
                                                     Optional[float]]] = None,
                   *, codecs: Optional[Sequence] = None,
                   rtt_s: float = 0.0,
                   input_bytes: Union[float, Mapping[str, float]] = 0.0,
                   down_bw_factor: float = 1.0,
                   max_err: Optional[float] = None,
                   single_cut_only: bool = False,
                   chunk_grid=None, queue_hz: float = 0.0,
                   queue_cv2: float = 1.0, queue_service_scale: float = 1.0
                   ) -> Dict[str, MulticutResult]:
    """Fleet-scale multi-cut plan: one padded (M, C, S1, S2, B) pass over
    every registered model — the multi-cut sibling of ``sweep_search``.
    Shallower models are masked (not padded with sentinel costs) so the
    triangular window algebra stays finite.  Per-model budgets /
    input_bytes accept the same scalar-or-mapping forms as
    ``sweep_search``.

    ``chunk_grid`` adds the streamed chunk axis: each model runs its own
    (C, S1, S2, K, B) ``search_streamed`` pass (per-model rather than one
    padded all-model tensor — the extra K axis makes the padded tensor
    memory-heavy for no planner-rate win; the per-model passes are still
    one numpy evaluation each) and every bin carries the joint
    (S1, S2, codec, n_chunks) optimum.  ``chunk_grid=(1,)`` reproduces
    the non-streamed sweep bit-for-bit."""
    names = list(graphs)
    if not names:
        raise ValueError("sweep_multicut needs at least one graph")
    bw = np.atleast_1d(np.asarray(bandwidths_bps, dtype=np.float64))
    cs = resolve_codecs(codecs, max_err)
    n_c = len(cs) if cs is not None else 1

    def per_model(val, name, default):
        if isinstance(val, Mapping):
            v = val.get(name, default)
        else:
            v = val if val is not None else default
        return default if v is None else v

    if chunk_grid is not None:
        return {
            k: search_streamed(
                g, edge, cloud, bw,
                per_model(cloud_budget_bytes, k, None),
                codecs=codecs, chunk_grid=chunk_grid, rtt_s=rtt_s,
                input_bytes=per_model(input_bytes, k, 0.0),
                down_bw_factor=down_bw_factor, max_err=max_err,
                single_cut_only=single_cut_only, queue_hz=queue_hz,
                queue_cv2=queue_cv2,
                queue_service_scale=queue_service_scale)
            for k, g in graphs.items()}

    gas = [graph_arrays(graphs[k], edge, cloud,
                        input_bytes=per_model(input_bytes, k, 0.0))
           for k in names]
    S = max(ga.n for ga in gas) + 1
    M = len(names)
    ns = np.array([ga.n for ga in gas])

    def pad(vals):
        out = np.zeros((M, S), dtype=np.float64)
        for i, v in enumerate(vals):
            out[i, :len(v)] = v
        return out

    E = pad([ga.edge_s for ga in gas])
    C = pad([ga.cloud_s for ga in gas])
    L = pad([ga.cloud_load_bytes for ga in gas])
    Wu = pad([ga.wire_bytes for ga in gas])
    Wd = pad([ga.down_wire_bytes for ga in gas])
    En = E[np.arange(M), ns]                        # total edge latency
    budgets = np.array([per_model(cloud_budget_bytes, k, float("inf"))
                        for k in names], dtype=np.float64)

    s1 = np.arange(S)[:, None]
    s2 = np.arange(S)[None, :]
    tri = (s1 < s2)[None, :, :]
    in_range = (s1[None, :, :] <= ns[:, None, None]) \
        & (s2[None, :, :] <= ns[:, None, None])
    edge_t = E[:, :, None] + (En[:, None, None] - E[:, None, :])  # (M,S1,S2)
    cloud_t = np.where(tri, C[:, :, None] - C[:, None, :], 0.0)
    load = np.where(tri, L[:, :, None] - L[:, None, :], 0.0)
    infeasible = ~in_range | (s1 > s2)[None, :, :] \
        | (load > budgets[:, None, None])
    if single_cut_only:
        infeasible = infeasible | (s2[None, :, :] != ns[:, None, None])

    # per-model codec wire/overhead (M, C, S) — mid-graph gate uses each
    # model's own depth, so the shared helper runs on the unpadded prefix
    up_w = np.zeros((M, n_c, S))
    up_o = np.zeros((M, n_c, S))
    dn_w = np.zeros((M, n_c, S))
    dn_o = np.zeros((M, n_c, S))
    for i, ga in enumerate(gas):
        k = ga.n + 1
        if cs is None:
            up_w[i, 0, :k] = ga.wire_bytes
            dn_w[i, 0, :k] = ga.down_wire_bytes
        else:
            up_w[i, :, :k], up_o[i, :, :k] = _codec_wire_overhead(
                ga.wire_bytes, ga.n, cs, edge, cloud)
            dn_w[i, :, :k], dn_o[i, :, :k] = _codec_wire_overhead(
                ga.down_wire_bytes, ga.n, cs, cloud, edge)
    net_up = np.where(up_w[..., None] > 0,
                      up_w[..., None] / bw[None, None, None, :] + rtt_s,
                      0.0) + up_o[..., None]        # (M, C, S, B)
    net_dn = np.where(dn_w[..., None] > 0,
                      dn_w[..., None] / (bw[None, None, None, :]
                                         * down_bw_factor) + rtt_s,
                      0.0) + dn_o[..., None]

    totals = edge_t[:, None, :, :, None] + cloud_t[:, None, :, :, None] \
        + np.where(tri[:, None, :, :, None],
                   net_up[:, :, :, None, :] + net_dn[:, :, None, :, :], 0.0)
    if queue_hz > 0:
        # (M, S1, S2) M/G/1 wait on the window's cloud service time
        qd = queue_delay_s(cloud_t, queue_hz, cv2=queue_cv2,
                           service_scale=queue_service_scale)
        totals = totals + qd[:, None, :, :, None]
    totals = np.where(infeasible[:, None, :, :, None], np.inf, totals)

    flat = totals[:, :, ::-1, ::-1, :].reshape(M, n_c * S * S, len(bw))
    idx = np.argmin(flat, axis=1)                   # (M, B)
    cols = np.arange(len(bw))
    out: Dict[str, MulticutResult] = {}
    codec_names = tuple(c.name for c in cs) if cs is not None else None
    for i, k in enumerate(names):
        n_i = gas[i].n
        ci = idx[i] // (S * S)
        rem = idx[i] % (S * S)
        # un-flip the padded axes; out-of-range cells are inf-masked, so
        # the first-occurrence argmin still lands on the largest VALID
        # (S1, S2) — the scalar tie-break — for every model depth
        s1v = (S - 1) - rem // S
        s2v = (S - 1) - rem % S
        real = s1v < s2v
        out[k] = MulticutResult(
            bandwidths_bps=bw, s1=s1v, s2=s2v,
            total_s=totals[i, ci, s1v, s2v, cols],
            edge_s=edge_t[i, s1v, s2v], cloud_s=cloud_t[i, s1v, s2v],
            up_s=np.where(real, net_up[i, ci, s1v, cols], 0.0),
            down_s=np.where(real, net_dn[i, ci, s2v, cols], 0.0),
            n=n_i,
            codec_idx=ci if cs is not None else None,
            codec_names=codec_names)
    return out


def fixed_split(graph: Sequence[LayerCost]) -> int:
    """Baseline: ~50/50 weight split (paper's "Fixed Seg")."""
    total = sum(c.weight_bytes for c in graph)
    acc = 0.0
    for i, c in enumerate(graph):
        acc += c.weight_bytes
        if acc >= total / 2:
            return i + 1
    return len(graph) // 2
