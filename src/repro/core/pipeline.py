"""Streamed split execution — the chunked 3-stage transfer pipeline.

RoboECC's Eq. 2 cost model (and every decision layer built on it through
PR 3) prices a split as *edge compute + full activation transfer + cloud
compute*, strictly in sequence: at the 0.2–1 MB/s operating points the
link sits idle while a tier computes and vice versa.  ActionFlow
(arXiv 2512.20276) shows that pipelining chunked work across the edge
boundary recovers exactly this dead time, and the XPU characterization
line of PAPERS.md shows transfer — not compute — dominates VLA split
latency on weak links.  This module is the shared *makespan* model for
that streamed execution: the cut activation is sliced into ``n_chunks``
along the token/patch axis and shipped through a 3-stage pipeline

    stage 1  edge encode     (codec encode of chunk i on the edge device)
    stage 2  uplink          (chunk wire bytes / bandwidth + per-chunk rtt)
    stage 3  cloud decode +  (codec decode of chunk i, then prefill of the
             chunked prefill  arrived chunk — exact under causal attention,
                              the vLLM chunked-prefill argument)

so the planner prices ``max``-based pipeline *makespan* instead of a sum.
Chunked prefill is what makes streaming worth anything here: codec
encode/decode is µs-scale, but overlapping the cloud window's compute
with the transfer recovers up to ``min(cloud_s, wire_s)`` per request.

The trade the planner searches: more chunks shrink the fill/drain bubbles
(the first chunk's encode and the last chunk's decode+prefill are exposed)
but every chunk pays its own ``rtt`` on the wire stage — so chunking wins
on slow links where wire time dwarfs the rtt and *loses* on fast links
where the per-chunk rtt is the whole transfer (the honest negative result
recorded in docs/EXPERIMENTS.md §Streaming).  A chunk count picked for
10 MB/s is wrong at 0.2 MB/s — the paper's performance-drift story
replayed on a new axis — which is why ``core/controller.py`` replans
``n_chunks`` from the LSTM bandwidth forecast and ``runtime/fleet.py``
counts ``n_chunk_reconfigs``.

Two implementations of the same model (PR 2/3 parity discipline):

* ``stream_makespan_scalar`` — the literal chunk-by-chunk pipeline
  recurrence (supports non-uniform per-chunk transfer times, which the
  fleet's trace-integrating transfers produce); the property-test oracle.
* ``stream_makespan`` — the closed form for uniform chunks,
  numpy-broadcastable over whole (codec × S1 × S2 × K × bandwidth)
  planner tensors (``segmentation.search_streamed``).

``n_chunks = 1`` is *defined* as the sequential path: every planner and
runtime consumer short-circuits K = 1 cells to the exact non-streamed
expression, so streaming with one chunk reproduces today's numbers
bit-for-bit (DESIGN.md §9).  Streaming applies only where a codec would
(``stream_applies``): mid-graph cuts with traffic — the S = 0 raw
observation upload and the S = n no-transfer extreme never chunk.
"""
from __future__ import annotations

from typing import Sequence, Union

import numpy as np

# Chunk counts every planner searches by default.  Powers of two keep the
# token-axis slices even-ish; 16 is past the point where per-chunk rtt
# dominates at every modeled operating point, so the grid brackets the
# optimum rather than clipping it.
DEFAULT_CHUNK_GRID = (1, 2, 4, 8, 16)

ArrayLike = Union[float, np.ndarray]


def stream_applies(split: int, n: int, wire_raw: float) -> bool:
    """Chunked streaming is meaningful only for mid-graph cuts with
    traffic — the same gate as ``segmentation.codec_applies`` plus a
    non-empty payload.  Extremes (raw-observation upload at S = 0,
    no-transfer at S = n) are forced to ``n_chunks = 1``."""
    return 0 < split < n and wire_raw > 0


def stream_makespan_scalar(enc_s: float, wire_s, comp_s: float,
                           n_chunks: int, rtt_s: float = 0.0) -> float:
    """Chunk-by-chunk 3-stage pipeline recurrence — the scalar oracle.

    ``enc_s`` / ``comp_s`` are the *totals* for stage 1 (edge encode) and
    stage 3 (cloud decode + window prefill), split uniformly across
    chunks.  ``wire_s`` is either the total stage-2 wire seconds (split
    uniformly) or a length-``n_chunks`` sequence of per-chunk wire
    seconds (the fleet's trace-integrated transfers are non-uniform);
    every chunk additionally pays ``rtt_s`` on the wire stage.

    Recurrence (t_* = completion time of chunk i in each stage)::

        t_enc[i] = t_enc[i-1] + a
        t_tx[i]  = max(t_enc[i], t_tx[i-1]) + b_i
        t_out[i] = max(t_tx[i],  t_out[i-1]) + c

    and the makespan is ``t_out[K-1]``.  ``n_chunks = 1`` degenerates to
    the sequential sum ``enc + wire + rtt + comp``.
    """
    K = int(n_chunks)
    if K < 1:
        raise ValueError(f"n_chunks must be >= 1, got {n_chunks}")
    if np.ndim(wire_s) == 0:
        b = np.full(K, float(wire_s) / K + rtt_s)
    else:
        b = np.asarray(wire_s, dtype=np.float64) + rtt_s
        if len(b) != K:
            raise ValueError(f"need {K} per-chunk wire times, got {len(b)}")
    a = enc_s / K
    c = comp_s / K
    t_enc = t_tx = t_out = 0.0
    for i in range(K):
        t_enc = t_enc + a
        t_tx = max(t_enc, t_tx) + float(b[i])
        t_out = max(t_tx, t_out) + c
    return t_out


def stream_makespan(enc_s: ArrayLike, wire_s: ArrayLike, comp_s: ArrayLike,
                    n_chunks: ArrayLike, rtt_s: ArrayLike = 0.0
                    ) -> np.ndarray:
    """Closed-form makespan for uniform chunks, broadcastable over planner
    tensors.  With per-chunk stage times ``a = enc/K``, ``b = wire/K +
    rtt``, ``c = comp/K`` the 3-stage pipeline finishes at::

        a + b + c + (K - 1) * max(a, b, c)

    (one pass through the pipe plus K-1 repeats of the bottleneck stage —
    the ``max`` term is the steady state, ``a + b + c - max`` the
    fill/drain bubbles).  Agrees with ``stream_makespan_scalar`` to float
    rounding; the planner parity tests pin the two together.
    """
    K = np.asarray(n_chunks, dtype=np.float64)
    a = np.asarray(enc_s, dtype=np.float64) / K
    b = np.asarray(wire_s, dtype=np.float64) / K + rtt_s
    c = np.asarray(comp_s, dtype=np.float64) / K
    return a + b + c + (K - 1.0) * np.maximum(np.maximum(a, b), c)


def stream_bubble_fraction(enc_s: ArrayLike, wire_s: ArrayLike,
                           comp_s: ArrayLike, n_chunks: ArrayLike,
                           rtt_s: ArrayLike = 0.0) -> np.ndarray:
    """Fraction of the makespan NOT covered by the bottleneck stage —
    the fill/drain dead time streaming has not (yet) recovered::

        bubble = (makespan - K * max(a, b, c)) / makespan

    1 chunk (sequential) exposes the two non-bottleneck stages entirely;
    perfect pipelining drives the fraction to 0.  Zero-work pipelines
    report 0.  Used by ``runtime/fleet.py`` for ``FleetReport``'s
    ``mean_bubble_frac`` counter."""
    K = np.asarray(n_chunks, dtype=np.float64)
    a = np.asarray(enc_s, dtype=np.float64) / K
    b = np.asarray(wire_s, dtype=np.float64) / K + rtt_s
    c = np.asarray(comp_s, dtype=np.float64) / K
    peak = np.maximum(np.maximum(a, b), c)
    m = a + b + c + (K - 1.0) * peak
    with np.errstate(invalid="ignore", divide="ignore"):
        frac = np.where(m > 0.0, (m - K * peak) / np.where(m > 0, m, 1.0),
                        0.0)
    return frac


def chunk_sizes(total: int, n_chunks: int) -> Sequence[int]:
    """Token-axis slice sizes for ``total`` rows in ``n_chunks`` chunks —
    ``numpy.array_split`` semantics (first ``total % K`` chunks one row
    longer), shared by the planner's byte accounting and the runtime's
    ``partition.chunk_payload`` so both layers slice identically."""
    K = int(n_chunks)
    if K < 1:
        raise ValueError(f"n_chunks must be >= 1, got {n_chunks}")
    base, extra = divmod(int(total), K)
    return [base + 1 if i < extra else base for i in range(K)]
