"""Flight-recorder telemetry: spans, streaming metrics, drift audit.

Zero-overhead-when-off observability for the fleet simulator
(``runtime/fleet.py``).  The design splits cleanly into three layers:

* **Span tracing** — every recorded request decomposes into typed stage
  spans (edge compute, encode/wire/decode of the uplink, cloud queue
  wait, batched service, downlink + edge tail) on per-cohort and
  per-replica lanes.  Span groups are held in a bounded ``Reservoir``
  (Algorithm R beyond the cap), so a 100k-robot run stays inside a fixed
  memory budget; ``runtime/trace_export.py`` renders the kept groups as
  Chrome trace-event JSON viewable in Perfetto.
* **Metrics registry** — counters, gauges and streaming quantile
  sketches (``QuantileSketch``, a t-digest-style fixed-size centroid
  merge: tails keep near-singleton resolution, the middle compresses)
  instead of full latency lists; the fleet report exposes one
  ``snapshot()`` dict.
* **Drift audit** — the planner's predicted stage decomposition
  (``evaluate_placement`` / ``stream_makespan`` / ``queue_delay_s``
  terms, captured at issue time) is joined against the measured spans at
  completion into per-stage signed-error sketches, plus an exact
  reconciliation check: the measured stages of every joined request must
  re-sum to its reported latency (``reconcile_max_abs_s``).

Determinism contract: the recorder NEVER touches the simulator's RNG —
the reservoir keeps its own ``random.Random`` and the sampling decision
is a pure hash of the request key (robot index × issue tick), so
recorder-off runs are bit-identical to a build without telemetry and
recorder-on runs never perturb the simulation's draw order
(tests/test_engine_parity.py pins both).
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Span", "Reservoir", "QuantileSketch", "MetricsRegistry",
    "DriftAudit", "FlightRecorder", "ContObserver", "DRIFT_STAGES",
]


# ------------------------------------------------------------------- spans
@dataclasses.dataclass(frozen=True)
class Span:
    """One timed stage on one lane.  ``lane`` names the track the span
    renders on (``robot:<arch>`` cohorts, ``replica:<name>``,
    ``proc:<process>``, ``executor:<tier>``); ``req`` ties the stages of
    one request together across lanes (-1 = unaffiliated)."""
    name: str                 # stage kind: "edge", "uplink", "queue", ...
    cat: str                  # trace category: "request", "cloud", "wall"
    t0_s: float
    dur_s: float
    lane: str
    req: int = -1


# --------------------------------------------------------------- reservoir
class Reservoir:
    """Bounded uniform sample of an unbounded stream (Algorithm R).

    The first ``cap`` offers are kept verbatim; beyond that each new item
    replaces a random kept one with probability ``cap / n_seen`` — every
    item in the stream ends up kept with equal probability, with memory
    pinned at ``cap``.  Uses its OWN ``random.Random(seed)`` so offering
    never perturbs any simulation RNG."""

    def __init__(self, cap: int, seed: int = 0):
        if cap < 1:
            raise ValueError("reservoir cap must be >= 1")
        self.cap = int(cap)
        self.n_seen = 0
        self._rng = random.Random(seed)
        self._items: List = []

    def offer(self, item) -> bool:
        """Offer one item; returns True when it was kept."""
        self.n_seen += 1
        if len(self._items) < self.cap:
            self._items.append(item)
            return True
        j = self._rng.randrange(self.n_seen)
        if j < self.cap:
            self._items[j] = item
            return True
        return False

    @property
    def items(self) -> List:
        return self._items

    def __len__(self) -> int:
        return len(self._items)


# ---------------------------------------------------------- quantile sketch
class QuantileSketch:
    """Fixed-size streaming quantile estimator (merging t-digest).

    Values buffer until ``max_centroids`` are pending, then merge into
    weighted centroids under the arcsine scale function
    ``k(q) = δ/(2π) · asin(2q − 1)``: adjacent items merge while their
    combined quantile range spans less than one k-unit, so the tails
    stay near-singleton (p99.9 keeps resolution) while the middle
    compresses.  ``k`` spans δ/2 units over [0, 1], which hard-caps the
    merged centroid count at ``δ/2 + 2`` — memory is O(max_centroids)
    regardless of stream length.  No RNG, so identical streams give
    identical sketches."""

    def __init__(self, max_centroids: int = 128):
        self.max_centroids = max(8, int(max_centroids))
        self._cent: List[Tuple[float, float]] = []   # (mean, weight) sorted
        self._buf: List[float] = []
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def add(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        self._buf.append(x)
        if len(self._buf) >= self.max_centroids:
            self._compress()

    def extend(self, xs: Sequence[float]) -> None:
        for x in xs:
            self.add(x)

    def _k(self, q: float) -> float:
        return self.max_centroids / (2.0 * math.pi) \
            * math.asin(2.0 * min(1.0, max(0.0, q)) - 1.0)

    def _compress(self) -> None:
        items = self._cent + [(x, 1.0) for x in self._buf]
        self._buf = []
        if not items:
            return
        items.sort(key=lambda mw: mw[0])
        total = sum(w for _, w in items)
        out: List[Tuple[float, float]] = []
        cum = 0.0                      # weight strictly before the open centroid
        k_lo = self._k(0.0)
        c_sum, c_w = items[0][0] * items[0][1], items[0][1]
        for m, w in items[1:]:
            if self._k((cum + c_w + w) / total) - k_lo > 1.0:
                out.append((c_sum / c_w, c_w))
                cum += c_w
                k_lo = self._k(cum / total)
                c_sum, c_w = 0.0, 0.0
            c_sum += m * w
            c_w += w
        out.append((c_sum / c_w, c_w))
        self._cent = out

    @property
    def n_centroids(self) -> int:
        return len(self._cent) + len(self._buf)

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile (0 <= q <= 1) by linear interpolation
        across centroid midpoints, anchored at the exact min/max."""
        if self.count == 0:
            return math.nan
        self._compress()
        cents = self._cent
        if q <= 0.0:
            return self.min
        if q >= 1.0:
            return self.max
        target = q * self.count
        # midpoint positions: centroid i sits at cum_before + w_i / 2
        pts = [(0.0, self.min)]
        cum = 0.0
        for m, w in cents:
            pts.append((cum + w / 2.0, m))
            cum += w
        pts.append((float(self.count), self.max))
        for k in range(1, len(pts)):
            p1, v1 = pts[k]
            if target <= p1:
                p0, v0 = pts[k - 1]
                if p1 <= p0:
                    return v1
                f = (target - p0) / (p1 - p0)
                return v0 + f * (v1 - v0)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def snapshot(self) -> Dict[str, float]:
        if self.count == 0:
            return {"n": 0}
        return {"n": self.count, "min": self.min, "max": self.max,
                "mean": self.mean, "p50": self.quantile(0.50),
                "p95": self.quantile(0.95), "p99": self.quantile(0.99)}


# --------------------------------------------------------- metrics registry
class MetricsRegistry:
    """Counters, gauges and streaming histograms behind string names.
    Replaces ad-hoc per-metric plumbing: a new measurement is one
    ``observe()`` call, not a new report field."""

    def __init__(self, max_centroids: int = 128):
        self._max_centroids = max_centroids
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, QuantileSketch] = {}

    def inc(self, name: str, n: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = QuantileSketch(self._max_centroids)
        h.add(value)

    def snapshot(self) -> dict:
        return {
            "counters": {k: self.counters[k] for k in sorted(self.counters)},
            "gauges": {k: self.gauges[k] for k in sorted(self.gauges)},
            "hists": {k: self.hists[k].snapshot()
                      for k in sorted(self.hists)},
        }


# -------------------------------------------------------------- drift audit
# Stage keys of the planner's predicted decomposition and the measured
# one.  The seconds-stages MUST re-sum to the reported request latency
# (reconciliation below); the unit-suffixed extras ride alongside.
DRIFT_STAGES = ("edge_s", "uplink_s", "queue_s", "service_s", "down_s",
                "total_s")
DRIFT_EXTRAS = ("wire_bytes", "bubble_frac")


class DriftAudit:
    """Predicted-vs-measured per-stage signed error distributions.

    ``join(pred, meas)`` takes the stage decomposition the planner
    priced at issue time and the stages the runtime actually measured,
    and feeds ``measured - predicted`` into one sketch per stage — a
    standing, regression-checked version of the M/G/1-vs-reality
    experiments.  Every join also re-sums the measured seconds-stages
    against ``meas["total_s"]`` (the latency the fleet reported) and
    tracks the worst absolute mismatch: a drifted *model* is expected,
    a drifted *accounting identity* is a bug."""

    def __init__(self, max_centroids: int = 128):
        self.err: Dict[str, QuantileSketch] = {
            k: QuantileSketch(max_centroids)
            for k in DRIFT_STAGES + DRIFT_EXTRAS}
        self.n_joined = 0
        self.n_pred_saturated = 0      # P-K prior hit rho >= 1 (wait = inf)
        self.reconcile_max_abs_s = 0.0

    def join(self, pred: dict, meas: dict) -> None:
        self.n_joined += 1
        for k in DRIFT_STAGES + DRIFT_EXTRAS:
            if k in pred and k in meas:
                self.err[k].add(float(meas[k]) - float(pred[k]))
        recon = float(abs((meas["edge_s"] + meas["uplink_s"]
                           + meas["queue_s"] + meas["service_s"]
                           + meas["down_s"]) - meas["total_s"]))
        if recon > self.reconcile_max_abs_s:
            self.reconcile_max_abs_s = recon

    def summary(self) -> dict:
        stages = {}
        for k in DRIFT_STAGES + DRIFT_EXTRAS:
            sk = self.err[k]
            if sk.count == 0:
                continue
            stages[k] = {"n": sk.count, "mean_err": sk.mean,
                         "p50_err": sk.quantile(0.50),
                         "p95_err": sk.quantile(0.95)}
        return {"n_joined": self.n_joined,
                "n_pred_saturated": self.n_pred_saturated,
                "reconcile_max_abs_s": self.reconcile_max_abs_s,
                "stages": stages}


# ---------------------------------------------------------- flight recorder
_HASH_KNUTH = 2654435761     # Fibonacci-hash multiplier for key sampling


class FlightRecorder:
    """The fleet's flight recorder; ``None`` on the simulator means off.

    ``mode="full"`` records every request; ``mode="sampled"`` records a
    deterministic ~``1/sample_every`` subset chosen by hashing the
    request key (robot index and issue tick — NOT arrival order, so the
    sampled set is identical whichever engine or batching path replays
    the run).  Span groups are reservoir-bounded at ``cap``; metrics and
    drift sketches are O(1) memory either way."""

    def __init__(self, mode: str = "sampled", cap: int = 65536,
                 sample_every: int = 64, seed: int = 0,
                 max_centroids: int = 128):
        if mode not in ("sampled", "full"):
            raise ValueError(f"telemetry mode {mode!r} "
                             "(expected 'sampled' or 'full')")
        self.mode = mode
        self.sample_every = max(1, int(sample_every))
        self.metrics = MetricsRegistry(max_centroids)
        self.drift = DriftAudit(max_centroids)
        self.spans = Reservoir(cap, seed=seed * 0x9E3779B1 + 1)
        self.n_recorded = 0
        # continuous-tier per-request state fed by ContObserver
        self._cont: Dict[int, dict] = {}

    # ------------------------------------------------------------ sampling
    def want(self, key: int) -> bool:
        """Record this request?  Pure function of the request key, so the
        decision is independent of event replay order."""
        if self.mode == "full":
            return True
        h = (key * _HASH_KNUTH) & 0xFFFFFFFF
        return h % self.sample_every == 0

    # ----------------------------------------------------- continuous hooks
    def cont_open(self, rid: int) -> None:
        """Register a sampled continuous-tier request: only opened rids
        accumulate observer state, so unsampled traffic costs the
        observer a single failed dict lookup per event."""
        self._cont[rid] = {"queue_s": 0.0, "spans": [],
                           "replica": None, "preempts": 0}

    def cont_admit(self, rid: int, wait_s: float, now_s: float,
                   kv_reserved: float, replica: str) -> None:
        st = self._cont.get(rid)
        if st is None:
            return
        st["queue_s"] += wait_s
        st["replica"] = replica
        st["spans"].append(Span("kv_admit", "cloud", now_s, 0.0,
                                f"replica:{replica}", rid))
        self.metrics.observe("cloud/kv_admit_wait_s", wait_s)
        self.metrics.observe("cloud/kv_reserved_bytes", kv_reserved)

    def cont_preempt(self, rid: int, now_s: float, replica: str) -> None:
        st = self._cont.get(rid)
        if st is None:
            return
        st["preempts"] += 1
        st["spans"].append(Span("preempt", "cloud", now_s, 0.0,
                                f"replica:{replica}", rid))
        self.metrics.inc("cloud/preemptions")

    def pop_cont(self, rid: int) -> Optional[dict]:
        return self._cont.pop(rid, None)

    # ------------------------------------------------------------ recording
    def record_span(self, span: Span) -> None:
        """Offer one free-standing span (e.g. executor wall-clock stages
        from ``runtime/partition.py``) to the reservoir."""
        self.spans.offer([span])

    def record_request(self, *, req: int, lane: str, t0_s: float,
                       edge_s: float, uplink_s: float, queue_s: float,
                       service_s: float, down_s: float, total_s: float,
                       replica: Optional[str] = None,
                       enc_s: float = 0.0, dec_s: float = 0.0,
                       pred: Optional[dict] = None,
                       extra_spans: Sequence[Span] = (),
                       outcome: str = "ok",
                       wire_bytes: Optional[float] = None,
                       bubble_frac: Optional[float] = None) -> None:
        """Fold one completed request: build its stage spans, feed the
        metrics sketches, and (when the issue-time prediction rode along)
        join the drift audit.  The five stage durations are the exact
        addends of the latency the fleet reported — reconciliation in
        ``DriftAudit.join`` holds by construction."""
        self.n_recorded += 1
        m = self.metrics
        m.inc("requests/total")
        m.inc(f"requests/{outcome}")
        m.observe("latency/total_s", total_s)
        m.observe("latency/edge_s", edge_s)
        m.observe("latency/uplink_s", uplink_s)
        m.observe("latency/queue_s", queue_s)
        m.observe("latency/service_s", service_s)
        if down_s:
            m.observe("latency/down_s", down_s)

        group: List[Span] = []
        t = t0_s
        if edge_s > 0.0:
            group.append(Span("edge", "request", t, edge_s, lane, req))
        t += edge_s
        if uplink_s > 0.0:
            # encode/decode sub-spans when the codec costs are known;
            # the wire span is the remainder of the uplink leg
            if enc_s > 0.0:
                group.append(Span("encode", "request", t, enc_s, lane, req))
            wire = max(0.0, uplink_s - enc_s - dec_s)
            group.append(Span("uplink", "request", t + enc_s, wire,
                              lane, req))
            if dec_s > 0.0:
                group.append(Span("decode", "request",
                                  t + enc_s + wire, dec_s, lane, req))
        t += uplink_s
        rlane = f"replica:{replica}" if replica is not None else lane
        if queue_s > 0.0:
            group.append(Span("queue", "cloud", t, queue_s, rlane, req))
        t += queue_s
        if service_s > 0.0:
            group.append(Span("service", "cloud", t, service_s, rlane, req))
        t += service_s
        if down_s > 0.0:
            group.append(Span("downlink", "request", t, down_s, lane, req))
        group.extend(extra_spans)
        self.spans.offer(group)

        if pred is not None:
            self.drift.join(pred, {
                "edge_s": edge_s, "uplink_s": uplink_s, "queue_s": queue_s,
                "service_s": service_s, "down_s": down_s, "total_s": total_s,
                **({"wire_bytes": wire_bytes} if wire_bytes is not None
                   else {}),
                **({"bubble_frac": bubble_frac} if bubble_frac is not None
                   else {}),
            })

    # ------------------------------------------------------------- snapshot
    def snapshot(self) -> dict:
        return {"mode": self.mode,
                "n_recorded": self.n_recorded,
                "spans": {"kept": len(self.spans),
                          "seen": self.spans.n_seen,
                          "cap": self.spans.cap},
                "metrics": self.metrics.snapshot(),
                "drift": self.drift.summary()}


# -------------------------------------------------------- batcher observer
class ContObserver:
    """Per-replica adapter between ``runtime/scheduler.ContinuousBatcher``
    and the recorder: the batcher only knows request ids and its own
    clock, the observer adds the replica identity and forwards admission
    waits / KV reservations / preemptions.  Attached by the fleet only
    when the recorder is on — a ``None`` observer costs the batcher one
    attribute check per event."""

    def __init__(self, recorder: FlightRecorder, replica: str):
        self.recorder = recorder
        self.replica = replica

    def on_admit(self, rid: int, wait_s: float, now_s: float,
                 kv_reserved: float) -> None:
        self.recorder.cont_admit(rid, wait_s, now_s, kv_reserved,
                                 self.replica)

    def on_preempt(self, rid: int, now_s: float) -> None:
        self.recorder.cont_preempt(rid, now_s, self.replica)
