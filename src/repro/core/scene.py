"""Scene-dynamics simulation: per-step token change fractions.

The temporal-delta codec (``codec.DeltaCodec``) ships only the token
rows that changed since the previous step, so its wire bytes depend on
*scene content*, not just the link.  This module is the content axis:
a seeded, reproducible trace of the fraction of token rows that change
at each control-loop tick — near zero for a static tabletop, near one
for a robot driving through a crowd.

The process mirrors ``network.generate_trace``'s shape on purpose: a
log-AR(1) fluctuation around a mean change fraction, plus rare "scene
event" spikes (an object enters the frame, the arm occludes the camera)
that momentarily drive the change fraction to ``event_frac``.  All
randomness is drawn in bulk up front (AR(1) normals then event
uniforms, in that order — the draw ORDER is part of the reproducibility
contract), and the AR(1) recurrence reuses ``network._ar1_kernel``.
Same ``(n_steps, cfg, seed)`` → bit-identical trace.

``generate_scene_matrix`` is the fleet-scale bulk variant, blocked like
``network.generate_trace_matrix`` so row ``i`` is bit-identical to the
1-D call with ``seeds[i]``.

Values are fractions in ``[floor_frac, ceil_frac] ⊆ [0, 1]``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Union

import numpy as np

from .network import _MATRIX_BLOCK_ROWS, _ar1_kernel


@dataclasses.dataclass(frozen=True)
class SceneConfig:
    mean_frac: float = 0.15         # typical fraction of changed token rows
    ar_rho: float = 0.9             # AR(1) smoothness of the fluctuation
    ar_sigma: float = 0.2           # relative (log-space) noise
    event_prob: float = 0.01        # per-step scene-event probability
    event_frac: float = 1.0         # change fraction during an event
    floor_frac: float = 0.005       # sensor noise never lets it hit zero
    ceil_frac: float = 1.0


#: Named scene classes for the benchmarks and the fleet config string
#: axis.  ``static`` is a fixed camera over a mostly-still tabletop,
#: ``slow`` a manipulation scene with steady arm motion, ``dynamic`` a
#: mobile robot in a busy environment where nearly every token changes
#: every step (the honest negative for the delta codec).
SCENES: Dict[str, SceneConfig] = {
    "static": SceneConfig(mean_frac=0.02, event_prob=0.002),
    "slow": SceneConfig(mean_frac=0.15, event_prob=0.01),
    "dynamic": SceneConfig(mean_frac=0.9, ar_sigma=0.1, event_prob=0.05),
}


def scene_config(scene: Union[str, SceneConfig]) -> SceneConfig:
    """Resolve a scene given by name or by config.  ``SceneConfig``
    instances pass through; unknown names raise ``KeyError``."""
    if isinstance(scene, SceneConfig):
        return scene
    try:
        return SCENES[scene]
    except KeyError:
        raise KeyError(f"unknown scene {scene!r}; have {sorted(SCENES)}")


def generate_scene_trace(n_steps: int, cfg: Optional[SceneConfig] = None,
                         seed: int = 0) -> np.ndarray:
    """Change fraction at each control-loop tick.  ``cfg`` defaults to a
    fresh ``SceneConfig()`` per call (same no-aliasing rule as
    ``generate_trace``).

    Vectorized: the seeded generator draws the AR(1) normals then the
    event uniforms — two bulk draws in contract order — and the AR(1)
    noise is the same truncated-kernel convolution the bandwidth trace
    uses."""
    cfg = cfg if cfg is not None else SceneConfig()
    rng = np.random.default_rng(seed)
    n = int(n_steps)
    if n <= 0:
        return np.empty(0)
    eps = rng.normal(0.0, cfg.ar_sigma, n)
    u_event = rng.random(n)

    kernel = _ar1_kernel(cfg.ar_rho, n)
    x = eps if kernel is None else np.convolve(eps, kernel)[:n]

    v = cfg.mean_frac * np.exp(x)
    v = np.where(u_event < cfg.event_prob, cfg.event_frac, v)
    return np.clip(v, cfg.floor_frac, cfg.ceil_frac)


def generate_scene_matrix(n_steps: int, cfg: Optional[SceneConfig] = None,
                          seeds: Iterable[int] = ()) -> np.ndarray:
    """Bulk variant of ``generate_scene_trace``: one
    ``(len(seeds), n_steps)`` float64 matrix whose row ``i`` is
    bit-identical to ``generate_scene_trace(n_steps, cfg, seeds[i])``.
    Per-row randomness and convolution stay per-row (reproducibility);
    the elementwise tail runs on row blocks like
    ``network.generate_trace_matrix``."""
    cfg = cfg if cfg is not None else SceneConfig()
    seeds = list(seeds)
    m = len(seeds)
    n = int(n_steps)
    out = np.empty((m, max(n, 0)), dtype=np.float64)
    if n <= 0 or m == 0:
        return out
    kernel = _ar1_kernel(cfg.ar_rho, n)
    for lo in range(0, m, _MATRIX_BLOCK_ROWS):
        hi = min(lo + _MATRIX_BLOCK_ROWS, m)
        rows = hi - lo
        x = np.empty((rows, n), dtype=np.float64)
        u_event = np.empty((rows, n), dtype=np.float64)
        for r in range(rows):
            rng = np.random.default_rng(seeds[lo + r])
            eps = rng.normal(0.0, cfg.ar_sigma, n)
            u_event[r] = rng.random(n)
            x[r] = eps if kernel is None else np.convolve(eps, kernel)[:n]
        v = cfg.mean_frac * np.exp(x)
        v = np.where(u_event < cfg.event_prob, cfg.event_frac, v)
        out[lo:hi] = np.clip(v, cfg.floor_frac, cfg.ceil_frac)
    return out
