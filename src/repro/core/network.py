"""Network bandwidth simulation (paper §III-B setting).

A Markov-modulated bandwidth process with AR(1) noise, diurnal drift and
random congestion spikes — the "internet bandwidth fluctuations" RoboECC
must adapt to.  Traces are seeded + reproducible; units are BYTES/s.

``generate_trace`` is fully vectorized: all randomness is drawn in bulk
up front (three streams, in a fixed documented order), the rare
regime-flip events are walked directly instead of ticking a Python loop,
and the AR(1) noise is a truncated-kernel convolution (``rho**k`` decays
below double precision after a few hundred lags, so the truncation is
invisible).  Reproducibility contract: same ``(n_steps, cfg, seed)`` →
bit-identical trace, pinned by the seed-0 regression test in
``tests/test_pipeline.py``.

``NetworkSim`` answers two kinds of transfer query: ``transfer_s`` prices
a whole payload at the *current tick's* bandwidth (the historical model —
fine for sub-tick transfers, wrong for transfers spanning hundreds of
ticks at ``tick_s=0.05``), and ``transfer_trace_s`` integrates the trace
tick-by-tick (consume bytes at each tick's rate, clamp to the last sample
past the trace end) — the honest price for long/streamed transfers, used
by ``runtime/fleet.py`` for chunked uplinks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    mean_bps: float = 10e6          # 10 MB/s (paper Fig. 3 "good" regime)
    bad_bps: float = 1e6            # 1 MB/s (paper Fig. 3 degraded regime)
    p_degrade: float = 0.02         # per-step regime transitions
    p_recover: float = 0.15
    ar_rho: float = 0.9             # AR(1) smoothness
    ar_sigma: float = 0.08          # relative noise
    spike_prob: float = 0.01        # sudden congestion dips
    spike_depth: float = 0.25
    diurnal_amp: float = 0.15
    diurnal_period: int = 2_000
    floor_bps: float = 0.05e6


def _regime_chain(u: np.ndarray, p_degrade: float, p_recover: float
                  ) -> np.ndarray:
    """2-state Markov regime from one bulk uniform stream, walked by
    *transition events* instead of per-tick: from the good state the next
    flip is the first draw ``< p_degrade``; from the bad state the first
    draw ``< p_recover`` recovers.  Iterations = number of regime
    switches (a few % of the ticks), each a ``searchsorted``."""
    n = len(u)
    bad = np.zeros(n, dtype=bool)
    idx_deg = np.flatnonzero(u < p_degrade)
    idx_rec = np.flatnonzero(u < p_recover)
    t, is_bad = 0, False
    while t < n:
        if not is_bad:
            j = np.searchsorted(idx_deg, t)
            if j == len(idx_deg):
                break                       # good to the end
            tg = int(idx_deg[j])
            bad[tg] = True                  # flip lands on its own tick
            t, is_bad = tg + 1, True
        else:
            j = np.searchsorted(idx_rec, t)
            if j == len(idx_rec):
                bad[t:] = True              # bad to the end
                break
            tr = int(idx_rec[j])
            bad[t:tr] = True                # recovery tick is good again
            t, is_bad = tr + 1, False
    return bad


def generate_trace(n_steps: int, cfg: Optional[TraceConfig] = None,
                   seed: int = 0) -> np.ndarray:
    """Bandwidth (bytes/s) at each control-loop tick.  ``cfg`` defaults to
    a fresh ``TraceConfig()`` per call — a shared default instance would be
    one mutable object across every call site (``TraceConfig`` is frozen
    now, but the default still shouldn't alias).

    Vectorized: the seeded generator draws, in this order, the regime
    uniforms, the AR(1) normals, then the spike uniforms — three bulk
    draws (the draw ORDER is part of the reproducibility contract; the
    historical per-tick loop interleaved them, so traces differ from
    pre-streaming releases at the same seed — summary stats for seed 0
    are pinned in ``tests/test_pipeline.py``)."""
    cfg = cfg if cfg is not None else TraceConfig()
    rng = np.random.default_rng(seed)
    n = int(n_steps)
    if n <= 0:
        return np.empty(0)
    u_reg = rng.random(n)
    eps = rng.normal(0.0, cfg.ar_sigma, n)
    u_spike = rng.random(n)

    bad = _regime_chain(u_reg, cfg.p_degrade, cfg.p_recover)
    # AR(1) x[t] = rho x[t-1] + eps[t] as a convolution with rho**k,
    # truncated where |rho|**k < 1e-18 (below double noise relative to
    # x).  Negative rho (anticorrelated noise) keeps the alternating-sign
    # kernel; |rho| >= 1 falls back to the full-length kernel.
    rho = cfg.ar_rho
    if rho == 0.0:
        x = eps
    else:
        a = abs(rho)
        klen = n if a >= 1.0 else min(
            n, int(np.ceil(np.log(1e-18) / np.log(a))) + 1)
        kernel = rho ** np.arange(klen)
        x = np.convolve(eps, kernel)[:n]

    base = np.where(bad, cfg.bad_bps, cfg.mean_bps)
    diurnal = 1.0 + cfg.diurnal_amp * np.sin(
        2 * np.pi * np.arange(n) / cfg.diurnal_period)
    v = base * np.exp(x) * diurnal
    v = np.where(u_spike < cfg.spike_prob, v * cfg.spike_depth, v)
    return np.maximum(v, cfg.floor_bps)


class NetworkSim:
    """Replays a trace; answers transfer-time queries at the current tick."""

    def __init__(self, trace: np.ndarray, tick_s: float = 0.05,
                 rtt_s: float = 0.005):
        self.trace = np.asarray(trace, dtype=np.float64)
        self.tick_s = tick_s
        self.rtt_s = rtt_s
        self.t = 0

    @property
    def now_bps(self) -> float:
        return float(self.trace[min(self.t, len(self.trace) - 1)])

    def transfer_s(self, n_bytes: float) -> float:
        """Seconds to ship ``n_bytes`` at the current tick.  Zero bytes
        cost zero — no rtt is paid when nothing crosses the link, matching
        ``segmentation.net_time`` (edge-only splits are transfer-free).

        NOTE: prices the ENTIRE transfer at this tick's bandwidth even
        when it spans many ticks — adequate for sub-tick payloads, wrong
        for long transfers on a moving link; those should use
        ``transfer_trace_s``."""
        if n_bytes <= 0:
            return 0.0
        return n_bytes / self.now_bps + self.rtt_s

    def wire_trace_s(self, n_bytes: float, offset_s: float = 0.0) -> float:
        """Pure wire seconds to ship ``n_bytes`` starting ``offset_s``
        seconds after the current tick boundary, consuming the trace
        tick-by-tick (each tick delivers ``trace[t] * tick_s`` bytes).
        Past the trace end the bandwidth clamps to the last sample.  No
        rtt; zero bytes are free.  The building block for chunked
        streamed uplinks (chunks ship back-to-back, each starting at the
        previous chunk's finish offset)."""
        if n_bytes <= 0:
            return 0.0
        tick = self.tick_s
        pos = self.t + offset_s / tick          # fractional tick index
        i = int(np.floor(pos))
        frac = pos - i
        remaining = float(n_bytes)
        elapsed = 0.0
        last = len(self.trace) - 1
        while True:
            bw = float(self.trace[min(max(i, 0), last)])
            if i >= last:                       # clamped constant tail
                return elapsed + remaining / bw
            avail_s = (1.0 - frac) * tick
            cap = bw * avail_s
            if remaining <= cap:
                return elapsed + remaining / bw
            remaining -= cap
            elapsed += avail_s
            i += 1
            frac = 0.0

    def transfer_trace_s(self, n_bytes: float, offset_s: float = 0.0
                         ) -> float:
        """Trace-integrating variant of ``transfer_s``: wire seconds from
        ``wire_trace_s`` plus one rtt.  Zero bytes stay free."""
        if n_bytes <= 0:
            return 0.0
        return self.wire_trace_s(n_bytes, offset_s) + self.rtt_s

    def step(self, n: int = 1) -> None:
        self.t += n

    def seek(self, tick: int) -> None:
        """Jump the trace cursor to an absolute tick index.

        The tick loop advances every robot's link once per tick
        (``step()``), so at tick ``T`` a robot's net always sits at
        ``t == T``.  The event-driven engine skips the per-tick walk and
        positions the cursor absolutely before pricing — ``seek(T)``
        followed by the same ``now_bps`` / ``wire_trace_s`` reads is
        bit-identical to having stepped ``T`` times."""
        self.t = int(tick)

    def window(self, n: int) -> np.ndarray:
        """Last n observed bandwidth samples (for the predictor)."""
        lo = max(0, self.t - n)
        w = self.trace[lo:self.t]
        if len(w) < n:
            w = np.concatenate([np.full(n - len(w), self.trace[0]), w])
        return w
