"""Network bandwidth simulation (paper §III-B setting).

A Markov-modulated bandwidth process with AR(1) noise, diurnal drift and
random congestion spikes — the "internet bandwidth fluctuations" RoboECC
must adapt to.  Traces are seeded + reproducible; units are BYTES/s.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    mean_bps: float = 10e6          # 10 MB/s (paper Fig. 3 "good" regime)
    bad_bps: float = 1e6            # 1 MB/s (paper Fig. 3 degraded regime)
    p_degrade: float = 0.02         # per-step regime transitions
    p_recover: float = 0.15
    ar_rho: float = 0.9             # AR(1) smoothness
    ar_sigma: float = 0.08          # relative noise
    spike_prob: float = 0.01        # sudden congestion dips
    spike_depth: float = 0.25
    diurnal_amp: float = 0.15
    diurnal_period: int = 2_000
    floor_bps: float = 0.05e6


def generate_trace(n_steps: int, cfg: Optional[TraceConfig] = None,
                   seed: int = 0) -> np.ndarray:
    """Bandwidth (bytes/s) at each control-loop tick.  ``cfg`` defaults to
    a fresh ``TraceConfig()`` per call — a shared default instance would be
    one mutable object across every call site (``TraceConfig`` is frozen
    now, but the default still shouldn't alias)."""
    cfg = cfg if cfg is not None else TraceConfig()
    rng = np.random.default_rng(seed)
    bw = np.empty(n_steps)
    regime_bad = False
    x = 0.0                         # AR(1) log-noise
    for t in range(n_steps):
        if regime_bad:
            regime_bad = rng.random() >= cfg.p_recover
        else:
            regime_bad = rng.random() < cfg.p_degrade
        base = cfg.bad_bps if regime_bad else cfg.mean_bps
        x = cfg.ar_rho * x + rng.normal(0.0, cfg.ar_sigma)
        diurnal = 1.0 + cfg.diurnal_amp * np.sin(
            2 * np.pi * t / cfg.diurnal_period)
        v = base * np.exp(x) * diurnal
        if rng.random() < cfg.spike_prob:
            v *= cfg.spike_depth
        bw[t] = max(v, cfg.floor_bps)
    return bw


class NetworkSim:
    """Replays a trace; answers transfer-time queries at the current tick."""

    def __init__(self, trace: np.ndarray, tick_s: float = 0.05,
                 rtt_s: float = 0.005):
        self.trace = np.asarray(trace, dtype=np.float64)
        self.tick_s = tick_s
        self.rtt_s = rtt_s
        self.t = 0

    @property
    def now_bps(self) -> float:
        return float(self.trace[min(self.t, len(self.trace) - 1)])

    def transfer_s(self, n_bytes: float) -> float:
        """Seconds to ship ``n_bytes`` at the current tick.  Zero bytes
        cost zero — no rtt is paid when nothing crosses the link, matching
        ``segmentation.net_time`` (edge-only splits are transfer-free)."""
        if n_bytes <= 0:
            return 0.0
        return n_bytes / self.now_bps + self.rtt_s

    def step(self, n: int = 1) -> None:
        self.t += n

    def window(self, n: int) -> np.ndarray:
        """Last n observed bandwidth samples (for the predictor)."""
        lo = max(0, self.t - n)
        w = self.trace[lo:self.t]
        if len(w) < n:
            w = np.concatenate([np.full(n - len(w), self.trace[0]), w])
        return w
