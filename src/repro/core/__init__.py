"""RoboECC core — the paper's contribution.

* structure.py    — Eq. 1 structure model (flattened layer graphs)
* hardware.py     — Eq. 2 hardware roofline model (Table I + TPU v5e)
* segmentation.py — Alg. 1 optimal split search (scalar + vectorized,
                    with an optional codec axis) + the multi-cut
                    (S1, S2) placement search
* placement.py    — K-segment ``PlacementPlan`` (ordered cuts, per-segment
                    tier, per-cut codec); single-split is the K=1 case
* codec.py        — split-boundary transport codecs (wire bytes, priced
                    encode/decode compute, accuracy-proxy error bounds)
* predictor.py    — LSTM bandwidth predictor (Eq. 3 granularity check)
* pool.py         — parameter-sharing pool
* adjustment.py   — ΔNB / T_high / T_low fine-grained adjustment
                    (joint split × codec when given a codec axis;
                    ``adjust_placement`` moves either cut of a multi-cut
                    placement)
* network.py      — bandwidth trace simulator
* scene.py        — scene-dynamics trace simulator (per-step token
                    change fractions for the temporal-delta codec)
* pipeline.py     — streamed chunk-transport makespan model (3-stage
                    encode → uplink → decode+prefill pipeline; the
                    chunk-count axis of the streamed planner)
* controller.py   — end-to-end RoboECC controller
"""
from .adjustment import (AdjustmentDecision, PlacementDecision, Thresholds,
                         adjust, adjust_placement, calibrate_thresholds)
from .codec import (CODECS, Codec, DeltaCodec, get_codec, make_codecs,
                    make_delta_codec, resolve_codecs, transport_s)
from .controller import RoboECC, TickResult
from .hardware import (A100, DEVICES, ORIN, THOR, TPU_V5E, DeviceSpec,
                       RooflineTerms, fit_eta, layer_latency, roofline,
                       stack_latency)
from .network import NetworkSim, TraceConfig, generate_trace
from .scene import (SCENES, SceneConfig, generate_scene_matrix,
                    generate_scene_trace, scene_config)
from .pipeline import (DEFAULT_CHUNK_GRID, chunk_sizes, stream_applies,
                       stream_bubble_fraction, stream_makespan,
                       stream_makespan_scalar)
from .placement import PlacementPlan
from .pool import Pool, build_pool, pool_transfer_profile
from .predictor import (Predictor, PredictorConfig, check_granularity,
                        lstm_forward, train_predictor)
from .segmentation import (GraphArrays, MulticutResult, PlacementEval,
                           SegmentationResult, VecSearchResult,
                           codec_applies, cut_bytes, downlink_bytes,
                           evaluate_placement, evaluate_split,
                           exhaustive_best, fixed_split, graph_arrays,
                           net_time, queue_delay_s, search, search_joint,
                           search_multicut,
                           search_multicut_scalar, search_streamed,
                           search_streamed_scalar, search_vec,
                           sweep_multicut, sweep_search)
from .structure import LayerCost, Workload, build_graph, total_flops, \
    total_weight_bytes

__all__ = [
    "AdjustmentDecision", "PlacementDecision", "Thresholds", "adjust",
    "adjust_placement", "calibrate_thresholds",
    "CODECS", "Codec", "DeltaCodec", "get_codec", "make_codecs",
    "make_delta_codec", "resolve_codecs", "transport_s",
    "RoboECC", "TickResult",
    "A100", "DEVICES", "ORIN", "THOR", "TPU_V5E", "DeviceSpec",
    "RooflineTerms", "fit_eta", "layer_latency", "roofline", "stack_latency",
    "NetworkSim", "TraceConfig", "generate_trace",
    "SCENES", "SceneConfig", "generate_scene_matrix", "generate_scene_trace",
    "scene_config",
    "DEFAULT_CHUNK_GRID", "chunk_sizes", "stream_applies",
    "stream_bubble_fraction", "stream_makespan", "stream_makespan_scalar",
    "PlacementPlan",
    "Pool", "build_pool", "pool_transfer_profile",
    "Predictor", "PredictorConfig", "check_granularity", "lstm_forward",
    "train_predictor",
    "GraphArrays", "MulticutResult", "PlacementEval", "SegmentationResult",
    "VecSearchResult", "codec_applies", "cut_bytes", "downlink_bytes",
    "evaluate_placement", "evaluate_split", "exhaustive_best", "fixed_split",
    "graph_arrays", "net_time", "queue_delay_s", "search", "search_joint",
    "search_multicut",
    "search_multicut_scalar", "search_streamed", "search_streamed_scalar",
    "search_vec", "sweep_multicut", "sweep_search",
    "LayerCost", "Workload", "build_graph", "total_flops",
    "total_weight_bytes",
]
