"""Network-aware fine-grained segmentation adjustment — paper §IV-B-3.

``ΔNB = NB_pred(t+1) − NB_real(t)``.  If ``ΔNB > T_high`` (bandwidth will
rise) move the split to the pool layer with the **maximum** transfer volume
(exploit the link); if ``ΔNB < T_low`` (bandwidth will drop) move to the
**minimum**-transfer layer (hide the bad link); otherwise keep the current
split.  Compute-load deltas inside the pool are ignored (paper: "impacts on
both sides are negligible").

Codec-aware extension (``core/codec.py``): given a ``codecs`` axis the move
is **joint over (split × codec)**.  On "down" the pair minimising predicted
transport seconds at ``NB_pred`` wins — compressing harder is an
alternative (or complement) to retreating to the minimum-volume layer.  On
"up" the split goes to the maximum-volume layer and the codec snaps to the
lowest-error one — both are the same *greedy exploit* as the paper's up
move, which jumps to the transfer-heaviest cut on a predicted rise without
checking absolute transport cost.  The guard against flip-flapping under
an oscillating link is the hold band ``[T_low, T_high]`` (sized by
``calibrate_thresholds``), not the move itself.  Pass ``edge``/``cloud``
DeviceSpecs to include encode/decode compute in the transport price
(without them the move is wire-only).

Streamed extension (``core/pipeline.py``): ``adjust_placement`` with a
``chunk_grid`` adds streaming chunk-count moves to the same ΔNB policy —
the uplink leg of every candidate is priced as the chunk-pipeline
makespan minus the overlapped cloud-window compute, so a predicted
bandwidth drop can answer with *more chunks* (hide the slow link behind
prefill) as an alternative to retreating the cut or compressing harder,
and a predicted rise can shed per-chunk rtt overhead.  Like a codec
switch, a chunk-count change ships no weights.

Threshold calibration follows the paper §V-C-2: ``T_high`` starts at the
maximum historical ``ΔNB``; ``T_low`` is then grid-searched on a validation
trace; ``T_high`` is re-searched afterwards (Fig. 7).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from .codec import get_codec, resolve_codecs
from .hardware import DeviceSpec, layer_latency
from .pipeline import stream_applies, stream_makespan_scalar
from .placement import PlacementPlan
from .pool import Pool
from .segmentation import (codec_applies, cut_bytes, downlink_bytes,
                           net_time, queue_delay_s)
from .structure import LayerCost


@dataclasses.dataclass
class Thresholds:
    high: float                  # bytes/s
    low: float


@dataclasses.dataclass
class AdjustmentDecision:
    split: int
    moved: bool
    reason: str                  # "up" | "down" | "hold"
    delta_nb: float
    codec: Optional[str] = None  # set when the move was joint (codecs given)


def adjust(graph: Sequence[LayerCost], pool: Pool, current_split: int,
           nb_pred_bps: float, nb_real_bps: float, thr: Thresholds,
           *, codecs: Optional[Sequence] = None,
           current_codec: Optional[str] = None,
           edge: Optional[DeviceSpec] = None,
           cloud: Optional[DeviceSpec] = None,
           max_err: Optional[float] = None) -> AdjustmentDecision:
    delta = nb_pred_bps - nb_real_bps
    splits = list(pool.splits())
    volumes = [cut_bytes(graph, s) for s in splits]
    cs = resolve_codecs(codecs, max_err)
    if delta > thr.high:
        s = splits[int(np.argmax(volumes))]
        codec = None
        if cs is not None:
            # greedy exploit, mirroring the paper's max-volume jump: the
            # improving link ships the most faithful codec (anti-flap is
            # the [T_low, T_high] hold band, see module docstring)
            codec = min(cs, key=lambda c: c.err_bound).name
        moved = s != current_split or (codec is not None
                                       and codec != current_codec)
        return AdjustmentDecision(s, moved, "up", delta, codec=codec)
    if delta < thr.low:
        if cs is None:
            s = splits[int(np.argmin(volumes))]
            return AdjustmentDecision(s, s != current_split, "down", delta)
        # joint move: minimise predicted transport seconds at NB_pred;
        # ties break toward the earliest codec in the list, then the
        # largest split — the planner's tie-break direction.  net_time
        # applies the shared codec_applies gate, so the S=0 / S=n pool
        # extremes are priced raw exactly as evaluate_split prices them
        best = None
        n = len(graph)
        for ci, c in enumerate(cs):
            for s, vol in sorted(zip(splits, volumes), reverse=True):
                t = net_time(vol, nb_pred_bps, codec=c,
                             applicable=codec_applies(s, n),
                             edge=edge, cloud=cloud)
                if best is None or t < best[0]:
                    best = (t, ci, s)
        _, ci, s = best
        codec = cs[ci].name
        moved = s != current_split or codec != current_codec
        return AdjustmentDecision(s, moved, "down", delta, codec=codec)
    return AdjustmentDecision(current_split, False, "hold", delta,
                              codec=current_codec if cs is not None else None)


@dataclasses.dataclass
class PlacementDecision:
    """``adjust_placement`` outcome: the (possibly multi-cut) placement to
    run the next inference with."""
    placement: PlacementPlan
    moved: bool
    reason: str                  # "up" | "down" | "hold"
    delta_nb: float
    codec: Optional[str] = None


def adjust_placement(graph: Sequence[LayerCost], pool: Pool,
                     current: PlacementPlan, nb_pred_bps: float,
                     nb_real_bps: float, thr: Thresholds, *,
                     pool2: Optional[Pool] = None,
                     codecs: Optional[Sequence] = None,
                     edge: Optional[DeviceSpec] = None,
                     cloud: Optional[DeviceSpec] = None,
                     down_bw_factor: float = 1.0,
                     max_err: Optional[float] = None,
                     chunk_grid: Optional[Sequence[int]] = None,
                     rtt_s: float = 0.0, queue_hz: float = 0.0,
                     queue_cv2: float = 1.0,
                     queue_service_scale: float = 1.0) -> PlacementDecision:
    """Multi-cut ΔNB adjustment: the same up/down/hold policy as
    ``adjust``, generalized to move **either cut** of an edge→cloud→edge
    placement (uplink cut inside ``pool``, downlink cut inside ``pool2``).

    * ``up`` (link will rise): greedy exploit — both cuts jump to their
      maximum-transfer pool layer and the codec snaps to the lowest-error
      one, mirroring the paper's max-volume move.
    * ``down`` (link will drop): joint argmin of predicted transport
      seconds (uplink at ``NB_pred`` + downlink at
      ``down_bw_factor × NB_pred``) over (S1 × S2 × codec).  Ties break
      toward the earliest codec, then the largest S1, then the largest S2
      — so when ``pool2`` reaches the graph end, choosing ``S2 = n`` (no
      downlink leg at all) **collapses the plan back to K=1** for free.
    * otherwise hold.

    ``chunk_grid`` adds streaming chunk-count moves (``core/pipeline.py``)
    to the move set: every candidate's uplink leg is priced as the
    3-stage chunk-pipeline makespan at ``NB_pred`` *minus the overlapped
    cloud-window compute* (the transport-exposed seconds — for
    ``n_chunks = 1`` exactly the sequential transport the codec-free move
    prices), and both the "up" and "down" moves pick the chunk count
    jointly.  A chunk count is a pure software reconfiguration — like a
    codec switch it ships no weights — so it rides the same hold band.
    Chunk pricing needs ``cloud`` (the window compute that overlaps);
    without a device the chunk axis degenerates to wire-only pipelines
    where ``n_chunks = 1`` always wins (per-chunk rtt with nothing to
    overlap).

    ``queue_hz > 0`` makes the "down" move queue-aware: every candidate
    pays the M/G/1 expected wait of its cloud window
    (``segmentation.queue_delay_s`` — same parameters the planner uses),
    so a congested cloud biases the retreat toward shallower windows.
    The "up" move stays the paper's greedy max-volume exploit (it never
    priced absolute cost, so it gains no queue term).  ``queue_hz = 0``
    (default) reproduces the queue-blind move set bit-for-bit.

    With ``pool2=None``, ``chunk_grid=None`` and a single-cut ``current``
    this reduces exactly to ``adjust`` (the K=1 special case); the
    ``AdjustmentDecision`` split is ``placement.primary_cut(n)``."""
    n = len(graph)
    cur = current.normalize(n)
    cur_s1 = cur.primary_cut(n)
    cur_s2 = cur.tail_cut(n)
    cs = resolve_codecs(codecs, max_err)
    cur_codec = next((c for c in cur.cut_codecs if c is not None), None)
    delta = nb_pred_bps - nb_real_bps
    s2_opts = list(pool2.splits()) if pool2 is not None else [cur_s2]
    ks = sorted({int(k) for k in chunk_grid} | {1}) \
        if chunk_grid is not None else [1]
    # suffix cloud-latency cumsum: O(1) window compute for chunk pricing
    # and for the queue-aware down move's M/G/1 wait
    csum = None
    if cloud is not None and (len(ks) > 1 or queue_hz > 0):
        lat = np.array([layer_latency(c, cloud) for c in graph])
        csum = np.concatenate([np.cumsum(lat[::-1])[::-1], [0.0]])

    def window_cloud_s(s1: int, s2: int) -> float:
        if csum is None or s1 >= s2:
            return 0.0
        return float(csum[s1] - csum[s2])

    def up_leg(s1: int, s2: int, c, k: int, bw: float) -> Optional[float]:
        """Transport-exposed uplink seconds at bandwidth ``bw`` for chunk
        count ``k`` (None = streaming not applicable at this cut)."""
        vol = cut_bytes(graph, s1)
        seq = net_time(vol, bw, rtt_s=rtt_s, codec=c,
                       applicable=codec_applies(s1, n),
                       edge=edge, cloud=cloud) if s1 < s2 else 0.0
        if k == 1:
            return seq
        if not (s1 < s2 and stream_applies(s1, n, vol)):
            return None
        app = codec_applies(s1, n)
        enc = c.encode_s(vol, edge) if c is not None and app \
            and edge is not None else 0.0
        dec = c.decode_s(vol, cloud) if c is not None and app \
            and cloud is not None else 0.0
        wire_c = c.wire_bytes(vol) if c is not None and app else vol
        g = window_cloud_s(s1, s2)
        m = stream_makespan_scalar(enc, wire_c / bw, dec + g, k, rtt_s)
        return m - g

    def mk(s1: int, s2: int, codec: Optional[str],
           k: int = 1) -> PlacementPlan:
        return PlacementPlan.from_window(s1, s2, n, codec, k)

    def window_ok(s1: int, s2: int) -> bool:
        # an adjuster move must keep a REAL cloud window (or be the
        # explicit edge-only retreat s1 == s2 == n, reachable only when
        # both pools extend to the graph end — mirroring single-cut
        # ``adjust``, whose edge-only retreat needs n inside the pool).
        # Without this, overlapping pools would let the zero-transport
        # empty mid-graph window (s1 == s2 < n) win every "down" move and
        # silently collapse the whole model onto the edge.
        return s1 < s2 or s1 == s2 == n

    if delta > thr.high:
        s1 = max(pool.splits(), key=lambda s: cut_bytes(graph, s))
        wide = [s for s in s2_opts if s > s1] or [n]
        s2 = max(wide, key=lambda s: downlink_bytes(graph, s))
        cbest = min(cs, key=lambda c: c.err_bound) if cs is not None \
            else None
        codec = cbest.name if cbest is not None else cur_codec
        k = 1
        if len(ks) > 1:
            # chunking is not part of the paper's greedy max-volume jump;
            # re-pick it for the exploited cuts at NB_pred (smallest
            # count on ties — less machinery when the link is good).
            # Resolve through the adjuster's own axis first: it may hold
            # custom Codec instances a registry lookup would miss.
            try:
                cobj = cbest if cbest is not None else get_codec(codec)
            except KeyError:
                cobj = None
            legs = [(up_leg(s1, s2, cobj, kk, nb_pred_bps), kk)
                    for kk in ks]
            k = min((t, kk) for t, kk in legs if t is not None)[1]
        plan = mk(s1, s2, codec, k)
        moved = plan != cur
        return PlacementDecision(plan, moved, "up", delta, codec=codec)
    if delta < thr.low:
        axis = cs if cs is not None else (None,)
        best = None
        # tie-break order mirrors ``adjust`` exactly: its codec-free down
        # move is argmin over volumes (FIRST minimum -> smallest split),
        # its joint move scans splits descending (largest tied split) —
        # uniform trunks tie constantly, so the order is observable.  The
        # chunk loop is innermost-ascending: sequential wins ties.
        for ci, c in enumerate(axis):
            for s1 in sorted(pool.splits(), reverse=cs is not None):
                for s2 in sorted(s2_opts, reverse=True):
                    if not window_ok(s1, s2):
                        continue
                    # the downlink pays the same per-message rtt the
                    # uplink candidates price (rtt_s = 0 keeps the
                    # historical rtt-free objective exactly)
                    dn = net_time(downlink_bytes(graph, s2),
                                  nb_pred_bps * down_bw_factor, codec=c,
                                  rtt_s=rtt_s,
                                  applicable=codec_applies(s2, n),
                                  edge=cloud, cloud=edge) \
                        if s1 < s2 < n else 0.0
                    # queue-aware retreat: transport-equivalent seconds
                    # also pay the window's expected M/G/1 wait (0 when
                    # queue_hz == 0 — the historical objective exactly)
                    wq = queue_delay_s(window_cloud_s(s1, s2), queue_hz,
                                       cv2=queue_cv2,
                                       service_scale=queue_service_scale) \
                        if queue_hz > 0 else 0.0
                    for k in ks:
                        up = up_leg(s1, s2, c, k, nb_pred_bps)
                        if up is None:
                            continue
                        t = up + dn + wq
                        if best is None or t < best[0]:
                            best = (t, ci, s1, s2, k)
        if best is None:
            return PlacementDecision(cur, False, "down", delta,
                                     codec=cur_codec)
        _, ci, s1, s2, k = best
        codec = axis[ci].name if axis[ci] is not None else cur_codec
        plan = mk(s1, s2, codec, k)
        moved = plan != cur
        return PlacementDecision(plan, moved, "down", delta, codec=codec)
    return PlacementDecision(cur, False, "hold", delta,
                             codec=cur_codec if cs is not None else None)


def calibrate_thresholds(
        deltas: np.ndarray,
        eval_fn: Callable[[Thresholds], float],
        n_grid: int = 9) -> Thresholds:
    """Paper §V-C-2 procedure. ``eval_fn`` returns avg latency for a
    candidate threshold pair on a validation trace (lower is better)."""
    t_high = float(np.max(deltas))
    lows = np.quantile(deltas[deltas < 0], np.linspace(0.05, 0.95, n_grid)) \
        if np.any(deltas < 0) else np.array([-1.0])
    best_low, best = None, None
    for tl in lows:
        lat = eval_fn(Thresholds(t_high, float(tl)))
        if best is None or lat < best:
            best, best_low = lat, float(tl)
    highs = np.quantile(deltas[deltas > 0], np.linspace(0.05, 0.95, n_grid)) \
        if np.any(deltas > 0) else np.array([t_high])
    best_high = t_high
    for th in highs:
        lat = eval_fn(Thresholds(float(th), best_low))
        if lat < best:
            best, best_high = lat, float(th)
    return Thresholds(best_high, best_low)
