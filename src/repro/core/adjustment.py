"""Network-aware fine-grained segmentation adjustment — paper §IV-B-3.

``ΔNB = NB_pred(t+1) − NB_real(t)``.  If ``ΔNB > T_high`` (bandwidth will
rise) move the split to the pool layer with the **maximum** transfer volume
(exploit the link); if ``ΔNB < T_low`` (bandwidth will drop) move to the
**minimum**-transfer layer (hide the bad link); otherwise keep the current
split.  Compute-load deltas inside the pool are ignored (paper: "impacts on
both sides are negligible").

Threshold calibration follows the paper §V-C-2: ``T_high`` starts at the
maximum historical ``ΔNB``; ``T_low`` is then grid-searched on a validation
trace; ``T_high`` is re-searched afterwards (Fig. 7).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from .pool import Pool
from .segmentation import cut_bytes
from .structure import LayerCost


@dataclasses.dataclass
class Thresholds:
    high: float                  # bytes/s
    low: float


@dataclasses.dataclass
class AdjustmentDecision:
    split: int
    moved: bool
    reason: str                  # "up" | "down" | "hold"
    delta_nb: float


def adjust(graph: Sequence[LayerCost], pool: Pool, current_split: int,
           nb_pred_bps: float, nb_real_bps: float, thr: Thresholds
           ) -> AdjustmentDecision:
    delta = nb_pred_bps - nb_real_bps
    splits = list(pool.splits())
    volumes = [cut_bytes(graph, s) for s in splits]
    if delta > thr.high:
        s = splits[int(np.argmax(volumes))]
        return AdjustmentDecision(s, s != current_split, "up", delta)
    if delta < thr.low:
        s = splits[int(np.argmin(volumes))]
        return AdjustmentDecision(s, s != current_split, "down", delta)
    return AdjustmentDecision(current_split, False, "hold", delta)


def calibrate_thresholds(
        deltas: np.ndarray,
        eval_fn: Callable[[Thresholds], float],
        n_grid: int = 9) -> Thresholds:
    """Paper §V-C-2 procedure. ``eval_fn`` returns avg latency for a
    candidate threshold pair on a validation trace (lower is better)."""
    t_high = float(np.max(deltas))
    lows = np.quantile(deltas[deltas < 0], np.linspace(0.05, 0.95, n_grid)) \
        if np.any(deltas < 0) else np.array([-1.0])
    best_low, best = None, None
    for tl in lows:
        lat = eval_fn(Thresholds(t_high, float(tl)))
        if best is None or lat < best:
            best, best_low = lat, float(tl)
    highs = np.quantile(deltas[deltas > 0], np.linspace(0.05, 0.95, n_grid)) \
        if np.any(deltas > 0) else np.array([t_high])
    best_high = t_high
    for th in highs:
        lat = eval_fn(Thresholds(float(th), best_low))
        if lat < best:
            best, best_high = lat, float(th)
    return Thresholds(best_high, best_low)
