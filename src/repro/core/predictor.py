"""Network fluctuation predictor — lightweight LSTM (paper §IV-B-1).

Pure-JAX LSTM trained on historical bandwidth traces to predict the
next-tick bandwidth.  Constraint Eq. 3: the input granularity ``t_input``
must be finer than ``min(t_cloud, t_edge)`` — enforced by
:func:`check_granularity`, which the controller calls with the modeled
per-tier latencies.

Inputs are log-normalised bandwidth windows; the model is deliberately tiny
(default hidden=64 → ~70 KB, vs the paper's 20.1 MB LSTM; Fig. 6 reports it
as negligible either way).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PredictorConfig:
    window: int = 32
    hidden: int = 64
    lr: float = 1e-2
    epochs: int = 200
    batch: int = 64


def init_lstm(key: jax.Array, hidden: int) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s = hidden ** -0.5
    return {
        "wx": jax.random.normal(k1, (1, 4 * hidden), jnp.float32) * s,
        "wh": jax.random.normal(k2, (hidden, 4 * hidden), jnp.float32) * s,
        "b": jnp.zeros((4 * hidden,), jnp.float32),
        "head": jax.random.normal(k3, (hidden, 1), jnp.float32) * s,
    }


def lstm_forward(params: Dict, window: jax.Array) -> jax.Array:
    """window: (B, T) log-normalised -> (B,) next-value prediction."""
    B, T = window.shape
    H = params["wh"].shape[0]

    def cell(carry, x_t):
        h, c = carry
        g = x_t[:, None] @ params["wx"] + h @ params["wh"] + params["b"]
        i, f, o, u = jnp.split(g, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(u)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    h0 = jnp.zeros((B, H)), jnp.zeros((B, H))
    (h, _), _ = jax.lax.scan(cell, h0, window.T)
    return (h @ params["head"])[:, 0]


def _normalise(bw: np.ndarray, ref: float) -> np.ndarray:
    return np.log(np.maximum(bw, 1.0) / ref)


def _denormalise(x: jax.Array, ref: float) -> jax.Array:
    return jnp.exp(x) * ref


_lstm_jit = jax.jit(lstm_forward)


@dataclasses.dataclass
class Predictor:
    params: Dict
    cfg: PredictorConfig
    ref_bps: float

    def predict(self, window_bps: np.ndarray) -> float:
        x = jnp.asarray(_normalise(window_bps, self.ref_bps),
                        jnp.float32)[None, :]
        y = _lstm_jit(self.params, x)[0]
        return float(_denormalise(y, self.ref_bps))

    def n_bytes(self) -> int:
        return sum(v.size * v.dtype.itemsize
                   for v in jax.tree_util.tree_leaves(self.params))


def train_predictor(trace_bps: np.ndarray, cfg: PredictorConfig = PredictorConfig(),
                    seed: int = 0) -> Tuple[Predictor, list]:
    """Train on (window -> next tick) pairs from a historical trace."""
    ref = float(np.mean(trace_bps))
    x = _normalise(trace_bps, ref)
    W = cfg.window
    wins = np.stack([x[i:i + W] for i in range(len(x) - W)])
    tgts = x[W:]
    key = jax.random.PRNGKey(seed)
    params = init_lstm(key, cfg.hidden)
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)

    @jax.jit
    def step(params, m, v, t, xb, yb):
        def loss_fn(p):
            pred = lstm_forward(p, xb)
            return jnp.mean((pred - yb) ** 2)

        loss, g = jax.value_and_grad(loss_fn)(params)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree_util.tree_map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree_util.tree_map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        mh = jax.tree_util.tree_map(lambda a: a / (1 - b1 ** t), m)
        vh = jax.tree_util.tree_map(lambda a: a / (1 - b2 ** t), v)
        params = jax.tree_util.tree_map(
            lambda p, a, b: p - cfg.lr * a / (jnp.sqrt(b) + eps),
            params, mh, vh)
        return params, m, v, loss

    rng = np.random.default_rng(seed)
    losses = []
    for e in range(1, cfg.epochs + 1):
        idx = rng.integers(0, len(wins), cfg.batch)
        params, m, v, loss = step(params, m, v, jnp.float32(e),
                                  jnp.asarray(wins[idx]), jnp.asarray(tgts[idx]))
        losses.append(float(loss))
    return Predictor(params, cfg, ref), losses


def check_granularity(t_input_s: float, t_cloud_s: float, t_edge_s: float
                      ) -> bool:
    """Paper Eq. 3: t_input < min(t_cloud, t_edge)."""
    return t_input_s < min(t_cloud_s, t_edge_s)
