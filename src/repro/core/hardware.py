"""Hardware modeling — paper Eq. 2 (per-layer roofline latency) + Table I.

``T_GPU = Σ_i max(C_compute_i / (P · parallel), C_datamove_i / BW)``

The same functional form serves three roles:
  1. the paper's edge/cloud latency model (Table I devices, calibrated);
  2. the TPU v5e roofline constants for §Roofline of EXPERIMENTS.md;
  3. napkin-math estimates in the §Perf hillclimbing loop.

Calibration: the paper uses measured GPU latencies ("hardware performance
data", Insight ①); lacking the physical devices, we keep Table I peak
numbers and fit a single efficiency factor per device (``eta``) to the
paper's own *-only deployments (Tab. II edge-only / cloud-only rows), then
validate that RoboECC's relative speedups emerge (EXPERIMENTS.md
§Paper-validation).  All absolute milliseconds are model outputs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional

from .structure import LayerCost


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    name: str
    peak_flops: float          # FLOP/s at the deployment compute dtype
    hbm_bw: float              # bytes/s
    mem_bytes: float
    eta_compute: float = 1.0   # achieved fraction of peak (calibrated)
    eta_mem: float = 1.0
    # TPU-only: inter-chip interconnect
    ici_bw: float = 0.0        # bytes/s per link
    ici_links: int = 0

    def with_eta(self, eta_compute: float, eta_mem: float) -> "DeviceSpec":
        return dataclasses.replace(self, eta_compute=eta_compute,
                                   eta_mem=eta_mem)


# --------------------------------------------------------- paper Table I
# "Computing Power (4-bit)" entries; memory bandwidth in GB/s.
A100 = DeviceSpec("A100", peak_flops=2496e12, hbm_bw=2039e9,
                  mem_bytes=80e9, eta_compute=0.30, eta_mem=0.75)
ORIN = DeviceSpec("Jetson-Orin", peak_flops=275e12, hbm_bw=204.8e9,
                  mem_bytes=64e9, eta_compute=0.30, eta_mem=0.60)
THOR = DeviceSpec("Jetson-Thor", peak_flops=517.5e12, hbm_bw=273e9,
                  mem_bytes=128e9, eta_compute=0.30, eta_mem=0.60)

# --------------------------------------------------------- TPU target (ours)
TPU_V5E = DeviceSpec("TPU-v5e", peak_flops=197e12, hbm_bw=819e9,
                     mem_bytes=16e9, ici_bw=50e9, ici_links=4)

DEVICES: Dict[str, DeviceSpec] = {
    "a100": A100, "orin": ORIN, "thor": THOR, "tpu-v5e": TPU_V5E,
}


# ------------------------------------------------------------------ Eq. 2
def layer_latency(c: LayerCost, dev: DeviceSpec, *, parallel: float = 1.0
                  ) -> float:
    """max(compute, memory) seconds for one layer on one device (Eq. 2)."""
    t_comp = c.flops / (dev.peak_flops * dev.eta_compute * parallel)
    t_mem = c.datamove_bytes / (dev.hbm_bw * dev.eta_mem)
    return max(t_comp, t_mem)


def stack_latency(costs: Iterable[LayerCost], dev: DeviceSpec) -> float:
    return sum(layer_latency(c, dev) for c in costs)


def fit_eta(costs: Iterable[LayerCost], dev: DeviceSpec, target_s: float,
            ) -> DeviceSpec:
    """One-parameter calibration: scale (eta_compute, eta_mem) jointly so
    the modeled stack latency matches a measured/published number."""
    base = stack_latency(costs, dev)
    scale = base / target_s  # <1 -> device slower than modeled
    return dev.with_eta(dev.eta_compute * scale, dev.eta_mem * scale)


# ------------------------------------------------------------------ roofline
@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             n_chips: int, dev: DeviceSpec = TPU_V5E,
             links_used: Optional[int] = None) -> RooflineTerms:
    """Assignment formulas (global quantities over the whole step):

      compute    = HLO_FLOPs / (chips * peak)
      memory     = HLO_bytes / (chips * HBM_bw)
      collective = collective_bytes / (chips * link_bw)
    """
    links = dev.ici_bw * (links_used if links_used else 1)
    return RooflineTerms(
        compute_s=hlo_flops / (n_chips * dev.peak_flops),
        memory_s=hlo_bytes / (n_chips * dev.hbm_bw),
        collective_s=collective_bytes / (n_chips * links) if collective_bytes
        else 0.0,
    )
