"""Sharded checkpointing: atomic, retention-managed, async, restartable.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``meta.json``.  Leaves are saved
host-gathered (this container is single-host; the per-leaf key scheme
``a/b/c`` maps 1:1 onto a tensorstore/GCS layout for the multi-host case —
swap ``_write_arrays`` to write one file per shard).  Writes go to a temp
dir + atomic rename, so a crash mid-save never corrupts the latest
checkpoint; ``AsyncCheckpointer`` overlaps serialisation with training.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Tree = Any


def _flatten(tree: Tree, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
        return out
    out[prefix[:-1] if prefix.endswith("/") else prefix] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Tree:
    root: Dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


def save_checkpoint(ckpt_dir: str, step: int, tree: Tree,
                    extra: Optional[Dict] = None, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(jax.tree_util.tree_map(np.asarray, tree))
    # npz can't round-trip ml_dtypes (bf16 etc.) — store raw bytes + dtype
    enc, dtypes = {}, {}
    for k, v in flat.items():
        if v.dtype.str not in _NATIVE:
            dtypes[k] = str(v.dtype)
            v = v.view(np.uint8)
        enc[k] = v
    np.savez(os.path.join(tmp, "arrays.npz"), **enc)
    meta = {"step": step, "time": time.time(), "extra": extra or {},
            "n_arrays": len(flat), "dtypes": dtypes}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _apply_retention(ckpt_dir, keep)
    return final


def _apply_retention(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            try:
                out.append(int(name.split("_", 1)[1]))
            except ValueError:
                pass
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


_NATIVE = {np.dtype(t).str for t in
           ("float64", "float32", "float16", "int64", "int32", "int16",
            "int8", "uint8", "uint16", "uint32", "uint64", "bool")}


def load_checkpoint(ckpt_dir: str, step: Optional[int] = None
                    ) -> Tuple[int, Tree, Dict]:
    import ml_dtypes
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    dtypes = meta.get("dtypes", {})
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {}
        for k in z.files:
            v = z[k]
            if k in dtypes:
                v = v.view(np.dtype(getattr(ml_dtypes, dtypes[k])))
            flat[k] = v
    return step, _unflatten(flat), meta.get("extra", {})


def restore_into(tree_like: Tree, loaded: Tree) -> Tree:
    """Cast/shape-check loaded numpy arrays onto an existing tree structure
    (e.g. re-device_put with the right shardings)."""
    import jax.numpy as jnp

    def one(ref, val):
        assert ref.shape == val.shape, (ref.shape, val.shape)
        return jnp.asarray(val, dtype=ref.dtype)

    return jax.tree_util.tree_map(one, tree_like, loaded)


class AsyncCheckpointer:
    """Background-thread writer; at most one save in flight."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, tree: Tree, extra: Optional[Dict] = None):
        self.wait()
        # materialise on host *before* handing to the thread so the trainer
        # can donate/overwrite device buffers immediately
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def _run():
            self.last_path = save_checkpoint(self.ckpt_dir, step, host_tree,
                                             extra, self.keep)

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
