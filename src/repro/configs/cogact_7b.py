"""CogACT — the paper's second evaluation model (§V, Table III).

ViT encoder + Llama-2-7B backbone + DiT action module (DiT-Base: 12L, 768d)
run for `diffusion_steps` denoising iterations.  This is the heterogeneous
S_dec structure that breaks load-budget-only segmentation (paper Fig. 2).
[arXiv:2411.19650]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="cogact-7b",
    family="vla",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32064,
    rope_theta=10_000.0,
    vla_action_head="dit",
    vit_layers=24,
    vit_dim=1024,
    n_patches=256,
    action_dim=7,
    action_horizon=16,
    diffusion_steps=10,
    dit_layers=12,
    dit_dim=768,
    dit_heads=12,
)
