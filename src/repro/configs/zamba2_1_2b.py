"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=32000.

Mamba2 backbone (ssm_state=64) + ONE shared attention+MLP transformer block
invoked every 6 SSM blocks (weights shared across invocations).
Simplification vs HF checkpoint noted in DESIGN.md §4 (no [h, embed] concat /
per-invocation LoRA).
[arXiv:2411.15242; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=256,
    shared_attn_every=6,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
