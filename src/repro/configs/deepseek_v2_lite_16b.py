"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff(expert)=1408 vocab=102400.

MLA kv_lora=512, 64 routed experts top-6 + 2 shared, first layer dense.
The assignment line lists both "64e top-6" and "160 routed"; we follow the
primary spec (64 routed, top-6) — see DESIGN.md §4.
[arXiv:2405.04434; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,            # dense FFN width (first layer)
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=10_000.0,
)
