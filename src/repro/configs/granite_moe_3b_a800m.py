"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) vocab=49155.

MoE 40 experts top-8, expert d_ff=512.  The assignment note also says
"32 experts"; we follow the primary spec line (40e top-8) — DESIGN.md §4.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,              # unused (no dense layers), kept for spec fidelity
    vocab_size=49155,
    n_experts=40,
    n_shared_experts=0,
    moe_top_k=8,
    moe_d_ff=512,
    first_dense_layers=0,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
