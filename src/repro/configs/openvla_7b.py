"""OpenVLA-7B — the paper's main evaluation model (§V).

ViT encoder + Llama-2-7B backbone + action de-tokenizer (no generative
action model).  OpenVLA generates 7-DoF actions token-by-token through the
LM head; the paper's Fig. 3 cut tensor [1, 17, 3072]... (OpenVLA's prompt
yields short action sequences).  ViT is a real ViT here (prismatic-style
patch encoder); dry-run input specs stub the image as patch embeddings.
[arXiv:2406.09246]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="openvla-7b",
    family="vla",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32064,
    rope_theta=10_000.0,
    vla_action_head="detok",
    vit_layers=24,
    vit_dim=1024,
    n_patches=256,
    action_dim=7,
    action_horizon=1,
)
