"""Configuration dataclasses for all architectures and input shapes.

Every assigned architecture (plus the paper's own OpenVLA / CogACT models)
is expressed as a :class:`ModelConfig`.  The same config object drives

* parameter-spec construction (``models.model.param_specs``),
* the analytic structure model of the paper (``core.structure``),
* the dry-run input specs (``launch.dryrun``),
* reduced "smoke" variants for CPU tests (:meth:`ModelConfig.reduced`).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    # -- identity ----------------------------------------------------------
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | audio | vlm | hybrid | vla

    # -- core transformer dims --------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0          # 0 -> d_model // n_heads

    # -- attention ---------------------------------------------------------
    rope_theta: float = 500_000.0
    parallel_block: bool = False      # command-r style parallel attn+ffn
    qkv_bias: bool = False
    causal: bool = True

    # -- MLA (deepseek) ------------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # -- MoE -----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    first_dense_layers: int = 0     # deepseek: first k layers use dense FFN

    # -- SSM (mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # -- hybrid (zamba2) -------------------------------------------------------
    shared_attn_every: int = 0      # shared transformer block every k ssm blocks

    # -- encoder-decoder (seamless) --------------------------------------------
    is_encdec: bool = False
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # -- VLM (llama-3.2-vision) --------------------------------------------------
    cross_attn_every: int = 0       # every k-th layer gets a gated cross-attn sublayer
    n_vision_tokens: int = 0

    # -- VLA (paper models) -------------------------------------------------------
    vla_action_head: str = ""       # detok | mlp | lstm | diffusion | dit
    vit_layers: int = 0
    vit_dim: int = 0
    n_patches: int = 0
    action_dim: int = 7
    action_horizon: int = 16
    diffusion_steps: int = 10
    dit_layers: int = 0
    dit_dim: int = 0
    dit_heads: int = 0

    # -- numerics / implementation ---------------------------------------------
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    scan_layers: bool = True        # False -> unrolled (exact HLO costs; dry-run)
    remat: bool = True
    attn_impl: str = "xla"          # xla | pallas
    tie_embeddings: bool = False
    # -- distribution variants (§Perf hillclimbing) -----------------------------
    decode_attn: str = "tp"         # tp | sp (shard_map flash-decode over seq)
    tp_collective: str = "ar"       # ar | int8_ring (inference projections)

    # ------------------------------------------------------------------ helpers
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // max(self.ssm_headdim, 1)

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can decode a 500k context (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # no encoder-only archs in the assigned pool

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------- param count
    def n_params(self) -> int:
        """Analytic parameter count (used for 6ND MODEL_FLOPS and paper tables)."""
        d, hd = self.d_model, self.resolved_head_dim
        nl = self.n_layers

        def attn_params() -> int:
            if self.use_mla:
                q = d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                kv_a = d * (self.kv_lora_rank + self.qk_rope_dim)
                kv_b = self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                o = self.n_heads * self.v_head_dim * d
                return q + kv_a + kv_b + o
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            return q + kv + o

        def mlp_params(ff: int) -> int:
            return 3 * d * ff  # SwiGLU: gate, up, down

        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # head

        if self.family in ("dense", "vlm"):
            total += nl * (attn_params() + mlp_params(self.d_ff) + 2 * d)
            if self.family == "vlm" and self.cross_attn_every:
                n_x = nl // self.cross_attn_every
                total += n_x * (attn_params() + 2 * d)
        elif self.family == "moe":
            n_moe = nl - self.first_dense_layers
            moe = self.n_experts * mlp_params(self.moe_d_ff) + d * self.n_experts
            moe += self.n_shared_experts * mlp_params(self.moe_d_ff)
            total += nl * (attn_params() + 2 * d)
            total += self.first_dense_layers * mlp_params(self.d_ff) + n_moe * moe
        elif self.family == "ssm":
            total += nl * (self._mamba_params() + d)
        elif self.family == "hybrid":
            total += nl * (self._mamba_params() + d)
            total += attn_params() + mlp_params(self.d_ff) + 2 * d  # one shared block
        elif self.family == "audio":
            enc = self.n_enc_layers * (attn_params() + mlp_params(self.d_ff) + 2 * d)
            dec = self.n_dec_layers * (2 * attn_params() + mlp_params(self.d_ff) + 3 * d)
            total += enc + dec
        elif self.family == "vla":
            total += self.vit_layers * (4 * self.vit_dim ** 2 + 8 * self.vit_dim ** 2) \
                + self.vit_dim * d
            total += nl * (attn_params() + mlp_params(self.d_ff) + 2 * d)
            total += self._action_head_params()
        return total

    def _mamba_params(self) -> int:
        d, di, ns = self.d_model, self.d_inner, self.ssm_state
        nh = self.ssm_nheads
        # B/C are per-group (n_groups=1), width ssm_state each
        in_proj = d * (2 * di + 2 * ns + nh)        # x, z, B, C, dt
        conv = self.ssm_conv * (di + 2 * ns)
        out = di * d
        return in_proj + conv + out + 2 * nh + di   # A, D, norm

    def _action_head_params(self) -> int:
        d, a = self.d_model, self.action_dim
        h = self.action_horizon
        if self.vla_action_head in ("detok", ""):
            return 0
        if self.vla_action_head == "mlp":
            return d * 4 * d + 4 * d * d + d * a * h
        if self.vla_action_head == "lstm":
            return 8 * d * d + d * a
        if self.vla_action_head == "diffusion":
            return 3 * (d * d) + d * a + a * d
        if self.vla_action_head == "dit":
            dd = self.dit_dim
            per = 4 * dd * dd + 8 * dd * dd + 6 * dd * dd  # attn+mlp+adaLN
            return self.dit_layers * per + d * dd + dd * a
        return 0

    # ------------------------------------------------------------------ reduced
    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            scan_layers=True,
            remat=False,
        )
        if self.use_mla:
            kw.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
        if self.n_experts:
            kw.update(n_experts=4, moe_top_k=2, moe_d_ff=64,
                      n_shared_experts=min(self.n_shared_experts, 1),
                      first_dense_layers=min(self.first_dense_layers, 1))
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32, d_model=64)
        if self.shared_attn_every:
            kw.update(shared_attn_every=2, n_layers=4)
        if self.is_encdec:
            kw.update(n_enc_layers=2, n_dec_layers=2)
        if self.cross_attn_every:
            kw.update(cross_attn_every=2, n_vision_tokens=8, n_layers=4)
        if self.family == "vla":
            kw.update(vit_layers=2, vit_dim=32, n_patches=16,
                      dit_layers=2, dit_dim=32, dit_heads=2,
                      diffusion_steps=2, action_horizon=4)
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell of the assignment grid."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "long_decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason). long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    if shape.kind == "long_decode" and not cfg.sub_quadratic:
        return False, ("skip: full-attention arch cannot decode 524288 ctx "
                       "(quadratic); see DESIGN.md §4")
    return True, ""
