"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

Cross-attn image layers: every 5th layer carries a gated cross-attention
sublayer over precomputed vision-patch embeddings (frontend STUB per the
assignment; input_specs() provides (B, n_vision_tokens, d_model)).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_every=5,
    n_vision_tokens=1600,
    rope_theta=500_000.0,
)
