"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H d_ff=8192 vocab=256206.

Encoder-decoder, multimodal.  "24L" is read as 24 encoder + 24 decoder layers
(the HF checkpoint's speech-encoder / text-decoder depths) — DESIGN.md §4.
The audio frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, S, d_model).
[arXiv:2308.11596; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=48,            # total, for bookkeeping
    n_enc_layers=24,
    n_dec_layers=24,
    is_encdec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    causal=True,
    rope_theta=10_000.0,
)
