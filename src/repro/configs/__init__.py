"""Architecture registry: ``get_config("<arch-id>")`` returns a ModelConfig.

The 10 assigned architectures (``--arch`` ids) plus the paper's own VLA
models (openvla-7b, cogact-7b) used by the RoboECC experiments.
"""
from __future__ import annotations

from .base import ModelConfig, ShapeConfig, SHAPES, get_shape, shape_applicable
from . import (
    llama3_2_3b,
    command_r_35b,
    glm4_9b,
    phi3_mini_3_8b,
    deepseek_v2_lite_16b,
    granite_moe_3b_a800m,
    mamba2_1_3b,
    seamless_m4t_large_v2,
    llama_3_2_vision_11b,
    zamba2_1_2b,
    openvla_7b,
    cogact_7b,
)

ARCHS = {
    "llama3.2-3b": llama3_2_3b.CONFIG,
    "command-r-35b": command_r_35b.CONFIG,
    "glm4-9b": glm4_9b.CONFIG,
    "phi3-mini-3.8b": phi3_mini_3_8b.CONFIG,
    "deepseek-v2-lite-16b": deepseek_v2_lite_16b.CONFIG,
    "granite-moe-3b-a800m": granite_moe_3b_a800m.CONFIG,
    "mamba2-1.3b": mamba2_1_3b.CONFIG,
    "seamless-m4t-large-v2": seamless_m4t_large_v2.CONFIG,
    "llama-3.2-vision-11b": llama_3_2_vision_11b.CONFIG,
    "zamba2-1.2b": zamba2_1_2b.CONFIG,
    # paper's own evaluation models
    "openvla-7b": openvla_7b.CONFIG,
    "cogact-7b": cogact_7b.CONFIG,
}

ASSIGNED = tuple(k for k in ARCHS if k not in ("openvla-7b", "cogact-7b"))


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}") from None


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "ASSIGNED",
    "get_config", "get_shape", "shape_applicable",
]
