"""Kernel micro-bench: jnp-oracle wall time on CPU + analytic TPU occupancy.

On this CPU-only container real kernel timings are meaningless for the TPU
target, so we report (a) oracle wall-time as a regression canary and (b) the
analytic MXU/VMEM occupancy of the Pallas tiling (FLOPs vs bytes per tile).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=5):
    fn(*args)  # warmup + compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(quiet=False):
    lines = []
    key = jax.random.PRNGKey(0)

    # flash attention tile analytics: (128,128) tiles, D=128
    bq = bk = 128
    D = 128
    tile_flops = 2 * bq * bk * D * 2
    tile_bytes = (bq * D + 2 * bk * D) * 2 + bq * D * 4
    lines.append(f"kernel_flash_tile,{tile_flops},"
                 f"arith_intensity={tile_flops / tile_bytes:.1f} flops/byte "
                 f"(v5e ridge ~240)")

    from repro.kernels.flash_attention import ref as fa_ref
    q = jax.random.normal(key, (2, 512, 4, 64), jnp.float32)
    k = jax.random.normal(key, (2, 512, 2, 64), jnp.float32)
    v = jax.random.normal(key, (2, 512, 2, 64), jnp.float32)
    t = _time(jax.jit(lambda a, b, c: fa_ref.attention(a, b, c)), q, k, v)
    lines.append(f"kernel_flash_oracle_cpu,{t * 1e6:.0f},B2S512H4D64")

    from repro.kernels.decode_attention import ref as da_ref
    q1 = jax.random.normal(key, (4, 8, 64))
    kc = jax.random.normal(key, (4, 2, 2048, 64))
    vc = jax.random.normal(key, (4, 2, 2048, 64))
    t = _time(jax.jit(lambda a, b, c: da_ref.decode_attention(a, b, c, 2000)),
              q1, kc, vc)
    lines.append(f"kernel_decode_oracle_cpu,{t * 1e6:.0f},B4H8T2048")

    from repro.kernels.ssd_scan import ref as ssd_ref
    x = jax.random.normal(key, (2, 512, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(key, (2, 512, 4)))
    A = -jnp.exp(jax.random.normal(key, (4,)) * 0.3)
    Bm = jax.random.normal(key, (2, 512, 32))
    Cm = jax.random.normal(key, (2, 512, 32))
    t = _time(jax.jit(lambda *a: ssd_ref.ssd(*a, 128)), x, dt, A, Bm, Cm)
    lines.append(f"kernel_ssd_oracle_cpu,{t * 1e6:.0f},B2T512H4P32N32")

    # activation codec column: wall time + wire reduction per format
    from repro.kernels.activation_codec import ops as codec
    from repro.kernels.activation_codec import ref as codec_ref
    x = jax.random.normal(key, (1024, 4096), jnp.bfloat16)
    raw = 1024 * 4096 * 2
    t = _time(lambda a: codec.quantize(a)[0], x)
    ratio = raw / codec_ref.wire_bytes((1024, 4096))
    lines.append(f"kernel_codec_int8_oracle_cpu,{t * 1e6:.0f},"
                 f"compression={ratio:.2f}x wire reduction")
    t = _time(lambda a: codec.quantize_int4(a)[0], x)
    ratio4 = raw / codec_ref.wire_bytes_int4((1024, 4096))
    lines.append(f"kernel_codec_int4_oracle_cpu,{t * 1e6:.0f},"
                 f"compression={ratio4:.2f}x wire reduction")
    q4, s4 = codec.quantize_int4(x)
    t = _time(lambda p, s: codec.dequantize_int4(p, s), q4, s4)
    lines.append(f"kernel_codec_int4_dec_oracle_cpu,{t * 1e6:.0f},"
                 f"packed {q4.shape[0]}x{q4.shape[1]}B")
    if not quiet:
        for ln in lines:
            print("  " + ln)
    return lines
