"""Fill EXPERIMENTS.md markers from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.fill_experiments
"""
from __future__ import annotations

import glob
import json
import os

from .roofline import ARTIFACT_DIR, markdown, table

EXP = os.path.join(os.path.dirname(__file__), "..", "docs", "EXPERIMENTS.md")


def dryrun_status() -> str:
    lines = ["| mesh | ok | skipped (long_500k, documented) | error |",
             "|---|---|---|---|"]
    for mesh in ("16x16", "2x16x16"):
        ok = sk = err = 0
        for path in glob.glob(os.path.join(ARTIFACT_DIR, "*.json")):
            name = os.path.basename(path)
            if not name.endswith(f"__{mesh}.json"):
                continue  # tagged variants / other meshes
            with open(path) as f:
                st = json.load(f).get("status")
            ok += st == "ok"
            sk += st == "skipped"
            err += st == "error"
        lines.append(f"| {mesh} | {ok} | {sk} | {err} |")
    return "\n".join(lines)


def roofline_notes() -> str:
    rows = [r for r in table() if "compute_ms" in r]
    if not rows:
        return ""
    worst = min(rows, key=lambda r: r["compute_ms"] / max(
        r["compute_ms"], r["memory_analytic_ms"], r["collective_ms"]))
    coll = max(rows, key=lambda r: r["collective_ms"] / max(
        r["compute_ms"], r["memory_analytic_ms"], r["collective_ms"], 1e-12))
    out = [
        "Per-cell one-line reads (what would move the dominant term):",
        "",
        "* **train_4k cells** are collective-dominated at TP=16: 4 residual"
        " all-reduces/layer (fwd+bwd) scale with activations, not params —"
        " fix = FSDP for the <10B archs (§Perf C) or fewer TP shards.",
        "* **prefill_32k cells**: same 2-per-layer TP all-reduce wall;"
        " int8-ring combine halves it (§Perf B); ring/sequence attention"
        " would remove it.",
        "* **decode_32k cells** are KV-bound: the baseline gathers the"
        " model-sharded cache every layer — sequence-parallel flash-decode"
        " (§Perf A) reduces wire by ~3 orders of magnitude.",
        "* **long_500k (mamba2/zamba2)**: state-recurrent decode is"
        " parameter-bound (memory term), already near its roofline;"
        " collective term negligible.",
        "* **MoE cells** (deepseek/granite): EP keeps the combine-psum at"
        " dense-FFN cost; dominant term matches the dense analogue.",
        "",
        f"Worst compute-fraction cell: {worst['arch']} x {worst['shape']}"
        f" ({worst['compute_ms'] / max(worst['compute_ms'], worst['memory_analytic_ms'], worst['collective_ms']):.2f}).",
        f" Most collective-bound: {coll['arch']} x {coll['shape']}.",
    ]
    return "\n".join(out)


def perf_section() -> str:
    from .perf_report import collective_kinds, compare
    parts = []
    parts.append("""### A. decode_32k / llama3.2-3b — most collective-bound cell

**Iteration 1 — hypothesis:** the baseline decode all-gathers the
model-sharded KV cache every layer (HLO shows 140 all-gathers = 35 GiB/dev
+ 42 GiB of resharding permutes per step -> collective term 639 ms, the
dominant term); napkin math says a sequence-parallel flash-decode (cache
sharded on T, shards combine with pmax/psum of (B,H)-stat tensors) needs
~25 MB/layer of psum instead — **~10³x less wire**, collective term
< 1 ms.  **Change:** `cfg.decode_attn="sp"` shard_map kernel
(models/attention.py `_sp_flash_decode`), cache layout `(B, T→model,
KV*hd)`.

**Iteration 1a — engineering detours (recorded):** the first two
formulations crashed GSPMD at production scale — `lax.axis_index` in a
partial-manual region lowers to an unsupported `PartitionId` (fixed by
feeding pre-sharded position iotas), and the partial-manual
(`axis_names={"model"}`) form then hit a hard `hlo_instruction.cc:1558
Invalid binary instruction opcode copy` check failure at >= 64 host
devices (logs in benchmarks/artifacts/perf_A.log).  Switching to a
FULL-manual shard_map over every mesh axis (batch explicitly over
`(pod, data)`, cache over `model`, cache update computed locally per
shard) avoids the partitioner paths entirely.

**Measurement — hypothesis CONFIRMED on the 16x16 production mesh:**
""")
    parts.append(compare("llama3.2-3b", "decode_32k", "sp",
                         "sequence-parallel flash-decode (beyond-paper)"))
    parts.append("""
Collective term **639 ms -> 3.73 ms (171x)**; HLO memory term halves (no
more gathered-cache traffic); the cell flips from collective-bound to its
parameter+cache memory floor.  Numerics exact (max err 8e-6 vs the TP
baseline over prefill+4 decode steps).  Per-step wire is now 28 layers x
(pmax/psum stats + one (B,1,H,hd) psum) ≈ 62 MB/dev vs 21.2 GiB/dev
gathered baseline — matching the napkin estimate within 2x.
""")
    parts.append("baseline collectives: "
                 + collective_kinds("llama3.2-3b", "decode_32k"))
    parts.append("variant collectives:  "
                 + collective_kinds("llama3.2-3b", "decode_32k", "sp"))
    parts.append("""
### B. prefill_32k / command-r-35b — paper-representative serving shape

**Iteration 1 — hypothesis:** prefill is TP-all-reduce-bound (2 per layer x
40 layers of (B,S,8192) bf16 residual all-reduces ≈ 172 GB/dev wire);
quantising the TP combine to int8+scales (the paper's own wire-compression
insight applied to intra-pod links) should halve the dominant term at ~1 %
activation error (measured 0.94 % end-to-end on 8 devices).  **Change:**
`cfg.tp_collective="int8_ring"` — shard_map row-parallel projections with a
hand-rolled int8 ring all-reduce (models/layers.py `int8_ring_proj`).
**Measurement — hypothesis REFUTED:**
""")
    parts.append(compare("command-r-35b", "prefill_32k", "int8ring",
                         "int8-ring TP combine (beyond-paper attempt)"))
    parts.append("""
**Lesson:** the fori-loop ring (dynamic chunk slice + ppermute per hop,
requantise each hop) lowers to ~16x MORE wire than the fused bf16
all-reduce: inside a partial-manual shard_map GSPMD cannot fuse the ring,
each hop moves full-tensor-sized intermediates, and the while-loop hides the
schedule from overlap.  A hand-rolled collective has to beat XLA's
decomposed ring all-reduce, which already pipelines at (2(N-1)/N)x bytes —
halving dtype is worth 2x only if the schedule stays fused.  The right
int8-combine is a compiler-level reduce-scatter/all-gather pair in s8 (not
expressible from JAX today); we keep the bf16 all-reduce as the shipped
default and record the negative result.  (The int8 ring IS still the right
tool for the *gradient* all-reduce, where one collective per step amortises
the ring overhead — see train/compression.py tests.)""")
    parts.append("""
### C. train_4k / llama3.2-3b — worst roofline fraction

**Iteration 1 — hypothesis:** a 3B model on 256 chips does not need TP=16;
the 160 GiB/dev of per-layer residual all-reduces is pure deployment
choice.  FSDP (params sharded over all 256 devices, activations
batch-sharded only) replaces them with per-layer parameter all-gathers:
~2x params bytes ≈ 1.7 GiB/dev — **~100x predicted wire reduction**, flops
unchanged.  **Change:** `make_rules(strategy="fsdp")`.  **Measurement:**
""")
    parts.append(compare("llama3.2-3b", "train_4k", "fsdp",
                         "FSDP / ZeRO-3 layout (beyond-paper)"))
    return "\n".join(parts)


def main():
    with open(EXP) as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_STATUS -->", dryrun_status())
    text = text.replace("<!-- ROOFLINE_TABLE -->",
                        markdown() + "\n\n(2x16x16 table: same reader with "
                        "`mesh='2x16x16'`; artifacts in the same directory.)")
    text = text.replace("<!-- ROOFLINE_NOTES -->", roofline_notes())
    text = text.replace("<!-- PERF_SECTION -->", perf_section())
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
