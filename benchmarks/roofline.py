"""§Roofline reader: dry-run artifacts -> per-cell roofline table.

Reads benchmarks/artifacts/dryrun/*.json and emits, per (arch x shape) on
the single-pod mesh: the three terms, the dominant bottleneck, MODEL_FLOPS /
HLO_FLOPS, and an analytic memory term (HLO "bytes accessed" on the CPU
backend over-counts fused traffic; the analytic term models weights+cache
+activation DRAM traffic — both are reported).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")

V5E_FLOPS = 197e12
V5E_HBM = 819e9
V5E_LINK = 50e9


def load_cells(mesh: str = "16x16", tag: str = "") -> List[Dict]:
    cells = []
    suffix = f"__{tag}.json" if tag else ".json"
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        name = os.path.basename(path)
        if f"__{mesh}" not in name:
            continue
        if tag:
            if not name.endswith(suffix):
                continue
        elif name.count("__") != 2:
            continue
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analytic_memory_s(cell: Dict) -> Optional[float]:
    """DRAM-traffic estimate per step from the residency breakdown."""
    r = cell.get("analytic_residency_per_device")
    if not r:
        return None
    kind = cell["shape"]
    p = r.get("params", 0.0)
    if kind.startswith("train"):
        traffic = 3 * p + 2 * r.get("adam_moments", 0.0) \
            + 3 * r.get("remat_activations", 0.0) \
            + 2 * r.get("logits_shard", 0.0)
    elif kind.startswith("prefill"):
        traffic = p + 2 * r.get("kv_cache", 0.0) \
            + 4 * r.get("working_set", 0.0)
    else:
        traffic = p + r.get("kv_cache", 0.0) + r.get("working_set", 0.0)
    return traffic / V5E_HBM


def row(cell: Dict) -> Dict:
    pd = cell["per_device"]
    rf = cell["roofline"]
    mem_a = analytic_memory_s(cell)
    comp = rf["compute_s"]
    coll = rf["collective_s"]
    # older artifacts zeroed collective-permute wire (no replica_groups);
    # patch in bytes*0.5 (bf16-equivalent) from the by_kind summary
    cp = cell.get("collectives", {}).get("by_kind", {}).get(
        "collective-permute")
    if cp and cp.get("wire_bytes_bf16", 0) == 0 and cp.get("bytes", 0) > 0:
        coll = coll + 0.5 * cp["bytes"] / V5E_LINK
    dom_terms = {"compute": comp, "memory(analytic)": mem_a or 0.0,
                 "collective": coll}
    dominant = max(dom_terms, key=dom_terms.get)
    bound = max(dom_terms.values())
    frac = comp / bound if bound else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "compute_ms": comp * 1e3,
        "memory_hlo_ms": rf["memory_s"] * 1e3,
        "memory_analytic_ms": (mem_a or 0.0) * 1e3,
        "collective_ms": coll * 1e3,
        "dominant": dominant,
        "roofline_fraction": frac,
        "useful_flops_ratio": cell.get("useful_flops_ratio", 0.0),
        "peak_gib_cpu": pd["peak_hbm_bytes"] / 2 ** 30,
        "est_gib_tpu": cell["analytic_residency_per_device"]["total"] / 2 ** 30
        if cell.get("analytic_residency_per_device") else 0.0,
        "compile_s": cell.get("compile_s", 0.0),
    }


def table(mesh: str = "16x16", tag: str = "") -> List[Dict]:
    rows = []
    for cell in load_cells(mesh, tag):
        if cell["status"] == "ok":
            rows.append(row(cell))
        else:
            rows.append({"arch": cell["arch"], "shape": cell["shape"],
                         "mesh": cell["mesh"], "dominant": cell["status"],
                         "reason": cell.get("reason",
                                            cell.get("error", ""))[:90]})
    return rows


def markdown(mesh: str = "16x16", tag: str = "") -> str:
    rows = table(mesh, tag)
    hdr = ("| arch | shape | compute ms | mem(HLO) ms | mem(analytic) ms | "
           "coll ms | dominant | useful-FLOPs | est GiB/dev |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        if "compute_ms" not in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"{r['dominant']}: {r.get('reason', '')} | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_ms']:.2f} | "
            f"{r['memory_hlo_ms']:.1f} | {r['memory_analytic_ms']:.2f} | "
            f"{r['collective_ms']:.2f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.2f} | {r['est_gib_tpu']:.2f} |")
    return "\n".join(out)


def run(quiet=False) -> List[str]:
    lines = []
    for r in table():
        if "compute_ms" in r:
            lines.append(
                f"roofline_{r['arch']}_{r['shape']},"
                f"{max(r['compute_ms'], r['memory_analytic_ms'], r['collective_ms']) * 1e3:.0f},"
                f"dom={r['dominant']} comp={r['compute_ms']:.2f}ms "
                f"coll={r['collective_ms']:.2f}ms "
                f"useful={r['useful_flops_ratio']:.2f}")
            if not quiet:
                print("  " + lines[-1])
    return lines


if __name__ == "__main__":
    print(markdown())
