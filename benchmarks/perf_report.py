"""§Perf report: baseline vs hillclimb-variant artifact comparison."""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from .roofline import ARTIFACT_DIR, V5E_LINK, analytic_memory_s, row


def load(arch: str, shape: str, mesh: str = "16x16", tag: str = ""
         ) -> Optional[Dict]:
    t = f"__{tag}" if tag else ""
    path = os.path.join(ARTIFACT_DIR, f"{arch}__{shape}__{mesh}{t}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        d = json.load(f)
    return d if d.get("status") == "ok" else None


def compare(arch: str, shape: str, tag: str, label: str) -> str:
    base = load(arch, shape)
    var = load(arch, shape, tag=tag)
    if base is None or var is None:
        return f"*(artifact missing for {arch} x {shape} [{tag}])*"
    rb, rv = row(base), row(var)

    def fmt(r, d):
        bound = max(r["compute_ms"], r["memory_analytic_ms"],
                    r["collective_ms"])
        return (f"| {d} | {r['compute_ms']:.2f} | {r['memory_analytic_ms']:.2f} | "
                f"{r['collective_ms']:.2f} | {r['dominant']} | "
                f"{bound:.2f} | {r['compute_ms'] / bound:.2f} |")

    hdr = ("| variant | compute ms | memory ms | collective ms | dominant | "
           "bound ms | roofline fraction |\n|---|---|---|---|---|---|---|")
    bb = max(rb["compute_ms"], rb["memory_analytic_ms"], rb["collective_ms"])
    vb = max(rv["compute_ms"], rv["memory_analytic_ms"], rv["collective_ms"])
    gain = bb / vb if vb else float("inf")
    return "\n".join([hdr, fmt(rb, "baseline (paper-faithful TP)"),
                      fmt(rv, label),
                      f"\n**step-bound improvement: x{gain:.2f}**"])


def collective_kinds(arch: str, shape: str, tag: str = "") -> str:
    d = load(arch, shape, tag=tag)
    if d is None:
        return "(missing)"
    out = []
    for k, v in d["collectives"]["by_kind"].items():
        out.append(f"{k}: n={v['count']} wire16={v['wire_bytes_bf16'] / 2**30:.2f}GiB")
    return "; ".join(out)
