"""Paper figures: Fig.2 (segmentation curves), Fig.3 (drift), Fig.6
(overhead), Fig.7 (threshold sweep)."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import (NetworkSim, PredictorConfig, RoboECC, Thresholds,
                        TraceConfig, Workload, build_graph, build_pool,
                        calibrate_thresholds, cut_bytes, evaluate_split,
                        generate_trace, pool_transfer_profile, search,
                        total_weight_bytes)
from .paper_tables import NOMINAL_BW, calibrated_devices, net_latency


def fig2_segmentation(quiet=False):
    """Latency vs split point for OpenVLA (linear) vs CogACT (DiT kink)."""
    lines = []
    for model in ("openvla", "cogact"):
        cfg, g, edge, cloud = calibrated_devices(model, "orin")
        n = len(g)
        lat = []
        for s in range(n + 1):
            e, c, _ = evaluate_split(g, s, edge, cloud, NOMINAL_BW)
            t = e + c + net_latency(g, s, model)
            lat.append(t * 1e3)
        # linearity probe on the LLM-block tail region
        llm_idx = [i for i, c_ in enumerate(g) if c_.kind == "llm"]
        tail = lat[llm_idx[len(llm_idx) // 2]:llm_idx[-1]]
        diffs = np.diff(tail)
        lines.append(f"fig2_{model}_curve,{np.mean(lat) * 1e3:.0f},"
                     f"min={min(lat):.1f}ms@{int(np.argmin(lat))} "
                     f"llm_region_slope_std={np.std(diffs):.3f}")
        if model == "cogact":
            # structural transition: latency jumps at the llm->dit boundary
            first_dit = next(i for i, c_ in enumerate(g)
                             if c_.kind == "dit")
            jump = abs(lat[first_dit + 1] - lat[first_dit])
            base = np.mean(np.abs(diffs)) + 1e-9
            lines.append(f"fig2_cogact_dit_kink,{jump * 1e3:.0f},"
                         f"jump={jump:.2f}ms vs llm slope {base:.2f}ms")
        if not quiet:
            print("  " + lines[-1])
    return lines


def fig3_drift(quiet=False):
    """The paper's exact example: cut [1,17,3072] (102KB) vs [1,17,768]
    (25.5KB); optimal split moves when 10 MB/s drops to 1 MB/s."""
    lines = []
    old_cut = 17 * 3072 * 2     # 104448 B ~ 102 KB
    new_cut = 17 * 768 * 2      # 26112 B ~ 25.5 KB
    for bw, name in ((10e6, "good"), (1e6, "bad")):
        t_old = old_cut / bw * 1e3
        t_new = new_cut / bw * 1e3
        lines.append(f"fig3_{name}_old_cut,{t_old * 1e3:.0f},"
                     f"{t_old:.1f}ms for 102KB @{bw / 1e6:.0f}MB/s")
        lines.append(f"fig3_{name}_new_cut,{t_new * 1e3:.0f},"
                     f"{t_new:.1f}ms for 25.5KB @{bw / 1e6:.0f}MB/s")
    # paper: 9.9ms -> 99.6ms -> move -> 24.9ms
    assert abs(old_cut / 10e6 * 1e3 - 10.4) < 1.0
    assert abs(old_cut / 1e6 * 1e3 - 104.4) < 6.0
    assert abs(new_cut / 1e6 * 1e3 - 26.1) < 2.0
    if not quiet:
        for ln in lines:
            print("  " + ln)
    return lines


def fig6_overhead(quiet=False):
    """Parameter-sharing pool + LSTM size as % of model weights."""
    lines = []
    for model in ("openvla", "cogact"):
        cfg, g, edge, cloud = calibrated_devices(model, "orin")
        seg = search(g, edge, cloud, NOMINAL_BW,
                     cloud_budget_bytes=12.1e9)
        pool = build_pool(g, seg.split, overhead_target=0.028)
        from repro.core import PredictorConfig, train_predictor
        trace = generate_trace(400, seed=0)
        pred, _ = train_predictor(trace, PredictorConfig(epochs=5))
        lstm_frac = pred.n_bytes() / total_weight_bytes(g)
        lines.append(
            f"fig6_{model}_pool,{pool.overhead_frac * 1e8:.0f},"
            f"pool={pool.overhead_frac * 100:.2f}% (paper 2.55-2.62%) "
            f"lstm={lstm_frac * 100:.4f}%")
        assert pool.overhead_frac < 0.04
        assert lstm_frac < 0.01
        if not quiet:
            print("  " + lines[-1])
    return lines


def fig7_thresholds(quiet=False):
    """T_low / T_high calibration sweep (paper §V-C-2 procedure)."""
    cfg, g, edge, cloud = calibrated_devices("openvla", "orin")
    seg = search(g, edge, cloud, NOMINAL_BW, cloud_budget_bytes=12.1e9)
    pool = build_pool(g, seg.split, overhead_target=0.03)
    trace = generate_trace(1200, TraceConfig(), seed=5)
    deltas = np.diff(trace)

    from repro.core import adjust

    def eval_fn(thr: Thresholds) -> float:
        split = seg.split
        lat = []
        for t in range(64, 400):
            d = adjust(g, pool, split, trace[t], trace[t - 1], thr)
            split = d.split
            e, c, _ = evaluate_split(g, split, edge, cloud, trace[t])
            lat.append(e + c + net_latency(g, split, "openvla", bw=trace[t]))
        return float(np.mean(lat))

    thr = calibrate_thresholds(deltas, eval_fn, n_grid=5)
    base = eval_fn(Thresholds(high=float("inf"), low=float("-inf")))  # never adjust
    best = eval_fn(thr)
    line = (f"fig7_thresholds,{best * 1e6:.0f},"
            f"T_high={thr.high / 1e6:.2f}MB/s T_low={thr.low / 1e6:.2f}MB/s "
            f"avg={best * 1e3:.1f}ms vs no-adjust {base * 1e3:.1f}ms")
    assert best <= base * 1.001, "calibrated thresholds must not lose"
    if not quiet:
        print("  " + line)
    return [line]


def adjustment_overhead_vs_gain(quiet=False):
    """Paper §V-C-1: adjust overhead ~10.7ms vs ~32.6ms average gain."""
    cfg = get_config("openvla-7b")
    ctl = RoboECC(cfg, *calibrated_devices("openvla", "orin")[2:4],
                  cloud_budget_bytes=12.1e9,
                  thresholds=Thresholds(high=1.5e6, low=-1.5e6))
    trace = generate_trace(3000, seed=2)
    ctl.fit_predictor(trace[:2000], PredictorConfig(epochs=80))
    net = NetworkSim(trace[2000:])
    net.step(40)
    with_adj, overheads = [], []
    for _ in range(120):
        r = ctl.tick(net)
        with_adj.append(r.total_s - r.adjust_overhead_s)
        overheads.append(r.adjust_overhead_s)
    ctl2 = RoboECC(cfg, ctl.edge_dev, ctl.cloud_dev,
                   cloud_budget_bytes=12.1e9)
    net2 = NetworkSim(trace[2000:])
    net2.step(40)
    without = [ctl2.tick(net2, adjust_enabled=False).total_s
               for _ in range(120)]
    gain = (np.mean(without) - np.mean(with_adj)) * 1e3
    ovh = np.mean(overheads[3:]) * 1e3
    line = (f"adjust_overhead_vs_gain,{ovh * 1e3:.0f},"
            f"overhead={ovh:.1f}ms gain={gain:.1f}ms (paper: 10.7 vs 32.6)")
    if not quiet:
        print("  " + line)
    return [line]
