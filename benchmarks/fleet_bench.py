"""Fleet-scale serving benchmark: vectorized planner + fleet simulator.

Four measurements:

1. **Planner**: a full bandwidth-sweep plan (every registered config × a
   log-spaced bandwidth grid) via the scalar Alg. 1 loop vs the vectorized
   ``sweep_search`` — reports wall time of each and the speedup, and checks
   the two return identical splits everywhere (incl. the codec axis vs the
   scalar ``search_joint`` oracle, and the multi-cut (S1, S2) pass vs the
   scalar ``search_multicut_scalar`` oracle).
2. **Fleet**: an end-to-end ``FleetSimulator`` run (default 24 robots over
   4 heterogeneous model configs, 3 cloud replicas, with a mid-run capacity
   crunch and a full outage window) — reports per-robot p50/p95 latency and
   fleet-aggregate latency/throughput.
3. **Codecs**: the same fleet pinned to a constrained link (default
   2 MB/s mean) under each split-boundary codec — identity vs int8 vs int4
   vs the joint codec axis — reporting fleet p50/p95 per codec (the
   compression-in-the-loop win recorded in docs/EXPERIMENTS.md §Perf).
4. **Multi-cut**: single-cut vs multi-cut plan tables on the same OpenVLA
   fleet at the paper's 10 / 1 / 0.2 MB/s operating points, under a tight
   per-robot cloud quota and an asymmetric (8x) downlink — the
   edge→cloud→edge placement keeps the byte-heavy action head on the edge,
   freeing quota for one more trunk layer on the cloud
   (docs/EXPERIMENTS.md §Multi-cut).

5. **Streamed**: sequential vs streamed chunk transport
   (``core/pipeline.py``) on the same multi-cut OpenVLA fleet at the same
   operating points — the streamed plan table adds the chunk-count axis,
   chunked uplinks draw the per-tick trace bandwidth and overlap the
   cloud window's prefill, and the report carries chunk reconfigs +
   residual bubble fraction (docs/EXPERIMENTS.md §Streaming).

6. **Queue**: fixed-batch queue-blind (the pre-continuous baseline) vs
   the continuous-batching cloud tier (``runtime/scheduler.
   ContinuousBatcher``) vs continuous + queue-aware planning (M/G/1 wait
   term in the plan tables) on the 1 MB/s OpenVLA multi-cut fleet, plus a
   tight-KV-budget row that forces preempt/recompute — reporting p50/p95
   alongside ``n_preemptions`` / ``mean_queue_delay_s`` /
   ``kv_high_watermark_bytes`` (docs/EXPERIMENTS.md §Queue-aware).

7. **Scale**: the event-driven engine (``runtime/events.py``) at
   10k robots × 2000 ticks (1k in smoke) with the chaos schedule and an
   open-loop Poisson stream — wall time plus the p99/p99.9 tail
   percentiles only a fleet this size can estimate
   (docs/EXPERIMENTS.md §Scale).

8. **Overhead + drift**: the scale scenario with the flight recorder
   (``core/telemetry.py``) off vs sampled vs full — wall-clock ratios
   (sampled must stay under the <3% budget, mirroring the paper's
   2.55–2.62% sharing overhead), a bit-identity check across modes,
   the sampled run's Chrome trace exported to
   ``BENCH_fleet.trace.json``, and the full run's planner-vs-runtime
   per-stage drift audit (docs/EXPERIMENTS.md §Drift).

The machine-readable payload written to ``BENCH_fleet.json`` carries a
``schema_version`` field validated by ``tools/check_bench_schema.py``
(wired into CI next to the doc-link check).

    PYTHONPATH=src python benchmarks/fleet_bench.py [--robots N] [--ticks T]

``run(quiet=True)`` yields the repo-standard ``name,us_per_call,derived``
CSV lines for ``benchmarks/run.py``; ``run_with_json`` additionally
returns the machine-readable payload ``benchmarks/run.py`` writes to
``BENCH_fleet.json`` so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.configs import ARCHS, get_config
from repro.core import (TraceConfig, Workload, build_graph, graph_arrays,
                        search, search_joint, search_multicut,
                        search_multicut_scalar, sweep_multicut, sweep_search)
from repro.core.hardware import A100, ORIN
from repro.runtime.fleet import (FleetConfig, FleetReport, outage_schedule,
                                 run_fleet)

DEFAULT_ARCHS = ("openvla-7b", "cogact-7b", "llama3.2-3b", "glm4-9b")
CODEC_AXIS = ("identity", "int8", "int4")
# BENCH_fleet.json schema version — bump when payload sections/keys
# change; tools/check_bench_schema.py validates the emitted file
# (v3: added the "queue" section — continuous batching + queue-aware
# planning; v4: added the "scale" section — event-engine 10k-robot run
# with p99/p99.9 tails and open-loop arrival traffic; v5: added the
# "scaling_curve" section — per-size wall/peak-RSS/setup-loop-replan
# breakdown of the vectorized engine, monotonicity-checked — and the
# "autoscale" section — AutoScaler threshold sweep over a two-cohort
# regional bandwidth mix; v6: added the "overhead" section — flight
# recorder off/sampled/full wall-clock ratios at the 10k-robot scale
# point — and the "drift" section — planner-predicted vs measured
# per-stage signed error distributions from the recorder's audit;
# v7: added the "delta" section — temporal-delta transport bytes per
# step by scene class (static/slow/dynamic) vs int4, key-frame rates,
# and the wire-bytes drift row auditing the planner's cycle-average
# pricing against the measured per-frame bytes)
BENCH_SCHEMA_VERSION = 7
# multi-cut scenario: per-robot cloud quota (a shared cloud cannot host
# every robot's full tail) + asymmetric WAN (downlink 8x the uplink)
MULTICUT_QUOTA_BYTES = 5.8e9
MULTICUT_DOWN_FACTOR = 8.0
MULTICUT_POINTS_BPS = (10e6, 1e6, 0.2e6)
# queue scenario: the 1 MB/s acceptance point; the tight budget is sized
# well under the fleet's observed KV high watermark so preempt/recompute
# actually fires in the comparison row
QUEUE_BW_BPS = 1e6
QUEUE_TIGHT_KV_BYTES = 1.5e8
# scale scenario: the event-engine acceptance run — 10k robots x 2000
# ticks with the chaos schedule and an open-loop Poisson stream (the
# vectorized SoA engine lands this in single-digit seconds); smoke
# shrinks to 1k robots (the CI scale-smoke step asserts its own wall
# budget against the emitted payload)
SCALE_ROBOTS, SCALE_TICKS, SCALE_REPLICAS = 10_000, 2_000, 6
SCALE_SMOKE_ROBOTS, SCALE_SMOKE_TICKS = 1_000, 200
SCALE_ARRIVAL_HZ = 50.0
# scaling curve: the same chaos+arrivals scenario at increasing fleet
# sizes, run ASCENDING so the peak-RSS high-water mark is per-size
# meaningful; 100k x 2000 is the vectorized engine's acceptance point
# (must land under the 120 s budget on CI hardware)
SCALE_CURVE_SIZES = (1_000, 10_000, 100_000)
SCALE_CURVE_SMOKE_SIZES = (200, 500, 1_000)
SCALE_100K_BUDGET_S = 120.0
# autoscale scenario: backlog-threshold sweep over a two-cohort regional
# bandwidth mix (metro fiber vs rural LTE, per-cohort TraceConfig) — the
# fleet starts with most replicas parked (tick-0 leaves) so the scaler's
# watermark decides how much capacity the arrival load recruits
AUTOSCALE_HIGH_S = (0.05, 0.25, 1.0)
AUTOSCALE_COHORTS = (
    ("metro", TraceConfig()),                             # 10 MB/s fiber
    ("rural", TraceConfig(mean_bps=1.5e6, bad_bps=0.3e6)))  # LTE fringe
AUTOSCALE_ARRIVAL_HZ = 25.0
# telemetry overhead scenario: the scale fleet with the flight recorder
# off vs sampled (1/64) vs full — the sampled mode must stay inside the
# pool-overhead class of budgets (paper §V reports 2.55–2.62% sharing
# overhead; the recorder gets the same <3% allowance).  Smoke runs are
# noise-dominated at sub-second walls, so they get a loose 2x gate and
# the payload records which gate applied.
OVERHEAD_ROBOTS, OVERHEAD_TICKS, OVERHEAD_REPEATS = 10_000, 1_000, 3
OVERHEAD_SMOKE_ROBOTS, OVERHEAD_SMOKE_TICKS = 500, 200
OVERHEAD_BUDGET_RATIO = 1.03
OVERHEAD_SMOKE_BUDGET_RATIO = 2.0
TRACE_EXPORT_PATH = "BENCH_fleet.trace.json"
# temporal-delta scenario: the delta codec priced for each scene class's
# mean change fraction, vs plain int4, on the same constrained link.
# The static-scene acceptance gate (measured wire bytes ≥5x below int4)
# runs at full size only — smoke fleets are keyframe-dominated by their
# short horizon.  The drift row compares the planner's cycle-average
# wire bytes against the measured per-frame bytes via the flight
# recorder's audit; |mean signed error| must stay within
# DELTA_DRIFT_REL_TOL of the mean measured bytes (the residual is the
# keyframe-phase beat the cycle average can't see).
DELTA_SCENES = ("static", "slow", "dynamic")
DELTA_RESYNC = 16
DELTA_STATIC_GATE_RATIO = 5.0
DELTA_DRIFT_REL_TOL = 0.5


# ---------------------------------------------------------------- planner
def bench_planner(n_bw: int = 64, repeats: int = 3):
    """Time scalar-vs-vectorized Alg. 1 over (all configs × n_bw bandwidths).

    Returns (scalar_s, vec_s, n_cells, mismatches)."""
    w = Workload()
    graphs = {k: build_graph(get_config(k), w) for k in sorted(ARCHS)}
    bws = np.geomspace(0.05e6, 100e6, n_bw)

    t0 = time.perf_counter()
    for _ in range(repeats):
        scalar = {k: [search(g, ORIN, A100, float(bw),
                             input_bytes=w.input_bytes).split
                      for bw in bws]
                  for k, g in graphs.items()}
    scalar_s = (time.perf_counter() - t0) / repeats

    t0 = time.perf_counter()
    for _ in range(repeats):
        vec = sweep_search(graphs, ORIN, A100, bws,
                           input_bytes=w.input_bytes)
    vec_s = (time.perf_counter() - t0) / repeats

    mism = sum(int(vec[k].splits[j]) != scalar[k][j]
               for k in graphs for j in range(n_bw))
    return scalar_s, vec_s, len(graphs) * n_bw, mism


def bench_planner_codecs(n_bw: int = 64, repeats: int = 3):
    """Same comparison with the codec axis enabled: scalar ``search_joint``
    per (config × bandwidth) vs one vectorized (M, C, S, B) pass.

    Returns (scalar_s, vec_s, n_cells, mismatches) where a mismatch is a
    differing split OR codec."""
    w = Workload()
    graphs = {k: build_graph(get_config(k), w) for k in sorted(ARCHS)}
    bws = np.geomspace(0.05e6, 100e6, n_bw)

    t0 = time.perf_counter()
    for _ in range(repeats):
        scalar = {k: [search_joint(g, ORIN, A100, float(bw), CODEC_AXIS,
                                   input_bytes=w.input_bytes)
                      for bw in bws]
                  for k, g in graphs.items()}
    scalar_s = (time.perf_counter() - t0) / repeats

    t0 = time.perf_counter()
    for _ in range(repeats):
        vec = sweep_search(graphs, ORIN, A100, bws,
                           input_bytes=w.input_bytes, codecs=CODEC_AXIS)
    vec_s = (time.perf_counter() - t0) / repeats

    mism = sum(int(vec[k].splits[j]) != scalar[k][j].split
               or vec[k].codec_names[vec[k].codec_idx[j]]
               != scalar[k][j].codec
               for k in graphs for j in range(n_bw))
    return scalar_s, vec_s, len(graphs) * n_bw * len(CODEC_AXIS), mism


def bench_planner_multicut(n_bw: int = 8, repeats: int = 1,
                           archs=None):
    """Multi-cut planner: the scalar (S1, S2, codec) oracle loop per
    (config × bandwidth) vs the vectorized (C, S1, S2, B)
    ``search_multicut`` pass per config — both sides run on the same
    precomputed ``GraphArrays`` so the ratio is pure search, not array
    construction.  Also checks the padded all-model ``sweep_multicut``
    pass returns identical plans.  Returns (scalar_s, vec_s, n_cells,
    mismatches) where a mismatch is a differing cut pair OR codec — the
    ≥50x acceptance gate for the multi-cut refactor."""
    w = Workload()
    names = sorted(ARCHS) if archs is None else list(archs)
    graphs = {k: build_graph(get_config(k), w) for k in names}
    gas = {k: graph_arrays(g, ORIN, A100, input_bytes=w.input_bytes)
           for k, g in graphs.items()}
    bws = np.geomspace(0.05e6, 100e6, n_bw)

    t0 = time.perf_counter()
    for _ in range(repeats):
        scalar = {k: [search_multicut_scalar(
            g, ORIN, A100, float(bw), MULTICUT_QUOTA_BYTES,
            codecs=CODEC_AXIS, input_bytes=w.input_bytes,
            down_bw_factor=MULTICUT_DOWN_FACTOR, arrays=gas[k])
            for bw in bws]
            for k, g in graphs.items()}
    scalar_s = (time.perf_counter() - t0) / repeats

    t0 = time.perf_counter()
    for _ in range(repeats):
        vec = {k: search_multicut(
            g, ORIN, A100, bws, MULTICUT_QUOTA_BYTES, codecs=CODEC_AXIS,
            input_bytes=w.input_bytes,
            down_bw_factor=MULTICUT_DOWN_FACTOR, arrays=gas[k])
            for k, g in graphs.items()}
    vec_s = (time.perf_counter() - t0) / repeats

    sw = sweep_multicut(graphs, ORIN, A100, bws, MULTICUT_QUOTA_BYTES,
                        codecs=CODEC_AXIS, input_bytes=w.input_bytes,
                        down_bw_factor=MULTICUT_DOWN_FACTOR)
    mism = sum(vec[k].plan_at(j) != scalar[k][j].plan
               or sw[k].plan_at(j) != scalar[k][j].plan
               for k in graphs for j in range(n_bw))
    # triangular S1 <= S2 region — the space the oracle actually scans
    cells = sum((len(g) + 1) * (len(g) + 2) // 2 for g in graphs.values()) \
        * n_bw * len(CODEC_AXIS)
    return scalar_s, vec_s, cells, mism


# ------------------------------------------------------------------ fleet
def fleet_config(n_robots: int = 24, n_ticks: int = 400, n_replicas: int = 3,
                 seed: int = 0, archs=DEFAULT_ARCHS) -> FleetConfig:
    cfg = FleetConfig(n_robots=n_robots, archs=tuple(archs),
                      n_ticks=n_ticks, n_replicas=n_replicas, seed=seed)
    cfg.replica_events = outage_schedule(cfg)
    return cfg


# ------------------------------------------------------------------ codecs
def bench_codecs(n_robots: int = 16, n_ticks: int = 200, n_replicas: int = 3,
                 seed: int = 0, mean_bw_bps: float = 2e6):
    """Fleet latency per split-boundary codec on a constrained link.

    Runs the same fleet (no outage events — isolate the transport effect)
    with the link pinned around ``mean_bw_bps`` (default 2 MB/s, the
    paper's degraded regime) once per codec, plus once with the full joint
    codec axis.  Returns ``[(label, FleetReport)]``.
    """
    trace = TraceConfig(mean_bps=mean_bw_bps, bad_bps=mean_bw_bps / 4)
    rows = []
    for label, axis in (
            [(c, (c,)) for c in CODEC_AXIS] + [("joint", CODEC_AXIS)]):
        cfg = FleetConfig(n_robots=n_robots, archs=DEFAULT_ARCHS,
                          n_ticks=n_ticks, n_replicas=n_replicas, seed=seed,
                          codecs=axis, trace=trace,
                          nominal_bw_bps=mean_bw_bps)
        rows.append((label, run_fleet(cfg)))
    return rows


def bench_multicut(n_robots: int = 16, n_ticks: int = 200,
                   n_replicas: int = 3, seed: int = 0,
                   points=MULTICUT_POINTS_BPS, arch: str = "openvla-7b"):
    """Single-cut vs multi-cut plan tables, same fleet, same quota, same
    codec axis, at each bandwidth operating point.  The trace is pinned
    near the operating point (``bad_bps`` floored at 0.2 MB/s so the p95
    tail stays in the collaborative regime rather than collapsing both
    plans to edge-only).  Returns ``[(bw, mode, FleetReport)]``."""
    rows = []
    for bw in points:
        trace = TraceConfig(mean_bps=bw, bad_bps=max(bw / 4, 0.2e6))
        for mode in ("single", "multi"):
            cfg = FleetConfig(
                n_robots=n_robots, archs=(arch,), n_ticks=n_ticks,
                n_replicas=n_replicas, seed=seed, codecs=CODEC_AXIS,
                trace=trace, nominal_bw_bps=bw,
                cloud_budget_bytes=MULTICUT_QUOTA_BYTES,
                multicut=(mode == "multi"),
                down_bw_factor=MULTICUT_DOWN_FACTOR)
            rows.append((bw, mode, run_fleet(cfg)))
    return rows


def bench_streamed(n_robots: int = 16, n_ticks: int = 200,
                   n_replicas: int = 3, seed: int = 0,
                   points=MULTICUT_POINTS_BPS, arch: str = "openvla-7b",
                   seq_reports=None):
    """Sequential vs streamed chunk transport, same multi-cut fleet, same
    quota and codec axis, at each bandwidth operating point.  The
    ``seq`` rows are the multi-cut fleet as-is; ``stream`` rows plan the
    chunk axis too and price chunked uplinks against the per-tick trace.
    ``seq_reports`` (``{bw: FleetReport}``) reuses already-simulated
    sequential rows — ``run_with_json`` passes ``bench_multicut``'s
    ``multi`` reports, whose configs are identical, instead of paying
    the same three fleet simulations twice.  Returns
    ``[(bw, mode, FleetReport)]``."""
    rows = []
    for bw in points:
        trace = TraceConfig(mean_bps=bw, bad_bps=max(bw / 4, 0.2e6))
        for mode in ("seq", "stream"):
            if mode == "seq" and seq_reports is not None \
                    and bw in seq_reports:
                rows.append((bw, mode, seq_reports[bw]))
                continue
            cfg = FleetConfig(
                n_robots=n_robots, archs=(arch,), n_ticks=n_ticks,
                n_replicas=n_replicas, seed=seed, codecs=CODEC_AXIS,
                trace=trace, nominal_bw_bps=bw,
                cloud_budget_bytes=MULTICUT_QUOTA_BYTES,
                multicut=True, down_bw_factor=MULTICUT_DOWN_FACTOR,
                streamed=(mode == "stream"))
            rows.append((bw, mode, run_fleet(cfg)))
    return rows


def bench_queue(n_robots: int = 16, n_ticks: int = 200,
                n_replicas: int = 2, seed: int = 0,
                bw: float = QUEUE_BW_BPS, arch: str = "openvla-7b"):
    """Continuous batching + queue-aware planning at the 1 MB/s OpenVLA
    multi-cut operating point: the fixed-batch queue-blind fleet (the
    pre-continuous baseline path, bit-identical to earlier releases) vs
    the ContinuousBatcher tier, queue-blind and queue-aware, plus a
    tight-KV-budget queue-aware row where preempt/recompute fires.
    Returns ``[(label, FleetReport)]``."""
    trace = TraceConfig(mean_bps=bw, bad_bps=max(bw / 4, 0.2e6))

    def cfg(**kw) -> FleetConfig:
        return FleetConfig(n_robots=n_robots, archs=(arch,),
                           n_ticks=n_ticks, n_replicas=n_replicas,
                           seed=seed, codecs=CODEC_AXIS, trace=trace,
                           nominal_bw_bps=bw,
                           cloud_budget_bytes=MULTICUT_QUOTA_BYTES,
                           multicut=True,
                           down_bw_factor=MULTICUT_DOWN_FACTOR, **kw)

    return [
        ("micro_blind", run_fleet(cfg())),
        ("cont_blind", run_fleet(cfg(continuous=True))),
        ("cont_aware", run_fleet(cfg(continuous=True, queue_aware=True))),
        ("cont_tightkv", run_fleet(cfg(
            continuous=True, queue_aware=True,
            kv_budget_bytes=QUEUE_TIGHT_KV_BYTES))),
    ]


def bench_delta(n_robots: int = 16, n_ticks: int = 200,
                n_replicas: int = 3, seed: int = 0,
                arch: str = "openvla-7b", bw: float = QUEUE_BW_BPS,
                scenes=DELTA_SCENES):
    """Temporal-delta transport by scene class: the delta codec (priced
    for each scene's mean change fraction, ``DELTA_RESYNC`` key-frame
    cadence) vs plain int4 on the same constrained link.  Wire bytes
    are the fleet's MEASURED uplink bytes (``total_wire_bytes``), so
    the comparison captures content-dependence: static scenes ship
    mask-plus-few-rows deltas, dynamic scenes degrade to key frames.
    The delta rows run with the flight recorder on so the wire-bytes
    drift stage audits predicted (cycle-average) vs measured per-frame
    bytes.  Returns ``[(scene, label, FleetReport)]``."""
    from repro.core.codec import make_delta_codec
    from repro.core.scene import SCENES
    trace = TraceConfig(mean_bps=bw, bad_bps=max(bw / 4, 0.2e6))
    rows = []
    for scene in scenes:
        d = make_delta_codec(change_frac=SCENES[scene].mean_frac,
                             resync_every=DELTA_RESYNC, name="delta")
        for label, axis in (("delta", (d,)), ("int4", ("int4",))):
            cfg = FleetConfig(
                n_robots=n_robots, archs=(arch,), n_ticks=n_ticks,
                n_replicas=n_replicas, seed=seed, codecs=axis,
                trace=trace, nominal_bw_bps=bw, scene=scene,
                telemetry="full" if label == "delta" else "off")
            rows.append((scene, label, run_fleet(cfg)))
    return rows


def bench_scale(n_robots: int = SCALE_ROBOTS, n_ticks: int = SCALE_TICKS,
                n_replicas: int = SCALE_REPLICAS, seed: int = 7):
    """Event-engine scale run (``runtime/events.py``): chaos schedule plus
    an open-loop Poisson stream — the regime where the dense tick loop's
    every-robot-every-tick scan stops being viable and the p99/p99.9
    tail percentiles start meaning something.  Returns
    ``(FleetReport, wall_s, profile)`` where ``profile`` splits the wall
    into setup vs event loop, the setup further into plan tables /
    controllers / trace matrix (``FleetSimulator.profile``), and carries
    the accumulated chaos-replan wall (``replan_s``) separately."""
    from repro.runtime.events import EventEngine
    from repro.runtime.fleet import ArrivalProcess, FleetSimulator
    cfg = FleetConfig(
        n_robots=n_robots, n_ticks=n_ticks, n_replicas=n_replicas,
        batch_size=16, seed=seed, engine="events",
        arrival_processes=(ArrivalProcess("users",
                                          rate_hz=SCALE_ARRIVAL_HZ),))
    cfg.replica_events = outage_schedule(cfg)
    t0 = time.perf_counter()
    sim = FleetSimulator(cfg)
    t1 = time.perf_counter()
    rep = EventEngine(sim).run()
    t2 = time.perf_counter()
    prof = {"setup_s": t1 - t0, "loop_s": t2 - t1,
            "replan_s": sim.replan_wall_s, **sim.profile}
    return rep, t2 - t0, prof


def bench_scaling_curve(sizes=SCALE_CURVE_SIZES, n_ticks: int = SCALE_TICKS,
                        n_replicas: int = SCALE_REPLICAS, seed: int = 7):
    """The scale scenario at each fleet size, ascending, with peak-RSS
    sampled after each run (``ru_maxrss`` is a process high-water mark,
    so ascending order keeps the column attributable and monotone —
    ``tools/check_bench_schema.py`` asserts it).  Returns the
    ``scaling_curve`` payload entries."""
    import resource
    rows = []
    for n in sorted(sizes):
        rep, wall, prof = bench_scale(n, n_ticks, n_replicas, seed)
        rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
        rows.append({
            "n_robots": int(n), "n_ticks": int(n_ticks),
            "wall_s": wall, "peak_rss_bytes": int(rss),
            "setup_s": prof["setup_s"], "loop_s": prof["loop_s"],
            "replan_s": prof["replan_s"],
            "n_requests": rep.n_requests,
            "p999_s": rep.fleet_p999_s})
    return rows


def bench_autoscale(n_robots: int = 64, n_ticks: int = 600,
                    n_replicas: int = 6, seed: int = 11,
                    highs=AUTOSCALE_HIGH_S, cohorts=AUTOSCALE_COHORTS,
                    rate_hz: float = AUTOSCALE_ARRIVAL_HZ):
    """AutoScaler policy comparison: sweep the scale-up backlog watermark
    over an arrival mix of two regional cohorts riding different
    bandwidth regimes (per-process ``TraceConfig``).  All but two
    replicas start parked (tick-0 leave events), so the watermark alone
    decides how much capacity the load recruits; per-cohort outcomes
    come back through the report's ``ProcessStats``.  Returns
    ``[(high_s, FleetReport)]``."""
    from repro.runtime.fleet import ArrivalProcess, ReplicaEvent
    procs = tuple(ArrivalProcess(name, rate_hz=rate_hz, trace=tr)
                  for name, tr in cohorts)
    parked = tuple(ReplicaEvent(0, f"cloud{i}", "leave")
                   for i in range(2, n_replicas))
    rows = []
    for high in highs:
        cfg = FleetConfig(
            n_robots=n_robots, n_ticks=n_ticks, n_replicas=n_replicas,
            seed=seed, engine="events", arrival_processes=procs,
            replica_events=parked, autoscale=True,
            autoscale_high_s=high, autoscale_low_s=min(0.02, high / 4))
        rows.append((high, run_fleet(cfg)))
    return rows


def bench_overhead(n_robots: int = OVERHEAD_ROBOTS,
                   n_ticks: int = OVERHEAD_TICKS,
                   n_replicas: int = SCALE_REPLICAS, seed: int = 7,
                   repeats: int = OVERHEAD_REPEATS,
                   trace_path=TRACE_EXPORT_PATH):
    """Flight-recorder cost: the scale scenario (chaos schedule + open-loop
    arrivals) with telemetry off vs sampled vs full.  Wall is the event
    loop only (setup builds identical plan tables in every mode).  The
    three modes run INTERLEAVED within each round and the overhead
    ratio is taken pairwise inside a round, min over ``repeats`` — a
    per-mode min can't cancel slow machine drift (CPU frequency,
    thermal, a neighbour process), but back-to-back runs share it, so
    the within-round ratio is the robust estimator.  Asserts the three
    runs' reports are dataclass-identical modulo the ``metrics`` field
    — the recorder-off bit-identity guarantee, at benchmark scale —
    and exports the sampled run's Chrome trace to ``trace_path`` (None
    skips).  Returns ``(walls, ratios, reports, drift)``: ``walls`` is
    the per-mode min, ``ratios`` the min within-round sampled/off and
    full/off (noise-floored at 1), ``drift`` the full-mode audit
    summary.
    """
    import dataclasses as _dc
    from repro.runtime.events import EventEngine
    from repro.runtime.fleet import ArrivalProcess, FleetSimulator
    from repro.runtime.trace_export import export_chrome_trace
    modes = ("off", "sampled", "full")
    round_walls = []
    reports: Dict[str, FleetReport] = {}
    drift = None
    # warmup: one small untimed run so the first timed mode doesn't pay
    # one-time allocator / import / cache-fill costs alone
    wcfg = FleetConfig(n_robots=min(200, n_robots), n_ticks=50,
                       n_replicas=n_replicas, batch_size=16, seed=seed,
                       engine="events")
    EventEngine(FleetSimulator(wcfg)).run()
    for r in range(repeats):
        rw: Dict[str, float] = {}
        for mode in modes:
            cfg = FleetConfig(
                n_robots=n_robots, n_ticks=n_ticks,
                n_replicas=n_replicas, batch_size=16, seed=seed,
                engine="events", telemetry=mode,
                arrival_processes=(ArrivalProcess(
                    "users", rate_hz=SCALE_ARRIVAL_HZ),))
            cfg.replica_events = outage_schedule(cfg)
            sim = FleetSimulator(cfg)
            t0 = time.perf_counter()
            rep = EventEngine(sim).run()
            rw[mode] = time.perf_counter() - t0
            if r == 0:
                reports[mode] = rep
                if mode == "sampled" and trace_path:
                    export_chrome_trace(sim.recorder, trace_path)
                if mode == "full":
                    drift = rep.metrics["drift"]
        round_walls.append(rw)
    walls = {m: min(rw[m] for rw in round_walls) for m in modes}
    # noise floor: a mode landing (measurably) under its paired off run
    # is timing jitter, not negative overhead — clamp the ratio at 1
    ratios = {m: max(1.0, min(rw[m] / rw["off"] for rw in round_walls))
              for m in ("sampled", "full")}
    base = _dc.replace(reports["off"], metrics=None)
    for mode in ("sampled", "full"):
        assert _dc.replace(reports[mode], metrics=None) == base, (
            f"telemetry={mode} perturbed the simulation")
    return walls, ratios, reports, drift


def print_report(rep: FleetReport) -> None:
    print(f"\n{'robot':9s} {'arch':22s} {'n':>4s} {'p50 ms':>8s} "
          f"{'p95 ms':>8s} {'mean ms':>8s}")
    for r in rep.robots:
        print(f"{r.name:9s} {r.arch:22s} {r.n_requests:4d} "
              f"{r.p50_s * 1e3:8.1f} {r.p95_s * 1e3:8.1f} "
              f"{r.mean_s * 1e3:8.1f}")
    print(f"\nfleet: p50 {rep.fleet_p50_s * 1e3:.1f} ms  "
          f"p95 {rep.fleet_p95_s * 1e3:.1f} ms  "
          f"throughput {rep.throughput_rps:.1f} req/s  "
          f"({rep.n_requests} requests, {rep.n_hedged} hedges, "
          f"{rep.n_replans} replans, "
          f"{rep.n_outage_completions} outage completions)")


def run_with_json(quiet: bool = False, n_robots: int = 24,
                  n_ticks: int = 400, n_replicas: int = 3, seed: int = 0,
                  smoke: bool = False) -> Tuple[List[str], Dict]:
    """CSV lines for benchmarks/run.py plus the machine-readable payload
    written to ``BENCH_fleet.json`` (p95s per scenario, planner wall
    times) so the perf trajectory is tracked across PRs.  ``smoke=True``
    shrinks every axis to a seconds-scale CI invocation."""
    if smoke:
        n_robots, n_ticks, n_replicas = 6, 40, 2
    payload: Dict = {"schema_version": BENCH_SCHEMA_VERSION,
                     "planner": {}, "fleet": {}, "codecs": {},
                     "multicut": {}, "streamed": {}, "queue": {},
                     "delta": {},
                     "scale": {}, "scaling_curve": [], "autoscale": {},
                     "overhead": {}, "drift": {},
                     "config": {
                         "n_robots": n_robots, "n_ticks": n_ticks,
                         "n_replicas": n_replicas, "seed": seed,
                         "smoke": smoke}}
    pk = (2, 1) if smoke else (64, 3)
    scalar_s, vec_s, cells, mism = bench_planner(*pk)
    assert mism == 0, f"vectorized planner diverged on {mism} cells"
    jscalar_s, jvec_s, jcells, jmism = bench_planner_codecs(*pk)
    assert jmism == 0, f"codec-axis planner diverged on {jmism} cells"
    mscalar_s, mvec_s, mcells, mmism = bench_planner_multicut(
        2 if smoke else 8, 1)
    assert mmism == 0, f"multi-cut planner diverged on {mmism} cells"
    payload["planner"] = {
        "scalar_s": scalar_s, "vec_s": vec_s, "cells": cells,
        "codec_scalar_s": jscalar_s, "codec_vec_s": jvec_s,
        "codec_cells": jcells,
        "multicut_scalar_s": mscalar_s, "multicut_vec_s": mvec_s,
        "multicut_cells": mcells,
        "multicut_speedup": mscalar_s / mvec_s}
    lines = [
        f"fleet_plan_scalar,{scalar_s * 1e6:.0f},{cells}cells",
        f"fleet_plan_vec,{vec_s * 1e6:.0f},x{scalar_s / vec_s:.1f}",
        f"fleet_plan_codec_scalar,{jscalar_s * 1e6:.0f},{jcells}cells",
        f"fleet_plan_codec_vec,{jvec_s * 1e6:.0f},x{jscalar_s / jvec_s:.1f}",
        f"fleet_plan_multicut_scalar,{mscalar_s * 1e6:.0f},{mcells}cells",
        f"fleet_plan_multicut_vec,{mvec_s * 1e6:.0f},"
        f"x{mscalar_s / mvec_s:.1f}",
    ]
    t0 = time.perf_counter()
    rep = run_fleet(fleet_config(n_robots, n_ticks, n_replicas, seed))
    sim_wall = time.perf_counter() - t0
    payload["fleet"] = {
        "p50_s": rep.fleet_p50_s, "p95_s": rep.fleet_p95_s,
        "throughput_rps": rep.throughput_rps,
        "n_requests": rep.n_requests, "sim_wall_s": sim_wall}
    lines += [
        f"fleet_p50,{rep.fleet_p50_s * 1e6:.0f},{n_robots}robots",
        f"fleet_p95,{rep.fleet_p95_s * 1e6:.0f},{rep.n_hedged}hedges",
        f"fleet_throughput,{rep.throughput_rps * 1e3:.0f},req_per_ks",
        f"fleet_sim_wall,{sim_wall * 1e6:.0f},{rep.n_requests}reqs",
    ]
    codec_rows = bench_codecs(n_robots=8 if smoke else 16,
                              n_ticks=60 if smoke else 200,
                              n_replicas=n_replicas, seed=seed)
    for label, crep in codec_rows:
        lines.append(f"fleet_codec_{label}_p95,{crep.fleet_p95_s * 1e6:.0f},"
                     f"p50={crep.fleet_p50_s * 1e6:.0f}us")
        payload["codecs"][label] = {"p50_s": crep.fleet_p50_s,
                                    "p95_s": crep.fleet_p95_s,
                                    "throughput_rps": crep.throughput_rps}
    mc_rows = bench_multicut(n_robots=8 if smoke else 16,
                             n_ticks=60 if smoke else 200,
                             n_replicas=n_replicas, seed=seed)
    by_bw: Dict[float, Dict[str, FleetReport]] = {}
    for bw, mode, mrep in mc_rows:
        by_bw.setdefault(bw, {})[mode] = mrep
        tag = f"{bw / 1e6:g}MBs_{mode}"
        lines.append(f"fleet_multicut_{tag}_p95,"
                     f"{mrep.fleet_p95_s * 1e6:.0f},"
                     f"{mrep.n_multicut_requests}mc_reqs")
        payload["multicut"][tag] = {
            "p50_s": mrep.fleet_p50_s, "p95_s": mrep.fleet_p95_s,
            "n_multicut_requests": mrep.n_multicut_requests}
    st_rows = bench_streamed(n_robots=8 if smoke else 16,
                             n_ticks=60 if smoke else 200,
                             n_replicas=n_replicas, seed=seed,
                             seq_reports={bw: modes["multi"]
                                          for bw, modes in by_bw.items()})
    st_by_bw: Dict[float, Dict[str, FleetReport]] = {}
    for bw, mode, srep in st_rows:
        st_by_bw.setdefault(bw, {})[mode] = srep
        tag = f"{bw / 1e6:g}MBs_{mode}"
        lines.append(f"fleet_streamed_{tag}_p95,"
                     f"{srep.fleet_p95_s * 1e6:.0f},"
                     f"{srep.n_streamed_requests}st_reqs")
        payload["streamed"][tag] = {
            "p50_s": srep.fleet_p50_s, "p95_s": srep.fleet_p95_s,
            "n_streamed_requests": srep.n_streamed_requests,
            "n_chunk_reconfigs": srep.n_chunk_reconfigs,
            "mean_bubble_frac": srep.mean_bubble_frac}
    q_rows = bench_queue(n_robots=8 if smoke else 16,
                         n_ticks=60 if smoke else 200,
                         n_replicas=n_replicas, seed=seed)
    for label, qrep in q_rows:
        lines.append(f"fleet_queue_{label}_p95,"
                     f"{qrep.fleet_p95_s * 1e6:.0f},"
                     f"{qrep.n_preemptions}preempt")
        payload["queue"][label] = {
            "p50_s": qrep.fleet_p50_s, "p95_s": qrep.fleet_p95_s,
            "n_preemptions": qrep.n_preemptions,
            "mean_queue_delay_s": qrep.mean_queue_delay_s,
            "kv_high_watermark_bytes": qrep.kv_high_watermark_bytes}
    d_rows = bench_delta(n_robots=8 if smoke else 16,
                         n_ticks=60 if smoke else 200,
                         n_replicas=n_replicas, seed=seed)
    d_by_scene: Dict[str, Dict[str, FleetReport]] = {}
    for scene, label, drep in d_rows:
        d_by_scene.setdefault(scene, {})[label] = drep
    payload["delta"] = {"resync_every": DELTA_RESYNC,
                        "static_gate_ratio": DELTA_STATIC_GATE_RATIO,
                        "scenes": {}, "drift": {}}
    for scene, modes in d_by_scene.items():
        dr, i4 = modes["delta"], modes["int4"]
        dbps = dr.total_wire_bytes / max(1, dr.n_requests)
        ibps = i4.total_wire_bytes / max(1, i4.n_requests)
        frames = dr.n_keyframes + dr.n_delta_frames
        payload["delta"]["scenes"][scene] = {
            "delta_bytes_per_step": dbps,
            "int4_bytes_per_step": ibps,
            "ratio_vs_int4": ibps / dbps if dbps else 0.0,
            "keyframe_rate": dr.n_keyframes / max(1, frames),
            "n_keyframes": dr.n_keyframes,
            "n_delta_frames": dr.n_delta_frames}
        lines.append(f"fleet_delta_{scene}_bytes,{dbps:.0f},"
                     f"x{ibps / dbps if dbps else 0.0:.1f}_vs_int4")
    # wire-bytes drift row: the planner's cycle-average pricing vs the
    # measured per-frame bytes, from the static delta run's audit
    d_static = d_by_scene["static"]["delta"]
    wdrift = d_static.metrics["drift"]["stages"]["wire_bytes"]
    d_meas = d_static.total_wire_bytes / max(1, d_static.n_requests)
    d_rel = abs(wdrift["mean_err"]) / d_meas if d_meas else 0.0
    payload["delta"]["drift"] = {
        "n": wdrift["n"], "mean_err_bytes": wdrift["mean_err"],
        "p95_err_bytes": wdrift["p95_err"],
        "meas_mean_bytes": d_meas, "rel_err": d_rel,
        "rel_tol": DELTA_DRIFT_REL_TOL}
    assert d_rel <= DELTA_DRIFT_REL_TOL, (
        f"delta wire-bytes drift {d_rel:.3f} outside the "
        f"{DELTA_DRIFT_REL_TOL:g} tolerance")
    if not smoke:
        got = payload["delta"]["scenes"]["static"]["ratio_vs_int4"]
        assert got >= DELTA_STATIC_GATE_RATIO, (
            f"static-scene delta ratio x{got:.1f} under the "
            f"x{DELTA_STATIC_GATE_RATIO:g} gate")
    sc_robots = SCALE_SMOKE_ROBOTS if smoke else SCALE_ROBOTS
    sc_ticks = SCALE_SMOKE_TICKS if smoke else SCALE_TICKS
    srep_scale, sc_wall, sc_prof = bench_scale(sc_robots, sc_ticks)
    payload["scale"] = {
        "engine": "events",
        "n_robots": sc_robots, "n_ticks": sc_ticks,
        "wall_s": sc_wall,
        "p50_s": srep_scale.fleet_p50_s, "p95_s": srep_scale.fleet_p95_s,
        "p99_s": srep_scale.fleet_p99_s,
        "p999_s": srep_scale.fleet_p999_s,
        "n_requests": srep_scale.n_requests,
        "n_open_arrivals": srep_scale.n_open_arrivals,
        "throughput_rps": srep_scale.throughput_rps}
    lines += [
        f"fleet_scale_wall,{sc_wall * 1e6:.0f},{sc_robots}robots",
        f"fleet_scale_p999,{srep_scale.fleet_p999_s * 1e6:.0f},"
        f"{srep_scale.n_requests}reqs",
    ]
    curve_sizes = SCALE_CURVE_SMOKE_SIZES if smoke else SCALE_CURVE_SIZES
    curve = bench_scaling_curve(curve_sizes,
                                sc_ticks if smoke else SCALE_TICKS)
    payload["scaling_curve"] = curve
    for row in curve:
        lines.append(f"fleet_curve_{row['n_robots']}_wall,"
                     f"{row['wall_s'] * 1e6:.0f},"
                     f"rss{row['peak_rss_bytes'] // (1 << 20)}MB")
    if not smoke:
        assert curve[-1]["wall_s"] <= SCALE_100K_BUDGET_S, (
            f"100k run {curve[-1]['wall_s']:.1f}s blew the "
            f"{SCALE_100K_BUDGET_S:.0f}s budget")
    as_rows = bench_autoscale(n_robots=16 if smoke else 64,
                              n_ticks=80 if smoke else 600,
                              n_replicas=4 if smoke else 6)
    for high, arep in as_rows:
        tag = f"high_{high:g}"
        payload["autoscale"][tag] = {
            "high_s": high,
            "n_autoscale_events": arep.n_autoscale_events,
            "p50_s": arep.fleet_p50_s, "p95_s": arep.fleet_p95_s,
            "cohorts": {ps.name: {
                "p50_s": ps.p50_s, "p95_s": ps.p95_s,
                "n_arrivals": ps.n_arrivals,
                "n_rejected": ps.n_rejected}
                for ps in arep.processes}}
        lines.append(f"fleet_autoscale_{tag}_p95,"
                     f"{arep.fleet_p95_s * 1e6:.0f},"
                     f"{arep.n_autoscale_events}scale_events")
    ov_robots = OVERHEAD_SMOKE_ROBOTS if smoke else OVERHEAD_ROBOTS
    ov_ticks = OVERHEAD_SMOKE_TICKS if smoke else OVERHEAD_TICKS
    ov_budget = OVERHEAD_SMOKE_BUDGET_RATIO if smoke \
        else OVERHEAD_BUDGET_RATIO
    ov_walls, ov_ratios, ov_reports, drift = bench_overhead(
        ov_robots, ov_ticks, repeats=1 if smoke else OVERHEAD_REPEATS)
    sampled_ratio = ov_ratios["sampled"]
    full_ratio = ov_ratios["full"]
    assert sampled_ratio <= ov_budget, (
        f"sampled telemetry overhead x{sampled_ratio:.3f} blew the "
        f"x{ov_budget:g} budget")
    payload["overhead"] = {
        "n_robots": ov_robots, "n_ticks": ov_ticks,
        "off_wall_s": ov_walls["off"],
        "sampled_wall_s": ov_walls["sampled"],
        "full_wall_s": ov_walls["full"],
        "sampled_ratio": sampled_ratio, "full_ratio": full_ratio,
        "budget_ratio": ov_budget, "smoke": smoke,
        "n_recorded_sampled": ov_reports["sampled"].metrics["n_recorded"],
        "n_recorded_full": ov_reports["full"].metrics["n_recorded"]}
    payload["drift"] = drift
    lines += [
        f"fleet_tele_off_wall,{ov_walls['off'] * 1e6:.0f},"
        f"{ov_robots}robots",
        f"fleet_tele_sampled_wall,{ov_walls['sampled'] * 1e6:.0f},"
        f"x{sampled_ratio:.3f}",
        f"fleet_tele_full_wall,{ov_walls['full'] * 1e6:.0f},"
        f"x{full_ratio:.3f}",
    ]
    if not quiet:
        print(f"planner: scalar {scalar_s * 1e3:.1f} ms vs vectorized "
              f"{vec_s * 1e3:.2f} ms over {cells} (model × bandwidth) cells "
              f"-> x{scalar_s / vec_s:.1f}, identical splits")
        print(f"planner+codec axis: scalar {jscalar_s * 1e3:.1f} ms vs "
              f"vectorized {jvec_s * 1e3:.2f} ms over {jcells} "
              f"(model × bandwidth × codec) cells "
              f"-> x{jscalar_s / jvec_s:.1f}, identical (split, codec)")
        print(f"planner multi-cut: scalar {mscalar_s * 1e3:.1f} ms vs "
              f"vectorized {mvec_s * 1e3:.2f} ms over {mcells} "
              f"(model × S1 × S2 × bandwidth × codec) cells "
              f"-> x{mscalar_s / mvec_s:.1f}, identical (cuts, codec)")
        print_report(rep)
        print(f"sim wall time {sim_wall:.2f} s")
        print(f"\ncodec comparison at 2 MB/s mean bandwidth "
              f"({codec_rows[0][1].n_requests} reqs identity):")
        print(f"{'codec':9s} {'p50 ms':>8s} {'p95 ms':>8s} {'req/s':>7s} "
              f"{'switches':>8s}")
        for label, crep in codec_rows:
            print(f"{label:9s} {crep.fleet_p50_s * 1e3:8.1f} "
                  f"{crep.fleet_p95_s * 1e3:8.1f} "
                  f"{crep.throughput_rps:7.1f} {crep.n_codec_switches:8d}")
        print(f"\nsingle-cut vs multi-cut (openvla-7b, "
              f"{MULTICUT_QUOTA_BYTES / 1e9:.1f} GB/robot cloud quota, "
              f"{MULTICUT_DOWN_FACTOR:.0f}x downlink):")
        print(f"{'bw MB/s':>8s} {'single p95':>11s} {'multi p95':>10s} "
              f"{'delta':>8s} {'mc reqs':>8s}")
        for bw, modes in by_bw.items():
            s, m = modes["single"], modes["multi"]
            print(f"{bw / 1e6:8.1f} {s.fleet_p95_s * 1e3:9.1f}ms "
                  f"{m.fleet_p95_s * 1e3:8.1f}ms "
                  f"{(s.fleet_p95_s - m.fleet_p95_s) * 1e3:6.1f}ms "
                  f"{m.n_multicut_requests:8d}")
        print(f"\nsequential vs streamed chunk transport (openvla-7b "
              f"multi-cut fleet, per-tick trace-integrated uplinks):")
        print(f"{'bw MB/s':>8s} {'seq p95':>9s} {'stream p95':>11s} "
              f"{'delta':>8s} {'st reqs':>8s} {'reconf':>7s} "
              f"{'bubble':>7s}")
        for bw, modes in st_by_bw.items():
            q, s = modes["seq"], modes["stream"]
            print(f"{bw / 1e6:8.1f} {q.fleet_p95_s * 1e3:7.1f}ms "
                  f"{s.fleet_p95_s * 1e3:9.1f}ms "
                  f"{(q.fleet_p95_s - s.fleet_p95_s) * 1e3:6.1f}ms "
                  f"{s.n_streamed_requests:8d} {s.n_chunk_reconfigs:7d} "
                  f"{s.mean_bubble_frac:7.3f}")
        print(f"\ncontinuous batching + queue-aware planning (openvla-7b "
              f"multi-cut fleet at {QUEUE_BW_BPS / 1e6:g} MB/s):")
        print(f"{'mode':13s} {'p50 ms':>8s} {'p95 ms':>8s} {'reqs':>5s} "
              f"{'preempt':>8s} {'qdelay ms':>10s} {'kv hw MB':>9s}")
        for label, qrep in q_rows:
            print(f"{label:13s} {qrep.fleet_p50_s * 1e3:8.1f} "
                  f"{qrep.fleet_p95_s * 1e3:8.1f} {qrep.n_requests:5d} "
                  f"{qrep.n_preemptions:8d} "
                  f"{qrep.mean_queue_delay_s * 1e3:10.2f} "
                  f"{qrep.kv_high_watermark_bytes / 1e6:9.1f}")
        print(f"\ntemporal-delta transport by scene class (openvla-7b at "
              f"{QUEUE_BW_BPS / 1e6:g} MB/s, resync every "
              f"{DELTA_RESYNC} frames):")
        print(f"{'scene':9s} {'delta B/step':>13s} {'int4 B/step':>12s} "
              f"{'ratio':>6s} {'kf rate':>8s}")
        for scene in DELTA_SCENES:
            sc = payload["delta"]["scenes"][scene]
            print(f"{scene:9s} {sc['delta_bytes_per_step']:13.0f} "
                  f"{sc['int4_bytes_per_step']:12.0f} "
                  f"x{sc['ratio_vs_int4']:5.1f} "
                  f"{sc['keyframe_rate']:8.3f}")
        dd = payload["delta"]["drift"]
        print(f"  wire-bytes drift: {dd['n']} joined, mean err "
              f"{dd['mean_err_bytes']:.0f} B vs {dd['meas_mean_bytes']:.0f} "
              f"B/step measured (rel {dd['rel_err']:.3f}, "
              f"tol {dd['rel_tol']:g})")
        print(f"\nevent-engine scale run ({sc_robots} robots x "
              f"{sc_ticks} ticks, chaos + {SCALE_ARRIVAL_HZ:g} req/s "
              f"open-loop): wall {sc_wall:.1f} s, "
              f"{srep_scale.n_requests} closed-loop reqs + "
              f"{srep_scale.n_open_arrivals} arrivals, "
              f"p50 {srep_scale.fleet_p50_s * 1e3:.0f} ms, "
              f"p99 {srep_scale.fleet_p99_s * 1e3:.0f} ms, "
              f"p99.9 {srep_scale.fleet_p999_s * 1e3:.0f} ms")
        print(f"  setup {sc_prof['setup_s']:.1f} s "
              f"(plan {sc_prof['plan_s']:.1f} / ctl "
              f"{sc_prof['controller_s']:.1f} / trace "
              f"{sc_prof['trace_s']:.1f}), loop {sc_prof['loop_s']:.1f} s, "
              f"replans {sc_prof['replan_s']:.2f} s")
        print(f"\nscaling curve (vectorized events engine, chaos + "
              f"arrivals):")
        print(f"{'robots':>8s} {'wall s':>8s} {'setup s':>8s} "
              f"{'loop s':>8s} {'replan s':>9s} {'rss MB':>8s}")
        for row in curve:
            print(f"{row['n_robots']:8d} {row['wall_s']:8.1f} "
                  f"{row['setup_s']:8.1f} {row['loop_s']:8.1f} "
                  f"{row['replan_s']:9.2f} "
                  f"{row['peak_rss_bytes'] / (1 << 20):8.0f}")
        print(f"\nautoscale watermark sweep ({AUTOSCALE_ARRIVAL_HZ:g} "
              f"req/s per cohort, metro vs rural links):")
        print(f"{'high_s':>7s} {'events':>7s} {'fleet p95':>10s} "
              + "".join(f" {name + ' p95':>11s}"
                        for name, _ in AUTOSCALE_COHORTS))
        for high, arep in as_rows:
            by_name = {ps.name: ps for ps in arep.processes}
            print(f"{high:7.2f} {arep.n_autoscale_events:7d} "
                  f"{arep.fleet_p95_s * 1e3:8.1f}ms "
                  + "".join(f" {by_name[name].p95_s * 1e3:9.1f}ms"
                            for name, _ in AUTOSCALE_COHORTS))
        print(f"\ntelemetry overhead ({ov_robots} robots x {ov_ticks} "
              f"ticks, chaos + arrivals): off {ov_walls['off']:.2f} s, "
              f"sampled x{sampled_ratio:.3f}, full x{full_ratio:.3f} "
              f"(budget x{ov_budget:g}); sampled kept "
              f"{payload['overhead']['n_recorded_sampled']} / full "
              f"{payload['overhead']['n_recorded_full']} requests")
        print(f"\nplanner-vs-runtime drift ({drift['n_joined']} joined, "
              f"reconcile {drift['reconcile_max_abs_s']:.1e} s):")
        print(f"{'stage':12s} {'n':>6s} {'mean err':>12s} "
              f"{'p50 err':>12s} {'p95 err':>12s}")
        for k, st in drift["stages"].items():
            print(f"{k:12s} {st['n']:6d} {st['mean_err']:12.3e} "
                  f"{st['p50_err']:12.3e} {st['p95_err']:12.3e}")
    return lines, payload


def run(quiet: bool = False, n_robots: int = 24, n_ticks: int = 400,
        n_replicas: int = 3, seed: int = 0, smoke: bool = False
        ) -> List[str]:
    """CSV lines for benchmarks/run.py: name,us_per_call,derived."""
    return run_with_json(quiet=quiet, n_robots=n_robots, n_ticks=n_ticks,
                         n_replicas=n_replicas, seed=seed, smoke=smoke)[0]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--robots", type=int, default=24)
    ap.add_argument("--ticks", type=int, default=400)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI sizes")
    ap.add_argument("--csv", action="store_true",
                    help="emit only the CSV lines")
    ap.add_argument("--profile", action="store_true",
                    help="run only bench_scale and print its "
                         "setup/loop/replan wall split")
    args = ap.parse_args()
    if args.profile:
        rep, wall, prof = bench_scale(
            args.robots if args.robots != 24 else SCALE_ROBOTS,
            args.ticks if args.ticks != 400 else SCALE_TICKS)
        print(f"scale run: wall {wall:.2f} s "
              f"({rep.n_requests} reqs, {rep.n_open_arrivals} arrivals)")
        for k in ("setup_s", "plan_s", "controller_s", "trace_s",
                  "loop_s", "replan_s"):
            print(f"  {k:13s} {prof[k]:8.3f} s")
        return
    lines = run(quiet=args.csv, n_robots=args.robots, n_ticks=args.ticks,
                n_replicas=args.replicas, seed=args.seed, smoke=args.smoke)
    if args.csv:
        for ln in lines:
            print(ln)


if __name__ == "__main__":
    main()
