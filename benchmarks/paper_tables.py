"""Paper Tables II / III / IV reproduction.

Calibration (DESIGN.md §8): Table I peak specs + one efficiency factor per
device fitted to the paper's OWN edge-only / cloud-only rows (the paper's
"hardware performance data").  Everything else — the split, the latency
decomposition, the speedups — comes out of RoboECC's models.  Validated
claims: speedup bands 3.16-3.28x (Orin+A100) / 2.10-2.23x (Thor+A100),
RoboECC beating Fixed-Seg, and the Table IV ablation ordering.

Network model: VLA inference crosses the link once for the prompt/feature
transfer plus twice per autoregressive action token (activation over, token
id back) — OpenVLA's 7-token decode is what makes its network share large
(~120 ms in the paper) while CogACT's single-pass DiT is ~10 ms.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs import get_config
from repro.core import (Workload, build_graph, cut_bytes, evaluate_split,
                        fixed_split, fit_eta, layer_latency, search)
from repro.core.hardware import A100, ORIN, THOR, DeviceSpec

NOMINAL_BW = 10e6          # bytes/s (paper Fig. 3 "good network")
RTT = 0.0065               # per crossing

PAPER = {
    # (model, edge): {row: (cloud_ms, edge_ms, total_ms)}
    ("openvla", "orin"): {
        "edge_only": (0, 1119.4, 1119.4), "cloud_only": (151.2, 0, 151.2),
        "fixed": (87.9, 717.8, 923.3), "roboecc": (136.7, 94.5, 354.4),
        "budget_gb": 12.1},
    ("openvla", "thor"): {
        "edge_only": (0, 628.9, 628.9), "cloud_only": (151.2, 0, 151.2),
        "fixed": (89.5, 378.4, 587.2), "roboecc": (137.1, 51.3, 300.1),
        "budget_gb": 12.1},
    ("cogact", "orin"): {
        "edge_only": (0, 775.3, 775.3), "cloud_only": (111.4, 0, 111.4),
        "fixed": (46.9, 437.2, 572.5), "roboecc": (81.9, 143.2, 236.1),
        "budget_gb": 12.0},
    ("cogact", "thor"): {
        "edge_only": (0, 429.6, 429.6), "cloud_only": (111.4, 0, 111.4),
        "fixed": (47.2, 240.4, 375.4), "roboecc": (82.7, 105.7, 192.7),
        "budget_gb": 12.0},
}


def _workload(model: str) -> Workload:
    if model == "openvla":
        return Workload(s_new=17, decode_steps=7)
    return Workload(s_new=17, decode_steps=0)      # CogACT: DiT single pass


def _crossings(model: str) -> int:
    w = _workload(model)
    return 1 + 2 * w.decode_steps


def net_latency(graph, split, model: str, bw=NOMINAL_BW, rtt=RTT,
                input_bytes=0.0) -> float:
    wire = cut_bytes(graph, split, input_bytes)
    if wire == 0:
        return 0.0
    return wire / bw + rtt * _crossings(model)


@dataclasses.dataclass
class Row:
    method: str
    cloud_ms: float
    edge_ms: float
    net_ms: float
    total_ms: float
    cloud_load_gb: float
    edge_load_gb: float


def calibrated_devices(model: str, edge_name: str):
    cfg = get_config("openvla-7b" if model == "openvla" else "cogact-7b")
    w = _workload(model)
    g = build_graph(cfg, w)
    p = PAPER[(model, edge_name)]
    edge0 = ORIN if edge_name == "orin" else THOR
    edge = fit_eta(g, edge0, p["edge_only"][2] / 1e3)
    cloud = fit_eta(g, A100, p["cloud_only"][2] / 1e3)
    return cfg, g, edge, cloud


def table_rows(model: str, edge_name: str) -> Dict[str, Row]:
    cfg, g, edge, cloud = calibrated_devices(model, edge_name)
    w = _workload(model)
    p = PAPER[(model, edge_name)]
    budget = p["budget_gb"] * 1e9
    total_w = sum(c.weight_bytes for c in g)

    def row(method: str, split: int, net_on: bool = True) -> Row:
        e, c, _ = evaluate_split(g, split, edge, cloud, NOMINAL_BW)
        n = net_latency(g, split, model,
                        input_bytes=w.input_bytes) if net_on else 0.0
        if split == len(g):
            n = 0.0
        cl = sum(x.weight_bytes for x in g[split:])
        return Row(method, c * 1e3, e * 1e3, n * 1e3, (e + c + n) * 1e3,
                   cl / 1e9, (total_w - cl) / 1e9)

    n = len(g)
    seg = search(g, edge, cloud, NOMINAL_BW, cloud_budget_bytes=budget,
                 input_bytes=w.input_bytes)
    return {
        "edge_only": row("Edge-Only", n),
        "cloud_only": row("Cloud-Only", 0),
        "fixed": row("Fixed Seg", fixed_split(g)),
        "roboecc": row("RoboECC", seg.split),
    }


def run_table(model: str, quiet: bool = False):
    """Returns list of CSV lines 'name,us_per_call,derived'."""
    lines = []
    for edge_name in ("orin", "thor"):
        rows = table_rows(model, edge_name)
        p = PAPER[(model, edge_name)]
        speedup = rows["edge_only"].total_ms / rows["roboecc"].total_ms
        paper_speedup = p["edge_only"][2] / p["roboecc"][2]
        for key, r in rows.items():
            lines.append(
                f"table_{model}_{edge_name}_{key},{r.total_ms * 1e3:.0f},"
                f"edge={r.edge_ms:.1f}ms cloud={r.cloud_ms:.1f}ms "
                f"net={r.net_ms:.1f}ms cloud_load={r.cloud_load_gb:.1f}GB")
        lines.append(
            f"table_{model}_{edge_name}_speedup,{speedup * 1e6:.0f},"
            f"x{speedup:.2f} vs paper x{paper_speedup:.2f}")
        assert rows["roboecc"].total_ms < rows["fixed"].total_ms, \
            "RoboECC must beat Fixed-Seg"
        if not quiet:
            for ln in lines[-5:]:
                print("  " + ln)
    return lines


def run_ablation(quiet: bool = False):
    """Table IV: Edge-Only -> +Co-Aware Seg -> +Network-Aware Adjustment."""
    cfg, g, edge, cloud = calibrated_devices("openvla", "orin")
    w = _workload("openvla")
    budget = PAPER[("openvla", "orin")]["budget_gb"] * 1e9
    n = len(g)
    lines = []
    # row 1: edge only
    e1, _, _ = evaluate_split(g, n, edge, cloud, NOMINAL_BW)
    # row 2: + segmentation (static split, nominal bandwidth planning only)
    seg = search(g, edge, cloud, NOMINAL_BW, cloud_budget_bytes=budget,
                 input_bytes=w.input_bytes)
    e2, c2, _ = evaluate_split(g, seg.split, edge, cloud, NOMINAL_BW)
    # degraded network costs the static split dearly:
    bad_bw = 1.5e6
    n2 = net_latency(g, seg.split, "openvla", bw=bad_bw,
                     input_bytes=w.input_bytes)
    t2 = e2 + c2 + n2
    # row 3: + network-aware adjustment moves to the min-transfer pool layer
    from repro.core import build_pool, pool_transfer_profile
    import numpy as np
    pool = build_pool(g, seg.split, overhead_target=0.03)
    vols = pool_transfer_profile(g, pool)
    s3 = list(pool.splits())[int(np.argmin(vols))]
    e3, c3, _ = evaluate_split(g, s3, edge, cloud, NOMINAL_BW)
    n3 = net_latency(g, s3, "openvla", bw=bad_bw, input_bytes=w.input_bytes)
    t3 = e3 + c3 + n3
    rows = [("edge_only", 0.0, e1 * 1e3, e1 * 1e3),
            ("co_aware_seg", c2 * 1e3, e2 * 1e3, t2 * 1e3),
            ("net_aware_adjust", c3 * 1e3, e3 * 1e3, t3 * 1e3)]
    assert rows[1][3] < rows[0][3] > rows[2][3]
    assert rows[2][3] <= rows[1][3], "adjustment must not hurt"
    for name, c, e, t in rows:
        lines.append(f"table4_{name},{t * 1e3:.0f},"
                     f"cloud={c:.1f}ms edge={e:.1f}ms total={t:.1f}ms")
        if not quiet:
            print("  " + lines[-1])
    return lines
