"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call holds the headline
quantity scaled to integer microseconds where latency-like; see each
module's docstring for the derived column semantics).

The fleet bench additionally writes a machine-readable ``BENCH_fleet.json``
(p95s per scenario, planner wall times — see
``fleet_bench.run_with_json``) so the perf trajectory is tracked across
PRs; ``--json ''`` disables it, ``--smoke`` shrinks the fleet axes to a
seconds-scale CI invocation.

    PYTHONPATH=src python -m benchmarks.run [--only table2,fig3,...]
    PYTHONPATH=src python -m benchmarks.run --only fleet --smoke  # CI
"""
import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma list of bench names")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale sizes for the fleet bench (CI)")
    ap.add_argument("--json", default="BENCH_fleet.json",
                    help="path for the fleet bench JSON payload "
                         "('' disables)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import figures, fleet_bench, kernel_bench, paper_tables, roofline

    def fleet() -> list:
        lines, payload = fleet_bench.run_with_json(quiet=True,
                                                   smoke=args.smoke)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
        return lines

    benches = {
        "fleet": fleet,
        "table2": lambda: paper_tables.run_table("openvla", quiet=True),
        "table3": lambda: paper_tables.run_table("cogact", quiet=True),
        "table4": lambda: paper_tables.run_ablation(quiet=True),
        "fig2": lambda: figures.fig2_segmentation(quiet=True),
        "fig3": lambda: figures.fig3_drift(quiet=True),
        "fig6": lambda: figures.fig6_overhead(quiet=True),
        "fig7": lambda: figures.fig7_thresholds(quiet=True),
        "adjust": lambda: figures.adjustment_overhead_vs_gain(quiet=True),
        "kernels": lambda: kernel_bench.run(quiet=True),
        "roofline": lambda: roofline.run(quiet=True),
    }
    failed = []
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            for line in fn():
                print(line)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f"{name},-1,FAILED {e}")
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
