"""Scene-dynamics axis: seeded reproducibility, matrix/1-D bit identity,
and the named scene classes actually ordering static < slow < dynamic."""
import numpy as np
import pytest

from repro.core import (SCENES, SceneConfig, generate_scene_matrix,
                        generate_scene_trace, scene_config)


def test_trace_deterministic_and_bounded():
    cfg = SceneConfig()
    a = generate_scene_trace(500, cfg, seed=11)
    b = generate_scene_trace(500, cfg, seed=11)
    np.testing.assert_array_equal(a, b)
    assert (a >= cfg.floor_frac).all() and (a <= cfg.ceil_frac).all()
    assert not np.array_equal(a, generate_scene_trace(500, cfg, seed=12))
    assert generate_scene_trace(0, cfg).size == 0


def test_matrix_rows_bit_identical_to_1d():
    cfg = SCENES["slow"]
    seeds = [3, 7, 12345, 9]
    mat = generate_scene_matrix(200, cfg, seeds)
    assert mat.shape == (4, 200)
    for r, s in enumerate(seeds):
        np.testing.assert_array_equal(
            mat[r], generate_scene_trace(200, cfg, s))


def test_scene_classes_ordered():
    means = {k: float(generate_scene_trace(2000, c, seed=0).mean())
             for k, c in SCENES.items()}
    assert means["static"] < means["slow"] < means["dynamic"]
    assert means["static"] < 0.05          # delta-friendly
    assert means["dynamic"] > 0.6          # the honest negative


def test_scene_events_spike_to_event_frac():
    cfg = SceneConfig(mean_frac=0.01, event_prob=0.2, event_frac=1.0,
                      ar_sigma=0.01)
    tr = generate_scene_trace(400, cfg, seed=5)
    assert (tr == 1.0).any() and (tr < 0.1).any()


def test_scene_config_resolution():
    assert scene_config("static") is SCENES["static"]
    own = SceneConfig(mean_frac=0.4)
    assert scene_config(own) is own
    with pytest.raises(KeyError):
        scene_config("bustling")
