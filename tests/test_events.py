"""Event-engine internals: heap ordering properties, arrival-process
generation, engine state invariants (run with ``validate=True``, which
asserts nondecreasing pops, no robot acting while its request is in
flight, continuous-tier capacity at every service boundary, and no
request leaked past the horizon), and the 10k-robot scale run.

The heap/arrival properties run twice, following the repo's pattern
(``tests/test_scheduler.py``): property-based via ``hypothesis`` when the
optional dep is installed, and always as seeded numpy scenario sweeps
through the same checkers.
"""
import dataclasses
import time

import numpy as np
import pytest

from repro.core.network import TraceConfig
from repro.runtime.fleet import (ArrivalProcess, FleetConfig, FleetSimulator,
                                 ReplicaEvent, outage_schedule, run_fleet)
from repro.runtime.events import (EventEngine, EventHeap, PH_ARRIVAL,
                                  PH_POOL, PH_REPLICA, PH_ROBOT, PH_SCALE,
                                  PH_SERVICE, generate_arrivals)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ------------------------------------------------------------- EventHeap
def _check_heap_order(keys):
    """Pops come out sorted by (tick, phase, idx); equal keys pop in
    insertion order; push/pop counters conserve."""
    h = EventHeap(validate=True)
    for seq, (tick, phase, idx) in enumerate(keys):
        h.push(tick, phase, idx)
    out = []
    while len(h):
        out.append(h.pop())
    assert out == sorted(out)
    assert sorted(out) == sorted(tuple(k) for k in keys)
    assert h.n_pushed == h.n_popped == len(keys)


def _check_heap_fifo_ties(n):
    """Equal keys carry a strictly increasing seq tiebreak, so heap
    entries with identical (tick, phase, idx) never compare equal — the
    pop order of ties is the push order, deterministically."""
    h = EventHeap()
    for _ in range(n):
        h.push(5, PH_SERVICE, 0)
    seqs = [entry[3] for entry in h._h]
    assert len(set(seqs)) == n                # all distinct
    while len(h):
        assert h.pop() == (5, PH_SERVICE, 0)
    assert h.n_popped == n


_RNG_CASES = 40


def test_heap_order_seeded_sweep():
    rng = np.random.default_rng(1234)
    for _ in range(_RNG_CASES):
        n = int(rng.integers(0, 60))
        keys = [(int(rng.integers(0, 50)), int(rng.integers(0, 7)),
                 int(rng.integers(0, 20))) for _ in range(n)]
        _check_heap_order(keys)
        _check_heap_fifo_ties(int(rng.integers(1, 10)))


def test_heap_interleaved_push_pop_stays_ordered():
    """Pops interleaved with pushes of later keys never regress — the
    engine's actual access pattern (handlers push future events mid-drain)."""
    rng = np.random.default_rng(7)
    h = EventHeap(validate=True)
    for t in range(10):
        h.push(t, PH_ROBOT, 0)
    last = None
    while len(h):
        key = h.pop()                 # validate=True raises on regression
        if last is not None:
            assert key >= last
        last = key
        if rng.random() < 0.5:
            h.push(key[0] + int(rng.integers(0, 4)),
                   int(rng.integers(0, 7)), int(rng.integers(0, 5)))


def test_heap_validate_catches_phase_order():
    """The phase constants must keep the tick loop's section order —
    pinned so nobody reorders them without noticing."""
    assert (PH_REPLICA < PH_POOL < PH_ROBOT < PH_ARRIVAL
            < PH_SERVICE < PH_SCALE)


if HAVE_HYPOTHESIS:
    _key = st.tuples(st.integers(0, 50), st.integers(0, 6),
                     st.integers(0, 20))

    @settings(deadline=None)
    @given(st.lists(_key, max_size=60))
    def test_heap_order_property(keys):
        _check_heap_order(keys)


# ------------------------------------------------------ arrival processes
def _arrival_cfg(n_ticks=200, **kw):
    return FleetConfig(n_robots=2, n_ticks=n_ticks, tick_s=0.05, seed=5,
                       **kw)


def _check_arrivals(seed, rate, n_ticks):
    cfg = FleetConfig(n_robots=2, n_ticks=n_ticks, tick_s=0.05, seed=seed,
                      arrival_processes=(
                          ArrivalProcess("a", rate_hz=rate),
                          ArrivalProcess("b", kind="diurnal", rate_hz=rate,
                                         diurnal_amp=0.7,
                                         diurnal_period_s=3.0)))
    arr = generate_arrivals(cfg)
    horizon = cfg.n_ticks * cfg.tick_s
    assert arr == sorted(arr)                       # globally time-sorted
    assert all(0.0 <= t < horizon for t, _ in arr)
    assert arr == generate_arrivals(cfg)            # deterministic
    return arr


def test_arrival_generation_seeded_sweep():
    rng = np.random.default_rng(42)
    for _ in range(_RNG_CASES):
        _check_arrivals(int(rng.integers(0, 10_000)),
                        float(rng.uniform(0.5, 40.0)),
                        int(rng.integers(10, 300)))


def test_poisson_rate_is_roughly_right():
    cfg = _arrival_cfg(n_ticks=4000, arrival_processes=(
        ArrivalProcess("a", rate_hz=20.0),))
    n = len(generate_arrivals(cfg))
    expect = 20.0 * 4000 * 0.05
    assert 0.85 * expect < n < 1.15 * expect


def test_diurnal_thinning_tracks_intensity():
    """Arrivals in the sinusoid's peak half-period outnumber the trough's."""
    cfg = _arrival_cfg(n_ticks=4000, arrival_processes=(
        ArrivalProcess("d", kind="diurnal", rate_hz=10.0, diurnal_amp=0.9,
                       diurnal_period_s=200.0),))
    ts = np.asarray([t for t, _ in generate_arrivals(cfg)])
    phase = (ts % 200.0) / 200.0
    peak = int(((phase > 0.0) & (phase < 0.5)).sum())    # sin > 0 half
    trough = int(((phase >= 0.5) & (phase < 1.0)).sum())
    assert peak > 1.5 * trough


def test_unknown_arrival_kind_raises():
    cfg = _arrival_cfg(arrival_processes=(ArrivalProcess("x", kind="burst"),))
    with pytest.raises(ValueError):
        generate_arrivals(cfg)


# --------------------------------------------- engine invariants (validate)
def _validated_run(cfg):
    cfg = dataclasses.replace(cfg, engine="events")
    return EventEngine(FleetSimulator(cfg), validate=True).run()


def test_validated_engine_matches_plain_run():
    """validate=True adds assertions, never behavior: same report."""
    cfg = FleetConfig(n_robots=6, n_ticks=50, n_replicas=2,
                      archs=("openvla-7b",), batch_size=3,
                      trace=TraceConfig(mean_bps=1e6, bad_bps=2.5e5),
                      seed=9)
    cfg = dataclasses.replace(cfg,
                              replica_events=tuple(outage_schedule(cfg)))
    plain = run_fleet(dataclasses.replace(cfg, engine="events"))
    assert _validated_run(cfg) == plain


def test_request_conservation_closed_loop():
    """Every issued request completes exactly once: the report's request
    count equals the robots' latency series lengths, and the engine's
    internal pending map drains (asserted inside validate mode)."""
    cfg = FleetConfig(n_robots=10, n_ticks=80, n_replicas=2,
                      continuous=True, batch_size=4,
                      trace=TraceConfig(mean_bps=1e6, bad_bps=2.5e5),
                      seed=2)
    cfg = dataclasses.replace(cfg,
                              replica_events=tuple(outage_schedule(cfg)))
    rep = _validated_run(cfg)
    assert rep.n_requests == sum(r.n_requests for r in rep.robots)
    assert rep.n_requests > 0


def test_invariants_hold_under_chaos_sweep():
    """Seeded sweep of chaotic configs through the validated engine: the
    in-flight/capacity/conservation assertions must never fire."""
    rng = np.random.default_rng(77)
    for _ in range(8):
        cfg = FleetConfig(
            n_robots=int(rng.integers(2, 9)),
            n_ticks=int(rng.integers(30, 90)),
            n_replicas=int(rng.integers(1, 4)),
            continuous=bool(rng.integers(0, 2)),
            multicut=bool(rng.integers(0, 2)),
            batch_size=int(rng.integers(2, 6)),
            trace=TraceConfig(mean_bps=1e6, bad_bps=2.5e5),
            seed=int(rng.integers(0, 1000)))
        cfg = dataclasses.replace(
            cfg, replica_events=tuple(outage_schedule(cfg)))
        _validated_run(cfg)


def test_autoscale_scales_and_conserves():
    """Cold spares join under load and the run still conserves requests;
    the scaler's actions are counted."""
    spares = tuple(ReplicaEvent(0, f"cloud{i}", "leave") for i in (2, 3))
    cfg = FleetConfig(n_robots=48, n_ticks=300, n_replicas=4,
                      engine="events", autoscale=True, autoscale_every=25,
                      trace=TraceConfig(mean_bps=1e6, bad_bps=2.5e5),
                      replica_events=spares, seed=11)
    rep = _validated_run(cfg)
    assert rep.n_autoscale_events > 0
    assert rep.n_requests == sum(r.n_requests for r in rep.robots)


def test_slo_admission_rejects_under_pressure():
    """A near-zero SLO with a saturated cloud rejects open-loop arrivals
    to edge-only service; arrivals are conserved either way."""
    procs = (ArrivalProcess("users", rate_hz=40.0),)
    cfg = FleetConfig(n_robots=24, n_ticks=200, n_replicas=1,
                      engine="events", continuous=True, batch_size=4,
                      arrival_processes=procs, slo_s=1e-6,
                      trace=TraceConfig(mean_bps=1e6, bad_bps=2.5e5),
                      seed=4)
    rep = _validated_run(cfg)
    p = rep.processes[0]
    assert p.n_arrivals == p.n_completed        # rejected still completes
    assert rep.n_slo_rejections == p.n_rejected > 0
    relaxed = _validated_run(dataclasses.replace(cfg, slo_s=None))
    assert relaxed.n_slo_rejections == 0


def test_open_arrivals_complete_and_report_percentiles():
    procs = (ArrivalProcess("users", rate_hz=15.0),
             ArrivalProcess("shift", kind="diurnal", rate_hz=8.0,
                            diurnal_amp=0.8, diurnal_period_s=5.0))
    cfg = FleetConfig(n_robots=8, n_ticks=300, n_replicas=2,
                      engine="events", arrival_processes=procs, seed=6)
    rep = _validated_run(cfg)
    assert rep.n_open_arrivals == sum(p.n_arrivals for p in rep.processes)
    for p in rep.processes:
        assert p.n_completed == p.n_arrivals
        assert 0.0 < p.p50_s <= p.p95_s <= p.p99_s <= p.p999_s


# ------------------------------------- SoA state <-> object equivalence
def _assert_soa_matches_objects(sim):
    """Every struct-of-arrays mirror must agree with the object state it
    caches: pool bound arrays vs the controllers' Pool objects, the bulk
    trace matrix vs each robot's NetworkSim (views, not copies), the
    stacked batch plan tables (once built) vs the per-arch plan dicts,
    and the ``place_of`` compatibility view vs its backing arrays."""
    for i, ctl in enumerate(sim.controllers):
        p1, p2 = ctl.pool, getattr(ctl, "pool2", None)
        assert sim._pool_lo1[i] == p1.start
        assert sim._pool_hi1[i] == p1.end
        assert sim._pools1[i] is p1
        if p2 is not None:
            assert sim._has_pool2[i]
            assert sim._pool_lo2[i] == p2.start
            assert sim._pool_hi2[i] == p2.end
        else:
            assert not sim._has_pool2[i]
    for i, net in enumerate(sim.nets):
        assert np.shares_memory(net.trace, sim.trace_mat)
        assert np.array_equal(net.trace, sim.trace_mat[i])
    if sim._bst is not None:
        bst = sim._bst
        for j, a in enumerate(sim.graphs):
            assert np.array_equal(bst["s1"][j], np.asarray(sim.plan[a]))
            assert np.array_equal(bst["s2"][j], np.asarray(sim.plan_s2[a]))
            assert np.array_equal(bst["codec"][j],
                                  np.asarray(sim.plan_codec[a]))
            assert np.array_equal(bst["chunks"][j],
                                  np.asarray(sim.plan_chunks[a]))
            n = sim.arrays[a].n
            assert bst["n"][j] == n
            assert np.array_equal(bst["E"][j, :n + 1], sim.arrays[a].edge_s)
            assert np.array_equal(bst["C"][j, :n + 1],
                                  sim.arrays[a].cloud_s)
            assert np.array_equal(bst["W"][j, :n + 1],
                                  sim.arrays[a].wire_bytes)
    assert sim.place_of == list(zip(sim.place_s1.tolist(),
                                    sim.place_s2.tolist()))


def _check_soa_mid_run(seed, n_robots, n_ticks, continuous, multicut):
    """Run a chaotic vectorized fleet with the SoA<->object checker wired
    in front of every batched robot phase — so the equivalence is pinned
    MID-run, after replan waves have moved pools and plans, not just at
    construction — and once more after the run."""
    cfg = FleetConfig(n_robots=n_robots, n_ticks=n_ticks, n_replicas=2,
                      continuous=continuous, multicut=multicut,
                      engine="events",
                      trace=TraceConfig(mean_bps=1e6, bad_bps=2.5e5),
                      seed=seed)
    cfg = dataclasses.replace(cfg,
                              replica_events=tuple(outage_schedule(cfg)))
    sim = FleetSimulator(cfg)
    orig = sim._robot_step_batch
    calls = [0]

    def checked(idxs, tick, now, routable):
        calls[0] += 1
        _assert_soa_matches_objects(sim)
        return orig(idxs, tick, now, routable)

    sim._robot_step_batch = checked
    EventEngine(sim, validate=True).run()
    assert calls[0] > 0
    _assert_soa_matches_objects(sim)


def test_soa_object_equivalence_seeded_sweep():
    rng = np.random.default_rng(31)
    for _ in range(6):
        _check_soa_mid_run(int(rng.integers(0, 1000)),
                           int(rng.integers(3, 9)),
                           int(rng.integers(40, 90)),
                           bool(rng.integers(0, 2)),
                           bool(rng.integers(0, 2)))


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=10)
    @given(st.integers(0, 10_000), st.booleans(), st.booleans())
    def test_soa_object_equivalence_property(seed, continuous, multicut):
        _check_soa_mid_run(seed, 5, 50, continuous, multicut)


# ----------------------------------------------------------------- scale
@pytest.mark.slow
def test_scale_10k_robots_under_budget():
    """The PR-6 acceptance bar, re-tightened for the vectorized engine:
    10k robots x 2000 ticks, chaos schedule and open-loop traffic
    included, completes inside 30 s wall-clock (the batched robot phase
    does it in ~6 s; the old scalar bar was 60 s) and produces meaningful
    tail percentiles.  The 100k bar lives in the benchmark's scaling
    curve (``benchmarks/fleet_bench.py``), not the test suite."""
    procs = (ArrivalProcess("users", rate_hz=50.0),)
    cfg = FleetConfig(n_robots=10_000, n_ticks=2_000, n_replicas=6,
                      batch_size=16, engine="events",
                      arrival_processes=procs, seed=7)
    cfg = dataclasses.replace(cfg,
                              replica_events=tuple(outage_schedule(cfg)))
    t0 = time.time()
    rep = run_fleet(cfg)
    wall = time.time() - t0
    assert wall < 30.0, f"10k-robot run took {wall:.1f}s (budget 30s)"
    assert rep.n_requests > 10_000
    assert rep.fleet_p999_s >= rep.fleet_p99_s >= rep.fleet_p95_s > 0.0
    assert rep.processes[0].n_completed == rep.processes[0].n_arrivals
