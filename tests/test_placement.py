"""Multi-cut placements: plan algebra, oracle parity, K=1 equivalence,
multi-cut adjustment, controller integration, and the satellite
regressions (zero-byte transfers, frozen TraceConfig)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.core import (CODECS, NetworkSim, PlacementPlan, RoboECC,
                        Thresholds, TraceConfig, Workload, adjust,
                        adjust_placement, build_graph, build_pool,
                        downlink_bytes, evaluate_placement, evaluate_split,
                        generate_trace, graph_arrays, search,
                        search_multicut, search_multicut_scalar, search_vec,
                        sweep_multicut)
from repro.core.hardware import A100, ORIN

W = Workload()
BWS = np.geomspace(0.1e6, 40e6, 5)
AXIS = ("identity", "int8", "int4")
QUOTA = 5.8e9
DOWN = 8.0


@pytest.fixture(scope="module")
def graphs():
    return {k: build_graph(get_config(k), W) for k in sorted(ARCHS)}


# ------------------------------------------------------------- plan algebra
def test_plan_normalize_collapses_to_single():
    n = 10
    assert PlacementPlan.edge_cloud_edge(3, n).normalize(n) == \
        PlacementPlan.single(3)
    assert PlacementPlan.edge_cloud_edge(4, 4).normalize(n) == \
        PlacementPlan.single(n)
    assert PlacementPlan.single(n).normalize(n) == PlacementPlan.single(n)
    assert PlacementPlan.single(0).normalize(n) == PlacementPlan.single(0)
    ece = PlacementPlan.edge_cloud_edge(2, 7, "int8", "int4")
    assert ece.normalize(n) == ece
    # codec of the surviving cut is kept when a segment vanishes
    assert PlacementPlan.edge_cloud_edge(3, n, "int8", "int4") \
        .normalize(n).cut_codecs == ("int8",)


def test_plan_cut_accessors():
    n = 10
    p = PlacementPlan.edge_cloud_edge(2, 7)
    assert p.primary_cut(n) == 2 and p.tail_cut(n) == 7
    s = PlacementPlan.single(4)
    assert s.primary_cut(n) == 4 and s.tail_cut(n) == n
    assert PlacementPlan.single(n).primary_cut(n) == n
    assert PlacementPlan.single(0).primary_cut(n) == 0


def test_plan_validation():
    with pytest.raises(ValueError):
        PlacementPlan(cuts=(5, 3), tiers=("edge", "cloud", "edge"))
    with pytest.raises(ValueError):
        PlacementPlan(cuts=(3,), tiers=("edge",))
    with pytest.raises(ValueError):
        PlacementPlan(cuts=(3,), tiers=("edge", "mars"))


# -------------------------------------------------------- pricing equivalence
def test_evaluate_placement_k1_matches_evaluate_split(graphs):
    g = graphs["openvla-7b"]
    for s in (0, 1, 28, len(g) // 2, len(g)):
        for codec in (None, "int8"):
            ev = evaluate_placement(g, PlacementPlan.single(s, codec),
                                    ORIN, A100, 1e6, rtt_s=0.005,
                                    input_bytes=W.input_bytes)
            e, c, t = evaluate_split(g, s, ORIN, A100, 1e6, rtt_s=0.005,
                                     input_bytes=W.input_bytes,
                                     codec=CODECS[codec] if codec else None)
            assert ev.total_s == pytest.approx(e + c + t, rel=1e-12)
            assert ev.edge_s == pytest.approx(e, rel=1e-12)


def test_evaluate_placement_matches_arrays_placement_latency(graphs):
    g = graphs["cogact-7b"]
    n = len(g)
    ga = graph_arrays(g, ORIN, A100, input_bytes=W.input_bytes)
    for (s1, s2) in [(0, n), (28, 57), (40, 60), (10, 10), (n, n), (0, 30)]:
        for codec in (None, "int4"):
            if s2 >= n or s1 >= s2:
                plan = PlacementPlan.single(s1 if s2 >= n else n, codec)
            else:
                plan = PlacementPlan.edge_cloud_edge(s1, s2, codec, codec)
            ev = evaluate_placement(g, plan, ORIN, A100, 2e6, rtt_s=0.005,
                                    input_bytes=W.input_bytes,
                                    down_bw_factor=DOWN)
            e, c, up, dn = ga.placement_latency(
                s1, s2, 2e6, 0.005, codec=CODECS[codec] if codec else None,
                down_bw_factor=DOWN)
            assert ev.total_s == pytest.approx(e + c + up + dn, rel=1e-9)


def test_downlink_bytes_semantic_head_slice(graphs):
    """Action heads consume a small conditioning slice; mid-trunk cuts the
    full upstream activation."""
    g = graphs["openvla-7b"]
    cfg = get_config("openvla-7b")
    head_idx = len(g) - 1
    assert g[head_idx].kind == "head"
    assert downlink_bytes(g, head_idx) == \
        W.batch * cfg.action_dim * cfg.d_model * W.act_bytes
    assert downlink_bytes(g, head_idx) < g[head_idx - 1].out_transfer_bytes
    # mid-LLM: full activation (== uplink cut bytes)
    mid = 40
    assert downlink_bytes(g, mid) == g[mid - 1].out_transfer_bytes
    # CogACT DiT: single cognition token
    g2 = graphs["cogact-7b"]
    dit0 = next(i for i, c in enumerate(g2) if c.kind == "dit")
    assert downlink_bytes(g2, dit0) == W.batch * 1 * 4096 * W.act_bytes


# ----------------------------------------------------------- oracle parity
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_multicut_vectorized_matches_scalar_oracle_every_config(
        arch, graphs):
    """The vectorized (C, S1, S2, B) pass must return the identical
    (cuts, codec) plan to the exhaustive scalar oracle on every registered
    config — the multi-cut acceptance gate."""
    g = graphs[arch]
    for budget in (None, QUOTA):
        res = search_multicut(g, ORIN, A100, BWS, budget, codecs=AXIS,
                              rtt_s=0.005, input_bytes=W.input_bytes,
                              down_bw_factor=DOWN)
        for j, bw in enumerate(BWS):
            sc = search_multicut_scalar(
                g, ORIN, A100, float(bw), budget, codecs=AXIS, rtt_s=0.005,
                input_bytes=W.input_bytes, down_bw_factor=DOWN)
            assert res.plan_at(j) == sc.plan, (arch, budget, bw)
            assert res.total_s[j] == pytest.approx(sc.total_s, rel=1e-12)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_multicut_k1_restriction_reproduces_search_vec(arch, graphs):
    """Restricted to S2 = n (single_cut_only) the multi-cut pass must be
    split-identical to search/search_vec — K=1 is the exact special case."""
    g = graphs[arch]
    for budget in (None, 12.1e9):
        r1 = search_multicut(g, ORIN, A100, BWS, budget, codecs=None,
                             rtt_s=0.005, input_bytes=W.input_bytes,
                             single_cut_only=True)
        rv = search_vec(g, ORIN, A100, BWS, budget, rtt_s=0.005,
                        input_bytes=W.input_bytes)
        assert np.array_equal(r1.s1, rv.splits), (arch, budget)
        assert np.all(r1.s2 == len(g))
        np.testing.assert_allclose(r1.total_s, rv.total_s, rtol=1e-12)
        for j, bw in enumerate(BWS):
            seg = search(g, ORIN, A100, float(bw), cloud_budget_bytes=budget,
                         rtt_s=0.005, input_bytes=W.input_bytes)
            assert int(r1.s1[j]) == seg.split, (arch, budget, bw)


def test_sweep_multicut_matches_per_model(graphs):
    sw = sweep_multicut(graphs, ORIN, A100, BWS, QUOTA, codecs=AXIS,
                        rtt_s=0.005, input_bytes=W.input_bytes,
                        down_bw_factor=DOWN)
    for k, g in graphs.items():
        one = search_multicut(g, ORIN, A100, BWS, QUOTA, codecs=AXIS,
                              rtt_s=0.005, input_bytes=W.input_bytes,
                              down_bw_factor=DOWN)
        assert np.array_equal(sw[k].s1, one.s1), k
        assert np.array_equal(sw[k].s2, one.s2), k
        assert np.array_equal(sw[k].codec_idx, one.codec_idx), k
        np.testing.assert_allclose(sw[k].total_s, one.total_s, rtol=1e-12)


def test_multicut_budget_respected(graphs):
    g = graphs["openvla-7b"]
    ga = graph_arrays(g, ORIN, A100, input_bytes=W.input_bytes)
    res = search_multicut(g, ORIN, A100, BWS, QUOTA, codecs=AXIS,
                          rtt_s=0.005, input_bytes=W.input_bytes,
                          down_bw_factor=DOWN)
    for j in range(len(BWS)):
        load = ga.window_load_bytes(int(res.s1[j]), int(res.s2[j]))
        assert load <= QUOTA + 1e-6


def test_multicut_beats_single_cut_under_quota(graphs):
    """The tentpole win: on OpenVLA-7B under a per-robot cloud quota the
    best edge→cloud→edge placement strictly beats the best single cut at
    every operating point (incl. ≤ 1 MB/s) — keeping the byte-heavy
    detok head on the edge frees quota for one more trunk layer."""
    g = graphs["openvla-7b"]
    n = len(g)
    for bw in (10e6, 1e6, 0.2e6):
        multi = search_multicut_scalar(g, ORIN, A100, bw, QUOTA,
                                       codecs=AXIS, rtt_s=0.005,
                                       input_bytes=W.input_bytes,
                                       down_bw_factor=DOWN)
        single = search_multicut(g, ORIN, A100, [bw], QUOTA, codecs=AXIS,
                                 rtt_s=0.005, input_bytes=W.input_bytes,
                                 down_bw_factor=DOWN, single_cut_only=True)
        assert multi.plan.n_cuts == 2, (bw, multi.plan)
        assert int(multi.plan.cuts[1]) < n
        assert multi.total_s < float(single.total_s[0]) - 1e-9, bw


def test_multicut_collapses_when_tail_is_expensive(graphs):
    """CogACT's DiT is compute-dense per byte — putting it on the edge
    does not pay at low bandwidth, and the planner must honestly collapse
    to K=1 rather than force a second cut."""
    g = graphs["cogact-7b"]
    res = search_multicut_scalar(g, ORIN, A100, 0.2e6, QUOTA, codecs=AXIS,
                                 rtt_s=0.005, input_bytes=W.input_bytes,
                                 down_bw_factor=DOWN)
    assert res.plan.is_single


# ------------------------------------------------------- adjustment layer
def test_adjust_placement_k1_matches_adjust(graphs):
    """With no pool2 and a single-cut placement, adjust_placement must
    reproduce adjust's split decisions."""
    g = build_graph(get_config("cogact-7b"), Workload(decode_steps=0))
    first_dit = next(i for i, c in enumerate(g) if c.kind == "dit")
    pool = build_pool(g, first_dit)
    thr = Thresholds(high=2e6, low=-2e6)
    n = len(g)
    for pred, real in ((15e6, 10e6), (1e6, 10e6), (10.5e6, 10e6)):
        old = adjust(g, pool, first_dit, pred, real, thr)
        new = adjust_placement(g, pool, PlacementPlan.single(first_dit),
                               pred, real, thr)
        assert new.reason == old.reason
        assert new.placement.primary_cut(n) == old.split
    # tie-break parity on a UNIFORM trunk (every pool cut the same
    # volume): adjust's codec-free down move is argmin -> first/smallest
    # tied split, and adjust_placement must reproduce it exactly
    g2 = build_graph(get_config("openvla-7b"), Workload())
    n2 = len(g2)
    pool2 = build_pool(g2, 30)          # mid-LLM: all volumes equal
    for pred, real in ((1e6, 10e6), (15e6, 10e6)):
        old = adjust(g2, pool2, 30, pred, real, thr)
        new = adjust_placement(g2, pool2, PlacementPlan.single(30),
                               pred, real, thr)
        assert new.placement.primary_cut(n2) == old.split, (pred, real)


def test_adjust_placement_moves_either_cut(graphs):
    g = graphs["openvla-7b"]
    n = len(g)
    pool = build_pool(g, 43)
    pool2 = build_pool(g, 57)
    cur = PlacementPlan.edge_cloud_edge(43, 57, "int4", "int4")
    thr = Thresholds(high=2e6, low=-2e6)
    # predicted drop: joint transport argmin over (S1 × S2 × codec)
    dn = adjust_placement(g, pool, cur, 0.3e6, 10e6, thr, pool2=pool2,
                          codecs=AXIS, edge=ORIN, cloud=A100,
                          down_bw_factor=DOWN)
    assert dn.reason == "down"
    assert pool.contains(dn.placement.primary_cut(n))
    s2 = dn.placement.tail_cut(n)
    assert pool2.contains(s2) or s2 == n
    # predicted rise: exploit — max-volume cuts, lowest-error codec
    up = adjust_placement(g, pool, cur, 20e6, 10e6, thr, pool2=pool2,
                          codecs=AXIS, edge=ORIN, cloud=A100,
                          down_bw_factor=DOWN)
    assert up.reason == "up" and up.codec == "identity"
    hold = adjust_placement(g, pool, cur, 10.2e6, 10e6, thr, pool2=pool2,
                            codecs=AXIS)
    assert hold.reason == "hold" and hold.placement == cur.normalize(n)


def test_adjust_placement_overlapping_pools_keep_real_window(graphs):
    """Regression: with overlapping/touching pools, the zero-transport
    empty mid-graph window (s1 == s2 < n) must NOT win the down move
    (that would silently collapse the whole model onto the edge), and the
    up move must not shrink the cloud window to empty."""
    g = graphs["openvla-7b"]
    n = len(g)
    pool = build_pool(g, 20)
    pool2 = build_pool(g, 22)
    assert pool2.start <= pool.end              # pools genuinely overlap
    cur = PlacementPlan.edge_cloud_edge(20, 22)
    thr = Thresholds(high=2e6, low=-2e6)
    dn = adjust_placement(g, pool, cur, 1e6, 10e6, thr, pool2=pool2,
                          codecs=AXIS, edge=ORIN, cloud=A100,
                          down_bw_factor=DOWN)
    s1, s2 = dn.placement.primary_cut(n), dn.placement.tail_cut(n)
    assert s1 < s2, (s1, s2)                    # a real cloud window
    up = adjust_placement(g, pool, cur, 20e6, 10e6, thr, pool2=pool2,
                          codecs=AXIS, edge=ORIN, cloud=A100,
                          down_bw_factor=DOWN)
    u1, u2 = up.placement.primary_cut(n), up.placement.tail_cut(n)
    assert u1 < u2, (u1, u2)


def test_adjust_placement_collapse_to_k1(graphs):
    """When pool2 reaches the graph end, a predicted drop can pick S2 = n
    — no downlink leg at all — collapsing the placement back to K=1."""
    g = graphs["openvla-7b"]
    n = len(g)
    pool = build_pool(g, 43)
    pool2 = build_pool(g, n)        # wraps the edge-only end
    assert pool2.end == n
    cur = PlacementPlan.edge_cloud_edge(43, pool2.start, "int4", "int4")
    thr = Thresholds(high=2e6, low=-2e6)
    dn = adjust_placement(g, pool, cur, 0.1e6, 10e6, thr, pool2=pool2,
                          codecs=AXIS, edge=ORIN, cloud=A100,
                          down_bw_factor=DOWN)
    assert dn.reason == "down"
    assert dn.placement.tail_cut(n) == n      # collapsed: no second cut
    assert dn.placement.is_single


# ------------------------------------------------------------- controller
def test_controller_multicut_end_to_end():
    cfg = get_config("openvla-7b")
    ctl = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=QUOTA,
                  nominal_bw_bps=1e6, codec="int4",
                  adjust_codecs=["identity", "int8", "int4"],
                  multicut=True, down_bw_factor=DOWN)
    n = len(ctl.graph)
    assert not ctl.placement.is_single          # quota makes 2 cuts win
    assert ctl.pool.contains(ctl.split)
    assert ctl.pool2 is not None
    assert ctl.pool2.contains(ctl.placement.tail_cut(n))
    trace = generate_trace(1500, seed=1)
    ctl.fit_predictor(trace[:1000])
    net = NetworkSim(trace[1000:])
    net.step(40)
    res = [ctl.tick(net) for _ in range(20)]
    assert all(r.total_s > 0 for r in res)
    assert all(r.placement is not None for r in res)
    assert all(ctl.pool.contains(r.split) for r in res)


def test_controller_multicut_replan_outage_and_recovery():
    cfg = get_config("openvla-7b")
    ctl = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=QUOTA,
                  nominal_bw_bps=1e6, codec="int4",
                  multicut=True, down_bw_factor=DOWN)
    n = len(ctl.graph)
    plan0 = ctl.placement
    dead = A100.with_eta(1e-12, 1e-12)
    ctl.replan(cloud=dead, nominal_bw_bps=1e6)
    assert ctl.split == n and ctl.placement.is_single     # edge-only
    ctl.replan(cloud=A100, cloud_budget_bytes=QUOTA, nominal_bw_bps=1e6)
    assert ctl.placement == plan0                          # restored


def test_controller_single_mode_placement_is_k1():
    cfg = get_config("openvla-7b")
    ctl = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=12.1e9)
    assert ctl.placement == PlacementPlan.single(ctl.seg.split)
    assert ctl.pool2 is None


# ------------------------------------------------- satellite regressions
def test_zero_byte_transfer_is_free():
    """NetworkSim.transfer_s(0) must cost nothing — consistent with
    segmentation.net_time (edge-only splits ship nothing, so they pay
    neither wire time nor rtt)."""
    net = NetworkSim(np.full(4, 10e6), rtt_s=0.005)
    assert net.transfer_s(0) == 0.0
    assert net.transfer_s(0.0) == 0.0
    assert net.transfer_s(100e3) == pytest.approx(0.01 + 0.005)


def test_trace_config_frozen_and_no_shared_default():
    with pytest.raises(dataclasses.FrozenInstanceError):
        TraceConfig().mean_bps = 1.0
    # default argument is constructed per call, never a shared instance
    a = generate_trace(50, seed=3)
    b = generate_trace(50, seed=3)
    assert np.array_equal(a, b)
    assert np.array_equal(a, generate_trace(50, TraceConfig(), seed=3))
