"""Structure model (Eq.1) + hardware model (Eq.2) invariants."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import ASSIGNED, get_config
from repro.core import (Workload, build_graph, fit_eta, layer_latency,
                        roofline, stack_latency, total_flops,
                        total_weight_bytes)
from repro.core.hardware import A100, ORIN, THOR, TPU_V5E, DeviceSpec
from repro.core.structure import LayerCost


@pytest.mark.parametrize("arch", list(ASSIGNED) + ["openvla-7b", "cogact-7b"])
def test_graph_wellformed(arch):
    cfg = get_config(arch)
    g = build_graph(cfg, Workload())
    assert len(g) >= cfg.n_layers
    assert all(c.flops >= 0 for c in g)
    assert all(c.weight_bytes >= 0 for c in g)
    assert all(c.datamove_bytes > 0 for c in g)
    # weight bytes consistent with the config's analytic param count
    wb = total_weight_bytes(g)
    n = cfg.n_params() * Workload().wbytes
    assert 0.5 * n <= wb <= 1.3 * n


def test_dit_layers_carry_repeat():
    g = build_graph(get_config("cogact-7b"), Workload(decode_steps=0))
    dits = [c for c in g if c.kind == "dit"]
    assert len(dits) == 12
    assert all(c.repeat == 10 for c in dits)
    llm = [c for c in g if c.kind == "llm"]
    # a DiT layer is tiny by weights but repeated 10x in compute & transfer
    assert dits[0].weight_bytes < llm[0].weight_bytes
    assert dits[0].out_transfer_bytes > 0


def test_moe_graph_heterogeneity():
    g = build_graph(get_config("deepseek-v2-lite-16b"), Workload())
    kinds = [c.kind for c in g]
    assert "moe" in kinds and "llm" in kinds  # first dense layer vs moe


def test_eq2_roofline_shape():
    c = LayerCost("l", "llm", flops=1e12, weight_bytes=1e9,
                  datamove_bytes=1e9, out_transfer_bytes=1e5)
    t_orin = layer_latency(c, ORIN)
    # compute-bound on Orin at eta 0.3: 1e12/(275e12*0.3) vs 1e9/(204.8e9*0.6)
    assert t_orin == pytest.approx(max(1e12 / (275e12 * 0.3),
                                       1e9 / (204.8e9 * 0.6)))
    t_a100 = layer_latency(c, A100)
    assert t_a100 < t_orin


def test_fit_eta_hits_target():
    g = build_graph(get_config("openvla-7b"), Workload())
    dev = fit_eta(g, ORIN, target_s=1.1194)
    assert stack_latency(g, dev) == pytest.approx(1.1194, rel=1e-6)


def test_roofline_terms():
    t = roofline(hlo_flops=197e12 * 256, hlo_bytes=819e9 * 256,
                 collective_bytes=50e9 * 256, n_chips=256, dev=TPU_V5E)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)
    assert t.bound_s == 1.0


@given(st.floats(1e9, 1e15), st.floats(1e6, 1e12), st.floats(0, 1e12))
@settings(max_examples=30, deadline=None)
def test_roofline_dominant_consistent(f, b, c):
    t = roofline(f, b, c, 256, TPU_V5E)
    assert t.bound_s == max(t.compute_s, t.memory_s, t.collective_s)
    assert t.dominant in ("compute", "memory", "collective")


def test_decode_steps_increase_datamove():
    cfg = get_config("openvla-7b")
    g0 = build_graph(cfg, Workload(decode_steps=0))
    g7 = build_graph(cfg, Workload(decode_steps=7))
    llm0 = next(c for c in g0 if c.kind == "llm")
    llm7 = next(c for c in g7 if c.kind == "llm")
    assert llm7.datamove_bytes > 5 * llm0.datamove_bytes  # weight re-reads
