"""Alg. 1 properties (hypothesis): optimality vs brute force, budget, extremes."""
import dataclasses

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import (Workload, build_graph, cut_bytes, evaluate_split,
                        exhaustive_best, fixed_split, search)
from repro.core.hardware import A100, ORIN, DeviceSpec
from repro.core.structure import LayerCost


def _rand_graph(draw):
    n = draw(st.integers(2, 24))
    layers = []
    for i in range(n):
        flops = draw(st.floats(1e6, 1e12))
        wb = draw(st.floats(1e3, 1e9))
        tb = draw(st.floats(1e2, 1e7))
        layers.append(LayerCost(f"l{i}", "llm", flops, wb, wb + 1e4, tb))
    return layers


graphs = st.builds(lambda: None)


@st.composite
def graph_strategy(draw):
    return _rand_graph(draw)


@given(graph_strategy(), st.floats(0.1e6, 100e6),
       st.floats(0.05, 1.0))
@settings(max_examples=60, deadline=None)
def test_alg1_matches_exhaustive(graph, bw, budget_frac):
    total = sum(c.weight_bytes for c in graph)
    budget = budget_frac * total
    # guarantee feasibility: edge-only (split=n) has cloud load 0
    seg = search(graph, ORIN, A100, bw, cloud_budget_bytes=budget)
    best = exhaustive_best(graph, ORIN, A100, bw, cloud_budget_bytes=budget)
    e, c, t = evaluate_split(graph, best, ORIN, A100, bw)
    assert abs(seg.total_s - (e + c + t)) < 1e-9 * max(1.0, e + c + t), \
        f"alg1 split {seg.split} not optimal vs {best}"


@given(graph_strategy(), st.floats(0.1e6, 100e6))
@settings(max_examples=30, deadline=None)
def test_budget_respected(graph, bw):
    total = sum(c.weight_bytes for c in graph)
    budget = 0.3 * total
    seg = search(graph, ORIN, A100, bw, cloud_budget_bytes=budget)
    cloud_load = sum(c.weight_bytes for c in graph[seg.split:])
    assert cloud_load <= budget + 1e-6


@given(graph_strategy())
@settings(max_examples=20, deadline=None)
def test_extremes(graph):
    n = len(graph)
    e, c, t = evaluate_split(graph, n, ORIN, A100, 10e6)
    assert c == 0 and t == 0                      # edge-only
    e0, c0, t0 = evaluate_split(graph, 0, ORIN, A100, 10e6)
    assert e0 == 0 and t0 == 0                    # no input bytes configured


def test_faster_cloud_pulls_split_down():
    g = build_graph(get_config("openvla-7b"), Workload())
    fast = dataclasses.replace(A100, peak_flops=A100.peak_flops * 4,
                               hbm_bw=A100.hbm_bw * 4)
    s1 = search(g, ORIN, A100, 10e6).split
    s2 = search(g, ORIN, fast, 10e6).split
    assert s2 <= s1


def test_lower_bandwidth_pushes_more_to_one_side():
    g = build_graph(get_config("openvla-7b"), Workload())
    hi = search(g, ORIN, A100, 50e6)
    lo = search(g, ORIN, A100, 0.2e6)
    # at very low bandwidth the optimum avoids transfer-heavy middle cuts
    assert lo.net_s <= hi.net_s * 300  # sanity: search didn't explode
    assert lo.split in lo.feasible


def test_fixed_split_half_weights():
    g = build_graph(get_config("openvla-7b"), Workload())
    fs = fixed_split(g)
    left = sum(c.weight_bytes for c in g[:fs])
    total = sum(c.weight_bytes for c in g)
    assert 0.4 <= left / total <= 0.65


def test_fig3_transfer_volumes():
    """Paper Fig. 3: [1,17,3072] = 102KB and [1,17,768] = 25.5KB."""
    assert 17 * 3072 * 2 == 104448           # ~102 KB
    assert 17 * 768 * 2 == 26112             # ~25.5 KB
    cfg = get_config("llama3.2-3b")          # d_model = 3072
    g = build_graph(cfg, Workload(s_new=17, decode_steps=0))
    mid = len(g) // 2
    assert cut_bytes(g, mid) == 17 * 3072 * 2
