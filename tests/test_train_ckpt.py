"""Training loop, checkpointing, fault tolerance, error feedback."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step,
                                   load_checkpoint, restore_into,
                                   save_checkpoint)
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.models import build
from repro.runtime.fault import FaultPlan, Supervisor
from repro.train.compression import ef_compress
from repro.train.optimizer import OptConfig, clip_by_global_norm
from repro.train.train_loop import init_state, make_train_step


def test_loss_decreases_dense():
    cfg = get_config("llama3.2-3b").reduced().replace(n_layers=2)
    model = build(cfg)
    state = init_state(model.init(jax.random.PRNGKey(0)))
    step = jax.jit(make_train_step(model, OptConfig(lr=2e-3, warmup_steps=5)))
    stream = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                        global_batch=4))
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in stream.next().items()}
        state, m = step(state, batch, jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7


def test_microbatched_equals_full_batch():
    cfg = get_config("llama3.2-3b").reduced().replace(n_layers=2,
                                                      dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    s1 = init_state(params)
    s2 = init_state(params)
    opt = OptConfig(lr=1e-3, warmup_steps=1)
    f1 = jax.jit(make_train_step(model, opt, n_microbatches=1))
    f2 = jax.jit(make_train_step(model, opt, n_microbatches=2))
    stream = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                        global_batch=4))
    batch = {k: jnp.asarray(v) for k, v in stream.next().items()}
    s1, m1 = f1(s1, batch, jax.random.PRNGKey(0))
    s2, m2 = f2(s2, batch, jax.random.PRNGKey(0))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    # param updates agree up to f32 accumulation-order noise through Adam
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), s1.params, s2.params)
    assert max(jax.tree_util.tree_leaves(d)) < 5e-3


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(200.0)


def test_error_feedback_reduces_bias():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (64, 128)),
                          jnp.float32)}
    ef = jax.tree_util.tree_map(jnp.zeros_like, g)
    acc = jnp.zeros_like(g["w"])
    acc_plain = jnp.zeros_like(g["w"])
    from repro.train.compression import _dequant, _quant
    for _ in range(20):
        gq, ef = ef_compress(g, ef)
        acc = acc + gq["w"]
        q, s = _quant(g["w"])
        acc_plain = acc_plain + _dequant(q, s)
    err_ef = float(jnp.mean(jnp.abs(acc - 20 * g["w"])))
    err_plain = float(jnp.mean(jnp.abs(acc_plain - 20 * g["w"])))
    assert err_ef < err_plain


def test_checkpoint_roundtrip_bf16():
    tree = {"a": {"w": jnp.ones((3, 4), jnp.bfloat16) * 1.5,
                  "b": jnp.arange(5, dtype=jnp.int32)},
            "m": jnp.zeros((2, 2), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, tree, extra={"foo": 1})
        step, loaded, extra = load_checkpoint(d)
        assert step == 7 and extra == {"foo": 1}
        restored = restore_into(tree, loaded)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            assert a.dtype == b.dtype
            assert bool(jnp.all(a == b))


def test_checkpoint_retention_and_latest():
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(d, s, {"x": jnp.ones(1)}, keep=2)
        assert latest_step(d) == 5
        assert sorted(int(n.split("_")[1]) for n in os.listdir(d)
                      if n.startswith("step_")) == [4, 5]


def test_async_checkpointer():
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d)
        ck.save(3, {"x": jnp.ones((256, 256))})
        ck.wait()
        assert latest_step(d) == 3


def test_supervisor_restart_replays_data():
    cfg = get_config("llama3.2-3b").reduced().replace(n_layers=2)
    model = build(cfg)
    state = init_state(model.init(jax.random.PRNGKey(0)))
    step = jax.jit(make_train_step(model, OptConfig(lr=1e-3)))
    stream = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                        global_batch=2))
    with tempfile.TemporaryDirectory() as d:
        sup = Supervisor(d, ckpt_every=4)
        rep = sup.run(state, stream, step, 12,
                      key_fn=lambda s: jax.random.PRNGKey(s),
                      fault_plan=FaultPlan(fail_at=(6,)))
        assert rep.steps_done == 12 and rep.restarts == 1
