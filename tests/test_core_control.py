"""Pool, adjustment, predictor, network sim, controller, elasticity."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (NetworkSim, PredictorConfig, RoboECC, Thresholds,
                        TraceConfig, Workload, adjust, build_graph,
                        build_pool, calibrate_thresholds, check_granularity,
                        generate_trace, pool_transfer_profile, search,
                        train_predictor)
from repro.core.hardware import A100, ORIN


@pytest.fixture(scope="module")
def openvla_graph():
    return build_graph(get_config("openvla-7b"), Workload())


def test_pool_overhead_band(openvla_graph):
    seg = search(openvla_graph, ORIN, A100, 10e6, cloud_budget_bytes=12.1e9)
    pool = build_pool(openvla_graph, seg.split, overhead_target=0.03)
    assert pool.start <= seg.split <= pool.end
    assert 0 < pool.overhead_frac <= 0.035
    assert len(list(pool.splits())) >= 2


def test_pool_prefers_many_candidates():
    g = build_graph(get_config("cogact-7b"), Workload(decode_steps=0))
    # put the split right at the llm -> dit boundary
    first_dit = next(i for i, c in enumerate(g) if c.kind == "dit")
    pool = build_pool(g, first_dit, overhead_target=0.026)
    # greedy-cheapest growth must pick up several cheap DiT layers
    assert pool.end - pool.start >= 3
    vols = pool_transfer_profile(g, pool)
    assert max(vols) > min(vols)   # spans a structure transition


def test_adjust_directions(openvla_graph):
    g = build_graph(get_config("cogact-7b"), Workload(decode_steps=0))
    first_dit = next(i for i, c in enumerate(g) if c.kind == "dit")
    pool = build_pool(g, first_dit)
    thr = Thresholds(high=2e6, low=-2e6)
    vols = pool_transfer_profile(g, pool)
    splits = list(pool.splits())
    up = adjust(g, pool, first_dit, 15e6, 10e6, thr)
    dn = adjust(g, pool, first_dit, 1e6, 10e6, thr)
    hold = adjust(g, pool, first_dit, 10.5e6, 10e6, thr)
    assert up.reason == "up" and up.split == splits[int(np.argmax(vols))]
    assert dn.reason == "down" and dn.split == splits[int(np.argmin(vols))]
    assert hold.reason == "hold" and hold.split == first_dit


def test_calibrate_thresholds():
    rng = np.random.default_rng(0)
    deltas = rng.normal(0, 1e6, 500)

    def eval_fn(thr):
        # toy objective: prefer moderate thresholds
        return abs(thr.high - 1.2e6) + abs(thr.low + 0.8e6)

    thr = calibrate_thresholds(deltas, eval_fn)
    assert thr.low < 0 < thr.high


def test_trace_reproducible():
    a = generate_trace(500, seed=7)
    b = generate_trace(500, seed=7)
    c = generate_trace(500, seed=8)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.min() > 0


def test_network_sim_transfer():
    tr = np.full(10, 10e6)
    net = NetworkSim(tr, rtt_s=0.005)
    assert abs(net.transfer_s(100e3) - (0.01 + 0.005)) < 1e-9


def test_predictor_beats_trivial():
    trace = generate_trace(3000, TraceConfig(ar_sigma=0.05), seed=3)
    pred, losses = train_predictor(trace[:2500],
                                   PredictorConfig(epochs=150), seed=0)
    # single-batch losses are noisy anchors; compare 10-epoch means so the
    # convergence check doesn't hinge on one lucky/unlucky first batch
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.8
    # one-step predictions should be in a sane band
    w = pred.cfg.window
    errs, base = [], []
    for t in range(2500, 2600):
        p = pred.predict(trace[t - w:t])
        errs.append(abs(p - trace[t]))
        base.append(abs(trace[t - 1] - trace[t]))
    assert np.median(errs) < 3 * np.median(base) + 1e5


def test_granularity_check():
    assert check_granularity(0.05, 0.137, 0.094)
    assert not check_granularity(0.2, 0.137, 0.094)


def test_controller_end_to_end():
    cfg = get_config("openvla-7b")
    ctl = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=12.1e9)
    trace = generate_trace(1500, seed=1)
    ctl.fit_predictor(trace[:1000], PredictorConfig(epochs=60))
    net = NetworkSim(trace[1000:])
    net.step(40)
    res = [ctl.tick(net) for _ in range(30)]
    assert all(r.total_s > 0 for r in res)
    assert all(ctl.pool.contains(r.split) for r in res)
    # warm adjustment decisions are fast (paper: 10.7ms on their host)
    warm = [r.adjust_overhead_s for r in res[5:]]
    assert np.mean(warm) < 0.25


def test_elastic_replan_cloud_only():
    cfg = get_config("openvla-7b")
    ctl = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=12.1e9)
    assert ctl.split > 0
    # edge tier dies: model a dead edge as ~zero compute capability
    dead = ORIN.with_eta(1e-9, 1e-9)
    seg = ctl.replan(edge=dead)
    assert seg.split == 0          # cloud-only fallback


def test_elastic_pool_heartbeat_drives_replan_cycle():
    """ElasticPool heartbeat timeout -> on_change -> RoboECC.replan():
    losing the edge tier degrades to cloud-only (split=0); its re-join
    re-runs Alg. 1 and restores the original collaborative split."""
    from repro.runtime.scheduler import ElasticPool

    cfg = get_config("openvla-7b")
    ctl = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=12.1e9)
    s0, pool0 = ctl.split, ctl.pool
    assert s0 > 0
    dead_edge = ORIN.with_eta(1e-9, 1e-9)
    replans = []

    def on_change(live):
        if "edge" in live:
            seg = ctl.replan(edge=ORIN, cloud_budget_bytes=12.1e9)
        else:
            # cloud-only fallback must host the whole model: lift the budget
            seg = ctl.replan(edge=dead_edge)
        replans.append(seg.split)

    pool = ElasticPool(on_change=on_change, timeout_s=1.0)
    pool.heartbeat("edge", 0.0)
    pool.heartbeat("cloud", 0.0)
    assert pool.live(0.5) == ["cloud", "edge"]

    pool.heartbeat("cloud", 2.0)          # edge silent past the timeout
    assert pool.live(2.0) == ["cloud"]
    assert ctl.split == 0                 # degraded to cloud-only
    assert ctl.pool.contains(0)

    pool.heartbeat("edge", 2.5)           # edge re-joins
    assert pool.live(2.5) == ["cloud", "edge"]
    assert ctl.split == s0                # Alg. 1 re-ran and restored plan
    assert (ctl.pool.start, ctl.pool.end) == (pool0.start, pool0.end)
    # on_change fired for join, loss, re-join (initial join included)
    assert replans[-2:] == [0, s0]
