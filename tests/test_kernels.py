"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode,
plus hypothesis properties for the activation codec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.activation_codec import ops as codec_ops, ref as codec_ref
from repro.kernels.decode_attention import ops as da_ops, ref as da_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 128, 2, 2, 32),      # MHA
    (2, 256, 4, 2, 64),      # GQA 2x
    (1, 384, 8, 2, 32),      # GQA 4x, non-pow2 seq blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KV, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    ref = fa_ref.attention(q, k, v, causal=True)
    out = fa_ops.flash_attention(q, k, v, causal=True, impl="interpret",
                                 bq=128, bk=128)
    tol = 5e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 128, 2, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    ref = fa_ref.attention(q, k, v, causal=False)
    out = fa_ops.flash_attention(q, k, v, causal=False, impl="interpret",
                                 bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-6)


# ------------------------------------------------------------- decode attn
@pytest.mark.parametrize("kv_len", [1, 7, 100, 256])
@pytest.mark.parametrize("B,H,KV,T,D", [(2, 4, 2, 256, 32), (1, 8, 8, 512, 64)])
def test_decode_attention_sweep(B, H, KV, T, D, kv_len):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, KV, T, D))
    v = jax.random.normal(ks[2], (B, KV, T, D))
    ref = da_ref.decode_attention(q, k, v, kv_len)
    out = da_ops.decode_attention(q, k, v, jnp.int32(kv_len),
                                  impl="interpret", bk=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_decode_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 4, 2, 32), jnp.bfloat16)[:, 0]
    k = jax.random.normal(ks[1], (2, 2, 256, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 2, 256, 32), jnp.bfloat16)
    ref = da_ref.decode_attention(q, k, v, 200)
    out = da_ops.decode_attention(q, k, v, jnp.int32(200), impl="interpret",
                                  bk=128)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2)


# ---------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (2, 128, 3, 16, 32, 32),
    (1, 256, 2, 32, 16, 64),
    (1, 64, 1, 8, 8, 64),       # T == chunk
])
def test_ssd_scan_sweep(B, T, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (B, T, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, T, N)) * 0.3
    y_ref, s_ref = ssd_ref.ssd(x, dt, A, Bm, Cm, chunk)
    y, s = ssd_ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, impl="interpret")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=2e-5)


def test_ssd_state_equals_sequential():
    """Chunked kernel state must match a literal per-token recurrence."""
    from repro.models.ssm import ssd_step
    B, T, H, P, N = 1, 48, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (B, T, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, T, N)) * 0.3
    y_k, s_k = ssd_ops.ssd_scan(x, dt, A, Bm, Cm, chunk=16, impl="interpret")
    S = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(T):
        y, S = ssd_step(S, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(y)
    y_seq = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(S), atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_seq), atol=1e-4)


# ------------------------------------------------------------------- codec
@pytest.mark.parametrize("shape", [(4, 128), (256, 384), (2, 17, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_codec_roundtrip_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(6), shape, dtype)
    q, s = codec_ops.quantize(x)
    back = codec_ops.dequantize(q, s, dtype)
    amax = float(jnp.max(jnp.abs(x.astype(jnp.float32))))
    err = float(jnp.max(jnp.abs(back.astype(jnp.float32)
                                - x.astype(jnp.float32))))
    assert err <= amax / 127.0 + 1e-2 * amax


def test_codec_pallas_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(7), (256, 384), jnp.bfloat16)
    qi, si = codec_ops.quantize(x, impl="interpret")
    qr, sr = codec_ref.quantize_int8(x)
    assert bool(jnp.all(qi == qr))
    np.testing.assert_allclose(np.asarray(si), np.asarray(sr))
    di = codec_ops.dequantize(qi, si, impl="interpret")
    dr = codec_ref.dequantize_int8(qr, sr)
    assert bool(jnp.all(di == dr))


@given(st.integers(1, 8), st.integers(1, 4), st.floats(0.01, 100.0))
@settings(max_examples=20, deadline=None)
def test_codec_error_bound_property(rows, blocks, scale):
    x = (jax.random.normal(jax.random.PRNGKey(rows * 7 + blocks),
                           (rows, blocks * 128)) * scale).astype(jnp.float32)
    q, s = codec_ref.quantize_int8(x)
    back = codec_ref.dequantize_int8(q, s, jnp.float32)
    xb = np.asarray(x).reshape(rows, blocks, 128)
    bb = np.asarray(back).reshape(rows, blocks, 128)
    amax = np.abs(xb).max(-1, keepdims=True)
    assert np.all(np.abs(bb - xb) <= amax / 127.0 * 1.01 + 1e-7)


def test_codec_wire_bytes():
    assert codec_ref.wire_bytes((1, 17, 3072)) == 17 * 3072 + 17 * 24 * 4
