"""Direct unit tests for runtime/scheduler.py primitives — previously
exercised only indirectly through the fleet simulator: StragglerMitigator
hedge firing + p95 bookkeeping, ElasticPool join/leave → replan
callbacks, MicroBatcher deadline semantics, LatencyStats windows, and the
``ContinuousBatcher`` event loop.

The continuous-batching invariants (conservation, KV watermark, FIFO
no-starvation) run twice: as property-based ``hypothesis`` tests when the
optional dep is installed, and always as seeded numpy-random scenario
sweeps through the same checkers — CI gets the generative coverage, a
bare container still exercises every invariant."""
import numpy as np
import pytest

from repro.runtime.scheduler import (Batch, ContinuousBatcher, ElasticPool,
                                     LatencyStats, MicroBatcher, Request,
                                     StragglerMitigator)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ------------------------------------------------------------ LatencyStats
def test_latency_stats_p95_and_ewma():
    st = LatencyStats(alpha=0.5, window=200)
    assert st.p95() == float("inf")          # no samples yet: never hedge
    for v in range(1, 101):
        st.observe(float(v))
    # sorted[min(n-1, int(.95*n))] with n=100 -> index 95 -> value 96
    assert st.p95() == 96.0
    assert st.mean is not None and 50.0 < st.mean < 101.0


def test_latency_stats_sliding_window_forgets():
    st = LatencyStats(window=4)
    for v in (10.0, 10.0, 10.0, 10.0, 1.0, 1.0, 1.0, 1.0):
        st.observe(v)
    assert st.p95() == 1.0                   # old regime fully evicted


# ------------------------------------------------------- StragglerMitigator
def _seed(mit, replica, value, n=20):
    for _ in range(n):
        mit.stats[replica].observe(value)


def test_pick_primary_prefers_lowest_mean_and_unknowns():
    mit = StragglerMitigator()
    _seed(mit, "slow", 2.0)
    _seed(mit, "fast", 1.0)
    assert mit.pick_primary(["slow", "fast"]) == "fast"
    # an unobserved replica counts as mean 0 — it gets probed first
    assert mit.pick_primary(["slow", "fast", "new"]) == "new"


def test_hedge_fires_past_p95_and_backup_can_win():
    mit = StragglerMitigator()
    _seed(mit, "a", 1.0)
    _seed(mit, "b", 2.0)
    calls = []

    def exec_fn(r):
        calls.append(r)
        return 10.0 if r == "a" else 0.5

    out = mit.run(["a", "b"], exec_fn)
    assert calls == ["a", "b"]               # hedge actually launched
    assert out.hedged and out.replica == "a" and out.winner == "b"
    # hedge fires AT the primary's p95 deadline: latency = deadline + backup
    assert out.latency_s == pytest.approx(1.0 + 0.5)


def test_hedge_does_not_fire_under_deadline():
    mit = StragglerMitigator()
    _seed(mit, "a", 1.0)
    _seed(mit, "b", 1.0)
    out = mit.run(["a", "b"], lambda r: 0.9)
    assert not out.hedged and out.winner == "a"
    assert out.latency_s == pytest.approx(0.9)


def test_hedge_primary_still_wins_when_backup_slower():
    mit = StragglerMitigator()
    _seed(mit, "a", 1.0)
    _seed(mit, "b", 1.0)
    out = mit.run(["a", "b"], lambda r: 1.5 if r == "a" else 3.0)
    assert out.hedged and out.winner == "a"  # deadline + 3.0 > 1.5
    assert out.latency_s == pytest.approx(1.5)


def test_single_replica_never_hedges():
    mit = StragglerMitigator()
    _seed(mit, "a", 1.0)
    out = mit.run(["a"], lambda r: 50.0)
    assert not out.hedged and out.latency_s == 50.0


def test_run_updates_primary_p95():
    """Every run feeds the primary's observed latency back into its
    stats — a straggling replica's deadline adapts upward."""
    mit = StragglerMitigator()
    _seed(mit, "a", 1.0, n=4)
    before = mit.stats["a"].p95()
    for _ in range(30):
        mit.run(["a"], lambda r: 5.0)
    assert mit.stats["a"].p95() > before
    assert mit.stats["a"].mean > 1.0


# ------------------------------------------------------------- ElasticPool
def test_elastic_pool_join_leave_fires_replan_callbacks():
    seen = []
    pool = ElasticPool(on_change=seen.append, timeout_s=1.0)
    pool.heartbeat("r0", 0.0)
    pool.heartbeat("r1", 0.0)
    assert seen == [["r0"], ["r0", "r1"]]    # each join is a transition
    # r1 goes silent past the timeout -> leave event on next refresh
    pool.heartbeat("r0", 2.0)
    assert seen[-1] == ["r0"]
    assert pool.live(2.0) == ["r0"]
    # r1 re-joins -> replan callback with the restored set
    pool.heartbeat("r1", 2.5)
    assert seen[-1] == ["r0", "r1"]


def test_elastic_pool_full_outage_and_recovery():
    seen = []
    pool = ElasticPool(on_change=seen.append, timeout_s=0.5)
    pool.heartbeat("r0", 0.0)
    assert pool.live(10.0) == []             # timed out -> full outage
    assert seen[-1] == []
    pool.heartbeat("r0", 10.1)
    assert seen[-1] == ["r0"]


def test_elastic_pool_no_callback_without_transition():
    seen = []
    pool = ElasticPool(on_change=seen.append, timeout_s=1.0)
    pool.heartbeat("r0", 0.0)
    pool.heartbeat("r0", 0.1)
    pool.heartbeat("r0", 0.2)
    assert seen == [["r0"]]                  # steady state stays silent


# ------------------------------------------------------------- MicroBatcher
def test_microbatcher_deadline_forms_partial_batch():
    mb = MicroBatcher(batch_size=8, max_wait_s=0.02)
    mb.add(Request(0, 0.0, 1))
    mb.add(Request(1, 0.005, 1))
    assert mb.maybe_form(0.01) is None       # young queue, under size
    b = mb.maybe_form(0.025)                 # oldest aged past deadline
    assert isinstance(b, Batch) and len(b.requests) == 2
    assert mb.maybe_form(0.03) is None       # drained


def test_microbatcher_size_trigger_before_deadline():
    mb = MicroBatcher(batch_size=2, max_wait_s=10.0)
    mb.add(Request(0, 0.0, 1))
    mb.add(Request(1, 0.0, 1))
    mb.add(Request(2, 0.0, 1))
    b = mb.maybe_form(0.001)
    assert b is not None and [r.rid for r in b.requests] == [0, 1]
    assert len(mb.queue) == 1                # remainder rides the next one


def test_hedge_observes_backup_latency():
    """Regression: the hedge used to discard the backup's own execution
    time, so a hedged-to replica never accumulated stats and every later
    hedge target was chosen on no data."""
    mit = StragglerMitigator()
    _seed(mit, "a", 1.0)
    _seed(mit, "b", 2.0, n=1)
    out = mit.run(["a", "b"], lambda r: 10.0 if r == "a" else 0.5)
    assert out.hedged and out.winner == "b"
    # the backup's service time is now a real observation
    assert len(mit.stats["b"].samples) == 2
    assert mit.stats["b"].mean == pytest.approx(0.8 * 2.0 + 0.2 * 0.5)
    # and the next primary pick is made on measured data: a's EWMA moved
    # to 0.8 * 1.0 + 0.2 * 10.0 = 2.8, b's down to 1.65
    assert mit.stats["a"].mean == pytest.approx(2.8)
    assert mit.pick_primary(["a", "b"]) == "b"


# -------------------------------------------------------- ContinuousBatcher
def test_continuous_admits_and_completes_in_batch():
    """3 same-instant requests, 2 slots, overlap 0.8: the first pair runs
    as a 2-batch (eff = 1.2 → both finish at 1.2 s), the third is
    admitted on the first free slot and finishes 1 s later."""
    cb = ContinuousBatcher(2, 1e9, batch_overlap=0.8)
    for rid in range(3):
        cb.add(Request(rid, 0.0, 1), 1.0, 1e6)
    done = cb.step(None)
    fins = {req.rid: fin for req, fin in done}
    assert fins[0] == pytest.approx(1.2)
    assert fins[1] == pytest.approx(1.2)
    assert fins[2] == pytest.approx(2.2)
    assert cb.n_admitted == 3 and cb.n_preempted == 0


def test_continuous_preempts_youngest_and_recomputes():
    """A tight KV budget forces the youngest slot out; the evicted
    request recomputes from scratch and everything still completes."""
    cb = ContinuousBatcher(3, 1.5e6, batch_overlap=1.0, kv_admit_frac=0.1)
    for rid in range(3):
        cb.add(Request(rid, 0.0, 1), 1.0, 1e6)
    done = cb.step(None)
    assert sorted(req.rid for req, _ in done) == [0, 1, 2]
    assert cb.n_preempted > 0
    assert cb.kv_high_watermark_bytes <= 1.5e6 + 1e-6
    # every preemption re-queues → one extra admission each
    assert cb.n_admitted == cb.n_completed + cb.n_preempted


def test_continuous_horizon_stepping_and_future_arrivals():
    cb = ContinuousBatcher(2, 1e9)
    cb.add(Request(0, 0.0, 1), 1.0, 1e6)
    cb.add(Request(1, 5.0, 1), 1.0, 1e6)     # not here yet
    assert cb.step(0.5) == []                # mid-flight: nothing done
    assert len(cb.slots) == 1
    done = cb.step(2.0)
    assert [req.rid for req, _ in done] == [0]
    assert len(cb) == 1                      # rid 1 still queued (future)
    done = cb.step(None)
    assert [req.rid for req, _ in done] == [1]
    assert done[0][1] == pytest.approx(6.0)  # starts at its arrival


def test_continuous_solo_admission_exceeding_budget():
    """A request whose reservation alone exceeds the budget still runs
    (solo) instead of deadlocking the queue."""
    cb = ContinuousBatcher(4, 1e6, kv_admit_frac=1.0)
    cb.add(Request(0, 0.0, 1), 1.0, 5e6)
    cb.add(Request(1, 0.0, 1), 1.0, 5e6)
    done = cb.step(None)
    assert sorted(req.rid for req, _ in done) == [0, 1]
    assert cb.n_preempted == 0               # solo slots are never evicted


def test_continuous_drain_returns_flight_then_queue():
    cb = ContinuousBatcher(1, 1e9)
    cb.add(Request(0, 0.0, 1), 1.0, 1e6)
    cb.add(Request(1, 0.0, 1), 2.0, 2e6)
    cb.step(0.5)                             # rid 0 in flight, rid 1 queued
    out = cb.drain()
    assert [(r.rid, svc, kv) for r, svc, kv in out] == \
        [(0, 1.0, 1e6), (1, 2.0, 2e6)]       # full service restored
    assert len(cb) == 0


# --------------------------------------- continuous-batching invariants
def _run_to_quiescence(reqs, max_slots, kv_budget, overlap, admit_frac):
    """Feed a scenario (sorted by arrival — the fleet enqueues in time
    order) and drain to quiescence."""
    cb = ContinuousBatcher(max_slots, kv_budget, batch_overlap=overlap,
                           kv_admit_frac=admit_frac)
    for rid, (arr, svc, kv) in enumerate(sorted(reqs)):
        cb.add(Request(rid, arr, 1), svc, kv)
    return cb, cb.step(None)


def _check_continuous_invariants(reqs, max_slots, kv_budget, overlap,
                                 admit_frac):
    reqs = sorted(reqs)
    cb, done = _run_to_quiescence(reqs, max_slots, kv_budget, overlap,
                                  admit_frac)
    # conservation: every request completes exactly once, nothing lingers
    assert sorted(req.rid for req, _ in done) == list(range(len(reqs)))
    assert len(cb) == 0 and cb.n_completed == len(reqs)
    # causality: a request cannot finish before arrival + full service
    # (batching only stretches service, preemption only adds recompute)
    for req, fin in done:
        arr, svc, _ = reqs[req.rid]
        assert fin >= arr + svc - 1e-9
    # KV watermark never exceeds the budget — except when one request's
    # footprint alone does (solo admission must still run it)
    biggest = max((kv for _, _, kv in reqs), default=0.0)
    assert cb.kv_high_watermark_bytes <= max(kv_budget, biggest) + 1e-6
    # accounting: each preemption re-queues exactly one admission
    assert cb.n_admitted == cb.n_completed + cb.n_preempted
    assert cb.queue_delay_sum_s >= -1e-12


def _check_no_starvation(reqs, max_slots, kv_budget, overlap, admit_frac,
                         until_s):
    """FIFO no-starvation: after a step, an arrived queue head is only
    waiting because the machine genuinely cannot admit it — all slots
    busy, or no KV headroom for its reservation."""
    cb = ContinuousBatcher(max_slots, kv_budget, batch_overlap=overlap,
                           kv_admit_frac=admit_frac)
    for rid, (arr, svc, kv) in enumerate(sorted(reqs)):
        cb.add(Request(rid, arr, 1), svc, kv)
    cb.step(until_s)
    if cb.queue and cb.queue[0].req.arrival_s <= cb.now_s:
        head = cb.queue[0]
        full = len(cb.slots) == cb.max_slots
        res = cb.kv_admit_frac * head.kv_bytes
        blocked = bool(cb.slots) and \
            cb.occupancy_bytes() + res > cb.kv_budget_bytes + 1e-9
        assert full or blocked


def _check_micro_invariants(arrivals, batch_size, max_wait):
    """MicroBatcher: FIFO order, batch-size cap, conservation."""
    mb = MicroBatcher(batch_size, max_wait)
    for rid, arr in enumerate(sorted(arrivals)):
        mb.add(Request(rid, arr, 1))
    seen = []
    now = max(arrivals, default=0.0) + max_wait + 1.0
    while True:
        b = mb.maybe_form(now) or mb.flush(now)
        if b is None:
            break
        assert len(b.requests) <= batch_size
        seen.extend(r.rid for r in b.requests)
    assert seen == list(range(len(arrivals)))     # FIFO + conservation


_RNG_CASES = 40


def _random_scenario(rng):
    n = int(rng.integers(1, 13))
    reqs = [(float(rng.uniform(0.0, 5.0)), float(rng.uniform(0.01, 3.0)),
             float(rng.uniform(0.0, 2e6))) for _ in range(n)]
    return (reqs, int(rng.integers(1, 7)), float(rng.uniform(1e5, 4e6)),
            float(rng.uniform(0.0, 1.0)), float(rng.uniform(0.0, 1.0)))


def test_continuous_invariants_seeded_sweep():
    """Always-on fallback for the hypothesis properties: the same
    invariant checkers over a deterministic random scenario sweep."""
    rng = np.random.default_rng(1234)
    for _ in range(_RNG_CASES):
        args = _random_scenario(rng)
        _check_continuous_invariants(*args)
        _check_no_starvation(*args, until_s=float(rng.uniform(0.0, 8.0)))


def test_micro_invariants_seeded_sweep():
    rng = np.random.default_rng(99)
    for _ in range(_RNG_CASES):
        arrivals = [float(rng.uniform(0.0, 1.0))
                    for _ in range(int(rng.integers(0, 20)))]
        _check_micro_invariants(arrivals, int(rng.integers(1, 9)),
                                float(rng.uniform(0.001, 0.1)))


if HAVE_HYPOTHESIS:
    _req = st.tuples(st.floats(0.0, 5.0), st.floats(0.01, 3.0),
                     st.floats(0.0, 2e6))
    _scenario = st.tuples(st.lists(_req, min_size=1, max_size=12),
                          st.integers(1, 6), st.floats(1e5, 4e6),
                          st.floats(0.0, 1.0), st.floats(0.0, 1.0))

    @settings(deadline=None)
    @given(_scenario)
    def test_continuous_invariants_property(case):
        _check_continuous_invariants(*case)

    @settings(deadline=None)
    @given(_scenario, st.floats(0.0, 8.0))
    def test_continuous_no_starvation_property(case, until_s):
        _check_no_starvation(*case, until_s=until_s)

    @settings(deadline=None)
    @given(st.lists(st.floats(0.0, 1.0), max_size=20),
           st.integers(1, 8), st.floats(0.001, 0.1))
    def test_micro_invariants_property(arrivals, batch_size, max_wait):
        _check_micro_invariants(arrivals, batch_size, max_wait)
