"""Direct unit tests for runtime/scheduler.py primitives — previously
exercised only indirectly through the fleet simulator: StragglerMitigator
hedge firing + p95 bookkeeping, ElasticPool join/leave → replan
callbacks, MicroBatcher deadline semantics, LatencyStats windows."""
import pytest

from repro.runtime.scheduler import (Batch, ElasticPool, LatencyStats,
                                     MicroBatcher, Request,
                                     StragglerMitigator)


# ------------------------------------------------------------ LatencyStats
def test_latency_stats_p95_and_ewma():
    st = LatencyStats(alpha=0.5, window=200)
    assert st.p95() == float("inf")          # no samples yet: never hedge
    for v in range(1, 101):
        st.observe(float(v))
    # sorted[min(n-1, int(.95*n))] with n=100 -> index 95 -> value 96
    assert st.p95() == 96.0
    assert st.mean is not None and 50.0 < st.mean < 101.0


def test_latency_stats_sliding_window_forgets():
    st = LatencyStats(window=4)
    for v in (10.0, 10.0, 10.0, 10.0, 1.0, 1.0, 1.0, 1.0):
        st.observe(v)
    assert st.p95() == 1.0                   # old regime fully evicted


# ------------------------------------------------------- StragglerMitigator
def _seed(mit, replica, value, n=20):
    for _ in range(n):
        mit.stats[replica].observe(value)


def test_pick_primary_prefers_lowest_mean_and_unknowns():
    mit = StragglerMitigator()
    _seed(mit, "slow", 2.0)
    _seed(mit, "fast", 1.0)
    assert mit.pick_primary(["slow", "fast"]) == "fast"
    # an unobserved replica counts as mean 0 — it gets probed first
    assert mit.pick_primary(["slow", "fast", "new"]) == "new"


def test_hedge_fires_past_p95_and_backup_can_win():
    mit = StragglerMitigator()
    _seed(mit, "a", 1.0)
    _seed(mit, "b", 2.0)
    calls = []

    def exec_fn(r):
        calls.append(r)
        return 10.0 if r == "a" else 0.5

    out = mit.run(["a", "b"], exec_fn)
    assert calls == ["a", "b"]               # hedge actually launched
    assert out.hedged and out.replica == "a" and out.winner == "b"
    # hedge fires AT the primary's p95 deadline: latency = deadline + backup
    assert out.latency_s == pytest.approx(1.0 + 0.5)


def test_hedge_does_not_fire_under_deadline():
    mit = StragglerMitigator()
    _seed(mit, "a", 1.0)
    _seed(mit, "b", 1.0)
    out = mit.run(["a", "b"], lambda r: 0.9)
    assert not out.hedged and out.winner == "a"
    assert out.latency_s == pytest.approx(0.9)


def test_hedge_primary_still_wins_when_backup_slower():
    mit = StragglerMitigator()
    _seed(mit, "a", 1.0)
    _seed(mit, "b", 1.0)
    out = mit.run(["a", "b"], lambda r: 1.5 if r == "a" else 3.0)
    assert out.hedged and out.winner == "a"  # deadline + 3.0 > 1.5
    assert out.latency_s == pytest.approx(1.5)


def test_single_replica_never_hedges():
    mit = StragglerMitigator()
    _seed(mit, "a", 1.0)
    out = mit.run(["a"], lambda r: 50.0)
    assert not out.hedged and out.latency_s == 50.0


def test_run_updates_primary_p95():
    """Every run feeds the primary's observed latency back into its
    stats — a straggling replica's deadline adapts upward."""
    mit = StragglerMitigator()
    _seed(mit, "a", 1.0, n=4)
    before = mit.stats["a"].p95()
    for _ in range(30):
        mit.run(["a"], lambda r: 5.0)
    assert mit.stats["a"].p95() > before
    assert mit.stats["a"].mean > 1.0


# ------------------------------------------------------------- ElasticPool
def test_elastic_pool_join_leave_fires_replan_callbacks():
    seen = []
    pool = ElasticPool(on_change=seen.append, timeout_s=1.0)
    pool.heartbeat("r0", 0.0)
    pool.heartbeat("r1", 0.0)
    assert seen == [["r0"], ["r0", "r1"]]    # each join is a transition
    # r1 goes silent past the timeout -> leave event on next refresh
    pool.heartbeat("r0", 2.0)
    assert seen[-1] == ["r0"]
    assert pool.live(2.0) == ["r0"]
    # r1 re-joins -> replan callback with the restored set
    pool.heartbeat("r1", 2.5)
    assert seen[-1] == ["r0", "r1"]


def test_elastic_pool_full_outage_and_recovery():
    seen = []
    pool = ElasticPool(on_change=seen.append, timeout_s=0.5)
    pool.heartbeat("r0", 0.0)
    assert pool.live(10.0) == []             # timed out -> full outage
    assert seen[-1] == []
    pool.heartbeat("r0", 10.1)
    assert seen[-1] == ["r0"]


def test_elastic_pool_no_callback_without_transition():
    seen = []
    pool = ElasticPool(on_change=seen.append, timeout_s=1.0)
    pool.heartbeat("r0", 0.0)
    pool.heartbeat("r0", 0.1)
    pool.heartbeat("r0", 0.2)
    assert seen == [["r0"]]                  # steady state stays silent


# ------------------------------------------------------------- MicroBatcher
def test_microbatcher_deadline_forms_partial_batch():
    mb = MicroBatcher(batch_size=8, max_wait_s=0.02)
    mb.add(Request(0, 0.0, 1))
    mb.add(Request(1, 0.005, 1))
    assert mb.maybe_form(0.01) is None       # young queue, under size
    b = mb.maybe_form(0.025)                 # oldest aged past deadline
    assert isinstance(b, Batch) and len(b.requests) == 2
    assert mb.maybe_form(0.03) is None       # drained


def test_microbatcher_size_trigger_before_deadline():
    mb = MicroBatcher(batch_size=2, max_wait_s=10.0)
    mb.add(Request(0, 0.0, 1))
    mb.add(Request(1, 0.0, 1))
    mb.add(Request(2, 0.0, 1))
    b = mb.maybe_form(0.001)
    assert b is not None and [r.rid for r in b.requests] == [0, 1]
    assert len(mb.queue) == 1                # remainder rides the next one
