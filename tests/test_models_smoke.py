"""Per-arch REDUCED-config smoke tests: one forward/train step on CPU,
asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build


def _batch(cfg, key, B=2, S=16):
    kt, kl, kx = jax.random.split(key, 3)
    tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(kl, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(kx, (B, S, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            kx, (B, cfg.n_vision_tokens, cfg.d_model))
    if cfg.family == "vla":
        batch = {
            "patches": jax.random.normal(kx, (B, cfg.n_patches, cfg.vit_dim)),
            "tokens": tokens[:, :8],
            "actions": jax.random.normal(
                kx, (B, cfg.action_horizon, cfg.action_dim)),
        }
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_loss_no_nan(arch):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss = model.loss_fn(params, batch, jax.random.PRNGKey(2))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step(arch):
    from repro.train.optimizer import OptConfig
    from repro.train.train_loop import init_state, make_train_step
    cfg = get_config(arch).reduced()
    model = build(cfg)
    state = init_state(model.init(jax.random.PRNGKey(0)))
    step = jax.jit(make_train_step(model, OptConfig(lr=1e-3)))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    state2, metrics = step(state, batch, jax.random.PRNGKey(2))
    assert int(state2.step) == 1
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed somewhere (single bf16 leaves can underflow
    # a 1e-3 update, so check the whole tree)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, state2.params)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0.0


@pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-v2-lite-16b",
                                  "mamba2-1.3b", "zamba2-1.2b",
                                  "seamless-m4t-large-v2",
                                  "llama-3.2-vision-11b"])
def test_prefill_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1), B=2, S=8)
    batch.pop("labels", None)
    logits, cache = model.prefill(params, batch)
    assert logits.shape[:2] == (2, 1)
    from repro.runtime.kvcache import pad_cache
    cache = pad_cache(cache, model.cache_specs(2, 16, src_len=8))
    l2, cache = model.decode(params, cache, batch["tokens"][:, :1],
                             jnp.int32(8))
    assert l2.shape[:2] == (2, 1)
    assert bool(jnp.all(jnp.isfinite(l2.astype(jnp.float32))))
