"""Decode-path correctness: prefill + step-by-step decode must reproduce the
full teacher-forced forward logits (float32, tight tolerance)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build
from repro.runtime.kvcache import pad_cache

ARCHS = ["llama3.2-3b", "glm4-9b", "deepseek-v2-lite-16b",
         "granite-moe-3b-a800m", "mamba2-1.3b", "zamba2-1.2b"]


def _full_logits(cfg, model, params, batch):
    if cfg.family in ("dense", "moe"):
        from repro.models.transformer import lm_hidden, lm_logits
        h, _ = lm_hidden(cfg, params, batch["tokens"], remat=False)
        return lm_logits(cfg, params, h)
    if cfg.family == "ssm":
        from repro.models.ssm import mamba_forward
        from repro.models.transformer import run_stack
        from repro.models.layers import embed, rmsnorm, unembed
        x = embed(params["embed"], batch["tokens"]).astype(
            jnp.dtype(cfg.dtype))

        def one(pl, h):
            return h + mamba_forward(cfg, pl, h), None, jnp.float32(0)

        x, _, _ = run_stack(cfg, params["mamba"], x, one, cfg.n_layers,
                            remat=False)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        w = params["embed"] if cfg.tie_embeddings else params["head"]
        return unembed(w, x)
    if cfg.family == "hybrid":
        from repro.models.hybrid import hybrid_hidden
        from repro.models.layers import rmsnorm, unembed
        x = hybrid_hidden(cfg, params, batch["tokens"], remat=False)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        w = params["embed"] if cfg.tie_embeddings else params["head"]
        return unembed(w, x)
    raise NotImplementedError


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    # moe_capacity_factor=8: GShard capacity drops depend on the token count,
    # so exact prefill==forward equivalence needs a non-binding capacity
    cfg = get_config(arch).reduced().replace(dtype="float32",
                                             moe_capacity_factor=8.0)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P, T = 2, 5, 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)
    full = _full_logits(cfg, model, params, {"tokens": tokens})
    logits, cache = model.prefill(params, {"tokens": tokens[:, :P]})
    assert jnp.allclose(logits[:, 0], full[:, P - 1], atol=2e-3), \
        "prefill last logits mismatch"
    cache = pad_cache(cache, model.cache_specs(B, T, src_len=P))
    errs = []
    for i in range(P, T):
        logits, cache = model.decode(params, cache, tokens[:, i:i + 1],
                                     jnp.int32(i))
        errs.append(float(jnp.max(jnp.abs(logits[:, 0] - full[:, i]))))
    assert max(errs) < 2e-3, f"decode drift {max(errs)}"


def test_encdec_decode_matches_teacher_forcing():
    cfg = get_config("seamless-m4t-large-v2").reduced().replace(
        dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S_src, P, T = 2, 12, 4, 8
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, S_src, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                                cfg.vocab_size)
    from repro.models.encdec import encode, encdec_prefill, _dec_block
    from repro.models.layers import embed, rmsnorm, unembed
    from repro.models.transformer import run_stack
    # teacher-forced full decoder pass
    enc_out = encode(cfg, params, frames, remat=False)
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(T)

    def one(pl, h):
        h, _, _ = _dec_block(cfg, pl, h, positions, enc_out=enc_out)
        return h, None, jnp.float32(0)

    x, _, _ = run_stack(cfg, params["dec_blocks"], x, one, cfg.n_dec_layers,
                        remat=False)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    full = unembed(w, x)

    logits, cache = model.prefill(params, {"frames": frames,
                                           "tokens": tokens[:, :P]})
    assert jnp.allclose(logits[:, 0], full[:, P - 1], atol=2e-3)
    cache = pad_cache(cache, model.cache_specs(B, T, src_len=S_src))
    for i in range(P, T):
        logits, cache = model.decode(params, cache, tokens[:, i:i + 1],
                                     jnp.int32(i))
        assert float(jnp.max(jnp.abs(logits[:, 0] - full[:, i]))) < 2e-3


def test_vlm_decode_matches_forward():
    cfg = get_config("llama-3.2-vision-11b").reduced().replace(
        dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P, T = 2, 4, 8
    vision = jax.random.normal(jax.random.PRNGKey(1),
                               (B, cfg.n_vision_tokens, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                                cfg.vocab_size)
    from repro.models.vlm import _hidden
    from repro.models.layers import rmsnorm, unembed
    x = _hidden(cfg, params, tokens, vision, remat=False)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["head"]
    full = unembed(w, x)
    logits, cache = model.prefill(params, {"tokens": tokens[:, :P],
                                           "vision": vision})
    assert jnp.allclose(logits[:, 0], full[:, P - 1], atol=2e-3)
    cache = pad_cache(cache, model.cache_specs(B, T))
    for i in range(P, T):
        logits, cache = model.decode(params, cache, tokens[:, i:i + 1],
                                     jnp.int32(i))
        assert float(jnp.max(jnp.abs(logits[:, 0] - full[:, i]))) < 2e-3
