import os
import sys

# tests run on the single real CPU device; only the dry-run uses fake devices
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_platform_name", "cpu")
