import os
import sys

# tests run on the single real CPU device; only the dry-run uses fake devices
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_platform_name", "cpu")

# Deterministic hypothesis profile for CI (HYPOTHESIS_PROFILE=ci):
# derandomized, example-capped, no deadline — property failures reproduce
# bit-for-bit across runs.  Guarded: hypothesis is an optional test dep
# and the suites fall back to seeded scenario tests without it.
try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", derandomize=True, max_examples=60, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:
    pass
