"""Direct unit tests for runtime/kvcache.py — previously exercised only
through serving: the jax cache helpers (alloc/pad/bytes) and the analytic
KV sizing that prices the continuous-batching tier's placement windows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Workload, build_graph
from repro.models import build
from repro.runtime.kvcache import (KV_KINDS, alloc_cache, cache_bytes,
                                   graph_kv_cumsum, kv_bytes_per_token,
                                   pad_cache, request_kv_tokens)

W = Workload()


# ----------------------------------------------------------- analytic KV
def test_kv_bytes_per_token_standard_attention():
    cfg = get_config("openvla-7b")
    want = 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2
    assert kv_bytes_per_token(cfg) == want
    assert kv_bytes_per_token(cfg, act_bytes=4) == 2 * want


def test_kv_bytes_per_token_mla_stores_latent_not_heads():
    cfg = get_config("deepseek-v2-lite-16b")
    assert cfg.use_mla
    want = (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
    assert kv_bytes_per_token(cfg) == want
    # MLA's whole point: far below the equivalent per-head cache
    assert want < 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2


def test_request_kv_tokens_counts_context_chunk_and_decode():
    assert request_kv_tokens(W) == W.s_ctx + W.s_new + W.decode_steps


def test_graph_kv_cumsum_window_convention():
    cfg = get_config("openvla-7b")
    g = build_graph(cfg, W)
    out = graph_kv_cumsum(g, cfg, W)
    assert out.shape == (len(g) + 1,)
    assert out[-1] == 0.0                       # empty window beyond n
    # suffix cumsum: non-increasing, so every window prices >= 0
    assert (np.diff(out) <= 1e-9).all()
    # out[0] is the whole model: per-layer bytes x KV-bearing layer count
    n_kv = sum(1 for c in g if c.kind in KV_KINDS)
    per = kv_bytes_per_token(cfg, W.act_bytes) * request_kv_tokens(W) \
        * W.batch
    assert out[0] == pytest.approx(per * n_kv)
    # a window's KV is the cumsum difference, and only KV layers count
    s1 = next(i for i, c in enumerate(g) if c.kind in KV_KINDS)
    assert out[0] == out[s1]                    # ViT prefix holds no KV
    assert out[s1] - out[s1 + 1] == pytest.approx(per)


def test_graph_kv_cumsum_zero_for_cacheless_graph():
    cfg = get_config("mamba2-1.3b")             # pure SSM trunk: no KV
    g = build_graph(cfg, W)
    assert not any(c.kind in KV_KINDS for c in g)
    assert (graph_kv_cumsum(g, cfg, W) == 0.0).all()


# ---------------------------------------------------------- jax helpers
@pytest.fixture(scope="module")
def lm():
    cfg = get_config("llama3.2-3b").reduced().replace(n_layers=4,
                                                      dtype="float32")
    return cfg, build(cfg)


def test_alloc_cache_bytes_match_analytic_sizing(lm):
    """The analytic per-token formula prices exactly what alloc_cache
    materializes: layers x batch x tokens x kv_bytes_per_token."""
    cfg, model = lm
    batch, max_len = 2, 8
    cache = alloc_cache(model, batch, max_len)
    want = kv_bytes_per_token(cfg, act_bytes=4) * cfg.n_layers \
        * batch * max_len
    assert cache_bytes(cache) == want


def test_alloc_cache_zero_initialized(lm):
    _, model = lm
    cache = alloc_cache(model, 1, 4)
    for leaf in jax.tree_util.tree_leaves(cache):
        assert not jnp.any(leaf)


def test_pad_cache_extends_seq_axis_and_keeps_content(lm):
    cfg, model = lm
    prompt = alloc_cache(model, 2, 5)
    prompt = jax.tree_util.tree_map(jnp.ones_like, prompt)
    specs = model.cache_specs(2, 8)
    padded = pad_cache(prompt, specs)
    shapes = jax.tree_util.tree_map(lambda x: x.shape, padded)
    want = jax.tree_util.tree_map(lambda x: x.shape,
                                  alloc_cache(model, 2, 8))
    assert shapes == want
    # zero padding: the prompt-sized content survives untouched
    for before, after in zip(jax.tree_util.tree_leaves(prompt),
                             jax.tree_util.tree_leaves(padded)):
        assert float(after.sum()) == float(before.sum())


def test_pad_cache_rejects_shrinking(lm):
    _, model = lm
    big = alloc_cache(model, 2, 8)
    with pytest.raises(AssertionError):
        pad_cache(big, model.cache_specs(2, 5))
