"""Queue-aware planning: M/G/1 wait-term semantics, scalar-oracle parity
of every vectorized sweep under congestion, bitwise zero-rate degeneracy,
and the fleet-level degenerate/determinism guarantees.

Repo discipline: each vectorized search must agree with its scalar oracle
under the new ``queue_hz`` axis on EVERY registered config, and setting
the arrival rate to zero must reproduce the queue-blind results
bit-for-bit (``np.array_equal``, not approx)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.core import (TraceConfig, Workload, build_graph, queue_delay_s,
                        search, search_multicut, search_multicut_scalar,
                        search_streamed, search_streamed_scalar, search_vec,
                        sweep_multicut, sweep_search)
from repro.core.hardware import A100, ORIN
from repro.runtime.fleet import FleetConfig, FleetSimulator, run_fleet

W = Workload()
BWS = np.geomspace(0.05e6, 40e6, 7)
AXIS = ("identity", "int8", "int4")
QUOTA = 5.8e9
DOWN = 8.0
GRID = (1, 2, 4, 8)
# a deliberately congested operating point: λ high enough that ρ → 1 for
# the larger cloud windows, cv² and service inflation off their defaults
QHZ = dict(queue_hz=7.0, queue_cv2=1.3, queue_service_scale=1.2)


@pytest.fixture(scope="module")
def graphs():
    return {k: build_graph(get_config(k), W) for k in sorted(ARCHS)}


# ------------------------------------------------------------ wait term
def test_queue_delay_known_value_and_edges():
    # M/M/1 check: λ=1, S=0.5 → W = 1·0.25·2 / (2·0.5) = 0.5
    assert queue_delay_s(0.5, 1.0) == pytest.approx(0.5)
    # zero arrival rate or zero service → no wait
    assert queue_delay_s(0.5, 0.0) == 0.0
    assert queue_delay_s(0.0, 10.0) == 0.0
    # saturation ρ >= 1 → infinite wait (the planner must retreat)
    assert queue_delay_s(1.0, 1.0) == float("inf")
    assert queue_delay_s(2.0, 1.0) == float("inf")
    # service_scale inflates S inside ρ as well: λ=1, S=0.25, scale=2
    assert queue_delay_s(0.25, 1.0, service_scale=2.0) == \
        pytest.approx(queue_delay_s(0.5, 1.0))
    # cv² scales the numerator linearly below saturation
    assert queue_delay_s(0.1, 1.0, cv2=3.0) == \
        pytest.approx(2.0 * queue_delay_s(0.1, 1.0))


def test_queue_delay_vectorized_matches_scalar():
    xs = np.array([0.0, 0.01, 0.1, 0.5, 1.0, 3.0])
    vec = queue_delay_s(xs, 1.7, cv2=1.3, service_scale=1.1)
    assert vec.shape == xs.shape
    for x, v in zip(xs, vec):
        s = queue_delay_s(float(x), 1.7, cv2=1.3, service_scale=1.1)
        assert v == s or (np.isinf(v) and np.isinf(s))


# -------------------------------------------------- scalar-oracle parity
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_queue_aware_vec_search_matches_scalar_every_config(arch, graphs):
    """Acceptance: the queue-aware vectorized sweep is plan-identical to
    the scalar oracle on all registered configs."""
    g = graphs[arch]
    res = search_vec(g, ORIN, A100, BWS, QUOTA, rtt_s=0.005,
                     input_bytes=W.input_bytes, **QHZ)
    sw = sweep_search({arch: g}, ORIN, A100, BWS, QUOTA, rtt_s=0.005,
                      input_bytes=W.input_bytes, **QHZ)[arch]
    for j, bw in enumerate(BWS):
        sc = search(g, ORIN, A100, float(bw), QUOTA, rtt_s=0.005,
                    input_bytes=W.input_bytes, **QHZ)
        assert int(res.splits[j]) == sc.split, (arch, bw)
        assert res.total_s[j] == pytest.approx(sc.total_s, rel=1e-9)
        assert int(sw.splits[j]) == sc.split, (arch, bw)


@pytest.mark.parametrize("arch", ("openvla-7b", "deepseek-v2-lite-16b",
                                  "llama3.2-3b"))
def test_queue_aware_multicut_matches_scalar(arch, graphs):
    g = graphs[arch]
    res = search_multicut(g, ORIN, A100, BWS, QUOTA, codecs=AXIS,
                          rtt_s=0.005, input_bytes=W.input_bytes,
                          down_bw_factor=DOWN, **QHZ)
    for j, bw in enumerate(BWS):
        sc = search_multicut_scalar(g, ORIN, A100, float(bw), QUOTA,
                                    codecs=AXIS, rtt_s=0.005,
                                    input_bytes=W.input_bytes,
                                    down_bw_factor=DOWN, **QHZ)
        assert res.plan_at(j) == sc.plan, (arch, bw)
        assert res.total_s[j] == pytest.approx(sc.total_s, rel=1e-9)


@pytest.mark.parametrize("arch", ("openvla-7b", "cogact-7b"))
def test_queue_aware_streamed_matches_scalar(arch, graphs):
    g = graphs[arch]
    res = search_streamed(g, ORIN, A100, BWS, QUOTA, codecs=AXIS,
                          chunk_grid=GRID, rtt_s=0.005,
                          input_bytes=W.input_bytes, down_bw_factor=DOWN,
                          **QHZ)
    for j, bw in enumerate(BWS):
        sc = search_streamed_scalar(g, ORIN, A100, float(bw), QUOTA,
                                    codecs=AXIS, chunk_grid=GRID,
                                    rtt_s=0.005, input_bytes=W.input_bytes,
                                    down_bw_factor=DOWN, **QHZ)
        assert res.plan_at(j) == sc.plan, (arch, bw)
        assert int(res.n_chunks[j]) == sc.n_chunks, (arch, bw)
        assert res.total_s[j] == pytest.approx(sc.total_s, rel=1e-9)


# ------------------------------------------------- zero-rate degeneracy
def test_zero_arrival_rate_is_bitwise_queue_blind(graphs):
    """queue_hz=0 must not merely approximate the queue-blind sweep — it
    must take the identical code path and produce identical bits."""
    sub = {k: graphs[k] for k in ("openvla-7b", "llama3.2-3b")}
    blind = sweep_search(sub, ORIN, A100, BWS, QUOTA, rtt_s=0.005,
                         input_bytes=W.input_bytes, codecs=AXIS)
    zero = sweep_search(sub, ORIN, A100, BWS, QUOTA, rtt_s=0.005,
                        input_bytes=W.input_bytes, codecs=AXIS,
                        queue_hz=0.0, queue_cv2=2.0,
                        queue_service_scale=3.0)
    for k in sub:
        for f in ("splits", "total_s", "edge_s", "cloud_s", "net_s",
                  "codec_idx"):
            assert np.array_equal(getattr(blind[k], f),
                                  getattr(zero[k], f)), (k, f)

    mc_b = sweep_multicut(sub, ORIN, A100, BWS, QUOTA, codecs=AXIS,
                          rtt_s=0.005, input_bytes=W.input_bytes,
                          down_bw_factor=DOWN, chunk_grid=GRID)
    mc_z = sweep_multicut(sub, ORIN, A100, BWS, QUOTA, codecs=AXIS,
                          rtt_s=0.005, input_bytes=W.input_bytes,
                          down_bw_factor=DOWN, chunk_grid=GRID,
                          queue_hz=0.0)
    for k in sub:
        for f in ("s1", "s2", "total_s", "edge_s", "cloud_s", "up_s",
                  "down_s", "codec_idx", "n_chunks"):
            assert np.array_equal(getattr(mc_b[k], f),
                                  getattr(mc_z[k], f)), (k, f)


def test_queue_term_is_planning_prior_not_physical(graphs):
    """Under congestion the reported total carries the expected wait, so
    the physical decomposition no longer sums to it — by design (the wait
    is a planning prior, not a transport/compute leg)."""
    g = graphs["openvla-7b"]
    res = search_vec(g, ORIN, A100, BWS, QUOTA, rtt_s=0.005,
                     input_bytes=W.input_bytes, **QHZ)
    parts = res.edge_s + res.cloud_s + res.net_s
    collaborative = res.splits < len(g)
    if collaborative.any():
        assert (res.total_s[collaborative]
                > parts[collaborative] + 1e-12).all()
    # edge-only bins carry no cloud queue → total == parts exactly
    edge_only = ~collaborative
    if edge_only.any():
        assert np.array_equal(res.total_s[edge_only], parts[edge_only])


# --------------------------------------------------------------- fleet
def _fleet_cfg(**kw) -> FleetConfig:
    bw = 1e6
    return FleetConfig(n_robots=8, n_ticks=60, n_replicas=2,
                       archs=("openvla-7b",), seed=3, multicut=True,
                       codecs=AXIS, cloud_budget_bytes=QUOTA,
                       down_bw_factor=DOWN,
                       trace=TraceConfig(mean_bps=bw, bad_bps=bw / 4),
                       nominal_bw_bps=bw, **kw)


def test_fleet_queue_aware_zero_rate_bitwise_degenerate():
    """queue_aware=True with an explicit zero rate skips the plan-table
    rebuild entirely: the FleetReport equals the default run bit-for-bit
    (dataclass equality covers every float)."""
    a = run_fleet(_fleet_cfg())
    b = run_fleet(_fleet_cfg(queue_aware=True, queue_hz=0.0))
    assert a == b


def test_fleet_continuous_false_leaves_micro_path_untouched():
    """The continuous-batching knobs are inert under continuous=False:
    identical report, zero queue metrics."""
    a = run_fleet(_fleet_cfg())
    b = run_fleet(_fleet_cfg(kv_budget_bytes=1.0, kv_admit_frac=0.9))
    assert a == b
    assert a.n_preemptions == 0 and a.mean_queue_delay_s == 0.0
    assert a.kv_high_watermark_bytes == 0.0


def test_fleet_queue_aware_auto_estimates_positive_rate():
    sim = FleetSimulator(_fleet_cfg(queue_aware=True))
    assert sim.plan_queue_hz > 0.0
    # every controller plans with the same rate the tables used
    assert all(c.queue_hz == sim.plan_queue_hz for c in sim.controllers)


def test_lambda_estimator_closed_network_cap_inactive_when_loose():
    """At the PR-5 acceptance operating point (degraded 1 MB/s link) the
    closed-network population bound sits well above the open-loop
    estimate: the cap must not engage, the auto estimate equals the open
    rate, and the queue-aware run is bit-identical to passing that rate
    explicitly — the cap is a guard rail, not a behavior change."""
    sim = FleetSimulator(_fleet_cfg(queue_aware=True))
    lam, cap = sim._open_arrival_hz(), sim._closed_loop_cap_hz()
    assert 0.0 < lam < cap
    assert sim._estimate_arrival_hz() == lam == sim.plan_queue_hz
    auto = run_fleet(_fleet_cfg(queue_aware=True))
    explicit = run_fleet(_fleet_cfg(queue_aware=True, queue_hz=lam))
    assert auto == explicit


def test_lambda_cap_prevents_edge_retreat_on_fast_cloud():
    """Regression for the plan-harmful over-count: on a fast default link
    the open estimator credits every robot its zero-wait cycle rate (~47
    Hz per replica at 32 robots) — far past what the closed loop can
    actually sustain — which drives the M/G/1 term toward ρ ≥ 1 and
    makes the planner retreat to edge-heavy splits.  The closed-network
    cap (~20 Hz here) keeps the collaborative split, and the capped
    queue-aware fleet beats both the uncapped-estimate plan and the
    queue-blind baseline on p95 (all three runs are deterministic)."""
    fast = FleetConfig(n_robots=32, n_ticks=120, n_replicas=2,
                       archs=("openvla-7b",), seed=3, queue_aware=True)
    # the estimate feeding the rebuild is computed on the queue-BLIND
    # tables (a queue-aware sim's estimator re-reads its rebuilt tables,
    # so measure on a blind twin)
    blind = FleetSimulator(dataclasses.replace(fast, queue_aware=False))
    lam, cap = blind._open_arrival_hz(), blind._closed_loop_cap_hz()
    assert 0.0 < cap < lam
    sim = FleetSimulator(fast)
    assert sim.plan_queue_hz == cap
    k0 = int(np.searchsorted(sim._bw_mid, fast.nominal_bw_bps))
    uncapped = FleetSimulator(dataclasses.replace(fast, queue_hz=lam))
    s1_cap = int(sim.plan["openvla-7b"][k0])
    s1_unc = int(uncapped.plan["openvla-7b"][k0])
    assert s1_cap < s1_unc            # cap keeps more layers on the cloud
    r_cap = run_fleet(fast)
    r_unc = run_fleet(dataclasses.replace(fast, queue_hz=lam))
    r_blind = run_fleet(dataclasses.replace(fast, queue_aware=False))
    assert r_cap.fleet_p95_s < r_unc.fleet_p95_s - 0.1
    assert r_cap.fleet_p95_s < r_blind.fleet_p95_s - 0.1


def test_fleet_continuous_seed_determinism():
    """Satellite acceptance: two runs of the full continuous + queue-aware
    configuration produce identical FleetReports; a different seed does
    not."""
    cfg = _fleet_cfg(continuous=True, queue_aware=True,
                     kv_budget_bytes=4e8)
    a, b = run_fleet(cfg), run_fleet(cfg)
    assert a == b
    c = run_fleet(dataclasses.replace(cfg, seed=99))
    assert c != a


def test_fleet_continuous_beats_micro_p95_at_1mbs():
    """Acceptance: at the 1 MB/s OpenVLA operating point the continuous
    tier (with queue-aware planning on) beats the micro-batching
    baseline's fleet p95 — same plan tables, same trace (the measured
    margin is ~100 ms; assert half of it so trace tweaks don't flake)."""
    kw = dict(n_robots=16, n_ticks=200)
    micro = run_fleet(dataclasses.replace(_fleet_cfg(), **kw))
    cont = run_fleet(dataclasses.replace(
        _fleet_cfg(continuous=True, queue_aware=True), **kw))
    assert cont.n_requests >= micro.n_requests
    assert cont.fleet_p95_s < micro.fleet_p95_s - 0.05


def test_fleet_continuous_reports_queue_metrics():
    cfg = _fleet_cfg(continuous=True, kv_budget_bytes=1.5e8)
    rep = run_fleet(cfg)
    assert rep.n_requests > 0
    assert rep.n_hedged == 0                 # continuous tier never hedges
    assert rep.kv_high_watermark_bytes > 0.0
    assert rep.kv_high_watermark_bytes <= 1.5e8 + 1e-6
    assert rep.mean_queue_delay_s >= 0.0
    assert rep.n_preemptions > 0             # tight budget forces evictions
