"""Unit tests for the CI guard scripts, which until now were exercised
only by actually running them in the workflow: the BENCH_fleet.json
schema checker (``tools/check_bench_schema.py`` — valid payloads pass,
each class of violation is reported with a pointed message, ``main``
exit codes are correct), the docs-link checker
(``tools/check_doc_links.py`` — resolvable references in docstrings and
markdown pass, dangling ones fail with file:line) and the doc-coverage
checker (``tools/check_doc_coverage.py`` — every public FleetConfig
field and registered codec must be mentioned in docs/ or README.md).
"""
import json
import os
import sys
import textwrap

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_bench_schema as cbs          # noqa: E402
import check_doc_coverage as cdc          # noqa: E402
import check_doc_links as cdl             # noqa: E402


# ------------------------------------------------------- schema fixtures
def _valid_payload() -> dict:
    lat = {"p50_s": 0.1, "p95_s": 0.2}
    return {
        "schema_version": cbs.EXPECTED_SCHEMA_VERSION,
        "config": {"n_robots": 6, "n_ticks": 40, "n_replicas": 2,
                   "seed": 0, "smoke": True},
        "planner": {"scalar_s": 1.0, "vec_s": 0.01, "cells": 100,
                    "codec_scalar_s": 1.0, "codec_vec_s": 0.01,
                    "codec_cells": 300, "multicut_scalar_s": 2.0,
                    "multicut_vec_s": 0.02, "multicut_cells": 5000,
                    "multicut_speedup": 100.0},
        "fleet": {**lat, "throughput_rps": 10.0, "n_requests": 100,
                  "sim_wall_s": 0.5},
        "codecs": {"identity": {**lat, "throughput_rps": 10.0}},
        "multicut": {"1MBs_single": {**lat, "n_multicut_requests": 0},
                     "1MBs_multi": {**lat, "n_multicut_requests": 5}},
        "streamed": {"1MBs_seq": {**lat, "n_streamed_requests": 0,
                                  "n_chunk_reconfigs": 0,
                                  "mean_bubble_frac": 0.0},
                     "1MBs_stream": {**lat, "n_streamed_requests": 9,
                                     "n_chunk_reconfigs": 2,
                                     "mean_bubble_frac": 0.12}},
        "queue": {t: {**lat, "n_preemptions": 0,
                      "mean_queue_delay_s": 0.01,
                      "kv_high_watermark_bytes": 1e8}
                  for t in cbs.QUEUE_REQUIRED_TAGS},
        "scale": {"engine": "events", "n_robots": 1000, "n_ticks": 200,
                  "wall_s": 3.2, "p50_s": 0.1, "p95_s": 0.2,
                  "p99_s": 0.3, "p999_s": 0.4, "n_requests": 5000,
                  "n_open_arrivals": 500, "throughput_rps": 25.0},
        "scaling_curve": [
            {"n_robots": 1000, "n_ticks": 200, "wall_s": 0.4,
             "peak_rss_bytes": 2 * 10**8, "setup_s": 0.1, "loop_s": 0.25,
             "replan_s": 0.01, "n_requests": 5000, "p999_s": 0.4},
            {"n_robots": 10_000, "n_ticks": 200, "wall_s": 1.8,
             "peak_rss_bytes": 5 * 10**8, "setup_s": 0.9, "loop_s": 0.8,
             "replan_s": 0.02, "n_requests": 50_000, "p999_s": 0.4},
            {"n_robots": 100_000, "n_ticks": 200, "wall_s": 16.0,
             "peak_rss_bytes": 2 * 10**9, "setup_s": 11.0, "loop_s": 4.5,
             "replan_s": 0.05, "n_requests": 500_000, "p999_s": 0.4},
        ],
        "autoscale": {
            f"high_{h:g}": {"high_s": h, "n_autoscale_events": 2,
                            "p50_s": 0.1, "p95_s": 0.2,
                            "cohorts": {"metro": _cohort(),
                                        "rural": _cohort()}}
            for h in (0.05, 0.25)},
        "overhead": {"n_robots": 500, "n_ticks": 200,
                     "off_wall_s": 0.5, "sampled_wall_s": 0.51,
                     "full_wall_s": 0.6, "sampled_ratio": 1.02,
                     "full_ratio": 1.2, "budget_ratio": 1.03,
                     "smoke": True, "n_recorded_sampled": 120,
                     "n_recorded_full": 2000},
        "drift": {"n_joined": 2000, "n_pred_saturated": 0,
                  "reconcile_max_abs_s": 2.3e-16,
                  "stages": {k: _drift_stage()
                             for k in ("edge_s", "uplink_s", "queue_s",
                                       "service_s", "down_s", "total_s",
                                       "wire_bytes")}},
        "delta": {"resync_every": 16, "static_gate_ratio": 5.0,
                  "scenes": {s: _delta_scene()
                             for s in cbs.DELTA_REQUIRED_SCENES},
                  "drift": {"n": 900, "mean_err_bytes": 30.0,
                            "p95_err_bytes": 120.0,
                            "meas_mean_bytes": 5e4,
                            "rel_err": 0.02, "rel_tol": 0.5}},
    }


def _drift_stage() -> dict:
    return {"n": 2000, "mean_err": 1e-3, "p50_err": 5e-4, "p95_err": 4e-3}


def _delta_scene() -> dict:
    return {"delta_bytes_per_step": 2e4, "int4_bytes_per_step": 1e5,
            "ratio_vs_int4": 5.0, "keyframe_rate": 0.07,
            "n_keyframes": 60, "n_delta_frames": 840}


def _cohort() -> dict:
    return {"p50_s": 0.1, "p95_s": 0.2, "n_arrivals": 50, "n_rejected": 0}


def test_schema_valid_payload_passes():
    assert cbs.check(_valid_payload()) == []


@pytest.mark.parametrize("mutate,needle", [
    (lambda p: p.pop("scale"), "missing top-level section 'scale'"),
    (lambda p: p.update(schema_version=3), "schema_version"),
    (lambda p: p["fleet"].update(p50_s=0.3), "fleet p50 > p95"),
    (lambda p: p["planner"].update(vec_s=-1.0), "finite positive"),
    (lambda p: p["queue"].pop("cont_aware"), "queue missing entry"),
    (lambda p: p["queue"]["cont_blind"].update(n_preemptions=-2),
     "n_preemptions"),
    (lambda p: p["streamed"]["1MBs_stream"].update(mean_bubble_frac=1.5),
     "mean_bubble_frac"),
    (lambda p: p["streamed"].pop("1MBs_stream"), "'_stream' counterpart"),
    (lambda p: p["scale"].update(engine="ticks"), "!= 'events'"),
    (lambda p: p["scale"].update(wall_s=0.0), "wall_s"),
    (lambda p: p["scale"].update(n_robots=-1), "non-negative int"),
    (lambda p: p["scale"].update(p99_s=0.05), "nondecreasing"),
    (lambda p: p["scale"].pop("p999_s"), "scale missing 'p999_s'"),
    (lambda p: p.update(scaling_curve=[]), "non-empty list"),
    (lambda p: p["scaling_curve"][1].pop("peak_rss_bytes"),
     "scaling_curve[1] missing 'peak_rss_bytes'"),
    (lambda p: p["scaling_curve"][2].update(wall_s=-1.0),
     "scaling_curve[2].wall_s"),
    (lambda p: p["scaling_curve"][1].update(n_robots=1000),
     "strictly increasing"),
    (lambda p: p["scaling_curve"][0].update(peak_rss_bytes=9 * 10**9),
     "peak_rss_bytes must be nondecreasing"),
    (lambda p: p["scaling_curve"][0].update(wall_s=30.0),
     "timing-noise allowance"),
    (lambda p: p["scaling_curve"][1].update(replan_s=-0.1),
     "scaling_curve[1].replan_s"),
    (lambda p: p.update(autoscale={}), "non-empty object"),
    (lambda p: p["autoscale"]["high_0.25"].pop("cohorts"),
     "autoscale['high_0.25'] missing 'cohorts'"),
    (lambda p: p["autoscale"]["high_0.05"].update(n_autoscale_events=-1),
     "n_autoscale_events"),
    (lambda p: p["autoscale"]["high_0.05"]["cohorts"]["rural"].pop(
        "n_rejected"), "cohorts['rural'] missing 'n_rejected'"),
    (lambda p: p["autoscale"]["high_0.05"]["cohorts"]["metro"].update(
        n_arrivals=-5), "cohorts['metro'].n_arrivals"),
    (lambda p: p.pop("overhead"), "missing top-level section 'overhead'"),
    (lambda p: p.update(overhead={}), "'overhead' must be a non-empty"),
    (lambda p: p["overhead"].pop("budget_ratio"),
     "overhead missing 'budget_ratio'"),
    (lambda p: p["overhead"].update(off_wall_s=0.0),
     "overhead.off_wall_s"),
    (lambda p: p["overhead"].update(sampled_ratio=0.97),
     "must be >= 1 (noise-floored ratio)"),
    (lambda p: p["overhead"].update(sampled_ratio=1.9),
     "exceeds its budget_ratio"),
    (lambda p: p["overhead"].update(n_recorded_sampled=0),
     "overhead.n_recorded_sampled"),
    (lambda p: p["overhead"].update(n_recorded_sampled=5000),
     "recorded more requests than full"),
    (lambda p: p.pop("drift"), "missing top-level section 'drift'"),
    (lambda p: p["drift"].update(n_joined=0), "drift.n_joined"),
    (lambda p: p["drift"].update(n_pred_saturated=-1),
     "drift.n_pred_saturated"),
    (lambda p: p["drift"].update(reconcile_max_abs_s=1e-3),
     "stage sums diverge from measured latency"),
    (lambda p: p["drift"].update(stages={}),
     "drift.stages must be a non-empty object"),
    (lambda p: p["drift"]["stages"]["queue_s"].pop("p95_err"),
     "drift.stages['queue_s'] missing 'p95_err'"),
    (lambda p: p["drift"]["stages"]["uplink_s"].update(
        mean_err=float("nan")), "drift.stages['uplink_s'].mean_err"),
    (lambda p: p["drift"]["stages"]["edge_s"].update(n=0),
     "drift.stages['edge_s'].n"),
    (lambda p: p.pop("delta"), "missing top-level section 'delta'"),
    (lambda p: p.update(delta={}), "'delta' must be a non-empty object"),
    (lambda p: p["delta"].update(resync_every=0), "delta.resync_every"),
    (lambda p: p["delta"]["scenes"].pop("dynamic"),
     "delta.scenes missing 'dynamic'"),
    (lambda p: p["delta"]["scenes"]["static"].update(ratio_vs_int4=0.0),
     "delta.scenes['static'].ratio_vs_int4"),
    (lambda p: p["delta"]["scenes"]["slow"].update(keyframe_rate=1.5),
     "keyframe_rate out of [0, 1]"),
    (lambda p: p["delta"]["scenes"]["dynamic"].update(n_keyframes=-1),
     "delta.scenes['dynamic'].n_keyframes"),
    (lambda p: p["delta"]["drift"].pop("rel_err"),
     "delta.drift missing 'rel_err'"),
    (lambda p: p["delta"]["drift"].update(rel_err=0.9),
     "exceeds its recorded tolerance"),
])
def test_schema_violations_are_reported(mutate, needle):
    payload = _valid_payload()
    mutate(payload)
    errs = cbs.check(payload)
    assert errs, f"expected an error containing {needle!r}"
    assert any(needle in e for e in errs), errs


def _run_schema_main(tmp_path, payload, monkeypatch):
    p = tmp_path / "BENCH_fleet.json"
    p.write_text(json.dumps(payload))
    monkeypatch.setattr(sys, "argv",
                        ["check_bench_schema.py", "--path", str(p)])
    return cbs.main()


def test_schema_main_exit_codes(tmp_path, monkeypatch, capsys):
    assert _run_schema_main(tmp_path, _valid_payload(), monkeypatch) == 0
    out = capsys.readouterr().out
    assert f"schema v{cbs.EXPECTED_SCHEMA_VERSION} OK" in out

    bad = _valid_payload()
    bad["scale"]["p999_s"] = -1.0
    assert _run_schema_main(tmp_path, bad, monkeypatch) == 1
    assert "scale percentiles" in capsys.readouterr().err


def test_schema_main_unreadable_file(tmp_path, monkeypatch, capsys):
    p = tmp_path / "nope.json"
    monkeypatch.setattr(sys, "argv",
                        ["check_bench_schema.py", "--path", str(p)])
    assert cbs.main() == 1
    assert "cannot read/parse" in capsys.readouterr().err
    p.write_text("{not json")
    assert cbs.main() == 1


# ------------------------------------------------------------- doc links
def _mini_repo(tmp_path):
    """A tiny repo layout exercising every resolution rule: repo-root
    refs, the src/repro shorthand, sibling refs, docs/ refs and
    bare-basename refs."""
    (tmp_path / "src" / "repro" / "core").mkdir(parents=True)
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text("see [design](docs/DESIGN.md)\n")
    (tmp_path / "docs" / "DESIGN.md").write_text("covers core/util.py\n")
    (tmp_path / "src" / "repro" / "core" / "util.py").write_text(
        '"""Helper; see sibling core/extra.py and README.md."""\n')
    (tmp_path / "src" / "repro" / "core" / "extra.py").write_text(
        '"""Bare basename ref: util.py resolves anywhere."""\n')
    return tmp_path


def test_doc_links_clean_repo_passes(tmp_path):
    assert cdl.check(str(_mini_repo(tmp_path))) == []


def test_doc_links_dangling_docstring_ref_fails(tmp_path):
    root = _mini_repo(tmp_path)
    (root / "src" / "repro" / "core" / "bad.py").write_text(
        '"""Cites core/missing_forever.py which does not exist."""\n')
    errors = cdl.check(str(root))
    assert len(errors) == 1
    assert "missing_forever.py" in errors[0]
    assert "bad.py:1" in errors[0]


def test_doc_links_dangling_markdown_link_fails(tmp_path):
    root = _mini_repo(tmp_path)
    (root / "docs" / "NOTES.md").write_text(
        "line one fine\nsee [gone](docs/GONE.md) here\n")
    errors = cdl.check(str(root))
    assert len(errors) == 1
    assert "GONE.md" in errors[0] and "NOTES.md:2" in errors[0]


def test_doc_links_urls_are_ignored(tmp_path):
    root = _mini_repo(tmp_path)
    (root / "docs" / "LINKS.md").write_text(
        "[ext](https://example.com/paper.py) is out of scope\n")
    assert cdl.check(str(root)) == []


def test_doc_links_main_exit_codes(tmp_path, monkeypatch, capsys):
    root = _mini_repo(tmp_path)
    monkeypatch.setattr(sys, "argv",
                        ["check_doc_links.py", "--root", str(root)])
    assert cdl.main() == 0
    assert "doc links OK" in capsys.readouterr().out
    (root / "docs" / "BAD.md").write_text("[x](docs/NOPE.md)\n")
    assert cdl.main() == 1
    err = capsys.readouterr()
    assert "unresolved repo-file reference" in err.err + err.out


def test_doc_links_checker_passes_on_this_repo():
    """The real repo must stay clean — the same invocation CI runs."""
    root = os.path.join(os.path.dirname(__file__), "..")
    assert cdl.check(os.path.abspath(root)) == []


# ---------------------------------------------------------- doc coverage
def _cov_repo(tmp_path, doc="n_robots tick_s identity int8 delta"):
    """Minimal source tree the pure-ast extractor understands: a
    FleetConfig dataclass (one private field, which must be ignored) and
    a make_codecs registry (dict literal + subscript registration)."""
    (tmp_path / "src" / "repro" / "runtime").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "core").mkdir(parents=True)
    (tmp_path / "docs").mkdir()
    (tmp_path / "src" / "repro" / "runtime" / "fleet.py").write_text(
        textwrap.dedent("""\
            from dataclasses import dataclass


            @dataclass(frozen=True)
            class FleetConfig:
                n_robots: int = 8
                tick_s: float = 0.05
                _cache: object = None
        """))
    (tmp_path / "src" / "repro" / "core" / "codec.py").write_text(
        textwrap.dedent("""\
            def make_codecs():
                out = {"identity": 1, "int8": 2}
                out["delta"] = 3
                return out
        """))
    (tmp_path / "docs" / "DESIGN.md").write_text(doc + "\n")
    (tmp_path / "README.md").write_text("overview\n")
    return tmp_path


def test_doc_coverage_clean_repo_passes(tmp_path):
    assert cdc.check(str(_cov_repo(tmp_path))) == []


@pytest.mark.parametrize("doc,needle", [
    ("n_robots identity int8 delta", "FleetConfig.tick_s"),
    ("n_robots tick_s identity int8", "codec 'delta'"),
    ("n_robots tick_s int8 delta", "codec 'identity'"),
    # substring hits must not count as mentions (word-boundary match)
    ("n_robots_per_cell tick_s identity int8 delta",
     "FleetConfig.n_robots"),
])
def test_doc_coverage_undocumented_name_fails(tmp_path, doc, needle):
    errors = cdc.check(str(_cov_repo(tmp_path, doc=doc)))
    assert len(errors) == 1, errors
    assert needle in errors[0]


def test_doc_coverage_private_fields_ignored(tmp_path):
    """``_cache`` is never required — and never satisfied either."""
    errors = cdc.check(str(_cov_repo(tmp_path)))
    assert not any("_cache" in e for e in errors)


def test_doc_coverage_readme_mentions_count(tmp_path):
    root = _cov_repo(tmp_path, doc="identity int8 delta tick_s")
    (root / "README.md").write_text("the n_robots knob\n")
    assert cdc.check(str(root)) == []


def test_doc_coverage_missing_sources_reported(tmp_path):
    root = _cov_repo(tmp_path)
    (root / "src" / "repro" / "runtime" / "fleet.py").write_text(
        "class SomethingElse:\n    pass\n")
    errors = cdc.check(str(root))
    assert any("'FleetConfig' not found" in e for e in errors)


def test_doc_coverage_main_exit_codes(tmp_path, monkeypatch, capsys):
    root = _cov_repo(tmp_path)
    monkeypatch.setattr(sys, "argv",
                        ["check_doc_coverage.py", "--root", str(root)])
    assert cdc.main() == 0
    assert "doc coverage OK" in capsys.readouterr().out
    (root / "docs" / "DESIGN.md").write_text("n_robots identity int8\n")
    assert cdc.main() == 1
    err = capsys.readouterr()
    assert "undocumented public name(s)" in err.err + err.out


def test_doc_coverage_checker_passes_on_this_repo():
    """The real repo must stay clean — the same invocation CI runs."""
    root = os.path.join(os.path.dirname(__file__), "..")
    assert cdc.check(os.path.abspath(root)) == []
