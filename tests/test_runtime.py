"""Runtime: partition executor equivalence, serving, scheduler, fault."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.models.transformer import lm_hidden, lm_logits
from repro.runtime.partition import (LMSplitExecutor, SplitPlan,
                                     VLASplitExecutor, payload_bytes)
from repro.runtime.scheduler import (ElasticPool, MicroBatcher, Request,
                                     StragglerMitigator)
from repro.runtime.serving import greedy_generate


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_config("llama3.2-3b").reduced().replace(n_layers=6,
                                                      dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    h, _ = lm_hidden(cfg, params, tokens, remat=False)
    ref = lm_logits(cfg, params, h)
    return cfg, model, params, tokens, ref


def test_lm_split_equivalence_all_pool_positions(lm_setup):
    cfg, model, params, tokens, ref = lm_setup
    ex = LMSplitExecutor(cfg, SplitPlan(2, 5))
    for split in range(2, 6):
        logits, payload = ex.run(params, tokens, split)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_lm_split_codec_halves_payload(lm_setup):
    cfg, model, params, tokens, ref = lm_setup
    raw = LMSplitExecutor(cfg, SplitPlan(2, 5))
    qz = LMSplitExecutor(cfg, SplitPlan(2, 5, use_codec=True))
    _, p_raw = raw.run(params, tokens, 3)
    logits, p_q = qz.run(params, tokens, 3)
    assert payload_bytes(p_q) < 0.6 * payload_bytes(p_raw)
    rel = float(jnp.max(jnp.abs(logits - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 0.05     # int8 cut tensor stays within a few percent


def test_moe_split_equivalence():
    cfg = get_config("granite-moe-3b-a800m").reduced().replace(
        n_layers=4, dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                cfg.vocab_size)
    h, _ = lm_hidden(cfg, params, tokens, remat=False)
    ref = lm_logits(cfg, params, h)
    ex = LMSplitExecutor(cfg, SplitPlan(1, 3))
    logits, _ = ex.run(params, tokens, 2)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_vla_split_equivalence():
    cfg = get_config("cogact-7b").reduced().replace(n_layers=4)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    patches = jax.random.normal(key, (2, cfg.n_patches, cfg.vit_dim))
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    ref = model.forward(params, {"patches": patches, "tokens": tokens}, key)
    Lv = cfg.vit_layers
    ex = VLASplitExecutor(cfg, SplitPlan(Lv + 1, Lv + 3))
    act, _ = ex.run(params, patches, tokens, Lv + 2, key)
    np.testing.assert_allclose(np.asarray(act), np.asarray(ref), atol=1e-5)


def test_greedy_generate():
    cfg = get_config("llama3.2-3b").reduced().replace(n_layers=2)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                cfg.vocab_size)
    out = greedy_generate(model, params, {"tokens": tokens}, n_steps=5)
    assert out.shape == (2, 5)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


# ------------------------------------------------------------- scheduler
def test_microbatcher_forms_on_size_and_timeout():
    mb = MicroBatcher(batch_size=3, max_wait_s=0.5)
    mb.add(Request(0, 0.0, 4))
    assert mb.maybe_form(0.1) is None
    mb.add(Request(1, 0.1, 4))
    mb.add(Request(2, 0.1, 4))
    b = mb.maybe_form(0.2)
    assert b is not None and len(b.requests) == 3
    mb.add(Request(3, 1.0, 4))
    assert mb.maybe_form(1.1) is None
    b2 = mb.maybe_form(1.6)          # timeout fires
    assert b2 is not None and len(b2.requests) == 1


def test_straggler_hedging_prefers_fast_replica():
    sm = StragglerMitigator()
    lat = {"fast": 0.01, "slow": 0.10}
    seq = {"n": 0}

    def exec_fn(r):
        seq["n"] += 1
        # one tail event on 'fast' after warmup
        if r == "fast" and seq["n"] == 30:
            return 1.0
        return lat[r]

    outs = [sm.run(["fast", "slow"], exec_fn) for _ in range(40)]
    assert sum(o.hedged for o in outs) >= 1
    hedged = [o for o in outs if o.hedged]
    assert all(o.latency_s < 1.0 for o in hedged)  # hedge rescued the tail
    # before the tail event, routing should prefer the fast replica
    assert all(o.replica == "fast" for o in outs[5:29])


def test_elastic_pool_detects_loss():
    events = []
    pool = ElasticPool(on_change=lambda live: events.append(tuple(live)),
                       timeout_s=1.0)
    pool.heartbeat("edge", 0.0)
    pool.heartbeat("cloud", 0.0)
    assert pool.live(0.5) == ["cloud", "edge"]
    pool.heartbeat("cloud", 2.0)     # edge went silent
    assert pool.live(2.0) == ["cloud"]
    assert events[-1] == ("cloud",)
