"""Runtime: partition executor equivalence, serving, scheduler, fault."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.models.transformer import lm_hidden, lm_logits
from repro.runtime.partition import (LMSplitExecutor, SplitPlan,
                                     VLASplitExecutor, chunk_payload,
                                     merge_chunks, payload_bytes)
from repro.runtime.scheduler import (ElasticPool, MicroBatcher, Request,
                                     StragglerMitigator)
from repro.runtime.serving import greedy_generate


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_config("llama3.2-3b").reduced().replace(n_layers=6,
                                                      dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                cfg.vocab_size)
    h, _ = lm_hidden(cfg, params, tokens, remat=False)
    ref = lm_logits(cfg, params, h)
    return cfg, model, params, tokens, ref


def test_lm_split_equivalence_all_pool_positions(lm_setup):
    cfg, model, params, tokens, ref = lm_setup
    ex = LMSplitExecutor(cfg, SplitPlan(2, 5))
    for split in range(2, 6):
        logits, payload = ex.run(params, tokens, split)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_lm_split_codec_halves_payload(lm_setup):
    cfg, model, params, tokens, ref = lm_setup
    raw = LMSplitExecutor(cfg, SplitPlan(2, 5))
    qz = LMSplitExecutor(cfg, SplitPlan(2, 5, codec="int8"))
    _, p_raw = raw.run(params, tokens, 3)
    logits, p_q = qz.run(params, tokens, 3)
    assert payload_bytes(p_q) < 0.6 * payload_bytes(p_raw)
    rel = float(jnp.max(jnp.abs(logits - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 0.05     # int8 cut tensor stays within a few percent


def test_split_plan_use_codec_deprecation_shim():
    """``use_codec`` stays a working alias for one release — but warns."""
    with pytest.warns(DeprecationWarning, match="use_codec"):
        plan = SplitPlan(2, 5, use_codec=True)
    assert plan.wire_codec == "int8"
    with pytest.warns(DeprecationWarning):
        plan_off = SplitPlan(2, 5, use_codec=False)
    assert plan_off.wire_codec == ""
    # the replacement spelling warns nothing
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        assert SplitPlan(2, 5, codec="int8").wire_codec == "int8"


def test_lm_two_pool_equivalence_all_cut_pairs(lm_setup):
    """Two-pool (edge→cloud→edge) forward must match the monolithic
    forward for EVERY (split, split2) inside the pools — and moving either
    cut must not retrigger compilation (the cuts are traced arguments)."""
    cfg, model, params, tokens, ref = lm_setup
    traces = {"edge": 0, "mid": 0, "tail": 0}
    ex = LMSplitExecutor(cfg, SplitPlan(1, 3, pool2_start=4, pool2_end=6))

    orig_edge, orig_mid, orig_tail = (ex._edge_fwd, ex._cloud_mid_fwd,
                                      ex._tail_fwd)

    def count(name, fn):
        def wrapped(*a):
            traces[name] += 1
            return fn(*a)
        return wrapped

    ex._edge = jax.jit(count("edge", orig_edge))
    ex._cloud_mid = jax.jit(count("mid", orig_mid))
    ex._tail = jax.jit(count("tail", orig_tail))
    for split in range(1, 4):
        for split2 in range(4, 7):
            logits, payloads = ex.run(params, tokens, split, split2)
            assert set(payloads) == {"up", "down"}
            np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)
    # one trace per function across all 9 (split, split2) pairs
    assert traces == {"edge": 1, "mid": 1, "tail": 1}


def test_vla_two_pool_equivalence():
    cfg = get_config("cogact-7b").reduced().replace(n_layers=6)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    patches = jax.random.normal(key, (2, cfg.n_patches, cfg.vit_dim))
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    ref = model.forward(params, {"patches": patches, "tokens": tokens}, key)
    Lv = cfg.vit_layers
    ex = VLASplitExecutor(cfg, SplitPlan(Lv + 1, Lv + 2,
                                         pool2_start=Lv + 4,
                                         pool2_end=Lv + 6))
    for split in (Lv + 1, Lv + 2):
        for split2 in (Lv + 4, Lv + 5, Lv + 6):
            act, payloads = ex.run(params, patches, tokens, split, key,
                                   split2=split2)
            assert set(payloads) == {"up", "down"}
            np.testing.assert_allclose(np.asarray(act), np.asarray(ref),
                                       atol=1e-5)


def test_vla_two_pool_codec_payloads():
    """Downlink codec ships a real compressed payload and the edge-tail
    action stays close to the monolithic reference."""
    cfg = get_config("cogact-7b").reduced().replace(n_layers=6)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    patches = jax.random.normal(key, (2, cfg.n_patches, cfg.vit_dim))
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    ref = model.forward(params, {"patches": patches, "tokens": tokens}, key)
    Lv = cfg.vit_layers
    raw = VLASplitExecutor(cfg, SplitPlan(Lv + 1, Lv + 2,
                                          pool2_start=Lv + 4,
                                          pool2_end=Lv + 6))
    qz = VLASplitExecutor(cfg, SplitPlan(Lv + 1, Lv + 2, codec="int8",
                                         pool2_start=Lv + 4,
                                         pool2_end=Lv + 6, codec2="int8"))
    _, p_raw = raw.run(params, patches, tokens, Lv + 2, key, split2=Lv + 5)
    act, p_q = qz.run(params, patches, tokens, Lv + 2, key, split2=Lv + 5)
    assert payload_bytes(p_q["up"]) < 0.6 * payload_bytes(p_raw["up"])
    assert payload_bytes(p_q["down"]) < 0.6 * payload_bytes(p_raw["down"])
    np.testing.assert_allclose(np.asarray(act), np.asarray(ref), atol=0.2)


def test_vla_two_pool_semantic_downlink_slice():
    """A degenerate pool 2 at the graph end makes the tail exactly the
    action stage: the downlink ships only the semantic conditioning slice
    (the bytes the planner prices via in_transfer_bytes), not the full
    sequence — and the action still matches the monolithic forward."""
    cfg = get_config("cogact-7b").reduced().replace(n_layers=6)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    patches = jax.random.normal(key, (2, cfg.n_patches, cfg.vit_dim))
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    ref = model.forward(params, {"patches": patches, "tokens": tokens}, key)
    Lv, L = cfg.vit_layers, cfg.vit_layers + cfg.n_layers
    ex = VLASplitExecutor(cfg, SplitPlan(Lv + 1, Lv + 3,
                                         pool2_start=L, pool2_end=L))
    act, payloads = ex.run(params, patches, tokens, Lv + 2, key)
    np.testing.assert_allclose(np.asarray(act), np.asarray(ref), atol=1e-5)
    # DiT head reads the single cognition token: 1 × d_model on the wire
    seq = cfg.n_patches + tokens.shape[1]
    assert payloads["down"]["x"].shape[1] == 1
    assert payload_bytes(payloads["down"]) < payload_bytes(payloads["up"]) / seq * 2


def test_chunk_payload_partitions_bytes_and_merges_exactly():
    """Chunk slices partition the payload bytes exactly and reassemble
    bit-identically — for raw, int8 and int4 wire formats."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 13, 256))
    from repro.runtime.partition import decode_activation, encode_activation
    for codec in ("", "int8", "int4"):
        payload = encode_activation(x.astype(jnp.bfloat16), codec)
        for k in (1, 2, 4, 13, 20):          # incl. empty chunks (k > S)
            chunks = chunk_payload(payload, k)
            assert len(chunks) == k
            assert sum(payload_bytes(c) for c in chunks) == \
                payload_bytes(payload)
            merged = merge_chunks(chunks)
            ref = decode_activation(payload)
            got = decode_activation(merged)
            assert np.array_equal(np.asarray(ref), np.asarray(got)), \
                (codec, k)


def test_lm_run_streamed_bit_identical_no_retrace(lm_setup):
    """Streamed transport must change NOTHING numerically — chunked
    shipping reassembles to the exact payload — and the jitted forwards
    must not retrace when the chunk count changes between requests."""
    cfg, model, params, tokens, ref = lm_setup
    traces = {"edge": 0, "cloud": 0}
    ex = LMSplitExecutor(cfg, SplitPlan(2, 5, codec="int8"))
    orig_edge, orig_cloud = ex._edge_fwd, ex._cloud_fwd

    def count(name, fn):
        def wrapped(*a):
            traces[name] += 1
            return fn(*a)
        return wrapped

    ex._edge = jax.jit(count("edge", orig_edge))
    ex._cloud = jax.jit(count("cloud", orig_cloud))
    base, payload = ex.run(params, tokens, 3)
    for k in (1, 2, 3, 5, 12):
        logits, chunks = ex.run_streamed(params, tokens, 3, k)
        assert len(chunks) == k
        assert np.array_equal(np.asarray(logits), np.asarray(base)), k
        assert sum(payload_bytes(c) for c in chunks) == \
            payload_bytes(payload)
    # one trace per function across the monolithic run AND all chunk
    # counts — the chunk count never reaches a traced function
    assert traces == {"edge": 1, "cloud": 1}


def test_lm_two_pool_run_streamed_bit_identical(lm_setup):
    cfg, model, params, tokens, ref = lm_setup
    ex = LMSplitExecutor(cfg, SplitPlan(1, 3, pool2_start=4, pool2_end=6))
    base, _ = ex.run(params, tokens, 2, split2=5)
    logits, payloads = ex.run_streamed(params, tokens, 2, 4, split2=5)
    assert np.array_equal(np.asarray(logits), np.asarray(base))
    assert isinstance(payloads["up"], list) and len(payloads["up"]) == 4
    assert isinstance(payloads["down"], dict)  # small tail never streams


def test_vla_run_streamed_bit_identical():
    cfg = get_config("cogact-7b").reduced().replace(n_layers=6)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    patches = jax.random.normal(key, (2, cfg.n_patches, cfg.vit_dim))
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    Lv = cfg.vit_layers
    ex = VLASplitExecutor(cfg, SplitPlan(Lv + 1, Lv + 3, codec="int8"))
    base, _ = ex.run(params, patches, tokens, Lv + 2, key)
    for k in (1, 3, 8):
        act, chunks = ex.run_streamed(params, patches, tokens, Lv + 2, k,
                                      key=key)
        assert np.array_equal(np.asarray(act), np.asarray(base)), k
        assert len(chunks) == k


def test_moe_split_equivalence():
    cfg = get_config("granite-moe-3b-a800m").reduced().replace(
        n_layers=4, dtype="float32")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                cfg.vocab_size)
    h, _ = lm_hidden(cfg, params, tokens, remat=False)
    ref = lm_logits(cfg, params, h)
    ex = LMSplitExecutor(cfg, SplitPlan(1, 3))
    logits, _ = ex.run(params, tokens, 2)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_vla_split_equivalence():
    cfg = get_config("cogact-7b").reduced().replace(n_layers=4)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    patches = jax.random.normal(key, (2, cfg.n_patches, cfg.vit_dim))
    tokens = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    ref = model.forward(params, {"patches": patches, "tokens": tokens}, key)
    Lv = cfg.vit_layers
    ex = VLASplitExecutor(cfg, SplitPlan(Lv + 1, Lv + 3))
    act, _ = ex.run(params, patches, tokens, Lv + 2, key)
    np.testing.assert_allclose(np.asarray(act), np.asarray(ref), atol=1e-5)


def test_greedy_generate():
    cfg = get_config("llama3.2-3b").reduced().replace(n_layers=2)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                cfg.vocab_size)
    out = greedy_generate(model, params, {"tokens": tokens}, n_steps=5)
    assert out.shape == (2, 5)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


# ------------------------------------------------------------- scheduler
def test_microbatcher_forms_on_size_and_timeout():
    mb = MicroBatcher(batch_size=3, max_wait_s=0.5)
    mb.add(Request(0, 0.0, 4))
    assert mb.maybe_form(0.1) is None
    mb.add(Request(1, 0.1, 4))
    mb.add(Request(2, 0.1, 4))
    b = mb.maybe_form(0.2)
    assert b is not None and len(b.requests) == 3
    mb.add(Request(3, 1.0, 4))
    assert mb.maybe_form(1.1) is None
    b2 = mb.maybe_form(1.6)          # timeout fires
    assert b2 is not None and len(b2.requests) == 1


def test_straggler_hedging_prefers_fast_replica():
    sm = StragglerMitigator()
    lat = {"fast": 0.01, "slow": 0.10}
    seq = {"n": 0}

    def exec_fn(r):
        seq["n"] += 1
        # one tail event on 'fast' after warmup
        if r == "fast" and seq["n"] == 30:
            return 1.0
        return lat[r]

    outs = [sm.run(["fast", "slow"], exec_fn) for _ in range(40)]
    assert sum(o.hedged for o in outs) >= 1
    hedged = [o for o in outs if o.hedged]
    assert all(o.latency_s < 1.0 for o in hedged)  # hedge rescued the tail
    # before the tail event, routing should prefer the fast replica
    assert all(o.replica == "fast" for o in outs[5:29])


def test_elastic_pool_detects_loss():
    events = []
    pool = ElasticPool(on_change=lambda live: events.append(tuple(live)),
                       timeout_s=1.0)
    pool.heartbeat("edge", 0.0)
    pool.heartbeat("cloud", 0.0)
    assert pool.live(0.5) == ["cloud", "edge"]
    pool.heartbeat("cloud", 2.0)     # edge went silent
    assert pool.live(2.0) == ["cloud"]
    assert events[-1] == ("cloud",)
