"""Multi-device integration: these spawn a subprocess with 8 fake host
devices (the flag must be set before jax init, so in-process is impossible).

Covers: int8 ring all-reduce == exact sum; sharded train_step on a 2x4 mesh;
MoE expert-parallel shard_map == single-device reference.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(body: str) -> str:
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n" + body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_ring_allreduce_int8_sums():
    print(_run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.train.compression import ring_allreduce_int8
mesh = make_mesh((8,), ("data",))
x = jnp.stack([jnp.full((33,), float(i + 1)) for i in range(8)])  # (8, 33)
def f(xs):
    return ring_allreduce_int8(xs[0], "data")
y = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("data", None),),
                      out_specs=P(None)))(x)
expect = float(sum(range(1, 9)))
err = float(jnp.max(jnp.abs(y - expect)))
assert err < 0.25, err   # int8 ring quantisation noise bound
print("ring ok", err)
"""))


def test_sharded_train_step_2x4():
    print(_run("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import build
from repro.models.sharding import make_rules, sharding_tree, use_mesh
from repro.train.optimizer import OptConfig
from repro.train.train_loop import init_state, make_train_step
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_config("llama3.2-3b").reduced().replace(n_layers=2)
model = build(cfg)
rules = make_rules(cfg, mesh, "train")
with use_mesh(mesh, rules):
    params = model.init(jax.random.PRNGKey(0))
    shard_p = sharding_tree(model.param_specs, mesh, rules)
    params = jax.tree_util.tree_map(jax.device_put, params, shard_p)
    state = init_state(params)
    step = jax.jit(make_train_step(model, OptConfig(lr=1e-3)))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": jax.device_put(toks, NamedSharding(mesh, P("data", None))),
             "labels": jax.device_put(toks, NamedSharding(mesh, P("data", None)))}
    losses = []
    for i in range(5):
        state, m = step(state, batch, jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
print("sharded train ok", losses[0], losses[-1])
"""))


def test_moe_ep_matches_single_device():
    print(_run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.moe import moe_ffn, moe_specs
from repro.models.sharding import init_params, make_rules, use_mesh, \
    sharding_tree
cfg = get_config("granite-moe-3b-a800m").reduced().replace(
    n_experts=8, moe_top_k=2, dtype="float32")
specs = moe_specs(cfg)
params = init_params(specs, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
# single-device reference
y_ref, aux_ref = moe_ffn(cfg, params, x)
# 1x8 mesh: experts sharded over model
from repro.compat import make_mesh
mesh = make_mesh((1, 8), ("data", "model"))
rules = make_rules(cfg, mesh, "train")
with use_mesh(mesh, rules):
    shard_p = sharding_tree(specs, mesh, rules)
    params_s = jax.tree_util.tree_map(jax.device_put, params, shard_p)
    y_ep, aux_ep = jax.jit(lambda p, x: moe_ffn(cfg, p, x))(params_s, x)
err = float(jnp.max(jnp.abs(y_ep - y_ref)))
# capacity differs only if token count differs; same tokens => identical
assert err < 1e-4, err
print("moe ep ok", err)
"""))


def test_dryrun_module_entrypoint_tiny():
    """The real dryrun module runs end to end on a shrunken mesh."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               DRYRUN_XLA_FLAGS="--xla_force_host_platform_device_count=8")
    code = """
import repro.launch.dryrun as dr
import repro.launch.mesh as mm
from repro.compat import make_mesh
def small(*, multi_pod=False):
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
dr.make_production_mesh = small
import repro.configs as C
C.ARCHS["mamba2-1.3b"] = C.get_config("mamba2-1.3b").replace(n_layers=2)
res = dr._cell("mamba2-1.3b", "long_500k", True)
assert res["status"] == "ok", res
print("tiny dryrun ok", res["roofline"]["dominant"])
"""
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "tiny dryrun ok" in out.stdout
