"""Streamed split execution: makespan model, planner parity on every
config, K=1 ≡ non-streamed exactness, chunked adjustment/controller, the
overlap-aware fleet, and the satellite regressions (trace-integrating
transfers, vectorized generate_trace)."""
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.core import (DEFAULT_CHUNK_GRID, NetworkSim, PlacementPlan,
                        RoboECC, Thresholds, TraceConfig, Workload,
                        adjust_placement, build_graph, build_pool,
                        chunk_sizes, evaluate_placement, generate_trace,
                        search_multicut, search_streamed,
                        search_streamed_scalar, stream_applies,
                        stream_bubble_fraction, stream_makespan,
                        stream_makespan_scalar, sweep_multicut)
from repro.core.hardware import A100, ORIN
from repro.runtime.fleet import FleetConfig, run_fleet

W = Workload()
BWS = np.geomspace(0.1e6, 40e6, 4)
AXIS = ("identity", "int8", "int4")
QUOTA = 5.8e9
DOWN = 8.0
GRID = (1, 2, 4, 8)


@pytest.fixture(scope="module")
def graphs():
    return {k: build_graph(get_config(k), W) for k in sorted(ARCHS)}


# ---------------------------------------------------------- makespan model
def test_makespan_k1_is_sequential_sum():
    assert stream_makespan_scalar(0.01, 0.5, 0.2, 1, rtt_s=0.005) == \
        pytest.approx(0.01 + 0.5 + 0.005 + 0.2, rel=1e-15)
    assert float(stream_makespan(0.01, 0.5, 0.2, 1, 0.005)) == \
        pytest.approx(0.01 + 0.5 + 0.005 + 0.2, rel=1e-15)


def test_makespan_recurrence_matches_closed_form():
    rng = np.random.default_rng(0)
    for _ in range(200):
        enc, wire, comp = rng.uniform(0, 0.5, 3)
        rtt = rng.uniform(0, 0.02)
        for k in (1, 2, 3, 4, 8, 16):
            rec = stream_makespan_scalar(enc, wire, comp, k, rtt)
            closed = float(stream_makespan(enc, wire, comp, k, rtt))
            assert rec == pytest.approx(closed, rel=1e-12), (enc, wire,
                                                             comp, k, rtt)


def test_makespan_overlap_bounds():
    """Pipelining can never beat the bottleneck stage nor lose to the
    sequential sum (at zero per-chunk overhead)."""
    enc, wire, comp = 0.01, 0.4, 0.3
    seq = enc + wire + comp
    for k in (2, 4, 8):
        m = stream_makespan_scalar(enc, wire, comp, k, rtt_s=0.0)
        assert max(enc, wire, comp) <= m <= seq
    # with per-chunk rtt, heavy chunking of a transfer-bound pipe loses
    m16 = stream_makespan_scalar(0.0, 0.1, 0.0, 16, rtt_s=0.01)
    assert m16 > 0.1 + 0.01  # 16 rtts serialize on the bottleneck wire


def test_makespan_non_uniform_chunks():
    """Per-chunk wire times (the fleet's trace-integrated transfers)."""
    b = [0.1, 0.3, 0.05]
    m = stream_makespan_scalar(0.03, b, 0.3, 3, rtt_s=0.0)
    # recurrence by hand: a=0.01, c=0.1
    t_enc = t_tx = t_out = 0.0
    for bi in b:
        t_enc += 0.01
        t_tx = max(t_enc, t_tx) + bi
        t_out = max(t_tx, t_out) + 0.1
    assert m == pytest.approx(t_out, rel=1e-15)
    with pytest.raises(ValueError):
        stream_makespan_scalar(0.0, [0.1, 0.2], 0.0, 3)


def test_bubble_fraction_shrinks_with_chunks():
    enc, wire, comp = 0.01, 0.4, 0.3
    fr = [float(stream_bubble_fraction(enc, wire, comp, k)) for k in
          (1, 2, 4, 8, 16)]
    assert all(0.0 <= f < 1.0 for f in fr)
    assert fr[-1] < fr[0]          # pipelining recovers fill/drain time
    assert float(stream_bubble_fraction(0.0, 0.0, 0.0, 4)) == 0.0


def test_chunk_sizes_partition():
    for total, k in ((12, 1), (12, 4), (13, 4), (3, 8), (0, 2)):
        sizes = chunk_sizes(total, k)
        assert len(sizes) == k and sum(sizes) == total
        assert max(sizes) - min(sizes) <= 1
    with pytest.raises(ValueError):
        chunk_sizes(10, 0)


def test_stream_applies_gate():
    assert stream_applies(3, 10, 100.0)
    assert not stream_applies(0, 10, 100.0)   # raw observation upload
    assert not stream_applies(10, 10, 0.0)    # edge-only, no traffic
    assert not stream_applies(5, 10, 0.0)     # zero-byte cut


# ----------------------------------------------------------- oracle parity
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_streamed_vectorized_matches_scalar_oracle_every_config(arch,
                                                                graphs):
    """The vectorized (C, S1, S2, K, B) pass must return the identical
    (cuts, codec, chunks) plan to the exhaustive scalar makespan oracle on
    every registered config — the streaming acceptance gate."""
    g = graphs[arch]
    res = search_streamed(g, ORIN, A100, BWS, QUOTA, codecs=AXIS,
                          chunk_grid=GRID, rtt_s=0.005,
                          input_bytes=W.input_bytes, down_bw_factor=DOWN)
    for j, bw in enumerate(BWS):
        sc = search_streamed_scalar(
            g, ORIN, A100, float(bw), QUOTA, codecs=AXIS, chunk_grid=GRID,
            rtt_s=0.005, input_bytes=W.input_bytes, down_bw_factor=DOWN)
        assert res.plan_at(j) == sc.plan, (arch, bw)
        assert int(res.n_chunks[j]) == sc.n_chunks, (arch, bw)
        assert res.total_s[j] == pytest.approx(sc.total_s, rel=1e-9)


def test_streamed_unbudgeted_and_single_cut_parity(graphs):
    g = graphs["openvla-7b"]
    for budget in (None, QUOTA):
        for sco in (False, True):
            res = search_streamed(g, ORIN, A100, BWS, budget, codecs=AXIS,
                                  chunk_grid=GRID, rtt_s=0.005,
                                  input_bytes=W.input_bytes,
                                  down_bw_factor=DOWN, single_cut_only=sco)
            for j, bw in enumerate(BWS):
                sc = search_streamed_scalar(
                    g, ORIN, A100, float(bw), budget, codecs=AXIS,
                    chunk_grid=GRID, rtt_s=0.005,
                    input_bytes=W.input_bytes, down_bw_factor=DOWN,
                    single_cut_only=sco)
                assert res.plan_at(j) == sc.plan, (budget, sco, bw)
                assert res.total_s[j] == pytest.approx(sc.total_s,
                                                       rel=1e-9)


# ------------------------------------------------- K=1 ≡ non-streamed exact
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_chunk_grid_one_reproduces_multicut_exactly(arch, graphs):
    """chunk_grid=(1,) must reproduce the non-streamed search bit-for-bit
    — the K=1 plane is literally the shared sequential tensor."""
    g = graphs[arch]
    for budget in (None, QUOTA):
        st = search_streamed(g, ORIN, A100, BWS, budget, codecs=AXIS,
                             chunk_grid=(1,), rtt_s=0.005,
                             input_bytes=W.input_bytes, down_bw_factor=DOWN)
        mc = search_multicut(g, ORIN, A100, BWS, budget, codecs=AXIS,
                             rtt_s=0.005, input_bytes=W.input_bytes,
                             down_bw_factor=DOWN)
        assert np.array_equal(st.s1, mc.s1), (arch, budget)
        assert np.array_equal(st.s2, mc.s2)
        assert np.array_equal(st.codec_idx, mc.codec_idx)
        assert np.array_equal(st.total_s, mc.total_s)  # bitwise
        assert np.all(st.n_chunks == 1)
        assert np.all(st.bubble_frac == 0.0)


def test_evaluate_placement_streamed_chunks_one_is_exact(graphs):
    """streamed=True with all cut_chunks == 1 must price identically to
    streamed=False (K=1 is defined as the sequential path)."""
    g = graphs["openvla-7b"]
    n = len(g)
    for plan in (PlacementPlan.single(28, "int8"),
                 PlacementPlan.edge_cloud_edge(43, 57, "int4", "int4"),
                 PlacementPlan.single(n), PlacementPlan.single(0)):
        a = evaluate_placement(g, plan, ORIN, A100, 1e6, rtt_s=0.005,
                               input_bytes=W.input_bytes,
                               down_bw_factor=DOWN, streamed=False)
        b = evaluate_placement(g, plan, ORIN, A100, 1e6, rtt_s=0.005,
                               input_bytes=W.input_bytes,
                               down_bw_factor=DOWN, streamed=True)
        assert a.total_s == b.total_s and a.up_s == b.up_s
        assert b.n_chunks == 1 and b.bubble_frac == 0.0


def test_evaluate_placement_streamed_matches_oracle_components(graphs):
    """A streamed plan priced by evaluate_placement must agree with the
    scalar planner's pricing of the same (cuts, codec, chunks) cell."""
    g = graphs["openvla-7b"]
    for bw in (0.3e6, 1e6):
        sc = search_streamed_scalar(g, ORIN, A100, bw, QUOTA, codecs=AXIS,
                                    chunk_grid=GRID, rtt_s=0.005,
                                    input_bytes=W.input_bytes,
                                    down_bw_factor=DOWN)
        ev = evaluate_placement(g, sc.plan, ORIN, A100, bw, rtt_s=0.005,
                                input_bytes=W.input_bytes,
                                down_bw_factor=DOWN, streamed=True)
        assert ev.total_s == pytest.approx(sc.total_s, rel=1e-9)
        assert ev.n_chunks == sc.n_chunks
        if sc.n_chunks > 1:
            assert ev.bubble_frac == pytest.approx(sc.bubble_frac,
                                                   rel=1e-9)


def test_evaluate_placement_streamed_overlaps_only_the_fed_window(graphs):
    """A generalized plan with TWO cloud windows: chunked uplink overlap
    is bounded by the FIRST window's compute (the one the chunks feed) —
    later cloud segments cannot prefill data that hasn't been produced
    yet, so the streamed saving must never exceed window-1 compute plus
    the hidden codec compute."""
    from repro.core.hardware import layer_latency
    g = graphs["openvla-7b"]
    plan_seq = PlacementPlan(cuts=(30, 40, 50), cut_chunks=(1, 1, 1),
                             tiers=("edge", "cloud", "edge", "cloud"),
                             cut_codecs=("int8", None, "int8"))
    plan_st = PlacementPlan(cuts=(30, 40, 50), cut_chunks=(8, 1, 1),
                            tiers=("edge", "cloud", "edge", "cloud"),
                            cut_codecs=("int8", None, "int8"))
    kw = dict(rtt_s=0.005, input_bytes=W.input_bytes, down_bw_factor=DOWN)
    seq = evaluate_placement(g, plan_seq, ORIN, A100, 0.2e6, **kw)
    st = evaluate_placement(g, plan_st, ORIN, A100, 0.2e6, streamed=True,
                            **kw)
    window1 = sum(layer_latency(c, A100) for c in g[30:40])
    assert st.n_chunks == 8
    saving = seq.total_s - st.total_s
    assert saving <= window1 + 1e-9        # window 2 never overlaps
    assert st.total_s >= st.edge_s + st.cloud_s + st.down_s - 1e-12


def test_streamed_never_loses_to_sequential_in_the_model(graphs):
    """The chunk axis is a superset search: its optimum can only match or
    beat the non-streamed optimum at every bandwidth."""
    for arch in ("openvla-7b", "cogact-7b", "llama3.2-3b"):
        g = graphs[arch]
        st = search_streamed(g, ORIN, A100, BWS, QUOTA, codecs=AXIS,
                             chunk_grid=GRID, rtt_s=0.005,
                             input_bytes=W.input_bytes, down_bw_factor=DOWN)
        mc = search_multicut(g, ORIN, A100, BWS, QUOTA, codecs=AXIS,
                             rtt_s=0.005, input_bytes=W.input_bytes,
                             down_bw_factor=DOWN)
        assert np.all(st.total_s <= mc.total_s + 1e-12), arch


def test_chunk_count_drifts_with_bandwidth_and_overchunking_loses(graphs):
    """The performance-drift story on the chunk axis: the optimal chunk
    count moves with bandwidth (why the controller replans it from the
    forecast), and a FIXED over-chunked plan is strictly worse than the
    sequential transfer on a transfer-bound link — per-chunk rtt is pure
    overhead once there is nothing left to overlap (the honest negative
    result recorded in docs/EXPERIMENTS.md §Streaming)."""
    g = graphs["openvla-7b"]
    full = DEFAULT_CHUNK_GRID
    k_lo = int(search_streamed(g, ORIN, A100, [0.5e6], QUOTA, codecs=AXIS,
                               chunk_grid=full, rtt_s=0.005,
                               input_bytes=W.input_bytes,
                               down_bw_factor=DOWN).n_chunks[0])
    k_hi = int(search_streamed(g, ORIN, A100, [5e6], QUOTA, codecs=AXIS,
                               chunk_grid=full, rtt_s=0.005,
                               input_bytes=W.input_bytes,
                               down_bw_factor=DOWN).n_chunks[0])
    assert k_lo > 1 and k_hi > 1 and k_lo != k_hi   # the optimum drifts

    def total(k):
        plan = PlacementPlan.edge_cloud_edge(43, 57, "int4", "int4",
                                             up_chunks=k)
        return evaluate_placement(g, plan, ORIN, A100, 0.2e6, rtt_s=0.005,
                                  input_bytes=W.input_bytes,
                                  down_bw_factor=DOWN,
                                  streamed=True).total_s
    assert total(k_lo) < total(1)        # right chunking wins at 0.2 MB/s
    assert total(16) > total(1) + 0.02   # over-chunking loses > 20 ms


# --------------------------------------------------------- placement plans
def test_plan_carries_cut_chunks():
    n = 10
    p = PlacementPlan.edge_cloud_edge(3, 7, "int8", "int8", up_chunks=4)
    assert p.cut_chunks == (4, 1)
    assert p.primary_chunks(n) == 4
    assert p.normalize(n).cut_chunks == (4, 1)
    # collapsing the tail keeps the uplink's chunk count
    assert PlacementPlan.edge_cloud_edge(3, n, "int8", None, 4) \
        .normalize(n).cut_chunks == (4,)
    assert PlacementPlan.single(5).cut_chunks == (1,)
    with pytest.raises(ValueError):
        PlacementPlan(cuts=(3,), tiers=("edge", "cloud"), cut_chunks=(0,))
    with pytest.raises(ValueError):
        PlacementPlan(cuts=(3,), tiers=("edge", "cloud"),
                      cut_chunks=(2, 2))
    assert "x4" in p.describe(n)


def test_from_window_pins_chunks_on_degenerate_plans():
    n = 10
    assert PlacementPlan.from_window(3, 7, n, "int8", 4).cut_chunks == (4, 1)
    assert PlacementPlan.from_window(3, n, n, None, 4).cut_chunks == (4,)
    assert PlacementPlan.from_window(n, n, n, None, 4).cut_chunks == (1,)
    assert PlacementPlan.from_window(0, n, n, None, 4).cut_chunks == (1,)


# ------------------------------------------------------- adjustment layer
def test_adjust_placement_chunk_moves(graphs):
    g = graphs["openvla-7b"]
    n = len(g)
    pool = build_pool(g, 43)
    pool2 = build_pool(g, 57)
    cur = PlacementPlan.edge_cloud_edge(43, 57, "int4", "int4", up_chunks=1)
    thr = Thresholds(high=2e6, low=-2e6)
    # predicted drop: the joint argmin may answer with chunking — the
    # slow link hides behind the overlapped cloud-window prefill
    dn = adjust_placement(g, pool, cur, 0.3e6, 10e6, thr, pool2=pool2,
                          codecs=AXIS, edge=ORIN, cloud=A100,
                          down_bw_factor=DOWN, chunk_grid=DEFAULT_CHUNK_GRID,
                          rtt_s=0.005)
    assert dn.reason == "down"
    k_dn = dn.placement.primary_chunks(n)
    assert k_dn > 1
    # hold keeps the current plan (and its chunks) untouched
    hold = adjust_placement(g, pool, dn.placement, 10.05e6, 10e6, thr,
                            pool2=pool2, codecs=AXIS, edge=ORIN, cloud=A100,
                            down_bw_factor=DOWN,
                            chunk_grid=DEFAULT_CHUNK_GRID, rtt_s=0.005)
    assert hold.reason == "hold"
    assert hold.placement == dn.placement.normalize(n)
    # chunk_grid=None reduces exactly to the chunk-free adjuster
    legacy = adjust_placement(g, pool, cur, 0.3e6, 10e6, thr, pool2=pool2,
                              codecs=AXIS, edge=ORIN, cloud=A100,
                              down_bw_factor=DOWN)
    assert legacy.placement.cut_chunks == \
        (1,) * legacy.placement.n_cuts


def test_adjust_placement_up_sheds_chunks(graphs):
    """On a predicted rise to a fast link the per-chunk rtt dominates the
    vanished transfer, so the exploit move sheds chunking."""
    g = graphs["openvla-7b"]
    n = len(g)
    pool = build_pool(g, 43)
    pool2 = build_pool(g, 57)
    cur = PlacementPlan.edge_cloud_edge(43, 57, "int4", "int4",
                                        up_chunks=16)
    thr = Thresholds(high=2e6, low=-2e6)
    up = adjust_placement(g, pool, cur, 200e6, 10e6, thr, pool2=pool2,
                          codecs=AXIS, edge=ORIN, cloud=A100,
                          down_bw_factor=DOWN, chunk_grid=(1, 16),
                          rtt_s=0.005)
    assert up.reason == "up"
    assert up.placement.primary_chunks(n) < 16


# ------------------------------------------------------------- controller
def test_controller_streamed_end_to_end():
    cfg = get_config("openvla-7b")
    ctl = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=QUOTA,
                  nominal_bw_bps=1e6, codec="int4",
                  adjust_codecs=["identity", "int8", "int4"],
                  multicut=True, down_bw_factor=DOWN, streamed=True)
    n = len(ctl.graph)
    assert ctl.placement.primary_chunks(n) > 1   # 1 MB/s: chunking pays
    trace = generate_trace(1500, seed=1)
    ctl.fit_predictor(trace[:1000])
    net = NetworkSim(trace[1000:])
    net.step(40)
    res = [ctl.tick(net) for _ in range(20)]
    assert all(r.total_s > 0 for r in res)
    assert all(r.n_chunks >= 1 for r in res)
    assert any(r.n_chunks > 1 for r in res)


def test_controller_streamed_replans_chunks_from_forecast():
    """The LSTM forecast drives chunk replanning: on a synthetic cliff
    from 10 MB/s to 0.2 MB/s the predicted drop re-chunks the uplink."""
    cfg = get_config("openvla-7b")
    ctl = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=QUOTA,
                  nominal_bw_bps=10e6, codec="int4",
                  adjust_codecs=["int4"], multicut=True,
                  down_bw_factor=DOWN, streamed=True,
                  thresholds=Thresholds(high=2e6, low=-2e6))
    n = len(ctl.graph)
    trace = np.concatenate([np.full(600, 10e6), np.full(200, 0.2e6)])
    ctl.fit_predictor(generate_trace(1000, seed=2))
    net = NetworkSim(trace)
    net.step(590)
    ks = [ctl.tick(net).n_chunks for _ in range(60)]
    # once the window fills with 0.2 MB/s samples the forecast drops and
    # the ΔNB move answers with more chunks than the 10 MB/s plan used
    assert max(ks[20:]) > ks[0] or ks[0] > 1


def test_controller_streamed_replan_outage_and_recovery():
    cfg = get_config("openvla-7b")
    ctl = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=QUOTA,
                  nominal_bw_bps=1e6, codec="int4", multicut=True,
                  down_bw_factor=DOWN, streamed=True)
    n = len(ctl.graph)
    plan0 = ctl.placement
    dead = A100.with_eta(1e-12, 1e-12)
    ctl.replan(cloud=dead, nominal_bw_bps=1e6)
    assert ctl.split == n and ctl.placement.is_single
    assert ctl.placement.primary_chunks(n) == 1   # nothing to stream
    ctl.replan(cloud=A100, cloud_budget_bytes=QUOTA, nominal_bw_bps=1e6)
    assert ctl.placement == plan0


# ------------------------------------------------------------------ fleet
def _fleet_cfg(bw, streamed, **kw):
    trace = TraceConfig(mean_bps=bw, bad_bps=max(bw / 4, 0.2e6))
    return FleetConfig(n_robots=16, archs=("openvla-7b",), n_ticks=200,
                       n_replicas=3, seed=0, codecs=AXIS, trace=trace,
                       nominal_bw_bps=bw, cloud_budget_bytes=QUOTA,
                       multicut=True, down_bw_factor=DOWN,
                       streamed=streamed, **kw)


def test_fleet_streamed_beats_non_streamed_p95_at_low_bandwidth():
    """The tentpole fleet win: chunked streaming beats sequential
    transfers on fleet p95 at ≤ 1 MB/s on openvla-7b."""
    seq = run_fleet(_fleet_cfg(0.2e6, False))
    st = run_fleet(_fleet_cfg(0.2e6, True))
    assert st.n_streamed_requests > 0
    assert st.fleet_p95_s < seq.fleet_p95_s - 0.05   # > 50 ms win
    seq1 = run_fleet(_fleet_cfg(1e6, False))
    st1 = run_fleet(_fleet_cfg(1e6, True))
    assert st1.fleet_p95_s <= seq1.fleet_p95_s + 1e-9
    assert st1.fleet_p50_s < seq1.fleet_p50_s


def test_fleet_streamed_counters_and_determinism():
    a = run_fleet(_fleet_cfg(0.2e6, True))
    b = run_fleet(_fleet_cfg(0.2e6, True))
    assert a.fleet_p95_s == b.fleet_p95_s
    assert a.n_chunk_reconfigs == b.n_chunk_reconfigs
    assert 0.0 <= a.mean_bubble_frac < 1.0
    assert a.n_streamed_requests > 0
    assert any(r.n_chunks > 1 for r in a.robots)
    assert "chunk reconfigs" in a.summary()


def test_fleet_streamed_chunk_grid_one_matches_non_streamed():
    """streamed mode restricted to 1 chunk must reproduce the
    non-streamed fleet — same plans, same latencies."""
    seq = run_fleet(_fleet_cfg(1e6, False))
    st = run_fleet(_fleet_cfg(1e6, True, chunk_grid=(1,)))
    assert st.n_streamed_requests == 0
    assert st.n_chunk_reconfigs == 0
    assert st.fleet_p95_s == pytest.approx(seq.fleet_p95_s, rel=1e-12)
    assert st.fleet_p50_s == pytest.approx(seq.fleet_p50_s, rel=1e-12)
    assert st.n_requests == seq.n_requests


def test_fleet_streamed_single_cut_mode():
    """streamed works without multicut: single-cut plans with a chunk
    axis (S2 pinned to n everywhere)."""
    trace = TraceConfig(mean_bps=0.5e6, bad_bps=0.2e6)
    cfg = FleetConfig(n_robots=8, archs=("openvla-7b",), n_ticks=120,
                      n_replicas=2, seed=1, codecs=AXIS, trace=trace,
                      nominal_bw_bps=0.5e6, cloud_budget_bytes=12.1e9,
                      multicut=False, streamed=True)
    rep = run_fleet(cfg)
    assert rep.n_multicut_requests == 0
    assert rep.n_streamed_requests > 0


# ------------------------------------------------- satellite: NetworkSim
def test_wire_trace_s_integrates_the_trace():
    net = NetworkSim(np.array([1e6, 2e6, 4e6, 4e6]), tick_s=0.05,
                     rtt_s=0.005)
    assert net.transfer_trace_s(0) == 0.0            # zero bytes free
    assert net.wire_trace_s(50e3) == pytest.approx(0.05)   # one full tick
    # spans two ticks at different rates: 50 KB @ 1 MB/s + 100 KB @ 2 MB/s
    assert net.wire_trace_s(150e3) == pytest.approx(0.10)
    # mid-tick start: offset lands in tick 1 (2 MB/s)
    assert net.wire_trace_s(50e3, offset_s=0.05) == pytest.approx(0.025)
    # the instantaneous price is wrong on a rising link — by design
    assert net.transfer_s(150e3) > net.transfer_trace_s(150e3)
    # clamp: past the trace end bandwidth holds at the last sample
    long = net.wire_trace_s(4e6 * 0.05 * 100)
    assert long == pytest.approx(0.05 * 2 + (4e6 * 0.05 * 100 - 150e3)
                                 / 4e6)
    assert net.transfer_trace_s(100e3) == \
        pytest.approx(net.wire_trace_s(100e3) + 0.005)


def test_wire_trace_s_advances_with_sim_time():
    net = NetworkSim(np.array([1e6, 4e6, 4e6]), tick_s=0.05, rtt_s=0.0)
    t0 = net.wire_trace_s(100e3)
    net.step()
    t1 = net.wire_trace_s(100e3)       # now starts on the 4 MB/s tick
    assert t1 < t0


# --------------------------------------------- satellite: generate_trace
def test_generate_trace_seed0_regression():
    """Pin seed-0 summary stats of the vectorized generator (bulk RNG,
    event-walked regime chain, convolution AR) — the reproducibility
    contract across releases."""
    tr = generate_trace(2000, seed=0)
    assert tr.shape == (2000,)
    assert float(tr.mean()) == pytest.approx(8611777.963389495, rel=1e-9)
    assert float(tr.std()) == pytest.approx(3811575.557226897, rel=1e-9)
    assert float(tr.min()) == pytest.approx(174870.53832042433, rel=1e-9)
    assert float(tr.max()) == pytest.approx(17891667.39795722, rel=1e-9)
    # both regimes visited, floor respected
    assert 0.05 < float((tr < 3e6).mean()) < 0.5
    assert tr.min() >= TraceConfig().floor_bps


def test_generate_trace_vectorized_matches_scalar_semantics():
    """The regime chain must equal the historical per-tick recurrence on
    the SAME uniform stream (the vectorization changed the RNG draw
    order, not the process law)."""
    from repro.core.network import _regime_chain
    rng = np.random.default_rng(11)
    u = rng.random(4000)
    for pd, pr in ((0.02, 0.15), (0.0, 0.15), (1.0, 0.0), (0.5, 0.5)):
        bad = np.zeros(len(u), dtype=bool)
        prev = False
        for t in range(len(u)):
            prev = (u[t] >= pr) if prev else (u[t] < pd)
            bad[t] = prev
        assert np.array_equal(_regime_chain(u, pd, pr), bad), (pd, pr)


def test_generate_trace_reproducible_and_fast():
    a = generate_trace(50_000, seed=5)
    b = generate_trace(50_000, seed=5)
    assert np.array_equal(a, b)
    assert not np.array_equal(a[:2000], generate_trace(2000, seed=6))
    import time
    t0 = time.perf_counter()
    generate_trace(100_000, seed=9)
    assert time.perf_counter() - t0 < 2.0   # was ~seconds under the loop
