"""Flight-recorder telemetry (``core/telemetry.py``) and the Chrome
trace exporter (``runtime/trace_export.py``): sketch accuracy against
numpy percentiles, reservoir bounds/uniformity, the recorder's
off/sampled/full bit-identity guarantee across both engines, the drift
audit's reconciliation identity, and trace-event JSON structure.

Property checks run twice, following the repo's pattern
(``tests/test_events.py``): via ``hypothesis`` when the optional dep is
installed, and always as seeded numpy sweeps through the same checkers.
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro.core.telemetry import (DRIFT_STAGES, DriftAudit, FlightRecorder,
                                  MetricsRegistry, QuantileSketch, Reservoir,
                                  Span)
from repro.runtime.fleet import (ArrivalProcess, FleetConfig, FleetSimulator,
                                 ReplicaEvent, run_fleet)
from repro.runtime.trace_export import chrome_trace, export_chrome_trace

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# -------------------------------------------------------- quantile sketch
def _check_sketch_accuracy(values, max_centroids=128):
    """Sketch quantiles land within a few centroid-widths of the exact
    percentiles, exact count/sum/min/max/mean, bounded memory."""
    sk = QuantileSketch(max_centroids)
    sk.extend(values)
    arr = np.asarray(values, dtype=float)
    assert sk.count == len(arr)
    assert sk.min == arr.min() and sk.max == arr.max()
    assert sk.mean == pytest.approx(arr.mean(), rel=1e-12, abs=1e-12)
    assert sk.n_centroids <= 2 * max_centroids
    span = float(arr.max() - arr.min())
    for q in (0.0, 0.01, 0.25, 0.50, 0.75, 0.95, 0.99, 1.0):
        est = sk.quantile(q)
        exact = float(np.quantile(arr, q))
        # rank-error style bound: generous, but catches gross breakage
        assert abs(est - exact) <= 0.05 * span + 1e-12, (
            f"q={q}: sketch {est} vs exact {exact}")


def test_sketch_seeded_sweeps():
    rng = np.random.default_rng(0)
    _check_sketch_accuracy(rng.normal(5.0, 2.0, size=10_000))
    _check_sketch_accuracy(rng.lognormal(0.0, 1.0, size=10_000))
    _check_sketch_accuracy(rng.uniform(-1.0, 1.0, size=3_000))
    _check_sketch_accuracy(np.arange(1000)[::-1].astype(float))
    _check_sketch_accuracy([3.0])
    _check_sketch_accuracy([1.0, 1.0, 1.0, 1.0])


def test_sketch_empty_and_tails():
    sk = QuantileSketch()
    assert math.isnan(sk.quantile(0.5)) and math.isnan(sk.mean)
    assert sk.snapshot() == {"n": 0}
    sk.extend(range(100))
    assert sk.quantile(0.0) == 0.0 and sk.quantile(1.0) == 99.0
    snap = sk.snapshot()
    assert snap["n"] == 100 and snap["min"] == 0.0 and snap["max"] == 99.0
    assert snap["p50"] <= snap["p95"] <= snap["p99"] <= snap["max"]


def test_sketch_deterministic_same_stream():
    rng = np.random.default_rng(3)
    xs = rng.exponential(1.0, size=5000)
    a, b = QuantileSketch(64), QuantileSketch(64)
    a.extend(xs)
    b.extend(xs)
    assert a.quantile(0.5) == b.quantile(0.5)
    assert a.quantile(0.99) == b.quantile(0.99)
    assert a._cent == b._cent


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=30)
    @given(st.lists(st.floats(-1e6, 1e6), min_size=1, max_size=2000),
           st.sampled_from([16, 64, 128]))
    def test_sketch_accuracy_property(xs, mc):
        _check_sketch_accuracy(xs, mc)


# --------------------------------------------------------------- reservoir
def _check_reservoir(n_stream, cap, seed):
    r = Reservoir(cap, seed=seed)
    kept_flags = [r.offer(i) for i in range(n_stream)]
    assert len(r) == min(cap, n_stream)
    assert r.n_seen == n_stream
    # kept items are a subset of the stream, no duplicates
    assert len(set(r.items)) == len(r.items)
    assert all(0 <= x < n_stream for x in r.items)
    # the first min(cap, n) offers are always kept at offer time
    assert all(kept_flags[: min(cap, n_stream)])
    return r


def test_reservoir_bounds_seeded_sweeps():
    for n, cap, seed in [(10, 16, 0), (16, 16, 1), (1000, 16, 2),
                         (1000, 1, 3), (100_000, 64, 4)]:
        _check_reservoir(n, cap, seed)


def test_reservoir_deterministic_and_isolated():
    a = _check_reservoir(5000, 32, seed=7)
    b = _check_reservoir(5000, 32, seed=7)
    assert a.items == b.items
    c = _check_reservoir(5000, 32, seed=8)
    assert a.items != c.items            # astronomically unlikely to tie


def test_reservoir_uniformity():
    """Every stream position is kept with probability cap/n: the mean
    kept index over many seeds must sit near the stream midpoint."""
    n, cap = 2000, 20
    means = [np.mean(_check_reservoir(n, cap, seed).items)
             for seed in range(200)]
    assert abs(np.mean(means) - n / 2) < n * 0.02


def test_reservoir_rejects_bad_cap():
    with pytest.raises(ValueError):
        Reservoir(0)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=30)
    @given(st.integers(0, 3000), st.integers(1, 64), st.integers(0, 99))
    def test_reservoir_bounds_property(n, cap, seed):
        _check_reservoir(n, cap, seed)


# -------------------------------------------------------- metrics registry
def test_metrics_registry_snapshot():
    m = MetricsRegistry()
    m.inc("a/total")
    m.inc("a/total", 2)
    m.set_gauge("g", 3.5)
    for v in (1.0, 2.0, 3.0):
        m.observe("h", v)
    snap = m.snapshot()
    assert snap["counters"] == {"a/total": 3}
    assert snap["gauges"] == {"g": 3.5}
    assert snap["hists"]["h"]["n"] == 3
    assert snap["hists"]["h"]["mean"] == pytest.approx(2.0)
    json.dumps(snap)                     # snapshot must be JSON-clean


# -------------------------------------------------------------- drift audit
def test_drift_join_and_reconcile():
    d = DriftAudit()
    pred = {"edge_s": 0.1, "uplink_s": 0.2, "queue_s": 0.0,
            "service_s": 0.3, "down_s": 0.0, "total_s": 0.6}
    meas = {"edge_s": 0.1, "uplink_s": 0.25, "queue_s": 0.02,
            "service_s": 0.3, "down_s": 0.0, "total_s": 0.67}
    d.join(pred, meas)
    s = d.summary()
    assert s["n_joined"] == 1
    assert s["stages"]["uplink_s"]["mean_err"] == pytest.approx(0.05)
    assert s["stages"]["queue_s"]["mean_err"] == pytest.approx(0.02)
    assert s["reconcile_max_abs_s"] < 1e-12
    # a broken decomposition is caught by the reconciliation tracker
    bad = dict(meas, total_s=1.0)
    d.join(pred, bad)
    assert d.reconcile_max_abs_s == pytest.approx(0.33)


# ---------------------------------------------------------- flight recorder
def test_recorder_rejects_bad_mode():
    with pytest.raises(ValueError):
        FlightRecorder(mode="on")


def test_recorder_sampling_is_key_pure():
    r = FlightRecorder(mode="sampled", sample_every=16)
    keys = list(range(100_000))
    frac = sum(r.want(k) for k in keys) / len(keys)
    assert 0.04 < frac < 0.09            # ~1/16, hash-spread
    assert [r.want(k) for k in keys[:100]] \
        == [r.want(k) for k in keys[:100]]
    full = FlightRecorder(mode="full")
    assert all(full.want(k) for k in keys[:100])


def test_recorder_cont_hooks_only_for_opened_rids():
    r = FlightRecorder(mode="full")
    r.cont_admit(7, 0.1, 1.0, 1e6, "cloud0")       # never opened: ignored
    assert r.pop_cont(7) is None
    assert "cloud/preemptions" not in r.metrics.counters
    r.cont_open(7)
    r.cont_admit(7, 0.1, 1.0, 1e6, "cloud0")
    r.cont_preempt(7, 2.0, "cloud0")
    st_ = r.pop_cont(7)
    assert st_["queue_s"] == pytest.approx(0.1)
    assert st_["preempts"] == 1 and st_["replica"] == "cloud0"
    assert len(st_["spans"]) == 2
    assert r.metrics.counters["cloud/preemptions"] == 1
    assert r.pop_cont(7) is None                    # popped exactly once


def _record_one(r, **kw):
    args = dict(req=1, lane="robot:a", t0_s=0.0, edge_s=0.1, uplink_s=0.2,
                queue_s=0.05, service_s=0.3, down_s=0.05, total_s=0.7,
                replica="cloud0")
    args.update(kw)
    r.record_request(**args)


def test_record_request_span_group_monotone():
    r = FlightRecorder(mode="full")
    _record_one(r, enc_s=0.02, dec_s=0.01)
    (group,) = r.spans.items
    names = [s.name for s in group]
    assert names == ["edge", "encode", "uplink", "decode", "queue",
                     "service", "downlink"]
    # spans tile the request: each starts where the previous ended
    for a, b in zip(group, group[1:]):
        assert b.t0_s == pytest.approx(a.t0_s + a.dur_s)
    assert group[0].t0_s == 0.0
    end = group[-1].t0_s + group[-1].dur_s
    assert end == pytest.approx(0.7)
    # queue/service ride the replica lane, the rest the robot lane
    by_name = {s.name: s for s in group}
    assert by_name["queue"].lane == "replica:cloud0"
    assert by_name["service"].lane == "replica:cloud0"
    assert by_name["edge"].lane == "robot:a"


def test_record_request_metrics_and_outcomes():
    r = FlightRecorder(mode="full")
    _record_one(r)
    _record_one(r, outcome="hedged")
    snap = r.snapshot()
    assert snap["n_recorded"] == 2
    assert snap["metrics"]["counters"]["requests/total"] == 2
    assert snap["metrics"]["counters"]["requests/hedged"] == 1
    assert snap["metrics"]["hists"]["latency/total_s"]["n"] == 2


# --------------------------------------------------- fleet-level integration
def _cfg(telemetry, engine="ticks", **kw):
    return FleetConfig(n_robots=48, n_ticks=100, seed=7, engine=engine,
                       telemetry=telemetry, telemetry_sample_every=4, **kw)


FLEET_VARIANTS = [
    dict(),
    dict(streamed=True, codecs=("identity", "int8"), multicut=True),
    dict(continuous=True, queue_aware=True, kv_budget_bytes=2e8),
]


@pytest.mark.parametrize("kw", FLEET_VARIANTS)
def test_recorder_on_is_bit_identical_modulo_metrics(kw):
    """The acceptance gate: telemetry compiled in and ENABLED must not
    perturb the simulation — every report field except ``metrics`` is
    dataclass-equal across off/sampled/full, on both engines."""
    reps = {(eng, mode): run_fleet(_cfg(mode, eng, **kw))
            for eng in ("ticks", "events")
            for mode in ("off", "sampled", "full")}
    base = dataclasses.replace(reps[("ticks", "off")], metrics=None)
    for key, rep in reps.items():
        assert dataclasses.replace(rep, metrics=None) == base, key
    assert reps[("ticks", "off")].metrics is None
    full = reps[("ticks", "full")].metrics
    sampled = reps[("ticks", "sampled")].metrics
    assert 0 < sampled["n_recorded"] < full["n_recorded"]


def test_sampled_set_identical_across_engines():
    """Hash-of-key sampling: the events engine records exactly the same
    request count as the tick loop (arrival order differs, keys don't)."""
    for kw in FLEET_VARIANTS:
        a = run_fleet(_cfg("sampled", "ticks", **kw)).metrics
        b = run_fleet(_cfg("sampled", "events", **kw)).metrics
        assert a["n_recorded"] == b["n_recorded"]
        assert a["metrics"]["counters"] == b["metrics"]["counters"]


def test_drift_reconciliation_on_seeded_run():
    """Per-stage drift sums must re-sum to the measured request latency
    to float tolerance — the PR's acceptance criterion."""
    for kw in FLEET_VARIANTS:
        m = run_fleet(_cfg("full", "events", **kw)).metrics
        d = m["drift"]
        assert d["n_joined"] == m["n_recorded"]
        assert d["reconcile_max_abs_s"] < 1e-9
        for k in DRIFT_STAGES:
            if k in d["stages"]:
                assert math.isfinite(d["stages"][k]["mean_err"])


def test_open_loop_arrivals_recorded():
    cfg = _cfg("full", "events", continuous=True, slo_s=1.0,
               arrival_processes=(ArrivalProcess(
                   name="ap0", arch="llama3.2-3b", rate_hz=25.0),))
    rep = run_fleet(cfg)
    counters = rep.metrics["metrics"]["counters"]
    assert counters["requests/total"] == rep.metrics["n_recorded"]
    assert rep.metrics["drift"]["n_joined"] > 0


def test_report_summary_mentions_modern_fields():
    rep = run_fleet(_cfg("off"))
    s = rep.summary()
    assert "p99" in s and "p99.9" in s
    assert "queue" in s and "preemptions" in s


# ------------------------------------------------------------ trace export
def _traced_sim(**kw):
    cfg = _cfg("full", "events", **kw)
    sim = FleetSimulator(cfg)
    rep = sim.run()
    return sim, rep


def test_chrome_trace_structure(tmp_path):
    sim, rep = _traced_sim(continuous=True, queue_aware=True,
                           kv_budget_bytes=2e8)
    path = export_chrome_trace(sim.recorder, str(tmp_path / "t.trace.json"))
    with open(path) as f:
        tr = json.load(f)                # valid JSON on disk
    assert set(tr) == {"traceEvents", "displayTimeUnit", "otherData"}
    evs = tr["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    ms = [e for e in evs if e["ph"] == "M"]
    assert xs and ms and len(xs) + len(ms) == len(evs)
    for e in xs:
        assert e["ts"] >= 0.0 and e["dur"] >= 0.0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        assert "req" in e["args"]
    # every (pid, tid) an X event uses is named by a thread_name record
    named = {(e["pid"], e["tid"]) for e in ms if e["name"] == "thread_name"}
    assert {(e["pid"], e["tid"]) for e in xs} <= named
    # one lane per replica, plus robot-cohort lanes
    lanes = {e["args"]["name"] for e in ms if e["name"] == "thread_name"}
    assert any(ln.startswith("replica:") for ln in lanes)
    assert any(ln.startswith("robot:") for ln in lanes)
    # X events are globally time-sorted (exporter contract)
    ts = [e["ts"] for e in xs]
    assert ts == sorted(ts)
    assert tr["otherData"]["mode"] == "full"
    assert tr["otherData"]["spans_kept"] <= tr["otherData"]["spans_seen"]


def test_chrome_trace_lane_pids_partition_families():
    sim, _ = _traced_sim()
    tr = chrome_trace(sim.recorder)
    ms = [e for e in tr["traceEvents"] if e["ph"] == "M"]
    fam_of_pid = {}
    for e in ms:
        if e["name"] != "thread_name":
            continue
        fam = e["args"]["name"].split(":", 1)[0]
        assert fam_of_pid.setdefault(e["pid"], fam) == fam, (
            "two lane families share a pid")


def test_trace_reservoir_cap_respected():
    cfg = _cfg("full", "events", telemetry_cap=32)
    sim = FleetSimulator(cfg)
    sim.run()
    assert len(sim.recorder.spans) <= 32
    assert sim.recorder.spans.n_seen > 32
    tr = chrome_trace(sim.recorder)
    assert tr["otherData"]["spans_kept"] <= 32
