"""Split-boundary transport codecs: kernel roundtrips (int8 + packed int4,
jnp vs Pallas-interpret vs ref), cost-model invariants, codec-aware planner
parity (scalar search_joint vs vectorized codec axis on every registered
config), and the joint split×codec ΔNB adjustment."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.core import (CODECS, Thresholds, Workload, adjust, build_graph,
                        build_pool, evaluate_split, get_codec, search,
                        search_joint, search_vec, sweep_search, transport_s)
from repro.core.codec import (DeltaCodec, make_codecs, make_delta_codec,
                              resolve_codecs)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
from repro.core.hardware import A100, ORIN
from repro.core.segmentation import cut_bytes, graph_arrays
from repro.kernels.activation_codec import ops as codec_ops, ref as codec_ref

BWS = np.geomspace(0.05e6, 100e6, 13)
W = Workload()
AXIS = ("identity", "int8", "int4", "topk", "fp16")


# -------------------------------------------------------- int4 kernel layer
@pytest.mark.parametrize("shape", [(4, 256), (8, 512), (2, 3, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int4_roundtrip_error_bound(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    p, s = codec_ops.quantize_int4(x)
    back = codec_ops.dequantize_int4(p, s, dtype)
    xf = np.asarray(x, np.float32).reshape(-1, 128)
    bf = np.asarray(back, np.float32).reshape(-1, 128)
    amax = np.abs(xf).max(-1, keepdims=True)
    assert np.all(np.abs(bf - xf) <= amax / 7.0 * 1.01 + 1e-6)


@pytest.mark.parametrize("shape", [(4, 256), (256, 512)])
def test_int4_impls_agree_exactly(shape):
    """jnp oracle, Pallas-interpret kernel and eager ref must agree
    bit-for-bit (packing layout AND scales)."""
    x = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.bfloat16)
    pr, sr = codec_ref.quantize_int4(x)
    pj, sj = codec_ops.quantize_int4(x, impl="jnp")
    pi, si = codec_ops.quantize_int4(x, impl="interpret")
    assert bool(jnp.all(pr == pj)) and bool(jnp.all(pr == pi))
    assert bool(jnp.all(sr == sj)) and bool(jnp.all(sr == si))
    br = codec_ref.dequantize_int4(pr, sr)
    bj = codec_ops.dequantize_int4(pj, sj, impl="jnp")
    bi = codec_ops.dequantize_int4(pi, si, impl="interpret")
    assert bool(jnp.all(br == bj)) and bool(jnp.all(br == bi))


def test_int8_impls_agree_exactly():
    x = jax.random.normal(jax.random.PRNGKey(2), (256, 512), jnp.bfloat16)
    qr, sr = codec_ref.quantize_int8(x)
    qi, si = codec_ops.quantize(x, impl="interpret")
    assert bool(jnp.all(qr == qi))
    np.testing.assert_allclose(np.asarray(sr), np.asarray(si))


def test_int4_packing_layout():
    """Byte j of each 256-lane pair holds elements j (low nibble) and
    j + 128 (high nibble), biased by +7 with a -128 byte offset."""
    x = jnp.arange(-128, 128, dtype=jnp.float32).reshape(1, 256) / 18.3
    p, s = codec_ref.quantize_int4(x)
    pv = np.asarray(p, np.int64) + 128
    lo, hi = pv % 16 - 7, pv // 16 - 7
    xf = np.asarray(x, np.float32)
    sf = np.asarray(s, np.float32)
    np.testing.assert_allclose(
        lo[0], np.clip(np.round(xf[0, :128] / sf[0, 0]), -7, 7))
    np.testing.assert_allclose(
        hi[0], np.clip(np.round(xf[0, 128:] / sf[0, 1]), -7, 7))


def test_wire_bytes_match_codec_model():
    """The analytic Codec wire model must equal the real packed payload."""
    shape = (1, 17, 3072)
    n = 17 * 3072
    int8, int4 = CODECS["int8"], CODECS["int4"]
    assert codec_ref.wire_bytes(shape) == int8.wire_bytes(n * 2)
    assert codec_ref.wire_bytes_int4(shape) == int4.wire_bytes(n * 2)


# ----------------------------------------------------------- codec pricing
def test_identity_codec_is_free():
    ident = CODECS["identity"]
    assert ident.wire_factor == 1.0
    assert ident.encode_s(1e6, ORIN) == 0.0
    assert ident.decode_s(1e6, A100) == 0.0
    assert ident.err_bound == 0.0


def test_codec_costs_linear_and_ordered():
    int8, int4 = CODECS["int8"], CODECS["int4"]
    assert int4.wire_factor < int8.wire_factor < 1.0
    assert int4.err_bound > int8.err_bound > 0.0
    # linearity is what lets the vectorized planner fold codecs in
    assert int8.encode_s(2e6, ORIN) == pytest.approx(
        2 * int8.encode_s(1e6, ORIN))
    # transport includes both sides' compute when devices are given
    raw = 1e6
    t_wire = int8.wire_bytes(raw) / 1e6
    assert transport_s(raw, 1e6, int8, ORIN, A100) > t_wire
    assert transport_s(raw, 1e6, int8) == pytest.approx(t_wire)


def test_resolve_codecs_max_err_gate():
    cs = resolve_codecs(("identity", "int8", "int4"), max_err=0.01)
    assert [c.name for c in cs] == ["identity", "int8"]
    with pytest.raises(ValueError):
        resolve_codecs(("int4",), max_err=1e-6)
    assert resolve_codecs(None) is None


def test_make_codecs_f32_raw():
    cs = make_codecs(raw_bytes_per_elem=4.0)
    assert cs["fp16"].wire_factor == pytest.approx(0.5)
    assert cs["int8"].wire_factor == pytest.approx((1 + 4 / 128) / 4)


# ------------------------------------------------------ planner with codecs
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_joint_planner_parity_every_config(arch):
    """search_vec's codec axis must return the identical (split, codec) to
    the scalar search_joint oracle for every registered config across a
    bandwidth sweep and budgets."""
    g = build_graph(get_config(arch), W)
    for budget in (None, 12e9):
        res = search_vec(g, ORIN, A100, BWS, cloud_budget_bytes=budget,
                         input_bytes=W.input_bytes, rtt_s=0.005, codecs=AXIS)
        for j, bw in enumerate(BWS):
            seg = search_joint(g, ORIN, A100, float(bw), AXIS,
                               cloud_budget_bytes=budget,
                               input_bytes=W.input_bytes, rtt_s=0.005)
            assert int(res.splits[j]) == seg.split, (arch, budget, bw)
            assert res.codec_names[res.codec_idx[j]] == seg.codec
            assert res.total_s[j] == pytest.approx(seg.total_s, rel=1e-12)


def test_sweep_search_codec_axis_matches_search_vec():
    graphs = {k: build_graph(get_config(k), W) for k in sorted(ARCHS)}
    sw = sweep_search(graphs, ORIN, A100, BWS, input_bytes=W.input_bytes,
                      codecs=AXIS)
    for k, g in graphs.items():
        one = search_vec(g, ORIN, A100, BWS, input_bytes=W.input_bytes,
                         codecs=AXIS)
        assert np.array_equal(sw[k].splits, one.splits), k
        assert np.array_equal(sw[k].codec_idx, one.codec_idx), k
        np.testing.assert_allclose(sw[k].total_s, one.total_s, rtol=1e-12)


def test_identity_axis_reproduces_codec_free_plan():
    g = build_graph(get_config("openvla-7b"), W)
    a = search_vec(g, ORIN, A100, BWS, input_bytes=W.input_bytes)
    b = search_vec(g, ORIN, A100, BWS, input_bytes=W.input_bytes,
                   codecs=("identity",))
    assert np.array_equal(a.splits, b.splits)
    np.testing.assert_array_equal(a.total_s, b.total_s)


def test_codec_shifts_optimal_split():
    """The motivating wart: compression changes the ranking of cut points,
    so planning on raw bytes picks a different (worse) split."""
    g = build_graph(get_config("openvla-7b"), W)
    raw = search_vec(g, ORIN, A100, BWS, input_bytes=W.input_bytes)
    joint = search_vec(g, ORIN, A100, BWS, input_bytes=W.input_bytes,
                       codecs=AXIS)
    assert np.any(raw.splits != joint.splits)
    # and the joint plan is never worse than the raw plan anywhere
    for j, bw in enumerate(BWS):
        seg = search(g, ORIN, A100, float(bw), input_bytes=W.input_bytes)
        assert joint.total_s[j] <= seg.total_s + 1e-15


def test_graph_arrays_codec_latency_matches_evaluate_split():
    g = build_graph(get_config("cogact-7b"), W)
    ga = graph_arrays(g, ORIN, A100, input_bytes=W.input_bytes)
    int4 = CODECS["int4"]
    for s in (0, 1, len(g) // 2, len(g)):
        ref = evaluate_split(g, s, ORIN, A100, 2e6, rtt_s=0.005,
                             input_bytes=W.input_bytes, codec=int4)
        got = ga.latency(s, 2e6, 0.005, codec=int4)
        assert got == pytest.approx(ref, rel=1e-12)


def test_search_accepts_codec_names():
    g = build_graph(get_config("llama3.2-3b"), W)
    a = search(g, ORIN, A100, 2e6, codec="int8")
    b = search(g, ORIN, A100, 2e6, codec=get_codec("int8"))
    assert a == b and a.codec == "int8"


# ------------------------------------------------- joint split×codec adjust
def _dit_pool():
    g = build_graph(get_config("cogact-7b"), Workload(decode_steps=0))
    first_dit = next(i for i, c in enumerate(g) if c.kind == "dit")
    return g, build_pool(g, first_dit), first_dit


def test_adjust_joint_move_on_bandwidth_drop():
    """A predicted bandwidth drop triggers a JOINT move: the decision both
    relocates the split (to the llm→dit boundary, the min-transfer layer)
    and compresses harder than identity — neither alone is optimal."""
    from repro.core import Pool
    g, _, first_dit = _dit_pool()
    # truncate the pool short of n so every candidate split ships bytes
    pool = Pool(start=first_dit, end=first_dit + 3, bytes=0.0,
                overhead_frac=0.0)
    cur = first_dit + 2
    thr = Thresholds(high=2e6, low=-2e6)
    dec = adjust(g, pool, cur, 1e6, 10e6, thr,
                 codecs=("identity", "int8", "int4"),
                 current_codec="identity", edge=ORIN, cloud=A100)
    assert dec.reason == "down" and dec.moved
    assert dec.codec in ("int8", "int4")
    assert dec.split != cur                  # split moved AND codec changed
    # the chosen pair minimises predicted transport seconds over the pool
    best = min(transport_s(cut_bytes(g, s), 1e6, get_codec(c), ORIN, A100)
               for s in pool.splits() for c in ("identity", "int8", "int4"))
    got = transport_s(cut_bytes(g, dec.split), 1e6, get_codec(dec.codec),
                      ORIN, A100)
    assert got == pytest.approx(best, rel=1e-12)


def test_adjust_up_relaxes_to_lossless():
    g, pool, cur = _dit_pool()
    thr = Thresholds(high=2e6, low=-2e6)
    dec = adjust(g, pool, cur, 20e6, 10e6, thr,
                 codecs=("identity", "int8", "int4"), current_codec="int4",
                 edge=ORIN, cloud=A100)
    assert dec.reason == "up" and dec.codec == "identity"
    vols = [cut_bytes(g, s) for s in pool.splits()]
    assert dec.split == list(pool.splits())[int(np.argmax(vols))]


def test_adjust_without_codecs_unchanged():
    g, pool, cur = _dit_pool()
    thr = Thresholds(high=2e6, low=-2e6)
    dec = adjust(g, pool, cur, 1e6, 10e6, thr)
    vols = [cut_bytes(g, s) for s in pool.splits()]
    assert dec.codec is None
    assert dec.split == list(pool.splits())[int(np.argmin(vols))]


def test_adjust_hold_keeps_codec():
    g, pool, cur = _dit_pool()
    thr = Thresholds(high=2e6, low=-2e6)
    dec = adjust(g, pool, cur, 10.5e6, 10e6, thr,
                 codecs=("identity", "int8"), current_codec="int8")
    assert dec.reason == "hold" and not dec.moved and dec.codec == "int8"


# ------------------------------------------------------- controller + fleet
def test_controller_codec_plans_different_split():
    """The controller's Alg. 1 must SEE the codec: with int4 the planned
    split differs from the raw-byte plan at constrained bandwidth (the bug
    the shared Codec path fixes — the old hard-coded int8 discount was
    applied after the split was already chosen)."""
    from repro.core import RoboECC
    cfg = get_config("openvla-7b")
    raw = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=12.1e9,
                  nominal_bw_bps=0.2e6)
    c4 = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=12.1e9,
                 nominal_bw_bps=0.2e6, codec="int4")
    assert raw.split != c4.split
    e, c, t_raw = raw.latency_at(raw.split, 0.2e6)
    e4, c4_, t4 = c4.latency_at(c4.split, 0.2e6)
    assert e4 + c4_ + t4 < e + c + t_raw


def test_controller_use_codec_alias():
    from repro.core import RoboECC
    cfg = get_config("openvla-7b")
    ctl = RoboECC(cfg, ORIN, A100, use_codec=True)
    assert ctl.codec is not None and ctl.codec.name == "int8"
    assert ctl.use_codec


def test_controller_adjust_resolves_custom_codec_instances():
    """tick() must resolve the adjuster's decision within adjust_codecs —
    a registry lookup would KeyError on custom Codec instances."""
    from repro.core import (NetworkSim, PredictorConfig, RoboECC,
                            generate_trace)
    from repro.core.codec import Codec
    custom = Codec("mycodec", bytes_per_elem=0.25)
    cfg = get_config("openvla-7b")
    ctl = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=12.1e9,
                  thresholds=Thresholds(high=1e3, low=-1e3),
                  adjust_codecs=[custom, "identity"])
    trace = generate_trace(1200, seed=2)
    ctl.fit_predictor(trace[:1000], PredictorConfig(epochs=5))
    net = NetworkSim(trace[1000:])
    net.step(40)
    seen = {ctl.tick(net).codec for _ in range(20)}
    assert "mycodec" in seen           # custom instance round-tripped
    assert ctl.codec in (custom, get_codec("identity"), None) or \
        ctl.codec.name in ("mycodec", "identity")


def test_encode_activation_rejects_unknown_codec():
    from repro.runtime.partition import encode_activation
    x = jnp.ones((1, 4, 256), jnp.bfloat16)
    with pytest.raises(ValueError):
        encode_activation(x, "topk")   # planner codec with no data plane
    with pytest.raises(ValueError):
        encode_activation(x, "int8x")  # typo must not silently ship int8
    with pytest.raises(ValueError):
        encode_activation(jnp.ones((1, 4, 384), jnp.bfloat16), "int4")
    assert "q4" in encode_activation(x, "int4")
    assert "q" in encode_activation(x, "int8")
    assert "x" in encode_activation(x, "")


def test_fleet_codec_axis_beats_identity_on_slow_links():
    """Acceptance: int8/int4 fleet p95 beats identity at ≤ 2 MB/s."""
    from repro.core import TraceConfig
    from repro.runtime.fleet import FleetConfig, run_fleet
    trace = TraceConfig(mean_bps=2e6, bad_bps=0.5e6)
    base = dict(n_robots=16, n_ticks=200, n_replicas=3, seed=0,
                archs=("openvla-7b", "cogact-7b", "llama3.2-3b", "glm4-9b"),
                trace=trace, nominal_bw_bps=2e6)
    reps = {c: run_fleet(FleetConfig(codecs=(c,), **base))
            for c in ("identity", "int8", "int4")}
    assert reps["int8"].fleet_p95_s < reps["identity"].fleet_p95_s
    assert reps["int4"].fleet_p95_s < reps["identity"].fleet_p95_s
    # compression also lets the closed-loop fleet complete more requests
    assert reps["int4"].n_requests >= reps["identity"].n_requests
    for rep in reps.values():
        assert rep.n_requests > 0


def test_fleet_identity_default_unchanged_and_deterministic():
    from repro.runtime.fleet import FleetConfig, run_fleet
    cfg = FleetConfig(n_robots=6, n_ticks=40, n_replicas=2, seed=7,
                      archs=("openvla-7b", "cogact-7b"))
    a, b = run_fleet(cfg), run_fleet(cfg)
    assert a == b
    assert all(r.codec == "identity" for r in a.robots)
    assert a.n_codec_switches == 0


# ---------------------------------------------------------- temporal delta
def test_delta_codec_cycle_average_pricing():
    """The DeltaCodec's cost fields must be the exact cycle average over
    one resync period: one key frame (full base payload) amortised over
    ``R`` frames plus ``R-1`` delta frames (changed rows + mask)."""
    base = CODECS["int8"]
    p, R, tau = 0.1, 8, 0.02
    d = make_delta_codec(base=base, change_frac=p, resync_every=R,
                         threshold=tau)
    mask_bpe = 1.0 / (8.0 * d.row_elems)
    delta_bpe = p * base.bytes_per_elem + mask_bpe
    want = (base.bytes_per_elem + (R - 1) * delta_bpe) / R
    assert d.bytes_per_elem == pytest.approx(want, rel=1e-12)
    assert d.err_bound == pytest.approx(base.err_bound + (R - 1) * tau)
    assert d.wire_factor < base.wire_factor   # the whole point
    assert isinstance(CODECS["delta"], DeltaCodec)


@pytest.mark.parametrize("kw", [dict(resync_every=1),
                                dict(change_frac=1.0)])
def test_delta_degenerate_matches_base_bitwise(kw):
    """R=1 (key frame every step) and change fraction 1.0 (deltas never
    beat a key frame) must reproduce the base codec's pricing EXACTLY —
    same planner plan, bit-for-bit, on every config."""
    base = CODECS["int8"]
    d = make_delta_codec(base=base, **kw)
    for f in ("bytes_per_elem", "raw_bytes_per_elem", "enc_flops_per_elem",
              "enc_move_bytes_per_elem", "dec_flops_per_elem",
              "dec_move_bytes_per_elem"):
        assert getattr(d, f) == getattr(base, f), f    # exact, not approx
    for arch in sorted(ARCHS):
        g = build_graph(get_config(arch), W)
        a = search_vec(g, ORIN, A100, BWS, input_bytes=W.input_bytes,
                       codecs=("identity", d))
        b = search_vec(g, ORIN, A100, BWS, input_bytes=W.input_bytes,
                       codecs=("identity", base))
        assert np.array_equal(a.splits, b.splits), arch
        assert np.array_equal(a.codec_idx, b.codec_idx), arch
        np.testing.assert_array_equal(a.total_s, b.total_s)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_joint_planner_parity_with_delta(arch):
    """Scalar search_joint oracle vs vectorized codec axis, with the
    delta codec in the axis, on every registered config."""
    axis = AXIS + ("delta",)
    g = build_graph(get_config(arch), W)
    res = search_vec(g, ORIN, A100, BWS, input_bytes=W.input_bytes,
                     rtt_s=0.005, codecs=axis)
    for j, bw in enumerate(BWS):
        seg = search_joint(g, ORIN, A100, float(bw), axis,
                           input_bytes=W.input_bytes, rtt_s=0.005)
        assert int(res.splits[j]) == seg.split, (arch, bw)
        assert res.codec_names[res.codec_idx[j]] == seg.codec
        assert res.total_s[j] == pytest.approx(seg.total_s, rel=1e-12)


def _delta_frames(seed, n_frames, frac, S=16, D=256):
    """A frame sequence where roughly ``frac`` of token rows move
    per step (the rest are bit-identical to the previous frame)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, 1.0, (1, S, D)).astype(np.float32)
    out = [x]
    for _ in range(n_frames - 1):
        x = x.copy()
        rows = rng.random(S) < frac
        x[0, rows, :] += rng.normal(0.0, 0.5, (int(rows.sum()), D)) \
            .astype(np.float32)
        out.append(x)
    return out


def _check_delta_stream(frames, threshold, R, base="int8"):
    """Drive ``delta_encode`` over a frame sequence and assert the full
    contract: key frames byte-identical to the plain codec path, exact
    wire-byte accounting, bounded error between key frames, and the
    resync cadence."""
    from repro.runtime.partition import (decode_activation, delta_decode,
                                         delta_encode, encode_activation,
                                         payload_bytes)
    base_err = CODECS[base].err_bound if base else 0.0
    ref, ssk = None, 0
    for step, xf in enumerate(frames):
        x = jnp.asarray(xf, jnp.float32)
        payload, new_ref, is_key = delta_encode(
            x, base, ref, threshold=threshold, resync_every=R,
            steps_since_key=ssk)
        S = x.shape[1]
        if is_key:
            # key frames are byte-identical to the non-delta path
            plain = encode_activation(x, base)
            assert payload.keys() == plain.keys()
            for k in payload:
                assert np.array_equal(np.asarray(payload[k]),
                                      np.asarray(plain[k])), k
            assert payload_bytes(payload) == payload_bytes(plain)
            np.testing.assert_array_equal(
                np.asarray(new_ref), np.asarray(decode_activation(
                    plain, jnp.float32)))
            ssk = 0
        else:
            # wire bytes exact to the byte: packed mask + changed rows
            mask = payload["mask"]
            changed = np.unpackbits(mask)[:S].astype(bool)
            idx = np.flatnonzero(changed)
            body = encode_activation(x[:, idx, :], base)
            assert payload_bytes(payload) == \
                mask.nbytes + payload_bytes(body)
            ssk += 1
        recon = np.asarray(delta_decode(payload, ref, jnp.float32))
        amax = float(np.abs(xf).max())
        tol = (base_err + (0.0 if is_key else threshold)) * amax
        assert np.all(np.abs(recon - xf) <= tol + 1e-6), step
        np.testing.assert_array_equal(recon, np.asarray(new_ref))
        assert ssk < max(R, 1)      # cadence honoured
        ref = new_ref
    return True


def test_delta_roundtrip_seeded_sweep():
    for seed in range(4):
        _check_delta_stream(_delta_frames(seed, 7, 0.2),
                            threshold=0.05, R=3 + seed)
    # degenerate cadence: every frame is a key frame
    _check_delta_stream(_delta_frames(9, 4, 0.5), threshold=0.05, R=1)
    # fully static: only the mask ships between key frames
    _check_delta_stream([_delta_frames(1, 1, 0.0)[0]] * 5,
                        threshold=0.05, R=8)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 8),
           frac=st.floats(0.0, 1.0), R=st.integers(1, 6),
           tau=st.floats(0.05, 0.3))
    def test_delta_roundtrip_property(seed, n, frac, R, tau):
        _check_delta_stream(_delta_frames(seed, n, frac),
                            threshold=tau, R=R)


def test_delta_transport_eviction_forces_resync():
    """Evicting a robot's cloud-side reference (budget pressure) must
    force its next frame back to a key frame."""
    from repro.runtime.partition import DeltaTransport
    frames = _delta_frames(3, 6, 0.1)
    ref_bytes = frames[0].size * 4           # float32 reference
    tr = DeltaTransport("int8", threshold=0.05, resync_every=100,
                        budget_bytes=1.5 * ref_bytes)
    _, _, k0 = tr.step(0, jnp.asarray(frames[0]))
    _, _, k1 = tr.step(0, jnp.asarray(frames[1]))
    assert k0 and not k1
    tr.step(1, jnp.asarray(frames[2]))       # robot 1 evicts robot 0
    assert tr.n_evictions >= 1
    _, _, k3 = tr.step(0, jnp.asarray(frames[3]))
    assert k3                                # reference gone → key frame
    # explicit evict has the same effect
    tr2 = DeltaTransport("int8", threshold=0.05, resync_every=100)
    tr2.step(5, jnp.asarray(frames[0]))
    _, _, kk = tr2.step(5, jnp.asarray(frames[1]))
    assert not kk
    tr2.evict(5)
    _, _, kk = tr2.step(5, jnp.asarray(frames[2]))
    assert kk


def test_controller_observe_change_frac_replans():
    """Measured change-fraction drift beyond tolerance must rebuild the
    delta codec around the measured fraction and replan; small drift and
    non-delta codecs are no-ops."""
    from repro.core import RoboECC
    cfg = get_config("openvla-7b")
    d0 = make_delta_codec(change_frac=0.15)
    ctl = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=12.1e9,
                  nominal_bw_bps=1e6, codec=d0,
                  adjust_codecs=[d0, "identity"])
    assert not ctl.observe_change_frac(0.16, nominal_bw_bps=1e6)
    assert ctl.codec.change_frac == 0.15
    assert ctl.observe_change_frac(0.9, nominal_bw_bps=1e6)
    assert ctl.codec.change_frac == 0.9
    assert any(isinstance(c, DeltaCodec) and c.change_frac == 0.9
               for c in ctl.adjust_codecs)
    plain = RoboECC(cfg, ORIN, A100, cloud_budget_bytes=12.1e9,
                    nominal_bw_bps=1e6, codec="int8")
    assert not plain.observe_change_frac(0.9, nominal_bw_bps=1e6)


def test_fleet_static_scene_delta_beats_int4_bytes():
    """Acceptance direction: on a static scene the delta codec ships far
    fewer measured wire bytes than int4 under identical placements; on a
    dynamic scene the advantage collapses (the honest negative)."""
    from repro.runtime.fleet import FleetConfig, run_fleet
    d = make_delta_codec(change_frac=0.02, resync_every=16, name="delta")
    base = dict(n_robots=8, n_ticks=120, seed=3, archs=("openvla-7b",),
                continuous=True)

    def bytes_for(codec, scene):
        rep = run_fleet(FleetConfig(**base, codecs=("identity", codec),
                                    scene=scene))
        assert rep.total_wire_bytes > 0
        return rep.total_wire_bytes, rep

    b_delta, rd = bytes_for(d, "static")
    b_int4, _ = bytes_for("int4", "static")
    assert b_delta * 4 < b_int4            # ≥4× fewer bytes on-wire
    assert rd.n_delta_frames > rd.n_keyframes
    b_dyn, rdyn = bytes_for(d, "dynamic")
    b_int4_dyn, _ = bytes_for("int4", "dynamic")
    assert b_dyn > 3 * b_delta             # the advantage collapses…
    assert b_dyn > b_int4_dyn              # …to worse than plain int4
    assert rdyn.n_keyframes > 0            # ceiling frames force resyncs


def test_fleet_joint_codecs_outage_recovery_consistent():
    """replan() after a full outage must restore a codec-consistent split
    (controllers plan with the same codec the plan table chose)."""
    from repro.runtime.fleet import FleetConfig, FleetSimulator, outage_schedule
    cfg = FleetConfig(n_robots=6, n_ticks=60, n_replicas=2, seed=3,
                      archs=("openvla-7b", "cogact-7b", "llama3.2-3b"),
                      codecs=("identity", "int8", "int4"))
    cfg.replica_events = outage_schedule(cfg)
    sim = FleetSimulator(cfg)
    initial = [ctl.split for ctl in sim.controllers]
    rep = sim.run()
    assert rep.n_replans == 2 * cfg.n_robots
    for ctl, s0 in zip(sim.controllers, initial):
        assert ctl.split == s0
