"""Config registry: presence, analytic param counts, shape applicability."""
import pytest

from repro.configs import ARCHS, ASSIGNED, SHAPES, get_config, get_shape, \
    shape_applicable

EXPECTED_PARAMS_B = {
    # analytic count sanity bands (embed+head included, hence some slack)
    "llama3.2-3b": (2.5, 4.5),
    "command-r-35b": (30.0, 40.0),
    "glm4-9b": (8.0, 11.0),
    "phi3-mini-3.8b": (3.2, 4.5),
    "deepseek-v2-lite-16b": (13.0, 18.0),
    "granite-moe-3b-a800m": (2.0, 4.0),
    "mamba2-1.3b": (1.0, 1.7),
    "seamless-m4t-large-v2": (1.4, 2.9),
    "llama-3.2-vision-11b": (9.0, 13.0),
    "zamba2-1.2b": (0.9, 1.6),
}


def test_all_assigned_present():
    assert len(ASSIGNED) == 10
    assert "openvla-7b" in ARCHS and "cogact-7b" in ARCHS


@pytest.mark.parametrize("arch", list(EXPECTED_PARAMS_B))
def test_param_counts(arch):
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = get_config(arch).n_params() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"


def test_40_cells():
    cells = [(a, s.name) for a in ASSIGNED for s in SHAPES]
    assert len(cells) == 40
    runnable = [c for c in cells
                if shape_applicable(get_config(c[0]), get_shape(c[1]))[0]]
    skipped = [c for c in cells if c not in runnable]
    # long_500k skipped exactly for the 8 non-sub-quadratic archs
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == {
        "llama3.2-3b", "command-r-35b", "glm4-9b", "phi3-mini-3.8b",
        "deepseek-v2-lite-16b", "granite-moe-3b-a800m",
        "seamless-m4t-large-v2", "llama-3.2-vision-11b"}


def test_sub_quadratic_run_long():
    for arch in ("mamba2-1.3b", "zamba2-1.2b"):
        ok, _ = shape_applicable(get_config(arch), get_shape("long_500k"))
        assert ok


def test_reduced_configs_small():
    for arch in ASSIGNED:
        r = get_config(arch).reduced()
        assert r.n_params() < 50e6
