"""HLO collective parser: validated against a real compiled SPMD program."""
import re

import pytest

from repro.launch.hlo_analysis import (_parse_trip_count, _shape_bytes,
                                       parse_collectives, summarize)


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128,512]") == 8 * 128 * 512 * 2
    assert _shape_bytes("(f32[4,4], bf16[2,2])") == 64 + 8
    assert _shape_bytes("u32[]") == 0 or _shape_bytes("u32[]") == 4  # scalar


SAMPLE = """
HloModule jit_f

%fused (p: f32[8]) -> f32[8] {
  ROOT %x = f32[8] parameter(0)
}

%body (p: (s32[], f32[16])) -> (s32[], f32[16]) {
  %ar.1 = f32[16]{0} all-reduce(%gte), channel_id=3, replica_groups={{0,1,2,3}}, to_apply=%sum
  ROOT %t = (s32[], f32[16]) tuple(%c, %ar.1)
}

%cond (p: (s32[], f32[16])) -> pred[] {
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %ag = bf16[128,256]{1,0} all-gather(%a0), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %ar = f32[128,256]{1,0} all-reduce(%a1), channel_id=2, replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%sum
  %w = (s32[], f32[16]) while(%init), condition=%cond, body=%body
  %cp = bf16[64,64]{1,0} collective-permute(%a2), channel_id=4, source_target_pairs={{0,1},{1,0}}
  ROOT %r = f32[128,256] add(%ar, %ar)
}
"""


def test_parse_collectives_sample():
    ops = parse_collectives(SAMPLE)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-reduce",
                     "collective-permute"]
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.group_size == 4
    assert ag.bytes == 128 * 256 * 2
    assert ag.wire_bytes == pytest.approx(0.75 * 128 * 256 * 2)
    ar = [o for o in ops if o.kind == "all-reduce"]
    big = next(o for o in ar if o.bytes == 128 * 256 * 4)
    assert big.group_size == 8
    assert big.wire_bytes == pytest.approx(2 * 7 / 8 * 128 * 256 * 4)
    # the while-body all-reduce got multiplied by trip count 7
    loop = next(o for o in ar if o.bytes == 64)
    assert loop.count == 7


def test_trip_count_parse():
    assert _parse_trip_count(SAMPLE, "cond") == 7


def test_bf16_equivalence_discount():
    # >=1MB f32 collectives are halved for the TPU roofline
    big = ("%ar = f32[1024,1024]{1,0} all-reduce(%x), channel_id=1, "
           "replica_groups={{0,1}}, to_apply=%sum\n")
    ops = parse_collectives(big)
    assert ops[0].wire_bytes_bf16 == pytest.approx(ops[0].wire_bytes / 2)


def test_real_compiled_program():
    import jax
    import jax.numpy as jnp

    def f(x):
        return jnp.sum(x * 2)

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    ops = parse_collectives(c.as_text())
    assert ops == []  # single-device: no collectives
    s = summarize(ops)
    assert s["total_wire_bytes_per_device"] == 0
