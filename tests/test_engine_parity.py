"""Engine parity harness: the event-driven core (``runtime/events.py``)
must reproduce the dense tick loop's ``FleetReport`` EXACTLY —
dataclass-equal, every float bit-identical — across the feature matrix
{micro, continuous} x {plain, streamed} x {single-cut, multi-cut}, outage
schedules included.

This is the contract that lets the 100k-robot scale runs trust the sparse
engine: both engines call the same phase bodies in ``runtime/fleet.py``
(``_robot_step`` / ``_drain_dead`` / ``_service_replica`` /
``_final_drain``), so any divergence means the heap replayed them in a
different order or at a different simulated time — a bug, not noise.

The event engine additionally carries a ``vectorized`` axis: the batched
robot phase (``_robot_step_batch``, the default) against the scalar
per-robot oracle (``vectorized=False``, the PR-6 path).  The matrix
tests run vectorized events against ticks; the dedicated axis tests pin
vectorized == scalar-events == ticks three ways, including under open
arrivals + autoscaling where the tick engine cannot follow.
"""
import dataclasses
import itertools

import pytest

from repro.core.network import TraceConfig
from repro.runtime.fleet import (ArrivalProcess, FleetConfig, ReplicaEvent,
                                 outage_schedule, run_fleet)


def _cfg(continuous=False, streamed=False, multicut=False, seed=3,
         chaos=True, **kw):
    """Small-but-busy fleet: a degraded trace forces collaborative splits
    (so cloud batching, hedging and codec switching all engage) and the
    default chaos schedule exercises leave/join + full-outage replans."""
    base = FleetConfig(
        n_robots=8, n_ticks=60, tick_s=0.05, n_replicas=2,
        archs=("openvla-7b",), batch_size=4, batch_wait_s=0.04,
        multicut=multicut, streamed=streamed, continuous=continuous,
        codecs=("identity", "int8", "topk") if multicut else ("identity",),
        cloud_budget_bytes=5.8e9,
        down_bw_factor=8.0 if multicut else 1.0,
        trace=TraceConfig(mean_bps=1e6, bad_bps=2.5e5),
        seed=seed, **kw)
    if chaos:
        base = dataclasses.replace(
            base, replica_events=tuple(outage_schedule(base)))
    return base


def _both(cfg):
    r_ticks = run_fleet(dataclasses.replace(cfg, engine="ticks"))
    r_events = run_fleet(dataclasses.replace(cfg, engine="events"))
    return r_ticks, r_events


def _assert_equal(r_ticks, r_events):
    if r_ticks == r_events:
        return
    diffs = [f.name for f in dataclasses.fields(r_ticks)
             if getattr(r_ticks, f.name) != getattr(r_events, f.name)]
    raise AssertionError(f"engines diverge on fields: {diffs}")


MATRIX = list(itertools.product([False, True], repeat=3))


@pytest.mark.parametrize("continuous,streamed,multicut", MATRIX)
def test_parity_matrix_with_chaos(continuous, streamed, multicut):
    """Every feature combination, under the default outage schedule:
    reports must be dataclass-equal (same requests, same floats, same
    counter values — hedges, replans, cut moves, preemptions, all of it)."""
    r_ticks, r_events = _both(_cfg(continuous, streamed, multicut))
    _assert_equal(r_ticks, r_events)
    assert r_ticks.n_requests > 0           # the config actually exercises


def test_parity_calm_fleet_no_chaos():
    """No replica events at all: the pure steady-state path (wake
    scheduling, batch deadlines, heartbeat expiry never fires)."""
    r_ticks, r_events = _both(_cfg(chaos=False))
    _assert_equal(r_ticks, r_events)


def test_parity_single_replica_full_outage():
    """One replica, killed mid-run and revived: the full-outage replan
    wave (edge-only degradation) and the recovery wave must land on the
    same ticks in both engines."""
    cfg = _cfg(chaos=False, continuous=True)
    cfg = dataclasses.replace(
        cfg, n_replicas=1,
        replica_events=(ReplicaEvent(20, "cloud0", "leave"),
                        ReplicaEvent(40, "cloud0", "join")))
    r_ticks, r_events = _both(cfg)
    _assert_equal(r_ticks, r_events)
    assert r_ticks.n_replans > 0


def test_parity_leave_at_tick_zero():
    """A tick-0 leave means the replica never heartbeats: the analytic
    live view must agree with the pool that it was never live (and the
    fleet must not count a 'down' replan for a cloud that never came up)."""
    cfg = _cfg(chaos=False)
    cfg = dataclasses.replace(
        cfg, replica_events=(ReplicaEvent(0, "cloud1", "leave"),))
    r_ticks, r_events = _both(cfg)
    _assert_equal(r_ticks, r_events)


def test_parity_same_tick_leave_join_order():
    """Same-tick leave+join of one replica: the ReplicaEvent total order
    applies the leave last (it wins the tick) in both engines, whichever
    order the schedule lists them."""
    for order in ((("leave", 30), ("join", 30)), (("join", 30),
                                                  ("leave", 30))):
        cfg = _cfg(chaos=False)
        cfg = dataclasses.replace(cfg, replica_events=tuple(
            ReplicaEvent(t, "cloud1", k) for k, t in order))
        r_ticks, r_events = _both(cfg)
        _assert_equal(r_ticks, r_events)


@pytest.mark.parametrize("continuous,chaos",
                         itertools.product([False, True], repeat=2))
def test_parity_vectorized_axis(continuous, chaos):
    """vectorized x {micro, continuous} x {calm, chaos}: the batched robot
    phase, the scalar event oracle and the dense tick loop must agree
    three ways on the busiest feature set (streamed + multicut, so codec
    switching, chunk reconfig and two-cut pricing all run through the
    batched kernels)."""
    cfg = _cfg(continuous=continuous, streamed=True, multicut=True,
               chaos=chaos)
    r_ticks = run_fleet(dataclasses.replace(cfg, engine="ticks"))
    r_scalar = run_fleet(dataclasses.replace(
        cfg, engine="events", vectorized=False))
    r_vec = run_fleet(dataclasses.replace(
        cfg, engine="events", vectorized=True))
    _assert_equal(r_ticks, r_scalar)
    _assert_equal(r_scalar, r_vec)


def test_parity_vectorized_arrivals_autoscale():
    """Events-only features (open arrivals, SLO hedging, autoscaling)
    where the tick engine cannot serve as oracle: the scalar event path
    is the reference and the batched path must match it exactly."""
    cfg = dataclasses.replace(
        _cfg(continuous=True, streamed=True, multicut=True),
        engine="events", n_replicas=3,
        arrival_processes=(ArrivalProcess("users", rate_hz=12.0),),
        slo_s=2.0, autoscale=True)
    r_scalar = run_fleet(dataclasses.replace(cfg, vectorized=False))
    r_vec = run_fleet(dataclasses.replace(cfg, vectorized=True))
    _assert_equal(r_scalar, r_vec)
    assert r_vec.n_open_arrivals > 0


def test_events_engine_seed_determinism():
    """Two event-engine runs at the same seed are dataclass-equal; a
    different seed must actually change the outcome (the arrival/straggler
    RNG streams are live, not dead code)."""
    cfg = dataclasses.replace(
        _cfg(continuous=True), engine="events",
        arrival_processes=(ArrivalProcess("users", rate_hz=10.0),),
        slo_s=2.0)
    r1, r2 = run_fleet(cfg), run_fleet(cfg)
    assert r1 == r2
    r3 = run_fleet(dataclasses.replace(cfg, seed=cfg.seed + 1))
    assert r1 != r3


@pytest.mark.parametrize("budget,drift", [(None, 0), (1.2e6, 15)])
def test_parity_scene_delta_axis(budget, drift):
    """Temporal-delta scene axis three ways: per-robot delta cadence
    state, measured wire pricing, drift replans and (budgeted) reference
    ledger evictions must replay identically in the dense tick loop, the
    scalar event path and the batched event path.  The delta codec is
    deliberately planned for a static scene while the fleet runs a
    dynamic one, so the drift schedule actually fires."""
    from repro.core.codec import make_delta_codec
    d = make_delta_codec(change_frac=0.02, name="delta")
    cfg = dataclasses.replace(
        _cfg(continuous=True, streamed=True, multicut=True),
        codecs=("identity", d, "int8"), scene="dynamic",
        delta_drift_every=drift, delta_ref_budget_bytes=budget)
    r_ticks = run_fleet(dataclasses.replace(cfg, engine="ticks"))
    r_scalar = run_fleet(dataclasses.replace(
        cfg, engine="events", vectorized=False))
    r_vec = run_fleet(dataclasses.replace(
        cfg, engine="events", vectorized=True))
    _assert_equal(r_ticks, r_scalar)
    _assert_equal(r_scalar, r_vec)
    assert r_ticks.n_keyframes > 0 and r_ticks.total_wire_bytes > 0
    if budget is not None:
        assert r_ticks.n_ref_evictions > 0
    if drift:
        assert r_ticks.n_delta_replans > 0


def test_scene_off_runs_unchanged():
    """scene=None must leave the report's delta fields at their zero
    defaults and stay bit-identical to a run that never had the axis
    (same RNG streams — the scene matrix draws from a disjoint stream
    only when a scene is configured)."""
    cfg = _cfg(continuous=True, streamed=True, multicut=True)
    a, b = _both(cfg)
    _assert_equal(a, b)
    assert a.total_wire_bytes == 0.0 and a.n_keyframes == 0
    assert a.n_delta_frames == 0 and a.n_ref_evictions == 0


def test_tick_engine_refuses_events_only_features():
    with pytest.raises(ValueError):
        run_fleet(dataclasses.replace(
            _cfg(chaos=False), engine="ticks",
            arrival_processes=(ArrivalProcess("u"),)))
    with pytest.raises(ValueError):
        run_fleet(dataclasses.replace(_cfg(chaos=False), autoscale=True))
    with pytest.raises(ValueError):
        run_fleet(dataclasses.replace(_cfg(chaos=False), engine="vortex"))
