"""Fleet simulator + vectorized Alg. 1: equivalence, determinism, elasticity."""
import dataclasses

import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.core import (Workload, build_graph, evaluate_split,
                        exhaustive_best, graph_arrays, search, search_vec,
                        sweep_search, total_weight_bytes)
from repro.core.hardware import A100, ORIN
from repro.runtime.fleet import (FleetConfig, FleetSimulator, ReplicaEvent,
                                 outage_schedule, run_fleet)
from repro.runtime.scheduler import MicroBatcher, Request

BWS = np.geomspace(0.05e6, 100e6, 17)
W = Workload()


# ------------------------------------------------- vectorized Alg. 1 search
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_vectorized_search_matches_scalar_every_config(arch):
    """search_vec must return the identical split to search/exhaustive_best
    for every registered config across a bandwidth sweep and budgets."""
    g = build_graph(get_config(arch), W)
    for budget in (None, 12e9, 0.4 * total_weight_bytes(g)):
        res = search_vec(g, ORIN, A100, BWS, cloud_budget_bytes=budget,
                         input_bytes=W.input_bytes)
        for j, bw in enumerate(BWS):
            seg = search(g, ORIN, A100, float(bw), cloud_budget_bytes=budget,
                         input_bytes=W.input_bytes)
            assert int(res.splits[j]) == seg.split, (arch, budget, bw)
            assert res.total_s[j] == pytest.approx(seg.total_s, rel=1e-12)
            best = exhaustive_best(g, ORIN, A100, float(bw),
                                   cloud_budget_bytes=budget,
                                   input_bytes=W.input_bytes)
            e, c, t = evaluate_split(g, best, ORIN, A100, float(bw),
                                     input_bytes=W.input_bytes)
            assert res.total_s[j] == pytest.approx(e + c + t, rel=1e-12)


def test_sweep_search_matches_per_model_search_vec():
    graphs = {k: build_graph(get_config(k), W) for k in sorted(ARCHS)}
    sw = sweep_search(graphs, ORIN, A100, BWS, input_bytes=W.input_bytes)
    for k, g in graphs.items():
        one = search_vec(g, ORIN, A100, BWS, input_bytes=W.input_bytes)
        assert np.array_equal(sw[k].splits, one.splits), k
        np.testing.assert_allclose(sw[k].total_s, one.total_s, rtol=1e-12)


def test_sweep_search_per_model_budgets():
    graphs = {k: build_graph(get_config(k), W)
              for k in ("openvla-7b", "llama3.2-3b")}
    budgets = {"openvla-7b": 12e9, "llama3.2-3b": None}
    sw = sweep_search(graphs, ORIN, A100, BWS, budgets,
                      input_bytes=W.input_bytes)
    for k, g in graphs.items():
        one = search_vec(g, ORIN, A100, BWS, cloud_budget_bytes=budgets[k],
                         input_bytes=W.input_bytes)
        assert np.array_equal(sw[k].splits, one.splits), k


def test_graph_arrays_latency_matches_evaluate_split():
    g = build_graph(get_config("cogact-7b"), W)
    ga = graph_arrays(g, ORIN, A100, input_bytes=W.input_bytes)
    for s in (0, 1, len(g) // 2, len(g)):
        ref = evaluate_split(g, s, ORIN, A100, 10e6, rtt_s=0.005,
                             input_bytes=W.input_bytes)
        got = ga.latency(s, 10e6, 0.005)
        assert got == pytest.approx(ref, rel=1e-12)


# ------------------------------------------------------------- MicroBatcher
def test_microbatcher_flush_drains_partial_batches():
    mb = MicroBatcher(batch_size=3, max_wait_s=10.0)
    for i in range(4):
        mb.add(Request(i, 0.0, 1))
    assert mb.maybe_form(0.1) is not None        # full batch forms
    assert mb.maybe_form(0.1) is None            # remainder under deadline
    b = mb.flush(0.1)
    assert b is not None and len(b.requests) == 1
    assert mb.flush(0.1) is None


# ------------------------------------------------------------------- fleet
def _small_cfg(**kw) -> FleetConfig:
    cfg = FleetConfig(n_robots=16, n_ticks=60, n_replicas=2,
                      archs=("openvla-7b", "cogact-7b", "llama3.2-3b"),
                      seed=3, **kw)
    return cfg


def test_fleet_heterogeneous_run_reports_sane_stats():
    rep = run_fleet(_small_cfg())
    assert len(rep.robots) == 16
    assert len({r.arch for r in rep.robots}) == 3
    assert rep.n_requests == sum(r.n_requests for r in rep.robots) > 0
    assert rep.throughput_rps > 0
    assert 0 < rep.fleet_p50_s <= rep.fleet_p95_s
    for r in rep.robots:
        assert r.n_requests > 0 and 0 < r.p50_s <= r.p95_s


def test_fleet_deterministic_under_fixed_seed():
    cfg = _small_cfg()
    a, b = run_fleet(cfg), run_fleet(cfg)
    assert a == b
    c = run_fleet(dataclasses.replace(cfg, seed=99))
    assert c.fleet_p50_s != a.fleet_p50_s or c.n_hedged != a.n_hedged


def test_fleet_outage_triggers_replans_and_recovery():
    cfg = _small_cfg()
    cfg.replica_events = outage_schedule(cfg)
    sim = FleetSimulator(cfg)
    initial = [ctl.split for ctl in sim.controllers]
    rep = sim.run()
    # full outage: one replan per robot down (edge-only) + one per robot up
    assert rep.n_replans == 2 * cfg.n_robots
    assert rep.n_outage_completions > 0
    # after recovery, re-running Alg. 1 restored the original plans
    for ctl, s0 in zip(sim.controllers, initial):
        assert ctl.split == s0 and ctl.pool.contains(ctl.split)


def test_fleet_edge_only_during_outage():
    """While the cloud tier is down, every controller's replan degrades to
    edge-only (split == n)."""
    cfg = _small_cfg()
    # outage from tick 20, never recovers
    cfg.replica_events = [ReplicaEvent(20, f"cloud{i}", "leave")
                          for i in range(cfg.n_replicas)]
    sim = FleetSimulator(cfg)
    rep = sim.run()
    assert rep.n_replans == cfg.n_robots
    for i, ctl in enumerate(sim.controllers):
        assert ctl.split == len(sim.graphs[sim.arch_of[i]])
    # edge-only requests completed during the outage window
    assert rep.n_outage_completions > 0


def test_fleet_partial_replica_loss_keeps_serving():
    cfg = _small_cfg()
    cfg.replica_events = [ReplicaEvent(10, "cloud1", "leave"),
                          ReplicaEvent(40, "cloud1", "join")]
    rep = run_fleet(cfg)
    assert rep.n_replans == 0            # cloud tier never fully vanished
    assert rep.n_requests > 0 and rep.throughput_rps > 0


def test_fleet_planned_splits_live_inside_pools():
    cfg = _small_cfg()
    sim = FleetSimulator(cfg)
    for i in range(cfg.n_robots):
        p = sim.controllers[i].pool
        for bw in (0.1e6, 1e6, 10e6, 40e6):
            assert p.start <= sim._planned_split(i, bw) <= p.end


# --------------------------------------------------------------- multi-cut
def _multicut_cfg(multicut: bool, bw: float = 1e6, **kw) -> FleetConfig:
    from repro.core import TraceConfig
    return FleetConfig(n_robots=8, n_ticks=60, n_replicas=2,
                       archs=("openvla-7b",), seed=3, multicut=multicut,
                       codecs=("identity", "int8", "int4"),
                       cloud_budget_bytes=5.8e9, down_bw_factor=8.0,
                       trace=TraceConfig(mean_bps=bw, bad_bps=bw / 4),
                       nominal_bw_bps=bw, **kw)


def test_fleet_multicut_serves_two_cut_requests():
    sim = FleetSimulator(_multicut_cfg(True))
    ctl = sim.controllers[0]
    assert not ctl.placement.is_single and ctl.pool2 is not None
    rep = sim.run()
    assert rep.n_multicut_requests > 0
    assert rep.n_requests > 0 and rep.fleet_p95_s > 0
    # placements stay inside both pools
    for i in range(sim.cfg.n_robots):
        s1, s2 = sim.place_of[i]
        ctl = sim.controllers[i]
        assert ctl.pool.contains(s1)
        assert ctl.pool2.contains(s2)


def test_fleet_multicut_beats_single_cut_p95_at_low_bandwidth():
    """Acceptance: on OpenVLA-7B at 1 MB/s under the per-robot cloud
    quota, the multi-cut plan table strictly beats the single-cut one in
    fleet p95 (same fleet, same seed, same codec axis)."""
    multi = run_fleet(_multicut_cfg(True))
    single = run_fleet(_multicut_cfg(False))
    assert multi.n_multicut_requests > 0
    assert single.n_multicut_requests == 0
    assert multi.fleet_p95_s < single.fleet_p95_s - 1e-9


def test_fleet_multicut_deterministic():
    cfg = _multicut_cfg(True)
    a, b = run_fleet(cfg), run_fleet(cfg)
    assert a == b


def test_fleet_multicut_outage_replans_to_edge_only():
    cfg = _multicut_cfg(True)
    cfg.replica_events = [ReplicaEvent(20, f"cloud{i}", "leave")
                          for i in range(cfg.n_replicas)]
    sim = FleetSimulator(cfg)
    rep = sim.run()
    assert rep.n_replans == cfg.n_robots
    for i, ctl in enumerate(sim.controllers):
        assert ctl.placement.is_single
        assert ctl.split == len(sim.graphs[sim.arch_of[i]])
    assert rep.n_outage_completions > 0


def test_fleet_single_mode_has_no_multicut_requests():
    rep = run_fleet(_small_cfg())
    assert rep.n_multicut_requests == 0


def test_replica_event_total_order_is_input_order_independent():
    """Regression: ReplicaEvent carries a total order (tick, kind,
    replica), so schedules listing a same-tick leave and join in either
    order sort — and therefore replay — identically.  Before the total
    order, ``sorted(..., key=lambda e: e.tick)`` was stable on the
    caller's construction order and two logically identical schedules
    could produce different fleets."""
    a = ReplicaEvent(30, "cloud1", "leave")
    b = ReplicaEvent(30, "cloud1", "join")
    assert sorted([a, b]) == sorted([b, a]) == [b, a]   # join < leave
    # ties break on replica name past (tick, kind)
    c = ReplicaEvent(30, "cloud0", "leave")
    assert sorted([a, c]) == [c, a]
    with pytest.raises(TypeError):          # __lt__ rejects non-events
        a < 42                              # noqa: B015

    cfg = _small_cfg()
    cfg.replica_events = [a, b]
    fwd = run_fleet(cfg)
    cfg.replica_events = [b, a]
    rev = run_fleet(cfg)
    assert fwd == rev
    # leave wins the tick: the replica is down right after tick 30
    sim = FleetSimulator(cfg)
    sim.run()
    assert "cloud1" in sim._down


def test_outage_schedule_is_sorted():
    cfg = _small_cfg()
    ev = outage_schedule(cfg)
    assert ev == sorted(ev)
