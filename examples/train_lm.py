"""Train a ~100M-param llama-family model for a few hundred steps, with a
mid-run injected failure to demonstrate checkpoint/restart (deliverable (b):
end-to-end training driver).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile
import time

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.launch.train import reduce_to_100m
from repro.models import build
from repro.runtime.fault import FaultPlan, Supervisor
from repro.train.optimizer import OptConfig
from repro.train.train_loop import init_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--d-model", type=int, default=512)
args = ap.parse_args()

cfg = reduce_to_100m(get_config("llama3.2-3b")).replace(
    d_model=args.d_model)
model = build(cfg)
print(f"model: {cfg.n_params() / 1e6:.1f}M params "
      f"({cfg.n_layers}L d{cfg.d_model})")

state = init_state(model.init(jax.random.PRNGKey(0)))
step_fn = jax.jit(make_train_step(
    model, OptConfig(lr=6e-4, warmup_steps=args.steps // 10),
    n_microbatches=2))
stream = SyntheticStream(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch, seed=1))

t0 = time.time()
logged = {"last": t0}


def logging_step(state, batch, key):
    state, m = step_fn(state, batch, key)
    s = int(m["step"])
    if s % 20 == 0:
        now = time.time()
        print(f"  step {s:4d}  loss {float(m['loss']):7.4f}  "
              f"gnorm {float(m['grad_norm']):6.2f}  "
              f"{20 / (now - logged['last'] + 1e-9):.2f} steps/s", flush=True)
        logged["last"] = now
    return state, m


with tempfile.TemporaryDirectory() as ckpt_dir:
    sup = Supervisor(ckpt_dir, ckpt_every=50)
    report = sup.run(state, stream, logging_step, args.steps,
                     key_fn=lambda s: jax.random.PRNGKey(s),
                     fault_plan=FaultPlan(fail_at=(args.steps // 2,)))

dt = time.time() - t0
tok_s = report.steps_done * args.batch * args.seq / dt
print(f"\n{report.steps_done} steps in {dt:.0f}s ({tok_s:,.0f} tok/s), "
      f"{report.restarts} restart(s) survived")
print(f"loss: {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")
assert report.losses[-1] < report.losses[0] * 0.7, "training must converge"
print("OK")
