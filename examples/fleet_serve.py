"""Fleet demo: 24 heterogeneous robots served by 3 shared cloud replicas.

Each robot runs its own RoboECC controller over its own fluctuating link;
cloud-side work is micro-batched per replica and hedged across replicas.
Mid-run, one replica drops (capacity crunch), then the whole cloud tier
goes dark — every controller replans to edge-only — and later recovers.

Picking a codec: ``FleetConfig.codecs`` is the split-boundary transport
axis (``core/codec.py`` names, preferred/lossless first).  The planner
searches (model × split × bandwidth × codec) jointly, so each robot lands
on the codec that minimises its end-to-end latency for its current link —
identity on fast links (no quantisation error for free), int8/int4 as the
link degrades.  Pin a single name (``codecs=("int8",)``) to force one
format fleet-wide, or set ``max_codec_err`` to cap the accuracy proxy.

    PYTHONPATH=src python examples/fleet_serve.py
"""
import numpy as np

from repro.runtime.fleet import FleetConfig, outage_schedule, run_fleet

cfg = FleetConfig(
    n_robots=24,
    archs=("openvla-7b", "cogact-7b", "llama3.2-3b", "glm4-9b"),
    n_ticks=400,
    n_replicas=3,
    codecs=("identity", "int8", "int4"),
    seed=0,
    # flight recorder (core/telemetry.py): "sampled" records ~1/64 of
    # requests — stage spans, metric sketches and the planner-drift
    # audit land in rep.metrics without perturbing the simulation
    telemetry="sampled",
)
cfg.replica_events = outage_schedule(cfg)
for ev in cfg.replica_events:
    print(f"  t={ev.tick * cfg.tick_s:5.1f}s  {ev.replica} {ev.kind}s")

rep = run_fleet(cfg)

print(f"\n{'robot':9s} {'arch':22s} {'n':>4s} {'p50 ms':>8s} {'p95 ms':>8s} "
      f"{'codec':>8s}")
for r in rep.robots:
    print(f"{r.name:9s} {r.arch:22s} {r.n_requests:4d} "
          f"{r.p50_s * 1e3:8.1f} {r.p95_s * 1e3:8.1f} {r.codec:>8s}")

print(f"\n{rep.summary()}")
print(f"outage-window completions (edge-only): {rep.n_outage_completions}")

drift = rep.metrics["drift"]
print(f"telemetry: {rep.metrics['n_recorded']} requests recorded, "
      f"{rep.metrics['spans']['kept']} span groups kept; planner drift "
      f"over {drift['n_joined']} joins, worst stage-sum mismatch "
      f"{drift['reconcile_max_abs_s']:.1e} s")
for stage, st in drift["stages"].items():
    print(f"  {stage:12s} mean err {st['mean_err']:+10.2e}  "
          f"p95 err {st['p95_err']:+10.2e}")

assert rep.throughput_rps > 0 and rep.fleet_p95_s >= rep.fleet_p50_s > 0
assert rep.n_replans > 0, "outage schedule should have triggered replans"
assert all(r.n_requests > 0 for r in rep.robots)
p95s = np.array([r.p95_s for r in rep.robots])
print(f"per-robot p95 spread: {p95s.min() * 1e3:.1f}–{p95s.max() * 1e3:.1f} ms")
print("OK")
