"""Run RoboECC's segmentation across ALL 10 assigned architectures + the
paper's own VLAs: per-arch optimal split, pool, and latency decomposition —
the paper's "diverse model structures" claim (Insight 1) at framework scale.

    PYTHONPATH=src python examples/multi_arch_segmentation.py
"""
from repro.configs import ARCHS, get_config
from repro.core import Workload, build_graph, build_pool, fixed_split, \
    evaluate_split, search
from repro.core.hardware import A100, ORIN

BW = 10e6

print(f"{'arch':24s} {'layers':>6s} {'split':>5s} {'edge ms':>8s} "
      f"{'cloud ms':>8s} {'net ms':>7s} {'total ms':>8s} {'vs fixed':>8s} "
      f"{'pool %':>6s}")
for arch in sorted(ARCHS):
    cfg = get_config(arch)
    w = Workload(decode_steps=7 if cfg.vla_action_head in ("detok", "")
                 and cfg.family == "vla" else 0)
    g = build_graph(cfg, w)
    budget = 0.9 * sum(c.weight_bytes for c in g)
    seg = search(g, ORIN, A100, BW, cloud_budget_bytes=budget)
    fx = sum(evaluate_split(g, fixed_split(g), ORIN, A100, BW))
    pool = build_pool(g, seg.split, overhead_target=0.028)
    print(f"{arch:24s} {len(g):6d} {seg.split:5d} {seg.edge_s * 1e3:8.1f} "
          f"{seg.cloud_s * 1e3:8.1f} {seg.net_s * 1e3:7.1f} "
          f"{seg.total_s * 1e3:8.1f} {fx / seg.total_s:7.2f}x "
          f"{pool.overhead_frac * 100:6.2f}")
print("\n(all 12 architectures segmented by the same Alg.1 + Eq.1/Eq.2 "
      "models; DESIGN.md §4)")
